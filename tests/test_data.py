"""Synthetic data + bandwidth trace generators."""
import numpy as np

from repro.data.bandwidth import MBPS, belgium_lte_like, dcn_trace, oboe_like_traces
from repro.data.synthetic import cifar_like, token_stream


def test_cifar_like_learnable():
    """Class templates are separable: nearest-template classification beats
    chance by a wide margin (so per-exit accuracy differences are real)."""
    rng = np.random.default_rng(0)
    x, y = cifar_like(rng, 256, noise=0.7)
    xt, yt = cifar_like(rng, 256, noise=0.7)
    # nearest-centroid on training means
    cents = np.stack([x[y == c].mean(0) for c in range(10)])
    pred = np.argmin(((xt[:, None] - cents[None]) ** 2).sum((2, 3, 4)), axis=1)
    assert (pred == yt).mean() > 0.5


def test_cifar_deterministic_templates():
    rng1 = np.random.default_rng(0)
    rng2 = np.random.default_rng(0)
    x1, y1 = cifar_like(rng1, 16)
    x2, y2 = cifar_like(rng2, 16)
    np.testing.assert_array_equal(x1, x2)


def test_token_stream_structure():
    rng = np.random.default_rng(0)
    toks = token_stream(rng, 8, 256, vocab=100)
    assert toks.shape == (8, 256)
    assert toks.min() >= 0 and toks.max() < 100
    # bigram structure: successor entropy far below uniform
    from collections import Counter
    pairs = Counter(zip(toks[:, :-1].ravel(), toks[:, 1:].ravel()))
    top = sum(c for _, c in pairs.most_common(100))
    assert top / sum(pairs.values()) > 0.5


def test_oboe_traces_stats():
    traces = oboe_like_traces(seed=0, num=428)
    assert len(traces) == 428
    means = np.array([t.mean() for t in traces]) / MBPS
    assert means.min() >= 0.0 and means.max() <= 6.5
    assert all(len(t) == 49 for t in traces)


def test_belgium_lte_range():
    tr = belgium_lte_like(seed=0, length=600, transport="bus")
    assert tr.shape == (600,)
    assert tr.min() > 0 and tr.max() <= 10.5 * MBPS


def test_dcn_trace_congestion_episodes():
    tr = dcn_trace(seed=0, length=600)
    gbps = tr * 8 / 1e9
    assert gbps.max() > 300          # uncongested baseline
    assert gbps.min() < 100          # congestion episodes exist
