"""Chunked scan == sequential oracle (both RWKV and Mamba semantics),
including a hypothesis sweep over shapes/decay ranges."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st  # hypothesis or skip-stub

from repro.models.linear_scan import scan_chunked, scan_sequential


def _inputs(seed, B, S, H, dk, dv, decay_scale):
    ks = jax.random.split(jax.random.key(seed), 6)
    q = jax.random.normal(ks[0], (B, S, H, dk))
    k = jax.random.normal(ks[1], (B, S, H, dk))
    v = jax.random.normal(ks[2], (B, S, H, dv))
    lw = -jnp.exp(jax.random.normal(ks[3], (B, S, H, dk)) * decay_scale)
    st0 = jax.random.normal(ks[4], (B, H, dk, dv)) * 0.2
    u = jax.random.normal(ks[5], (H, dk)) * 0.2
    return q, k, v, lw, st0, u


@pytest.mark.parametrize("rwkv", [True, False])
@pytest.mark.parametrize("chunk", [8, 16, 32])
def test_chunked_matches_sequential(rwkv, chunk):
    q, k, v, lw, st0, u = _inputs(0, 2, 64, 3, 8, 16, 0.5)
    uu = u if rwkv else None
    o1, s1 = scan_sequential(q, k, v, lw, st0, u=uu)
    o2, s2 = scan_chunked(q, k, v, lw, st0, u=uu, chunk=chunk)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2), rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(s1), np.asarray(s2), rtol=2e-4, atol=2e-4)


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 1000),
       B=st.integers(1, 3), nchunks=st.integers(1, 4),
       H=st.integers(1, 3), dk=st.sampled_from([4, 8, 16]),
       dv=st.sampled_from([4, 16]),
       rwkv=st.booleans(),
       decay=st.floats(0.1, 1.0))
def test_property_chunked_equivalence(seed, B, nchunks, H, dk, dv, rwkv, decay):
    S = 16 * nchunks
    q, k, v, lw, st0, u = _inputs(seed, B, S, H, dk, dv, decay)
    uu = u if rwkv else None
    o1, s1 = scan_sequential(q, k, v, lw, st0, u=uu)
    o2, s2 = scan_chunked(q, k, v, lw, st0, u=uu, chunk=16)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2), rtol=5e-4, atol=5e-4)
    np.testing.assert_allclose(np.asarray(s1), np.asarray(s2), rtol=5e-4, atol=5e-4)


def test_state_carry_composes():
    """scan(S1++S2) == scan(S2) after scan(S1) — the partition-cut invariant:
    shipping the recurrent state across a cut is lossless (DESIGN.md §4)."""
    q, k, v, lw, st0, u = _inputs(7, 1, 64, 2, 8, 8, 0.5)
    o_full, s_full = scan_sequential(q, k, v, lw, st0, u=u)
    o1, s1 = scan_sequential(q[:, :32], k[:, :32], v[:, :32], lw[:, :32], st0, u=u)
    o2, s2 = scan_sequential(q[:, 32:], k[:, 32:], v[:, 32:], lw[:, 32:], s1, u=u)
    np.testing.assert_allclose(np.asarray(o_full),
                               np.asarray(jnp.concatenate([o1, o2], axis=1)),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(s_full), np.asarray(s2), rtol=1e-5, atol=1e-5)
