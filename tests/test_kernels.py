"""Per-kernel allclose sweeps against the pure-jnp oracles (interpret=True
executes the Pallas kernel body on CPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st  # hypothesis or skip-stub

from repro.kernels.exit_head import ops as eh_ops
from repro.kernels.exit_head import ref as eh_ref
from repro.kernels.flash_attention import ops as fa_ops
from repro.kernels.flash_attention import ref as fa_ref
from repro.kernels.ssm_scan import ops as ss_ops
from repro.kernels.ssm_scan import ref as ss_ref


# ---------------------------------------------------------------- exit head
@pytest.mark.parametrize("B,S,D,V", [
    (2, 4, 64, 1000), (1, 7, 128, 313), (3, 1, 32, 2048), (1, 1, 16, 17),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_exit_head_sweep(B, S, D, V, dtype):
    ks = jax.random.split(jax.random.key(B * S + D + V), 2)
    h = jax.random.normal(ks[0], (B, S, D), dtype)
    emb = jax.random.normal(ks[1], (V, D), dtype)
    got = eh_ops.exit_confidence(h, emb, tile_rows=8, tile_v=128)
    # the kernel upcasts h/emb to f32 before the dot, so the oracle must do
    # the same — an einsum in bf16 rounds the logits and is the LESS precise
    # of the two, flipping argmax ties and drifting the entropy sum
    want = eh_ref.exit_confidence(h.astype(jnp.float32),
                                  emb.astype(jnp.float32))
    tol = 1e-5 if dtype == jnp.float32 else 1e-4
    assert bool(jnp.all(got["token"] == want["token"]))
    np.testing.assert_allclose(np.asarray(got["conf"]),
                               np.asarray(want["conf"]), rtol=tol, atol=tol)
    np.testing.assert_allclose(np.asarray(got["entropy"]),
                               np.asarray(want["entropy"]), rtol=tol, atol=tol)


def test_exit_head_confidence_semantics():
    """A peaked logit distribution -> conf near 1, entropy near 0."""
    D, V = 32, 500
    emb = jax.random.normal(jax.random.key(0), (V, D))
    h = 20.0 * emb[42][None, None, :]            # aligned with one row
    got = eh_ops.exit_confidence(h, emb, tile_rows=8, tile_v=128)
    assert int(got["token"][0, 0]) == 42
    assert float(got["conf"][0, 0]) > 0.9
    assert float(got["entropy"][0, 0]) < 0.5


# ------------------------------------------------------------ flash attention
@pytest.mark.parametrize("B,H,KV,S,hd,causal", [
    (1, 4, 2, 256, 64, True), (2, 8, 8, 128, 32, True),
    (1, 4, 1, 256, 64, False), (2, 2, 2, 64, 128, True),
])
def test_flash_attention_sweep(B, H, KV, S, hd, causal):
    ks = jax.random.split(jax.random.key(S + H), 3)
    q = jax.random.normal(ks[0], (B, S, H, hd), jnp.float32)
    k = jax.random.normal(ks[1], (B, S, KV, hd), jnp.float32)
    v = jax.random.normal(ks[2], (B, S, KV, hd), jnp.float32)
    got = fa_ops.flash_attention(q, k, v, causal=causal, block_q=64, block_k=64)
    want = fa_ref.attention(q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
                            v.transpose(0, 2, 1, 3), causal=causal
                            ).transpose(0, 2, 1, 3)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


def test_flash_attention_bf16():
    B, H, S, hd = 1, 2, 128, 64
    ks = jax.random.split(jax.random.key(9), 3)
    q = jax.random.normal(ks[0], (B, S, H, hd), jnp.bfloat16)
    k = jax.random.normal(ks[1], (B, S, H, hd), jnp.bfloat16)
    v = jax.random.normal(ks[2], (B, S, H, hd), jnp.bfloat16)
    got = fa_ops.flash_attention(q, k, v, causal=True, block_q=64, block_k=64)
    want = fa_ref.attention(q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
                            v.transpose(0, 2, 1, 3)).transpose(0, 2, 1, 3)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), rtol=5e-2, atol=5e-2)


# ------------------------------------------------------ decode attention
@pytest.mark.parametrize("B,H,KV,T,hd", [
    (3, 4, 2, 256, 64), (2, 8, 8, 128, 32), (1, 2, 1, 64, 128),
])
def test_decode_attention_sweep(B, H, KV, T, hd):
    """Single-query arena kernel vs the jnp oracle under ragged per-slot
    lengths (including a zero-length padded slot when B > 2)."""
    ks = jax.random.split(jax.random.key(T + H), 4)
    q = jax.random.normal(ks[0], (B, 1, H, hd), jnp.float32)
    k = jax.random.normal(ks[1], (B, T, KV, hd), jnp.float32)
    v = jax.random.normal(ks[2], (B, T, KV, hd), jnp.float32)
    lengths = jax.random.randint(ks[3], (B,), 1, T + 1)
    if B > 2:
        lengths = lengths.at[B - 1].set(0)     # an empty arena slot
    got = fa_ops.decode_attention(q, k, v, lengths, block_k=64)
    want = fa_ref.decode_attention(
        q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
        v.transpose(0, 2, 1, 3), lengths).transpose(0, 2, 1, 3)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


def test_decode_attention_bf16():
    B, H, T, hd = 2, 4, 128, 64
    ks = jax.random.split(jax.random.key(5), 3)
    q = jax.random.normal(ks[0], (B, 1, H, hd), jnp.bfloat16)
    k = jax.random.normal(ks[1], (B, T, H, hd), jnp.bfloat16)
    v = jax.random.normal(ks[2], (B, T, H, hd), jnp.bfloat16)
    lengths = jnp.asarray([7, 128], jnp.int32)
    got = fa_ops.decode_attention(q, k, v, lengths, block_k=64)
    want = fa_ref.decode_attention(
        q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
        v.transpose(0, 2, 1, 3), lengths).transpose(0, 2, 1, 3)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=5e-2, atol=5e-2)


def test_decode_attention_matches_causal_last_row():
    """Decoding position L-1 with lengths=[L] equals the last row of the
    causal prefill oracle — the kernel prices exactly the step the arena
    path runs."""
    B, H, T, hd = 1, 2, 64, 32
    ks = jax.random.split(jax.random.key(11), 3)
    k = jax.random.normal(ks[1], (B, T, H, hd), jnp.float32)
    v = jax.random.normal(ks[2], (B, T, H, hd), jnp.float32)
    q_full = jax.random.normal(ks[0], (B, T, H, hd), jnp.float32)
    full = fa_ref.attention(q_full.transpose(0, 2, 1, 3),
                            k.transpose(0, 2, 1, 3),
                            v.transpose(0, 2, 1, 3)).transpose(0, 2, 1, 3)
    got = fa_ops.decode_attention(q_full[:, -1:], k, v,
                                  jnp.asarray([T], jnp.int32), block_k=32)
    np.testing.assert_allclose(np.asarray(got), np.asarray(full[:, -1:]),
                               rtol=2e-5, atol=2e-5)


# ---------------------------------------------------------------- ssm scan
@pytest.mark.parametrize("B,S,H,dk,dv,rwkv", [
    (2, 64, 3, 8, 16, True), (2, 64, 3, 8, 16, False),
    (1, 32, 2, 64, 64, True), (1, 128, 4, 16, 64, False),
])
def test_ssm_scan_sweep(B, S, H, dk, dv, rwkv):
    ks = jax.random.split(jax.random.key(S + dk), 6)
    q = jax.random.normal(ks[0], (B, S, H, dk))
    k = jax.random.normal(ks[1], (B, S, H, dk))
    v = jax.random.normal(ks[2], (B, S, H, dv))
    lw = -jnp.exp(jax.random.normal(ks[3], (B, S, H, dk)) * 0.5)
    st0 = jax.random.normal(ks[4], (B, H, dk, dv)) * 0.1
    u = jax.random.normal(ks[5], (H, dk)) * 0.1 if rwkv else None
    o1, s1 = ss_ops.ssm_scan(q, k, v, lw, st0, u=u, chunk=16)
    o2, s2 = ss_ref.ssm_scan(q, k, v, lw, st0, u=u)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2), rtol=3e-4, atol=3e-4)
    np.testing.assert_allclose(np.asarray(s1), np.asarray(s2), rtol=3e-4, atol=3e-4)


@settings(max_examples=8, deadline=None)
@given(seed=st.integers(0, 100), nchunk=st.integers(1, 3),
       rwkv=st.booleans(), chunk=st.sampled_from([8, 16]))
def test_property_ssm_scan(seed, nchunk, rwkv, chunk):
    B, H, dk, dv = 1, 2, 8, 8
    S = chunk * nchunk
    ks = jax.random.split(jax.random.key(seed), 6)
    q = jax.random.normal(ks[0], (B, S, H, dk))
    k = jax.random.normal(ks[1], (B, S, H, dk))
    v = jax.random.normal(ks[2], (B, S, H, dv))
    lw = -jnp.exp(jax.random.normal(ks[3], (B, S, H, dk)) * 0.5)
    st0 = jnp.zeros((B, H, dk, dv))
    u = jax.random.normal(ks[5], (H, dk)) * 0.1 if rwkv else None
    o1, s1 = ss_ops.ssm_scan(q, k, v, lw, st0, u=u, chunk=chunk)
    o2, s2 = ss_ref.ssm_scan(q, k, v, lw, st0, u=u)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2), rtol=5e-4, atol=5e-4)
