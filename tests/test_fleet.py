"""Fleet simulator: event-loop determinism, router policy ordering, monotone
load response, device-local bypass — plus pick_exit edge cases.  All
scenarios are wired through the declarative ``repro.sim`` specs."""
import numpy as np
import pytest

from repro.fleet import EventQueue
from repro.serving.scheduler import pick_exit
from repro.sim import (RouterSpec, ScenarioSpec, Simulation, TopologySpec,
                       WorkloadSpec)


def test_event_queue_orders_by_time_then_fifo():
    q = EventQueue()
    q.push(2.0, "b")
    q.push(1.0, "a")
    q.push(1.0, "c")          # same timestamp -> FIFO by insertion
    assert [q.pop().kind for _ in range(3)] == ["a", "c", "b"]
    assert q.now == 2.0


def test_event_queue_tie_break_contract():
    """The ordering contract the vectorized sample sweep relies on
    (repro.fleet.events module docstring): same-timestamp events pop
    strictly in push order — independent of kind and payload — and an
    event pushed *while handling* time t pops after everything already
    scheduled at t.  This is what makes one fleet-wide sweep equivalent to
    the per-device sample events it batches: those popped contiguously in
    device (push) order, ahead of any same-time event pushed during their
    handling."""
    q = EventQueue()
    # interleave kinds/payloads that would sort differently than seq order
    q.push(1.0, "zzz", {"x": 1})
    q.push(1.0, "aaa", None)
    q.push(1.0, "mmm", 42)
    first = q.pop()
    assert (first.kind, q.now) == ("zzz", 1.0)
    # handling the first t=1.0 event schedules more work at the SAME time:
    # it must land after the rest of the t=1.0 batch
    q.push(1.0, "late-same-t")
    q.push(0.5, "earlier-time-is-still-earlier")  # but an earlier time wins
    kinds = [q.pop().kind for _ in range(4)]
    assert kinds == ["earlier-time-is-still-earlier", "aaa", "mmm",
                     "late-same-t"]
    # seq strictly increases across pushes, making the order total
    a = q.push(3.0, "x")
    b = q.push(3.0, "x")
    assert a.seq < b.seq


def test_pick_exit_nothing_fits_floors_at_one():
    per_exit = [0.5, 1.0, 2.0]
    assert pick_exit(0.0, per_exit, tokens_left=5, preferred=3) == 1
    assert pick_exit(-1.0, per_exit, tokens_left=5, preferred=3) == 1


def test_pick_exit_preferred_fits_stays_preferred():
    per_exit = [0.001, 0.002, 0.003]
    assert pick_exit(10.0, per_exit, tokens_left=5, preferred=3) == 3
    assert pick_exit(10.0, per_exit, tokens_left=5, preferred=2) == 2


def _spec(router, *, seed=2, nd=60, rate=80.0, horizon=20.0):
    return ScenarioSpec(
        name="fleet-test", seed=seed,
        topology=TopologySpec(num_devices=nd, num_edges=4, edge_capacity=8,
                              lo_mbps=0.1, hi_mbps=6.0,
                              max_edge_slowdown=4.0),
        workload=WorkloadSpec(rate_hz=rate, horizon_s=horizon,
                              arrival="diurnal", device_skew=1.0),
        router=RouterSpec(name=router))


def _run(router, **kw):
    return Simulation(_spec(router, **kw)).run()


def test_fleet_determinism_same_seed():
    a = _run("jsq").summary()
    b = _run("jsq").summary()
    assert a == b                      # bit-identical virtual-time metrics
    assert a["requests"] > 100


def test_jsq_beats_round_robin_under_skewed_load():
    rr = _run("round-robin").summary()["slo_attainment"]
    jsq = _run("jsq").summary()["slo_attainment"]
    assert jsq > rr


def test_slo_attainment_degrades_monotonically_with_rate():
    # nested workloads (subsampled from one spec-built draw) isolate the
    # load effect from arrival-sampling noise: build once at the top rate,
    # then re-run the same engine over strided subsets
    sc = Simulation(_spec("jsq", rate=640.0)).build()
    attains = []
    for stride in (16, 4, 1):          # rate 40 -> 160 -> 640
        wl = sc.workload[::stride]
        attains.append(sc.engine.run(wl).summary()["slo_attainment"])
    assert attains[0] >= attains[1] >= attains[2]
    assert attains[0] > attains[2]     # the effect is real, not flat


def test_device_only_plans_bypass_edges():
    m = _run("jsq")
    local = [r for r in m.records if r.edge == -1]
    offloaded = [r for r in m.records if r.edge >= 0]
    assert local and offloaded         # mixed-bandwidth fleet splits both ways
    assert all(r.partition == 0 for r in local)
    # local queue delay comes only from the device's own serial execution,
    # never from an edge queue: each device's first local request starts
    # immediately
    first_local = {}
    for r in sorted(local, key=lambda r: r.arrival_s):
        first_local.setdefault(r.device, r)
    assert all(r.queue_delay_s == 0.0 for r in first_local.values())


def test_shared_plan_cache_is_populated():
    sc = Simulation(ScenarioSpec(
        name="plan-cache", seed=0,
        topology=TopologySpec(num_devices=30, num_edges=2),
        workload=WorkloadSpec(rate_hz=30.0, horizon_s=10.0),
        router=RouterSpec(name="bandwidth-aware"))).build()
    sc.engine.run(sc.workload)
    # many devices, few quantized bandwidth states -> far fewer searches
    assert 0 < len(sc.engine.stepper.plan_cache) < len(sc.workload)
