"""Mini dry-run on 8 virtual host devices (subprocess — the device-count env
var must be set before jax initializes, and the main test process must keep
seeing 1 device)."""
import json
import os
import subprocess
import sys

import pytest

SRC = os.path.abspath(os.path.join(os.path.dirname(__file__), "..", "src"))

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json
import jax, jax.numpy as jnp
from repro.configs import get_smoke_config
from repro.config import ShapeConfig
from repro.models import Model
from repro.launch.mesh import mesh_axis_kwargs
from repro.launch.steps import make_step
from repro.launch.dryrun import collective_stats

arch, kind, multipod = "%(arch)s", "%(kind)s", %(multipod)s
if multipod:
    mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"),
                         **mesh_axis_kwargs(3))
else:
    mesh = jax.make_mesh((2, 4), ("data", "model"),
                         **mesh_axis_kwargs(2))
cfg = get_smoke_config(arch)
model = Model(cfg)
shape = ShapeConfig("t", 64, 8, kind)
step, abstract_inputs = make_step(model, mesh, shape)
with mesh:
    lowered = step.lower(*abstract_inputs())
compiled = lowered.compile()
ca = compiled.cost_analysis()
if isinstance(ca, list):               # older jax: list of per-device dicts
    ca = ca[0] if ca else {}
coll = collective_stats(compiled.as_text())
print(json.dumps({"flops": ca.get("flops", 0.0),
                  "coll": coll["total_link_bytes"],
                  "mem": compiled.memory_analysis().argument_size_in_bytes}))
"""


def _run(arch, kind, multipod):
    env = dict(os.environ, PYTHONPATH=SRC)
    env.pop("XLA_FLAGS", None)
    out = subprocess.run([sys.executable, "-c",
                          SCRIPT % dict(arch=arch, kind=kind, multipod=multipod)],
                         capture_output=True, text=True, env=env, timeout=600)
    assert out.returncode == 0, out.stderr[-3000:]
    return json.loads(out.stdout.strip().splitlines()[-1])


@pytest.mark.slow
@pytest.mark.parametrize("arch,kind", [
    ("granite-3-2b", "train"),
    ("llama4-scout-17b-a16e", "train"),
    ("rwkv6-3b", "decode"),
    ("zamba2-2.7b", "prefill"),
])
def test_small_mesh_dryrun(arch, kind):
    r = _run(arch, kind, False)
    assert r["flops"] > 0
    assert r["coll"] > 0      # sharded step must communicate


@pytest.mark.slow
def test_small_mesh_multipod():
    r = _run("granite-3-2b", "train", True)
    assert r["flops"] > 0 and r["coll"] > 0
