"""Pins for the vectorized fleet hot path (PR: 10k-device simulations).

The rewrite's contract is *bit-identical metrics, order-of-magnitude
faster*; these tests pin the bit-identical half:

* batched mobility geometry (positions/distances/bandwidth matrices and
  rows) equals the scalar law entry by entry,
* the vectorized ``JointPlanner.decide`` equals its scalar reference on
  every arrival of a live simulation,
* streaming ``FleetMetrics`` aggregates equal the record-replay computation
  (hypothesis-fuzzed) and are unaffected by ``retain_records``,
* the ``smoke-lm`` / ``smoke-mobility`` registry scenarios reproduce the
  exact pre-refactor summaries (golden floats recorded before the rewrite),
* tombstoned queue entries behave as removals,
* ``_on_arrival`` prices the plan at the *serving* edge's bandwidth under
  mobility (not the best-signal bandwidth the router shopped with).
"""
import numpy as np
import pytest

from _hypothesis_compat import HAVE_HYPOTHESIS, given, settings, st
from repro.fleet.metrics import FleetMetrics, RequestRecord
from repro.sim import (MobilitySpec, PlannerSpec, RouterSpec, ScenarioSpec,
                       Simulation, TopologySpec, WorkloadSpec, get_scenario)

# ---------------------------------------------------------------- mobility


def _mobile_scenario(**kw):
    spec = ScenarioSpec(
        name="perf-mob", seed=kw.pop("seed", 0),
        planner=PlannerSpec(result_kb=4.0),
        topology=TopologySpec(kind="mobile", num_devices=kw.pop("nd", 12),
                              num_edges=kw.pop("ne", 5), speed=0.4,
                              horizon_s=30.0, noise_sigma=0.1),
        workload=WorkloadSpec(rate_hz=4.0, horizon_s=6.0),
        router=RouterSpec(name="nearest"),
        mobility=MobilitySpec(policy="bocd"))
    return Simulation(spec).build()


def _ulp_diff(a: float, b: float) -> int:
    ia = np.float64(a).view(np.int64)
    ib = np.float64(b).view(np.int64)
    return abs(int(ia) - int(ib))


def test_vectorized_mobility_matches_scalar():
    """Positions, distances, and the replan bandwidth row must equal the
    scalar calls *bitwise* (they price billing and replans); the sweep's
    bandwidth matrix — observation input only — is allowed numpy's
    vectorized-pow rounding of at most 1 ulp (see MobilityModel.bw_matrix).
    Boundaries covered: t=0 (first waypoint), far beyond the horizon
    (parked devices), off-grid interior times."""
    sc = _mobile_scenario()
    mob = sc.mobility
    n, m = len(mob.trajectories), len(mob.edge_pos)
    for t in (0.0, 0.37, 1.0, 7.77, 15.5, 29.99, 31.0, 500.0):
        pos = mob.positions_at(t)
        dist = mob.distances_at(t)
        bw = mob.bw_matrix(t)
        for d in range(n):
            assert pos[d].tolist() == mob.pos(d, t).tolist(), (d, t)
            row_d = mob.distance_row(d, t)
            row_b = mob.bw_row(d, t)
            for e in range(m):
                assert float(dist[d, e]) == mob.distance(d, e, t), (d, e, t)
                assert float(row_d[e]) == mob.distance(d, e, t), (d, e, t)
                assert float(row_b[e]) == mob.bw(d, e, t), (d, e, t)
                # the vectorized pow's 1-ulp rounding difference can grow
                # by a couple more ulp through the following divide/noise
                assert _ulp_diff(float(bw[d, e]),
                                 mob.bw(d, e, t)) <= 4, (d, e, t)
            assert mob.nearest(d, t) == int(np.argmin(dist[d]))


def test_sample_sweep_equals_per_device_observe():
    """One fleet-wide sweep tick fires exactly the devices a per-device
    loop of scalar detectors would, in ascending id order — both fed the
    *same* per-slot matrices, so this pins the BOCDBank lockstep update and
    the firing/rate-limit logic (the geometry equivalence is pinned
    separately)."""
    from repro.core.bocd import BandwidthStateDetector
    from repro.fleet.mobility import MBPS, HandoverController
    sc = _mobile_scenario()
    mob = sc.mobility
    n = len(mob.trajectories)
    ctrl = HandoverController(mob, policy="bocd", min_gap_s=0.0)
    detectors = {}
    rng = np.random.default_rng(0)
    for k in range(1, 40):
        t = 0.5 * k
        servings = [tuple(sorted(int(e) for e in rng.choice(
            len(mob.edge_pos), size=rng.integers(0, 3), replace=False)))
            for _ in range(n)]
        dist, bw = mob.distances_at(t), mob.bw_matrix(t)
        # reference: the pre-sweep per-device grid, on the same matrices
        fired_ref = []
        for d in range(n):
            serving = servings[d]
            if serving:
                eid = max(serving, key=lambda e: (float(dist[d, e]), e))
            else:
                eid = int(np.argmin(dist[d]))
            det = detectors.setdefault(d, BandwidthStateDetector(
                hazard=ctrl.hazard))
            before = len(det.changes)
            det.update(float(bw[d, eid]) / MBPS)
            if len(det.changes) > before and serving:
                fired_ref.append(d)
        fired_sweep = ctrl.observe_sweep(t, servings, dist, bw)
        assert fired_ref == fired_sweep, (k, fired_ref, fired_sweep)


def test_oracle_sweep_equals_per_device_observe():
    from repro.fleet.mobility import HandoverController
    sc = _mobile_scenario()
    mob = sc.mobility
    n = len(mob.trajectories)
    a = HandoverController(mob, policy="oracle", min_gap_s=0.0)
    b = HandoverController(mob, policy="oracle", min_gap_s=0.0)
    rng = np.random.default_rng(1)
    for k in range(1, 40):
        t = 0.5 * k
        servings = [tuple(sorted(rng.choice(
            len(mob.edge_pos), size=rng.integers(0, 3), replace=False)))
            for _ in range(n)]
        assert [d for d in range(n) if a.observe(d, t, servings[d])] == \
            b.observe_sweep(t, servings, mob.distances_at(t),
                            mob.bw_matrix(t))


# ---------------------------------------------------------------- planner


def test_joint_decide_vectorized_matches_scalar():
    """Every arrival of a live coop simulation: the vectorized candidate
    scoring must pick the identical (plan, assignment, estimates)."""
    import repro.fleet.joint as J
    checked = [0]
    orig = J.JointPlanner.decide

    def both(self, req, device, topo, now):
        a = orig(self, req, device, topo, now)
        b = J.JointPlanner.decide_scalar(self, req, device, topo, now)
        assert (a.plan, a.assign, a.est_s, a.est_min_s) == \
            (b.plan, b.assign, b.est_s, b.est_min_s), req.rid
        checked[0] += 1
        return a

    spec = ScenarioSpec(
        name="joint-vec", seed=5,
        topology=TopologySpec(num_devices=16, num_edges=4, edge_capacity=4,
                              lo_mbps=0.1, hi_mbps=6.0,
                              max_edge_slowdown=4.0),
        workload=WorkloadSpec(rate_hz=20.0, horizon_s=6.0, device_skew=1.0),
        router=RouterSpec(name="joint"))
    J.JointPlanner.decide = both
    try:
        Simulation(spec).run()
    finally:
        J.JointPlanner.decide = orig
    assert checked[0] > 50


# ---------------------------------------------------------------- metrics


def _replay_summary(m: FleetMetrics) -> dict:
    """The pre-streaming FleetMetrics.summary, recomputed from retained
    records — the oracle the running aggregates must match bitwise."""
    if not m.records:
        # schema-complete empty summary (ISSUE 6 satellite): same keys as
        # the populated path, None for undefined statistics, and the
        # non-request aggregates reported from what was actually observed
        horizon = max(m.horizon_s, 1e-9)
        return {
            "requests": 0,
            "coop_requests": 0,
            "handovers": len(m.handover_log),
            "migrated_mb": round(
                sum(h[3] for h in m.handover_log) / 1e6, 6),
            "handover_slo": None,
            "backbone_mb": round(sum(m.transfer_bytes.values()) / 1e6, 6),
            "coop_busy_s": {eid: round(v, 6)
                            for eid, v in sorted(m.coop_busy_s.items())},
            "slo_attainment": 0.0,
            "p50_latency_s": None,
            "p95_latency_s": None,
            "p99_latency_s": None,
            "mean_queue_delay_s": None,
            "makespan_s": float(m.horizon_s),
            "edge_utilization": {
                eid: round(m.edge_busy_s.get(eid, 0.0) / horizon, 6)
                for eid in range(m.num_edges)},
            "slo_by_tenant": {},
            "exit_histogram": {},
            "partition_histogram": {},
        }
    lat = np.array([r.latency_s for r in m.records])
    met = np.array([r.met_slo for r in m.records])
    qd = np.array([r.queue_delay_s for r in m.records])
    horizon = max(m.horizon_s, 1e-9)
    util = {eid: round(m.edge_busy_s.get(eid, 0.0) / horizon, 6)
            for eid in range(m.num_edges)}
    exits, parts, per_tenant = {}, {}, {}
    for r in m.records:
        exits[r.exit_point] = exits.get(r.exit_point, 0) + 1
        parts[r.partition] = parts.get(r.partition, 0) + 1
        per_tenant.setdefault(r.tenant, []).append(r.met_slo)
    coop = sum(1 for r in m.records if len(r.edges) > 1)
    moved = [r.met_slo for r in m.records if r.handovers > 0]
    return {
        "requests": len(m.records),
        "coop_requests": coop,
        "handovers": len(m.handover_log),
        "migrated_mb": round(sum(h[3] for h in m.handover_log) / 1e6, 6),
        "handover_slo": float(np.mean(moved)) if moved else None,
        "backbone_mb": round(sum(m.transfer_bytes.values()) / 1e6, 6),
        "coop_busy_s": {eid: round(v, 6)
                        for eid, v in sorted(m.coop_busy_s.items())},
        "slo_attainment": float(np.mean(met)),
        "p50_latency_s": float(np.percentile(lat, 50)),
        "p95_latency_s": float(np.percentile(lat, 95)),
        "p99_latency_s": float(np.percentile(lat, 99)),
        "mean_queue_delay_s": float(np.mean(qd)),
        "makespan_s": float(m.horizon_s),
        "edge_utilization": util,
        "slo_by_tenant": {k: float(np.mean(v))
                          for k, v in sorted(per_tenant.items())},
        "exit_histogram": dict(sorted(exits.items())),
        "partition_histogram": dict(sorted(parts.items())),
    }


def _feed(metrics: FleetMetrics, events: list):
    rid = 0
    for kind, a, b, c in events:
        if kind == 0:
            arrival, lat, qdelay = a, b, c
            metrics.record(RequestRecord(
                rid=rid, tenant=("t%d" % (rid % 3)), device=rid % 5,
                edge=rid % 4 - 1, arrival_s=arrival, finish_s=arrival + lat,
                latency_s=lat, queue_delay_s=qdelay,
                met_slo=bool(rid % 2), exit_point=1 + rid % 3,
                partition=rid % 5,
                edges=tuple(range(rid % 3)), handovers=rid % 3,
                migrated_bytes=(rid % 3) * 1000))
            rid += 1
        elif kind == 1:
            metrics.add_busy(int(a) % 4, b)
        elif kind == 2:
            metrics.add_transfer(int(a) % 4, int(b) % 4, int(c * 1e6))
        elif kind == 3:
            metrics.add_handover(int(a) % 4, int(b) % 4, int(c * 1e6), a + b)
            metrics.add_coop_busy(int(a) % 4, c)


@settings(max_examples=30, deadline=None)
@given(events=st.lists(
    st.tuples(st.integers(min_value=0, max_value=3),
              st.floats(min_value=0.0, max_value=100.0),
              st.floats(min_value=0.0, max_value=50.0),
              st.floats(min_value=0.0, max_value=10.0)),
    min_size=0, max_size=60))
def test_streaming_metrics_equal_record_replay(events):
    """Property: for any record stream, the streaming aggregates reproduce
    the record-replay summary bitwise, and dropping retention changes
    nothing but the retention itself."""
    retained = FleetMetrics(num_edges=4)
    compact = FleetMetrics(num_edges=4, retain_records=False)
    _feed(retained, events)
    _feed(compact, events)
    assert retained.summary() == _replay_summary(retained)
    assert compact.summary() == retained.summary()
    assert compact.records == [] and compact.handover_log == []
    assert compact.handover_count == retained.handover_count
    assert compact.migrated_bytes_total == retained.migrated_bytes_total


def test_streaming_metrics_equal_replay_end_to_end():
    """Same property on a real simulation's metrics (mobility + handovers:
    every aggregate path exercised)."""
    m = Simulation(get_scenario("smoke-mobility")).run()
    assert m.summary() == _replay_summary(m)


def test_retain_records_off_is_bit_identical_end_to_end():
    from dataclasses import replace
    base = get_scenario("smoke-mobility")
    spec = replace(base, engine=replace(base.engine, retain_records=False))
    a = Simulation(base).run()
    b = Simulation(spec).run()
    assert a.summary() == b.summary()
    assert b.records == [] and b.handover_log == []
    assert b.handover_count == a.handover_count


# ------------------------------------------------- pre/post refactor pins
# Golden floats recorded from the pre-rewrite engine (PR 4 tree) on the
# registry scenarios: the vectorized hot path must reproduce them exactly.

GOLDEN_SMOKE_LM = {
    "requests": 1356,
    "slo_attainment": 0.5376106194690266,
    "p50_latency_s": 0.6615177717071261,
    "p95_latency_s": 47.09573076173493,
    "p99_latency_s": 53.90370828429884,
    "mean_queue_delay_s": 7.599025976156218,
    "makespan_s": 84.68538310386597,
    "handovers": 0,
}

GOLDEN_SMOKE_MOBILITY = {
    "requests": 229,
    "slo_attainment": 0.7903930131004366,
    "p50_latency_s": 1.2943226555145273,
    "p95_latency_s": 5.6746156237852325,
    "p99_latency_s": 8.180382220563278,
    "mean_queue_delay_s": 0.32956362837827147,
    "makespan_s": 30.465720733163874,
    "handovers": 11,
    "migrated_mb": 0.674976,
    "handover_slo": 0.9090909090909091,
}

GOLDEN_SMOKE_MOBILITY_HANDOVER_LOG = [
    (5.011523842, 0, 2, 82944), (7.002356407, 0, 2, 86240),
    (8.514628819, 1, 2, 52224), (10.046677844, 3, 0, 27648),
    (10.514468059, 3, 0, 31488), (14.563789431, 3, 0, 65856),
    (16.013483797, 1, 3, 71424), (20.028524651, 3, 0, 53760),
    (22.023524772, 3, 1, 85248), (24.519732814, 3, 1, 49152),
    (26.504036614, 2, 0, 68992),
]


def test_smoke_lm_summary_pinned_pre_refactor():
    s = Simulation(get_scenario("smoke-lm")).run().summary()
    for key, val in GOLDEN_SMOKE_LM.items():
        assert s[key] == val, key


def test_smoke_mobility_summary_pinned_pre_refactor():
    m = Simulation(get_scenario("smoke-mobility")).run()
    s = m.summary()
    for key, val in GOLDEN_SMOKE_MOBILITY.items():
        assert s[key] == val, key
    assert m.handover_log == GOLDEN_SMOKE_MOBILITY_HANDOVER_LOG


# ---------------------------------------------------------------- engine


def test_tombstoned_queue_entry_is_skipped():
    """A dequeued request's heap entry stays physically queued but must
    never be admitted, and backlog() must not count it."""
    import heapq

    from repro.fleet.cluster import EdgeNode
    sc = Simulation(get_scenario("smoke-lm")).build()
    eng, wl = sc.engine, sc.workload
    eng._qseq, eng._qentry = 0, {}
    edge = EdgeNode(0, capacity=2)
    r1, r2, r3 = wl[0], wl[1], wl[2]
    for r in (r1, r2, r3):
        r.admitted_s, r.assign, r.plan = None, None, sc.engine.stepper.plan(1e6)
        r.prefill_pending = False
    for r in (r1, r2, r3):
        eng._enqueue(edge, r)
    assert edge.backlog() == 3
    eng._dequeue(edge, r2)
    assert edge.backlog() == 2 and edge.q_dead == 1
    admitted = []
    while edge.queue and len(admitted) < 3:
        req = heapq.heappop(edge.queue)[2]
        if req is None:
            edge.q_dead -= 1
            continue
        admitted.append(req)
    assert [id(a) for a in admitted] == \
        [id(r) for r in sorted((r1, r3), key=lambda r: r.deadline_s)]
    assert edge.q_dead == 0


def test_arrival_plans_at_serving_edge_bandwidth():
    """Satellite fix: with a placement policy that is *not* nearest-edge,
    the admitted plan must be priced at the serving edge's bandwidth, not
    the best-signal bandwidth the router shopped with."""
    from repro.fleet.events import EventQueue
    from repro.fleet.metrics import FleetMetrics as FM
    spec = ScenarioSpec(
        name="arrival-bw", seed=2,
        planner=PlannerSpec(result_kb=4.0),
        # flat-ish path loss so non-nearest edges still sustain offloading
        # and jsq genuinely places requests away from the nearest edge
        topology=TopologySpec(kind="mobile", num_devices=12, num_edges=4,
                              speed=0.0, horizon_s=30.0, noise_sigma=0.0,
                              peak_mbps=20.0, d_ref=0.6),
        workload=WorkloadSpec(rate_hz=20.0, horizon_s=4.0),
        router=RouterSpec(name="jsq"))
    sc = Simulation(spec).build()
    eng, mob = sc.engine, sc.mobility
    evq = EventQueue()
    eng._qseq, eng._pending = 0, len(sc.workload)
    eng._qentry = {}
    eng._dev_inflight = {d.did: [] for d in sc.topo.devices}
    metrics = FM(num_edges=sc.topo.num_edges)
    differing = repriced = 0
    for req in sc.workload:
        device = sc.topo.devices[req.device]
        evq.now = req.arrival_s
        bw_best = device.link.bw_at(req.arrival_s)
        eng._on_arrival(req, evq, metrics)
        if req.edge < 0:                   # device-only fallback is legal
            assert req.plan.partition == 0
            continue
        bw_serve = mob.bw(device.did, req.edge, evq.now)
        assert req.plan == eng.stepper.plan(bw_serve), req.rid
        if req.edge != mob.nearest(device.did, req.arrival_s):
            differing += 1                 # serving != best-signal edge
            if eng.stepper.plan(bw_serve) != eng.stepper.plan(bw_best):
                repriced += 1              # ... and the plan truly changed
    # jsq spreads load, so the property must have been exercised for real
    assert differing > 0 and repriced > 0


@pytest.mark.perf
def test_thousand_device_mobility_cell_runs():
    """Scale smoke (marked perf): a 1k-device mobility cell with the full
    sampling + BOCD + handover pipeline completes and drains."""
    from dataclasses import replace
    base = get_scenario("smoke-mobility")
    spec = replace(
        base,
        topology=replace(base.topology, num_devices=1000, num_edges=10),
        workload=replace(base.workload, rate_per_device_hz=0.05,
                         horizon_s=10.0),
        engine=replace(base.engine, retain_records=False))
    sc = Simulation(spec).build()
    m = sc.engine.run(sc.workload)
    assert m.summary()["requests"] == len(sc.workload)
    assert sc.engine.events_processed > 10000
    for e in sc.topo.edges:
        assert e.backlog() == 0 and e.tokens_owed == 0


if HAVE_HYPOTHESIS:
    def test_perf_property_suite_is_active():
        assert True


def test_sample_sweep_with_controller_but_no_engine_mobility():
    """A pre-built HandoverController passed without mobility= must keep
    working (the sweep falls back to the controller's own mobility model;
    regression: the batched sweep used to dereference engine.mobility)."""
    from repro.fleet.engine import FleetEngine
    from repro.fleet.mobility import HandoverController
    sc = _mobile_scenario()
    eng = FleetEngine(sc.topo, sc.graph, sc.planner, router="jsq",
                      handover=HandoverController(sc.mobility,
                                                  policy="bocd"))
    m = eng.run(sc.workload)
    assert m.summary()["requests"] == len(sc.workload)


def _congested_mobile_spec():
    """smoke-mobility at capacity 1 and 3x the arrival rate: queues build
    while devices move, so BOCD replans tombstone queued requests (the
    workload test_tombstoned_queue_entry_is_skipped exercises in vitro)."""
    from dataclasses import replace
    base = get_scenario("smoke-mobility")
    return replace(base, name="tombstone-compaction",
                   topology=replace(base.topology, edge_capacity=1),
                   workload=replace(base.workload, rate_per_device_hz=0.6,
                                    horizon_s=15.0))


def test_heap_compaction_fires_and_is_bit_identical():
    """Satellite fix for unbounded tombstone-heap growth: with an
    aggressive threshold every tombstone triggers a heap rebuild; with
    compaction disabled the heap only ever grows.  Pop order is a total
    order on (deadline, seq) either way, so summaries and the handover log
    must not move by a single bit."""
    spec = _congested_mobile_spec()
    sc = Simulation(spec).build()

    sc.engine.compact_ratio = 0.0          # compact on every tombstone
    m_on = sc.engine.run(sc.workload)
    assert sc.engine.tombstoned > 0        # the scenario genuinely queues
    assert sc.engine.compactions > 0
    compactions_on = sc.engine.compactions

    sc.engine.compact_ratio = None         # lazy deletion only
    m_off = sc.engine.run(sc.workload)
    assert sc.engine.compactions == 0

    assert m_on.summary() == m_off.summary()
    assert m_on.handover_log == m_off.handover_log
    assert compactions_on == sc.engine.tombstoned


def test_default_compaction_threshold_matches_disabled():
    """The shipping default (compact at 50% dead) is also bit-identical to
    no compaction on the congested scenario."""
    spec = _congested_mobile_spec()
    sc = Simulation(spec).build()
    assert sc.engine.compact_ratio == 0.5
    m_def = sc.engine.run(sc.workload)
    sc.engine.compact_ratio = None
    m_off = sc.engine.run(sc.workload)
    assert m_def.summary() == m_off.summary()
    assert m_def.handover_log == m_off.handover_log
