"""Fleet-elasticity test suite (docs/elastic.md): autoscaling, admission
control, and the $/slot-hour price model.

* property suite over (seed, workload rate, autoscale policy, admission
  on/off): exactly-once completion accounting under forced scale-down
  drains, capacity never negative and never reclaimed under a busy slot,
  ``completed + rejected == issued`` once the fleet drains, ``cost_usd``
  equal (float-exact) to the piecewise-constant integral of the capacity
  timeline reconstructed from the scale-event log, and rerun determinism of
  summaries and the scale-event log;
* bit-identity pins: with elasticity disabled, the ``smoke-lm`` / ``coop``
  / ``smoke-mobility`` summaries *and* handover logs are byte-identical to
  the pre-elasticity goldens in tests/goldens/;
* direct unit tests for :class:`repro.runtime.elastic.ElasticPlanner`
  (``plan_for`` / ``shrink_event``, the shrink-below-one-chip clamp, the
  explicit-calibration re-scaling the fleet shrink path relies on);
* :meth:`FleetMetrics.summary` schema-completeness when every request is
  rejected (None-for-undefined, never NaN);
* the cost-vs-SLO Pareto frontier over a diurnal elastic sweep is
  non-degenerate (>= 3 non-dominated points).
"""
import dataclasses
import json
import os

import pytest

from _hypothesis_compat import HAVE_HYPOTHESIS, given, settings, st
from repro.fleet.elastic import AdmissionControl, Autoscaler
from repro.fleet.metrics import FleetMetrics
from repro.fleet.workload import TenantClass
from repro.sim import (AdmissionSpec, AutoscaleSpec, RouterSpec,
                       ScenarioSpec, Simulation, TopologySpec, WorkloadSpec,
                       apply_overrides, get_scenario)

GOLDEN_DIR = os.path.join(os.path.dirname(__file__), "goldens")
# long decode per request so edge slots are genuinely scarce and the
# autoscaler/admission gate both fire (same trick as the mobility suites)
SLOW_TENANTS = (
    TenantClass("stream", slo_s=2.0, max_new_tokens=48, weight=0.7),
    TenantClass("batch", slo_s=6.0, max_new_tokens=96, weight=0.3),
)


def _elastic_spec(*, seed=0, nd=10, ne=3, rate=10.0, horizon=8.0, cap=2,
                  autoscale=None, admission=None, router="bandwidth-aware"):
    return ScenarioSpec(
        name="elastic-invariants", seed=seed,
        topology=TopologySpec(num_devices=nd, num_edges=ne,
                              edge_capacity=cap, lo_mbps=0.1, hi_mbps=6.0,
                              max_edge_slowdown=4.0),
        workload=WorkloadSpec(rate_hz=rate, horizon_s=horizon,
                              tenants=SLOW_TENANTS),
        router=RouterSpec(name=router),
        autoscale=autoscale, admission=admission)


DRAIN_AUTOSCALE = AutoscaleSpec(min_slots=1, max_slots=6, decide_dt=0.25,
                                up_backlog_s=0.5, down_util=1.0, step=2)
#                                                ^ down_util=1.0: scale-down
# fires whenever an edge's queue is empty even with every slot busy, so
# the drain path (reclaim only at round boundaries) is exercised constantly


class _ElasticQueue:
    """EventQueue proxy asserting, at every event pop, that the clock is
    monotone and that no edge's provisioned capacity ever drops below 1 or
    below its busy-slot count (scale-down must drain, never preempt)."""

    def __init__(self, inner, engine):
        self._inner, self._engine = inner, engine
        self.saw_drain = False          # a pop while a drain was pending

    def push(self, *a, **k):
        return self._inner.push(*a, **k)

    def pop(self):
        before = self._inner.now
        ev = self._inner.pop()
        assert ev.time >= before - 1e-12, \
            f"clock moved backwards: {before} -> {ev.time}"
        for e in self._engine.topo.edges:
            assert e.capacity >= 1, "capacity must never reach zero"
            assert e.capacity >= len(e.active), \
                "a busy slot was reclaimed (scale-down must drain)"
            assert e.backlog() >= 0
        if self._engine._cap_target:
            self.saw_drain = True
        return ev

    @property
    def now(self):
        return self._inner.now

    def __len__(self):
        return len(self._inner)

    def __bool__(self):
        return bool(self._inner)


def _run_elastic_checked(spec):
    """Build and run with the capacity-invariant proxy; then check the
    conservation, drain, and price-model properties."""
    sc = Simulation(spec).build()

    import repro.fleet.engine as fe
    orig = fe.EventQueue
    proxy = {}

    def make():
        proxy["q"] = _ElasticQueue(orig(), sc.engine)
        return proxy["q"]

    fe.EventQueue = make
    try:
        metrics = sc.engine.run(sc.workload)
    finally:
        fe.EventQueue = orig

    wl, topo = sc.workload, sc.topo
    # ---- conservation: completed + rejected == issued, no double counting
    assert len(metrics.records) + metrics.rejected_count == len(wl)
    rids = sorted(r.rid for r in metrics.records)
    assert len(set(rids)) == len(rids), "a request completed twice"
    assert set(rids) <= {r.rid for r in wl}
    # ---- the fleet drains; no drain target survives the run
    for e in topo.edges:
        assert e.backlog() == 0
        assert e.coop_inflight == 0
        assert e.tokens_owed == 0
    assert not sc.engine._cap_target
    # ---- price model: slot_s is float-exactly the piecewise-constant
    # integral of the capacity timeline (capacity_log + exact scale_at
    # times, closed at the horizon) — same per-edge sequential accumulation
    assert len(metrics.capacity_log) == len(metrics.scale_at)
    marks = {e.eid: (0.0, int(topo.base_capacity[e.eid]))
             for e in topo.edges}
    acc = {e.eid: 0.0 for e in topo.edges}
    for (t_r, eid, old, new), t in zip(metrics.capacity_log,
                                       metrics.scale_at):
        t0, cap = marks[eid]
        assert old == cap, "scale-event log disagrees with the timeline"
        assert t >= t0
        assert round(t, 9) == t_r
        acc[eid] += cap * (t - t0)
        marks[eid] = (t, new)
    for eid, (t0, cap) in marks.items():
        # the engine closes the timeline at the run makespan
        # (metrics.horizon_s = max finish time), not the workload horizon
        acc[eid] += cap * (max(metrics.horizon_s, t0) - t0)
    assert acc == metrics.slot_s, "cost integral must reconstruct exactly"
    s = metrics.summary()
    assert s["slot_hours"] == \
        sum(v for _, v in sorted(metrics.slot_s.items())) / 3600.0
    assert s["cost_usd"] == metrics.usd_per_slot_hour * s["slot_hours"]
    assert s["rejected"] == metrics.rejected_count
    assert s["requests"] + s["rejected"] == len(wl)
    return sc, metrics, proxy["q"]


# ------------------------------------------------------- elastic invariants
@pytest.mark.parametrize("admission", [None, AdmissionSpec(policy="reject"),
                                       AdmissionSpec(policy="local")],
                         ids=["no-admission", "reject", "local"])
@pytest.mark.parametrize("seed", [0, 7])
def test_elastic_invariants_seed_matrix(admission, seed):
    _, m, q = _run_elastic_checked(_elastic_spec(
        seed=seed, autoscale=DRAIN_AUTOSCALE, admission=admission))
    assert m.summary()["scale_events"] > 0, \
        "the stress scenario must actually scale"
    if admission is not None and admission.policy == "local":
        # degraded-to-device arrivals still complete — nothing is shed
        assert m.rejected_count == 0


def test_forced_scale_down_drains():
    """down_util=1.0 + bursty load forces scale-downs while slots are busy:
    the proxy must observe a pending drain, capacity must step down in the
    log, and every request still completes exactly once."""
    _, m, q = _run_elastic_checked(_elastic_spec(
        seed=3, rate=14.0, autoscale=DRAIN_AUTOSCALE))
    assert q.saw_drain, "the scenario must exercise the drain path"
    assert any(new < old for _, _, old, new in m.capacity_log), \
        "no scale-down ever landed"
    assert any(new > old for _, _, old, new in m.capacity_log), \
        "no scale-up ever landed"


def test_admission_rejects_at_saturation():
    # no autoscaler: a 1-slot fleet under heavy load must shed arrivals
    spec = _elastic_spec(seed=1, rate=20.0, cap=1,
                         admission=AdmissionSpec(policy="reject",
                                                 max_queue=0))
    _, m, _ = _run_elastic_checked(spec)
    assert m.rejected_count > 0
    s = m.summary()
    assert s["reject_rate"] == pytest.approx(
        m.rejected_count / (s["requests"] + m.rejected_count))
    assert s["cost_usd"] == 0.0      # no autoscaler => no price attached


def test_admission_local_degrades_not_drops():
    spec = _elastic_spec(seed=1, rate=20.0, cap=1,
                         admission=AdmissionSpec(policy="local",
                                                 max_queue=0))
    sc, m, _ = _run_elastic_checked(spec)
    assert m.rejected_count == 0
    assert len(m.records) == len(sc.workload)
    # the shed arrivals ran device-only
    assert any(r.edge == -1 and r.partition == 0 for r in m.records)


def test_elastic_rerun_determinism():
    """Same engine, same workload, twice: identical summaries *and*
    identical scale-event logs (the autoscaler resets per run)."""
    spec = _elastic_spec(seed=5, autoscale=DRAIN_AUTOSCALE,
                         admission=AdmissionSpec(policy="reject"))
    sc = Simulation(spec).build()
    a = sc.engine.run(sc.workload)
    sa, log_a = a.summary(), (list(a.capacity_log), list(a.scale_at))
    b = sc.engine.run(sc.workload)
    sb, log_b = b.summary(), (list(b.capacity_log), list(b.scale_at))
    assert sa == sb
    assert log_a == log_b


def test_elastic_rebuild_determinism():
    spec = _elastic_spec(seed=9, autoscale=DRAIN_AUTOSCALE,
                         admission=AdmissionSpec(policy="reject"))
    assert Simulation(spec).run().summary() == \
        Simulation(spec).run().summary()


if HAVE_HYPOTHESIS:
    _ADMISSIONS = (None, AdmissionSpec(policy="reject", max_queue=1),
                   AdmissionSpec(policy="local"))

    @settings(max_examples=10, deadline=None)
    @given(seed=st.integers(min_value=0, max_value=2 ** 16),
           rate=st.floats(min_value=2.0, max_value=30.0),
           min_slots=st.integers(min_value=1, max_value=2),
           max_slots=st.integers(min_value=2, max_value=8),
           step=st.integers(min_value=1, max_value=3),
           down_util=st.floats(min_value=0.0, max_value=1.0),
           adm=st.integers(min_value=0, max_value=2))
    def test_elastic_invariants_property(seed, rate, min_slots, max_slots,
                                         step, down_util, adm):
        auto = AutoscaleSpec(min_slots=min_slots,
                             max_slots=max(min_slots, max_slots),
                             decide_dt=0.25, up_backlog_s=0.5,
                             down_util=down_util, step=step)
        _run_elastic_checked(_elastic_spec(
            seed=seed, rate=rate, horizon=5.0, autoscale=auto,
            admission=_ADMISSIONS[adm]))


# --------------------------------------------------- golden bit-identity
@pytest.mark.parametrize("name", ["smoke-lm", "coop", "smoke-mobility"])
def test_disabled_elasticity_is_bit_identical_to_goldens(name):
    """Elasticity off => byte-identical behavior to the pre-elasticity
    engine: summaries and handover logs pinned against goldens captured
    before the elastic code paths existed."""
    spec = get_scenario(name)
    assert spec.autoscale is None and spec.admission is None
    m = Simulation(spec).run()
    got = json.loads(json.dumps(
        {"scenario": name, "summary": m.summary(),
         "handover_log": [list(h) for h in m.handover_log]},
        sort_keys=True))
    with open(os.path.join(GOLDEN_DIR, f"{name}.json")) as f:
        want = json.load(f)
    assert got == want


def test_non_elastic_summary_has_no_elastic_keys():
    m = Simulation(get_scenario("smoke-lm")).run()
    s = m.summary()
    for key in ("rejected", "reject_rate", "scale_events", "slot_hours",
                "cost_usd"):
        assert key not in s


# ------------------------------------------------- ElasticPlanner (runtime)
def _lm_stack():
    from repro.sim.build import build_stack
    from repro.sim.spec import PlannerSpec
    sc = build_stack(PlannerSpec())
    return sc.graph, sc.planner


def test_elastic_planner_plan_for_default_mode():
    from repro.runtime.elastic import ElasticPlanner, TierSpec
    graph, _ = _lm_stack()
    ep = ElasticPlanner(graph=graph, latency_req_s=0.5, link_bps=4e6)
    plan = ep.plan_for(TierSpec(chips=8), TierSpec(chips=1))
    assert 0 <= plan.partition <= len(graph.branches[-1])
    assert plan.exit_point >= 1


def test_elastic_planner_shrink_clamps_at_one_chip():
    from repro.runtime.elastic import ElasticPlanner, TierSpec
    graph, _ = _lm_stack()
    ep = ElasticPlanner(graph=graph, latency_req_s=0.5, link_bps=4e6)
    plan, new_edge = ep.shrink_event(TierSpec(chips=2), TierSpec(chips=1),
                                     lost_chips=5)
    assert new_edge.chips == 1, "the tier must clamp at one chip"
    assert plan is not None


def test_elastic_planner_explicit_models_rescale():
    """Explicit calibration (the fleet shrink path): halving ref_chips'
    slots must never *raise* the predicted edge speed, and pricing at
    ref_chips must equal the original planner's own models."""
    from repro.runtime.elastic import ElasticPlanner, TierSpec
    graph, planner = _lm_stack()
    ep = ElasticPlanner(graph=graph, latency_req_s=0.5, link_bps=1.0,
                        f_edge=planner.f_edge, f_dev=planner.f_device,
                        ref_chips=8)
    full = graph.branches[-1]
    f8, _ = ep._models(TierSpec(chips=8), TierSpec(chips=1))
    f4, _ = ep._models(TierSpec(chips=4), TierSpec(chips=1))
    t8 = sum(f8.predict(l) for l in full)
    t4 = sum(f4.predict(l) for l in full)
    assert t8 == pytest.approx(
        sum(planner.f_edge.predict(l) for l in full))
    assert t4 == pytest.approx(2.0 * t8)
    # link_bps override reaches the optimizer: high bandwidth must offload
    # at least as much as a starved link
    lo = ep.plan_for(TierSpec(chips=8), TierSpec(chips=1), link_bps=1e3)
    hi = ep.plan_for(TierSpec(chips=8), TierSpec(chips=1), link_bps=1e8)
    assert hi.partition >= lo.partition


def test_fleet_shrink_replan_wired():
    """The fleet scale path re-prices queued work through ElasticPlanner:
    with replan_on_shrink the built Autoscaler carries a planner calibrated
    at the spec's base capacity."""
    spec = _elastic_spec(autoscale=DRAIN_AUTOSCALE, cap=4)
    sc = Simulation(spec).build()
    ep = sc.engine.autoscaler.planner
    assert ep is not None
    assert ep.ref_chips == 4
    assert ep.f_edge is sc.planner.f_edge
    off = dataclasses.replace(spec, autoscale=dataclasses.replace(
        spec.autoscale, replan_on_shrink=False))
    assert Simulation(off).build().engine.autoscaler.planner is None


# ------------------------------------------------ policy objects + metrics
def test_autoscaler_validation():
    with pytest.raises(ValueError, match="min_slots"):
        Autoscaler(min_slots=0)
    with pytest.raises(ValueError, match="max_slots"):
        Autoscaler(min_slots=4, max_slots=2)
    with pytest.raises(ValueError, match="decide_dt"):
        Autoscaler(decide_dt=0.0)
    with pytest.raises(ValueError, match="step"):
        Autoscaler(step=0)
    with pytest.raises(ValueError, match="min_slots"):
        AutoscaleSpec(min_slots=0)
    with pytest.raises(ValueError, match="policy"):
        AdmissionSpec(policy="teleport")
    with pytest.raises(ValueError, match="max_queue"):
        AdmissionSpec(max_queue=-1)


def test_admission_row_matches_scalar():
    spec = _elastic_spec(seed=2, rate=16.0, cap=1)
    sc = Simulation(spec).build()
    sc.engine.run(sc.workload)
    adm = AdmissionControl(policy="reject", max_queue=1)
    row = adm.saturated_row(sc.topo)
    assert [bool(v) for v in row] == \
        [adm.saturated(e) for e in sc.topo.edges]


def test_all_rejected_summary_schema_complete():
    """Every arrival rejected: summary() must keep the full schema with
    None for undefined statistics — no NaN, no KeyError."""
    m = FleetMetrics(num_edges=1, horizon_s=1.0)
    m.elastic = True
    m.mark_capacity(0, 2, 0.0)
    for _ in range(5):
        m.reject()
    m.finalize_capacity()
    s = m.summary()
    assert s["requests"] == 0 and s["rejected"] == 5
    assert s["reject_rate"] == 1.0
    assert s["slot_hours"] == pytest.approx(2.0 / 3600.0)
    assert s["p50_latency_s"] is None
    assert s["p95_latency_s"] is None
    assert s["mean_queue_delay_s"] is None
    assert s["slo_attainment"] == 0.0
    assert not any(v != v for v in s.values()
                   if isinstance(v, float)), "NaN leaked into the summary"
    # engine-level variant: saturate a 1-slot fleet with an impossible gate
    spec = _elastic_spec(seed=4, rate=25.0, cap=1, horizon=4.0,
                         admission=AdmissionSpec(policy="reject",
                                                 max_queue=0))
    sm = Simulation(spec).run().summary()
    assert set(s) == set(sm), "schema must not depend on the reject count"


def test_spec_round_trip_and_override_materialization():
    spec = get_scenario("elastic-smoke")
    assert ScenarioSpec.from_json(spec.to_json()) == spec
    base = get_scenario("smoke-lm")
    assert base.autoscale is None and base.admission is None
    up = apply_overrides(base, {"autoscale.max_slots": 12,
                                "admission.policy": "local"})
    assert up.autoscale == AutoscaleSpec(max_slots=12)
    assert up.admission == AdmissionSpec(policy="local")
    with pytest.raises(ValueError, match="unknown spec path"):
        apply_overrides(base, {"autoscale.warp_factor": 9})


# ---------------------------------------------------- cost/SLO frontier
def test_pareto_frontier_on_synthetic_rows():
    from repro.sim.sweep import pareto_frontier
    mk = lambda c, s: {"metrics": {"cost_usd": c, "slo_attainment": s}}
    rows = [mk(1.0, 0.2), mk(2.0, 0.5), mk(3.0, 0.4),   # 3.0 dominated
            mk(4.0, 0.9), None, {"metrics": {"slo_attainment": 1.0}}]
    front = pareto_frontier(rows)
    assert [(r["metrics"]["cost_usd"], r["metrics"]["slo_attainment"])
            for r in front] == [(1.0, 0.2), (2.0, 0.5), (4.0, 0.9)]
    assert pareto_frontier([]) == []


def test_elastic_sweep_yields_nondegenerate_frontier():
    """The ISSUE acceptance bar: a cost-vs-SLO sweep over the diurnal
    elastic scenario must produce >= 3 non-dominated points (capacity
    genuinely trades off against attainment)."""
    from repro.sim.sweep import grid_cells, pareto_frontier, run_sweep
    base = get_scenario("elastic-smoke")
    cells = grid_cells(base, {"autoscale.max_slots": [1, 4, 16]})
    rows = run_sweep(cells)
    front = pareto_frontier(rows)
    assert len(front) >= 3
    costs = [r["metrics"]["cost_usd"] for r in front]
    slos = [r["metrics"]["slo_attainment"] for r in front]
    assert costs == sorted(costs)
    assert slos == sorted(slos), \
        "along the frontier, paying more must buy attainment"


# ------------------------------------------------------- observability
def test_timeline_samples_capacity_gauge():
    import numpy as np

    from repro.obs.timeline import Timeline
    spec = _elastic_spec(seed=6, autoscale=DRAIN_AUTOSCALE)
    sc = Simulation(spec).build()
    tl = Timeline(sc.topo.num_edges, dt=0.25)
    sc.engine.timeline = tl
    sc.engine.run(sc.workload)
    kept = tl.num_retained
    assert kept > 0
    caps = tl.edge["capacity"][:kept]
    assert caps.min() >= 1
    assert len(np.unique(caps)) > 1, \
        "the capacity gauge must track scale events, not a constant"
