"""Per-arch reduced-config smoke tests (assignment requirement): one
forward/train step on CPU asserting output shapes + no NaNs, plus
prefill/decode consistency and the exit-point (right-sizing) variants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_smoke_config
from repro.models import Model


def _batch(cfg, rng, B=2, S=16):
    batch = {"tokens": jax.random.randint(rng, (B, S + 1), 0, cfg.vocab_size)}
    if cfg.is_encdec:
        batch["frames"] = jax.random.normal(rng, (B, S, 1024), jnp.float32)
    if cfg.frontend == "vision":
        batch["prefix_emb"] = jax.random.normal(
            rng, (B, cfg.num_prefix_tokens, 1024), jnp.float32)
    return batch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_forward_loss_no_nan(arch, rng):
    cfg = get_smoke_config(arch)
    model = Model(cfg)
    params = model.init_params(rng, dtype=jnp.float32)
    loss, metrics = model.loss(params, _batch(cfg, rng), remat=False)
    assert loss.shape == ()
    assert bool(jnp.isfinite(loss)), arch
    assert metrics["exit_ce"].shape[0] == model.num_segments
    assert bool(jnp.all(jnp.isfinite(metrics["exit_ce"])))


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_train_step_updates(arch, rng):
    from repro.optim.adamw import adamw_init, adamw_update

    cfg = get_smoke_config(arch)
    model = Model(cfg)
    params = model.init_params(rng, dtype=jnp.float32)
    opt = adamw_init(params)
    batch = _batch(cfg, rng)
    (loss, _), grads = jax.value_and_grad(
        lambda p: model.loss(p, batch, remat=True), has_aux=True)(params)
    new_params, new_opt = adamw_update(grads, opt, params, lr=1e-3)
    assert int(new_opt.step) == 1
    # params actually changed and stayed finite
    diffs = jax.tree.map(lambda a, b: float(jnp.abs(a - b).max()), params, new_params)
    assert max(jax.tree.leaves(diffs)) > 0
    assert all(bool(jnp.all(jnp.isfinite(l))) for l in jax.tree.leaves(new_params))


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_prefill_decode_shapes(arch, rng):
    cfg = get_smoke_config(arch)
    model = Model(cfg)
    params = model.init_params(rng, dtype=jnp.float32)
    B, S = 2, 8
    pre = cfg.num_prefix_tokens if cfg.frontend == "vision" else 0
    toks = jax.random.randint(rng, (B, S), 0, cfg.vocab_size)
    kw = {}
    if cfg.is_encdec:
        kw["frames"] = jax.random.normal(rng, (B, S, 1024), jnp.float32)
    if cfg.frontend == "vision":
        kw["prefix_emb"] = jax.random.normal(rng, (B, pre, 1024), jnp.float32)
    cache = model.init_cache(B, S + pre + 4, dtype=jnp.float32, enc_len=S)
    h, cache = model.prefill(params, toks, cache, **kw)
    assert h.shape == (B, 1, cfg.d_model)
    nt = jax.random.randint(rng, (B, 1), 0, cfg.vocab_size)
    h2, cache2, _ = model.decode_step(params, cache, nt,
                                      jnp.asarray(S + pre, jnp.int32))
    assert h2.shape == (B, 1, cfg.d_model)
    assert bool(jnp.all(jnp.isfinite(h2)))
    # right-sizing variants: every exit point gives finite hidden
    for ep in range(model.num_segments):
        h3, _, _ = model.decode_step(params, cache, nt,
                                     jnp.asarray(S + pre, jnp.int32),
                                     exit_point=ep)
        assert bool(jnp.all(jnp.isfinite(h3))), (arch, ep)


@pytest.mark.parametrize("arch", ["granite-3-2b", "rwkv6-3b", "zamba2-2.7b"])
def test_decode_matches_forward(arch, rng):
    """prefill(S) + decode(1) last hidden == forward(S+1) last hidden."""
    cfg = get_smoke_config(arch)
    model = Model(cfg)
    params = model.init_params(rng, dtype=jnp.float32)
    B, S = 1, 12
    toks = jax.random.randint(rng, (B, S + 1), 0, cfg.vocab_size)
    outs, _ = model.stack.forward(cfg, params, toks, collect_exits=False)
    h_fwd = outs[-1][1][:, -1, :]
    cache = model.init_cache(B, S + 2, dtype=jnp.float32, enc_len=S)
    _, cache = model.prefill(params, toks[:, :S], cache)
    h_dec, _, _ = model.decode_step(params, cache, toks[:, S:S + 1],
                                    jnp.asarray(S, jnp.int32))
    np.testing.assert_allclose(np.asarray(h_fwd), np.asarray(h_dec[:, 0, :]),
                               rtol=2e-4, atol=2e-4)
