"""Property-based invariants of the fleet simulator, run against every
router (including the joint multi-edge planner) and, for mobile fleets,
against the handover policies:

* every submitted request completes exactly once (also under forced
  mid-request migration),
* the virtual clock is monotone per event pop (also across handovers),
* edge backlogs (queue + active + cooperative spans) never go negative and
  drain to zero,
* metrics conserve the request count, and migrated handover bytes are
  non-negative and conserved against the backbone transfer events,
* simulations rebuilt from the same ``repro.sim`` spec (including fresh
  ``Simulation`` objects) are deterministic,
* BOCD replan timing is deterministic (golden-pinned).

Every scenario is declared as a ``repro.sim`` ScenarioSpec — seeds derive
from the one root seed via ``ScenarioSpec.seeds()``.  With hypothesis
installed (CI) the properties are fuzzed over fleet shapes and workloads;
without it the deterministic seed matrix below still covers all routers and
policies.
"""
import pytest

from _hypothesis_compat import HAVE_HYPOTHESIS, given, settings, st
from repro.fleet.workload import TenantClass
from repro.sim import (MobilitySpec, PlannerSpec, RouterSpec, ScenarioSpec,
                       Simulation, TopologySpec, WorkloadSpec)

ROUTERS = ("round-robin", "jsq", "bandwidth-aware", "joint")
HANDOVER_POLICIES = ("oracle", "bocd")
# long-lived streaming requests: decode spans many sampling intervals, so
# the handover policies genuinely fire mid-request
STREAM_TENANTS = (
    TenantClass("stream", slo_s=2.0, max_new_tokens=48, weight=0.7),
    TenantClass("batch", slo_s=6.0, max_new_tokens=96, weight=0.3),
)


def _static_spec(router, *, nd, ne, rate, seed, horizon=8.0,
                 device_skew=1.0):
    return ScenarioSpec(
        name="invariants", seed=seed,
        topology=TopologySpec(num_devices=nd, num_edges=ne, edge_capacity=4,
                              lo_mbps=0.1, hi_mbps=6.0,
                              max_edge_slowdown=4.0),
        workload=WorkloadSpec(rate_hz=rate, horizon_s=horizon,
                              device_skew=device_skew),
        router=RouterSpec(name=router))


def _mobility_spec(policy, *, nd=10, ne=4, rate=6.0, speed=0.5, seed=0,
                   horizon=10.0):
    return ScenarioSpec(
        name="mobility-invariants", seed=seed,
        planner=PlannerSpec(result_kb=4.0),
        topology=TopologySpec(kind="mobile", num_devices=nd, num_edges=ne,
                              speed=speed, horizon_s=horizon + 30.0,
                              floor_mbps=0.1, noise_sigma=0.08),
        workload=WorkloadSpec(rate_hz=rate, horizon_s=horizon,
                              device_skew=0.5, tenants=STREAM_TENANTS),
        router=RouterSpec(name="nearest"),
        mobility=MobilitySpec(policy=policy))


class _MonotoneQueue:
    """EventQueue proxy that asserts pops never move the clock backwards and
    that no edge's backlog has gone negative at any pop."""

    def __init__(self, inner, topo):
        self._inner, self._topo = inner, topo
        self.pops = 0

    def push(self, *a, **k):
        return self._inner.push(*a, **k)

    def pop(self):
        before = self._inner.now
        ev = self._inner.pop()
        assert ev.time >= before - 1e-12, \
            f"clock moved backwards: {before} -> {ev.time}"
        for e in self._topo.edges:
            assert e.backlog() >= 0
            assert e.coop_inflight >= 0
            # the O(1) owed-token counter must track the ground truth
            owed = sum(r.max_new_tokens - r.tokens_done
                       for _, _, r in e.queue if r is not None) + \
                sum(r.max_new_tokens - r.tokens_done for r in e.active)
            assert e.tokens_owed == owed
        self.pops += 1
        return ev

    @property
    def now(self):
        return self._inner.now

    def __len__(self):
        return len(self._inner)

    def __bool__(self):
        return bool(self._inner)


def _run_spec_monotone(spec):
    """Build the spec and run it with the monotone-clock/backlog proxy
    patched over the engine's event queue."""
    sc = Simulation(spec).build()

    import repro.fleet.engine as fe
    orig = fe.EventQueue
    fe.EventQueue = lambda: _MonotoneQueue(orig(), sc.topo)
    try:
        metrics = sc.engine.run(sc.workload)
    finally:
        fe.EventQueue = orig
    return sc, metrics


def _run_checked(router, *, nd, ne, rate, seed, horizon=8.0):
    sc, metrics = _run_spec_monotone(
        _static_spec(router, nd=nd, ne=ne, rate=rate, seed=seed,
                     horizon=horizon))
    topo, wl = sc.topo, sc.workload

    # ---- completion exactly once + request-count conservation
    rids = sorted(r.rid for r in metrics.records)
    assert rids == sorted(r.rid for r in wl), \
        "every submitted request must complete exactly once"
    assert len(metrics.records) == len(wl)
    local = sum(1 for r in metrics.records if r.edge == -1)
    assert sum(e.completed for e in topo.edges) + local == len(wl)
    # ---- the fleet drains: no stranded slots, queue entries, or coop spans
    for e in topo.edges:
        assert e.backlog() == 0
        assert e.coop_inflight == 0
        assert e.tokens_owed == 0
    # ---- per-record sanity
    for r in metrics.records:
        assert r.finish_s >= r.arrival_s
        assert r.latency_s >= 0.0
        assert r.queue_delay_s >= 0.0
        if r.edge == -1:
            assert r.partition == 0
    return metrics


def _run_mobility_checked(policy, *, nd=10, ne=4, rate=6.0, speed=0.5,
                          seed=0, horizon=10.0):
    """Mobile-fleet variant of :func:`_run_checked`: nearest-edge routing,
    random-waypoint motion, the given handover policy — same monotone-clock
    and backlog proxies, same exactly-once / drain assertions, plus the
    handover-specific conservation checks."""
    sc, metrics = _run_spec_monotone(
        _mobility_spec(policy, nd=nd, ne=ne, rate=rate, speed=speed,
                       seed=seed, horizon=horizon))
    topo, wl = sc.topo, sc.workload

    # ---- completion exactly once + request-count conservation: a migrated
    # request must neither drop nor complete at both its edges
    rids = sorted(r.rid for r in metrics.records)
    assert rids == sorted(r.rid for r in wl), \
        "every submitted request must complete exactly once under migration"
    assert len(metrics.records) == len(wl)
    # ---- the fleet drains: no stranded slots, queue entries, or owed tokens
    for e in topo.edges:
        assert e.backlog() == 0
        assert e.coop_inflight == 0
        assert e.tokens_owed == 0
    # ---- migrated bytes: non-negative, conserved against transfer events
    # (nearest routing + single-edge replan => the backbone carries nothing
    # but handover state snapshots)
    assert all(h[3] >= 0 for h in metrics.handover_log)
    assert metrics.migrated_bytes_total == \
        sum(r.migrated_bytes for r in metrics.records)
    assert metrics.migrated_bytes_total == \
        sum(metrics.transfer_bytes.values())
    assert metrics.handover_count == \
        sum(r.handovers for r in metrics.records)
    for r in metrics.records:
        assert r.finish_s >= r.arrival_s
        assert r.latency_s >= 0.0
        assert r.migrated_bytes >= 0
        if r.handovers == 0:
            assert r.migrated_bytes == 0
    return metrics


@pytest.mark.parametrize("router", ROUTERS)
@pytest.mark.parametrize("seed", [0, 7])
def test_invariants_seed_matrix(router, seed):
    _run_checked(router, nd=12, ne=3, rate=14.0, seed=seed)


@pytest.mark.parametrize("policy", HANDOVER_POLICIES)
@pytest.mark.parametrize("seed", [0, 5])
def test_handover_invariants(policy, seed):
    """Fast mobility forces mid-request migrations; every invariant the
    static fleet holds must survive them (exactly-once, monotone clock via
    _MonotoneQueue, byte conservation)."""
    m = _run_mobility_checked(policy, seed=seed)
    assert m.handover_count > 0, \
        "the forced-migration scenario must actually migrate"


def test_handover_mid_request_state_ships():
    """At least one migration must move a *prefilled* request (non-zero
    state bytes over the backbone), not just re-route queued work."""
    m = _run_mobility_checked("oracle", speed=0.6, seed=1)
    assert m.migrated_bytes_total > 0
    moved = [r for r in m.records if r.handovers > 0]
    assert any(r.migrated_bytes > 0 for r in moved)


def test_no_handover_policy_never_migrates():
    m = _run_mobility_checked("none", speed=0.6, seed=1)
    assert m.handover_count == 0
    assert m.migrated_bytes_total == 0
    assert sum(m.transfer_bytes.values()) == 0


@pytest.mark.parametrize("policy", ("none",) + HANDOVER_POLICIES)
def test_mobility_rerun_determinism(policy):
    """Stateful handover machinery (BOCD posteriors, attachments, sampling
    grid) must reset between runs: same engine, same workload => identical
    summaries."""
    sc = Simulation(ScenarioSpec(
        name="rerun", seed=11,
        planner=PlannerSpec(result_kb=4.0),
        topology=TopologySpec(kind="mobile", num_devices=8, num_edges=3,
                              speed=0.4, horizon_s=40.0),
        workload=WorkloadSpec(rate_hz=6.0, horizon_s=8.0,
                              tenants=STREAM_TENANTS),
        router=RouterSpec(name="nearest"),
        mobility=MobilitySpec(policy=policy))).build()
    a = sc.engine.run(sc.workload).summary()
    events_a = (sc.engine.events_processed, dict(sc.engine.event_counts))
    b = sc.engine.run(sc.workload).summary()
    events_b = (sc.engine.events_processed, dict(sc.engine.event_counts))
    assert a == b
    # the event stream itself is deterministic, not just its outcome
    assert events_a == events_b


@pytest.mark.parametrize("spec", [
    _static_spec("jsq", nd=10, ne=3, rate=12.0, seed=4),
    _mobility_spec("bocd", nd=8, ne=3, rate=5.0, seed=9, horizon=6.0),
], ids=["static", "mobility"])
def test_sim_rebuild_determinism(spec):
    """Seed centralization contract (`ScenarioSpec.seeds()`): two
    *independently built* Simulations of the same spec — fresh topology,
    trajectories, workload, engine — produce bit-identical summaries."""
    assert Simulation(spec).run().summary() == \
        Simulation(spec).run().summary()


@pytest.mark.parametrize("router", ROUTERS)
def test_single_edge_fleet(router):
    # degenerate topology: one edge — routing is forced, invariants must hold
    _run_checked(router, nd=6, ne=1, rate=8.0, seed=3)


def test_round_robin_is_deterministic_across_runs():
    """RoundRobinRouter used to carry its cycle position across
    ``FleetEngine.run`` calls, so back-to-back simulations of the same
    workload diverged.  Same scenario twice => identical FleetMetrics."""
    sc = Simulation(ScenarioSpec(
        name="rr-rerun", seed=1,
        topology=TopologySpec(num_devices=10, num_edges=3),
        workload=WorkloadSpec(rate_hz=12.0, horizon_s=6.0),
        router=RouterSpec(name="round-robin"))).build()
    a = sc.engine.run(sc.workload).summary()
    b = sc.engine.run(sc.workload).summary()
    assert a == b


@pytest.mark.parametrize("router", ROUTERS)
def test_rerun_determinism_all_routers(router):
    sc = Simulation(ScenarioSpec(
        name="rerun-router", seed=5,
        topology=TopologySpec(num_devices=8, num_edges=2),
        workload=WorkloadSpec(rate_hz=10.0, horizon_s=6.0),
        router=RouterSpec(name=router))).build()
    a = sc.engine.run(sc.workload).summary()
    events_a = (sc.engine.events_processed, dict(sc.engine.event_counts))
    b = sc.engine.run(sc.workload).summary()
    events_b = (sc.engine.events_processed, dict(sc.engine.event_counts))
    assert a == b
    assert events_a == events_b


@settings(max_examples=12, deadline=None)
@given(nd=st.integers(min_value=1, max_value=16),
       ne=st.integers(min_value=1, max_value=4),
       rate=st.floats(min_value=0.5, max_value=40.0),
       seed=st.integers(min_value=0, max_value=2 ** 16),
       router=st.sampled_from(ROUTERS))
def test_invariants_property(nd, ne, rate, seed, router):
    _run_checked(router, nd=nd, ne=ne, rate=rate, seed=seed, horizon=5.0)


@settings(max_examples=8, deadline=None)
@given(seed=st.integers(min_value=0, max_value=2 ** 16))
def test_joint_matches_submitted_set_under_skew(seed):
    """Joint routing with heavy device skew: still exactly-once completion
    and non-negative cooperative in-flight accounting."""
    m = _run_checked("joint", nd=10, ne=4, rate=25.0, seed=seed, horizon=5.0)
    assert all(len(r.edges) <= 4 for r in m.records)


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(min_value=0, max_value=2 ** 16),
       speed=st.floats(min_value=0.0, max_value=1.0),
       policy=st.sampled_from(HANDOVER_POLICIES))
def test_handover_invariants_property(seed, speed, policy):
    """Fuzz mobility speed and seeds: exactly-once, drain, monotone clock
    and byte conservation hold whether migrations fire or not."""
    _run_mobility_checked(policy, nd=6, ne=3, rate=5.0, speed=speed,
                          seed=seed, horizon=6.0)


# ---- golden regression: BOCD replan timing is deterministic -------------
# Pinned from the fixed scenario below: every (time, src, dst) of each
# migration the BOCD policy triggers.  Any change to the sampling grid, the
# detector parameters, the replan estimates, or the event ordering that
# shifts handover timing must show up here (and be justified in the diff).
GOLDEN_BOCD_HANDOVERS = [
    (4.528111, 3, 0), (5.503407, 2, 0), (6.560989, 2, 0), (6.560946, 2, 0),
    (7.125527, 3, 0), (8.503998, 1, 2), (10.515674, 3, 0), (11.024732, 3, 1),
]


def test_bocd_replan_timing_golden():
    m = _run_mobility_checked("bocd", nd=10, ne=4, rate=6.0, speed=0.5,
                              seed=3, horizon=10.0)
    log = [(round(t, 6), src, dst) for t, src, dst, _ in m.handover_log]
    assert log == GOLDEN_BOCD_HANDOVERS


if HAVE_HYPOTHESIS:
    def test_property_suite_is_active():
        # CI installs hypothesis; make sure the @given tests above are not
        # silently skipped there
        assert True
