"""Property-based invariants of the fleet simulator, run against every
router (including the joint multi-edge planner):

* every submitted request completes exactly once,
* the virtual clock is monotone per event pop,
* edge backlogs (queue + active + cooperative spans) never go negative and
  drain to zero,
* metrics conserve the request count.

With hypothesis installed (CI) the properties are fuzzed over fleet shapes
and workloads; without it the deterministic seed matrix below still covers
all routers.
"""
import functools

import pytest

from _hypothesis_compat import HAVE_HYPOTHESIS, given, settings, st
from repro.fleet import FleetEngine, make_fleet, make_workload, \
    smoke_lm_scenario

ROUTERS = ("round-robin", "jsq", "bandwidth-aware", "joint")


@functools.lru_cache(maxsize=1)
def _scenario():
    _, graph, planner = smoke_lm_scenario()
    return graph, planner


class _MonotoneQueue:
    """EventQueue proxy that asserts pops never move the clock backwards and
    that no edge's backlog has gone negative at any pop."""

    def __init__(self, inner, topo):
        self._inner, self._topo = inner, topo
        self.pops = 0

    def push(self, *a, **k):
        return self._inner.push(*a, **k)

    def pop(self):
        before = self._inner.now
        ev = self._inner.pop()
        assert ev.time >= before - 1e-12, \
            f"clock moved backwards: {before} -> {ev.time}"
        for e in self._topo.edges:
            assert e.backlog() >= 0
            assert e.coop_inflight >= 0
            # the O(1) owed-token counter must track the ground truth
            owed = sum(r.max_new_tokens - r.tokens_done
                       for _, _, r in e.queue) + \
                sum(r.max_new_tokens - r.tokens_done for r in e.active)
            assert e.tokens_owed == owed
        self.pops += 1
        return ev

    @property
    def now(self):
        return self._inner.now

    def __len__(self):
        return len(self._inner)

    def __bool__(self):
        return bool(self._inner)


def _run_checked(router, *, nd, ne, rate, seed, horizon=8.0,
                 monkeypatch=None):
    graph, planner = _scenario()
    topo = make_fleet(nd, ne, seed=seed, edge_capacity=4,
                      lo_mbps=0.1, hi_mbps=6.0, max_edge_slowdown=4.0)
    wl = make_workload(nd, rate_hz=rate, horizon_s=horizon, seed=seed + 1,
                       arrival="poisson", device_skew=1.0)
    eng = FleetEngine(topo, graph, planner, router=router)

    import repro.fleet.engine as fe
    orig = fe.EventQueue
    fe.EventQueue = lambda: _MonotoneQueue(orig(), topo)
    try:
        metrics = eng.run(wl)
    finally:
        fe.EventQueue = orig

    # ---- completion exactly once + request-count conservation
    rids = sorted(r.rid for r in metrics.records)
    assert rids == sorted(r.rid for r in wl), \
        "every submitted request must complete exactly once"
    assert len(metrics.records) == len(wl)
    local = sum(1 for r in metrics.records if r.edge == -1)
    assert sum(e.completed for e in topo.edges) + local == len(wl)
    # ---- the fleet drains: no stranded slots, queue entries, or coop spans
    for e in topo.edges:
        assert e.backlog() == 0
        assert e.coop_inflight == 0
        assert e.tokens_owed == 0
    # ---- per-record sanity
    for r in metrics.records:
        assert r.finish_s >= r.arrival_s
        assert r.latency_s >= 0.0
        assert r.queue_delay_s >= 0.0
        if r.edge == -1:
            assert r.partition == 0
    return metrics


@pytest.mark.parametrize("router", ROUTERS)
@pytest.mark.parametrize("seed", [0, 7])
def test_invariants_seed_matrix(router, seed):
    _run_checked(router, nd=12, ne=3, rate=14.0, seed=seed)


@pytest.mark.parametrize("router", ROUTERS)
def test_single_edge_fleet(router):
    # degenerate topology: one edge — routing is forced, invariants must hold
    _run_checked(router, nd=6, ne=1, rate=8.0, seed=3)


def test_round_robin_is_deterministic_across_runs():
    """RoundRobinRouter used to carry its cycle position across
    ``FleetEngine.run`` calls, so back-to-back simulations of the same
    workload diverged.  Same scenario twice => identical FleetMetrics."""
    graph, planner = _scenario()
    topo = make_fleet(10, 3, seed=1)
    wl = make_workload(10, rate_hz=12.0, horizon_s=6.0, seed=2)
    eng = FleetEngine(topo, graph, planner, router="round-robin")
    a = eng.run(wl).summary()
    b = eng.run(wl).summary()
    assert a == b


@pytest.mark.parametrize("router", ROUTERS)
def test_rerun_determinism_all_routers(router):
    graph, planner = _scenario()
    topo = make_fleet(8, 2, seed=5)
    wl = make_workload(8, rate_hz=10.0, horizon_s=6.0, seed=6)
    eng = FleetEngine(topo, graph, planner, router=router)
    assert eng.run(wl).summary() == eng.run(wl).summary()


@settings(max_examples=12, deadline=None)
@given(nd=st.integers(min_value=1, max_value=16),
       ne=st.integers(min_value=1, max_value=4),
       rate=st.floats(min_value=0.5, max_value=40.0),
       seed=st.integers(min_value=0, max_value=2 ** 16),
       router=st.sampled_from(ROUTERS))
def test_invariants_property(nd, ne, rate, seed, router):
    _run_checked(router, nd=nd, ne=ne, rate=rate, seed=seed, horizon=5.0)


@settings(max_examples=8, deadline=None)
@given(seed=st.integers(min_value=0, max_value=2 ** 16))
def test_joint_matches_submitted_set_under_skew(seed):
    """Joint routing with heavy device skew: still exactly-once completion
    and non-negative cooperative in-flight accounting."""
    m = _run_checked("joint", nd=10, ne=4, rate=25.0, seed=seed, horizon=5.0)
    assert all(len(r.edges) <= 4 for r in m.records)


if HAVE_HYPOTHESIS:
    def test_property_suite_is_active():
        # CI installs hypothesis; make sure the @given tests above are not
        # silently skipped there
        assert True
