"""Algorithm 1 invariants + hypothesis properties (the paper's claims)."""
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st  # hypothesis or skip-stub

from repro.core.graph import GraphLayer, InferenceGraph
from repro.core.partitioner import (best_partition, branch_latency, optimize,
                                    optimize_with_fallback)


class ConstModel:
    """Latency model with fixed per-layer latency."""
    def __init__(self, per_layer):
        self.per_layer = per_layer

    def predict(self, layer):
        return self.per_layer[layer.name]


def _graph(n_exits=3, layers_per=4, out_bytes=1000, input_bytes=5000):
    branches = []
    for i in range(1, n_exits + 1):
        branches.append([
            GraphLayer(name=f"l{i}_{j}", kind="fc",
                       features={"in_size": 1.0, "out_size": 1.0},
                       out_bytes=out_bytes)
            for j in range(layers_per * i)])
    return InferenceGraph("toy", branches,
                          accuracy=[0.5 + 0.1 * i for i in range(n_exits)],
                          input_bytes=input_bytes, result_bytes=8)


def test_feasible_plan_meets_slo():
    g = _graph()
    lat = {l.name: 0.01 for b in g.branches for l in b}
    fe, fd = ConstModel(lat), ConstModel({k: v * 10 for k, v in lat.items()})
    plan = optimize(g, fe, fd, bandwidth_bps=1e6, latency_req_s=0.5)
    assert plan is not None
    assert plan.latency_s <= 0.5
    assert branch_latency(g, plan.exit_point, plan.partition, fe, fd, 1e6) \
        == pytest.approx(plan.latency_s)


def test_prefers_larger_exit():
    g = _graph()
    lat = {l.name: 0.001 for b in g.branches for l in b}
    fe, fd = ConstModel(lat), ConstModel(lat)
    plan = optimize(g, fe, fd, 1e9, 10.0)
    assert plan.exit_point == g.num_exits        # everything feasible -> best accuracy


def test_infeasible_returns_none_and_fallback():
    g = _graph()
    lat = {l.name: 1.0 for b in g.branches for l in b}
    fe, fd = ConstModel(lat), ConstModel(lat)
    assert optimize(g, fe, fd, 1e6, 0.001) is None
    plan = optimize_with_fallback(g, fe, fd, 1e6, 0.001)
    assert not plan.feasible
    assert plan.exit_point == 1                  # min-latency rescue


def test_zero_partition_has_no_transfer():
    g = _graph()
    lat = {l.name: 0.01 for b in g.branches for l in b}
    fe, fd = ConstModel(lat), ConstModel(lat)
    # device-only cost is independent of bandwidth
    l1 = branch_latency(g, 2, 0, fe, fd, 1.0)
    l2 = branch_latency(g, 2, 0, fe, fd, 1e12)
    assert l1 == l2


@settings(max_examples=40, deadline=None)
@given(bw=st.floats(1e3, 1e9), slo=st.floats(0.01, 5.0),
       dev_slow=st.floats(1.0, 100.0))
def test_property_plan_feasibility_and_optimality(bw, slo, dev_slow):
    g = _graph()
    lat = {l.name: 0.005 for b in g.branches for l in b}
    fe = ConstModel(lat)
    fd = ConstModel({k: v * dev_slow for k, v in lat.items()})
    plan = optimize(g, fe, fd, bw, slo)
    if plan is None:
        # verify truly infeasible: even exit 1 best partition exceeds slo
        _, best = best_partition(g, 1, fe, fd, bw)
        assert best > slo
    else:
        assert plan.latency_s <= slo + 1e-12
        # no deeper exit is feasible (paper: maximize accuracy first)
        for i in range(plan.exit_point + 1, g.num_exits + 1):
            _, best = best_partition(g, i, fe, fd, bw)
            assert best > slo


@settings(max_examples=25, deadline=None)
@given(bw1=st.floats(1e3, 1e8), factor=st.floats(1.1, 100.0))
def test_property_latency_monotone_in_bandwidth(bw1, factor):
    """For any fixed (exit, partition), latency is non-increasing in B."""
    g = _graph()
    lat = {l.name: 0.005 for b in g.branches for l in b}
    fe, fd = ConstModel(lat), ConstModel({k: v * 20 for k, v in lat.items()})
    bw2 = bw1 * factor
    for i in range(1, g.num_exits + 1):
        for p in range(0, len(g.branches[i - 1]) + 1, 3):
            assert branch_latency(g, i, p, fe, fd, bw2) <= \
                branch_latency(g, i, p, fe, fd, bw1) + 1e-12


def test_search_under_1ms(alexnet_planner, alexnet_setup):
    from repro.core.partitioner import search_latency
    _, _, graph = alexnet_setup
    t = search_latency(graph, alexnet_planner.f_edge, alexnet_planner.f_device,
                       500 * 125, 1.0, repeats=20)
    assert t < 0.005, f"Algorithm-1 search took {t*1e3:.2f} ms"  # paper: <1ms
