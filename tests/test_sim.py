"""The declarative scenario API (`repro.sim`): spec round-trips, the
named-scenario registry, the CLI, engine argument validation, and — the
refactor's acceptance gate — bit-identical equivalence between
spec-built simulations and the legacy hand-wired setups they replaced."""
import json
from dataclasses import replace

import pytest

from repro.fleet import FleetEngine, make_fleet, make_workload
from repro.fleet.mobility import HandoverController, make_mobile_fleet
from repro.fleet.scenario import smoke_lm_scenario, smoke_mobility_scenario
from repro.sim import (MobilitySpec, PlannerSpec, RouterSpec, ScenarioSpec,
                       Simulation, TopologySpec, WorkloadSpec,
                       apply_overrides, build_stack, get_scenario,
                       list_scenarios, register_scenario)
from repro.sim.cli import main as sim_main

BUILTINS = ("smoke-lm", "coop", "smoke-mobility")


# ------------------------------------------------------------------ specs

@pytest.mark.parametrize("name", BUILTINS)
def test_spec_json_round_trip_is_lossless(name):
    spec = get_scenario(name)
    assert ScenarioSpec.from_json(spec.to_json()) == spec
    # dict round-trip too, including tenant tuples and nested specs
    assert ScenarioSpec.from_dict(spec.to_dict()) == spec


def test_seed_derivation_is_centralized():
    """All per-subsystem seeds flow from the one root seed: topology (and
    trajectories/noise, which sample from the same generator) at ``seed``,
    arrivals at ``seed + 1``."""
    seeds = ScenarioSpec(seed=5).seeds()
    assert (seeds.topology, seeds.workload) == (5, 6)


def test_from_dict_rejects_unknown_fields():
    with pytest.raises(ValueError, match="unknown ScenarioSpec field"):
        ScenarioSpec.from_dict({"seed": 1, "typo": 2})
    with pytest.raises(ValueError, match="unknown TopologySpec field"):
        TopologySpec.from_dict({"num_device": 4})


def test_spec_validation_rejects_bad_enums():
    with pytest.raises(ValueError, match="unknown topology kind"):
        TopologySpec(kind="orbital")
    with pytest.raises(ValueError, match="unknown handover policy"):
        MobilitySpec(policy="sometimes")
    with pytest.raises(ValueError, match="unknown router"):
        RouterSpec(name="warp")


def test_workload_rate_must_be_exactly_one_of_two():
    with pytest.raises(ValueError, match="exactly one"):
        WorkloadSpec().resolve_rate_hz(10)
    with pytest.raises(ValueError, match="exactly one"):
        WorkloadSpec(rate_hz=1.0, rate_per_device_hz=1.0).resolve_rate_hz(10)
    assert WorkloadSpec(rate_per_device_hz=0.5).resolve_rate_hz(10) == 5.0


def test_apply_overrides_dotted_paths():
    spec = get_scenario("smoke-lm")
    out = apply_overrides(spec, {"topology.num_devices": 7,
                                 "router.name": "jsq", "seed": 9})
    assert (out.topology.num_devices, out.router.name, out.seed) == \
        (7, "jsq", 9)
    assert spec.topology.num_devices == 40      # input spec untouched
    # overriding into an unset mobility materializes a default MobilitySpec
    out = apply_overrides(spec, {"mobility.policy": "oracle"})
    assert out.mobility.policy == "oracle"
    with pytest.raises(ValueError, match="unknown spec path"):
        apply_overrides(spec, {"topology.num_device": 7})


def test_unknown_engine_dtype_is_rejected_at_build():
    spec = apply_overrides(get_scenario("smoke-lm"),
                           {"engine.dtype": "float23",
                            "topology.num_devices": 2})
    with pytest.raises(ValueError, match="unknown engine dtype"):
        Simulation(spec).build()
    # non-dtype jnp attribute names must not be silently accepted either
    spec = apply_overrides(spec, {"engine.dtype": "sum"})
    with pytest.raises(ValueError, match="unknown engine dtype"):
        Simulation(spec).build()


def test_mobility_policy_on_static_topology_is_rejected():
    spec = replace(get_scenario("smoke-lm"),
                   mobility=MobilitySpec(policy="bocd"))
    with pytest.raises(ValueError, match="static"):
        Simulation(spec).build()


# --------------------------------------------------------------- registry

def test_registry_lists_builtins():
    names = [s.name for s in list_scenarios()]
    for name in BUILTINS:
        assert name in names


def test_registry_unknown_name():
    with pytest.raises(ValueError, match="unknown scenario 'nope'"):
        get_scenario("nope")


def test_registry_returns_fresh_specs_and_rejects_collisions():
    a = get_scenario("smoke-lm")
    a.topology.num_devices = 1          # caller-owned: mutate freely
    assert get_scenario("smoke-lm").topology.num_devices == 40
    with pytest.raises(ValueError, match="already registered"):
        register_scenario("smoke-lm", lambda: ScenarioSpec())
    from repro.sim import registry
    register_scenario("test-tiny", lambda: ScenarioSpec(
        name="test-tiny", workload=WorkloadSpec(rate_hz=1.0)))
    try:
        assert get_scenario("test-tiny").name == "test-tiny"
    finally:
        registry._REGISTRY.pop("test-tiny")    # keep the registry hermetic


# ------------------------------------------------- engine validation (PR)

def _tiny_stack():
    return build_stack(PlannerSpec())


def test_fleet_engine_rejects_handover_without_mobility():
    sc = _tiny_stack()
    topo = make_fleet(2, 1, seed=0)
    with pytest.raises(ValueError, match="needs a mobility model"):
        FleetEngine(topo, sc.graph, sc.planner, handover="bocd")


def test_fleet_engine_rejects_unknown_names():
    sc = _tiny_stack()
    topo = make_fleet(2, 1, seed=0)
    with pytest.raises(ValueError, match="unknown handover policy"):
        FleetEngine(topo, sc.graph, sc.planner, handover="sometimes")
    with pytest.raises(ValueError, match="unknown router"):
        FleetEngine(topo, sc.graph, sc.planner, router="warp")
    with pytest.raises(ValueError, match="nearest-edge routing needs"):
        FleetEngine(topo, sc.graph, sc.planner, router="nearest")


# ------------------------------------------------------ deprecated shims

def test_smoke_lm_scenario_tuple_shim_warns():
    with pytest.warns(DeprecationWarning, match="smoke_lm_scenario"):
        out = smoke_lm_scenario()
    assert len(out) == 3                # legacy arity preserved
    cfg, graph, planner = out
    assert graph.num_exits >= 1 and planner is not None


def test_smoke_mobility_scenario_tuple_shim_warns():
    with pytest.warns(DeprecationWarning, match="smoke_mobility_scenario"):
        out = smoke_mobility_scenario(3, 2, seed=0, policy="none")
    assert len(out) == 6
    assert out[5] is None               # policy='none' -> no controller


def test_scenario_object_replaces_tuple_arity():
    """The named Scenario result: same objects the tuples carried, but by
    field name, independent of flags."""
    sc = Simulation(apply_overrides(get_scenario("smoke-lm"), {
        "topology.num_devices": 2, "workload.horizon_s": 1.0})).build()
    for attr in ("spec", "cfg", "graph", "planner", "topo", "workload",
                 "engine"):
        assert getattr(sc, attr) is not None
    assert sc.model is None             # timing-only: no real decode stack
    assert sc.mobility is None and sc.handover is None


# ------------------------------------------------------------------- CLI

def test_cli_list(capsys):
    assert sim_main(["--list"]) == 0
    out = capsys.readouterr().out
    for name in BUILTINS:
        assert name in out


def test_cli_json_cell(capsys):
    rc = sim_main(["--scenario", "smoke-lm", "--json",
                   "--set", "topology.num_devices=6",
                   "--set", "workload.horizon_s=4.0",
                   "--set", "router.name=jsq"])
    assert rc == 0
    payload = json.loads(capsys.readouterr().out)
    assert payload["scenario"] == "smoke-lm"
    assert payload["spec"]["topology"]["num_devices"] == 6
    assert payload["spec"]["router"]["name"] == "jsq"
    assert payload["metrics"]["requests"] > 0
    assert 0.0 <= payload["metrics"]["slo_attainment"] <= 1.0


def test_cli_spec_file_round_trip(tmp_path, capsys):
    spec = apply_overrides(get_scenario("smoke-lm"),
                           {"topology.num_devices": 5,
                            "workload.horizon_s": 3.0})
    path = tmp_path / "cell.json"
    path.write_text(spec.to_json())
    assert sim_main(["--spec", str(path), "--json"]) == 0
    payload = json.loads(capsys.readouterr().out)
    assert payload["spec"] == spec.to_dict()


def test_cli_rejects_bad_usage():
    with pytest.raises(ValueError, match="exactly one of"):
        sim_main(["--json"])
    with pytest.raises(ValueError, match="key=value"):
        sim_main(["--scenario", "smoke-lm", "--set", "oops"])
    with pytest.raises(ValueError, match="unknown scenario"):
        sim_main(["--scenario", "nope"])


# ------------------------------------- equivalence gate (legacy parity)

@pytest.mark.parametrize("router",
                         ("round-robin", "jsq", "bandwidth-aware", "joint"))
def test_simulation_matches_legacy_static_wiring(router):
    """`smoke-lm` across all four routers: a Simulation built from the spec
    must reproduce the legacy hand-wired run_cell (make_fleet +
    make_workload + FleetEngine with ad-hoc seed offsets) bit-for-bit."""
    spec = apply_overrides(get_scenario("smoke-lm"),
                           {"router.name": router})
    got = Simulation(spec).run()

    stack = build_stack(spec.planner)           # the pre-repro.sim wiring
    topo = make_fleet(40, 4, seed=2, edge_capacity=8, lo_mbps=0.1,
                      hi_mbps=6.0, max_edge_slowdown=4.0)
    wl = make_workload(40, rate_hz=1.2 * 40, horizon_s=30.0, seed=3,
                       arrival="diurnal", device_skew=1.0)
    want = FleetEngine(topo, stack.graph, stack.planner, router=router).run(wl)

    assert want.summary() == got.summary()
    assert [r.rid for r in want.records] == [r.rid for r in got.records]


@pytest.mark.parametrize("policy", ("none", "oracle", "bocd"))
def test_simulation_matches_legacy_mobility_wiring(policy):
    """`smoke-mobility` across all handover policies: spec-built vs the
    legacy smoke_mobility_scenario + hand-wired engine, including the
    handover log (migration timing) — bit-identical."""
    spec = get_scenario("smoke-mobility")
    spec = replace(spec, mobility=replace(spec.mobility, policy=policy))
    got = Simulation(spec).run()

    stack = build_stack(spec.planner)           # the pre-repro.sim wiring
    topo, mobility = make_mobile_fleet(40, 4, seed=3, speed=0.25,
                                       horizon_s=60.0, floor_mbps=0.1,
                                       noise_sigma=0.08)
    ctrl = None if policy == "none" else HandoverController(
        mobility, policy=policy, sample_dt=0.5, hazard=1 / 20.0)
    wl = make_workload(40, rate_hz=0.2 * 40, horizon_s=25.0, seed=4,
                       device_skew=0.5,
                       tenants=get_scenario("smoke-mobility").workload.tenants)
    want = FleetEngine(topo, stack.graph, stack.planner, router="nearest",
                       mobility=mobility, handover=ctrl).run(wl)

    assert want.summary() == got.summary()
    assert want.handover_log == got.handover_log


@pytest.mark.parametrize("name", ("smoke-lm", "smoke-mobility"))
def test_json_round_trip_rebuilds_identical_metrics(name):
    """Serialization gate: spec -> JSON -> spec rebuilds a simulation whose
    FleetMetrics (completed count, SLO attainment, handover log) are
    bit-identical to the original run."""
    spec = get_scenario(name)
    a = Simulation(spec).run()
    b = Simulation(ScenarioSpec.from_json(spec.to_json())).run()
    assert len(a.records) == len(b.records)
    assert a.summary() == b.summary()
    assert a.handover_log == b.handover_log


def test_cli_runs_sharded_spec(capsys):
    """Regression: a sharded spec has no single live engine — the CLI must
    report the merged event counts from the tile infos instead."""
    rc = sim_main(["--scenario", "smoke-lm", "--json",
                   "--set", "topology.num_devices=20",
                   "--set", "topology.num_edges=4",
                   "--set", "topology.shards=2",
                   "--set", "workload.horizon_s=4.0"])
    assert rc == 0
    payload = json.loads(capsys.readouterr().out)
    assert payload["spec"]["topology"]["shards"] == 2
    assert payload["metrics"]["requests"] > 0
    assert payload["events"]["processed"] > 0
