import os

# Smoke tests and benches must see the real single device; ONLY the dry-run
# launcher sets xla_force_host_platform_device_count (see launch/dryrun.py).
assert "xla_force_host_platform_device_count" not in os.environ.get("XLA_FLAGS", "")

import jax
import jax.numpy as jnp
import numpy as np
import pytest


@pytest.fixture(scope="session")
def rng():
    return jax.random.key(0)


@pytest.fixture(scope="session")
def alexnet_setup():
    """Shared branchy AlexNet + params + graph (expensive to re-init)."""
    from repro.core import alexnet_graph
    from repro.models.alexnet import BranchyAlexNet, BranchyAlexNetConfig

    net = BranchyAlexNet(BranchyAlexNetConfig())
    params = net.init(jax.random.key(0))
    graph = alexnet_graph(net)
    return net, params, graph


@pytest.fixture(scope="session")
def alexnet_planner(alexnet_setup):
    from repro.core import EdgentPlanner

    net, params, graph = alexnet_setup
    x = jax.random.normal(jax.random.key(1), (1, 32, 32, 3))
    return EdgentPlanner(graph, latency_req_s=1.0).offline_static(params, x)
