"""repro.obs: tracing, timelines, self-profiling — and the determinism
contract they ride on.

The load-bearing property: observers are *read-only* with respect to the
simulation.  Attaching a Tracer/Timeline/SimProfiler never schedules
simulation events (timeline "obs" ticks excepted — and those never mutate
state), consumes RNG, or reorders the heap, so ``summary()`` and the
``handover_log`` are bit-identical with observers on or off.  That is
asserted here deterministically on the smoke scenarios and (with
hypothesis installed) fuzzed over fleet shapes.

Also covered: registry instruments, the schema-complete zero-request
summary, structural trace well-formedness (non-negative durations, spans
nested within their request's lifetime, monotone per-track timestamps,
balanced async pairs), timeline export/load round-trips, the profiler
report, and the ``repro.sim --trace`` / ``python -m repro.obs`` CLIs.
"""
import json
from dataclasses import replace

import numpy as np
import pytest

from _hypothesis_compat import given, settings, st
from repro.fleet.metrics import FleetMetrics
from repro.obs import (EDGE_GAUGES, MetricsRegistry, SimProfiler, Timeline,
                       Tracer, load_timeline, load_trace, validate_trace)
from repro.sim import (MobilitySpec, PlannerSpec, RouterSpec, ScenarioSpec,
                       Simulation, TopologySpec, WorkloadSpec, get_scenario)

# ---------------------------------------------------------------- registry


def test_registry_instruments():
    r = MetricsRegistry()
    c = r.counter("n")
    c.inc()
    c.inc(3)
    assert c.value == 4
    g = r.gauge("depth")
    g.set(2.5)
    assert g.value == 2.5
    f = r.family("exits")
    f.inc(3)
    f.inc(1, 2)
    f.inc(3)
    assert f.as_dict() == {1: 2, 3: 2}          # sorted label order
    assert f.get(1) == 2 and f.get(9) == 0
    assert 3 in f and 9 not in f and len(f) == 2


def test_registry_histogram_matches_numpy():
    r = MetricsRegistry()
    h = r.histogram("lat")
    vals = [0.3, 1.7, 0.2, 5.0, 0.9]
    for v in vals:
        h.observe(v)
    # bit-identical to the pre-registry list math (the summary() contract)
    assert h.percentile(95) == float(np.percentile(np.array(vals), 95))
    assert h.mean() == float(np.mean(np.array(vals)))
    empty = r.histogram("unused")
    assert empty.percentile(50) is None and empty.mean() is None


def test_registry_get_or_create_and_kind_clash():
    r = MetricsRegistry()
    assert r.counter("x") is r.counter("x")
    with pytest.raises(ValueError, match="already registered"):
        r.histogram("x")
    r.counter("x").inc()
    r.histogram("h").observe(1.0)
    snap = r.snapshot()
    assert snap["x"] == 1
    assert snap["h"]["count"] == 1
    assert "x" in r and "h" in r.names()


# ------------------------------------------------- schema-complete summary


def test_empty_summary_schema_complete():
    """Zero completed requests must not change the summary schema: same
    keys, zero/empty values, None for undefined statistics."""
    empty = FleetMetrics(num_edges=3).summary()
    populated = Simulation(_small_mobility_spec()).run().summary()
    assert set(empty) == set(populated)
    assert empty["requests"] == 0
    assert empty["slo_attainment"] == 0.0
    assert empty["p50_latency_s"] is None
    assert empty["p95_latency_s"] is None
    assert empty["mean_queue_delay_s"] is None
    assert empty["handover_slo"] is None
    assert empty["exit_histogram"] == {}
    assert empty["slo_by_tenant"] == {}
    assert empty["edge_utilization"] == {0: 0.0, 1: 0.0, 2: 0.0}
    json.dumps(empty)                           # still JSON-serializable


def test_summary_without_requests_keeps_observed_aggregates():
    """Non-request aggregates (handovers, backbone traffic) still report
    what was observed even when no request completed."""
    m = FleetMetrics(num_edges=2)
    m.add_transfer(0, 1, 500_000)
    m.add_handover(0, 1, 500_000, t_s=1.5)
    s = m.summary()
    assert s["requests"] == 0
    assert s["handovers"] == 1
    assert s["backbone_mb"] == 0.5
    assert s["migrated_mb"] == 0.5


# ------------------------------------------------------ observer neutrality


def _small_mobility_spec(seed=7):
    return ScenarioSpec(
        name="obs-mobility", seed=seed,
        planner=PlannerSpec(result_kb=4.0),
        topology=TopologySpec(kind="mobile", num_devices=10, num_edges=3,
                              speed=0.5, horizon_s=40.0, floor_mbps=0.1,
                              noise_sigma=0.08),
        workload=WorkloadSpec(rate_hz=6.0, horizon_s=8.0),
        router=RouterSpec(name="nearest"),
        mobility=MobilitySpec(policy="bocd"))


def _run_observed(spec, tmp_path, tag):
    traced = replace(spec, engine=replace(
        spec.engine, trace=str(tmp_path / f"{tag}.json"),
        timeline=str(tmp_path / f"{tag}.jsonl")))
    sim = Simulation(traced)
    m = sim.run()
    return sim, m


@pytest.mark.parametrize("scenario", ["smoke-lm", "smoke-mobility"])
def test_observer_neutrality_smoke(scenario, tmp_path):
    """The tentpole contract on the canonical scenarios: summaries AND the
    handover log are bit-identical with the tracer+timeline attached."""
    spec = get_scenario(scenario)
    base = Simulation(spec).run()
    sim, observed = _run_observed(spec, tmp_path, scenario)
    assert observed.summary() == base.summary()
    assert observed.handover_log == base.handover_log
    assert validate_trace(load_trace(str(tmp_path / f"{scenario}.json"))) \
        == []


@settings(max_examples=8, deadline=None)
@given(nd=st.integers(min_value=2, max_value=12),
       ne=st.integers(min_value=1, max_value=4),
       rate=st.floats(min_value=0.5, max_value=12.0),
       seed=st.integers(min_value=0, max_value=2 ** 16),
       policy=st.sampled_from(["none", "bocd", "oracle"]))
def test_observer_neutrality_property(nd, ne, rate, seed, policy):
    spec = ScenarioSpec(
        name="obs-prop", seed=seed,
        planner=PlannerSpec(result_kb=4.0),
        topology=TopologySpec(kind="mobile", num_devices=nd, num_edges=ne,
                              speed=0.5, horizon_s=30.0),
        workload=WorkloadSpec(rate_hz=rate, horizon_s=5.0),
        router=RouterSpec(name="nearest"),
        mobility=MobilitySpec(policy=policy))
    base = Simulation(spec).run()
    sc = Simulation(spec).build()
    sc.engine.tracer = Tracer()
    sc.engine.timeline = Timeline(ne, num_devices=nd)
    sc.engine.profiler = SimProfiler()
    observed = sc.engine.run(sc.workload)
    assert observed.summary() == base.summary()
    assert observed.handover_log == base.handover_log
    if observed.summary()["requests"] > 0:
        assert validate_trace(sc.engine.tracer.to_chrome()) == []


# --------------------------------------------------- trace well-formedness


@pytest.fixture(scope="module")
def mobility_trace(tmp_path_factory):
    """One traced smoke-mobility run shared by the structural tests."""
    out = tmp_path_factory.mktemp("obs") / "trace.json"
    spec = get_scenario("smoke-mobility")
    spec = replace(spec, engine=replace(spec.engine, trace=str(out)))
    sim = Simulation(spec)
    summary = sim.run().summary()
    return load_trace(str(out)), summary, sim


def test_trace_valid_and_has_all_stages(mobility_trace):
    """The acceptance artifact: Perfetto-loadable, with spans for every
    lifecycle stage and per-edge counter tracks."""
    trace, summary, _ = mobility_trace
    assert validate_trace(trace) == []
    events = trace["traceEvents"]
    x_names = {e["name"] for e in events if e["ph"] == "X"}
    assert {"queue", "uplink", "prefill", "decode", "round",
            "transfer"} <= x_names
    async_names = {e["name"] for e in events if e["ph"] in ("b", "e")}
    assert {"request", "queue", "handover"} <= async_names
    counter_names = {e["name"] for e in events if e["ph"] == "C"}
    assert {"backlog_s", "slots", "tokens_owed", "coop_inflight"} \
        <= counter_names
    # one request async pair per completed request
    begins = sum(1 for e in events
                 if e["ph"] == "b" and e["name"] == "request")
    assert begins == summary["requests"]


def test_trace_spans_nested_within_request_lifetime(mobility_trace):
    """Every per-request X span lies inside its request's async
    [begin, end] window, and durations are non-negative."""
    trace, _, _ = mobility_trace
    events = trace["traceEvents"]
    window = {}
    for e in events:
        if e["name"] == "request" and e["ph"] in ("b", "e"):
            lo, hi = window.get(e["id"], (None, None))
            window[e["id"]] = (e["ts"], hi) if e["ph"] == "b" \
                else (lo, e["ts"])
    eps = 1e-3          # trace-event us rounding slack
    checked = 0
    for e in events:
        if e["ph"] != "X":
            continue
        assert e["dur"] >= 0
        rid = (e.get("args") or {}).get("rid")
        if rid is None or rid not in window:
            continue
        lo, hi = window[rid]
        assert lo is not None and hi is not None
        assert e["ts"] >= lo - eps
        assert e["ts"] + e["dur"] <= hi + eps
        checked += 1
    assert checked > 0


def test_trace_monotone_per_track(mobility_trace):
    """Edge tracks emit in round order, so timestamps never regress within
    one (pid, tid) span track or one (pid, name) counter track.  (Device/
    net pseudo-process spans are emitted at *scheduling* time with future
    start stamps — deferred local starts — so only edge pids are strictly
    ordered; viewers sort by ts regardless.)"""
    trace, _, _ = mobility_trace
    last_x, last_c = {}, {}
    for e in trace["traceEvents"]:
        if e.get("pid", 0) >= Tracer.PID_DEVICES:
            continue
        if e["ph"] == "X":
            key = (e["pid"], e["tid"])
            assert e["ts"] >= last_x.get(key, -1.0)
            last_x[key] = e["ts"]
        elif e["ph"] == "C":
            key = (e["pid"], e["name"])
            assert e["ts"] >= last_c.get(key, -1.0)
            last_c[key] = e["ts"]
    assert last_x and last_c


def test_rerun_event_counts_identical(mobility_trace):
    """Satellite (b): the per-kind event counts are part of the
    deterministic contract — identical across reruns of one engine."""
    _, _, sim = mobility_trace
    sc = sim.scenario
    a = (sc.engine.events_processed, dict(sc.engine.event_counts))
    sc.engine.run(sc.workload)
    b = (sc.engine.events_processed, dict(sc.engine.event_counts))
    assert a == b
    assert a[0] == sum(v for k, v in a[1].items() if k != "sample") \
        + a[1].get("sample", 0) * sc.topo.num_devices


# ----------------------------------------------------------------- timeline


def test_timeline_roundtrip(tmp_path):
    spec = _small_mobility_spec()
    path = tmp_path / "tl.jsonl"
    spec = replace(spec, engine=replace(spec.engine, timeline=str(path)))
    sim = Simulation(spec)
    sim.run()
    tl = sim.scenario.engine.timeline
    assert tl.num_retained > 0
    loaded = load_timeline(str(path))
    assert loaded["header"]["samples"] == tl.num_retained
    assert loaded["header"]["edge_gauges"] == list(EDGE_GAUGES)
    assert loaded["t"].shape == (tl.num_retained,)
    for g in EDGE_GAUGES:
        assert loaded["edge"][g].shape == (tl.num_retained, 3)
    # mobility runs carry the per-device signals the sweep computed
    assert loaded["device"]["bw_bps"].shape == (tl.num_retained, 10)
    assert np.all(np.diff(loaded["t"]) > 0)
    # completions are cumulative, hence monotone per edge
    assert np.all(np.diff(loaded["edge"]["completed"], axis=0) >= 0)


def test_timeline_static_fleet_uses_obs_events(tmp_path):
    """Fleets with no sampling sweep get dedicated 'obs' ticks — and those
    must not change the summary either."""
    spec = ScenarioSpec(
        name="obs-static", seed=3,
        topology=TopologySpec(num_devices=8, num_edges=2),
        workload=WorkloadSpec(rate_hz=10.0, horizon_s=5.0))
    base = Simulation(spec).run().summary()
    path = tmp_path / "tl.jsonl"
    traced = replace(spec, engine=replace(spec.engine, timeline=str(path),
                                          timeline_dt=0.25))
    sim = Simulation(traced)
    s = sim.run().summary()
    assert s == base
    engine = sim.scenario.engine
    assert engine.event_counts.get("obs", 0) > 0
    assert load_timeline(str(path))["header"]["dt"] == 0.25


def test_timeline_ring_overwrites_oldest():
    tl = Timeline(1, dt=1.0, capacity=4)

    class _Edge:
        tokens_owed = 0
        active = ()
        queue = ()
        q_dead = 0
        coop_inflight = 0
        busy_s = 0.0
        completed = 0
        capacity = 8

        def backlog_s(self):
            return 0.0

    class _Topo:
        edges = [_Edge()]

    for t in range(6):
        tl.snapshot(float(t), _Topo())
    assert tl.n == 6 and tl.num_retained == 4
    assert [r["t"] for r in tl.rows()] == [2.0, 3.0, 4.0, 5.0]


# ----------------------------------------------------------------- profiler


def test_profiler_report(tmp_path):
    spec = _small_mobility_spec()
    sim = Simulation(spec)
    sc = sim.build()
    prof = SimProfiler()
    prof.build_s = sim.build_s
    sc.engine.profiler = prof
    base = Simulation(spec).run().summary()
    s = sc.engine.run(sc.workload).summary()
    assert s == base                    # profiling is neutral too
    rep = prof.report(sc.engine)
    assert rep["wall_s"] > 0
    assert rep["peak_heap"] > 0
    assert rep["build_s"] is not None
    assert set(rep["events"]) == set(sc.engine.event_counts)
    for kind, block in rep["events"].items():
        assert block["count"] == sc.engine.event_counts[kind]
    assert 0.0 <= rep["tombstone_ratio"] <= 1.0
    caches = rep["stepper_caches"]
    assert set(caches) == {"plan", "step", "hop", "jit", "decode", "arena"}
    assert caches["plan"]["hits"] + caches["plan"]["misses"] > 0
    # nearest-routing mobility replans via the JointPlanner
    assert set(rep["replanner_caches"]) == {"score", "ordered_sets"}


def test_profiler_reset_keeps_build_s():
    prof = SimProfiler()
    prof.build_s = 1.25
    prof.add("round", 0.5, heap_len=10)
    prof.reset()
    assert prof.run_wall_s == 0.0 and prof.peak_heap == 0
    assert prof.report()["build_s"] == 1.25


# ---------------------------------------------------------------------- CLI


def test_obs_report_and_validate_cli(tmp_path, capsys):
    from repro.obs.report import main as obs_main
    from repro.sim.cli import main as sim_main
    trace = tmp_path / "t.json"
    tl = tmp_path / "t.jsonl"
    rc = sim_main(["--scenario", "smoke-mobility",
                   "--trace", str(trace), "--timeline", str(tl), "--json"])
    assert rc == 0
    payload = json.loads(capsys.readouterr().out)
    assert payload["events"]["processed"] > 0
    assert payload["events"]["by_kind"]["handover"] > 0
    assert payload["metrics"]["requests"] > 0

    assert obs_main(["validate", str(trace)]) == 0
    assert "valid Chrome trace" in capsys.readouterr().out

    assert obs_main(["report", str(trace)]) == 0
    out = capsys.readouterr().out
    for stage in ("queue", "uplink", "prefill", "decode", "transfer",
                  "handover", "request e2e", "edge utilization"):
        assert stage in out

    assert obs_main(["report", str(tl)]) == 0
    out = capsys.readouterr().out
    assert "timeline:" in out and "backlog_s" in out


def test_obs_validate_rejects_broken_trace(tmp_path, capsys):
    from repro.obs.report import main as obs_main
    bad = tmp_path / "bad.json"
    bad.write_text(json.dumps({"traceEvents": [
        {"name": "x", "ph": "X", "pid": 0, "tid": 0, "ts": 1.0,
         "dur": -5.0},
        {"name": "q", "ph": "e", "cat": "req", "id": 1, "pid": 0,
         "tid": 0, "ts": 2.0},
    ]}))
    assert obs_main(["validate", str(bad)]) == 1
    err = capsys.readouterr().err
    assert "negative duration" in err and "async end before begin" in err


def test_trace_validate_helpers():
    assert validate_trace({}) == ["no traceEvents array"]
    t = Tracer()
    t.complete("a", 0.0, 1.0, 0, 0)
    t.async_begin("r", 1, 0.0, 0, 0)
    t.async_end("r", 1, 2.0, 0, 0)
    t.counter("c", 0.5, 0, {"v": 1.0})
    assert validate_trace(t.to_chrome()) == []
    t.async_begin("r", 2, 3.0, 0, 0)    # left open
    problems = validate_trace(t.to_chrome())
    assert any("unbalanced" in p for p in problems)
