"""Checkpoint manager + resilient loop (fault-tolerance contract)."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpointing import CheckpointManager
from repro.runtime.fault_tolerance import (FailureInjector, ResilientLoop,
                                           SimulatedFailure)


def _tree(x=0.0):
    return {"a": jnp.full((4, 3), x), "nested": {"b": jnp.arange(5) + int(x)},
            "t": (jnp.ones(2) * x, jnp.zeros(1))}


def test_save_restore_roundtrip(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    tree = _tree(3.0)
    mgr.save(10, tree, async_=False)
    restored, step = mgr.restore(_tree(0.0))
    assert step == 10
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_async_save_and_gc(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    for s in (1, 2, 3, 4):
        mgr.save(s, _tree(float(s)))
    mgr.wait()
    assert mgr.all_steps() == [3, 4]
    restored, step = mgr.restore(_tree())
    assert step == 4
    assert float(restored["a"][0, 0]) == 4.0


def test_atomic_no_partial_dirs(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(7, _tree(1.0), async_=False)
    names = os.listdir(tmp_path)
    assert not any(n.endswith(".tmp") for n in names)


def test_resilient_loop_restarts(tmp_path):
    """Inject a failure mid-run: the loop restores and the final state is
    identical to a failure-free run (bitwise training restart contract)."""
    def step_fn(state, i):
        return jax.tree.map(lambda x: x + 1.0, state)

    def run(fail_at):
        mgr = CheckpointManager(str(tmp_path / f"ck_{fail_at}"))
        loop = ResilientLoop(mgr, save_every=5)
        inj = FailureInjector(fail_at=(fail_at,)) if fail_at else None
        state, info = loop.run(_tree(0.0), step_fn, 20, injector=inj)
        return state, info

    clean, info0 = run(None)
    failed, info1 = run(13)
    assert info0["restarts"] == 0 and info1["restarts"] == 1
    for a, b in zip(jax.tree.leaves(clean), jax.tree.leaves(failed)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_restore_missing_raises(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    with pytest.raises(FileNotFoundError):
        mgr.restore(_tree())
