"""Optimizer + gradient compression."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.optim.adamw import adamw_init, adamw_update
from repro.optim.grad_compress import (EFState, compress_grads, ef_init,
                                       quantize_int8, topk_compress)
from repro.optim.schedule import warmup_cosine


def test_adamw_minimizes_quadratic():
    params = {"w": jnp.array([5.0, -3.0, 2.0])}
    opt = adamw_init(params)
    loss = lambda p: jnp.sum(p["w"] ** 2)
    for _ in range(300):
        g = jax.grad(loss)(params)
        params, opt = adamw_update(g, opt, params, lr=5e-2, weight_decay=0.0)
    assert float(loss(params)) < 1e-2


def test_adamw_grad_clip():
    params = {"w": jnp.array([1.0])}
    opt = adamw_init(params)
    g = {"w": jnp.array([1e9])}
    p2, _ = adamw_update(g, opt, params, lr=0.1, weight_decay=0.0, grad_clip=1.0)
    assert abs(float(p2["w"][0]) - 0.9) < 1e-3   # clipped unit-step


def test_bf16_moments_shardable():
    params = {"w": jnp.ones((8, 4))}
    opt = adamw_init(params, moment_dtype=jnp.bfloat16)
    assert opt.mu["w"].dtype == jnp.bfloat16
    g = {"w": jnp.ones((8, 4))}
    p2, opt2 = adamw_update(g, opt, params, lr=1e-2)
    assert opt2.nu["w"].dtype == jnp.bfloat16
    assert bool(jnp.all(jnp.isfinite(p2["w"])))


def test_quantize_roundtrip_error_bounded():
    x = jnp.linspace(-4, 4, 1000)
    q, s = quantize_int8(x)
    err = jnp.abs(q.astype(jnp.float32) * s - x).max()
    assert float(err) <= float(s) / 2 + 1e-6


def test_error_feedback_unbiased_over_time():
    """Error feedback: the *sum* of compressed grads converges to the sum of
    true grads (residual stays bounded) — the property that keeps int8 DCN
    all-reduce from biasing training."""
    rng = np.random.default_rng(0)
    g_true = {"w": jnp.asarray(rng.normal(0, 1, (64,)), jnp.float32)}
    ef = ef_init(g_true)
    total_c = jnp.zeros(64)
    n = 50
    for _ in range(n):
        c, ef = compress_grads(g_true, ef)
        total_c = total_c + c["w"]
    # mean compressed grad ~= true grad to quantization precision
    np.testing.assert_allclose(np.asarray(total_c / n), np.asarray(g_true["w"]),
                               atol=2e-3)


def test_topk_keeps_largest():
    g = jnp.asarray([0.1, -5.0, 0.2, 3.0, -0.05])
    out = topk_compress(g, frac=0.4)
    np.testing.assert_array_equal(np.asarray(out != 0), [False, True, False, True, False])


def test_warmup_cosine_shape():
    lrs = [float(warmup_cosine(jnp.asarray(s), peak_lr=1.0, warmup=10, total=100))
           for s in range(100)]
    assert lrs[0] < lrs[9] <= 1.0 + 1e-6
    assert lrs[10] == pytest.approx(1.0, rel=1e-2)
    assert lrs[99] < 0.2
