"""Geography sharding (repro.sim.shard) and the spatial nearest-edge grid.

Two determinism contracts introduced by the 100k-device scaling work:

* ``MobilityModel.nearest`` answers from a uniform spatial grid; it must be
  *bit-identical* to the brute-force ``argmin`` over the distance row —
  including the first-minimum tie-break — on random geographies, on exact
  equidistant tie points, and under global id offsets (``eid0``/``did0``).
* ``TopologySpec.shards = k`` defines the fleet as ``k`` disjoint geography
  tiles.  However the tiles execute — ``Simulation(spec).run()``, a
  sequential ``run_sharded``, or a spawn-pool ``processes=k`` run — the
  merged summary and handover log are bit-identical.
"""
import dataclasses

import numpy as np
import pytest

from repro.fleet.mobility import (MobilityModel, Trajectory, edge_grid,
                                  make_mobile_fleet)
from repro.sim import ScenarioSpec, Simulation, get_scenario
from repro.sim.shard import (RID_STRIDE, run_sharded, run_sharded_info,
                             tile_spec)


def _resharded(name: str, *, shards: int, num_devices: int,
               num_edges: int) -> ScenarioSpec:
    base = get_scenario(name)
    return dataclasses.replace(
        base, topology=dataclasses.replace(
            base.topology, shards=shards, num_devices=num_devices,
            num_edges=num_edges))


# ------------------------------------------------- spatial nearest-edge grid


@pytest.mark.parametrize("num_edges", [1, 4, 7, 16, 100])
def test_grid_matches_bruteforce_random_geographies(num_edges):
    """Grid-accelerated nearest == argmin over the distance row, bitwise,
    for every device at many timestamps, across grid shapes (including a
    single-cell 1-edge grid and a non-square 7-edge one)."""
    _, mob = make_mobile_fleet(30, num_edges, seed=num_edges,
                               speed=0.4, horizon_s=20.0)
    for did in range(30):
        for t in np.linspace(0.0, 20.0, 9):
            assert mob.nearest(did, float(t)) == \
                mob.nearest_bruteforce(did, float(t))


def test_grid_tie_break_is_first_minimum():
    """Exactly equidistant edges must resolve to the lowest edge id — the
    ``argmin`` first-minimum rule — even when the winner lives in a farther
    grid ring than a higher-id candidate."""
    pos = edge_grid(4)    # 2x2 grid: (0.25, 0.25) .. (0.75, 0.75), exact
    parks = [
        (0.5, 0.5),                  # equidistant center of all four edges
        (0.5, 0.25),                 # equidistant between edges 0 and 1
        (0.25, 0.25),                # on edge 0 exactly (distance 0)
        (-3.0, 0.5),                 # far outside the grid bounding box
        (0.0, 2.5),                  # outside, above the top-left corner
    ]
    trajs = [Trajectory(np.zeros(1), np.array([p])) for p in parks]
    mob = MobilityModel(edge_pos=pos, trajectories=trajs, noise=None)
    for did in range(len(parks)):
        assert mob.nearest(did, 0.0) == mob.nearest_bruteforce(did, 0.0)
    # the center point is genuinely tied four ways (the coordinates are
    # exact binary floats); the winner must be the lowest edge id even
    # though edge 0 sits in a farther grid ring than edge 3
    d = [mob.distance(0, e, 0.0) for e in range(4)]
    assert d[0] == d[1] == d[2] == d[3]
    assert mob.nearest(0, 0.0) == 0


def test_grid_respects_global_id_offsets():
    """A tile's model (eid0/did0 offsets) answers in global edge ids and
    still matches brute force."""
    _, mob = make_mobile_fleet(10, 5, seed=11, speed=0.3, horizon_s=10.0,
                               eid0=100, did0=5000)
    for did in range(5000, 5010):
        for t in (0.0, 3.7, 10.0):
            near = mob.nearest(did, t)
            assert near == mob.nearest_bruteforce(did, t)
            assert 100 <= near < 105


# ------------------------------------------------------- tile spec derivation


def test_tile_specs_split_rate_and_namespaces():
    spec = _resharded("smoke-mobility", shards=4, num_devices=80,
                      num_edges=8)
    fleet_rate = spec.workload.resolve_rate_hz(80)
    tiles = [tile_spec(spec, g) for g in range(4)]
    for g, t in enumerate(tiles):
        assert t.topology.shards == 1
        assert t.topology.num_devices == 20 and t.topology.num_edges == 2
        assert t.seed != spec.seed or g == 0
    assert sum(t.workload.resolve_rate_hz(t.topology.num_devices)
               for t in tiles) == pytest.approx(fleet_rate)
    assert len({t.seed for t in tiles}) == 4


def test_sharded_ids_are_globally_disjoint():
    """Per-tile request/device/edge ids land in disjoint global ranges."""
    spec = _resharded("smoke-mobility", shards=4, num_devices=80,
                      num_edges=8)
    metrics = run_sharded(spec)
    rids, devs, edges = set(), set(), set()
    for r in metrics.records:
        rids.add(r.rid)
        devs.add(r.device)
        if r.edge >= 0:
            edges.add(r.edge)
    tiles_hit = {rid // RID_STRIDE for rid in rids}
    assert tiles_hit == {0, 1, 2, 3}
    assert all(0 <= d < 80 for d in devs)
    assert all(0 <= e < 8 for e in edges)
    # block-diagonal reachability: a device's serving edge is in its tile
    for r in metrics.records:
        if r.edge >= 0:
            assert r.edge // 2 == r.device // 20


# ---------------------------------------------- sharded-vs-unsharded pins


def _run_three_ways(spec):
    a = Simulation(spec).run()
    b, info = run_sharded_info(spec)
    c = run_sharded(spec, processes=2)
    return a, b, c, info


@pytest.mark.parametrize("name,shards,nd,ne", [
    ("smoke-lm", 2, 40, 4),           # static fleet, bandwidth-aware router
    ("smoke-mobility", 4, 80, 8),     # mobile fleet, BOCD handovers
    ("coop", 2, 40, 4),               # joint multi-edge planner
])
def test_sharded_execution_is_bit_identical(name, shards, nd, ne):
    """``Simulation(spec).run()``, sequential ``run_sharded``, and a
    spawn-pool ``processes=2`` run all produce the identical summary and
    handover log for the same sharded spec."""
    spec = _resharded(name, shards=shards, num_devices=nd, num_edges=ne)
    a, b, c, info = _run_three_ways(spec)
    assert a.summary() == b.summary() == c.summary()
    assert a.handover_log == b.handover_log == c.handover_log
    assert info["shards"] == shards
    assert info["requests"] > 0
    assert info["events_processed"] == \
        sum(t["events_processed"] for t in info["tiles"])


def test_sharded_rerun_determinism():
    spec = _resharded("smoke-mobility", shards=4, num_devices=80,
                      num_edges=8)
    a = run_sharded(spec)
    b = run_sharded(spec)
    assert a.summary() == b.summary()
    assert a.handover_log == b.handover_log


# -------------------------------------------------------------- validation


def test_shards_must_divide_fleet():
    base = get_scenario("smoke-mobility")
    with pytest.raises(ValueError, match="shards"):
        dataclasses.replace(base, topology=dataclasses.replace(
            base.topology, shards=3, num_devices=80, num_edges=8))
    with pytest.raises(ValueError, match="shards"):
        dataclasses.replace(base, topology=dataclasses.replace(
            base.topology, shards=4, num_devices=80, num_edges=6))
    with pytest.raises(ValueError, match="shards"):
        dataclasses.replace(base, topology=dataclasses.replace(
            base.topology, shards=0))


def test_unsharded_spec_rejected_by_run_sharded():
    with pytest.raises(ValueError, match="nothing to shard"):
        run_sharded(get_scenario("smoke-mobility"))


def test_sharded_spec_rejects_observers_and_build():
    spec = _resharded("smoke-mobility", shards=4, num_devices=80,
                      num_edges=8)
    traced = dataclasses.replace(spec, engine=dataclasses.replace(
        spec.engine, trace="/tmp/never-written.json"))
    with pytest.raises(ValueError, match="trace"):
        run_sharded(traced)
    with pytest.raises(ValueError, match="no single live Scenario"):
        Simulation(spec).build()


# ------------------------------------------------------------- scale smoke


@pytest.mark.perf
def test_sharded_scale_smoke():
    """Scale smoke (marked perf): a 400-device mobility fleet across 8
    geography tiles, sequential vs spawn-pool execution — the CI perf leg's
    sharded equivalence cell."""
    base = get_scenario("smoke-mobility")
    spec = dataclasses.replace(
        base,
        topology=dataclasses.replace(base.topology, shards=8,
                                     num_devices=400, num_edges=8),
        workload=dataclasses.replace(base.workload, horizon_s=15.0),
        engine=dataclasses.replace(base.engine, retain_records=False))
    seq, info = run_sharded_info(spec)
    par = run_sharded(spec, processes=4)
    assert seq.summary() == par.summary()
    assert info["shards"] == 8 and len(info["tiles"]) == 8
    assert info["events_processed"] > 0
    assert seq.summary()["requests"] == info["requests"]
