"""Calibration-subsystem suite (docs/calibration.md).

* batched real decode: ``CoInferenceStepper.decode_step_batch`` produces
  token-bit-identical results to the serial per-request path at B=1..8
  across exits (mixed exits and mixed cache geometries in one call), and a
  small real-decode fleet run is token- and summary-identical batched vs
  serial while actually exercising the vmap path (>= 4 co-located
  requests, pinned via ``cache_stats()``);
* jit-cache hygiene: the batched-variant cache is LRU-bounded
  (``jit_cache_max``) and ``cache_stats()`` keeps its pre-PR blocks;
* model-construction split: ``build_stack`` pays for the model/params only
  when asked; sharded specs with ``real_decode=True`` raise ``ValueError``;
* goldens: model-only ``smoke-lm`` stays byte-identical to the pre-PR
  golden with calibration off;
* ``CalibrationTable`` strict JSON round-trip (ScenarioSpec conventions);
* fit: the joint branch-level regression reproduces planted latencies, and
  (hypothesis) the per-layer path recovers planted Table-I coefficients;
  a calibrated ``ElasticPlanner``'s exits are monotone in bandwidth;
* ``validate_scenario`` emits a schema-complete error report.
"""
import dataclasses
import json
import os

import numpy as np
import pytest

from _hypothesis_compat import HAVE_HYPOTHESIS, given, settings, st
from repro.calib.fit import (elastic_planner_from_table, fit_table,
                             models_from_table)
from repro.calib.table import CalibrationTable, TimingSample
from repro.core.latency_model import RegressionLatencyModel
from repro.serving.engine import CoInferenceStepper
from repro.sim import (CalibrationSpec, EngineSpec, PlannerSpec, RouterSpec,
                       ScenarioSpec, Simulation, TopologySpec, WorkloadSpec,
                       get_scenario)
from repro.sim.build import build_stack

GOLDEN_DIR = os.path.join(os.path.dirname(__file__), "goldens")
ARCH = "llama3.2-1b"


@pytest.fixture(scope="module")
def stack():
    return build_stack(PlannerSpec(), with_model=True)


# --------------------------------------------------------- batched decode
def _prefill_rows(stack, n, *, prompt_len=6, extra=4, seed=7):
    """n independent B=1 (cache, tok) rows after a real prefill."""
    import jax.numpy as jnp
    rng = np.random.default_rng(seed)
    rows = []
    for _ in range(n):
        toks = jnp.asarray(
            rng.integers(0, stack.cfg.vocab_size, (1, prompt_len)),
            jnp.int32)
        cache = stack.model.init_cache(1, prompt_len + extra + 1,
                                       dtype=jnp.float32, enc_len=prompt_len)
        h, cache = stack.model.prefill(stack.params, toks, cache)
        logits = stack.model.logits(stack.params, h)
        tok = jnp.argmax(logits[:, -1, :], -1).astype(jnp.int32)[:, None]
        rows.append((cache, tok))
    return rows


def _decode_tokens_serial(stack, stepper, rows, exits, prompt_len, steps):
    import jax.numpy as jnp
    toks = [[] for _ in rows]
    state = list(rows)
    for step in range(steps):
        for i, (cache, tok) in enumerate(state):
            fn = stepper.decode_fn(exits[i])
            h, cache = fn(stack.params, cache, tok,
                          jnp.asarray(prompt_len + step, jnp.int32))
            logits = stack.model.logits(stack.params, h)
            tok = jnp.argmax(logits[:, -1, :], -1).astype(jnp.int32)[:, None]
            toks[i].append(int(tok[0, 0]))
            state[i] = (cache, tok)
    return toks


def _decode_tokens_batched(stack, stepper, rows, exits, prompt_len, steps):
    import jax.numpy as jnp
    toks = [[] for _ in rows]
    state = list(rows)
    for step in range(steps):
        items = [(exits[i], cache, tok, prompt_len + step)
                 for i, (cache, tok) in enumerate(state)]
        outs = stepper.decode_step_batch(stack.params, items)
        for i, (h, cache) in enumerate(outs):
            logits = stack.model.logits(stack.params, h)
            tok = jnp.argmax(logits[:, -1, :], -1).astype(jnp.int32)[:, None]
            toks[i].append(int(tok[0, 0]))
            state[i] = (cache, tok)
    return toks


def test_batched_decode_bit_identical_to_serial(stack):
    """Token values through decode_step_batch == the serial per-request
    path, at B=1..8, exits cycling through the graph, 3 decode steps."""
    stepper = CoInferenceStepper(stack.model, stack.graph, stack.planner)
    n_exits = stack.graph.num_exits
    for B in (1, 2, 3, 4, 5, 8):
        rows = _prefill_rows(stack, B, seed=100 + B)
        exits = [1 + (i % n_exits) for i in range(B)]
        serial = _decode_tokens_serial(stack, stepper, rows, exits, 6, 3)
        batched = _decode_tokens_batched(stack, stepper, rows, exits, 6, 3)
        assert serial == batched, f"token divergence at B={B}"
    stats = stepper.cache_stats()
    assert stats["decode"]["batched_calls"] > 0
    assert stats["decode"]["batched_tokens"] > 0


def test_batched_decode_mixed_cache_geometries(stack):
    """Rows whose caches differ in shape (different token budgets) must
    split into congruent groups and still match serial exactly."""
    rows = _prefill_rows(stack, 3, extra=4, seed=5) + \
        _prefill_rows(stack, 3, extra=12, seed=6)
    exits = [1, 1, 2, 1, 1, 2]
    stepper = CoInferenceStepper(stack.model, stack.graph, stack.planner)
    serial = _decode_tokens_serial(stack, stepper, rows, exits, 6, 2)
    batched = _decode_tokens_batched(stack, stepper, rows, exits, 6, 2)
    assert serial == batched
    # (exit 1, small), (exit 2, small), (exit 1, big), (exit 2, big):
    # 2-wide groups batched, 1-wide groups served serially
    assert stepper.batched_max == 2
    assert stepper.serial_tokens == 2 * 2


def _real_decode_spec(batch_decode: bool) -> ScenarioSpec:
    from repro.fleet.workload import TenantClass
    # one tenant class => every request's KV cache is congruent, so
    # co-located decodes land in one vmap group (grouping is by
    # (exit, cache signature))
    tenants = (TenantClass("standard", slo_s=2.0, max_new_tokens=8,
                           weight=1.0),)
    return ScenarioSpec(
        name="calib-real-decode", seed=3,
        topology=TopologySpec(num_devices=8, num_edges=2, trace="lte",
                              edge_capacity=8, max_edge_slowdown=2.0),
        workload=WorkloadSpec(rate_hz=10.0, horizon_s=4.0, device_skew=0.5,
                              prompt_len=6, tenants=tenants),
        router=RouterSpec(name="bandwidth-aware"),
        engine=EngineSpec(real_decode=True, batch_decode=batch_decode))


def test_fleet_real_decode_batched_equals_serial():
    """A real-decode fleet scenario runs its rounds through the vmap path
    (>= 4 co-located requests in one group) with token streams and
    summaries identical to the serial per-request engine."""
    sim_b = Simulation(_real_decode_spec(True))
    m_b = sim_b.run()
    stats = sim_b.scenario.engine.stepper.cache_stats()
    assert stats["decode"]["batched_calls"] > 0
    assert stats["decode"]["batched_max"] >= 4
    assert stats["jit"]["entries"] > 0

    sim_s = Simulation(_real_decode_spec(False))
    m_s = sim_s.run()
    stats_s = sim_s.scenario.engine.stepper.cache_stats()
    assert stats_s["decode"]["batched_calls"] == 0
    assert stats_s["decode"]["serial_tokens"] > 0

    tok_b = {r.rid: list(r.tokens) for r in sim_b.scenario.workload}
    tok_s = {r.rid: list(r.tokens) for r in sim_s.scenario.workload}
    assert tok_b == tok_s
    assert json.dumps(m_b.summary(), sort_keys=True) == \
        json.dumps(m_s.summary(), sort_keys=True)


def test_jit_cache_is_lru_bounded(stack):
    """Sweeping many batch buckets never holds more than jit_cache_max
    compiled batched variants (jit is lazy, so this is cheap)."""
    stepper = CoInferenceStepper(stack.model, stack.graph, stack.planner,
                                 jit_cache_max=2)
    for b in (2, 3, 5, 9):                 # buckets 2, 4, 8, 16
        stepper.decode_fn_batched(1, b)
    assert len(stepper._decode_vjit) == 2
    assert stepper.jit_misses == 4 and stepper.jit_hits == 0
    stepper.decode_fn_batched(1, 9)        # bucket 16 still resident
    assert stepper.jit_hits == 1
    stepper.decode_fn_batched(1, 2)        # bucket 2 was evicted
    assert stepper.jit_misses == 5


def test_cache_stats_keeps_existing_blocks():
    """The pre-PR plan/step/hop schema is intact; jit/decode blocks add."""
    sc = build_stack(PlannerSpec())
    stepper = CoInferenceStepper(None, sc.graph, sc.planner)
    stats = stepper.cache_stats()
    for name in ("plan", "step", "hop", "jit"):
        for key in ("hits", "misses", "entries", "hit_rate"):
            assert key in stats[name], (name, key)
    assert stats["jit"]["max_entries"] == CoInferenceStepper.JIT_CACHE_MAX
    for key in ("batched_calls", "batched_tokens", "serial_tokens",
                "padded_rows", "batched_max"):
        assert stats["decode"][key] == 0
    for key in ("calls", "tokens", "masked_rows", "admits", "evicts",
                "grows", "variants"):
        assert stats["arena"][key] == 0
    assert stats["arena"]["occupancy"] is None
    assert stats["jit"]["variants"] == \
        {"serial": 0, "batched": 0, "arena": 0}


def test_batch_bucket_powers_of_two():
    assert [CoInferenceStepper.batch_bucket(n)
            for n in (1, 2, 3, 4, 5, 8, 9)] == [1, 2, 4, 4, 8, 8, 16]


# ------------------------------------------------- model-construction split
def test_build_stack_model_params_split():
    sc = build_stack(PlannerSpec())
    assert sc.model is None and sc.params is None
    sc = build_stack(PlannerSpec(), with_model=True, with_params=False)
    assert sc.model is not None and sc.params is None


def test_sample_prompts_only_scenario_builds_no_model():
    spec = ScenarioSpec(
        name="prompts-only", seed=1,
        topology=TopologySpec(num_devices=4, num_edges=2),
        workload=WorkloadSpec(rate_hz=4.0, horizon_s=2.0,
                              sample_prompts=True))
    sc = Simulation(spec).build()
    assert sc.model is None and sc.params is None
    assert all(r.prompt is not None for r in sc.workload)


def test_sharded_real_decode_raises():
    from repro.sim.shard import run_sharded
    spec = ScenarioSpec(
        name="sharded-real", seed=0,
        topology=TopologySpec(num_devices=8, num_edges=2, shards=2),
        workload=WorkloadSpec(rate_hz=4.0, horizon_s=2.0),
        engine=EngineSpec(real_decode=True))
    with pytest.raises(ValueError, match="real_decode"):
        run_sharded(spec)
    with pytest.raises(ValueError, match="real_decode"):
        Simulation(spec).run()


# --------------------------------------------------------------- goldens
def test_model_only_summary_bit_identical_with_calibration_off():
    """Calibration off (the default) => byte-identical to the pre-calib
    golden, same pin as the elasticity suite."""
    spec = get_scenario("smoke-lm")
    assert spec.calibration is None
    m = Simulation(spec).run()
    got = json.loads(json.dumps(
        {"scenario": "smoke-lm", "summary": m.summary(),
         "handover_log": [list(h) for h in m.handover_log]},
        sort_keys=True))
    with open(os.path.join(GOLDEN_DIR, "smoke-lm.json")) as f:
        want = json.load(f)
    assert got == want


# ------------------------------------------------------ table round-trip
def test_table_json_round_trip_is_lossless_and_canonical(tmp_path):
    table = CalibrationTable(
        arch=ARCH, source="synthetic",
        samples=[TimingSample(phase="decode", latency_s=1e-3, exit_point=2,
                              batch=4, seq=8, reps=5),
                 TimingSample(phase="layer", kind="conv", latency_s=2e-4,
                              features={"in_maps": 3.0, "comp": 75.0})],
        meta={"reps": 5})
    d = table.to_dict()
    assert d == json.loads(json.dumps(d))
    assert CalibrationTable.from_json(table.to_json()).to_dict() == d
    p = tmp_path / "t.json"
    table.save(str(p))
    assert CalibrationTable.load(str(p)).to_dict() == d


def test_table_round_trip_is_strict():
    with pytest.raises(ValueError, match="unknown CalibrationTable"):
        CalibrationTable.from_dict({"arch": ARCH, "bogus": 1})
    with pytest.raises(ValueError, match="unknown TimingSample"):
        CalibrationTable.from_dict(
            {"arch": ARCH, "samples": [{"phase": "decode", "latency_s": 0.1,
                                        "nope": 2}]})
    with pytest.raises(ValueError, match="phase"):
        TimingSample(phase="warp", latency_s=0.1)
    with pytest.raises(ValueError, match="latency_s"):
        TimingSample(phase="decode", latency_s=-0.1)
    with pytest.raises(ValueError, match="phase"):
        CalibrationTable(arch=ARCH).by_phase("warp")


# ------------------------------------------------------------------- fit
def _lm_graphs(batches):
    from repro.configs import get_smoke_config
    from repro.core.graph import lm_graph
    cfg = get_smoke_config(ARCH)
    return {b: lm_graph(cfg, batch=b, seq=1) for b in batches}


def _planted_lm_table(theta, batches=(1, 2, 4)):
    """Branch-level decode samples whose latencies are exactly the planted
    per-kind linear model summed over each branch."""
    graphs = _lm_graphs(batches)
    samples = []
    for b, g in graphs.items():
        for e in range(1, g.num_exits + 1):
            t = sum(float(RegressionLatencyModel._design(
                l.kind, l.features) @ np.asarray(theta[l.kind]))
                for l in g.branches[e - 1])
            samples.append(TimingSample(phase="decode", latency_s=t,
                                        exit_point=e, batch=b))
    return CalibrationTable(arch=ARCH, source="synthetic", samples=samples)


PLANTED = {"block": (2e-12, 3e-16, 5e-5), "fc": (4e-12, 1e-13, 2e-5)}


def test_joint_fit_reproduces_planted_branch_latencies():
    table = _planted_lm_table(PLANTED)
    fitted = fit_table(table)
    assert set(fitted.theta) == {"block", "fc"}
    graphs = _lm_graphs((1, 2, 4))
    for s in table.samples:
        g = graphs[s.batch]
        pred = sum(fitted.predict(l) for l in g.branches[s.exit_point - 1])
        assert pred == pytest.approx(s.latency_s, rel=1e-6)


def test_fit_rejects_empty_and_bad_tables():
    with pytest.raises(ValueError, match="no fittable"):
        fit_table(CalibrationTable(arch=ARCH, samples=[
            TimingSample(phase="prefill", latency_s=0.1)]))
    with pytest.raises(ValueError, match="out of range"):
        fit_table(CalibrationTable(arch=ARCH, samples=[
            TimingSample(phase="decode", latency_s=0.1, exit_point=99)]))


def test_models_from_table_anchors_to_spec_step_times():
    spec = PlannerSpec()
    table = _planted_lm_table(PLANTED)
    f_edge, f_dev = models_from_table(table, spec)
    g = _lm_graphs((1,))[1]
    full = g.branches[-1]
    assert sum(f_edge.predict(l) for l in full) == \
        pytest.approx(spec.edge_step_s, rel=1e-9)
    assert sum(f_dev.predict(l) for l in full) == \
        pytest.approx(spec.device_step_s, rel=1e-9)


def _check_layer_fit_recovery(seed):
    rng = np.random.default_rng(seed)
    kinds = {"conv": ("in_maps", "comp"), "fc": ("in_size", "out_size")}
    theta = {k: rng.uniform(1e-6, 1e-3, len(f) + 1) for k, f in kinds.items()}
    samples = []
    for kind, fnames in kinds.items():
        for _ in range(10):
            feats = {n: float(rng.uniform(1.0, 200.0)) for n in fnames}
            t = float(RegressionLatencyModel._design(kind, feats)
                      @ theta[kind])
            samples.append(TimingSample(phase="layer", kind=kind,
                                        features=feats, latency_s=t))
    fitted = fit_table(CalibrationTable(arch="branchy-alexnet",
                                        source="synthetic",
                                        samples=samples))
    for kind in kinds:
        np.testing.assert_allclose(fitted.theta[kind], theta[kind],
                                   rtol=1e-5, atol=1e-12)
        assert fitted.r2[kind] == pytest.approx(1.0, abs=1e-9)


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 2 ** 31 - 1))
def test_layer_fit_recovers_planted_coefficients(seed):
    """Hypothesis: the per-layer path recovers planted Table-I thetas
    exactly (noise-free synthetic samples, well-conditioned designs)."""
    _check_layer_fit_recovery(seed)


def test_layer_fit_recovers_planted_coefficients_fixed_seeds():
    """The same recovery property on fixed seeds, so the check runs even
    where hypothesis is unavailable."""
    for seed in (0, 1, 7, 1234):
        _check_layer_fit_recovery(seed)


def _check_planner_monotone(seed, scale):
    from repro.runtime.elastic import TierSpec
    rng = np.random.default_rng(seed)
    theta = {"block": rng.uniform(1e-13, 1e-11, 3) * scale,
             "fc": rng.uniform(1e-14, 1e-12, 3) * scale}
    table = _planted_lm_table(theta, batches=(1, 2))
    ep = elastic_planner_from_table(table, PlannerSpec(), link_bps=1e6)
    edge, dev = TierSpec(chips=8), TierSpec(chips=1)
    feasible_exits = []
    for bw in np.logspace(4, 7, 12):
        plan = ep.plan_for(edge, dev, link_bps=float(bw))
        if plan.feasible:
            feasible_exits.append(plan.exit_point)
    assert feasible_exits == sorted(feasible_exits)


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 2 ** 31 - 1),
       scale=st.floats(0.1, 10.0))
def test_calibrated_elastic_planner_monotone_in_bandwidth(seed, scale):
    """Hypothesis: a re-parameterized ElasticPlanner's chosen exit is
    non-decreasing in link bandwidth wherever the plan is feasible
    (Algorithm 1 scans largest exit first; per-plan latency is
    non-increasing in bandwidth, so feasibility only grows)."""
    _check_planner_monotone(seed, scale)


def test_calibrated_elastic_planner_monotone_fixed_seeds():
    """The same monotonicity property on fixed (seed, scale) points, so the
    check runs even where hypothesis is unavailable."""
    for seed, scale in ((0, 1.0), (3, 0.1), (11, 10.0), (42, 2.5)):
        _check_planner_monotone(seed, scale)


# --------------------------------------------------------------- validate
def test_validate_scenario_report_schema():
    """Schema-complete report from a synthetic table, no fleet runs."""
    from repro.calib.validate import validate_scenario
    table = _planted_lm_table(PLANTED)
    report = validate_scenario("smoke-lm", table=table, bw_points=9,
                               run_summaries=False)
    for key in ("scenario", "arch", "table", "fit", "scale", "per_exit",
                "per_layer", "bias_s", "mape", "per_layer_bias_s",
                "per_layer_mape", "plan_divergence", "summaries"):
        assert key in report, key
    assert report["scenario"] == "smoke-lm"
    assert report["summaries"] is None
    assert report["plan_divergence"]["points"] == 9
    assert 0.0 <= report["plan_divergence"]["rate"] <= 1.0
    for row in report["per_exit"]:
        assert {"name", "predicted_s", "measured_s", "bias_s",
                "rel_err"} <= set(row)
    assert len(report["per_layer"]) == len(report["per_exit"]) - 1
    # the report is JSON-serializable as produced
    json.dumps(report)


def test_validate_rejects_mismatched_arch():
    from repro.calib.validate import validate_scenario
    table = CalibrationTable(arch="branchy-alexnet", samples=[
        TimingSample(phase="decode", latency_s=0.1, exit_point=1)])
    with pytest.raises(ValueError, match="arch"):
        validate_scenario("smoke-lm", table=table, run_summaries=False)


# -------------------------------------------------- spec section plumbing
def test_calibration_spec_round_trips():
    spec = ScenarioSpec(name="c", calibration=CalibrationSpec(
        table="/tmp/t.json", anchor=False))
    d = spec.to_dict()
    back = ScenarioSpec.from_dict(json.loads(json.dumps(d)))
    assert back.calibration.table == "/tmp/t.json"
    assert back.calibration.anchor is False
    assert ScenarioSpec.from_json(spec.to_json()).to_dict() == d


def test_calibration_spec_via_overrides_and_strictness():
    from repro.sim import apply_overrides
    spec = apply_overrides(get_scenario("smoke-lm"),
                           {"calibration.table": "t.json"})
    assert spec.calibration is not None and spec.calibration.table == "t.json"
    with pytest.raises(ValueError, match="unknown CalibrationSpec"):
        CalibrationSpec.from_dict({"table": "x", "oops": 1})


def test_scenario_with_calibrated_table_runs_and_differs(tmp_path):
    """End to end through the spec layer: a scenario pointed at a fitted
    table builds calibrated planner models and still runs model-only;
    anchoring keeps the full-branch step at the spec's step times."""
    table = _planted_lm_table(PLANTED)
    p = tmp_path / "table.json"
    table.save(str(p))
    spec = dataclasses.replace(
        get_scenario("smoke-lm"),
        workload=WorkloadSpec(rate_hz=10.0, horizon_s=3.0),
        calibration=CalibrationSpec(table=str(p)))
    sc = Simulation(spec).build()
    full = sc.graph.branches[-1]
    assert sum(sc.planner.f_edge.predict(l) for l in full) == \
        pytest.approx(spec.planner.edge_step_s, rel=1e-9)
    m = Simulation(spec).run()
    assert m.summary()["requests"] > 0
