"""Slot-resident decode arena suite (docs/performance.md).

* bit-identity: the masked full-arena call (``decode_fn_arena``) produces
  token streams identical to the serial per-request path under fuzzed
  mixed exits, mixed prompt lengths, and slot churn — admits, evicts, and
  extract -> re-admit handovers mid-stream (hypothesis + a fixed-seed
  variant that always runs);
* fleet-level pins: a static real-decode scenario and a mobile BOCD
  scenario with ``handovers > 0`` are token- and summary-identical with
  ``arena_decode`` on vs off, while compiling at most one arena variant
  per model exit and padding zero rows;
* arena mechanics: ``extract`` returns a cache bitwise equal to the
  admitted one (sliced back from the padded row), slot/length growth
  doubles and re-buckets without disturbing resident rows, and the free
  list hands out lowest slots first;
* spec plumbing: ``EngineSpec`` validates ``arena_bucket``; sweep rows
  carry the decode-efficiency columns only for real-decode cells; the
  tracer's ``decode_stats`` metadata event validates and renders as the
  report's decode panel.
"""
import dataclasses
import json

import numpy as np
import pytest

from _hypothesis_compat import HAVE_HYPOTHESIS, given, settings, st
from repro.serving.arena import DecodeArena, pow2
from repro.serving.engine import CoInferenceStepper
from repro.sim import (EngineSpec, PlannerSpec, RouterSpec, ScenarioSpec,
                       Simulation, TopologySpec, WorkloadSpec, get_scenario)
from repro.sim.build import build_stack


@pytest.fixture(scope="module")
def stack():
    return build_stack(PlannerSpec(), with_model=True)


def _prefill_row(stack, *, prompt_len, extra, seed):
    """One B=1 (cache, tok) row after a real prefill (the fleet's request
    state at decode start)."""
    import jax.numpy as jnp
    rng = np.random.default_rng(seed)
    toks = jnp.asarray(
        rng.integers(0, stack.cfg.vocab_size, (1, prompt_len)), jnp.int32)
    cache = stack.model.init_cache(1, prompt_len + extra + 1,
                                   dtype=jnp.float32, enc_len=prompt_len)
    h, cache = stack.model.prefill(stack.params, toks, cache)
    logits = stack.model.logits(stack.params, h)
    tok = jnp.argmax(logits[:, -1, :], -1).astype(jnp.int32)[:, None]
    return cache, tok


def _next_tok(stack, h):
    import jax.numpy as jnp
    logits = stack.model.logits(stack.params, h)
    return jnp.argmax(logits[:, -1, :], -1).astype(jnp.int32)[:, None]


# ------------------------------------------------------------- bit-identity
def _churn_tokens(stack, plan, *, arena_slots=2, arena_len=8,
                  handover_steps=()):
    """Decode ``plan`` rows (dicts with prompt_len/extra/exit/start/steps)
    twice — serial per-request vs slot-resident arena with churn — and
    return both token-stream dicts.

    Requests join at their ``start`` step (admit), leave after ``steps``
    decoded tokens (evict); at every step in ``handover_steps`` each
    active request is extracted and re-admitted (the handover motion),
    scrambling slot assignments mid-stream."""
    import jax.numpy as jnp
    rows = {i: _prefill_row(stack, prompt_len=p["prompt_len"],
                            extra=p["extra"], seed=1000 + i)
            for i, p in enumerate(plan)}
    horizon = max(p["start"] + p["steps"] for p in plan)

    # --- serial baseline
    stepper_s = CoInferenceStepper(stack.model, stack.graph, stack.planner)
    serial = {i: [] for i in rows}
    state = {i: rows[i] for i in rows}
    for step in range(horizon):
        for i, p in enumerate(plan):
            if not p["start"] <= step < p["start"] + p["steps"]:
                continue
            cache, tok = state[i]
            pos = p["prompt_len"] + (step - p["start"])
            fn = stepper_s.decode_fn(p["exit"])
            h, cache = fn(stack.params, cache, tok,
                          jnp.asarray(pos, jnp.int32))
            tok = _next_tok(stack, h)
            serial[i].append(int(tok[0, 0]))
            state[i] = (cache, tok)

    # --- arena path with churn
    stepper_a = CoInferenceStepper(stack.model, stack.graph, stack.planner)
    arena = DecodeArena(stack.model, slots=arena_slots, length=arena_len,
                        dtype=jnp.float32, stepper=stepper_a)
    got = {i: [] for i in rows}
    toks = {i: rows[i][1] for i in rows}
    for step in range(horizon):
        for i, p in enumerate(plan):          # admits (possibly mid-stream)
            if step == p["start"]:
                arena.admit(i, rows[i][0])
        if step in handover_steps:            # extract -> re-admit everyone
            resident = [i for i in rows if arena.has(i)]
            snaps = {i: arena.extract(i) for i in resident}
            for i in reversed(resident):
                arena.admit(i, snaps[i])
        items = []
        for i, p in enumerate(plan):
            if p["start"] <= step < p["start"] + p["steps"]:
                pos = p["prompt_len"] + (step - p["start"])
                items.append((p["exit"], arena.slot(i), toks[i], pos))
        if items:
            outs = stepper_a.decode_step_arena(stack.params, arena, items)
            nts = {}
            for group_rows, h_all in outs:   # grouped epilogue, as the fleet
                la = stack.model.logits(stack.params, h_all[:, 0])
                nt = jnp.argmax(la[:, -1, :], -1).astype(jnp.int32)
                for _, slot, _, _ in group_rows:
                    nts[slot] = nt[slot][None, None]
            for i, p in enumerate(plan):
                if p["start"] <= step < p["start"] + p["steps"]:
                    toks[i] = nts[arena.slot(i)]
                    got[i].append(int(toks[i][0, 0]))
        for i, p in enumerate(plan):          # evicts at end-of-stream
            if step == p["start"] + p["steps"] - 1:
                arena.evict(i)
    return serial, got


def _plan_from_seed(stack, seed, n):
    rng = np.random.default_rng(seed)
    n_exits = stack.graph.num_exits
    return [{"prompt_len": int(rng.integers(3, 9)),
             "extra": int(rng.integers(3, 10)),
             "exit": 1 + int(rng.integers(n_exits)),
             "start": int(rng.integers(0, 3)),
             "steps": int(rng.integers(2, 5))} for _ in range(n)]


def test_arena_decode_bit_identical_fixed_seed(stack):
    """Mixed exits, mixed prompt lengths, mid-stream admits/evicts and a
    forced extract->re-admit handover: arena tokens == serial tokens."""
    plan = _plan_from_seed(stack, 42, 4)
    serial, got = _churn_tokens(stack, plan, arena_slots=2, arena_len=4,
                                handover_steps=(2,))
    assert serial == got


@pytest.mark.skipif(not HAVE_HYPOTHESIS, reason="hypothesis not installed")
@settings(max_examples=4, deadline=None)
@given(seed=st.integers(0, 50), n=st.integers(2, 4),
       handover=st.booleans())
def test_property_arena_decode_bit_identical(stack, seed, n, handover):
    plan = _plan_from_seed(stack, seed, n)
    serial, got = _churn_tokens(
        stack, plan, arena_slots=1, arena_len=4,
        handover_steps=(1,) if handover else ())
    assert serial == got


def test_arena_counters_and_variant_budget(stack):
    """The churn run compiles at most one arena variant per model exit per
    arena geometry and counts masked rows for the occupancy metric."""
    import jax.numpy as jnp
    stepper = CoInferenceStepper(stack.model, stack.graph, stack.planner)
    arena = DecodeArena(stack.model, slots=4, length=16, dtype=jnp.float32,
                        stepper=stepper)
    rows = [_prefill_row(stack, prompt_len=4, extra=4, seed=i)
            for i in range(2)]
    for i, (cache, _) in enumerate(rows):
        arena.admit(i, cache)
    items = [(1, arena.slot(i), rows[i][1], 4) for i in range(2)]
    for _ in range(3):
        stepper.decode_step_arena(stack.params, arena, items)
    st_ = stepper.cache_stats()
    assert st_["arena"]["calls"] == 3
    assert st_["arena"]["tokens"] == 6
    assert st_["arena"]["masked_rows"] == 3 * (arena.slots - 2)
    assert st_["arena"]["occupancy"] == round(6 / (6 + 6), 4)
    assert st_["jit"]["variants"]["arena"] == 1
    assert st_["decode"]["padded_rows"] == 0   # arena path never pads


# ------------------------------------------------------------ arena object
def test_extract_roundtrip_bitwise(stack):
    """admit -> extract returns the exact cache: every leaf bitwise equal,
    shapes restored from the padded arena row."""
    import jax
    import jax.numpy as jnp
    cache, _ = _prefill_row(stack, prompt_len=5, extra=3, seed=0)
    arena = DecodeArena(stack.model, slots=2, length=32, dtype=jnp.float32)
    arena.admit("r", cache)
    out = arena.extract("r")
    flat_in = jax.tree_util.tree_leaves(cache)
    flat_out = jax.tree_util.tree_leaves(out)
    assert len(flat_in) == len(flat_out)
    for a, b in zip(flat_in, flat_out):
        assert a.shape == b.shape and a.dtype == b.dtype
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert not arena.has("r") and arena.active == 0


def test_arena_growth_slots_and_length(stack):
    """Admitting past capacity doubles slots; a longer-than-arena cache
    re-buckets the length — resident rows still extract bitwise."""
    import jax
    import jax.numpy as jnp
    small, _ = _prefill_row(stack, prompt_len=4, extra=2, seed=1)
    big, _ = _prefill_row(stack, prompt_len=4, extra=40, seed=2)
    stepper = CoInferenceStepper(stack.model, stack.graph, stack.planner)
    arena = DecodeArena(stack.model, slots=1, length=4, dtype=jnp.float32,
                        stepper=stepper)
    assert arena.slots == 1 and arena.length == 4
    arena.admit("a", small)                          # true len 7: len 4 -> 8
    assert arena.length == 8
    arena.admit("b", small)                          # slot growth: 1 -> 2
    assert arena.slots == 2
    arena.admit("c", big)                            # len 8 -> 64 and 2 -> 4
    assert arena.slots == 4 and arena.length == 64
    assert stepper.arena_grows == 4
    for rid, src in (("a", small), ("c", big)):
        got = jax.tree_util.tree_leaves(arena.extract(rid))
        for x, y in zip(jax.tree_util.tree_leaves(src), got):
            np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def test_arena_free_list_prefers_lowest_slot(stack):
    import jax.numpy as jnp
    cache, _ = _prefill_row(stack, prompt_len=4, extra=2, seed=3)
    arena = DecodeArena(stack.model, slots=4, length=16, dtype=jnp.float32)
    assert [arena.admit(r, cache) for r in "abc"] == [0, 1, 2]
    arena.evict("a")
    assert arena.admit("d", cache) == 0   # lowest free slot, deterministic
    assert arena.slot("b") == 1


def test_arena_rejects_bad_bucket(stack):
    import jax.numpy as jnp
    with pytest.raises(ValueError, match="bucket"):
        DecodeArena(stack.model, slots=1, length=4, dtype=jnp.float32,
                    bucket="linear")


def test_pow2():
    assert [pow2(n) for n in (1, 2, 3, 4, 5, 8, 9, 17)] == \
        [1, 2, 4, 4, 8, 8, 16, 32]


# ------------------------------------------------------------- fleet pins
def _static_spec(arena: bool, *, batch: bool = True) -> ScenarioSpec:
    from repro.fleet.workload import TenantClass
    tenants = (TenantClass("interactive", slo_s=1.0, max_new_tokens=6,
                           weight=0.5),
               TenantClass("standard", slo_s=2.0, max_new_tokens=10,
                           weight=0.5))
    return ScenarioSpec(
        name="arena-static", seed=3,
        topology=TopologySpec(num_devices=8, num_edges=2, trace="lte",
                              edge_capacity=8, max_edge_slowdown=2.0),
        workload=WorkloadSpec(rate_hz=10.0, horizon_s=4.0, device_skew=0.5,
                              prompt_len=6, tenants=tenants),
        router=RouterSpec(name="bandwidth-aware"),
        engine=EngineSpec(real_decode=True, batch_decode=batch,
                          arena_decode=arena))


def _run_fleet(spec):
    sim = Simulation(spec)
    m = sim.run()
    toks = {r.rid: list(r.tokens) for r in sim.scenario.workload}
    return m.summary(), toks, sim.scenario.engine.stepper.cache_stats()


def test_fleet_arena_equals_serial_static():
    """Static mixed-tenant real-decode fleet: token streams and summaries
    identical arena vs the serial engine (batch_decode=False), at most one
    compiled arena variant per model exit, zero padded rows."""
    s_off, t_off, _ = _run_fleet(_static_spec(False, batch=False))
    s_on, t_on, st_ = _run_fleet(_static_spec(True))
    assert t_on == t_off
    assert json.dumps(s_on, sort_keys=True) == \
        json.dumps(s_off, sort_keys=True)
    ar = st_["arena"]
    assert ar["calls"] > 0 and ar["tokens"] > 0
    assert ar["admits"] == ar["evicts"] > 0
    assert st_["decode"]["padded_rows"] == 0
    assert st_["decode"]["batched_calls"] == 0   # arena replaces the vmap path
    sc = build_stack(PlannerSpec())
    n_model = len(sc.graph.branches)             # model exits incl. full path
    assert 0 < st_["jit"]["variants"]["arena"] <= n_model


def _mobile_spec(arena: bool) -> ScenarioSpec:
    from repro.fleet.workload import TenantClass
    base = get_scenario("smoke-mobility")
    return dataclasses.replace(
        base, name="arena-mobility",
        topology=dataclasses.replace(base.topology, num_devices=12,
                                     num_edges=4, speed=1.5),
        workload=dataclasses.replace(
            base.workload, rate_per_device_hz=0.3, horizon_s=15.0,
            prompt_len=6, sample_prompts=True,
            tenants=(TenantClass("interactive", 1.0, 8, 0.5),
                     TenantClass("standard", 3.0, 16, 0.5))),
        mobility=dataclasses.replace(base.mobility, min_gap_s=0.5),
        engine=dataclasses.replace(base.engine, real_decode=True,
                                   arena_decode=arena))


@pytest.mark.slow
def test_fleet_arena_equals_serial_under_handover():
    """Mobile BOCD fleet that actually hands requests over mid-stream
    (pinned handovers > 0): the extract -> ship -> re-admit motion keeps
    token streams and summaries bit-identical to the serial engine."""
    s_off, t_off, _ = _run_fleet(_mobile_spec(False))
    s_on, t_on, st_ = _run_fleet(_mobile_spec(True))
    assert s_off.get("handovers", 0) > 0          # the pin with teeth
    assert t_on == t_off
    assert json.dumps(s_on, sort_keys=True) == \
        json.dumps(s_off, sort_keys=True)
    assert st_["arena"]["calls"] > 0
    assert st_["decode"]["padded_rows"] == 0


def test_arena_off_matches_pre_pr_goldens():
    """arena_decode=False is the default: the calib suite's golden pins
    cover byte-identity, here we just pin the default itself."""
    assert EngineSpec().arena_decode is False
    assert EngineSpec().arena_bucket == "pow2"


# ------------------------------------------------------------ spec plumbing
def test_engine_spec_validates_arena_bucket():
    with pytest.raises(ValueError, match="arena_bucket"):
        EngineSpec(arena_bucket="nope")


def test_sweep_row_decode_columns():
    from repro.sim.sweep import run_cell
    row = run_cell(_static_spec(True))
    dec = row["decode"]
    assert dec["padded_rows"] == 0 and dec["pad_waste"] == 0.0
    assert dec["arena_calls"] > 0 and dec["arena_tokens"] > 0
    assert 0.0 < dec["arena_occupancy"] <= 1.0
    assert dec["jit_variants"]["arena"] >= 1
    # model-free cells carry no decode block at all
    plain = dataclasses.replace(
        _static_spec(False), engine=EngineSpec(real_decode=False))
    assert "decode" not in run_cell(plain)


# ------------------------------------------------------------ observability
def test_tracer_decode_stats_event_and_panel(tmp_path):
    from repro.obs import Tracer, validate_trace
    from repro.obs.report import render_trace
    spec = dataclasses.replace(
        _static_spec(True),
        engine=dataclasses.replace(_static_spec(True).engine,
                                   trace=str(tmp_path / "t.json")))
    sim = Simulation(spec)
    sim.run()
    trace = sim.scenario.engine.tracer.to_chrome()
    assert validate_trace(trace) == []
    evs = [e for e in trace["traceEvents"]
           if e.get("ph") == "M" and e.get("name") == "decode_stats"]
    assert len(evs) == 1
    args = evs[0]["args"]
    assert args["arena"]["calls"] > 0
    assert args["decode"]["padded_rows"] == 0
    txt = render_trace(trace)
    assert "decode efficiency" in txt
    assert "arena" in txt and "occupancy" in txt
