"""Assigned-architecture configs: exact numbers from the assignment table."""
import pytest

from repro.config import SHAPES, cell_applicable
from repro.configs import ARCH_IDS, cells, get_config, get_smoke_config

EXPECTED = {
    "granite-3-2b": dict(num_layers=40, d_model=2048, num_heads=32,
                         num_kv_heads=8, d_ff=8192, vocab_size=49155),
    "granite-3-8b": dict(num_layers=40, d_model=4096, num_heads=32,
                         num_kv_heads=8, d_ff=12800, vocab_size=49155),
    "llama3.2-1b": dict(num_layers=16, d_model=2048, num_heads=32,
                        num_kv_heads=8, d_ff=8192, vocab_size=128256),
    "starcoder2-15b": dict(num_layers=40, d_model=6144, num_heads=48,
                           num_kv_heads=4, d_ff=24576, vocab_size=49152),
    "rwkv6-3b": dict(num_layers=32, d_model=2560, d_ff=8960,
                     vocab_size=65536, family="ssm"),
    "seamless-m4t-large-v2": dict(num_layers=24, d_model=1024, num_heads=16,
                                  num_kv_heads=16, d_ff=8192,
                                  vocab_size=256206, is_encdec=True),
    "llava-next-mistral-7b": dict(num_layers=32, d_model=4096, num_heads=32,
                                  num_kv_heads=8, d_ff=14336,
                                  vocab_size=32000, frontend="vision"),
    "llama4-maverick-400b-a17b": dict(num_layers=48, d_model=5120,
                                      num_heads=40, num_kv_heads=8, d_ff=8192,
                                      vocab_size=202048, num_experts=128,
                                      experts_per_tok=1),
    "llama4-scout-17b-a16e": dict(num_layers=48, d_model=5120, num_heads=40,
                                  num_kv_heads=8, d_ff=8192,
                                  vocab_size=202048, num_experts=16,
                                  experts_per_tok=1),
    "zamba2-2.7b": dict(num_layers=54, d_model=2560, num_heads=32,
                        num_kv_heads=32, d_ff=10240, vocab_size=32000,
                        ssm_state=64, family="hybrid"),
}


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_exact_config(arch):
    cfg = get_config(arch)
    for k, v in EXPECTED[arch].items():
        assert getattr(cfg, k) == v, (arch, k, getattr(cfg, k), v)


def test_all_ten_archs_present():
    assert len(ARCH_IDS) == 10


def test_forty_cells():
    cs = list(cells())
    assert len(cs) == 40
    skipped = [c for c in cs if not c[2]]
    # long_500k runs only for ssm/hybrid: 8 skips
    assert len(skipped) == 8
    assert all(c[1] == "long_500k" for c in skipped)
    for arch in ("rwkv6-3b", "zamba2-2.7b"):
        assert any(c[0] == arch and c[1] == "long_500k" and c[2] for c in cs)


def test_shapes_table():
    assert SHAPES["train_4k"].seq_len == 4096 and SHAPES["train_4k"].global_batch == 256
    assert SHAPES["prefill_32k"].seq_len == 32768 and SHAPES["prefill_32k"].global_batch == 32
    assert SHAPES["decode_32k"].seq_len == 32768 and SHAPES["decode_32k"].global_batch == 128
    assert SHAPES["long_500k"].seq_len == 524288 and SHAPES["long_500k"].global_batch == 1


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_config_small(arch):
    cfg = get_smoke_config(arch)
    assert cfg.d_model <= 128 and cfg.vocab_size <= 512
    assert cfg.family == get_config(arch).family


def test_param_counts_plausible():
    # sanity: within 2x of the names
    assert 1.5e9 < get_config("granite-3-2b").param_count() < 4e9
    assert 6e9 < get_config("granite-3-8b").param_count() < 12e9
    assert 0.9e9 < get_config("llama3.2-1b").param_count() < 2.5e9
    # SwiGLU (3-matrix) FFN is used uniformly (the assignment fixes dims, not
    # MLP kind), which puts starcoder2 at ~21.7B rather than its 2-matrix 15B.
    assert 11e9 < get_config("starcoder2-15b").param_count() < 24e9
    assert 300e9 < get_config("llama4-maverick-400b-a17b").param_count() < 500e9
    m = get_config("llama4-maverick-400b-a17b")
    assert 10e9 < m.param_count(active_only=True) < 25e9
