"""Algorithm 2 (configuration map) + Eq. (1) reward."""
import math

import numpy as np
import pytest

from repro.core import config_map as CM
from repro.core.graph import GraphLayer, InferenceGraph


class ConstModel:
    def __init__(self, t):
        self.t = t

    def predict(self, layer):
        return self.t


def _graph():
    branches = [[GraphLayer(f"l{i}_{j}", "fc", {"in_size": 1.0, "out_size": 1.0},
                            out_bytes=1000) for j in range(2 * i)]
                for i in range(1, 4)]
    return InferenceGraph("toy", branches, accuracy=[0.5, 0.7, 0.9],
                          input_bytes=4000, result_bytes=8)


def test_reward_eq1():
    assert CM.reward_fn(0.8, 0.5, 1.0) == pytest.approx(math.exp(0.8) + 2.0)
    assert CM.reward_fn(0.8, 1.5, 1.0) == 0.0     # misses the deadline -> 0


def test_reward_prioritizes_accuracy_then_throughput():
    # both feasible: higher accuracy wins even when slower (exp(acc) dominates
    # only when throughput difference is small)
    r_acc = CM.reward_fn(0.9, 0.9, 1.0)
    r_fast = CM.reward_fn(0.5, 0.8, 1.0)
    assert r_acc != r_fast


def test_sketch_states_means():
    traces = [[1.0, 2.0, 3.0], [10.0, 10.0], []]
    states = CM.sketch_states(traces)
    assert states == [2.0, 10.0]


def test_build_map_and_lookup():
    g = _graph()
    fe, fd = ConstModel(0.01), ConstModel(0.05)
    states = [1e4, 1e5, 1e6]
    cmap = CM.build_map(g, fe, fd, states, latency_req_s=1.0)
    assert set(cmap) == {1e4, 1e5, 1e6}
    for s, e in cmap.items():
        assert e.reward >= 0
    entry = CM.lookup(cmap, 2e5)       # nearest state = 1e5
    assert entry is cmap[1e5]
    # map entry == brute-force argmax of Eq. (1) over all (exit, partition)
    from repro.core.partitioner import branch_latency
    best = max(((i, p) for i in range(1, 4)
                for p in range(len(g.branches[i - 1]) + 1)),
               key=lambda ip: CM.reward_fn(
                   g.accuracy[ip[0] - 1],
                   branch_latency(g, ip[0], ip[1], fe, fd, 1e6), 1.0))
    assert (cmap[1e6].exit_point, cmap[1e6].partition) == best


def test_map_respects_deadline():
    g = _graph()
    fe, fd = ConstModel(0.4), ConstModel(2.0)    # slow tiers
    cmap = CM.build_map(g, fe, fd, [1e6], latency_req_s=1.0)
    e = cmap[1e6]
    # feasible strategies exist only at exit 1 (2 layers * 0.4 = 0.8s edge)
    if e.reward > 0:
        assert e.latency_s <= 1.0
