"""Regression tests for the §Perf optimizations (EXPERIMENTS.md)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import layers as L


# ------------------------------------------------------- flash custom_vjp
def test_flash_fused_grads_match_dense(rng):
    B, S, H, KV, hd = 2, 128, 4, 2, 32
    ks = jax.random.split(rng, 4)
    q = jax.random.normal(ks[0], (B, S, H, hd))
    k = jax.random.normal(ks[1], (B, S, KV, hd))
    v = jax.random.normal(ks[2], (B, S, KV, hd))
    ct = jax.random.normal(ks[3], (B, S, H, hd))

    g_dense = jax.grad(lambda q, k, v: jnp.sum(
        L._sdpa(q, k, v, L.causal_bias(S, S)) * ct), argnums=(0, 1, 2))(q, k, v)
    g_flash = jax.grad(lambda q, k, v: jnp.sum(
        L.flash_attention_fused(q, k, v, True, 32, 32) * ct),
        argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(g_dense, g_flash):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=2e-5, atol=2e-5)


def test_flash_fused_noncausal_grads(rng):
    B, S, H, hd = 1, 64, 2, 16
    ks = jax.random.split(rng, 4)
    q = jax.random.normal(ks[0], (B, S, H, hd))
    k = jax.random.normal(ks[1], (B, S, H, hd))
    v = jax.random.normal(ks[2], (B, S, H, hd))
    ct = jax.random.normal(ks[3], (B, S, H, hd))
    g1 = jax.grad(lambda q: jnp.sum(L._sdpa(q, k, v, 0.0) * ct))(q)
    g2 = jax.grad(lambda q: jnp.sum(
        L.flash_attention_fused(q, k, v, False, 16, 16) * ct))(q)
    np.testing.assert_allclose(np.asarray(g1), np.asarray(g2),
                               rtol=2e-5, atol=2e-5)


# ------------------------------------------------------- int8 KV cache
def test_int8_kv_cache_decode_close(rng):
    from repro.configs import get_smoke_config
    from repro.models import Model

    cfg = get_smoke_config("granite-3-2b")
    m = Model(cfg)
    params = m.init_params(rng, dtype=jnp.float32)
    B, S = 2, 12
    toks = jax.random.randint(rng, (B, S), 0, cfg.vocab_size)
    nt = jax.random.randint(jax.random.fold_in(rng, 1), (B, 1), 0, cfg.vocab_size)

    c16 = m.init_cache(B, S + 4, dtype=jnp.float32)
    _, c16 = m.prefill(params, toks, c16)
    h16, _, _ = m.decode_step(params, c16, nt, jnp.asarray(S, jnp.int32))

    c8 = m.init_cache(B, S + 4, dtype=jnp.float32, quant=True)
    _, c8 = m.prefill(params, toks, c8)
    h8, _, _ = m.decode_step(params, c8, nt, jnp.asarray(S, jnp.int32))

    rel = float(jnp.abs(h16 - h8).max() / jnp.abs(h16).max())
    assert rel < 0.05, rel
    # the quantized cache is actually int8
    dts = {str(l.dtype) for l in jax.tree.leaves(c8)}
    assert "int8" in dts


def test_int8_cache_bytes_halve():
    from repro.configs import get_config
    from repro.models import Model

    cfg = get_config("granite-3-8b")
    m = Model(cfg)
    full = jax.eval_shape(lambda: m.init_cache(4, 1024))
    quant = jax.eval_shape(lambda: m.init_cache(4, 1024, quant=True))
    b = lambda t: sum(np.prod(l.shape) * l.dtype.itemsize
                      for l in jax.tree.leaves(t))
    assert b(quant) < 0.6 * b(full)


# ------------------------------------------------------- padded heads
def test_padded_heads_zero_grad(rng):
    import dataclasses
    from repro.configs import get_smoke_config
    from repro.models import Model

    cfg = dataclasses.replace(get_smoke_config("granite-3-2b"),
                              num_heads=20, num_kv_heads=4, head_dim=16,
                              d_model=64)
    assert cfg.padded_heads == 32   # 4 kv-groups x 8 (first multiple: 4*Gp%16==0)
    m = Model(cfg)
    params = m.init_params(rng, dtype=jnp.float32)
    batch = {"tokens": jax.random.randint(rng, (2, 17), 0, cfg.vocab_size)}
    loss, _ = m.loss(params, batch, remat=False)
    assert bool(jnp.isfinite(loss))
    g = jax.grad(lambda p: m.loss(p, batch, remat=False)[0])(params)
    wo_g = g["segments"][0]["attn"]["wo"]
    hd, Hp = cfg.hd, cfg.padded_heads
    Gp, G = Hp // 4, 20 // 4
    pad_rows = np.repeat((np.arange(Hp) % Gp) >= G, hd)
    assert float(jnp.abs(wo_g[:, pad_rows, :]).max()) == 0.0
    assert float(jnp.abs(wo_g[:, ~pad_rows, :]).max()) > 0.0


def test_padded_heads_noop_when_divisible():
    from repro.configs import get_config
    assert get_config("granite-3-2b").padded_heads == 32
    assert get_config("llama4-maverick-400b-a17b").padded_heads == 48
    assert get_config("starcoder2-15b").padded_heads == 48
    from repro.configs import get_smoke_config
    assert get_smoke_config("granite-3-2b").padded_heads == 4  # < axis: no pad


# ------------------------------------------------------- HLO cost walker
def test_hlo_cost_walker_exact_on_matmul_and_scan():
    import os
    import subprocess
    import sys

    src = os.path.abspath(os.path.join(os.path.dirname(__file__), "..", "src"))
    script = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, json
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.launch.hlo_cost import walk_costs
from repro.launch.mesh import mesh_axis_kwargs
mesh = jax.make_mesh((2,4), ("data","model"), **mesh_axis_kwargs(2))
x_sh = NamedSharding(mesh, P("data", None))
w_sh = NamedSharding(mesh, P("data","model"))
def scanned(x, ws):
    def body(c, w): return c @ w, None
    return jax.lax.scan(body, x, ws)[0]
ws_sh = NamedSharding(mesh, P(None, "data","model"))
g = jax.jit(scanned, in_shardings=(x_sh, ws_sh), out_shardings=x_sh)
co = g.lower(jax.ShapeDtypeStruct((64,128), jnp.float32),
             jax.ShapeDtypeStruct((5,128,128), jnp.float32)).compile()
fl, _ = walk_costs(co.as_text())
print(json.dumps({"flops": fl, "expect": 5*2*64*128*128/8}))
"""
    env = dict(os.environ, PYTHONPATH=src)
    env.pop("XLA_FLAGS", None)
    out = subprocess.run([sys.executable, "-c", script], capture_output=True,
                         text=True, env=env, timeout=600)
    assert out.returncode == 0, out.stderr[-2000:]
    import json
    r = json.loads(out.stdout.strip().splitlines()[-1])
    assert abs(r["flops"] - r["expect"]) / r["expect"] < 0.02


# ------------------------------------------------------- collective parser
def test_link_bytes_model():
    from repro.launch.dryrun import _link_bytes
    # all-gather of result 1600 over group 4: each device receives 3/4
    assert _link_bytes("all-gather", 1600, 4) == pytest.approx(1200)
    assert _link_bytes("all-reduce", 1000, 4) == pytest.approx(1500)
    assert _link_bytes("reduce-scatter", 100, 4) == pytest.approx(300)
