"""Bayesian online change-point detection (Algorithm 3's D function)."""
import numpy as np

from repro.core.bocd import BOCD, BandwidthStateDetector


def test_detects_mean_shift():
    rng = np.random.default_rng(0)
    xs = np.concatenate([rng.normal(5.0, 0.3, 80), rng.normal(1.0, 0.3, 80)])
    det = BandwidthStateDetector(hazard=1 / 60)
    for x in xs:
        det.update(x)
    assert any(70 <= c <= 95 for c in det.changes), det.changes
    assert abs(det.current_state - 1.0) < 0.5


def test_stable_sequence_few_changes():
    rng = np.random.default_rng(1)
    xs = rng.normal(3.0, 0.2, 200)
    det = BandwidthStateDetector(hazard=1 / 100)
    for x in xs:
        det.update(x)
    assert len(det.changes) <= 4
    assert abs(det.current_state - 3.0) < 0.3


def test_multiple_segments():
    rng = np.random.default_rng(2)
    xs = np.concatenate([rng.normal(m, 0.2, 60) for m in (2.0, 6.0, 1.0, 4.0)])
    det = BandwidthStateDetector(hazard=1 / 50)
    states = [det.update(x) for x in xs]
    # state estimate tracks each segment by its end
    assert abs(states[55] - 2.0) < 0.6
    assert abs(states[115] - 6.0) < 0.8
    assert abs(states[175] - 1.0) < 0.6
    assert abs(states[235] - 4.0) < 0.8


def test_run_length_truncation_bounded():
    det = BOCD(max_run=64)
    rng = np.random.default_rng(3)
    for x in rng.normal(0, 1, 500):
        det.update(float(x))
    assert len(det.r_prob) <= 65


def test_bank_matches_scalar_detectors():
    """BOCDBank row i must track an independent BOCD fed the same stream
    bit-exactly (same posteriors, same change flags) — the fleet's sampling
    sweep relies on the lockstep batch being a pure vectorization."""
    from repro.core.bocd import BOCDBank
    rng = np.random.default_rng(7)
    n, steps = 5, 300
    bank = BOCDBank(n, hazard=1 / 30.0, max_run=96)
    dets = [BOCD(hazard=1 / 30.0, max_run=96) for _ in range(n)]
    # distinct regimes per row, with mean shifts at different times
    streams = [np.concatenate([rng.normal(m, 0.3, steps // 3)
                               for m in rng.uniform(0.5, 6.0, 3)])
               for _ in range(n)]
    for t in range(steps):
        x = np.array([streams[i][t] for i in range(n)])
        changed = bank.update(x)
        for i in range(n):
            assert bool(changed[i]) == dets[i].update(float(x[i])), (i, t)
            assert np.array_equal(bank.r_prob[i], dets[i].r_prob), (i, t)
            assert np.array_equal(bank.mu[i], dets[i].mu), (i, t)
            assert np.array_equal(bank.beta[i], dets[i].beta), (i, t)
            assert bank.map_run[i] == dets[i].map_run, (i, t)
