"""Cooperative multi-edge planning: k-cut oracle reduction (exact), span
allocation properties, golden-plan regressions for JointPlanner /
BandwidthAwareRouter, and plan-cache hit behavior."""
import functools

import pytest

from repro.configs import get_smoke_config
from repro.core import lm_graph
from repro.core.latency_model import RooflineLatencyModel
from repro.core.partitioner import (branch_latency, multi_branch_latency,
                                    optimize_multi, optimize_with_fallback,
                                    proportional_cuts)
from repro.fleet import FleetEngine, JointPlanner, make_fleet, make_workload
from repro.fleet.coop import assign_spans, hop_schedule, span_seconds
from repro.fleet.router import BandwidthAwareRouter
from repro.fleet.workload import FleetRequest
from repro.sim import PlannerSpec, build_stack


@functools.lru_cache(maxsize=1)
def _scenario():
    sc = build_stack(PlannerSpec())
    return sc.graph, sc.planner


# --------------------------------------------------------------------------
# k=1 reduction: multi-cut math must reproduce the 1-cut oracle EXACTLY
# --------------------------------------------------------------------------

@pytest.mark.parametrize("dtype_bytes", [2, 4])   # bf16 and fp32 activations
def test_k1_reduces_to_one_cut_oracle_exactly(dtype_bytes):
    cfg = get_smoke_config("llama3.2-1b")
    g = lm_graph(cfg, batch=1, seq=1, dtype_bytes=dtype_bytes)
    fe = RooflineLatencyModel(chips=8, efficiency=0.4)
    fd = RooflineLatencyModel(chips=1, efficiency=0.4)
    for exit_idx in range(1, g.num_exits + 1):
        n = len(g.branches[exit_idx - 1])
        for p in range(n + 1):
            for speed in (1.0, 2.5, 4.0):
                for dev in (1.0, 0.8, 2.3):
                    for bw in (1e4, 1e6, 1e8):
                        one = branch_latency(g, exit_idx, p, fe, fd, bw,
                                             edge_load=speed,
                                             device_load=dev)
                        cuts = (p,) if p > 0 else ()
                        loads = (speed,) if p > 0 else ()
                        multi = multi_branch_latency(
                            g, exit_idx, cuts, loads, fe, fd, bw,
                            device_load=dev, edge_bw_bps=1e9)
                        assert multi == one      # tolerance 0, bit-exact


@pytest.mark.parametrize("dtype_bytes", [2, 4])
def test_optimize_multi_single_speed_matches_fallback(dtype_bytes):
    cfg = get_smoke_config("llama3.2-1b")
    g = lm_graph(cfg, batch=1, seq=1, dtype_bytes=dtype_bytes)
    fe = RooflineLatencyModel(chips=8, efficiency=0.4)
    fd = RooflineLatencyModel(chips=1, efficiency=0.4)
    for bw in (1e4, 1e6, 1e8):
        for req in (1e-7, 1e-4, 1.0):
            a = optimize_with_fallback(g, fe, fd, bw, req)
            b = optimize_multi(g, fe, fd, bw, req, (1.0,), edge_bw_bps=1e9)
            assert (a.exit_point, a.partition, a.feasible) == \
                (b.exit_point, b.partition, b.feasible)
            assert a.latency_s == b.latency_s    # tolerance 0


def test_per_exit_coop_times_k1_identity():
    graph, planner = _scenario()
    from repro.serving.engine import CoInferenceStepper
    st = CoInferenceStepper(None, graph, planner)
    for p in (0, 2, 4):
        for speed in (1.0, 3.0):
            a = st.per_exit_times_cached(p, 5e5, edge_load=speed,
                                         device_load=1.3,
                                         include_input=False)
            b = st.per_exit_times_coop_cached(p, (speed,), 5e5,
                                              device_load=1.3,
                                              edge_bw_bps=5e7,
                                              include_input=False)
            assert a == b


# --------------------------------------------------------------------------
# span allocation
# --------------------------------------------------------------------------

def test_proportional_cuts_shapes():
    assert proportional_cuts(0, (1.0, 2.0)) == ((), ())
    assert proportional_cuts(4, (1.0,)) == ((4,), (0,))
    cuts, keep = proportional_cuts(4, (1.0, 1.0))
    assert cuts == (2, 4) and keep == (0, 1)
    # faster edge (speed 1) owns more layers than the 4x-slower one
    cuts, keep = proportional_cuts(4, (1.0, 4.0))
    assert cuts[-1] == 4 and cuts[0] >= 2
    # shares that round to zero layers drop the edge entirely
    cuts, keep = proportional_cuts(1, (1.0, 100.0))
    assert cuts == (1,) and keep == (0,)
    # always ascending, always ends at p
    for p in range(1, 9):
        for speeds in ((1.0, 2.0, 3.0, 4.0), (1.0, 1.0, 5.0), (2.0, 9.0)):
            cuts, keep = proportional_cuts(p, speeds)
            assert cuts[-1] == p
            assert list(cuts) == sorted(set(cuts))
            assert len(cuts) == len(keep)
            # idempotent on the kept set: re-splitting over the surviving
            # speeds reproduces the cuts, so plan search, span assignment,
            # and round timing all agree on one layout
            kept = tuple(speeds[i] for i in keep)
            assert proportional_cuts(p, kept)[0] == cuts


def test_assign_spans_maps_eids_and_hops_bill_cut_bytes():
    graph, planner = _scenario()
    topo = make_fleet(2, 3, seed=0)
    assign = assign_spans(4, [topo.edges[2], topo.edges[0]])
    assert assign.eids[0] == 2 and assign.partition == 4
    assert sum(e - s for _, s, e in assign.spans()) == 4
    hops = hop_schedule(graph, graph.num_exits, assign, planner.f_edge,
                        topo.edge_bw_bps)
    assert len(hops) == assign.k - 1
    for dt, src, dst, nbytes in hops:
        assert dt > 0 and nbytes > 0
        assert src in assign.eids and dst in assign.eids
    spans = span_seconds(graph, graph.num_exits, assign, planner.f_edge)
    assert len(spans) == assign.k and all(s > 0 for s in spans)
    # the chain's edge compute equals the sum of its spans once the
    # device-link transfer terms are removed
    bw = 1e9
    chain = multi_branch_latency(
        graph, graph.num_exits, assign.cuts, assign.speeds,
        planner.f_edge, planner.f_device, bw, device_load=0.0,
        edge_bw_bps=float("inf"))
    transfers = (graph.input_bytes +
                 graph.cut_bytes(graph.num_exits, assign.partition)) / bw
    assert chain - transfers == pytest.approx(sum(spans), rel=1e-9)


def test_multi_branch_latency_improves_with_backbone_bandwidth():
    graph, planner = _scenario()
    slow = multi_branch_latency(graph, 3, (2, 4), (1.0, 2.0),
                                planner.f_edge, planner.f_device, 1e6,
                                edge_bw_bps=1e5)
    fast = multi_branch_latency(graph, 3, (2, 4), (1.0, 2.0),
                                planner.f_edge, planner.f_device, 1e6,
                                edge_bw_bps=1e9)
    assert fast < slow


# --------------------------------------------------------------------------
# golden-plan regressions (fixed seed/topology — placement must not drift)
# --------------------------------------------------------------------------

def _golden_fleet():
    graph, planner = _scenario()
    topo = make_fleet(8, 4, seed=11, edge_capacity=4, lo_mbps=0.1,
                      hi_mbps=6.0, max_edge_slowdown=4.0)
    eng = FleetEngine(topo, graph, planner)
    return topo, eng


def _req(did, tenant="standard", slo=1.0, tokens=8):
    return FleetRequest(rid=0, device=did, tenant=tenant, slo_s=slo,
                        max_new_tokens=tokens, arrival_s=0.0)


def test_golden_joint_planner_decisions_idle_fleet():
    topo, eng = _golden_fleet()
    jp = JointPlanner(eng.stepper, topo)
    # device 0: mid bandwidth, ~1x compute -> stays local at the top exit
    d0 = jp.decide(_req(0), topo.devices[0], topo, 0.0)
    assert (d0.assign.eids, d0.plan.exit_point, d0.plan.partition) == \
        ((), 3, 0)
    # device 5: 2.4x-slow device -> full offload to the fastest idle edge
    d5 = jp.decide(_req(5), topo.devices[5], topo, 0.0)
    assert (d5.assign.eids, d5.plan.exit_point, d5.plan.partition,
            d5.plan.cuts) == ((0,), 3, 4, (4,))


def test_golden_bandwidth_aware_routes_idle_fleet():
    topo, eng = _golden_fleet()
    ba = BandwidthAwareRouter(eng.stepper)
    for did in (0, 3, 5):
        assert ba.route(_req(did), topo.devices[did], topo, 0.0).eid == 0


def _golden_sim(router):
    graph, planner = _scenario()
    topo = make_fleet(30, 4, seed=2, edge_capacity=8, lo_mbps=0.1,
                      hi_mbps=6.0, max_edge_slowdown=4.0)
    wl = make_workload(30, rate_hz=40.0, horizon_s=10.0, seed=3,
                       arrival="diurnal", device_skew=1.0)
    return FleetEngine(topo, graph, planner, router=router).run(wl)


def test_golden_joint_simulation():
    m = _golden_sim("joint")
    s = m.summary()
    assert s["requests"] == 370
    assert s["coop_requests"] == 19
    assert s["slo_attainment"] == pytest.approx(0.8324324324324325,
                                                rel=1e-12)
    by_rid = {r.rid: r for r in m.records}
    assert by_rid[10].edges == (2, 0, 1)      # first cooperative placement
    assert by_rid[10].partition == 4
    assert s["backbone_mb"] > 0


def test_golden_bandwidth_aware_simulation():
    s = _golden_sim("bandwidth-aware").summary()
    assert s["requests"] == 370
    assert s["coop_requests"] == 0
    assert s["slo_attainment"] == pytest.approx(0.5135135135135135,
                                                rel=1e-12)


# --------------------------------------------------------------------------
# plan cache: identical bandwidth states must not recompute
# --------------------------------------------------------------------------

def test_plan_cache_hit_on_repeated_states():
    graph, planner = _scenario()
    from repro.serving.engine import CoInferenceStepper
    st = CoInferenceStepper(None, graph, planner)
    calls = {"single": 0, "multi": 0}
    orig_plan, orig_multi = planner.plan, planner.plan_multi

    def count_plan(bw, **kw):
        calls["single"] += 1
        return orig_plan(bw, **kw)

    def count_multi(bw, speeds, **kw):
        calls["multi"] += 1
        return orig_multi(bw, speeds, **kw)

    planner.plan, planner.plan_multi = count_plan, count_multi
    try:
        a = st.plan(5.01e5)
        b = st.plan(5.013e5)        # same quantized bandwidth state
        assert a is b and calls["single"] == 1
        m1 = st.plan_multi(5.01e5, (1.0, 3.0), device_load=1.2,
                           edge_bw_bps=5e7)
        m2 = st.plan_multi(5.013e5, (1.0, 3.0), device_load=1.2,
                           edge_bw_bps=5e7)
        assert m1 is m2 and calls["multi"] == 1
        # a different edge-speed tuple is a different cache line
        st.plan_multi(5.01e5, (2.0,), device_load=1.2, edge_bw_bps=5e7)
        assert calls["multi"] == 2
    finally:
        planner.plan, planner.plan_multi = orig_plan, orig_multi


def test_fleet_run_shares_plan_searches_across_devices():
    graph, planner = _scenario()
    topo = make_fleet(30, 2, seed=0)
    wl = make_workload(30, rate_hz=30.0, horizon_s=10.0, seed=1)
    eng = FleetEngine(topo, graph, planner, router="joint")
    eng.run(wl)
    # many (device, arrival) pairs, far fewer quantized plan states
    assert 0 < len(eng.stepper.plan_cache) < len(wl) * 5


# --------------------------------------------------------------------------
# mobile pricing: decide() must price each candidate at its own primary
# --------------------------------------------------------------------------

def _asymmetric_mobile_fleet():
    """One stationary device parked on a *slow* edge, with a *fast* edge far
    away: best-signal pricing (the device link's rate, i.e. the nearest
    edge's) makes the far edge's uplink look cheap and over-admits it."""
    import numpy as np

    from repro.fleet.cluster import DeviceNode, EdgeNode, FleetTopology
    from repro.fleet.mobility import (MobileLink, MobilityModel, Trajectory,
                                      edge_grid)
    sc = build_stack(PlannerSpec())
    pos = edge_grid(2)               # (0.25, 0.25) and (0.75, 0.25)
    traj = Trajectory(np.zeros(1), np.array([[0.25, 0.25]]))
    mob = MobilityModel(edge_pos=pos, trajectories=[traj], noise=None)
    dev = DeviceNode(0, MobileLink(mob, 0), slowdown=2.0)
    edges = [EdgeNode(0, capacity=4, speed=3.0),   # near, slow hardware
             EdgeNode(1, capacity=4, speed=1.0)]   # far, fast hardware
    topo = FleetTopology([dev], edges, edge_bw_bps=400 * 125e3)
    eng = FleetEngine(topo, sc.graph, sc.planner, router="joint",
                      mobility=mob, max_coop=1)
    return eng, topo, dev


def test_joint_decide_prices_per_primary_under_mobility():
    """Regression for the joint-router bandwidth mispricing: decide() used
    to price every candidate's uplink at the device link's best-signal
    rate, systematically preferring a far fast edge whose real uplink is an
    order of magnitude slower.  Per-primary pricing must pick a different
    edge set here, and that choice must win on *realized* latency."""
    import numpy as np
    wl = make_workload(1, rate_hz=0.4, horizon_s=10.0, seed=5)

    eng_fix, topo_fix, dev_fix = _asymmetric_mobile_fleet()
    eng_bug, topo_bug, dev_bug = _asymmetric_mobile_fleet()
    eng_bug.router.planner.mobility = None     # legacy best-signal pricing

    req = wl[0]
    dec_fix = eng_fix.router.planner.decide(req, dev_fix, topo_fix,
                                            req.arrival_s)
    dec_bug = eng_bug.router.planner.decide(req, dev_bug, topo_bug,
                                            req.arrival_s)
    # the mispricing is decision-changing: best-signal admits the far edge
    assert dec_fix.assign.eids == (0,)
    assert dec_bug.assign.eids == (1,)

    m_fix = eng_fix.run(wl)
    m_bug = eng_bug.run(wl)
    lat_fix = float(np.mean([r.latency_s for r in m_fix.records]))
    lat_bug = float(np.mean([r.latency_s for r in m_bug.records]))
    assert lat_fix < lat_bug
    # and the fixed run never serves from the far edge
    assert {r.edge for r in m_fix.records} == {0}


def test_joint_decide_mobile_matches_scalar_reference():
    """The row-vectorized mobile decide() path must agree with the scalar
    per-candidate reference on the asymmetric geometry."""
    eng, topo, dev = _asymmetric_mobile_fleet()
    planner = eng.router.planner
    for req in make_workload(1, rate_hz=1.0, horizon_s=6.0, seed=9):
        a = planner.decide(req, dev, topo, req.arrival_s)
        b = planner.decide_scalar(req, dev, topo, req.arrival_s)
        assert a.assign.eids == b.assign.eids
        assert a.est_s == b.est_s and a.est_min_s == b.est_min_s
