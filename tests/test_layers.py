"""Layer-level unit tests."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.models import layers as L


def test_rmsnorm_unit_scale(rng):
    x = jax.random.normal(rng, (4, 8, 32))
    y = L.rms_norm(x, jnp.ones((32,)), 1e-6)
    rms = jnp.sqrt(jnp.mean(y * y, axis=-1))
    np.testing.assert_allclose(np.asarray(rms), 1.0, rtol=1e-3)


def test_rope_preserves_norm_and_relative(rng):
    x = jax.random.normal(rng, (1, 6, 2, 16))
    pos = jnp.arange(6)[None]
    y = L.apply_rope(x, pos, 10000.0)
    np.testing.assert_allclose(np.asarray(jnp.linalg.norm(y, axis=-1)),
                               np.asarray(jnp.linalg.norm(x, axis=-1)), rtol=1e-5)
    # relative property: <rope(q,i), rope(k,j)> depends only on i-j
    q = jax.random.normal(jax.random.fold_in(rng, 1), (1, 1, 1, 16))
    k = jax.random.normal(jax.random.fold_in(rng, 2), (1, 1, 1, 16))
    def dot_at(i, j):
        qi = L.apply_rope(q, jnp.array([[i]]), 1e4)
        kj = L.apply_rope(k, jnp.array([[j]]), 1e4)
        return float(jnp.sum(qi * kj))
    assert abs(dot_at(3, 1) - dot_at(7, 5)) < 1e-4


def test_flash_jnp_matches_dense(rng):
    B, S, H, KV, hd = 2, 128, 4, 2, 32
    ks = jax.random.split(rng, 3)
    q = jax.random.normal(ks[0], (B, S, H, hd))
    k = jax.random.normal(ks[1], (B, S, KV, hd))
    v = jax.random.normal(ks[2], (B, S, KV, hd))
    dense = L._sdpa(q, k, v, L.causal_bias(S, S))
    flash = L.flash_attention_jnp(q, k, v, causal=True, q_block=32, kv_block=32)
    np.testing.assert_allclose(np.asarray(dense), np.asarray(flash),
                               rtol=2e-5, atol=2e-5)


def test_flash_jnp_noncausal(rng):
    B, S, H, hd = 1, 64, 2, 16
    ks = jax.random.split(rng, 3)
    q = jax.random.normal(ks[0], (B, S, H, hd))
    k = jax.random.normal(ks[1], (B, S, H, hd))
    v = jax.random.normal(ks[2], (B, S, H, hd))
    dense = L._sdpa(q, k, v, 0.0)
    flash = L.flash_attention_jnp(q, k, v, causal=False, q_block=16, kv_block=16)
    np.testing.assert_allclose(np.asarray(dense), np.asarray(flash),
                               rtol=2e-5, atol=2e-5)


def test_attention_decode_consistency(rng):
    """Prefill-mode cache writes + decode attention == full causal attention."""
    cfg = get_smoke_config("granite-3-2b")
    p = L.init_attn(rng, cfg, jnp.float32)
    B, S = 1, 10
    x = jax.random.normal(jax.random.fold_in(rng, 3), (B, S + 1, cfg.d_model)) * 0.1
    pos = jnp.arange(S + 1)[None]
    full, _ = L.attention(p, cfg, x, pos, impl="dense")
    kvh, hd = cfg.num_kv_heads, cfg.hd
    cache = (jnp.zeros((B, S + 1, kvh, hd)), jnp.zeros((B, S + 1, kvh, hd)))
    _, cache = L.attention(p, cfg, x[:, :S], pos[:, :S], kv_cache=cache,
                           cache_pos=0, prefill_mode=True, impl="dense")
    out, _ = L.attention(p, cfg, x[:, S:], pos[:, S:], kv_cache=cache,
                         cache_pos=jnp.asarray(S), prefill_mode=False)
    np.testing.assert_allclose(np.asarray(full[:, -1]), np.asarray(out[:, 0]),
                               rtol=1e-4, atol=1e-4)


def test_moe_einsum_vs_gather(rng):
    from repro.models import moe as MOE
    cfg = get_smoke_config("llama4-scout-17b-a16e")
    p = MOE.init_moe(rng, cfg, jnp.float32)
    x = jax.random.normal(jax.random.fold_in(rng, 1), (2, 16, cfg.d_model)) * 0.3
    y1, aux1 = MOE.moe_ffn(p, cfg, x, dispatch_mode="einsum")
    y2, aux2 = MOE.moe_ffn(p, cfg, x, dispatch_mode="gather")
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(float(aux1), float(aux2), rtol=1e-5)


def test_alexnet_layer_shapes():
    from repro.models.alexnet import BranchyAlexNet, BranchyAlexNetConfig
    net = BranchyAlexNet(BranchyAlexNetConfig())
    # paper branch lengths
    assert [len(net.branch_layers(i)) for i in range(1, 6)] == [12, 16, 19, 20, 22]
    # every layer kind is a Table-I type
    kinds = {s.kind for i in range(1, 6) for s in net.branch_layers(i)}
    assert kinds <= {"conv", "relu", "lrn", "pool", "dropout", "fc"}
