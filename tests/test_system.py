"""End-to-end behaviour tests: the paper's own claims (DESIGN.md §6).

(i)   exit point monotonically non-decreasing in bandwidth (Fig. 8a)
(ii)  chosen-plan latency dips as bandwidth rises; bottleneck shifts (Fig. 8b)
(iii) exit/partition non-decreasing as the SLO relaxes (Fig. 8c)
(iv)  Edgent meets deadlines that edge-/device-only miss (Fig. 9)
(v)   dynamic configurator >= static under dynamic bandwidth (Fig. 11)
(vi)  Algorithm-1 search < 1 ms (tested in test_partitioner)
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.partitioner import branch_latency
from repro.data.bandwidth import belgium_lte_like, oboe_like_traces


def _plans_over_bandwidth(planner, kbps_list, slo=1.0):
    planner.latency_req_s = slo
    planner.static_opt.latency_req_s = slo
    return [planner.plan(kbps * 125) for kbps in kbps_list]


def test_exit_monotone_in_bandwidth(alexnet_planner):
    kbps = [25, 50, 100, 250, 500, 1000, 1500, 3000]
    plans = _plans_over_bandwidth(alexnet_planner, kbps)
    exits = [p.exit_point for p in plans if p.feasible]
    assert exits == sorted(exits), exits
    assert exits[-1] == 5


def test_latency_decreases_with_bandwidth_fixed_plan(alexnet_planner):
    g = alexnet_planner.graph
    fe, fd = alexnet_planner.f_edge, alexnet_planner.f_device
    lats = [branch_latency(g, 5, 22, fe, fd, kbps * 125)
            for kbps in (50, 100, 500, 1000)]
    assert all(a >= b for a, b in zip(lats, lats[1:]))


def test_exit_partition_monotone_in_slo(alexnet_planner):
    bw = 500 * 125
    exits = []
    for slo_ms in (100, 200, 300, 500, 800, 1200):
        alexnet_planner.latency_req_s = slo_ms / 1e3
        alexnet_planner.static_opt.latency_req_s = slo_ms / 1e3
        p = alexnet_planner.plan(bw)
        if p.feasible:
            exits.append(p.exit_point)
    assert exits == sorted(exits)
    assert len(exits) >= 3


def test_edgent_beats_single_tier_methods(alexnet_planner):
    """Fig. 9: at some (bandwidth, deadline) Edgent is feasible while both
    device-only and edge-only are not.  The window sits at low bandwidth,
    where the input uplink sinks edge-only and right-sizing (an early exit
    on the device) beats the full model."""
    g = alexnet_planner.graph
    fe, fd = alexnet_planner.f_edge, alexnet_planner.f_device
    found = False
    for kbps in (25, 40, 50, 75, 100, 200, 400):
        bw = kbps * 125
        for slo in np.linspace(0.05, 2.2, 60):
            alexnet_planner.latency_req_s = slo
            alexnet_planner.static_opt.latency_req_s = slo
            plan = alexnet_planner.plan(bw)
            device_only = branch_latency(g, 5, 0, fe, fd, bw)
            edge_only = branch_latency(g, 5, 22, fe, fd, bw)
            if plan.feasible and device_only > slo and edge_only > slo:
                found = True
                break
        if found:
            break
    assert found, "no (bw, deadline) where Edgent wins over both single-tier methods"


def test_dynamic_beats_static_under_dynamic_bandwidth(alexnet_planner):
    """Fig. 11: higher mean reward/throughput for the dynamic configurator."""
    from repro.core.config_map import reward_fn

    traces = oboe_like_traces(seed=0, num=80)
    alexnet_planner.latency_req_s = 1.0
    alexnet_planner.static_opt.latency_req_s = 1.0
    alexnet_planner.offline_dynamic([t.tolist() for t in traces])
    lte = belgium_lte_like(seed=3, length=300, transport="bus", hi_mbps=6.0)

    g = alexnet_planner.graph
    fe, fd = alexnet_planner.f_edge, alexnet_planner.f_device
    rew_static, rew_dyn = [], []
    for b in lte:
        ps = alexnet_planner.plan(b, dynamic=False)
        pd = alexnet_planner.plan(b, dynamic=True)
        ls = branch_latency(g, ps.exit_point, ps.partition, fe, fd, b)
        ld = branch_latency(g, pd.exit_point, pd.partition, fe, fd, b)
        rew_static.append(reward_fn(ps.accuracy, ls, 1.0))
        rew_dyn.append(reward_fn(pd.accuracy, ld, 1.0))
    # dynamic should be at least comparable (paper: better in general)
    assert np.mean(rew_dyn) >= 0.95 * np.mean(rew_static)


def test_coinference_executor_accounts_transfers(alexnet_setup):
    from repro.core.coinference import TwoTierExecutor
    from repro.core.partitioner import CoInferencePlan

    net, params, graph = alexnet_setup
    x = jax.random.normal(jax.random.key(0), (1, 32, 32, 3))
    ex = TwoTierExecutor(graph, params, bandwidth_bps=125e3,
                         device_slowdown=5.0)
    plan = CoInferencePlan(exit_point=5, partition=8, latency_s=0.0, accuracy=0.8)
    res = ex.run(plan, x)
    assert res.output.shape == (1, 10)
    expected_transfer = (graph.input_bytes + graph.cut_bytes(5, 8)) / 125e3
    assert res.transfer_s == pytest.approx(expected_transfer)
    assert res.latency_s >= res.transfer_s
    # device-only plan has zero transfer
    res0 = ex.run(CoInferencePlan(5, 0, 0.0, 0.8), x)
    assert res0.transfer_s == 0.0


def test_elastic_replanning():
    from repro.core import lm_graph
    from repro.configs import get_config
    from repro.runtime.elastic import ElasticPlanner, TierSpec

    cfg = get_config("llama3.2-1b")
    graph = lm_graph(cfg, batch=1, seq=1)
    ep = ElasticPlanner(graph, latency_req_s=0.05, link_bps=2e9)
    full = ep.plan_for(TierSpec(chips=64), TierSpec(chips=1))
    shrunk, new_edge = ep.shrink_event(TierSpec(chips=64), TierSpec(chips=1),
                                       lost_chips=60)
    assert new_edge.chips == 4
    # losing edge capacity can only reduce (or keep) the chosen exit depth
    assert shrunk.exit_point <= full.exit_point or shrunk.partition != full.partition
