"""Table-I regression predictors + roofline predictor."""
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st  # hypothesis or skip-stub

from repro.core.graph import GraphLayer
from repro.core.latency_model import (ProfileRecord, RegressionLatencyModel,
                                      RooflineLatencyModel, ScaledLatencyModel)


def _records(kind, theta, n=30, seed=0, noise=0.0):
    rng = np.random.default_rng(seed)
    from repro.core.latency_model import TABLE_I_FEATURES
    names = TABLE_I_FEATURES[kind]
    recs = []
    for _ in range(n):
        feats = {nm: float(rng.uniform(1, 100)) for nm in names}
        lat = sum(theta[i] * feats[nm] for i, nm in enumerate(names)) + theta[-1]
        lat += noise * rng.normal()
        recs.append(ProfileRecord(kind, feats, lat))
    return recs


def test_exact_recovery_linear():
    theta = [0.3, 0.05, 2.0]
    m = RegressionLatencyModel().fit(_records("conv", theta))
    np.testing.assert_allclose(m.theta["conv"], theta, rtol=1e-6)
    assert m.r2()["conv"] > 0.999999


def test_predict_matches_design():
    theta = [0.1, 1.0]
    m = RegressionLatencyModel().fit(_records("relu", theta))
    lay = GraphLayer("x", "relu", {"in_size": 50.0}, out_bytes=1)
    assert m.predict(lay) == pytest.approx(0.1 * 50 + 1.0, rel=1e-5)


@settings(max_examples=20, deadline=None)
@given(a=st.floats(1e-4, 1.0), b=st.floats(1e-4, 1.0), c=st.floats(0.0, 5.0),
       seed=st.integers(0, 100))
def test_property_fc_regression_recovers(a, b, c, seed):
    m = RegressionLatencyModel().fit(_records("fc", [a, b, c], seed=seed))
    np.testing.assert_allclose(m.theta["fc"], [a, b, c], rtol=1e-4, atol=1e-6)


def test_noise_r2_reasonable():
    m = RegressionLatencyModel().fit(_records("pool", [0.5, 0.2, 1.0], n=200,
                                              noise=0.5))
    assert 0.8 < m.r2()["pool"] <= 1.0


def test_unknown_kind_raises():
    m = RegressionLatencyModel().fit(_records("relu", [0.1, 0.0]))
    with pytest.raises(KeyError):
        m.predict(GraphLayer("x", "conv", {"in_maps": 3, "comp": 1}, 1))


def test_roofline_model_terms():
    m = RooflineLatencyModel(chips=2, peak_flops=100.0, hbm_bw=10.0,
                             efficiency=1.0)
    lay = GraphLayer("x", "block", {}, out_bytes=1, flops=400.0, bytes_moved=10.0)
    # compute-bound: 400/(2*100)=2.0 > 10/(2*10)=0.5
    assert m.predict(lay) == pytest.approx(2.0)
    lay2 = GraphLayer("y", "block", {}, out_bytes=1, flops=10.0, bytes_moved=400.0)
    assert m.predict(lay2) == pytest.approx(20.0)


def test_scaled_model():
    base = RooflineLatencyModel(chips=1, peak_flops=100.0, hbm_bw=10.0,
                                efficiency=1.0)
    lay = GraphLayer("x", "block", {}, out_bytes=1, flops=100.0, bytes_moved=0.0)
    assert ScaledLatencyModel(base, 3.0).predict(lay) == pytest.approx(3.0)
