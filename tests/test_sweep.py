"""repro.sim.sweep: grid expansion, JSONL rows, process-pool equivalence,
and the contract that sweep cells reproduce the fleet_scale benchmark
tables' numbers (each cell is just a spec — same spec, same metrics)."""
import json

import pytest

from repro.sim import (ScenarioSpec, Simulation, apply_overrides,
                       get_scenario, grid_cells, random_cells, run_sweep)
from repro.sim.sweep import main as sweep_main
from repro.sim.sweep import run_cell

SMALL = {"workload.horizon_s": 5.0, "topology.num_devices": 8}


def _base():
    return apply_overrides(get_scenario("smoke-lm"), SMALL)


def test_grid_cells_cartesian_order():
    cells = grid_cells(_base(), {"topology.num_devices": [4, 8],
                                 "router.name": ["rr", "jsq"]})
    combos = [(c.topology.num_devices, c.router.name) for c in cells]
    # row-major: later axes vary fastest
    assert combos == [(4, "rr"), (4, "jsq"), (8, "rr"), (8, "jsq")]
    # cells are independent specs; the base is untouched
    assert _base().topology.num_devices == 8


def test_grid_cells_reject_unknown_axis():
    with pytest.raises(ValueError):
        grid_cells(_base(), {"topology.nope": [1]})


def test_random_cells_deterministic_in_seed():
    axes = {"seed": [1, 2, 3, 4], "router.name": ["rr", "jsq"]}
    a = random_cells(_base(), axes, 6, seed=9)
    b = random_cells(_base(), axes, 6, seed=9)
    assert [c.to_dict() for c in a] == [c.to_dict() for c in b]
    assert len(a) == 6
    assert any(x.to_dict() != y.to_dict() for x, y in zip(a, a[1:]))


def test_run_sweep_rows_and_jsonl(tmp_path):
    out = tmp_path / "rows.jsonl"
    cells = grid_cells(_base(), {"router.name": ["rr", "jsq"]})
    rows = run_sweep(cells, out_path=str(out))
    assert [r["spec"]["router"]["name"] for r in rows] == ["rr", "jsq"]
    on_disk = [json.loads(line) for line in out.read_text().splitlines()]
    assert on_disk == json.loads(json.dumps(rows))  # canonical JSON rows
    for row in rows:
        # a row's spec re-runs to the identical metrics (reproducibility
        # contract: the JSONL is self-describing)
        again = Simulation(ScenarioSpec.from_dict(row["spec"])).run()
        assert again.summary() == row["metrics"]


def test_run_sweep_parallel_matches_inline():
    cells = grid_cells(_base(), {"router.name": ["rr", "jsq"],
                                 "seed": [0, 1]})
    inline = run_sweep(cells)
    pooled = run_sweep(cells, processes=2)
    strip = lambda rows: [{k: v for k, v in r.items() if k != "wall_s"}
                          for r in rows]                      # noqa: E731
    canon = lambda rows: json.loads(json.dumps(strip(rows)))  # noqa: E731
    assert canon(inline) == canon(pooled)


def test_sweep_cell_reproduces_fleet_scale_table_cells():
    """The --coop / --mobility benchmark tables are sweeps now; their cells
    must equal a direct Simulation of the registered scenario (the pinned
    smoke numbers in fleet_scale's --smoke gates rest on this)."""
    import sys
    sys.path.insert(0, "benchmarks")
    try:
        from fleet_scale import SEED, lm_cell_spec, mobility_cell_spec
    finally:
        sys.path.pop(0)
    # --coop --smoke cell == registry "coop" scenario
    row = run_cell(lm_cell_spec(40, "joint", seed=SEED))
    assert row["metrics"] == Simulation(get_scenario("coop")).run().summary()
    # --mobility --smoke bocd cell == registry "smoke-mobility" scenario
    mob = get_scenario("smoke-mobility")
    row = run_cell(mobility_cell_spec(mob.topology.num_devices,
                                      mob.topology.speed, "bocd", seed=SEED))
    assert row["metrics"] == Simulation(mob).run().summary()


def test_sweep_cli_grid(tmp_path, capsys):
    out = tmp_path / "cli.jsonl"
    rc = sweep_main([
        "--scenario", "smoke-lm",
        "--set", "workload.horizon_s=4", "--set", "topology.num_devices=6",
        "--grid", 'router.name=["rr","jsq"]',
        "--out", str(out)])
    assert rc == 0
    rows = [json.loads(line) for line in out.read_text().splitlines()]
    assert len(rows) == 2
    assert {r["spec"]["router"]["name"] for r in rows} == {"rr", "jsq"}


def test_sweep_cli_rejects_bad_usage(tmp_path):
    with pytest.raises(ValueError):
        sweep_main(["--scenario", "smoke-lm", "--out",
                    str(tmp_path / "x.jsonl")])          # no --grid
    with pytest.raises(ValueError):
        sweep_main(["--out", str(tmp_path / "x.jsonl"),
                    "--grid", "seed=[1]"])               # no base spec
    with pytest.raises(ValueError):
        sweep_main(["--scenario", "smoke-lm", "--grid", "seed=1",
                    "--out", str(tmp_path / "x.jsonl")])  # not a list
