"""Serving engine end-to-end (smoke scale) + scheduler units."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.core import EdgentPlanner, lm_graph
from repro.core.latency_model import RooflineLatencyModel
from repro.data.bandwidth import dcn_trace
from repro.models import Model
from repro.serving import Request, ServingEngine
from repro.serving.scheduler import SLOScheduler, pick_exit
from repro.serving.tiers import Link


def test_scheduler_edf_order():
    s = SLOScheduler(batch_size=2)
    s.submit(0, 5.0)
    s.submit(1, 1.0)
    s.submit(2, 3.0)
    assert s.next_batch() == [1, 2]
    assert s.next_batch() == [0]


def test_scheduler_does_not_admit_future_requests():
    """A not-yet-arrived request with a tight deadline must not stall an
    already-arrived one into its own batch."""
    s = SLOScheduler(batch_size=2)
    s.submit(0, deadline=1.0, arrival_s=0.0)
    s.submit(1, deadline=100.5, arrival_s=100.0)
    assert s.next_batch(now=0.0) == [0]
    assert s.next_batch(now=0.5) == []
    assert s.earliest_arrival() == 100.0
    assert s.next_batch(now=100.0) == [1]


def test_pick_exit_demotion():
    per_exit = [0.01, 0.02, 0.04]
    assert pick_exit(1.0, per_exit, tokens_left=10, preferred=3) == 3
    assert pick_exit(0.25, per_exit, tokens_left=10, preferred=3) == 2
    assert pick_exit(0.05, per_exit, tokens_left=10, preferred=3) == 1
    assert pick_exit(0.001, per_exit, tokens_left=10, preferred=3) == 1


@pytest.fixture(scope="module")
def engine_setup():
    cfg = get_smoke_config("llama3.2-1b")
    model = Model(cfg)
    params = model.init_params(jax.random.key(0), dtype=jnp.float32)
    graph = lm_graph(cfg, batch=2, seq=1)
    planner = EdgentPlanner(graph, latency_req_s=0.5)
    planner.with_models(RooflineLatencyModel(chips=8, efficiency=0.4),
                        RooflineLatencyModel(chips=1, efficiency=0.4))
    return cfg, model, params, graph, planner


def test_engine_serves_and_meets_slo(engine_setup):
    cfg, model, params, graph, planner = engine_setup
    link = Link(trace_bps=dcn_trace(0, 512))
    eng = ServingEngine(model, params, graph, planner, link, batch_size=2,
                        dtype=jnp.float32)
    rs = np.random.default_rng(0)
    reqs = [Request(rid=i, prompt=rs.integers(0, cfg.vocab_size, 6).astype(np.int32),
                    max_new_tokens=4, slo_s=0.5) for i in range(4)]
    stats = eng.serve(reqs)
    s = stats.summary()
    assert s["requests"] == 4
    assert s["slo_attainment"] > 0.5
    assert all(len(t) == 4 for t in stats.tokens.values())
    assert all(1 <= e <= model.num_segments for e in stats.exits)


def test_engine_bills_queueing_delay(engine_setup):
    """A request served in a later batch is billed clock - arrival, so its
    latency includes the time it spent queued behind earlier batches."""
    cfg, model, params, graph, planner = engine_setup
    link = Link(trace_bps=dcn_trace(0, 512))
    eng = ServingEngine(model, params, graph, planner, link, batch_size=1,
                        dtype=jnp.float32)
    rs = np.random.default_rng(2)
    reqs = [Request(rid=i, prompt=rs.integers(0, cfg.vocab_size, 6).astype(np.int32),
                    max_new_tokens=4, slo_s=10.0 - i) for i in range(3)]
    stats = eng.serve(reqs)
    # EDF serves rid 2 (tightest deadline) first; every later batch starts
    # where the previous one finished
    order = sorted(range(3), key=lambda i: stats.latencies[i])
    assert stats.latencies[order[0]] < stats.latencies[order[1]] \
        < stats.latencies[order[2]]
    assert stats.queue_delays[order[0]] == 0.0
    assert stats.queue_delays[order[1]] > 0.0
    assert stats.summary()["mean_queue_delay_s"] > 0.0


def test_engine_deadline_uses_own_arrival(engine_setup):
    """A late-arriving request's SLO budget starts at its arrival, not at
    the batch clock origin."""
    cfg, model, params, graph, planner = engine_setup
    link = Link(trace_bps=dcn_trace(0, 512))
    eng = ServingEngine(model, params, graph, planner, link, batch_size=2,
                        dtype=jnp.float32)
    rs = np.random.default_rng(3)
    prompt = rs.integers(0, cfg.vocab_size, 6).astype(np.int32)
    reqs = [Request(rid=0, prompt=prompt, max_new_tokens=4, slo_s=0.5,
                    arrival_s=5.0)]
    stats = eng.serve(reqs)
    # latency is measured from arrival (well under 5s of service), and the
    # deadline check is arrival + slo, so the request still meets its SLO
    assert stats.latencies[0] < 5.0
    assert stats.met_slo == [True]


def test_engine_demotes_under_tight_slo(engine_setup):
    cfg, model, params, graph, planner = engine_setup
    link = Link(trace_bps=dcn_trace(0, 512))
    eng = ServingEngine(model, params, graph, planner, link, batch_size=2,
                        dtype=jnp.float32)
    rs = np.random.default_rng(1)
    tight = [Request(rid=i, prompt=rs.integers(0, cfg.vocab_size, 6).astype(np.int32),
                     max_new_tokens=4, slo_s=0.0) for i in range(2)]
    stats = eng.serve(tight)
    # infeasible SLO -> engine demotes to the earliest exit rather than hang
    assert stats.summary()["mean_exit"] == 1.0
    assert stats.summary()["slo_attainment"] == 0.0
