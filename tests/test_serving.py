"""Serving engine end-to-end (smoke scale) + scheduler units."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.core import EdgentPlanner, lm_graph
from repro.core.latency_model import RooflineLatencyModel
from repro.data.bandwidth import dcn_trace
from repro.models import Model
from repro.serving import Request, ServingEngine
from repro.serving.scheduler import SLOScheduler, pick_exit
from repro.serving.tiers import Link


def test_scheduler_edf_order():
    s = SLOScheduler(batch_size=2)
    s.submit(0, 5.0)
    s.submit(1, 1.0)
    s.submit(2, 3.0)
    assert s.next_batch() == [1, 2]
    assert s.next_batch() == [0]


def test_pick_exit_demotion():
    per_exit = [0.01, 0.02, 0.04]
    assert pick_exit(1.0, per_exit, tokens_left=10, preferred=3) == 3
    assert pick_exit(0.25, per_exit, tokens_left=10, preferred=3) == 2
    assert pick_exit(0.05, per_exit, tokens_left=10, preferred=3) == 1
    assert pick_exit(0.001, per_exit, tokens_left=10, preferred=3) == 1


@pytest.fixture(scope="module")
def engine_setup():
    cfg = get_smoke_config("llama3.2-1b")
    model = Model(cfg)
    params = model.init_params(jax.random.key(0), dtype=jnp.float32)
    graph = lm_graph(cfg, batch=2, seq=1)
    planner = EdgentPlanner(graph, latency_req_s=0.5)
    planner.with_models(RooflineLatencyModel(chips=8, efficiency=0.4),
                        RooflineLatencyModel(chips=1, efficiency=0.4))
    return cfg, model, params, graph, planner


def test_engine_serves_and_meets_slo(engine_setup):
    cfg, model, params, graph, planner = engine_setup
    link = Link(trace_bps=dcn_trace(0, 512))
    eng = ServingEngine(model, params, graph, planner, link, batch_size=2,
                        dtype=jnp.float32)
    rs = np.random.default_rng(0)
    reqs = [Request(rid=i, prompt=rs.integers(0, cfg.vocab_size, 6).astype(np.int32),
                    max_new_tokens=4, slo_s=0.5) for i in range(4)]
    stats = eng.serve(reqs)
    s = stats.summary()
    assert s["requests"] == 4
    assert s["slo_attainment"] > 0.5
    assert all(len(t) == 4 for t in stats.tokens.values())
    assert all(1 <= e <= model.num_segments for e in stats.exits)


def test_engine_demotes_under_tight_slo(engine_setup):
    cfg, model, params, graph, planner = engine_setup
    link = Link(trace_bps=dcn_trace(0, 512))
    eng = ServingEngine(model, params, graph, planner, link, batch_size=2,
                        dtype=jnp.float32)
    rs = np.random.default_rng(1)
    tight = [Request(rid=i, prompt=rs.integers(0, cfg.vocab_size, 6).astype(np.int32),
                     max_new_tokens=4, slo_s=0.0) for i in range(2)]
    stats = eng.serve(tight)
    # infeasible SLO -> engine demotes to the earliest exit rather than hang
    assert stats.summary()["mean_exit"] == 1.0
    assert stats.summary()["slo_attainment"] == 0.0
