"""Optional-hypothesis shim for the property tests.

When hypothesis is installed (CI does this), re-export the real API.  When
it is not, export stand-ins: ``@given`` replaces the test with a skipped
zero-arg stub so the module still collects and its non-property tests run.
"""
import pytest

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

    def given(*_args, **_kwargs):
        def deco(fn):
            @pytest.mark.skip(reason="hypothesis not installed")
            def _skipped():
                pass
            _skipped.__name__ = fn.__name__
            _skipped.__doc__ = fn.__doc__
            return _skipped
        return deco

    def settings(*_args, **_kwargs):
        return lambda fn: fn

    class _AnyStrategy:
        """st.<anything>(...) placeholder; values are never drawn."""
        def __getattr__(self, _name):
            return lambda *a, **k: None

    st = _AnyStrategy()
