#!/usr/bin/env python
"""Intra-repo markdown link checker (CI docs job).

Scans every tracked ``*.md`` file for inline links/images and verifies that
relative targets resolve to an existing file or directory in the repo.
External schemes (http/https/mailto) and pure in-page anchors are skipped;
anchors on relative targets are stripped before the existence check.

Also asserts the docs index invariant: every ``docs/*.md`` page is
reachable from ``docs/README.md`` (ISSUE 3 acceptance criterion).

Exit code 0 = all links resolve; 1 = broken links (listed on stderr).

Run:  python tools/check_docs_links.py [root]
"""
from __future__ import annotations

import re
import sys
from pathlib import Path

# inline markdown links/images: [text](target) — tolerates titles after a
# space; reference-style links are not used in this repo
LINK_RE = re.compile(r"!?\[[^\]]*\]\(([^)\s]+)(?:\s+\"[^\"]*\")?\)")
SKIP_SCHEMES = ("http://", "https://", "mailto:", "ftp://")
SKIP_DIRS = {".git", ".github", "__pycache__", ".pytest_cache"}


def md_files(root: Path):
    for p in sorted(root.rglob("*.md")):
        if not SKIP_DIRS.intersection(p.relative_to(root).parts):
            yield p


def check_links(root: Path):
    broken = []
    for md in md_files(root):
        for m in LINK_RE.finditer(md.read_text(encoding="utf-8")):
            target = m.group(1)
            if target.startswith(SKIP_SCHEMES) or target.startswith("#"):
                continue
            path = target.split("#", 1)[0]
            if not path:
                continue
            resolved = (root / path.lstrip("/")) if path.startswith("/") \
                else (md.parent / path)
            if not resolved.exists():
                broken.append((md.relative_to(root), target))
    return broken


def check_docs_index(root: Path):
    """Every docs/*.md page must be linked from docs/README.md."""
    docs = root / "docs"
    index = docs / "README.md"
    missing = []
    if not index.exists():
        return [("docs/README.md", "<docs index missing>")]
    linked = {t.split("#", 1)[0] for t in LINK_RE.findall(
        index.read_text(encoding="utf-8"))}
    for page in sorted(docs.glob("*.md")):
        if page.name != "README.md" and page.name not in linked:
            missing.append((Path("docs/README.md"),
                            f"<no link to {page.name}>"))
    return missing


def main() -> int:
    root = Path(sys.argv[1]).resolve() if len(sys.argv) > 1 \
        else Path(__file__).resolve().parent.parent
    problems = check_links(root) + check_docs_index(root)
    n_files = len(list(md_files(root)))
    if problems:
        for md, target in problems:
            print(f"BROKEN  {md}: {target}", file=sys.stderr)
        print(f"{len(problems)} broken link(s) across {n_files} markdown "
              f"files", file=sys.stderr)
        return 1
    print(f"all intra-repo markdown links resolve ({n_files} files); "
          f"docs/README.md indexes every docs page")
    return 0


if __name__ == "__main__":
    sys.exit(main())
