"""Fig. 9: accuracy of four methods under different latency requirements at
400 kbps (negative accuracy == deadline missed, as the paper plots it)."""
from __future__ import annotations

from benchmarks.common import KBPS, alexnet_setup, set_slo
from repro.core.partitioner import branch_latency

METHODS = ("edgent", "partition_only", "edge_only", "device_only")


def run(emit):
    s = alexnet_setup()
    g, planner, acc = s["graph"], s["planner"], s["accuracy"]
    fe, fd = planner.f_edge, planner.f_device
    bw = 400 * KBPS
    n = len(g.branches[-1])
    out = {}
    for req_ms in (100, 200, 300, 400, 500, 700, 1000):
        slo = req_ms / 1e3
        set_slo(planner, slo)
        # edgent: joint optimization
        plan = planner.plan(bw)
        a_edgent = plan.accuracy if plan.feasible else -plan.accuracy
        # partition-only: full model, best partition
        from repro.core.partitioner import best_partition
        _, lat_part = best_partition(g, g.num_exits, fe, fd, bw)
        a_part = acc[-1] if lat_part <= slo else -acc[-1]
        # edge-only / device-only: full model one tier
        lat_edge = branch_latency(g, g.num_exits, n, fe, fd, bw)
        lat_dev = branch_latency(g, g.num_exits, 0, fe, fd, bw)
        a_edge = acc[-1] if lat_edge <= slo else -acc[-1]
        a_dev = acc[-1] if lat_dev <= slo else -acc[-1]
        vals = dict(zip(METHODS, (a_edgent, a_part, a_edge, a_dev)))
        out[req_ms] = vals
        for m, v in vals.items():
            emit(f"fig9_{m}_{req_ms}ms", 0.0, f"accuracy={v:+.3f}")
    set_slo(planner, 1.0)
    return out
