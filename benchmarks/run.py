# One function per paper table/figure. Prints ``name,us_per_call,derived`` CSV.
from __future__ import annotations

import json
import os
import sys
import traceback

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "results")

BENCHES = (
    "fig2_edge_vs_device",
    "fig3_layerwise",
    "table1_regression",
    "fig8_selection",
    "fig9_accuracy",
    "fig10_dynamic",
    "fig11_cdf",
    "roofline_report",
)


def main() -> None:
    os.makedirs(RESULTS_DIR, exist_ok=True)
    rows = []

    def emit(name: str, us_per_call: float, derived: str = ""):
        line = f"{name},{us_per_call:.1f},{derived}"
        rows.append(line)
        print(line, flush=True)

    print("name,us_per_call,derived")
    failures = []
    for mod_name in BENCHES:
        try:
            mod = __import__(f"benchmarks.{mod_name}", fromlist=["run"])
            mod.run(emit)
        except Exception as e:
            failures.append(mod_name)
            emit(f"{mod_name}_FAILED", 0.0, f"{type(e).__name__}: {e}")
            traceback.print_exc(file=sys.stderr)
    with open(os.path.join(RESULTS_DIR, "bench_rows.csv"), "w") as f:
        f.write("name,us_per_call,derived\n" + "\n".join(rows) + "\n")
    if failures:
        print(f"# FAILED benches: {failures}", file=sys.stderr)
        sys.exit(1)


if __name__ == "__main__":
    main()
