"""Fig. 8: (a) selection vs bandwidth at 1000ms SLO; (b) plan latency vs
bandwidth; (c) selection vs latency requirement at 500kbps."""
from __future__ import annotations

from benchmarks.common import KBPS, Timer, alexnet_setup, set_slo


def run(emit):
    s = alexnet_setup()
    planner = s["planner"]
    out = {"a": [], "c": []}

    set_slo(planner, 1.0)
    for kbps in (50, 100, 150, 250, 400, 500, 750, 1000, 1250, 1500):
        with Timer() as t:
            plan = planner.plan(kbps * KBPS)
        emit(f"fig8a_bw_{kbps}kbps", t.us,
             f"exit={plan.exit_point};partition={plan.partition};"
             f"latency_s={plan.latency_s:.4f};feasible={plan.feasible}")
        out["a"].append((kbps, plan.exit_point, plan.partition,
                         plan.latency_s, plan.feasible))

    for req_ms in (100, 200, 300, 400, 500, 700, 1000):
        set_slo(planner, req_ms / 1e3)
        plan = planner.plan(500 * KBPS)
        emit(f"fig8c_slo_{req_ms}ms", 0.0,
             f"exit={plan.exit_point};partition={plan.partition};"
             f"feasible={plan.feasible}")
        out["c"].append((req_ms, plan.exit_point, plan.partition, plan.feasible))
    set_slo(planner, 1.0)
    return out
