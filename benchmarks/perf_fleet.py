"""Fleet-engine throughput benchmark: events/sec and wall time vs fleet size.

Two cell families, both scaled from the registered smoke scenarios
(``repro.sim.registry``) so the measured path is exactly what the other
benchmarks and tests run:

* ``static``   — the ``smoke-lm`` fleet (diurnal arrivals, bandwidth-aware
  routing) at {100, 1k, 10k, 100k} devices.
* ``mobility`` — a ``smoke-mobility``-derived cell (random-waypoint motion,
  streaming tenants, nearest routing, BOCD handover) at the same sizes: the
  sampling + change-point + replan hot path.

Edges scale with the fleet (``max(4, devices // 100)``) so cells stay in the
serving regime rather than collapsing into one overload queue.  Cells at
>= 10k devices run geography-sharded (``repro.sim.shard``, ~500 devices
per tile): tile-scoped routing and sampling cut the per-event edge-scan
cost, and tiles fan out over worker processes where cores exist
(``--processes``; the recorded figures here are single-process).

An *event* is one unit of simulator work: one event-heap pop, where a
fleet-wide ``sample`` sweep counts once per device it observes (the engine
reports ``events_processed``; for engines predating that counter the
benchmark counts heap pops directly, which is equivalent there because those
engines schedule one heap event per device sample).

Results merge into ``BENCH_fleet.json`` at the repo root:

    python benchmarks/perf_fleet.py --record-baseline   # stamp "baseline"
    python benchmarks/perf_fleet.py                     # stamp "current"
    python benchmarks/perf_fleet.py --smoke             # 100-device CI cell

``current`` runs print and gate the speedup against the recorded baseline
(acceptance: >= 10x events/sec at 1k devices on the mobility family).  A
gate whose family/size cell is missing from either recording fails loudly
(exit 2) — a silent gate-pass on a missing cell is a measurement bug, not
a success.
"""
from __future__ import annotations

import argparse
import json
import time
from dataclasses import replace
from pathlib import Path

from repro.sim import Simulation, get_scenario

REPO_ROOT = Path(__file__).resolve().parent.parent
BENCH_PATH = REPO_ROOT / "BENCH_fleet.json"

SIZES = (100, 1000, 10000, 100000)
FAMILIES = ("static", "mobility")
GATE_FAMILY, GATE_SIZE, GATE_SPEEDUP = "mobility", 1000, 10.0
# devices per geography tile for sharded cells (>= SHARD_MIN_DEVICES)
SHARD_TILE_DEVICES = 500
SHARD_MIN_DEVICES = 10000
# the CI --smoke sharded cell: 100k devices, the cheaper (mobility) family
SMOKE_100K = ("mobility", 100000)


def calibrate() -> float:
    """Wall time of a fixed reference workload (Python-loop + small-numpy
    mix, the simulator's instruction profile).  Shared VMs drift by 2-3x
    within a session, so every recording stores its own ``calib_s`` and
    speedups compare *events per calibration unit*
    (``events_per_s * calib_s``), which cancels machine-speed drift between
    the baseline and current recordings."""
    import numpy as np
    t0 = time.perf_counter()
    x = np.arange(512, dtype=float)
    acc, heap = 0.0, []
    for i in range(4000):
        acc += float((x * 1.0001 + i).sum())
        heap.append((acc % 97.0, i))
        if len(heap) > 64:
            heap.sort()
            del heap[32:]
    return time.perf_counter() - t0


def _no_records(engine_spec):
    """retain_records=False when the engine spec supports it (engines
    predating the knob run with full retention — summaries are identical
    either way)."""
    try:
        return replace(engine_spec, retain_records=False)
    except TypeError:
        return engine_spec


def cell_shards(num_devices: int) -> int:
    """Geography tiles for one cell: ~``SHARD_TILE_DEVICES`` devices per
    tile at >= ``SHARD_MIN_DEVICES`` devices (1 = unsharded).  The tile
    count must divide devices and edges; sizes that don't split evenly
    stay unsharded."""
    if num_devices < SHARD_MIN_DEVICES:
        return 1
    k = num_devices // SHARD_TILE_DEVICES
    num_edges = max(4, num_devices // 100)
    while k > 1 and (num_devices % k or num_edges % k):
        k -= 1
    return k


def perf_spec(family: str, num_devices: int):
    """The benchmark cell at one fleet size: the registered smoke scenario
    rescaled (devices, proportional edges, geography shards at >= 10k
    devices; the mobility family also shortens the workload so big cells
    stay within CI budgets).  Record retention is off — summaries are
    bit-identical either way (pinned in tests/test_fleet_perf.py) and
    memory stays flat at 10k+ devices."""
    num_edges = max(4, num_devices // 100)
    shards = cell_shards(num_devices)
    if family == "static":
        base = get_scenario("smoke-lm")
        return replace(
            base, name=f"perf-static-{num_devices}",
            topology=replace(base.topology, num_devices=num_devices,
                             num_edges=num_edges, shards=shards),
            engine=_no_records(base.engine))
    base = get_scenario("smoke-mobility")
    return replace(
        base, name=f"perf-mobility-{num_devices}",
        topology=replace(base.topology, num_devices=num_devices,
                         num_edges=num_edges, shards=shards),
        workload=replace(base.workload, rate_per_device_hz=0.1,
                         horizon_s=20.0),
        engine=_no_records(base.engine))


def _count_events(engine, workload):
    """Run one simulation, returning (metrics, events, wall_s).  Engines
    that report ``events_processed`` are trusted; otherwise heap pops are
    counted via a thin EventQueue proxy (pre-refactor engines)."""
    import repro.fleet.engine as fe

    class _CountingQueue:
        def __init__(self, inner):
            self._inner = inner
            self.pops = 0

        def push(self, *a, **k):
            return self._inner.push(*a, **k)

        def pop(self):
            self.pops += 1
            return self._inner.pop()

        @property
        def now(self):
            return self._inner.now

        def __len__(self):
            return len(self._inner)

        def __bool__(self):
            return bool(self._inner)

    counters = []
    orig = fe.EventQueue

    def make():
        q = _CountingQueue(orig())
        counters.append(q)
        return q

    fe.EventQueue = make
    try:
        t0 = time.perf_counter()
        metrics = engine.run(workload)
        wall = time.perf_counter() - t0
    finally:
        fe.EventQueue = orig
    events = getattr(engine, "events_processed", None)
    if events is None:
        events = counters[-1].pops
    return metrics, int(events), wall


def run_cell(family: str, num_devices: int, *, profile: bool = False,
             processes: int = 1) -> dict:
    """One benchmark cell.  ``profile=True`` attaches a
    ``repro.obs.SimProfiler`` (per-event-kind wall time, heap peak, cache
    hit rates) and adds its report as the cell's ``profile`` block — gate
    runs stay observers-off so the measured path is the production one.
    Sharded cells (>= 10k devices) run tile-by-tile — across ``processes``
    workers when > 1 — and report the merged metrics; their ``wall_s``
    includes the per-tile builds (there is no separate build phase)."""
    spec = perf_spec(family, num_devices)
    if spec.topology.shards > 1:
        from repro.sim.shard import run_sharded_info
        t0 = time.perf_counter()
        metrics, info = run_sharded_info(
            spec, processes=processes if processes > 1 else None)
        wall = time.perf_counter() - t0
        s = metrics.summary()
        # the real worker count: run_sharded_info caps its pool at the tile
        # count and runs sequentially (no pool) when processes <= 1 —
        # recording the requested number here used to claim "processes": 1
        # for every pooled run
        workers = min(processes, spec.topology.shards) if processes > 1 else 1
        return {
            "devices": num_devices,
            "edges": spec.topology.num_edges,
            "shards": spec.topology.shards,
            "processes": workers,
            "requests": s["requests"],
            "events": info["events_processed"],
            "build_s": 0.0,
            "wall_s": round(wall, 3),
            "events_per_s": round(info["events_processed"]
                                  / max(wall, 1e-9), 1),
            "slo_attainment": s["slo_attainment"],
            "makespan_s": s["makespan_s"],
            "events_by_kind": info["event_counts"],
            "compactions": info["compactions"],
        }
    sim = Simulation(spec)
    t0 = time.perf_counter()
    sc = sim.build()
    build_s = time.perf_counter() - t0
    profiler = None
    if profile:
        from repro.obs import SimProfiler
        profiler = SimProfiler()
        profiler.build_s = build_s
        sc.engine.profiler = profiler
    metrics, events, wall = _count_events(sc.engine, sc.workload)
    s = metrics.summary()
    cell = {
        "devices": num_devices,
        "edges": spec.topology.num_edges,
        "requests": s["requests"],
        "events": events,
        "build_s": round(build_s, 3),
        "wall_s": round(wall, 3),
        "events_per_s": round(events / max(wall, 1e-9), 1),
        "slo_attainment": s["slo_attainment"],
        "makespan_s": s["makespan_s"],
    }
    counts = getattr(sc.engine, "event_counts", None)
    if counts is not None:
        cell["events_by_kind"] = dict(sorted(counts.items()))
    if profiler is not None:
        cell["profile"] = profiler.report(sc.engine)
    return cell


def _load() -> dict:
    if BENCH_PATH.exists():
        with open(BENCH_PATH) as f:
            return json.load(f)
    return {}


def main():
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--sizes", type=int, nargs="+", default=list(SIZES))
    ap.add_argument("--families", nargs="+", default=list(FAMILIES),
                    choices=FAMILIES)
    ap.add_argument("--smoke", action="store_true",
                    help="CI cells: 100-device cells plus the 100k-device "
                         "sharded mobility cell")
    ap.add_argument("--processes", type=int, default=1,
                    help="worker processes for sharded cells (1 = "
                         "sequential tiles in this process)")
    ap.add_argument("--record-baseline", action="store_true",
                    help="stamp results as the pre-optimization baseline")
    ap.add_argument("--no-gate", action="store_true",
                    help="measure without asserting the speedup gate")
    args = ap.parse_args()
    sizes = [100] if args.smoke else args.sizes

    key = "baseline" if args.record_baseline else "current"
    bench = _load()
    slot = bench.setdefault(key, {"cells": {}})
    print(f"fleet-engine throughput ({key}): sizes {sizes}")
    print(f"\n{'family':>10} {'devices':>8} {'edges':>6} {'requests':>9} "
          f"{'events':>9} {'wall':>8} {'events/s':>10}")
    cells_to_run = [(family, nd) for family in args.families
                    for nd in sizes]
    if args.smoke:
        # the CI sharded scale cell: 100k devices across geography tiles
        cells_to_run.append(SMOKE_100K)
    for family, nd in cells_to_run:
        # --smoke doubles as the CI observability cell: profile on
        # (per-kind wall time, cache hit rates) for unsharded cells; gate
        # runs stay observers-off so the measured path is the production
        # one (sharded cells report merged event/compaction counts instead).
        # The smoke sharded cell always exercises a real worker pool (4
        # processes unless more were requested) so the multiprocess merge
        # path is covered even when CI forgets --processes.
        procs = args.processes
        if args.smoke and (family, nd) == SMOKE_100K:
            procs = max(args.processes, 4)
        cell = run_cell(family, nd, profile=args.smoke and nd < 10000,
                        processes=procs)
        slot["cells"][f"{family}/{nd}"] = cell
        shard_tag = f"x{cell['shards']}" if cell.get("shards", 1) > 1 else ""
        print(f"{family:>10} {nd:>8} {cell['edges']:>6} "
              f"{cell['requests']:>9} {cell['events']:>9} "
              f"{cell['wall_s']:>7.2f}s {cell['events_per_s']:>10.0f} "
              f"{shard_tag}")
        prof = cell.get("profile")
        if prof:
            top = sorted(prof["events"].items(),
                         key=lambda kv: -kv[1]["wall_s"])[:3]
            hot = ", ".join(f"{k} {v['wall_pct']:.0f}%" for k, v in top)
            print(f"{'profile':>10} {'':>8} wall={prof['wall_s']:.2f}s "
                  f"peak_heap={prof['peak_heap']} [{hot}]")
    slot["recorded_unix"] = int(time.time())
    slot["calib_s"] = round(min(calibrate() for _ in range(3)), 4)
    with open(BENCH_PATH, "w") as f:
        json.dump(bench, f, indent=2, sort_keys=True)
    print(f"\nwrote {BENCH_PATH}  (calib_s={slot['calib_s']})")

    if key == "current" and "baseline" in bench:
        gate_key = f"{GATE_FAMILY}/{GATE_SIZE}"
        base = bench["baseline"]["cells"].get(gate_key)
        cur = bench["current"]["cells"].get(gate_key)
        if not base or not cur:
            # a missing gate cell must not read as a pass: fail loudly with
            # what each recording actually holds (--no-gate to measure only)
            missing = " and ".join(
                f"{slot_name!r} (has {sorted(bench[slot_name]['cells'])})"
                for slot_name, c in (("baseline", base), ("current", cur))
                if not c)
            msg = (f"perf gate: cell {gate_key!r} missing from {missing}; "
                   f"re-record with --sizes {GATE_SIZE} (and "
                   f"--record-baseline for the baseline slot) or pass "
                   f"--no-gate to skip gating")
            if args.no_gate:
                print(f"[no-gate] {msg}")
            else:
                raise SystemExit(msg)
        else:
            raw = cur["events_per_s"] / base["events_per_s"]
            # events per calibration unit: cancels machine-speed drift
            # between the two recordings (see calibrate())
            scale = slot["calib_s"] / bench["baseline"].get(
                "calib_s", slot["calib_s"])
            speedup = raw * scale
            bench["speedup_1k_mobility"] = round(speedup, 2)
            bench["speedup_1k_mobility_raw"] = round(raw, 2)
            with open(BENCH_PATH, "w") as f:
                json.dump(bench, f, indent=2, sort_keys=True)
            print(f"events/sec at {gate_key}: {base['events_per_s']:.0f} -> "
                  f"{cur['events_per_s']:.0f}  "
                  f"({raw:.1f}x raw, {speedup:.1f}x calibrated)")
            if not args.no_gate:
                assert speedup >= GATE_SPEEDUP, (
                    f"expected >= {GATE_SPEEDUP}x events/sec at {gate_key}, "
                    f"got {speedup:.1f}x")
                print(f"speedup gate (>= {GATE_SPEEDUP}x at {gate_key})  [ok]")


if __name__ == "__main__":
    main()
