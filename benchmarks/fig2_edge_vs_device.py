"""Fig. 2: edge-only vs device-only AlexNet latency under different
bandwidths (paper: device ~2s+; edge 0.123s @1Mbps rising to 2.317s @50kbps)."""
from __future__ import annotations

from benchmarks.common import KBPS, Timer, alexnet_setup
from repro.core.partitioner import branch_latency

BANDWIDTHS_KBPS = [50, 100, 250, 500, 1000]


def run(emit):
    s = alexnet_setup()
    g, planner = s["graph"], s["planner"]
    fe, fd = planner.f_edge, planner.f_device
    n = len(g.branches[-1])
    for kbps in BANDWIDTHS_KBPS:
        bw = kbps * KBPS
        with Timer() as t:
            edge = branch_latency(g, g.num_exits, n, fe, fd, bw)
            dev = branch_latency(g, g.num_exits, 0, fe, fd, bw)
        emit(f"fig2_edge_only_{kbps}kbps", t.us / 2,
             f"latency_s={edge:.4f}")
        emit(f"fig2_device_only_{kbps}kbps", t.us / 2,
             f"latency_s={dev:.4f}")
    return {"edge_1000kbps_s": branch_latency(g, g.num_exits, n, fe, fd, 1000 * KBPS),
            "device_s": branch_latency(g, g.num_exits, 0, fe, fd, 1000 * KBPS)}
