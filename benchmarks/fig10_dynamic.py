"""Fig. 10: Edgent under a dynamic (Belgium-LTE-like bus) bandwidth trace —
BOCD state detection driving the configuration-map lookup, with the
throughput and selections over time."""
from __future__ import annotations

import numpy as np

from benchmarks.common import Timer, alexnet_setup, set_slo
from repro.core.partitioner import branch_latency
from repro.data.bandwidth import belgium_lte_like, oboe_like_traces


def run(emit):
    s = alexnet_setup()
    planner = s["planner"]
    set_slo(planner, 1.0)
    # offline: 428 Oboe-like states (paper Sec. V-C)
    traces = oboe_like_traces(seed=0, num=428)
    with Timer() as t_map:
        planner.offline_dynamic([tr.tolist() for tr in traces])
    emit("fig10_config_map_build", t_map.us,
         f"states={len(planner.dynamic_opt.cmap)}")

    lte = belgium_lte_like(seed=3, length=400, transport="bus", hi_mbps=10.0)
    g, fe, fd = s["graph"], planner.f_edge, planner.f_device
    thr, exits, parts = [], [], []
    with Timer() as t_run:
        for b in lte:
            plan = planner.plan(b, dynamic=True)
            lat = branch_latency(g, plan.exit_point, plan.partition, fe, fd, b)
            thr.append(1.0 / lat)
            exits.append(plan.exit_point)
            parts.append(plan.partition)
    emit("fig10_online_step", t_run.us / len(lte),
         f"mean_thr_fps={np.mean(thr):.2f};transitions="
         f"{planner.dynamic_opt.transitions}")
    emit("fig10_exit_stability", 0.0,
         f"modal_exit={int(np.bincount(exits).argmax())};"
         f"exit_changes={int(np.sum(np.diff(exits) != 0))};"
         f"part_changes={int(np.sum(np.diff(parts) != 0))}")
    return {"throughput": thr, "exits": exits, "partitions": parts}
