"""Shared setup for the paper-reproduction benchmarks.

Builds the branchy AlexNet, trains it briefly on the synthetic CIFAR-like set
(so per-exit accuracies are *measured*, not assumed), profiles layers, and
arms the Edgent planner.  Cached across benchmark functions.
"""
from __future__ import annotations

import functools
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import EdgentPlanner, alexnet_graph
from repro.data.synthetic import cifar_like
from repro.models.alexnet import BranchyAlexNet, BranchyAlexNetConfig
from repro.optim.adamw import adamw_init, adamw_update

TRAIN_STEPS = int(os.environ.get("BENCH_TRAIN_STEPS", "120"))
BENCH_NOISE = float(os.environ.get("BENCH_NOISE", "1.2"))
KBPS = 125.0  # bytes/s per kbps


@functools.lru_cache(maxsize=1)
def alexnet_setup():
    net = BranchyAlexNet(BranchyAlexNetConfig())
    rng = jax.random.key(0)
    params = net.init(rng)

    # --- quick BranchyNet joint training on synthetic CIFAR
    opt = adamw_init(params)
    step = jax.jit(lambda p, o, x, y, r: _train_step(net, p, o, x, y, r))
    data_rng = np.random.default_rng(0)
    r = rng
    for i in range(TRAIN_STEPS):
        x, y = cifar_like(data_rng, 64, noise=BENCH_NOISE)
        r, sub = jax.random.split(r)
        params, opt, loss = step(params, opt, jnp.asarray(x), jnp.asarray(y), sub)

    # --- measured per-exit accuracy on held-out data
    xv, yv = cifar_like(np.random.default_rng(123), 512, noise=BENCH_NOISE)
    acc = [float(net.accuracy(params, jnp.asarray(xv), jnp.asarray(yv), i))
           for i in range(1, net.num_exits + 1)]

    graph = alexnet_graph(net, accuracy=acc)
    x1 = jnp.asarray(xv[:1])
    planner = EdgentPlanner(graph, latency_req_s=1.0).offline_static(params, x1)
    return dict(net=net, params=params, graph=graph, planner=planner,
                accuracy=acc, sample=x1)


def _train_step(net, params, opt, x, y, rng):
    loss, grads = jax.value_and_grad(net.loss)(params, (x, y), rng)
    params, opt = adamw_update(grads, opt, params, lr=1e-3, weight_decay=1e-4)
    return params, opt, loss


def set_slo(planner: EdgentPlanner, slo_s: float):
    planner.latency_req_s = slo_s
    planner.static_opt.latency_req_s = slo_s


class Timer:
    def __enter__(self):
        self.t0 = time.perf_counter()
        return self

    def __exit__(self, *a):
        self.s = time.perf_counter() - self.t0

    @property
    def us(self) -> float:
        return self.s * 1e6
