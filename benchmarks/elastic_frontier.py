"""Cost-vs-SLO frontier benchmark for elastic edges (docs/elastic.md).

Sweeps the registered ``elastic-diurnal`` scenario over autoscaler
provisioning knobs (``max_slots`` ceiling, ``up_backlog_s`` aggressiveness)
and prints the Pareto frontier of ``cost_usd`` (slot-hours billed at the
autoscaler's ``usd_per_slot_hour``) against ``slo_attainment`` — the
capacity-planning curve a fixed-capacity fleet cannot produce: every point
is a provisioning policy, non-dominated on (cheaper, better SLO).

Every cell is an independent ``repro.sim`` spec, so any row reproduces with
``python -m repro.sim --spec`` on its embedded spec; ``--jsonl`` /
``--frontier`` dump the raw rows and the frontier subset.  The same sweep
runs from the shell as::

    PYTHONPATH=src python -m repro.sim.sweep --scenario elastic-diurnal \\
        --grid autoscale.max_slots=[1,2,4,8,16] \\
        --grid autoscale.up_backlog_s=[0.25,1.0] \\
        --out sweep.jsonl --frontier frontier.jsonl

Run:  PYTHONPATH=src python benchmarks/elastic_frontier.py
      PYTHONPATH=src python benchmarks/elastic_frontier.py --smoke
"""
from __future__ import annotations

import argparse
import json

from repro.sim import get_scenario
from repro.sim.sweep import grid_cells, pareto_frontier, run_sweep

MAX_SLOTS = (1, 2, 4, 8, 16)
UP_BACKLOG_S = (0.25, 1.0)
SMOKE_MAX_SLOTS = (1, 4, 16)     # --smoke: 3 cells, still >= 3 frontier pts


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--smoke", action="store_true",
                    help="3-cell grid on the shorter elastic-smoke scenario "
                         "(the CI leg)")
    ap.add_argument("--processes", type=int, default=1,
                    help="worker processes across cells (1 = inline)")
    ap.add_argument("--jsonl", metavar="FILE",
                    help="stream all {spec, metrics} rows to a JSONL file")
    ap.add_argument("--frontier", metavar="FILE",
                    help="write the non-dominated rows to a JSONL file")
    args = ap.parse_args()

    if args.smoke:
        base = get_scenario("elastic-smoke")
        axes = {"autoscale.max_slots": list(SMOKE_MAX_SLOTS)}
    else:
        base = get_scenario("elastic-diurnal")
        axes = {"autoscale.max_slots": list(MAX_SLOTS),
                "autoscale.up_backlog_s": list(UP_BACKLOG_S)}
    cells = grid_cells(base, axes)
    rows = run_sweep(cells, out_path=args.jsonl, processes=args.processes)
    front = pareto_frontier(rows)

    hdr = (f"{'max_slots':>9} {'up_blg_s':>8} {'cost_usd':>9} "
           f"{'slo':>7} {'reject%':>8} {'scales':>6} {'front':>5}")
    print(f"\n{base.name}: cost-vs-SLO frontier over "
          f"{len(rows)} provisioning cells")
    print(hdr)
    print("-" * len(hdr))
    front_ids = {id(r) for r in front}
    for r in rows:
        a, m = r["spec"]["autoscale"], r["metrics"]
        print(f"{a['max_slots']:>9} {a['up_backlog_s']:>8.2f} "
              f"{m['cost_usd']:>9.4f} {m['slo_attainment']:>7.4f} "
              f"{100 * m['reject_rate']:>7.2f}% {m['scale_events']:>6} "
              f"{'  *' if id(r) in front_ids else '':>5}")
    print(f"\n{len(front)} non-dominated points "
          f"(* above, sorted output in --frontier)")
    if args.frontier:
        with open(args.frontier, "w") as f:
            for r in front:
                f.write(json.dumps(r, sort_keys=True, default=float) + "\n")
        print(f"frontier -> {args.frontier}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
