"""Fig. 11: CDF of throughput and reward — static vs dynamic configurator
under the dynamic bandwidth environment (paper: dynamic dominates)."""
from __future__ import annotations

import numpy as np

from benchmarks.common import alexnet_setup, set_slo
from repro.core.config_map import reward_fn
from repro.core.partitioner import branch_latency
from repro.data.bandwidth import belgium_lte_like, oboe_like_traces


def run(emit):
    s = alexnet_setup()
    planner = s["planner"]
    set_slo(planner, 1.0)
    if planner.dynamic_opt is None:
        traces = oboe_like_traces(seed=0, num=428)
        planner.offline_dynamic([tr.tolist() for tr in traces])
    g, fe, fd = s["graph"], planner.f_edge, planner.f_device
    lte = belgium_lte_like(seed=7, length=400, transport="bus", hi_mbps=10.0)

    rows = {"static": {"thr": [], "rew": []}, "dynamic": {"thr": [], "rew": []}}
    for b in lte:
        for mode, dyn in (("static", False), ("dynamic", True)):
            p = planner.plan(b, dynamic=dyn)
            lat = branch_latency(g, p.exit_point, p.partition, fe, fd, b)
            rows[mode]["thr"].append(1.0 / lat)
            rows[mode]["rew"].append(reward_fn(p.accuracy, lat, 1.0))
    for mode in rows:
        thr = np.asarray(rows[mode]["thr"])
        rew = np.asarray(rows[mode]["rew"])
        emit(f"fig11_{mode}_throughput", 0.0,
             f"p50={np.percentile(thr, 50):.2f};p10={np.percentile(thr, 10):.2f}")
        emit(f"fig11_{mode}_reward", 0.0,
             f"p50={np.percentile(rew, 50):.2f};mean={rew.mean():.2f}")
    adv = np.mean(rows["dynamic"]["thr"]) / max(np.mean(rows["static"]["thr"]), 1e-9)
    emit("fig11_dynamic_advantage", 0.0, f"thr_ratio={adv:.3f}")
    return rows
