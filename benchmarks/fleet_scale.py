"""Fleet-scale serving benchmark: SLO attainment vs. fleet size and router
policy under a skewed diurnal workload on heterogeneous edges.

Every table is a ``repro.sim.sweep`` over the registered ``smoke-lm`` /
``smoke-mobility`` specs (docs/api.md): the sweep axes edit the spec
(devices, router, speed, policy), each cell is an independent fully-
specified scenario, and the same seed always reproduces identical numbers —
the benchmark re-runs one cell to prove it.  ``--jsonl`` dumps the raw
``{spec, metrics}`` rows; ``--processes`` fans cells out over workers
(neither changes any number).

Run:  PYTHONPATH=src python benchmarks/fleet_scale.py
      PYTHONPATH=src python benchmarks/fleet_scale.py --coop
      PYTHONPATH=src python benchmarks/fleet_scale.py --mobility
"""
from __future__ import annotations

import argparse

from repro.sim import apply_overrides, get_scenario
from repro.sim.sweep import grid_cells, run_cell, run_sweep

# single source of truth: the registered smoke specs (repro.sim.registry)
_LM = get_scenario("smoke-lm")
_MOB = get_scenario("smoke-mobility")

ROUTERS = ("round-robin", "jsq", "bandwidth-aware")
NUM_EDGES = _LM.topology.num_edges
RATE_PER_DEVICE_HZ = _LM.workload.rate_per_device_hz
HORIZON_S = _LM.workload.horizon_s
SEED = _LM.seed

# ---- mobility sweep (--mobility): long-lived streaming requests, so the
# wireless link is exercised every decode round and a device walking away
# from its serving edge genuinely degrades in-flight work (docs/handover.md)
MOBILITY_POLICIES = ("none", "oracle", "bocd")
MOBILITY_SPEEDS = (0.0, 0.1, 0.25, 0.5)     # area units / s
MOBILITY_DEVICES = 48
MOBILITY_RATE_HZ = _MOB.workload.rate_per_device_hz
MOBILITY_HORIZON_S = _MOB.workload.horizon_s
SMOKE_DEVICES = _LM.topology.num_devices     # 40: the registered smoke cells


def _sweep(base, fixed, axes, args):
    """Expand (base + fixed overrides) x axes and run the sweep; rows come
    back in grid order (last axis fastest)."""
    cells = grid_cells(apply_overrides(base, fixed), axes)
    return run_sweep(cells, out_path=args.jsonl, processes=args.processes)


def lm_cell_spec(num_devices: int, router: str, *, seed: int = SEED):
    """One static-fleet cell: the smoke-lm spec at (devices, router)."""
    return apply_overrides(get_scenario("smoke-lm"),
                           {"seed": seed, "topology.num_devices": num_devices,
                            "router.name": router})


def run_coop(args):
    """--coop: cooperative multi-edge joint planning vs single-edge
    bandwidth-aware routing, SLO attainment per fleet size.  The acceptance
    gate: joint >= bandwidth-aware at 100 devices on the default seed."""
    sizes = [SMOKE_DEVICES] if args.smoke else args.sizes
    routers = ("bandwidth-aware", "joint")
    print(f"cooperative multi-edge planning: {NUM_EDGES} edges (speed "
          f"1x..4x), diurnal arrivals @ {RATE_PER_DEVICE_HZ}/device/s, "
          f"horizon {HORIZON_S}s, seed {args.seed}")
    rows = _sweep(get_scenario("smoke-lm"), {"seed": args.seed},
                  {"topology.num_devices": sizes, "router.name": routers},
                  args)
    cell = {(r["spec"]["topology"]["num_devices"], r["spec"]["router"]["name"]):
            r for r in rows}
    print(f"\n{'devices':>8} | " +
          " | ".join(f"{r:>16}" for r in routers) +
          " |     coop share    (SLO attainment)")
    print("-" * (16 + 19 * len(routers) + 16))
    gate = None
    for nd in sizes:
        joint = cell[(nd, "joint")]["metrics"]
        share = joint["coop_requests"] / max(joint["requests"], 1)
        print(f"{nd:>8} | " + " | ".join(
            f"{cell[(nd, r)]['metrics']['slo_attainment']:>9.4f} "
            f"{cell[(nd, r)]['wall_s']:5.1f}s"
            for r in routers) +
            f" |   {share:>6.3f}  ({joint['requests']} requests, "
            f"{joint['backbone_mb']:.3f} MB backbone)")
        if nd == 100:
            gate = (cell[(nd, "bandwidth-aware")]["metrics"]
                    ["slo_attainment"], joint["slo_attainment"])

    # ---- determinism: same seed -> bit-identical summary
    a = cell[(sizes[0], "joint")]["metrics"]
    b = run_cell(lm_cell_spec(sizes[0], "joint", seed=args.seed))["metrics"]
    assert a == b, "same seed must reproduce identical metrics"
    print("\ndeterminism check: identical summaries on re-run  [ok]")
    if gate is not None and args.seed == SEED:
        bw_slo, joint_slo = gate
        print(f"joint vs bandwidth-aware @ 100 devices: "
              f"{joint_slo:.4f} vs {bw_slo:.4f} ({joint_slo - bw_slo:+.4f})")
        assert joint_slo >= bw_slo, \
            "joint multi-edge planning must not lose to single-edge routing"


def mobility_cell_spec(nd: int, speed: float, policy: str, *, seed: int):
    """One deterministic mobility cell: ``nd`` devices random-waypoint
    walking at ``speed`` over a 4-edge geography, nearest-edge routing, the
    given handover policy driving mid-request migration."""
    return apply_overrides(get_scenario("smoke-mobility"),
                           {"seed": seed + 1, "topology.num_devices": nd,
                            "topology.speed": speed,
                            "mobility.policy": policy})


def run_mobility(args):
    """--mobility: the paper's static-vs-dynamic comparison at fleet scale.
    {no-handover, oracle-replan, BOCD-replan} x mobility speed; the
    acceptance gate requires BOCD >= no-handover at every speed with the
    gap widening as devices move faster."""
    nd = _MOB.topology.num_devices if args.smoke else MOBILITY_DEVICES
    speeds = [_MOB.topology.speed] if args.smoke else list(args.speeds)
    print(f"mobility-aware handover: {nd} devices random-waypoint over a "
          f"{NUM_EDGES}-edge geography, streaming tenants @ "
          f"{MOBILITY_RATE_HZ}/device/s, horizon {MOBILITY_HORIZON_S}s, "
          f"seed {args.seed}")
    rows = _sweep(get_scenario("smoke-mobility"),
                  {"seed": args.seed + 1, "topology.num_devices": nd},
                  {"topology.speed": speeds,
                   "mobility.policy": list(MOBILITY_POLICIES)},
                  args)
    cell = {(r["spec"]["topology"]["speed"], r["spec"]["mobility"]["policy"]):
            r["metrics"] for r in rows}
    print(f"\n{'speed':>6} | " +
          " | ".join(f"{p:>10}" for p in MOBILITY_POLICIES) +
          " |  bocd-none |  handovers  migrated   (SLO attainment)")
    print("-" * (10 + 13 * len(MOBILITY_POLICIES) + 40))
    gaps = []
    for speed in speeds:
        bocd, none = cell[(speed, "bocd")], cell[(speed, "none")]
        gap = bocd["slo_attainment"] - none["slo_attainment"]
        gaps.append((speed, gap, bocd, none))
        print(f"{speed:>6.2f} | " + " | ".join(
            f"{cell[(speed, p)]['slo_attainment']:>10.4f}"
            for p in MOBILITY_POLICIES) +
            f" |   {gap:>+7.4f} | {bocd['handovers']:>9d}  "
            f"{bocd['migrated_mb']:>6.3f}MB  "
            f"({bocd['requests']} requests)")

    # ---- determinism: same seed -> bit-identical summary (the sweep
    # already computed this cell once; one re-run suffices)
    a = gaps[-1][2]
    b = run_cell(mobility_cell_spec(nd, speeds[-1], "bocd",
                                    seed=args.seed))["metrics"]
    assert a == b, "same seed must reproduce identical metrics"
    print("\ndeterminism check: identical summaries on re-run  [ok]")

    for speed, gap, _, _ in gaps:
        assert gap >= 0.0, \
            f"BOCD-replan must not lose to no-handover (speed {speed})"
    print("BOCD-replan >= no-handover at every mobility speed  [ok]")
    if args.seed == SEED and not args.smoke and \
            list(args.speeds) == list(MOBILITY_SPEEDS):
        # the default configuration is a regression gate: the benefit of
        # handover must grow with mobility (static devices gain ~nothing,
        # fast movers gain the most)
        assert all(g1 <= g2 + 1e-12 for (_, g1, _, _), (_, g2, _, _)
                   in zip(gaps, gaps[1:])), \
            "the BOCD-vs-none gap must widen as mobility increases"
        assert gaps[-1][1] > gaps[0][1], \
            "fast movers must gain more from handover than static devices"
        print(f"gap widens with mobility: "
              f"{[round(g, 4) for _, g, _, _ in gaps]}  [ok]")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--sizes", type=int, nargs="+", default=[100, 200, 400])
    ap.add_argument("--speeds", type=float, nargs="+",
                    default=list(MOBILITY_SPEEDS),
                    help="mobility sweep speeds (area units / s)")
    ap.add_argument("--seed", type=int, default=SEED)
    ap.add_argument("--coop", action="store_true",
                    help="joint multi-edge planning vs bandwidth-aware")
    ap.add_argument("--mobility", action="store_true",
                    help="handover policies vs mobility speed")
    ap.add_argument("--smoke", action="store_true",
                    help="small fleet only (CI artifact)")
    ap.add_argument("--jsonl", metavar="FILE", default=None,
                    help="also write the sweep rows as JSONL")
    ap.add_argument("--processes", type=int, default=1,
                    help="worker processes across sweep cells")
    args = ap.parse_args()
    if args.coop:
        run_coop(args)
        return
    if args.mobility:
        run_mobility(args)
        return

    print(f"fleet-scale serving: {NUM_EDGES} edges (speed 1x..4x), diurnal "
          f"arrivals @ {RATE_PER_DEVICE_HZ}/device/s, horizon {HORIZON_S}s, "
          f"seed {args.seed}")
    rows = _sweep(get_scenario("smoke-lm"), {"seed": args.seed},
                  {"topology.num_devices": args.sizes,
                   "router.name": list(ROUTERS)}, args)
    cell = {(r["spec"]["topology"]["num_devices"], r["spec"]["router"]["name"]):
            r for r in rows}
    print(f"\n{'devices':>8} | " +
          " | ".join(f"{r:>16}" for r in ROUTERS) + " |   (SLO attainment)")
    print("-" * (12 + 19 * len(ROUTERS)))
    last, best_gap = {}, (None, -1.0)
    for nd in args.sizes:
        row = [(router, cell[(nd, router)]["metrics"],
                cell[(nd, router)]["wall_s"]) for router in ROUTERS]
        for router, s, _ in row:
            last[router] = s
        rr_cell = row[0][1]["slo_attainment"]
        for router, s, _ in row[1:]:
            gap = s["slo_attainment"] - rr_cell
            if gap > best_gap[1]:
                best_gap = (f"{router} @ {nd} devices", gap)
        print(f"{nd:>8} | " + " | ".join(
            f"{s['slo_attainment']:>9.4f} {dt:5.1f}s" for _, s, dt in row) +
            f" |   ({row[0][1]['requests']} requests)")

    # ---- detail for the largest fleet
    print("\nlargest fleet, per router:")
    for router, s in last.items():
        print(f"  {router:>16}: p50={s['p50_latency_s']*1e3:7.1f}ms "
              f"p99={s['p99_latency_s']:6.2f}s "
              f"queue_delay={s['mean_queue_delay_s']*1e3:7.1f}ms "
              f"util={list(s['edge_utilization'].values())}")
    print(f"  tenants (bandwidth-aware): {last['bandwidth-aware']['slo_by_tenant']}")
    print(f"  exits: {last['bandwidth-aware']['exit_histogram']}  "
          f"partitions: {last['bandwidth-aware']['partition_histogram']}")

    # ---- determinism: same seed -> bit-identical summary
    a = cell[(args.sizes[0], "jsq")]["metrics"]
    b = run_cell(lm_cell_spec(args.sizes[0], "jsq", seed=args.seed))["metrics"]
    assert a == b, "same seed must reproduce identical metrics"
    print("\ndeterminism check: identical summaries on re-run  [ok]")

    print(f"largest gain over round-robin: {best_gap[0]} ({best_gap[1]:+.4f})")
    if args.sizes == [100, 200, 400] and args.seed == SEED:
        # the default configuration is a regression gate; custom sweeps may
        # legitimately sit below the knee where routing policy matters
        assert best_gap[1] > 0.02, \
            "expected an adaptive policy to measurably beat round-robin"


if __name__ == "__main__":
    main()
