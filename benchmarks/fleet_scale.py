"""Fleet-scale serving benchmark: SLO attainment vs. fleet size and router
policy under a skewed diurnal workload on heterogeneous edges.

Each cell is a deterministic virtual-time simulation (``repro.fleet``):
N devices with independent bandwidth traces and per-device slowdowns, M
edges with a 4x speed spread, continuous batching per edge, Edgent planning
per device (shared plan cache).  The same seed always reproduces identical
numbers — the benchmark re-runs one cell to prove it.

Run:  PYTHONPATH=src python benchmarks/fleet_scale.py
"""
from __future__ import annotations

import argparse
import time

from repro.fleet import FleetEngine, make_fleet, make_workload, smoke_lm_scenario

ROUTERS = ("round-robin", "jsq", "bandwidth-aware")
NUM_EDGES = 4
RATE_PER_DEVICE_HZ = 1.2
HORIZON_S = 30.0
SEED = 2


def run_cell(graph, planner, num_devices: int, router: str, *,
             seed: int = SEED, rate_hz: float | None = None) -> dict:
    topo = make_fleet(num_devices, NUM_EDGES, seed=seed, edge_capacity=8,
                      lo_mbps=0.1, hi_mbps=6.0, max_edge_slowdown=4.0)
    wl = make_workload(num_devices,
                       rate_hz=rate_hz if rate_hz is not None
                       else RATE_PER_DEVICE_HZ * num_devices,
                       horizon_s=HORIZON_S, seed=seed + 1,
                       arrival="diurnal", device_skew=1.0)
    eng = FleetEngine(topo, graph, planner, router=router)
    return eng.run(wl).summary()


def run_coop(args):
    """--coop: cooperative multi-edge joint planning vs single-edge
    bandwidth-aware routing, SLO attainment per fleet size.  The acceptance
    gate: joint >= bandwidth-aware at 100 devices on the default seed."""
    _, graph, planner = smoke_lm_scenario()
    sizes = [40] if args.smoke else args.sizes
    routers = ("bandwidth-aware", "joint")
    print(f"cooperative multi-edge planning: {NUM_EDGES} edges (speed "
          f"1x..4x), diurnal arrivals @ {RATE_PER_DEVICE_HZ}/device/s, "
          f"horizon {HORIZON_S}s, seed {args.seed}")
    print(f"\n{'devices':>8} | " +
          " | ".join(f"{r:>16}" for r in routers) +
          " |     coop share    (SLO attainment)")
    print("-" * (16 + 19 * len(routers) + 16))
    gate = None
    for nd in sizes:
        row = {}
        for router in routers:
            t0 = time.perf_counter()
            row[router] = (run_cell(graph, planner, nd, router,
                                    seed=args.seed),
                           time.perf_counter() - t0)
        joint = row["joint"][0]
        share = joint["coop_requests"] / max(joint["requests"], 1)
        print(f"{nd:>8} | " + " | ".join(
            f"{row[r][0]['slo_attainment']:>9.4f} {row[r][1]:5.1f}s"
            for r in routers) +
            f" |   {share:>6.3f}  ({joint['requests']} requests, "
            f"{joint['backbone_mb']:.3f} MB backbone)")
        if nd == 100:
            gate = (row["bandwidth-aware"][0]["slo_attainment"],
                    joint["slo_attainment"])

    # ---- determinism: same seed -> bit-identical summary
    a = run_cell(graph, planner, sizes[0], "joint", seed=args.seed)
    b = run_cell(graph, planner, sizes[0], "joint", seed=args.seed)
    assert a == b, "same seed must reproduce identical metrics"
    print("\ndeterminism check: identical summaries on re-run  [ok]")
    if gate is not None and args.seed == SEED:
        bw_slo, joint_slo = gate
        print(f"joint vs bandwidth-aware @ 100 devices: "
              f"{joint_slo:.4f} vs {bw_slo:.4f} ({joint_slo - bw_slo:+.4f})")
        assert joint_slo >= bw_slo, \
            "joint multi-edge planning must not lose to single-edge routing"


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--sizes", type=int, nargs="+", default=[100, 200, 400])
    ap.add_argument("--seed", type=int, default=SEED)
    ap.add_argument("--coop", action="store_true",
                    help="joint multi-edge planning vs bandwidth-aware")
    ap.add_argument("--smoke", action="store_true",
                    help="small fleet only (CI artifact)")
    args = ap.parse_args()
    if args.coop:
        run_coop(args)
        return

    _, graph, planner = smoke_lm_scenario()

    print(f"fleet-scale serving: {NUM_EDGES} edges (speed 1x..4x), diurnal "
          f"arrivals @ {RATE_PER_DEVICE_HZ}/device/s, horizon {HORIZON_S}s, "
          f"seed {args.seed}")
    print(f"\n{'devices':>8} | " +
          " | ".join(f"{r:>16}" for r in ROUTERS) + " |   (SLO attainment)")
    print("-" * (12 + 19 * len(ROUTERS)))
    last, best_gap = {}, (None, -1.0)
    for nd in args.sizes:
        row = []
        for router in ROUTERS:
            t0 = time.perf_counter()
            s = run_cell(graph, planner, nd, router, seed=args.seed)
            row.append((router, s, time.perf_counter() - t0))
            last[router] = s
        rr_cell = row[0][1]["slo_attainment"]
        for router, s, _ in row[1:]:
            gap = s["slo_attainment"] - rr_cell
            if gap > best_gap[1]:
                best_gap = (f"{router} @ {nd} devices", gap)
        print(f"{nd:>8} | " + " | ".join(
            f"{s['slo_attainment']:>9.4f} {dt:5.1f}s" for _, s, dt in row) +
            f" |   ({row[0][1]['requests']} requests)")

    # ---- detail for the largest fleet
    print("\nlargest fleet, per router:")
    for router, s in last.items():
        print(f"  {router:>16}: p50={s['p50_latency_s']*1e3:7.1f}ms "
              f"p99={s['p99_latency_s']:6.2f}s "
              f"queue_delay={s['mean_queue_delay_s']*1e3:7.1f}ms "
              f"util={list(s['edge_utilization'].values())}")
    print(f"  tenants (bandwidth-aware): {last['bandwidth-aware']['slo_by_tenant']}")
    print(f"  exits: {last['bandwidth-aware']['exit_histogram']}  "
          f"partitions: {last['bandwidth-aware']['partition_histogram']}")

    # ---- determinism: same seed -> bit-identical summary
    a = run_cell(graph, planner, args.sizes[0], "jsq", seed=args.seed)
    b = run_cell(graph, planner, args.sizes[0], "jsq", seed=args.seed)
    assert a == b, "same seed must reproduce identical metrics"
    print("\ndeterminism check: identical summaries on re-run  [ok]")

    print(f"largest gain over round-robin: {best_gap[0]} ({best_gap[1]:+.4f})")
    if args.sizes == [100, 200, 400] and args.seed == SEED:
        # the default configuration is a regression gate; custom sweeps may
        # legitimately sit below the knee where routing policy matters
        assert best_gap[1] > 0.02, \
            "expected an adaptive policy to measurably beat round-robin"


if __name__ == "__main__":
    main()
