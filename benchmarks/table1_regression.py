"""Table I: per-layer-type latency regression quality (R^2 per type) and
predicted-vs-measured check (paper Fig. 8b: curves nearly overlap)."""
from __future__ import annotations

import numpy as np

from benchmarks.common import alexnet_setup
from repro.core.profiler import profile_all_branches


def run(emit):
    s = alexnet_setup()
    planner = s["planner"]
    r2 = planner.f_edge.r2()
    for kind, v in sorted(r2.items()):
        emit(f"table1_r2_{kind}", 0.0, f"r2={v:.4f}")
    # predicted vs measured total (edge tier, host scale)
    profiles = profile_all_branches(s["graph"], s["params"], s["sample"])
    meas = sum(p.latency_s for p in profiles if not p.name.startswith("b"))
    pred = sum(planner.f_edge.predict(l) for l in s["graph"].branches[-1])
    ratio = pred / (meas * planner.edge_factor)
    emit("table1_pred_vs_measured", meas * 1e6, f"ratio={ratio:.3f}")
    return {"r2": r2, "pred_over_measured": ratio}
