"""Fig. 3: AlexNet layer-wise runtime and output data size (the
heterogeneity that motivates partitioning)."""
from __future__ import annotations

import jax

from benchmarks.common import Timer, alexnet_setup
from repro.core.profiler import profile_graph


def run(emit):
    s = alexnet_setup()
    profiles = profile_graph(s["graph"], s["params"], s["sample"])
    out = {}
    for p in profiles:
        emit(f"fig3_layer_{p.name}", p.latency_s * 1e6,
             f"out_bytes={p.out_bytes}")
        out[p.name] = (p.latency_s, p.out_bytes)
    # the paper's observation: latency rank != output-size rank
    lat_rank = sorted(out, key=lambda k: -out[k][0])[:5]
    size_rank = sorted(out, key=lambda k: -out[k][1])[:5]
    emit("fig3_heterogeneity", 0.0,
         f"top_latency={lat_rank[0]};top_size={size_rank[0]};"
         f"distinct={lat_rank[0] != size_rank[0]}")
    return out
