"""Real-decode throughput benchmark: serial vs batched vs arena tokens/s.

One mixed-exit, mixed-geometry real-decode fleet (two tenant classes with
different token budgets => different KV-cache geometries; deadline
demotion on => exits mix mid-stream) runs through each of the engine's
three decode strategies:

* ``serial``  — one compiled call per request per token (pre-PR-9);
* ``batched`` — per-round vmap groups, host-side restack + pad by
  replication, one compiled variant per (exit, batch bucket)  (PR 9);
* ``arena``   — slot-resident decode arena, one masked full-arena call
  per model exit per round, no restacking, no pad rows.

Every path is warmed up with one full run (all compiles land), then the
same engine re-runs the same workload and only that second run is timed —
tokens/s compares steady-state decode, not compile time.  Token streams
are asserted identical across all three paths before anything is
recorded: a throughput number for a wrong decode is not a result.

Results merge into ``BENCH_decode.json`` at the repo root:

    python benchmarks/perf_decode.py            # full cell + gates
    python benchmarks/perf_decode.py --smoke    # CI cell (same shape,
                                                #   shorter horizon)

Gates (``--no-gate`` to measure only):

* arena >= 1.5x batched tokens/s;
* arena compiled variants <= one per model exit;
* zero padded rows on the arena path.
"""
from __future__ import annotations

import argparse
import json
import time
from dataclasses import replace
from pathlib import Path

from repro.sim import (EngineSpec, RouterSpec, ScenarioSpec, Simulation,
                       TopologySpec, WorkloadSpec)

REPO_ROOT = Path(__file__).resolve().parent.parent
BENCH_PATH = REPO_ROOT / "BENCH_decode.json"

PATHS = ("serial", "batched", "arena")
GATE_ARENA_SPEEDUP = 1.5
TIMED_RUNS = 2


def decode_spec(path: str, *, smoke: bool) -> ScenarioSpec:
    """The benchmark cell: a static LTE fleet whose co-located requests mix
    exit points (tight interactive SLO + deadline demotion) and cache
    geometries (two token budgets), decoded via ``path``."""
    from repro.fleet.workload import TenantClass
    tenants = (TenantClass("interactive", slo_s=0.8, max_new_tokens=12,
                           weight=0.5),
               TenantClass("standard", slo_s=3.0, max_new_tokens=24,
                           weight=0.5))
    # edge_capacity 4 with an oversubscribed arrival rate keeps the decode
    # queues saturated, so the arena runs near-full occupancy (its slot
    # count is the pow2 bucket of edge capacity) instead of masking most
    # of its rows — the regime the arena is built for.
    return ScenarioSpec(
        name=f"perf-decode-{path}", seed=3,
        topology=TopologySpec(num_devices=8, num_edges=2, trace="lte",
                              edge_capacity=4, max_edge_slowdown=2.0),
        workload=WorkloadSpec(rate_hz=32.0 if smoke else 48.0,
                              horizon_s=4.0 if smoke else 8.0,
                              device_skew=0.5, prompt_len=6,
                              tenants=tenants),
        router=RouterSpec(name="bandwidth-aware"),
        engine=EngineSpec(real_decode=True, demote_on_deadline=True,
                          batch_decode=(path == "batched"),
                          arena_decode=(path == "arena"),
                          retain_records=False))


def run_cells(*, smoke: bool) -> tuple:
    """One warm-up run per path (compiles land), then ``TIMED_RUNS``
    timed replays with the three paths interleaved — serial, batched,
    arena, serial, ... — so a slow host window degrades every path's
    sample, not one path's entire measurement; each path keeps its
    fastest replay.  Returns the cell dicts plus the token streams for
    the cross-path identity check."""
    scs, st0, walls, metrics = {}, {}, {}, {}
    for path in PATHS:
        sc = Simulation(decode_spec(path, smoke=smoke)).build()
        sc.engine.run(sc.workload)                   # warm-up: compile
        scs[path] = sc
        st0[path] = sc.engine.stepper.cache_stats()
        walls[path] = []
    for _ in range(TIMED_RUNS):
        for path in PATHS:
            sc = scs[path]
            t0 = time.perf_counter()
            metrics[path] = sc.engine.run(sc.workload)
            walls[path].append(time.perf_counter() - t0)
    cells, streams = {}, {}
    for path in PATHS:
        sc = scs[path]
        st1 = sc.engine.stepper.cache_stats()
        wall = min(walls[path])
        tokens = sum(len(r.tokens) for r in sc.workload)
        streams[path] = {r.rid: list(r.tokens) for r in sc.workload}
        cell = {
            "requests": metrics[path].summary()["requests"],
            "tokens": tokens,
            "wall_s": round(wall, 3),
            "tokens_per_s": round(tokens / max(wall, 1e-9), 1),
            "timed_run_compiles":
                st1["jit"]["misses"] - st0[path]["jit"]["misses"],
            "jit_variants": st1["jit"]["variants"],
            # counter deltas span all timed replays; the replays are
            # deterministic, so dividing recovers the per-run counts
            "decode": {
                k: (st1["decode"][k] - st0[path]["decode"][k]) // TIMED_RUNS
                for k in ("batched_calls", "batched_tokens",
                          "padded_rows", "serial_tokens")},
            "arena": {
                k: (st1["arena"][k] - st0[path]["arena"][k]) // TIMED_RUNS
                for k in ("calls", "tokens", "masked_rows", "admits",
                          "evicts", "grows")},
        }
        ar = cell["arena"]
        den = ar["tokens"] + ar["masked_rows"]
        cell["arena"]["occupancy"] = \
            round(ar["tokens"] / den, 4) if den else None
        cells[path] = cell
    return cells, streams


def main():
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--smoke", action="store_true",
                    help="CI cell: same fleet shape, shorter horizon")
    ap.add_argument("--no-gate", action="store_true",
                    help="measure without asserting the gates")
    args = ap.parse_args()

    key = "smoke" if args.smoke else "full"
    print(f"real-decode throughput ({key} cell): "
          f"{', '.join(PATHS)}")
    print(f"\n{'path':>8} {'requests':>9} {'tokens':>8} {'wall':>8} "
          f"{'tokens/s':>9} {'compiles':>9}")
    cells, streams = run_cells(smoke=args.smoke)
    for path in PATHS:
        cell = cells[path]
        print(f"{path:>8} {cell['requests']:>9} {cell['tokens']:>8} "
              f"{cell['wall_s']:>7.2f}s {cell['tokens_per_s']:>9.0f} "
              f"{cell['timed_run_compiles']:>9}")

    # correctness precedes throughput: all three decode strategies must
    # produce the same token streams before their speeds are comparable
    for path in ("batched", "arena"):
        assert streams[path] == streams["serial"], \
            f"{path} token streams diverge from serial"
    print("token streams identical across paths  [ok]")

    arena, batched = cells["arena"], cells["batched"]
    speedup = arena["tokens_per_s"] / max(batched["tokens_per_s"], 1e-9)
    # model exits = the ceiling on compiled arena variants per geometry
    sim = Simulation(decode_spec("arena", smoke=True))
    n_model = sim.build().engine.stepper.n_model
    arena_variants = arena["jit_variants"]["arena"]
    print(f"\narena vs batched: {speedup:.2f}x tokens/s "
          f"(arena variants {arena_variants} <= {n_model} model exits, "
          f"arena padded rows {arena['decode']['padded_rows']})")

    bench = {}
    if BENCH_PATH.exists():
        with open(BENCH_PATH) as f:
            bench = json.load(f)
    bench[key] = {
        "cells": cells,
        "arena_vs_batched_tokens_per_s": round(speedup, 2),
        "recorded_unix": int(time.time()),
    }
    with open(BENCH_PATH, "w") as f:
        json.dump(bench, f, indent=2, sort_keys=True)
    print(f"wrote {BENCH_PATH}")

    if not args.no_gate:
        assert speedup >= GATE_ARENA_SPEEDUP, (
            f"expected arena >= {GATE_ARENA_SPEEDUP}x batched tokens/s, "
            f"got {speedup:.2f}x")
        assert arena_variants <= n_model, (
            f"{arena_variants} compiled arena variants exceed the "
            f"{n_model} model exits")
        assert arena["decode"]["padded_rows"] == 0, \
            "arena path padded rows"
        assert arena["timed_run_compiles"] == 0, \
            "arena timed run recompiled: warm-up did not cover the run"
        print(f"gates (>= {GATE_ARENA_SPEEDUP}x, <= {n_model} variants, "
              f"0 padded rows)  [ok]")


if __name__ == "__main__":
    main()
