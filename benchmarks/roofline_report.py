"""Roofline terms per (arch x shape) from the dry-run artifacts
(EXPERIMENTS.md §Roofline).  Requires benchmarks/results/dryrun.json
(produced by ``python -m repro.launch.dryrun --all``); emits nothing if the
dry-run has not been executed yet."""
from __future__ import annotations

import json
import os

RESULTS = os.path.join(os.path.dirname(__file__), "results", "dryrun.json")


def run(emit):
    if not os.path.exists(RESULTS):
        emit("roofline_missing", 0.0, "run python -m repro.launch.dryrun --all")
        return {}
    from repro.launch.roofline import terms_from_record

    with open(RESULTS) as f:
        results = json.load(f)
    out = {}
    for key, rec in sorted(results.items()):
        parts = key.split("|")
        if len(parts) != 3 or parts[2] != "single" or rec.get("status") != "ok":
            continue
        t = terms_from_record(rec)
        name = f"roofline_{t.arch}_{t.shape}"
        dom_us = t.dominant() * 1e6
        emit(name, dom_us,
             f"compute_s={t.compute_s:.3e};memory_s={t.memory_s:.3e};"
             f"collective_s={t.collective_s:.3e};bottleneck={t.bottleneck};"
             f"useful={t.useful_ratio:.3f};roofline_frac={t.roofline_fraction:.3f}")
        out[(t.arch, t.shape)] = t
    return out
