"""Quickstart: Edgent end-to-end in ~60 lines.

Builds the paper's branchy AlexNet, profiles it, fits the Table-I latency
regressions, then asks the planner for co-inference plans across bandwidths
and executes one plan on the simulated two-tier testbed.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp

from repro.core import EdgentPlanner, alexnet_graph
from repro.core.coinference import TwoTierExecutor
from repro.models.alexnet import BranchyAlexNet, BranchyAlexNetConfig

KBPS = 125  # bytes/s


def main():
    # 1. the branchy model (5 exit points, paper Fig. 4)
    net = BranchyAlexNet(BranchyAlexNetConfig())
    params = net.init(jax.random.key(0))
    graph = alexnet_graph(net)
    print(f"model: {graph.name}, branches: "
          f"{[len(b) for b in graph.branches]} layers")

    # 2. offline configuration: profile + fit per-layer-type regressions
    x = jax.random.normal(jax.random.key(1), (1, 32, 32, 3))
    planner = EdgentPlanner(graph, latency_req_s=1.0)
    planner.offline_static(params, x)
    print(f"tier calibration: edge x{planner.edge_factor:.1f}, "
          f"device x{planner.device_factor:.0f} (paper Fig. 2 endpoints)")
    print(f"regression R^2 per layer type: "
          f"{ {k: round(v, 3) for k, v in planner.f_edge.r2().items()} }")

    # 3. online tuning: the joint (exit, partition) plan per bandwidth
    print("\nbandwidth -> plan (SLO = 1000 ms):")
    for kbps in (50, 100, 250, 500, 1000):
        plan = planner.plan(kbps * KBPS)
        print(f"  {kbps:5d} kbps: exit={plan.exit_point} "
              f"partition={plan.partition:2d} "
              f"latency={plan.latency_s * 1e3:7.1f} ms "
              f"acc={plan.accuracy:.2f} feasible={plan.feasible}")

    # 4. co-inference stage: execute the plan across the two tiers
    plan = planner.plan(500 * KBPS)
    executor = TwoTierExecutor(graph, params, bandwidth_bps=500 * KBPS,
                               device_slowdown=planner.device_factor,
                               edge_slowdown=planner.edge_factor)
    res = executor.run(plan, x)
    print(f"\nco-inference: exit={res.exit_point} partition={res.partition} "
          f"edge={res.edge_s * 1e3:.1f}ms device={res.device_s * 1e3:.1f}ms "
          f"transfer={res.transfer_s * 1e3:.1f}ms -> logits {res.output.shape}")


if __name__ == "__main__":
    main()
