"""Fleet serving end to end, with the real (smoke-scale) model in the loop.

A small fleet — 8 devices with independent LTE-like links, 2 heterogeneous
edges — serves a Poisson multi-tenant stream.  Timing is virtual (latency
models on the event heap); token values come from actual decode: each
admitted request carries its own B=1 cache and steps through the jitted
per-exit variants shared fleet-wide, so deadline demotion visibly changes
which exit a request decodes through.

Run:  PYTHONPATH=src python examples/serve_fleet.py
"""
import jax.numpy as jnp

from repro.fleet import FleetEngine, make_fleet, make_workload, smoke_lm_scenario


def main():
    cfg, graph, planner, model, params = smoke_lm_scenario(with_model=True)
    topo = make_fleet(8, 2, seed=0, trace="lte", edge_capacity=4,
                      max_edge_slowdown=2.0)
    wl = make_workload(8, rate_hz=6.0, horizon_s=10.0, seed=1,
                       arrival="poisson", device_skew=0.5,
                       vocab_size=cfg.vocab_size, prompt_len=6)
    print(f"fleet: {topo.num_devices} devices x {topo.num_edges} edges, "
          f"{len(wl)} requests over 10s (virtual)")

    eng = FleetEngine(topo, graph, planner, router="bandwidth-aware",
                      model=model, params=params, dtype=jnp.float32)
    metrics = eng.run(wl)
    s = metrics.summary()

    print(f"\nSLO attainment: {s['slo_attainment']:.2%}   "
          f"p50 {s['p50_latency_s']*1e3:.1f} ms   "
          f"p99 {s['p99_latency_s']*1e3:.1f} ms")
    print(f"per tenant: {s['slo_by_tenant']}")
    print(f"edge utilization: {s['edge_utilization']}")
    print(f"exits: {s['exit_histogram']}   partitions: {s['partition_histogram']}")

    print("\n rid  tenant       dev edge  exit  latency(ms)  met  tokens")
    by_rid = {r.rid: r for r in wl}
    for rec in metrics.records[:10]:
        toks = by_rid[rec.rid].tokens
        print(f"{rec.rid:4d}  {rec.tenant:<11} {rec.device:3d} {rec.edge:4d} "
              f"{rec.exit_point:5d} {rec.latency_s*1e3:12.1f}  {str(rec.met_slo):<5}"
              f"{toks}")


if __name__ == "__main__":
    main()
