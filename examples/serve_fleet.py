"""Fleet serving end to end, with the real (smoke-scale) model in the loop.

A small fleet — 8 devices with independent LTE-like links, 2 heterogeneous
edges — serves a Poisson multi-tenant stream, wired entirely from one
declarative ``repro.sim`` spec (docs/api.md).  Timing is virtual (latency
models on the event heap); token values come from actual decode
(``EngineSpec(real_decode=True)``): each admitted request carries its own
B=1 cache and steps through the jitted per-exit variants shared fleet-wide,
so deadline demotion visibly changes which exit a request decodes through.

Run:  PYTHONPATH=src python examples/serve_fleet.py
"""
from repro.sim import (EngineSpec, RouterSpec, ScenarioSpec, Simulation,
                       TopologySpec, WorkloadSpec)

SPEC = ScenarioSpec(
    name="serve-fleet",
    description="small LTE fleet with real decode in the loop",
    seed=0,
    topology=TopologySpec(num_devices=8, num_edges=2, trace="lte",
                          edge_capacity=4, max_edge_slowdown=2.0),
    workload=WorkloadSpec(rate_hz=6.0, horizon_s=10.0, device_skew=0.5,
                          prompt_len=6),
    router=RouterSpec(name="bandwidth-aware"),
    engine=EngineSpec(real_decode=True, dtype="float32"))


def main():
    sim = Simulation(SPEC)
    sc = sim.build()
    print(f"fleet: {sc.topo.num_devices} devices x {sc.topo.num_edges} "
          f"edges, {len(sc.workload)} requests over "
          f"{SPEC.workload.horizon_s:.0f}s (virtual)")

    metrics = sim.run()
    s = metrics.summary()

    print(f"\nSLO attainment: {s['slo_attainment']:.2%}   "
          f"p50 {s['p50_latency_s']*1e3:.1f} ms   "
          f"p99 {s['p99_latency_s']*1e3:.1f} ms")
    print(f"per tenant: {s['slo_by_tenant']}")
    print(f"edge utilization: {s['edge_utilization']}")
    print(f"exits: {s['exit_histogram']}   partitions: {s['partition_histogram']}")

    print("\n rid  tenant       dev edge  exit  latency(ms)  met  tokens")
    by_rid = {r.rid: r for r in sc.workload}
    for rec in metrics.records[:10]:
        toks = by_rid[rec.rid].tokens
        print(f"{rec.rid:4d}  {rec.tenant:<11} {rec.device:3d} {rec.edge:4d} "
              f"{rec.exit_point:5d} {rec.latency_s*1e3:12.1f}  {str(rec.met_slo):<5}"
              f"{toks}")


if __name__ == "__main__":
    main()
