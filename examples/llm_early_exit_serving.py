"""The paper's technique at LM scale (the end-to-end serving driver):
batched requests against a multi-exit llama-style model, the Edgent planner
choosing (exit point, partition) per bandwidth state, deadline demotion as
straggler mitigation, fused exit-head confidence on every decode step.

Run:  PYTHONPATH=src python examples/llm_early_exit_serving.py [--dynamic]
"""
import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, get_smoke_config
from repro.core import EdgentPlanner, lm_graph
from repro.core.latency_model import RooflineLatencyModel
from repro.data.bandwidth import dcn_trace
from repro.kernels.exit_head import ops as exit_ops
from repro.models import Model
from repro.serving import Request, ServingEngine
from repro.serving.tiers import Link


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-1b")
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--new-tokens", type=int, default=12)
    ap.add_argument("--slo-ms", type=float, default=300.0)
    ap.add_argument("--dynamic", action="store_true")
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch)
    model = Model(cfg)
    params = model.init_params(jax.random.key(0), dtype=jnp.float32)
    print(f"arch {cfg.name}: {model.num_segments} segments "
          f"(exit heads between them)")

    # datacenter tiers: 8-chip edge slice vs 1-chip device slice.
    # The planner's graph carries the FULL-size architecture (virtual
    # timing); the smoke model executes the actual tokens.
    graph = lm_graph(get_config(args.arch), batch=4, seq=1)
    planner = EdgentPlanner(graph, latency_req_s=args.slo_ms / 1e3)
    planner.with_models(RooflineLatencyModel(chips=8, efficiency=0.4),
                        RooflineLatencyModel(chips=1, efficiency=0.4))
    trace = dcn_trace(0, 4096)
    if args.dynamic:
        hist = [trace[i:i + 49] for i in range(0, 2450, 49)]
        planner.offline_dynamic(hist)

    engine = ServingEngine(model, params, graph, planner, Link(trace_bps=trace),
                           batch_size=4, dynamic=args.dynamic)
    rs = np.random.default_rng(0)
    reqs = [Request(rid=i,
                    prompt=rs.integers(0, cfg.vocab_size, 10).astype(np.int32),
                    max_new_tokens=args.new_tokens, slo_s=args.slo_ms / 1e3)
            for i in range(args.requests)]
    stats = engine.serve(reqs)
    print("\nserving summary:", stats.summary())

    # fused exit-head confidence (the Pallas kernel, interpret mode on CPU)
    toks = jnp.asarray(reqs[0].prompt)[None]
    cache = model.init_cache(1, 32, dtype=jnp.float32, enc_len=toks.shape[1])
    h, cache = model.prefill(params, toks, cache)
    conf = exit_ops.exit_confidence(h, params["embed"])
    print(f"\nfused exit-head on last prefill token: "
          f"token={int(conf['token'][0, 0])} "
          f"conf={float(conf['conf'][0, 0]):.3f} "
          f"entropy={float(conf['entropy'][0, 0]):.2f} "
          f"(vs vocab max {np.log(cfg.padded_vocab):.2f})")


if __name__ == "__main__":
    main()
