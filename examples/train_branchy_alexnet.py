"""Train the paper's branchy AlexNet (BranchyNet joint loss) on the
synthetic CIFAR-like set for a few hundred steps, with checkpoint/restart,
and report per-exit accuracy — the accuracy/latency tradeoff that the
right-sizing knob trades on.

Run:  PYTHONPATH=src python examples/train_branchy_alexnet.py [--steps 300]
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpointing import CheckpointManager
from repro.data.synthetic import cifar_like
from repro.models.alexnet import BranchyAlexNet, BranchyAlexNetConfig
from repro.optim.adamw import adamw_init, adamw_update
from repro.runtime.fault_tolerance import FailureInjector, ResilientLoop


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=64)
    ap.add_argument("--noise", type=float, default=1.4)
    ap.add_argument("--ckpt-dir", default="/tmp/branchy_alexnet_ckpt")
    ap.add_argument("--inject-failure-at", type=int, default=None)
    args = ap.parse_args()

    net = BranchyAlexNet(BranchyAlexNetConfig())
    rng = jax.random.key(0)
    params = net.init(rng)
    opt = adamw_init(params)

    @jax.jit
    def step(params, opt, x, y, r):
        loss, grads = jax.value_and_grad(net.loss)(params, (x, y), r)
        params, opt = adamw_update(grads, opt, params, lr=1e-3,
                                   weight_decay=1e-4)
        return params, opt, loss

    data_rng = np.random.default_rng(0)
    ckpt = CheckpointManager(args.ckpt_dir)
    loop = ResilientLoop(ckpt, save_every=100)
    injector = (FailureInjector(fail_at=(args.inject_failure_at,))
                if args.inject_failure_at else None)
    t0 = time.time()
    r = rng

    def step_fn(state, i):
        nonlocal r
        params, opt = state
        x, y = cifar_like(data_rng, args.batch, noise=args.noise)
        r, sub = jax.random.split(r)
        params, opt, loss = step(params, opt, jnp.asarray(x), jnp.asarray(y), sub)
        if i % 50 == 0:
            print(f"step {i:4d}  joint loss {float(loss):.4f}", flush=True)
        return params, opt

    (params, opt), info = loop.run((params, opt), step_fn, args.steps,
                                   injector=injector,
                                   on_restart=lambda s: print(f"[restart] at step {s}"))
    print(f"\ntrained {args.steps} steps in {time.time() - t0:.1f}s "
          f"(restarts={info['restarts']})")

    # per-exit accuracy (the right-sizing tradeoff, paper Fig. 4/9)
    xv, yv = cifar_like(np.random.default_rng(99), 1024, noise=args.noise)
    xv, yv = jnp.asarray(xv), jnp.asarray(yv)
    print("\nexit point -> accuracy (branch length):")
    for i in range(1, net.num_exits + 1):
        acc = float(net.accuracy(params, xv, yv, i))
        print(f"  exit {i}: {acc:.3f}   ({len(net.branch_layers(i))} layers)")


if __name__ == "__main__":
    main()
