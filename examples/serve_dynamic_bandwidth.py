"""The paper's dynamic-environment workflow, end to end:

  1. offline: sketch 428 bandwidth states from Oboe-like traces, build the
     configuration map (Algorithm 2);
  2. online: BOCD change-point detection over a Belgium-LTE-like mobility
     trace drives map lookups (Algorithm 3);
  3. co-inference: each plan is executed on the simulated two-tier testbed.

Run:  PYTHONPATH=src python examples/serve_dynamic_bandwidth.py
"""
import jax
import numpy as np

from repro.core import EdgentPlanner, alexnet_graph
from repro.core.coinference import TwoTierExecutor
from repro.data.bandwidth import MBPS, belgium_lte_like, oboe_like_traces
from repro.models.alexnet import BranchyAlexNet, BranchyAlexNetConfig


def main():
    net = BranchyAlexNet(BranchyAlexNetConfig())
    params = net.init(jax.random.key(0))
    graph = alexnet_graph(net)
    x = jax.random.normal(jax.random.key(1), (1, 32, 32, 3))

    planner = EdgentPlanner(graph, latency_req_s=1.0)
    planner.offline_static(params, x)
    traces = oboe_like_traces(seed=0, num=428)
    planner.offline_dynamic([t.tolist() for t in traces])
    print(f"configuration map: {len(planner.dynamic_opt.cmap)} bandwidth states")

    lte = belgium_lte_like(seed=3, length=120, transport="bus", hi_mbps=10.0)
    executor = TwoTierExecutor(graph, params, bandwidth_bps=1.0,
                               device_slowdown=planner.device_factor,
                               edge_slowdown=planner.edge_factor)
    print("\n t   bw(Mbps)  state(Mbps)  exit  partition  latency(ms)  in-SLO")
    met = 0
    for t, bw in enumerate(lte):
        plan = planner.plan(bw, dynamic=True)
        res = executor.run(plan, x, bandwidth_bps=bw)
        ok = res.latency_s <= planner.latency_req_s
        met += ok
        if t % 10 == 0:
            state = planner.dynamic_opt.state / MBPS
            print(f"{t:3d}  {bw / MBPS:7.2f}  {state:10.2f}  {plan.exit_point:4d} "
                  f"{plan.partition:9d}  {res.latency_s * 1e3:10.1f}  {ok}")
    print(f"\nSLO attainment: {met}/{len(lte)} "
          f"({100 * met / len(lte):.1f}%)  "
          f"state transitions: {planner.dynamic_opt.transitions}")


if __name__ == "__main__":
    main()
