"""AdamW with shardable state.  Moments can be stored in bf16 (with
stochastic-rounding-free error compensation skipped — bf16 moments are the
standard large-model memory trick; toggled per config) so the optimizer state
shards exactly like the params (same PartitionSpec tree)."""
from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: jnp.ndarray
    mu: Any
    nu: Any


def adamw_init(params, moment_dtype=jnp.float32) -> AdamWState:
    zeros = lambda p: jnp.zeros(p.shape, moment_dtype)
    return AdamWState(step=jnp.zeros((), jnp.int32),
                      mu=jax.tree.map(zeros, params),
                      nu=jax.tree.map(zeros, params))


def adamw_update(grads, state: AdamWState, params, *, lr, b1=0.9, b2=0.95,
                 eps=1e-8, weight_decay=0.1, grad_clip=1.0):
    """Returns (new_params, new_state).  Global-norm clip then AdamW."""
    gnorm = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                         for g in jax.tree.leaves(grads)))
    scale = jnp.minimum(1.0, grad_clip / (gnorm + 1e-9))
    step = state.step + 1
    b1c = 1 - b1 ** step.astype(jnp.float32)
    b2c = 1 - b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m32, v32 = m.astype(jnp.float32), v.astype(jnp.float32)
        m32 = b1 * m32 + (1 - b1) * g
        v32 = b2 * v32 + (1 - b2) * g * g
        update = (m32 / b1c) / (jnp.sqrt(v32 / b2c) + eps)
        update = update + weight_decay * p.astype(jnp.float32)
        newp = p.astype(jnp.float32) - lr * update
        return newp.astype(p.dtype), m32.astype(m.dtype), v32.astype(v.dtype)

    flat_p, tdef = jax.tree.flatten(params)
    flat_g = tdef.flatten_up_to(grads)
    flat_m = tdef.flatten_up_to(state.mu)
    flat_v = tdef.flatten_up_to(state.nu)
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = tdef.unflatten([o[0] for o in out])
    new_m = tdef.unflatten([o[1] for o in out])
    new_v = tdef.unflatten([o[2] for o in out])
    return new_p, AdamWState(step=step, mu=new_m, nu=new_v)
