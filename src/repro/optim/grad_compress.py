"""Gradient compression for the inter-pod (DCN) all-reduce — distributed-
optimization trick for the multi-pod mesh.

Error-feedback int8 quantization: each step quantizes (grad + residual) to
int8 per-tensor scale, keeps the quantization error as residual.  With 2 pods
the DCN all-reduce volume drops 4x (bf16 -> int8) at <1% step-direction error
after feedback; validated in tests/test_optim.py.
"""
from __future__ import annotations

from typing import Any, NamedTuple, Tuple

import jax
import jax.numpy as jnp


class EFState(NamedTuple):
    residual: Any


def ef_init(params) -> EFState:
    return EFState(residual=jax.tree.map(
        lambda p: jnp.zeros(p.shape, jnp.float32), params))


def quantize_int8(x):
    scale = jnp.maximum(jnp.max(jnp.abs(x)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize(q, scale):
    return q.astype(jnp.float32) * scale


def compress_grads(grads, ef: EFState) -> Tuple[Any, EFState]:
    """Returns (compressed-then-decompressed grads, new error-feedback state).
    In the production lowering the int8 payload is what crosses the pod axis;
    here compression and the feedback loop are exact."""

    def one(g, r):
        x = g.astype(jnp.float32) + r
        q, s = quantize_int8(x)
        deq = dequantize(q, s)
        return deq.astype(g.dtype), x - deq

    flat_g, tdef = jax.tree.flatten(grads)
    flat_r = tdef.flatten_up_to(ef.residual)
    out = [one(g, r) for g, r in zip(flat_g, flat_r)]
    return (tdef.unflatten([o[0] for o in out]),
            EFState(residual=tdef.unflatten([o[1] for o in out])))


def topk_compress(g, frac: float = 0.01):
    """Top-k sparsification (magnitude); returns dense tensor with only the
    top `frac` entries kept — the sparse indices+values are the DCN payload."""
    x = g.astype(jnp.float32)
    k = max(1, int(x.size * frac))
    flat = jnp.abs(x).reshape(-1)
    thresh = jax.lax.top_k(flat, k)[0][-1]
    return jnp.where(jnp.abs(x) >= thresh, x, 0.0).astype(g.dtype)
