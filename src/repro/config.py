"""Configuration dataclasses for models, input shapes, meshes and hardware.

Every assigned architecture is described by a :class:`ModelConfig`; the four
assigned input shapes by :class:`ShapeConfig`.  ``reduced()`` derives the
CPU-runnable smoke-test variant of any full config (same structural family —
MoE interleave, hybrid period, enc-dec split — just small).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Optional, Tuple

# ----------------------------------------------------------------------------
# Hardware model (TPU v5e, per chip) — used by roofline + analytic latency.
# ----------------------------------------------------------------------------
PEAK_FLOPS_BF16 = 197e12  # FLOP/s
HBM_BW = 819e9            # bytes/s
ICI_BW = 50e9             # bytes/s per link
VMEM_BYTES = 128 * 1024 * 1024
HBM_BYTES = 16 * 1024 * 1024 * 1024


@dataclass(frozen=True)
class ModelConfig:
    """Architecture description.  All sizes are in units of elements."""

    name: str
    family: str                    # dense | moe | ssm | hybrid | audio | vlm
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0              # 0 -> d_model // num_heads

    # --- MoE ---
    num_experts: int = 0
    experts_per_tok: int = 1
    moe_period: int = 1            # MoE FFN every `moe_period`-th layer (1 = every layer)
    capacity_factor: float = 1.25

    # --- SSM / hybrid ---
    ssm_state: int = 0             # Mamba2 state size N (zamba2) / rwkv head size
    hybrid_attn_period: int = 0    # zamba2: shared attention block every k mamba blocks

    # --- encoder-decoder (seamless) ---
    is_encdec: bool = False
    num_encoder_layers: int = 0

    # --- modality frontend stubs ---
    frontend: str = "none"         # none | audio | vision
    num_prefix_tokens: int = 0     # precomputed frame/patch embeddings prepended

    # --- early exit (the paper's right-sizing knob) ---
    num_exits: int = 0             # exit heads evenly spaced in depth (final head excluded)
    tie_exit_heads: bool = True

    # --- numerics ---
    rope_theta: float = 10000.0
    norm_eps: float = 1e-5
    max_seq_len: int = 524_288

    # metadata
    source: str = ""

    @property
    def hd(self) -> int:
        return self.head_dim or self.d_model // self.num_heads

    @property
    def padded_vocab(self) -> int:
        """Embedding rows padded to a multiple of 128 so the vocab dim shards
        over the 16-way ``model`` axis (MaxText-style; padded logits are
        random-init and harmless — see DESIGN.md)."""
        return (self.vocab_size + 127) // 128 * 128

    @property
    def padded_heads(self) -> int:
        """Query heads padded per kv-group so whole heads shard over the
        16-way model axis (llama4: 40 -> 48 = 8 kv-groups x 6).  Padded heads
        are masked dead (zero output, zero gradient) — see layers.attention.
        No padding for head counts below the axis size (smoke configs)."""
        H, KV = self.num_heads, self.num_kv_heads
        M = MODEL_AXIS_SIZE
        if H % M == 0 or H < M:
            return H
        G = H // KV
        Gp = G
        while (KV * Gp) % M:
            Gp += 1
        return KV * Gp

    @property
    def attn_free(self) -> bool:
        return self.family == "ssm"

    @property
    def sub_quadratic(self) -> bool:
        """True when 500k-context decode is admissible (O(1)-state archs)."""
        return self.family in ("ssm", "hybrid")

    # ------------------------------------------------------------------
    # Parameter counting (drives MODEL_FLOPS = 6*N*D roofline term).
    # ------------------------------------------------------------------
    def _attn_params(self) -> int:
        d, hd = self.d_model, self.hd
        q = d * self.num_heads * hd
        kv = 2 * d * self.num_kv_heads * hd
        o = self.num_heads * hd * d
        return q + kv + o

    def _dense_ffn_params(self) -> int:
        return 3 * self.d_model * self.d_ff  # SwiGLU: gate, up, down

    def _moe_ffn_params(self) -> int:
        return self.num_experts * 3 * self.d_model * self.d_ff + self.d_model * self.num_experts

    def _rwkv_layer_params(self) -> int:
        d = self.d_model
        # time-mix: r,k,v,g,o projections + decay/bonus params + small loras
        tm = 5 * d * d + 2 * d + 6 * (d * 64 + 64 * d)
        cm = d * self.d_ff + self.d_ff * d  # channel mix: key d->ff, value ff->d
        return tm + cm

    def _mamba2_layer_params(self) -> int:
        d, n = self.d_model, self.ssm_state
        d_inner = 2 * d
        # in_proj (z,x,B,C,dt), conv, out_proj, norm
        return d * (2 * d_inner + 2 * n + d_inner // 64) + d_inner * d + 4 * d_inner

    def param_count(self, active_only: bool = False) -> int:
        """Total (or active) parameter count, embeddings included once."""
        emb = self.vocab_size * self.d_model  # lm head tied to embedding
        n = 0
        if self.family == "ssm":
            n = self.num_layers * self._rwkv_layer_params()
        elif self.family == "hybrid":
            n = self.num_layers * self._mamba2_layer_params()
            if self.hybrid_attn_period:
                n += self._attn_params() + self._dense_ffn_params()  # one shared block
        else:
            layers = self.num_layers + (self.num_encoder_layers if self.is_encdec else 0)
            attn = layers * self._attn_params()
            if self.is_encdec:
                attn += self.num_layers * self._attn_params()  # cross attention
            ffn = 0
            for i in range(self.num_layers):
                is_moe = self.num_experts > 0 and (i % self.moe_period == self.moe_period - 1)
                if is_moe:
                    if active_only:
                        ffn += self.experts_per_tok * 3 * self.d_model * self.d_ff
                    else:
                        ffn += self._moe_ffn_params()
                else:
                    ffn += self._dense_ffn_params()
            if self.is_encdec:
                ffn += self.num_encoder_layers * self._dense_ffn_params()
            n = attn + ffn
        norms = (2 * self.num_layers + 2) * self.d_model
        return emb + n + norms

    def exit_layer_indices(self) -> Tuple[int, ...]:
        """Layer indices (1-based, exclusive of final layer) after which an
        exit head sits; evenly spaced in depth, BranchyNet-style."""
        if self.num_exits <= 0:
            return ()
        L = self.num_layers
        return tuple(max(1, round(L * (i + 1) / (self.num_exits + 1))) for i in range(self.num_exits))


@dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode

    @property
    def tokens(self) -> int:
        return self.seq_len * self.global_batch


SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}


@dataclass(frozen=True)
class MeshConfig:
    shape: Tuple[int, ...]
    axes: Tuple[str, ...]

    @property
    def num_devices(self) -> int:
        n = 1
        for s in self.shape:
            n *= s
        return n


MODEL_AXIS_SIZE = 16  # production model-parallel degree (16x16 pod)

SINGLE_POD = MeshConfig((16, 16), ("data", "model"))
MULTI_POD = MeshConfig((2, 16, 16), ("pod", "data", "model"))


def reduced(cfg: ModelConfig, *, seq_len: int = 64, batch: int = 2) -> ModelConfig:
    """Smoke-test variant: same family/topology, tiny sizes."""
    L = min(cfg.num_layers, 4)
    if cfg.family == "hybrid" and cfg.hybrid_attn_period:
        L = 2 * min(cfg.hybrid_attn_period, 2)
        period = min(cfg.hybrid_attn_period, 2)
    else:
        period = cfg.hybrid_attn_period
    if cfg.num_experts and cfg.moe_period > 1:
        L = 4  # two (dense, moe) pairs
    kv = max(1, min(cfg.num_kv_heads, 2))
    heads = 4
    return dataclasses.replace(
        cfg,
        name=cfg.name + "-smoke",
        num_layers=L,
        d_model=64,
        num_heads=heads,
        num_kv_heads=kv if cfg.num_kv_heads < cfg.num_heads else heads,
        head_dim=16,
        d_ff=128,
        vocab_size=256,
        num_experts=min(cfg.num_experts, 4),
        moe_period=cfg.moe_period,
        hybrid_attn_period=period,
        ssm_state=min(cfg.ssm_state, 16) if cfg.ssm_state else 0,
        num_encoder_layers=min(cfg.num_encoder_layers, 2),
        num_prefix_tokens=min(cfg.num_prefix_tokens, 8),
        num_exits=min(cfg.num_exits, 2),
        max_seq_len=4096,
    )


def cell_applicable(cfg: ModelConfig, shape: ShapeConfig) -> Tuple[bool, str]:
    """Whether (arch x shape) is a runnable dry-run cell, with reason."""
    if shape.name == "long_500k" and not cfg.sub_quadratic:
        return False, "full-attention arch: 500k-context decode skipped (see DESIGN.md §4)"
    return True, ""
