"""granite-3-8b — dense GQA transformer [hf:ibm-granite family]."""
from repro.config import ModelConfig

CONFIG = ModelConfig(
    name="granite-3-8b",
    family="dense",
    num_layers=40,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    d_ff=12800,
    vocab_size=49155,
    head_dim=128,
    num_exits=4,
    source="hf:ibm-granite/granite-3.0-2b-base; hf",
)
