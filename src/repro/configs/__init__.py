"""Architecture registry: ``--arch <id>`` resolves through here.

``get_config(arch)`` returns the full-size :class:`~repro.config.ModelConfig`;
``get_smoke_config(arch)`` the reduced CPU-runnable variant.
"""
from __future__ import annotations

import importlib

from repro.config import SHAPES, ModelConfig, ShapeConfig, cell_applicable, reduced

# arch id -> module name
_ARCH_MODULES = {
    "granite-3-2b": "granite_3_2b",
    "granite-3-8b": "granite_3_8b",
    "llama3.2-1b": "llama3_2_1b",
    "starcoder2-15b": "starcoder2_15b",
    "rwkv6-3b": "rwkv6_3b",
    "seamless-m4t-large-v2": "seamless_m4t_large_v2",
    "llava-next-mistral-7b": "llava_next_mistral_7b",
    "llama4-maverick-400b-a17b": "llama4_maverick_400b_a17b",
    "llama4-scout-17b-a16e": "llama4_scout_17b_a16e",
    "zamba2-2.7b": "zamba2_2_7b",
}

ARCH_IDS = tuple(_ARCH_MODULES)


def get_config(arch: str) -> ModelConfig:
    if arch not in _ARCH_MODULES:
        raise KeyError(f"unknown arch {arch!r}; known: {sorted(_ARCH_MODULES)}")
    mod = importlib.import_module(f"repro.configs.{_ARCH_MODULES[arch]}")
    return mod.CONFIG


def get_smoke_config(arch: str) -> ModelConfig:
    return reduced(get_config(arch))


def get_alexnet_config():
    mod = importlib.import_module("repro.configs.branchy_alexnet")
    return mod.CONFIG


def cells():
    """Yield every assigned (arch, shape, applicable, reason) dry-run cell."""
    for arch in ARCH_IDS:
        cfg = get_config(arch)
        for shape in SHAPES.values():
            ok, reason = cell_applicable(cfg, shape)
            yield arch, shape.name, ok, reason
