"""llava-next-mistral-7b — VLM: mistral-7b text backbone + anyres vision stub
[hf:llava-hf/llava-v1.6-mistral-7b-hf].

The anyres tiling frontend is a STUB: ``input_specs`` provides 2880
precomputed patch embeddings (576 base + 4x576 tiles) prepended to the text
tokens, exactly the activation-size heterogeneity the paper's partition
planner exploits (a big image prefix inflates the Input/B transfer term).
"""
from repro.config import ModelConfig

CONFIG = ModelConfig(
    name="llava-next-mistral-7b",
    family="vlm",
    num_layers=32,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    d_ff=14336,
    vocab_size=32000,
    head_dim=128,
    frontend="vision",
    num_prefix_tokens=2880,
    rope_theta=1000000.0,
    num_exits=4,
    source="hf:llava-hf/llava-v1.6-mistral-7b-hf; unverified",
)
