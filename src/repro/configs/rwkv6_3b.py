"""rwkv6-3b — Finch: attention-free RNN with data-dependent decay
[arXiv:2404.05892].  head size 64 -> 40 heads; wkv state is (heads, 64, 64).
"""
from repro.config import ModelConfig

CONFIG = ModelConfig(
    name="rwkv6-3b",
    family="ssm",
    num_layers=32,
    d_model=2560,
    num_heads=40,       # d_model / head_size(64)
    num_kv_heads=40,
    d_ff=8960,
    vocab_size=65536,
    head_dim=64,
    ssm_state=64,       # per-head square wkv state
    num_exits=4,
    source="arXiv:2404.05892; hf",
)
