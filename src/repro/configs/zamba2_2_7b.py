"""zamba2-2.7b — hybrid: Mamba2 backbone + shared attention block
[arXiv:2411.15242].

54 Mamba2 (SSD) blocks; one weight-shared GQA attention + FFN block is applied
every 6 mamba blocks (9 applications, single weight copy) — the zamba2
shared-block pattern.  ssm_state=64.
"""
from repro.config import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-2.7b",
    family="hybrid",
    num_layers=54,
    d_model=2560,
    num_heads=32,
    num_kv_heads=32,
    d_ff=10240,
    vocab_size=32000,
    head_dim=80,
    ssm_state=64,
    hybrid_attn_period=6,
    num_exits=4,
    source="arXiv:2411.15242; hf",
)
