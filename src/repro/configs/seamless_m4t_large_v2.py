"""seamless-m4t-large-v2 — encoder-decoder multimodal backbone
[arXiv:2308.11596].

The speech/text frontend is a STUB per the assignment: ``input_specs`` feeds
precomputed frame embeddings straight into the 24-layer encoder; the 24-layer
text decoder (self + cross attention) produces vocab logits.  For the assigned
LM shapes the encoder consumes ``seq_len`` frames and the decoder ``seq_len``
target positions; decode shapes drive the decoder with a ``seq_len`` KV cache
plus cross-attention over the encoder memory.
"""
from repro.config import ModelConfig

CONFIG = ModelConfig(
    name="seamless-m4t-large-v2",
    family="audio",
    num_layers=24,           # decoder layers
    num_encoder_layers=24,
    is_encdec=True,
    d_model=1024,
    num_heads=16,
    num_kv_heads=16,
    d_ff=8192,
    vocab_size=256206,
    head_dim=64,
    frontend="audio",
    num_exits=4,             # decoder-side exits only (see DESIGN.md §4)
    source="arXiv:2308.11596; hf",
)
