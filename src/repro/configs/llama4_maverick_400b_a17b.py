"""llama4-maverick-400b-a17b — MoE 128 experts, top-1
[hf:meta-llama/Llama-4 family].

MoE FFN on every 2nd layer (interleaved dense), matching the 400B-total /
17B-active budget implied by the name: 24 MoE layers x 128 experts x
3*5120*8192 ~= 386B expert params + attention + embeddings ~= 400B total;
top-1 routing keeps ~17B active per token.  (48 all-MoE layers would be
~780B total, inconsistent with the name — interleave recorded per DESIGN.md.)
"""
from repro.config import ModelConfig

CONFIG = ModelConfig(
    name="llama4-maverick-400b-a17b",
    family="moe",
    num_layers=48,
    d_model=5120,
    num_heads=40,
    num_kv_heads=8,
    d_ff=8192,
    vocab_size=202048,
    head_dim=128,
    num_experts=128,
    experts_per_tok=1,
    moe_period=2,
    rope_theta=500000.0,
    num_exits=4,
    source="hf:meta-llama/Llama-4-Scout-17B-16E; unverified",
)
