"""starcoder2-15b — dense GQA + RoPE [arXiv:2402.19173]."""
from repro.config import ModelConfig

CONFIG = ModelConfig(
    name="starcoder2-15b",
    family="dense",
    num_layers=40,
    d_model=6144,
    num_heads=48,
    num_kv_heads=4,
    d_ff=24576,
    vocab_size=49152,
    head_dim=128,
    num_exits=4,
    source="arXiv:2402.19173; hf",
)
