"""Branchy AlexNet — the paper's prototype model (Fig. 4).

A CIFAR-10-scale AlexNet trained with 5 exit points via BranchyNet-style
joint loss.  Branch lengths (number of layers from input to that exit),
longest to shortest: 22, 20, 19, 16, 12 — matching Sec. V-A of the paper.

This model is described by its own layer-graph spec (conv/LRN/pool/FC layers,
paper Table I layer types) rather than :class:`ModelConfig`; see
``repro.models.alexnet``.
"""
from repro.models.alexnet import BranchyAlexNetConfig

CONFIG = BranchyAlexNetConfig(
    name="branchy-alexnet",
    num_classes=10,
    image_size=32,
    channels=3,
)
