"""Pluggable device->edge routing policies.

The router answers one question per arrival: which edge should co-serve this
device's request?  Policies range from oblivious (round-robin) to
queue-aware (join-shortest-queue) to bandwidth/latency-aware — the latter
consults the device's Edgent plan at its *current* bandwidth plus each
edge's speed and backlog, i.e. partition decisions inform placement (the
joint view of arXiv:2310.12937).
"""
from __future__ import annotations

from typing import Optional

from repro.fleet.cluster import DeviceNode, EdgeNode, FleetTopology


class Router:
    name = "base"

    def route(self, req, device: DeviceNode, topo: FleetTopology,
              now: float) -> EdgeNode:
        raise NotImplementedError


class RoundRobinRouter(Router):
    """Oblivious: cycle through the edges in id order."""
    name = "round-robin"

    def __init__(self):
        self._next = 0

    def route(self, req, device, topo, now) -> EdgeNode:
        edge = topo.edges[self._next % topo.num_edges]
        self._next += 1
        return edge


class JoinShortestQueueRouter(Router):
    """Pick the edge with the fewest queued + in-flight requests
    (deterministic tie-break on edge id)."""
    name = "jsq"

    def route(self, req, device, topo, now) -> EdgeNode:
        return min(topo.edges, key=lambda e: (e.backlog(), e.eid))


class BandwidthAwareRouter(Router):
    """Latency-aware: estimated completion = edge backlog + the Edgent
    planner's predicted co-inference latency at the device's current
    bandwidth on that edge's hardware (``edge.speed``).  Requires a
    :class:`~repro.serving.engine.CoInferenceStepper` for plan lookups (its
    plan cache is shared with the fleet engine)."""
    name = "bandwidth-aware"

    def __init__(self, stepper):
        self.stepper = stepper

    def route(self, req, device, topo, now) -> EdgeNode:
        bw = device.link.bw_at(now)
        plan = self.stepper.plan(bw)

        def est(edge: EdgeNode) -> float:
            step = self.stepper.per_exit_times_cached(
                plan.partition, bw, edge_load=edge.speed,
                device_load=device.slowdown)[plan.exit_point - 1]
            return edge.backlog_s() + step * req.max_new_tokens

        return min(topo.edges, key=lambda e: (est(e), e.eid))


def make_router(name: str, stepper=None) -> Router:
    if name in ("rr", "round-robin"):
        return RoundRobinRouter()
    if name in ("jsq", "join-shortest-queue"):
        return JoinShortestQueueRouter()
    if name in ("bw", "bandwidth", "bandwidth-aware"):
        assert stepper is not None, "bandwidth-aware routing needs a stepper"
        return BandwidthAwareRouter(stepper)
    raise ValueError(f"unknown router: {name!r}")
