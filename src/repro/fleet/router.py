"""Pluggable device->edge routing policies.

The router answers one question per arrival: which edge should co-serve this
device's request?  Policies range from oblivious (round-robin) to
queue-aware (join-shortest-queue) to bandwidth/latency-aware — the latter
consults the device's Edgent plan at its *current* bandwidth plus each
edge's speed and backlog, i.e. partition decisions inform placement (the
joint view of arXiv:2310.12937).
"""
from __future__ import annotations

from typing import Optional

import numpy as np

from repro.fleet.cluster import DeviceNode, EdgeNode, FleetTopology
from repro.fleet.joint import JointDecision, JointPlanner


class Router:
    name = "base"

    def route(self, req, device: DeviceNode, topo: FleetTopology,
              now: float) -> EdgeNode:
        raise NotImplementedError

    def decide(self, req, device: DeviceNode, topo: FleetTopology,
               now: float) -> Optional[JointDecision]:
        """Joint routing hook: a router that plans (edge set, partition,
        exit) jointly returns a full decision; placement-only routers return
        None and the engine falls back to :meth:`route`."""
        return None

    def reset(self):
        """Called by ``FleetEngine.run`` before each simulation so a stateful
        policy cannot leak decisions across runs (determinism contract)."""


class RoundRobinRouter(Router):
    """Oblivious: cycle through the edges in id order."""
    name = "round-robin"

    def __init__(self):
        self._next = 0

    def reset(self):
        self._next = 0

    def route(self, req, device, topo, now) -> EdgeNode:
        edge = topo.edges[self._next % topo.num_edges]
        self._next += 1
        return edge


class JoinShortestQueueRouter(Router):
    """Pick the edge with the fewest queued + in-flight requests
    (deterministic tie-break on edge id)."""
    name = "jsq"

    def route(self, req, device, topo, now) -> EdgeNode:
        # engine-maintained SoA row; np.argmin takes the first minimum,
        # which is the lowest eid — same tie-break as the scalar
        # min((backlog, eid)) scan over edge objects
        return topo.edges[int(np.argmin(topo.backlog_n_row()))]


class BandwidthAwareRouter(Router):
    """Latency-aware: estimated completion = edge backlog + the Edgent
    planner's predicted co-inference latency at the device's current
    bandwidth on that edge's hardware (``edge.speed``).  Requires a
    :class:`~repro.serving.engine.CoInferenceStepper` for plan lookups (its
    plan cache is shared with the fleet engine).

    Scoring is vectorized over the edges: the per-edge step time at the
    plan's exit is a pure function of (quantized bandwidth, plan, device
    slowdown) and is cached as one array; per arrival only the backlog
    vector is fresh.  ``argmin`` takes the first minimum, which is the
    lowest eid — the same ``(est, eid)`` tie-break as the scalar loop."""
    name = "bandwidth-aware"

    def __init__(self, stepper):
        self.stepper = stepper
        self._steps = {}

    def reset(self):
        # step-vector entries are pure values — they survive resets; the
        # dict is bounded by (qbw x plan x slowdown) like the step cache
        pass

    def route(self, req, device, topo, now) -> EdgeNode:
        from repro.serving.engine import quantize_bw
        bw = device.link.bw_at(now)
        plan = self.stepper.plan(bw)
        # keyed on the immutable inputs (incl. the edge-speed tuple, which
        # also pins the edge order), never on object identity — a router
        # instance may outlive the topology it first served
        key = (quantize_bw(bw), plan.partition, plan.exit_point,
               device.slowdown, topo.speed_key)
        steps = self._steps.get(key)
        if steps is None:
            steps = self._steps[key] = np.array([
                self.stepper.per_exit_times_cached(
                    plan.partition, bw, edge_load=e.speed,
                    device_load=device.slowdown)[plan.exit_point - 1]
                for e in topo.edges])
        blg = topo.backlog_s_row()          # vectorized EdgeNode.backlog_s
        est = blg + steps * req.max_new_tokens
        return topo.edges[int(est.argmin())]


class NearestEdgeRouter(Router):
    """Mobility-aware placement: route to the geographically nearest edge
    (the one the device's radio sees the strongest signal from).  Requires a
    :class:`~repro.fleet.mobility.MobilityModel`; pair it with a
    :class:`~repro.fleet.mobility.HandoverController` on the engine to keep
    that binding fresh as devices move (docs/handover.md)."""
    name = "nearest"

    def __init__(self, mobility):
        self.mobility = mobility

    def route(self, req, device, topo, now) -> EdgeNode:
        return topo.edge(self.mobility.nearest(device.did, now))


class JointRouter(Router):
    """Joint (edge-set, partition, exit) routing: delegates the full search
    to :class:`~repro.fleet.joint.JointPlanner` and returns an edge *set* —
    the primary hosts the queue slot, the rest serve cooperative spans."""
    name = "joint"

    def __init__(self, planner: JointPlanner):
        self.planner = planner

    def decide(self, req, device, topo, now) -> JointDecision:
        return self.planner.decide(req, device, topo, now)

    def route(self, req, device, topo, now) -> EdgeNode:
        dec = self.decide(req, device, topo, now)
        assert dec.assign.eids, \
            "device-only decision has no edge — callers must use decide()"
        return topo.edge(dec.assign.eids[0])


# alias -> canonical policy name; the single source of truth for which
# router strings `FleetEngine(router=...)`, `RouterSpec`, and the CLI accept
ROUTER_ALIASES = {
    "rr": "round-robin", "round-robin": "round-robin",
    "jsq": "jsq", "join-shortest-queue": "jsq",
    "bw": "bandwidth-aware", "bandwidth": "bandwidth-aware",
    "bandwidth-aware": "bandwidth-aware",
    "nearest": "nearest", "nearest-edge": "nearest",
    "joint": "joint", "coop": "joint", "joint-coop": "joint",
}


def make_router(name: str, stepper=None, topo=None,
                max_coop: int = 3, prefill_div: int = 8,
                mobility=None, admission=None) -> Router:
    """Router registry (docs/fleet.md has the policy table): resolves the
    policy names accepted by ``FleetEngine(router=...)``,
    ``repro.sim.RouterSpec``, and the benchmarks' ``--router`` flags.
    Unknown names and missing dependencies raise ``ValueError``.

    ``admission`` (a :class:`~repro.fleet.elastic.AdmissionControl`) is
    consulted only by joint routing: the planner masks saturated primaries
    so the search steers around full cells; placement-only routers rely on
    the engine's admission backstop instead."""
    canon = ROUTER_ALIASES.get(name)
    if canon is None:
        raise ValueError(f"unknown router {name!r}: expected one of "
                         f"{sorted(ROUTER_ALIASES)}")
    if canon == "round-robin":
        return RoundRobinRouter()
    if canon == "jsq":
        return JoinShortestQueueRouter()
    if canon == "bandwidth-aware":
        if stepper is None:
            raise ValueError("bandwidth-aware routing needs a "
                             "CoInferenceStepper (FleetEngine passes its "
                             "own when given the name)")
        return BandwidthAwareRouter(stepper)
    if canon == "nearest":
        if mobility is None:
            raise ValueError(
                "nearest-edge routing needs a MobilityModel: build the "
                "fleet with make_mobile_fleet or a repro.sim mobile "
                "topology and pass FleetEngine(mobility=...)")
        return NearestEdgeRouter(mobility)
    # joint
    if stepper is None or topo is None:
        raise ValueError("joint routing needs a stepper and the fleet "
                         "topology (FleetEngine passes both when given "
                         "the name)")
    if getattr(stepper, "dynamic", False):
        raise ValueError(
            "joint routing is static-environment only: the plan cache it "
            "fans out over assumes dynamic=False")
    # mobility (when the fleet has one) lets decide() price every candidate
    # primary at that edge's observed bandwidth instead of the device's
    # best-signal link — without it, joint routing systematically
    # over-admits far edges under mobility (docs/fleet.md)
    return JointRouter(JointPlanner(stepper, topo, max_coop=max_coop,
                                    prefill_div=prefill_div,
                                    mobility=mobility, admission=admission))
