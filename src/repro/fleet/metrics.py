"""Fleet-level observability: streaming aggregates + optional records.

Everything is computed from plain floats recorded during the event loop, so
two runs with the same seed produce bit-identical summaries (the determinism
contract the tests assert).

:meth:`FleetMetrics.summary` is a pure function of *running aggregates*
maintained by :meth:`record`: counters, histograms, per-edge dicts, and two
compact float buffers (latency and queue delay — exact percentiles and the
``np.mean`` pairwise sum need the raw samples, ~16 bytes per request).
The per-request :class:`RequestRecord` objects and the ``handover_log`` are
*retention*, not inputs: with ``retain_records=False`` (the 10k-device /
sweep setting) neither is kept and memory stays O(edges) + the two float
buffers, while summaries are bit-identical to the retained run — a property
pinned by tests/test_fleet_perf.py (hypothesis: streaming aggregates ==
record-replay computation).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List

import numpy as np


@dataclass
class RequestRecord:
    rid: int
    tenant: str
    device: int
    edge: int                      # primary edge (-1 = device-only)
    arrival_s: float
    finish_s: float
    latency_s: float
    queue_delay_s: float
    met_slo: bool
    exit_point: int
    partition: int
    edges: tuple = ()              # full cooperative edge set (len > 1 = coop)
    handovers: int = 0             # mid-request migrations this request took
    migrated_bytes: int = 0        # state bytes it shipped across handovers


@dataclass
class FleetMetrics:
    num_edges: int
    # False drops per-request RequestRecord retention and the handover log
    # (running aggregates only; summary() is unchanged either way)
    retain_records: bool = True
    records: List[RequestRecord] = field(default_factory=list)
    edge_busy_s: Dict[int, float] = field(default_factory=dict)
    horizon_s: float = 0.0
    # edge<->edge backbone traffic from cooperative spans: (src, dst) -> bytes
    transfer_bytes: Dict[tuple, int] = field(default_factory=dict)
    transfer_events: int = 0
    # compute a secondary edge contributes to other edges' requests — kept
    # apart from edge_busy_s (slot occupancy) so utilization is not
    # double-billed: the primary's round already spans the full chain
    coop_busy_s: Dict[int, float] = field(default_factory=dict)
    # mobility handovers (docs/handover.md): every mid-request migration is
    # logged as (completion time, src edge, dst edge, state bytes); the bytes
    # are *also* billed as ordinary backbone transfer events, so migrated
    # traffic is conserved against transfer_bytes (invariant-tested)
    handover_log: List[tuple] = field(default_factory=list)

    def __post_init__(self):
        # ---- running aggregates (the only inputs summary() reads) ----
        self._lat: List[float] = []        # per-request latency (percentiles)
        self._qd: List[float] = []         # per-request queue delay (mean)
        self._n = 0
        self._met = 0                      # requests that met their SLO
        self._coop = 0                     # cooperative (multi-edge) requests
        self._moved_n = 0                  # requests with >= 1 handover ...
        self._moved_met = 0                # ... and how many met their SLO
        self._exits: Dict[int, int] = {}
        self._parts: Dict[int, int] = {}
        self._tenant_n: Dict[str, int] = {}
        self._tenant_met: Dict[str, int] = {}
        self._handover_count = 0
        self._migrated_bytes = 0

    def record(self, rec: RequestRecord):
        """Fold one completed request into the running aggregates (and
        retain the record itself when ``retain_records``)."""
        self._n += 1
        self._lat.append(rec.latency_s)
        self._qd.append(rec.queue_delay_s)
        if rec.met_slo:
            self._met += 1
        if len(rec.edges) > 1:
            self._coop += 1
        if rec.handovers > 0:
            self._moved_n += 1
            if rec.met_slo:
                self._moved_met += 1
        self._exits[rec.exit_point] = self._exits.get(rec.exit_point, 0) + 1
        self._parts[rec.partition] = self._parts.get(rec.partition, 0) + 1
        self._tenant_n[rec.tenant] = self._tenant_n.get(rec.tenant, 0) + 1
        if rec.met_slo:
            self._tenant_met[rec.tenant] = \
                self._tenant_met.get(rec.tenant, 0) + 1
        self.horizon_s = max(self.horizon_s, rec.finish_s)
        if self.retain_records:
            self.records.append(rec)

    def add_busy(self, eid: int, dt_s: float):
        """Bill one round's slot-occupancy time to an edge."""
        self.edge_busy_s[eid] = self.edge_busy_s.get(eid, 0.0) + dt_s

    def add_transfer(self, src: int, dst: int, nbytes: int):
        """Aggregate one edge->edge backbone hand-off (coop span hop or
        handover state snapshot)."""
        key = (src, dst)
        self.transfer_bytes[key] = self.transfer_bytes.get(key, 0) + nbytes
        self.transfer_events += 1

    def add_coop_busy(self, eid: int, dt_s: float):
        """Track span compute a secondary edge served for another edge."""
        self.coop_busy_s[eid] = self.coop_busy_s.get(eid, 0.0) + dt_s

    def add_handover(self, src: int, dst: int, nbytes: int, t_s: float):
        """Log one mid-request migration completing at virtual time t_s."""
        self._handover_count += 1
        self._migrated_bytes += nbytes
        if self.retain_records:
            self.handover_log.append((round(t_s, 9), src, dst, nbytes))

    @property
    def handover_count(self) -> int:
        return self._handover_count

    @property
    def migrated_bytes_total(self) -> int:
        return self._migrated_bytes

    # ------------------------------------------------------------ summaries
    def summary(self) -> Dict:
        """Aggregate into one flat dict.  Pure function of the streaming
        aggregates — same seed, same summary, bitwise, with or without
        record retention (the determinism contract the tests and benchmarks
        assert)."""
        if self._n == 0:
            return {"requests": 0, "slo_attainment": 0.0}
        lat = np.array(self._lat)
        qd = np.array(self._qd)
        horizon = max(self.horizon_s, 1e-9)
        util = {eid: round(self.edge_busy_s.get(eid, 0.0) / horizon, 6)
                for eid in range(self.num_edges)}
        return {
            "requests": self._n,
            "coop_requests": self._coop,
            "handovers": self._handover_count,
            "migrated_mb": round(self._migrated_bytes / 1e6, 6),
            # SLO attainment restricted to requests that migrated at least
            # once — how well handed-over requests still land their deadline
            "handover_slo": (self._moved_met / self._moved_n
                             if self._moved_n else None),
            "backbone_mb": round(sum(self.transfer_bytes.values()) / 1e6, 6),
            "coop_busy_s": {eid: round(v, 6)
                            for eid, v in sorted(self.coop_busy_s.items())},
            "slo_attainment": self._met / self._n,
            "p50_latency_s": float(np.percentile(lat, 50)),
            "p95_latency_s": float(np.percentile(lat, 95)),
            "p99_latency_s": float(np.percentile(lat, 99)),
            "mean_queue_delay_s": float(np.mean(qd)),
            "makespan_s": float(self.horizon_s),
            "edge_utilization": util,
            "slo_by_tenant": {t: self._tenant_met.get(t, 0) / n
                              for t, n in sorted(self._tenant_n.items())},
            "exit_histogram": dict(sorted(self._exits.items())),
            "partition_histogram": dict(sorted(self._parts.items())),
        }
