"""Fleet-level observability: per-request records -> aggregate summary.

Everything is computed from plain floats recorded during the event loop, so
two runs with the same seed produce bit-identical summaries (the determinism
contract the tests assert).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List

import numpy as np


@dataclass
class RequestRecord:
    rid: int
    tenant: str
    device: int
    edge: int                      # primary edge (-1 = device-only)
    arrival_s: float
    finish_s: float
    latency_s: float
    queue_delay_s: float
    met_slo: bool
    exit_point: int
    partition: int
    edges: tuple = ()              # full cooperative edge set (len > 1 = coop)
    handovers: int = 0             # mid-request migrations this request took
    migrated_bytes: int = 0        # state bytes it shipped across handovers


@dataclass
class FleetMetrics:
    num_edges: int
    records: List[RequestRecord] = field(default_factory=list)
    edge_busy_s: Dict[int, float] = field(default_factory=dict)
    horizon_s: float = 0.0
    # edge<->edge backbone traffic from cooperative spans: (src, dst) -> bytes
    transfer_bytes: Dict[tuple, int] = field(default_factory=dict)
    transfer_events: int = 0
    # compute a secondary edge contributes to other edges' requests — kept
    # apart from edge_busy_s (slot occupancy) so utilization is not
    # double-billed: the primary's round already spans the full chain
    coop_busy_s: Dict[int, float] = field(default_factory=dict)
    # mobility handovers (docs/handover.md): every mid-request migration is
    # logged as (completion time, src edge, dst edge, state bytes); the bytes
    # are *also* billed as ordinary backbone transfer events, so migrated
    # traffic is conserved against transfer_bytes (invariant-tested)
    handover_log: List[tuple] = field(default_factory=list)

    def record(self, rec: RequestRecord):
        """Append one completed request (and advance the makespan)."""
        self.records.append(rec)
        self.horizon_s = max(self.horizon_s, rec.finish_s)

    def add_busy(self, eid: int, dt_s: float):
        """Bill one round's slot-occupancy time to an edge."""
        self.edge_busy_s[eid] = self.edge_busy_s.get(eid, 0.0) + dt_s

    def add_transfer(self, src: int, dst: int, nbytes: int):
        """Aggregate one edge->edge backbone hand-off (coop span hop or
        handover state snapshot)."""
        key = (src, dst)
        self.transfer_bytes[key] = self.transfer_bytes.get(key, 0) + nbytes
        self.transfer_events += 1

    def add_coop_busy(self, eid: int, dt_s: float):
        """Track span compute a secondary edge served for another edge."""
        self.coop_busy_s[eid] = self.coop_busy_s.get(eid, 0.0) + dt_s

    def add_handover(self, src: int, dst: int, nbytes: int, t_s: float):
        """Log one mid-request migration completing at virtual time t_s."""
        self.handover_log.append((round(t_s, 9), src, dst, nbytes))

    @property
    def handover_count(self) -> int:
        return len(self.handover_log)

    @property
    def migrated_bytes_total(self) -> int:
        return sum(h[3] for h in self.handover_log)

    # ------------------------------------------------------------ summaries
    def summary(self) -> Dict:
        """Aggregate the per-request records into one flat dict.  Pure
        function of the recorded floats — same seed, same summary, bitwise
        (the determinism contract the tests and benchmarks assert)."""
        if not self.records:
            return {"requests": 0, "slo_attainment": 0.0}
        lat = np.array([r.latency_s for r in self.records])
        met = np.array([r.met_slo for r in self.records])
        qd = np.array([r.queue_delay_s for r in self.records])
        horizon = max(self.horizon_s, 1e-9)
        util = {eid: round(self.edge_busy_s.get(eid, 0.0) / horizon, 6)
                for eid in range(self.num_edges)}
        exits: Dict[int, int] = {}
        parts: Dict[int, int] = {}
        per_tenant: Dict[str, List[bool]] = {}
        for r in self.records:
            exits[r.exit_point] = exits.get(r.exit_point, 0) + 1
            parts[r.partition] = parts.get(r.partition, 0) + 1
            per_tenant.setdefault(r.tenant, []).append(r.met_slo)
        coop = sum(1 for r in self.records if len(r.edges) > 1)
        moved = [r.met_slo for r in self.records if r.handovers > 0]
        return {
            "requests": len(self.records),
            "coop_requests": coop,
            "handovers": self.handover_count,
            "migrated_mb": round(self.migrated_bytes_total / 1e6, 6),
            # SLO attainment restricted to requests that migrated at least
            # once — how well handed-over requests still land their deadline
            "handover_slo": float(np.mean(moved)) if moved else None,
            "backbone_mb": round(sum(self.transfer_bytes.values()) / 1e6, 6),
            "coop_busy_s": {eid: round(v, 6)
                            for eid, v in sorted(self.coop_busy_s.items())},
            "slo_attainment": float(np.mean(met)),
            "p50_latency_s": float(np.percentile(lat, 50)),
            "p95_latency_s": float(np.percentile(lat, 95)),
            "p99_latency_s": float(np.percentile(lat, 99)),
            "mean_queue_delay_s": float(np.mean(qd)),
            "makespan_s": float(self.horizon_s),
            "edge_utilization": util,
            "slo_by_tenant": {k: float(np.mean(v))
                              for k, v in sorted(per_tenant.items())},
            "exit_histogram": dict(sorted(exits.items())),
            "partition_histogram": dict(sorted(parts.items())),
        }
