"""Fleet-level observability: streaming aggregates + optional records.

Everything is computed from plain floats recorded during the event loop, so
two runs with the same seed produce bit-identical summaries (the determinism
contract the tests assert).

:meth:`FleetMetrics.summary` is a pure function of *running aggregates*
maintained by :meth:`record` — named :class:`~repro.obs.registry
.MetricsRegistry` instruments (counters, counter families, and two
sample-retaining histograms: latency and queue delay, whose exact
percentiles and ``np.mean`` pairwise sum need the raw samples, ~16 bytes
per request) plus the public per-edge dicts.
The per-request :class:`RequestRecord` objects and the ``handover_log`` are
*retention*, not inputs: with ``retain_records=False`` (the 10k-device /
sweep setting) neither is kept and memory stays O(edges) + the two float
buffers, while summaries are bit-identical to the retained run — a property
pinned by tests/test_fleet_perf.py (hypothesis: streaming aggregates ==
record-replay computation).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List

from repro.obs.registry import Counter, CounterFamily, MetricsRegistry


@dataclass
class RequestRecord:
    rid: int
    tenant: str
    device: int
    edge: int                      # primary edge (-1 = device-only)
    arrival_s: float
    finish_s: float
    latency_s: float
    queue_delay_s: float
    met_slo: bool
    exit_point: int
    partition: int
    edges: tuple = ()              # full cooperative edge set (len > 1 = coop)
    handovers: int = 0             # mid-request migrations this request took
    migrated_bytes: int = 0        # state bytes it shipped across handovers


@dataclass
class FleetMetrics:
    num_edges: int
    # False drops per-request RequestRecord retention and the handover log
    # (running aggregates only; summary() is unchanged either way)
    retain_records: bool = True
    records: List[RequestRecord] = field(default_factory=list)
    edge_busy_s: Dict[int, float] = field(default_factory=dict)
    horizon_s: float = 0.0
    # edge<->edge backbone traffic from cooperative spans: (src, dst) -> bytes
    transfer_bytes: Dict[tuple, int] = field(default_factory=dict)
    transfer_events: int = 0
    # compute a secondary edge contributes to other edges' requests — kept
    # apart from edge_busy_s (slot occupancy) so utilization is not
    # double-billed: the primary's round already spans the full chain
    coop_busy_s: Dict[int, float] = field(default_factory=dict)
    # mobility handovers (docs/handover.md): every mid-request migration is
    # logged as (completion time, src edge, dst edge, state bytes); the bytes
    # are *also* billed as ordinary backbone transfer events, so migrated
    # traffic is conserved against transfer_bytes (invariant-tested)
    handover_log: List[tuple] = field(default_factory=list)
    # ---- shard-merge keys (repro.sim.shard, docs/performance.md): the
    # virtual time each sample/log entry was *appended* at.  A sharded run
    # produces one FleetMetrics per tile; merging the per-tile streams by
    # (append time, tile index) with a stable sort reproduces the exact
    # append order of the equivalent single-process run, which is what the
    # order-sensitive aggregates (np.mean pairwise sums, handover_log) need
    # for bit-identical summaries.
    finish_keys: List[float] = field(default_factory=list)
    handover_at: List[float] = field(default_factory=list)
    # ---- elasticity (fleet.elastic, docs/elastic.md).  ``elastic`` is set
    # by the engine when an autoscaler or admission policy is attached; the
    # elastic summary keys (rejected / cost / scale counts) are emitted only
    # then, so summaries of non-elastic runs stay bit-identical to the
    # pre-elasticity schema (golden-pinned by tests/test_elastic.py).
    elastic: bool = False
    usd_per_slot_hour: float = 0.0
    # integral of provisioned capacity per edge (slot-seconds): summed from
    # the piecewise-constant capacity timeline at every change point
    slot_s: Dict[int, float] = field(default_factory=dict)
    # scale-event log: (virtual time, eid, old slots, new slots) — retained
    # like handover_log; ``scale_at`` carries the shard-merge keys
    capacity_log: List[tuple] = field(default_factory=list)
    scale_at: List[float] = field(default_factory=list)

    def __post_init__(self):
        # ---- running aggregates (the only inputs summary() reads), all
        # registered repro.obs instruments: the counters/histograms are the
        # same plain ints and float lists the pre-registry fields held, so
        # summary() arithmetic is unchanged bitwise — but they now share
        # one named, snapshottable registry instead of ad-hoc privates
        r = self.registry = MetricsRegistry()
        self._lat = r.histogram("latency_s")    # percentiles need samples
        self._qd = r.histogram("queue_delay_s")
        self._n = r.counter("requests")
        self._met = r.counter("requests_met_slo")
        self._coop = r.counter("coop_requests")
        self._moved_n = r.counter("moved_requests")      # >= 1 handover ...
        self._moved_met = r.counter("moved_requests_met_slo")  # ... met SLO
        self._exits = r.family("exit_histogram")
        self._parts = r.family("partition_histogram")
        self._tenant_n = r.family("tenant_requests")
        self._tenant_met = r.family("tenant_requests_met_slo")
        self._handovers = r.counter("handovers")
        self._migrated = r.counter("migrated_bytes")
        # elasticity instruments are registered unconditionally (zero-cost
        # when idle) so merged() folds them through the same registry loop;
        # summary() only *emits* them when self.elastic
        self._rejected = r.counter("rejected")
        self._scales = r.counter("scale_events")
        # last capacity change point per edge: (virtual time, slots)
        self._cap_mark: Dict[int, tuple] = {}

    def record(self, rec: RequestRecord):
        """Fold one completed request into the running aggregates (and
        retain the record itself when ``retain_records``)."""
        self._n.inc()
        self._lat.observe(rec.latency_s)
        self._qd.observe(rec.queue_delay_s)
        if rec.met_slo:
            self._met.inc()
        if len(rec.edges) > 1:
            self._coop.inc()
        if rec.handovers > 0:
            self._moved_n.inc()
            if rec.met_slo:
                self._moved_met.inc()
        self._exits.inc(rec.exit_point)
        self._parts.inc(rec.partition)
        self._tenant_n.inc(rec.tenant)
        if rec.met_slo:
            self._tenant_met.inc(rec.tenant)
        self.horizon_s = max(self.horizon_s, rec.finish_s)
        self.finish_keys.append(rec.finish_s)
        if self.retain_records:
            self.records.append(rec)

    def add_busy(self, eid: int, dt_s: float):
        """Bill one round's slot-occupancy time to an edge."""
        self.edge_busy_s[eid] = self.edge_busy_s.get(eid, 0.0) + dt_s

    def add_transfer(self, src: int, dst: int, nbytes: int):
        """Aggregate one edge->edge backbone hand-off (coop span hop or
        handover state snapshot)."""
        key = (src, dst)
        self.transfer_bytes[key] = self.transfer_bytes.get(key, 0) + nbytes
        self.transfer_events += 1

    def add_coop_busy(self, eid: int, dt_s: float):
        """Track span compute a secondary edge served for another edge."""
        self.coop_busy_s[eid] = self.coop_busy_s.get(eid, 0.0) + dt_s

    def add_handover(self, src: int, dst: int, nbytes: int, t_s: float,
                     at_s: float = None):
        """Log one mid-request migration completing at virtual time t_s.
        ``at_s`` is the virtual time the migration was *decided* (the append
        time) — the shard-merge key; defaults to ``t_s``."""
        self._handovers.inc()
        self._migrated.inc(nbytes)
        if self.retain_records:
            self.handover_log.append((round(t_s, 9), src, dst, nbytes))
            self.handover_at.append(t_s if at_s is None else at_s)

    # ---------------------------------------------------------- elasticity
    def reject(self):
        """Count one shed arrival (admission policy 'reject'): an explicit
        outcome, never a silent drop — conservation is
        ``completed + rejected + in_flight == issued``."""
        self._rejected.inc()

    def mark_capacity(self, eid: int, cap: int, t_s: float):
        """Open the capacity timeline of an edge (engine: once per run at
        t=0 with the provisioned-at-build slot count)."""
        self._cap_mark[eid] = (t_s, cap)
        self.slot_s.setdefault(eid, 0.0)

    def on_scale(self, eid: int, old: int, new: int, t_s: float):
        """One capacity change point: bill the closed piecewise-constant
        segment into ``slot_s`` and log the event.  Segments are billed
        per edge in event order, so the integral is exactly reconstructable
        from ``capacity_log`` (tests/test_elastic.py pins float equality)."""
        t0, cap = self._cap_mark[eid]
        self.slot_s[eid] += cap * (t_s - t0)
        self._cap_mark[eid] = (t_s, new)
        self._scales.inc()
        if self.retain_records:
            self.capacity_log.append((round(t_s, 9), eid, old, new))
            self.scale_at.append(t_s)

    def finalize_capacity(self):
        """Close every edge's capacity timeline at the run horizon (engine:
        once after the event loop drains).  Idempotent per run end."""
        for eid in sorted(self._cap_mark):
            t0, cap = self._cap_mark[eid]
            end = max(self.horizon_s, t0)
            self.slot_s[eid] += cap * (end - t0)
            self._cap_mark[eid] = (end, cap)

    @property
    def rejected_count(self) -> int:
        return self._rejected.value

    # ------------------------------------------------------------ sharding
    @classmethod
    def merged(cls, parts: List["FleetMetrics"],
               num_edges: int) -> "FleetMetrics":
        """Fold per-tile metrics from a sharded run (repro.sim.shard) into
        the metrics the equivalent single-process run would have produced,
        bit-identically.

        Tiles are disjoint (block-diagonal reachability), so per-edge float
        aggregates never collide across parts and integer counters sum
        exactly.  The order-sensitive pieces — the latency / queue-delay
        sample buffers (``np.mean`` is a pairwise sum over the append
        order) and ``handover_log`` — are rebuilt by a *stable* merge of
        the per-tile append streams keyed on (append virtual time, tile
        index): the union event loop pops cross-tile events in time order,
        and grid-aligned ties (the sampling sweep) process devices in
        ascending id = tile order, which is exactly this key."""
        out = cls(num_edges=num_edges,
                  retain_records=all(p.retain_records for p in parts))
        rows = []
        for pi, p in enumerate(parts):
            rows.extend((k, pi, j) for j, k in enumerate(p.finish_keys))
        rows.sort(key=lambda r: (r[0], r[1]))   # stable: within-tile order
        for _, pi, j in rows:
            p = parts[pi]
            out._lat.observe(p._lat.samples[j])
            out._qd.observe(p._qd.samples[j])
            out.finish_keys.append(p.finish_keys[j])
            if out.retain_records:
                out.records.append(p.records[j])
        hrows = []
        for pi, p in enumerate(parts):
            hrows.extend((k, pi, j) for j, k in enumerate(p.handover_at))
        hrows.sort(key=lambda r: (r[0], r[1]))
        for k, pi, j in hrows:
            out.handover_log.append(parts[pi].handover_log[j])
            out.handover_at.append(k)
        # elasticity: tile-disjoint per-edge slot integrals insert plainly;
        # the scale-event log merges on its append keys like handover_log
        out.elastic = any(p.elastic for p in parts)
        out.usd_per_slot_hour = max(
            (p.usd_per_slot_hour for p in parts), default=0.0)
        srows = []
        for pi, p in enumerate(parts):
            srows.extend((k, pi, j) for j, k in enumerate(p.scale_at))
        srows.sort(key=lambda r: (r[0], r[1]))
        for k, pi, j in srows:
            out.capacity_log.append(parts[pi].capacity_log[j])
            out.scale_at.append(k)
        for p in parts:
            for eid, v in p.slot_s.items():
                out.slot_s[eid] = out.slot_s.get(eid, 0.0) + v
        for p in parts:
            out.horizon_s = max(out.horizon_s, p.horizon_s)
            out.transfer_events += p.transfer_events
            # per-edge / per-pair keys are tile-disjoint: plain insertion,
            # no cross-part float accumulation can occur
            for eid, v in p.edge_busy_s.items():
                out.edge_busy_s[eid] = out.edge_busy_s.get(eid, 0.0) + v
            for eid, v in p.coop_busy_s.items():
                out.coop_busy_s[eid] = out.coop_busy_s.get(eid, 0.0) + v
            for key, v in p.transfer_bytes.items():
                out.transfer_bytes[key] = out.transfer_bytes.get(key, 0) + v
            for name, inst in p.registry._instruments.items():
                if isinstance(inst, Counter):
                    out.registry.counter(name).value += inst.value
                elif isinstance(inst, CounterFamily):
                    fam = out.registry.family(name)
                    for label, v in inst.items():
                        fam.inc(label, v)
        return out

    @property
    def handover_count(self) -> int:
        return self._handovers.value

    @property
    def migrated_bytes_total(self) -> int:
        return self._migrated.value

    # ------------------------------------------------------------ summaries
    def summary(self) -> Dict:
        """Aggregate into one flat dict.  Pure function of the streaming
        aggregates — same seed, same summary, bitwise, with or without
        record retention (the determinism contract the tests and benchmarks
        assert).

        Schema-complete at every request count: with zero completed requests
        the same keys come back with zero/empty values and ``None`` for the
        undefined statistics (percentiles, mean queue delay, handover SLO),
        so consumers indexing e.g. ``p95_latency_s`` on an empty sweep cell
        never KeyError.  Non-request aggregates (handovers, backbone bytes,
        cooperative busy time, edge utilization) still report whatever was
        actually observed."""
        n = self._n.value
        horizon = max(self.horizon_s, 1e-9)
        util = {eid: round(self.edge_busy_s.get(eid, 0.0) / horizon, 6)
                for eid in range(self.num_edges)}
        out = {
            "requests": n,
            "coop_requests": self._coop.value,
            "handovers": self._handovers.value,
            "migrated_mb": round(self._migrated.value / 1e6, 6),
            # SLO attainment restricted to requests that migrated at least
            # once — how well handed-over requests still land their deadline
            "handover_slo": (self._moved_met.value / self._moved_n.value
                             if self._moved_n.value else None),
            "backbone_mb": round(sum(self.transfer_bytes.values()) / 1e6, 6),
            "coop_busy_s": {eid: round(v, 6)
                            for eid, v in sorted(self.coop_busy_s.items())},
            "slo_attainment": self._met.value / n if n else 0.0,
            "p50_latency_s": self._lat.percentile(50),
            "p95_latency_s": self._lat.percentile(95),
            "p99_latency_s": self._lat.percentile(99),
            "mean_queue_delay_s": self._qd.mean(),
            "makespan_s": float(self.horizon_s),
            "edge_utilization": util,
            "slo_by_tenant": {t: self._tenant_met.get(t, 0) / c
                              for t, c in sorted(self._tenant_n.items())},
            "exit_histogram": self._exits.as_dict(),
            "partition_histogram": self._parts.as_dict(),
        }
        if self.elastic:
            # schema-complete at every request count — including the
            # all-rejected run: n == 0 keeps percentiles/means at None
            # above (the zero-request convention) while the reject path
            # still reports exactly what happened.  Emitted only for
            # elastic runs so non-elastic summaries keep the pre-elastic
            # key set bit-identically.
            rej = self._rejected.value
            issued = n + rej
            slot_hours = sum(
                v for _, v in sorted(self.slot_s.items())) / 3600.0
            out["rejected"] = rej
            out["reject_rate"] = rej / issued if issued else 0.0
            out["scale_events"] = self._scales.value
            out["slot_hours"] = slot_hours
            out["cost_usd"] = self.usd_per_slot_hour * slot_hours
        return out
