"""Joint (edge-set, partition, exit) planning (arXiv:2310.12937).

``BandwidthAwareRouter`` optimizes sequentially: Algorithm 1 fixes (exit,
partition) for a *speed-1* edge, then placement shops that fixed plan around.
``JointPlanner`` searches the product space instead: for every candidate
edge set it runs the k-cut Algorithm-1 search *conditioned on that set's
speeds and this device's slowdown* (``CoInferenceStepper.plan_multi``, cached
on quantized bandwidth x edge-speed tuple x device slowdown), prices in
queueing at the primary and contention at the secondaries, and picks the
cheapest estimated completion.  Single-edge sets are always in the candidate
pool, so the joint decision degrades gracefully to bandwidth-aware routing
when cooperation does not pay.

Candidate sets are speed-ordered prefixes around each primary (every edge as
primary, partnered with the fastest other edges up to ``max_coop``), which
bounds the search to O(M * max_coop) sets per arrival — and the per-set
plans are shared fleet-wide through the stepper's plan cache.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import List, Optional, Sequence, Tuple

import numpy as np

from repro.core.partitioner import CoInferencePlan
from repro.serving.engine import quantize_bw
from repro.fleet.cluster import DeviceNode, EdgeNode, FleetTopology
from repro.fleet.coop import CoopAssignment, assign_spans


@dataclass
class JointDecision:
    plan: CoInferencePlan
    assign: CoopAssignment        # empty (k=0) for device-only plans
    est_s: float                  # estimated completion at the plan's exit
    est_min_s: float = 0.0        # estimated completion demoted to exit 1

    @property
    def local(self) -> bool:
        return self.plan.partition == 0

    @property
    def primary(self) -> int:
        return self.assign.eids[0]


class JointPlanner:
    """Joint (edge-set, partition, exit) search per arrival — and, with a
    :class:`~repro.fleet.mobility.MobilityModel` attached, per mid-request
    handover via :meth:`replan` (nearest-edge candidate ordering, per-primary
    bandwidths, and an explicit migration surcharge)."""

    def __init__(self, stepper, topo: FleetTopology, *, max_coop: int = 3,
                 prefill_div: int = 8, mobility=None, admission=None):
        self.stepper = stepper
        self.topo = topo
        self.max_coop = max(1, max_coop)
        self.prefill_div = prefill_div
        self.mobility = mobility
        # admission control (fleet.elastic.AdmissionControl, optional):
        # candidates whose *primary* is saturated are priced at +inf in
        # every decide path, so the search steers to less-loaded cells or
        # the device-only fallback before the engine's backstop rejects.
        # None (the default) skips the mask entirely — decisions are
        # bit-identical to the pre-admission planner.  replan() is left
        # unmasked: an in-flight request already holds its slot, and the
        # backlog terms it prices already penalize full cells.
        self.admission = admission
        self._sets = self._candidate_sets(topo)
        self._ordered_sets_cache = {}
        # decide() hot path: per (quantized bw, device slowdown) the plans,
        # assignments, and per-exit step times of every candidate set are
        # fixed — precompute them once as flat arrays and score arrivals
        # with elementwise numpy (see _score_tables)
        self._score_cache = {}
        # hit/miss counters for cache_stats() (repro.obs self-profiling)
        self.score_hits = self.score_misses = 0
        self.ordered_hits = self.ordered_misses = 0

    # ------------------------------------------------------------ candidates
    def _candidate_sets(self, topo: FleetTopology) -> List[Tuple[EdgeNode, ...]]:
        """Every edge as primary, extended by the fastest remaining edges
        (speed ascending = fastest first, tie-break on eid), one prefix per
        cooperative width 1..max_coop.  Deduplicated, deterministic order."""
        # the empty set is always a candidate: its plan degenerates to
        # device-only, so congested edges push arrivals back onto their own
        # device (offload admission control)
        out: List[Tuple[EdgeNode, ...]] = [()]
        seen = set()
        for primary in topo.edges:
            partners = sorted((e for e in topo.edges if e.eid != primary.eid),
                              key=lambda e: (e.speed, e.eid))
            for k in range(1, min(self.max_coop, len(partners) + 1) + 1):
                cand = (primary,) + tuple(partners[:k - 1])
                key = tuple(e.eid for e in cand)
                if key not in seen:
                    seen.add(key)
                    out.append(cand)
        return out

    def _ordered_sets(self, order: Tuple[int, ...]
                      ) -> List[Tuple[EdgeNode, ...]]:
        """Candidate sets built from an explicit *preference order* over edge
        ids (mobility: nearest-first): each prefix position is a primary,
        partnered with the next edges in order up to ``max_coop``.  Cached
        per order tuple — the order changes slowly (device motion), not per
        arrival."""
        hit = self._ordered_sets_cache.get(order)
        if hit is not None:
            self.ordered_hits += 1
            return hit
        self.ordered_misses += 1
        edges = {e.eid: e for e in self.topo.edges}
        out: List[Tuple[EdgeNode, ...]] = [()]
        seen = set()
        for primary in order:
            if self.max_coop == 1:
                # singleton candidates only: skip the O(M) partner scan per
                # primary (the default replan fan-out at fleet scale)
                out.append((edges[primary],))
                continue
            partners = [e for e in order if e != primary]
            for k in range(1, min(self.max_coop, len(partners) + 1) + 1):
                key = (primary,) + tuple(partners[:k - 1])
                if key not in seen:
                    seen.add(key)
                    out.append(tuple(edges[e] for e in key))
        self._ordered_sets_cache[order] = out
        return out

    def cache_stats(self) -> dict:
        """Hit/miss/size per memo (score tables, ordered candidate sets) —
        surfaced by ``repro.obs.SimProfiler.report`` under
        ``replanner_caches`` when the engine's replanner is a JointPlanner."""
        def block(hits: int, misses: int, entries: int) -> dict:
            total = hits + misses
            return {"hits": hits, "misses": misses, "entries": entries,
                    "hit_rate": round(hits / total, 6) if total else None}
        return {
            "score": block(self.score_hits, self.score_misses,
                           len(self._score_cache)),
            "ordered_sets": block(self.ordered_hits, self.ordered_misses,
                                  len(self._ordered_sets_cache)),
        }

    # ------------------------------------------------------------ decision
    def _score_tables(self, bw: float, device: DeviceNode,
                      topo: FleetTopology) -> dict:
        """Per-(quantized bandwidth, device slowdown) candidate tensors:
        plan, assignment, and per-exit step times of every kept candidate
        set, flattened into arrays so :meth:`decide` scores one arrival with
        a handful of elementwise numpy ops.  Built once per key by replaying
        the scalar candidate loop (which also warms the shared plan cache
        exactly as the scalar path would)."""
        key = (quantize_bw(bw), device.slowdown)
        hit = self._score_cache.get(key)
        if hit is not None:
            self.score_hits += 1
            return hit
        self.score_misses += 1
        plans, assigns, accs, t_exit, t_min = [], [], [], [], []
        is_local, primaries, sec = [], [], []
        for cand in self._sets:
            speeds = tuple(e.speed for e in cand)
            plan = self.stepper.plan_multi(
                bw, speeds, device_load=device.slowdown,
                edge_bw_bps=topo.edge_bw_bps)
            if (plan.partition == 0) != (len(cand) == 0):
                continue               # collapsed duplicate of device-only
            if plan.partition == 0:
                assign = CoopAssignment((), (), ())
                per_exit = self.stepper.per_exit_times_cached(
                    0, bw, device_load=device.slowdown)
                is_local.append(True)
                primaries.append(0)
                sec.append([])
            else:
                assign = assign_spans(plan.partition, cand)
                per_exit = self.stepper.per_exit_times_coop_cached(
                    plan.partition, assign.speeds, bw,
                    device_load=device.slowdown,
                    edge_bw_bps=topo.edge_bw_bps, include_input=False)
                is_local.append(False)
                # SoA row indices (eid - eid0): global only when the
                # planner serves the whole fleet, tile-local under sharding
                primaries.append(assign.eids[0] - topo.eid0)
                sec.append([(eid - topo.eid0, frac) for eid, frac in
                            zip(assign.eids[1:],
                                assign.span_fractions()[1:])])
            plans.append(plan)
            assigns.append(assign)
            accs.append(plan.accuracy)
            t_exit.append(per_exit[plan.exit_point - 1])
            t_min.append(per_exit[0])
        c = len(plans)
        s_max = max((len(s) for s in sec), default=0)
        sec_idx = np.zeros((c, s_max), dtype=int)
        sec_frac = np.zeros((c, s_max))
        for i, pairs in enumerate(sec):
            for j, (eid, frac) in enumerate(pairs):
                sec_idx[i, j], sec_frac[i, j] = eid, frac
        order = sorted(range(c), key=lambda i: assigns[i].eids)
        rank = np.empty(c, dtype=int)
        rank[order] = np.arange(c)
        hit = {
            "plans": plans, "assigns": assigns,
            "acc": np.array(accs), "t_exit": np.array(t_exit),
            "t_min": np.array(t_min), "local": np.array(is_local),
            "primary": np.array(primaries, dtype=int),
            "sec_idx": sec_idx, "sec_frac": sec_frac, "rank": rank,
        }
        self._score_cache[key] = hit
        return hit

    def decide(self, req, device: DeviceNode, topo: FleetTopology,
               now: float) -> JointDecision:
        """Algorithm-1 semantics lifted to the fleet: among candidates whose
        *estimated completion* (plan latency + current queueing) meets the
        request's deadline, take the most accurate exit (tie-break cheaper
        estimate, then lower edge ids); if none fits, minimize the estimate
        — the fleet analogue of ``optimize_with_fallback``.

        Scoring is vectorized over the candidate tensors of
        :meth:`_score_tables`; every arithmetic step applies the same float
        ops in the same order as :meth:`decide_scalar`, so the two paths
        pick bit-identical decisions (property-pinned by
        tests/test_fleet_perf.py).

        With a mobility model attached, candidates are instead priced at
        the bandwidth the device would see *to each candidate's primary*
        (as :meth:`replan` always has) — the device's own link reports the
        best-signal edge, and pricing a far primary's uplink at that rate
        systematically over-admits far edges (docs/fleet.md)."""
        if self.mobility is not None:
            return self._decide_mobile(req, device, topo, now)
        bw = device.link.bw_at(now)
        tab = self._score_tables(bw, device, topo)
        blg = topo.backlog_s_row()     # vectorized EdgeNode.backlog_s row
        input_t = self.stepper.graph.input_bytes / bw
        base = np.where(tab["local"], device.local_backlog_s(now),
                        blg[tab["primary"]] + input_t)
        # secondary backlog surcharges, span order (padded columns add 0.0)
        for j in range(tab["sec_idx"].shape[1]):
            base = base + blg[tab["sec_idx"][:, j]] * tab["sec_frac"][:, j]
        prefill_steps = max(1, req.prompt_len // self.prefill_div)
        est = base + tab["t_exit"] * prefill_steps + \
            tab["t_exit"] * req.max_new_tokens
        est_min = base + tab["t_exit"] * prefill_steps + \
            tab["t_min"] * req.max_new_tokens
        if self.admission is not None:
            # saturated primaries are unroutable: +inf drops them from the
            # feasible set and the fallback argmin alike (the device-only
            # candidate always keeps a finite estimate)
            sat = self.admission.saturated_row(topo)
            mask = ~tab["local"] & sat[tab["primary"]]
            est = np.where(mask, np.inf, est)
            est_min = np.where(mask, np.inf, est_min)
        feasible = np.flatnonzero(est <= req.deadline_s - now)
        if len(feasible):
            # max accuracy, then min estimate, then lowest eids (rank):
            # float equality grouping mirrors the tuple-key min()
            acc = tab["acc"][feasible]
            sub = feasible[acc == acc.max()]
            sub = sub[est[sub] == est[sub].min()]
            i = int(sub[tab["rank"][sub].argmin()])
        else:
            sub = np.flatnonzero(est_min == est_min.min())
            i = int(sub[tab["rank"][sub].argmin()])
        return JointDecision(plan=tab["plans"][i], assign=tab["assigns"][i],
                             est_s=float(est[i]),
                             est_min_s=float(est_min[i]))

    def _decide_mobile(self, req, device: DeviceNode, topo: FleetTopology,
                       now: float) -> JointDecision:
        """Per-primary pricing for :meth:`decide` under mobility: one
        geometry row per arrival, each candidate set priced at the
        bandwidth to *its own* primary (the device-only candidate at the
        nearest edge's rate, which is what ``device.link.bw_at`` reports).
        Selection semantics are identical to the static path."""
        did = device.did
        drow = self.mobility.distance_row(did, now)
        brow = self.mobility.bw_row(did, now)
        nearest_i = int(np.argmin(drow))
        blg = topo.backlog_s_row()
        prefill_steps = max(1, req.prompt_len // self.prefill_div)
        cands: List[JointDecision] = []
        for cand in self._sets:
            i0 = (cand[0].eid - topo.eid0) if cand else nearest_i
            bw = float(brow[i0])
            speeds = tuple(e.speed for e in cand)
            plan = self.stepper.plan_multi(
                bw, speeds, device_load=device.slowdown,
                edge_bw_bps=topo.edge_bw_bps)
            if (plan.partition == 0) != (len(cand) == 0):
                continue               # collapsed duplicate of device-only
            if plan.partition == 0:
                assign = CoopAssignment((), (), ())
                per_exit = self.stepper.per_exit_times_cached(
                    0, bw, device_load=device.slowdown)
                base = device.local_backlog_s(now)
            else:
                assign = assign_spans(plan.partition, cand)
                per_exit = self.stepper.per_exit_times_coop_cached(
                    plan.partition, assign.speeds, bw,
                    device_load=device.slowdown,
                    edge_bw_bps=topo.edge_bw_bps, include_input=False)
                base = float(blg[assign.eids[0] - topo.eid0]) + \
                    self.stepper.input_time(plan.partition, bw)
                for frac, eid in zip(assign.span_fractions()[1:],
                                     assign.eids[1:]):
                    base += float(blg[eid - topo.eid0]) * frac
            prefill = per_exit[plan.exit_point - 1] * prefill_steps
            est = base + prefill + \
                per_exit[plan.exit_point - 1] * req.max_new_tokens
            est_min = base + prefill + per_exit[0] * req.max_new_tokens
            if self.admission is not None and plan.partition != 0 \
                    and self.admission.saturated(topo.edge(assign.eids[0])):
                est = est_min = float("inf")
            cands.append(JointDecision(plan=plan, assign=assign,
                                       est_s=est, est_min_s=est_min))
        slack = req.deadline_s - now
        feasible = [d for d in cands if d.est_s <= slack]
        if feasible:
            return min(feasible, key=lambda d: (-d.plan.accuracy, d.est_s,
                                                d.assign.eids))
        return min(cands, key=lambda d: (d.est_min_s, d.assign.eids))

    def decide_scalar(self, req, device: DeviceNode, topo: FleetTopology,
                      now: float) -> JointDecision:
        """Reference implementation of :meth:`decide` (one Python loop over
        candidate sets) — kept as the oracle the vectorized path is tested
        against.  Prices per-primary when a mobility model is attached,
        matching :meth:`_decide_mobile` (scalar geometry calls instead of
        rows)."""
        link_bw = device.link.bw_at(now)
        cands: List[JointDecision] = []
        for cand in self._sets:
            if self.mobility is not None and cand:
                bw = self.mobility.bw(device.did, cand[0].eid, now)
            else:
                bw = link_bw
            speeds = tuple(e.speed for e in cand)
            plan = self.stepper.plan_multi(
                bw, speeds, device_load=device.slowdown,
                edge_bw_bps=topo.edge_bw_bps)
            # the engine bills prompt_len/prefill_div prefill steps at the
            # plan exit on admission — estimate the same way or marginal
            # requests look feasible when they are not
            prefill_steps = max(1, req.prompt_len // self.prefill_div)
            if plan.partition == 0:
                assign = CoopAssignment((), (), ())
                per_exit = self.stepper.per_exit_times_cached(
                    0, bw, device_load=device.slowdown)
                # the device runs local requests serially — queue behind its
                # in-flight work exactly as edge candidates queue behind
                # theirs
                base = device.local_backlog_s(now)
            else:
                assign = assign_spans(plan.partition, cand)
                per_exit = self.stepper.per_exit_times_coop_cached(
                    plan.partition, assign.speeds, bw,
                    device_load=device.slowdown,
                    edge_bw_bps=topo.edge_bw_bps, include_input=False)
                primary = topo.edge(assign.eids[0])
                base = primary.backlog_s() + \
                    self.stepper.input_time(plan.partition, bw)
                # secondaries are contended resources too: bill their current
                # backlog against this plan in proportion to the span of work
                # we would place there
                for frac, eid in zip(assign.span_fractions()[1:],
                                     assign.eids[1:]):
                    base += topo.edge(eid).backlog_s() * frac
            prefill = per_exit[plan.exit_point - 1] * prefill_steps
            est = base + prefill + \
                per_exit[plan.exit_point - 1] * req.max_new_tokens
            est_min = base + prefill + per_exit[0] * req.max_new_tokens
            if self.admission is not None and plan.partition != 0 \
                    and self.admission.saturated(topo.edge(assign.eids[0])):
                # the vectorized path's saturation mask, scalar form
                est = est_min = float("inf")
            if (plan.partition == 0) == (len(cand) == 0):
                # keep one canonical device-only candidate (the empty set);
                # a non-empty set whose plan collapsed to partition 0 is a
                # duplicate of it
                cands.append(JointDecision(plan=plan, assign=assign,
                                           est_s=est, est_min_s=est_min))
        slack = req.deadline_s - now
        feasible = [d for d in cands if d.est_s <= slack]
        if feasible:
            return min(feasible, key=lambda d: (-d.plan.accuracy, d.est_s,
                                                d.assign.eids))
        # nothing fits at its plan exit: the engine will demote per round, so
        # judge candidates by what they can achieve at the earliest exit
        return min(cands, key=lambda d: (d.est_min_s, d.assign.eids))

    # ------------------------------------------------------------ replan
    def replan(self, req, device: DeviceNode, topo: FleetTopology,
               now: float, *, allow_local: bool = False,
               move_cost_s: float = 0.0) -> Optional[JointDecision]:
        """Mid-request replan hook (mobility handover, docs/handover.md).

        Re-searches (edge set, partition, exit) for a request that is
        *already in flight*: only the remaining decode tokens count, the
        input payload and prefill are sunk costs unless the request has not
        prefilled yet, and moving to a primary other than ``req.edge`` pays
        ``move_cost_s`` (the state-transfer time over the backbone) — which
        makes staying put the default when no candidate genuinely wins.

        Candidates are ordered **nearest-first** when a mobility model is
        attached (each of the nearest edges as primary, partnered with the
        next-nearest up to ``max_coop``) and each candidate is priced at the
        bandwidth the device would actually see *to that primary*.
        ``allow_local=True`` additionally admits the device-only fallback
        (only safe before prefill — afterwards the edge holds state the
        device cannot absorb).  Returns ``None`` when every candidate
        collapses to an unusable plan: the caller keeps the request where
        it is."""
        did = device.did
        eid0 = topo.eid0
        drow = brow = None
        if self.mobility is not None:
            # one vectorized geometry row per replan instead of M scalar
            # path-loss evaluations per candidate (entries are bit-identical
            # to mobility.distance/bw)
            drow = self.mobility.distance_row(did, now)
            brow = self.mobility.bw_row(did, now)
            order = tuple(sorted(range(eid0, eid0 + topo.num_edges),
                                 key=lambda e: (drow[e - eid0], e)))
        else:
            order = tuple(e.eid for e in sorted(
                topo.edges, key=lambda e: (e.speed, e.eid)))
        blg = topo.backlog_s_row()     # vectorized EdgeNode.backlog_s row
        tokens_left = req.max_new_tokens - req.tokens_done
        prefill_steps = max(1, req.prompt_len // self.prefill_div)
        cands: List[JointDecision] = []
        for cand in self._ordered_sets(order):
            if not cand and not allow_local:
                continue
            if self.mobility is not None:
                primary_eid = cand[0].eid if cand \
                    else eid0 + int(np.argmin(drow))
                bw = float(brow[primary_eid - eid0])
            else:
                bw = device.link.bw_at(now)
            speeds = tuple(e.speed for e in cand)
            plan = self.stepper.plan_multi(
                bw, speeds, device_load=device.slowdown,
                edge_bw_bps=topo.edge_bw_bps)
            if (plan.partition == 0) != (len(cand) == 0):
                # collapsed duplicates of the device-only candidate (or an
                # empty set that somehow kept a partition) are skipped
                continue
            if plan.partition == 0:
                assign = CoopAssignment((), (), ())
                per_exit = self.stepper.per_exit_times_cached(
                    0, bw, device_load=device.slowdown)
                base = device.local_backlog_s(now)
                prefill = per_exit[plan.exit_point - 1] * prefill_steps
            else:
                assign = assign_spans(plan.partition, cand)
                per_exit = self.stepper.per_exit_times_coop_cached(
                    plan.partition, assign.speeds, bw,
                    device_load=device.slowdown,
                    edge_bw_bps=topo.edge_bw_bps, include_input=False)
                primary = topo.edge(assign.eids[0])
                base = float(blg[assign.eids[0] - eid0])
                for frac, eid in zip(assign.span_fractions()[1:],
                                     assign.eids[1:]):
                    base += float(blg[eid - eid0]) * frac
                if req.edge >= 0 and assign.eids[0] == req.edge:
                    # the request's own owed tokens sit in this backlog;
                    # pricing them against itself would bias every replan
                    # toward a spurious migration to an idle edge
                    per_round = primary.ema_round_s \
                        if primary.ema_round_s > 0 else 1e-3
                    base = max(0.0, base - per_round * tokens_left /
                               max(primary.capacity, 1))
                elif req.edge >= 0:
                    base += move_cost_s
                prefill = 0.0
                if req.prefill_pending:
                    prefill = self.stepper.input_time(plan.partition, bw) + \
                        per_exit[plan.exit_point - 1] * prefill_steps
            est = base + prefill + \
                per_exit[plan.exit_point - 1] * tokens_left
            est_min = base + prefill + per_exit[0] * tokens_left
            cands.append(JointDecision(plan=plan, assign=assign,
                                       est_s=est, est_min_s=est_min))
        if not cands:
            return None
        slack = req.deadline_s - now
        feasible = [d for d in cands if d.est_s <= slack]
        if feasible:
            return min(feasible, key=lambda d: (-d.plan.accuracy, d.est_s,
                                                d.assign.eids))
        return min(cands, key=lambda d: (d.est_min_s, d.assign.eids))
