"""Cooperative multi-edge execution plans (CoEdge, arXiv:2012.03257).

A cooperative plan runs one request's edge portion across an *ordered set*
of edges: edge ``i`` owns the contiguous layer span ``[cuts[i-1], cuts[i])``
of the chosen branch, sized proportionally to its throughput (``1/speed``),
and hands the boundary activation to the next edge over the topology's
edge<->edge backbone link (``FleetTopology.edge_bw_bps``).  The device still
pays the wireless uplink once and receives the final cut activation per
token, exactly as in the single-edge case — a cooperative plan with one edge
*is* the single-edge plan (bit-exact; tests/test_coop.py).

The span math lives in ``repro.core.partitioner`` (``proportional_cuts``,
``multi_branch_latency``); this module binds it to concrete ``EdgeNode``s.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import List, Sequence, Tuple

from repro.core.graph import InferenceGraph
from repro.core.partitioner import proportional_cuts
from repro.fleet.cluster import EdgeNode


@dataclass(frozen=True)
class CoopAssignment:
    """Ordered edge spans for one request: ``eids[i]`` runs layers
    ``[cuts[i-1], cuts[i])`` at speed ``speeds[i]``.  ``eids[0]`` is the
    *primary* edge — it owns the request's queue slot and decode rounds;
    the others contribute span compute and appear via transfer events."""
    eids: Tuple[int, ...]
    speeds: Tuple[float, ...]
    cuts: Tuple[int, ...]

    @property
    def k(self) -> int:
        return len(self.eids)

    @property
    def partition(self) -> int:
        return self.cuts[-1] if self.cuts else 0

    def spans(self) -> List[Tuple[int, int, int]]:
        """[(eid, start, end)] over the edge portion."""
        out, start = [], 0
        for eid, cut in zip(self.eids, self.cuts):
            out.append((eid, start, cut))
            start = cut
        return out

    def span_fractions(self) -> Tuple[float, ...]:
        hit = _FRAC_MEMO.get(self)
        if hit is not None:
            return hit
        p = self.partition
        if p <= 0:
            fr = (0.0,) * self.k
        else:
            out, start = [], 0
            for cut in self.cuts:
                out.append((cut - start) / p)
                start = cut
            fr = tuple(out)
        _FRAC_MEMO[self] = fr
        return fr


# pure-value memos for the per-arrival/per-round hot paths: assignments and
# their span fractions are small immutable values asked for millions of
# times at fleet scale
_FRAC_MEMO: dict = {}
_ASSIGN_MEMO: dict = {}


def assign_spans(partition: int, edges: Sequence[EdgeNode]) -> CoopAssignment:
    """Size contiguous spans over ``[0, partition)`` proportionally to each
    edge's throughput; edges whose share rounds to zero layers are dropped
    (so the realized set can be smaller than the candidate set).  Pure in
    ``(partition, [(eid, speed)])`` — memoized."""
    key = (partition, tuple((e.eid, e.speed) for e in edges))
    hit = _ASSIGN_MEMO.get(key)
    if hit is not None:
        return hit
    speeds = tuple(e.speed for e in edges)
    cuts, keep = proportional_cuts(partition, speeds)
    out = CoopAssignment(eids=tuple(edges[i].eid for i in keep),
                         speeds=tuple(speeds[i] for i in keep),
                         cuts=cuts)
    _ASSIGN_MEMO[key] = out
    return out


def effective_assignment(graph: InferenceGraph, exit_point: int,
                         assign: CoopAssignment) -> CoopAssignment:
    """Re-derive the assignment for a (possibly demoted) exit: the branch
    may be shorter than the planned partition, so clamp and re-split —
    exactly the cuts :meth:`CoInferenceStepper.per_exit_times_coop_cached`
    bills for that exit, keeping hop/busy accounting consistent with the
    latency model.  Returns ``assign`` unchanged when nothing clamps."""
    n = len(graph.branches[exit_point - 1])
    p = min(assign.partition, n)
    if p == assign.partition:
        return assign
    cuts, keep = proportional_cuts(p, assign.speeds)
    return CoopAssignment(eids=tuple(assign.eids[i] for i in keep),
                          speeds=tuple(assign.speeds[i] for i in keep),
                          cuts=cuts)


def span_seconds(graph: InferenceGraph, exit_point: int,
                 assign: CoopAssignment, f_edge) -> List[float]:
    """Per-span compute seconds (speed-scaled) of one decode round — what
    each participating edge is busy for while the chain passes through it."""
    branch = graph.branches[exit_point - 1]
    n = len(branch)
    out, start = [], 0
    for speed, cut in zip(assign.speeds, assign.cuts):
        out.append(sum(f_edge.predict(branch[j]) * speed
                       for j in range(start, min(cut, n))))
        start = cut
    return out


def hop_schedule(graph: InferenceGraph, exit_point: int,
                 assign: CoopAssignment, f_edge,
                 edge_bw_bps: float) -> List[Tuple[float, int, int, int]]:
    """Relative timeline of the inter-edge hand-offs within one decode round:
    ``[(dt_s, from_eid, to_eid, nbytes)]`` where ``dt_s`` is the offset from
    round start at which the hop *completes* (span compute so far + transfer
    times so far).  Used by the fleet engine to emit ``transfer`` events on
    the virtual clock."""
    branch = graph.branches[exit_point - 1]
    n = len(branch)
    out: List[Tuple[float, int, int, int]] = []
    t, start = 0.0, 0
    for i, (eid, cut) in enumerate(zip(assign.eids, assign.cuts)):
        for j in range(start, min(cut, n)):
            t += f_edge.predict(branch[j]) * assign.speeds[i]
        if i < assign.k - 1:
            nbytes = graph.cut_bytes(exit_point, cut)
            t += nbytes / edge_bw_bps
            out.append((t, eid, assign.eids[i + 1], nbytes))
        start = cut
    return out
