"""Fleet workload generators: arrival processes + multi-tenant SLO mix.

Arrivals are Poisson (constant rate) or diurnal (sinusoidal rate, generated
by thinning), stamped onto devices either uniformly or with a power-law skew
(a few hot devices produce most of the traffic).  Each request draws a
tenant class fixing its SLO and decode length.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import List, Optional, Sequence

import numpy as np


@dataclass(frozen=True)
class TenantClass:
    name: str
    slo_s: float
    max_new_tokens: int
    weight: float


DEFAULT_TENANTS = (
    TenantClass("interactive", slo_s=0.25, max_new_tokens=4, weight=0.5),
    TenantClass("standard", slo_s=1.0, max_new_tokens=8, weight=0.35),
    TenantClass("batch", slo_s=4.0, max_new_tokens=16, weight=0.15),
)


@dataclass(eq=False)
class FleetRequest:
    """One request in a fleet simulation: identity + SLO contract up top,
    engine-owned runtime state below (reset by every ``FleetEngine.run``).

    ``eq=False``: requests are unique live objects — membership tests and
    removals on engine queues are identity checks, not field-by-field
    comparisons (which sat on the hot path and are ambiguous once ``prompt``
    holds an array)."""
    rid: int
    device: int
    tenant: str
    slo_s: float
    max_new_tokens: int
    arrival_s: float
    prompt_len: int = 8
    prompt: Optional[np.ndarray] = None
    # --- runtime state (owned by FleetEngine) ---
    edge: int = -1
    assign: object = None        # CoopAssignment for multi-edge plans
    admitted_s: Optional[float] = None
    tokens_done: int = 0
    prefill_pending: bool = True
    plan: object = None
    exit_point: int = 0
    cache: object = None
    next_tok: object = None
    tokens: List[int] = field(default_factory=list)
    # --- mobility / handover state (docs/handover.md) ---
    replan_pending: bool = False  # policy fired; resolve at round boundary
    migrating: bool = False       # state snapshot in flight on the backbone
    coop_counted: bool = False    # holds coop_inflight slots at secondaries
    handovers: int = 0            # completed mid-request migrations
    migrated_bytes: int = 0       # state bytes shipped across all handovers

    @property
    def deadline_s(self) -> float:
        return self.arrival_s + self.slo_s


def poisson_arrivals(rate_hz: float, horizon_s: float,
                     rng: np.random.Generator) -> np.ndarray:
    """Homogeneous Poisson arrival times on [0, horizon)."""
    if rate_hz <= 0:
        return np.empty(0)
    n = rng.poisson(rate_hz * horizon_s)
    return np.sort(rng.uniform(0.0, horizon_s, n))


def diurnal_rate(t_s: float, base_hz: float, peak_hz: float,
                 period_s: float) -> float:
    """Sinusoidal day curve: base at t=0, peak at half period."""
    phase = 0.5 * (1.0 - np.cos(2.0 * np.pi * t_s / period_s))
    return base_hz + (peak_hz - base_hz) * phase


def diurnal_arrivals(base_hz: float, peak_hz: float, period_s: float,
                     horizon_s: float, rng: np.random.Generator) -> np.ndarray:
    """Inhomogeneous Poisson arrivals by thinning against ``peak_hz``.

    ``diurnal_rate`` is pure ufunc math, so evaluating it on the whole
    candidate vector is bit-identical to the per-candidate scalar loop."""
    cand = poisson_arrivals(peak_hz, horizon_s, rng)
    keep = rng.uniform(0.0, 1.0, len(cand)) * peak_hz <= \
        diurnal_rate(cand, base_hz, peak_hz, period_s)
    return cand[keep]


def make_workload(num_devices: int, *, rate_hz: float, horizon_s: float,
                  seed: int = 0, arrival: str = "poisson",
                  tenants: Sequence[TenantClass] = DEFAULT_TENANTS,
                  device_skew: float = 0.0, peak_factor: float = 4.0,
                  period_s: Optional[float] = None, prompt_len: int = 8,
                  vocab_size: int = 0, rid0: int = 0,
                  did0: int = 0) -> List[FleetRequest]:
    """Generate the request stream for one simulation.

    ``rate_hz`` is the *fleet-wide* mean arrival rate.  ``device_skew`` > 0
    concentrates traffic on low-index devices with p(i) ~ (i+1)^-skew.
    ``vocab_size`` > 0 additionally samples real token prompts (needed only
    when the fleet engine executes the actual model).  ``rid0``/``did0``
    offset request and device ids into a fleet-global namespace — geography
    tiles (repro.sim.shard) generate their own streams with disjoint ids.
    """
    rng = np.random.default_rng(seed)
    if arrival == "poisson":
        times = poisson_arrivals(rate_hz, horizon_s, rng)
    elif arrival == "diurnal":
        period = period_s if period_s is not None else horizon_s
        base = 2.0 * rate_hz / (1.0 + peak_factor)
        times = diurnal_arrivals(base, base * peak_factor, period,
                                 horizon_s, rng)
    else:
        raise ValueError(f"unknown arrival process: {arrival!r}")

    dev_w = (np.arange(num_devices) + 1.0) ** -device_skew
    dev_w /= dev_w.sum()
    ten_w = np.array([t.weight for t in tenants], float)
    ten_w /= ten_w.sum()

    # Inverse-CDF sampling with the cumulative weights built once.  Each
    # draw consumes exactly one uniform double and lands on the same index
    # as ``rng.choice(n, p=w)`` (which rebuilds the O(n) CDF per call —
    # the build-time bottleneck at 10k+ devices), so request streams are
    # bit-identical to the per-call form.
    dev_cdf = np.cumsum(dev_w)
    dev_cdf /= dev_cdf[-1]
    ten_cdf = np.cumsum(ten_w)
    ten_cdf /= ten_cdf[-1]
    n_ten = len(tenants)

    reqs: List[FleetRequest] = []
    times_l = times.tolist()
    for rid, t in enumerate(times_l):
        dev = min(int(dev_cdf.searchsorted(rng.random(), side="right")),
                  num_devices - 1)
        ten = tenants[min(int(ten_cdf.searchsorted(rng.random(),
                                                   side="right")), n_ten - 1)]
        prompt = rng.integers(0, vocab_size, prompt_len).astype(np.int32) \
            if vocab_size > 0 else None
        reqs.append(FleetRequest(
            rid=rid0 + rid, device=did0 + dev, tenant=ten.name,
            slo_s=ten.slo_s,
            max_new_tokens=ten.max_new_tokens, arrival_s=t,
            prompt_len=prompt_len, prompt=prompt))
    return reqs
