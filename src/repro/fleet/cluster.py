"""Heterogeneous fleet topology: N device nodes x M edge nodes.

Each :class:`DeviceNode` carries its own bandwidth trace (an independent
draw from ``repro.data.bandwidth``) and a compute-slowdown factor; each
:class:`EdgeNode` is a capacity-limited continuous-batching server with a
speed factor (>1 = slower hardware), so a fleet can mix one beefy edge with
several weak ones — the regime where routing policy matters.

Hot per-node state (``tokens_owed``, the backlog EMA, ``coop_inflight``,
``busy_until_s``) is stored struct-of-arrays on :class:`FleetTopology` so
routers and replan candidate scans read whole vectorized rows instead of
looping node objects (docs/performance.md).  Node attributes remain the
API — they are properties that index into the owning topology's arrays —
so engine code mutates scalars while routers read rows, with one storage
location for both.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import List, Optional

import numpy as np

from repro.data.bandwidth import belgium_lte_like, oboe_like_traces


@dataclass
class TraceLink:
    """Time-indexed bandwidth trace (bytes/s), one per device.

    Unlike ``serving.tiers.Link`` (stepped once per decode iteration of a
    single engine), fleet links are read at *virtual timestamps* so that
    concurrent edges observe a consistent bandwidth history."""
    trace_bps: np.ndarray
    dt_s: float = 1.0

    def __post_init__(self):
        # hot path: plain-list indexing returns Python floats directly,
        # skipping per-call ndarray scalar boxing (same float64 values)
        self._trace = np.asarray(self.trace_bps, dtype=float).tolist()

    def bw_at(self, t_s: float) -> float:
        i = min(max(int(t_s / self.dt_s), 0), len(self._trace) - 1)
        return self._trace[i]


class _SoA:
    """Array bundle backing the hot node state of one fleet.  Owned by the
    :class:`FleetTopology` that bound it; nodes keep ``(_soa, _idx)`` and
    delegate their hot attributes here."""

    __slots__ = ("tokens_owed", "ema_round_s", "coop_inflight", "backlog_n",
                 "dev_busy_until_s", "capacity", "edge_cap_div")

    def __init__(self, num_edges: int, num_devices: int,
                 capacities: np.ndarray):
        self.tokens_owed = np.zeros(num_edges, np.int64)
        self.ema_round_s = np.zeros(num_edges)
        self.coop_inflight = np.zeros(num_edges, np.int64)
        # engine-maintained mirror of EdgeNode.backlog() (requests queued +
        # in the batch, tombstones excluded); lets JSQ routing argmin an
        # integer row instead of walking edge objects
        self.backlog_n = np.zeros(num_edges, np.int64)
        self.dev_busy_until_s = np.zeros(num_devices)
        # *live* provisioned decode slots per edge: static unless an
        # Autoscaler (fleet.elastic) drives `scale` events through the
        # engine, which mutate this via the EdgeNode.capacity setter
        self.capacity = np.asarray(capacities, np.int64).copy()
        # float64 of max(capacity, 1): integer-valued, so dividing by it is
        # bit-identical to the scalar ``/ max(e.capacity, 1)``; kept in
        # lock-step with `capacity` by the setter
        self.edge_cap_div = np.maximum(capacities, 1).astype(float)


@dataclass
class DeviceNode:
    """One end-user device: a bandwidth link (``TraceLink`` for static
    fleets, ``fleet.mobility.MobileLink`` under mobility) plus a compute
    slowdown; executes device-side partitions serially."""
    did: int
    link: object                 # TraceLink | MobileLink (duck-typed bw_at)
    slowdown: float = 1.0        # device-tier compute multiplier (>=1 = slower)

    def __post_init__(self):
        self._soa: Optional[_SoA] = None
        self._idx = -1
        self._busy = 0.0

    # --- runtime state (owned by FleetEngine; SoA-backed once bound) ---
    @property
    def busy_until_s(self) -> float:
        """Device-local execution is serial: one request at a time, later
        ones queue behind this timestamp."""
        s = self._soa
        return float(s.dev_busy_until_s[self._idx]) if s is not None \
            else self._busy

    @busy_until_s.setter
    def busy_until_s(self, v: float) -> None:
        s = self._soa
        if s is not None:
            s.dev_busy_until_s[self._idx] = v
        else:
            self._busy = v

    def local_backlog_s(self, now: float) -> float:
        return max(0.0, self.busy_until_s - now)


@dataclass
class EdgeNode:
    eid: int
    capacity: int = 8            # concurrent decode slots (continuous-batch width)
    speed: float = 1.0           # edge-tier compute multiplier (>=1 = slower)
    # --- runtime state (owned by FleetEngine) ---
    queue: list = field(default_factory=list)   # EDF heap: [deadline, seq, req]
    #                              entries; req slot None = tombstoned by a
    #                              replan (lazy deletion, see FleetEngine)
    q_dead: int = 0              # tombstoned entries still sitting in `queue`
    active: list = field(default_factory=list)  # requests in the running batch
    round_inflight: bool = False
    busy_s: float = 0.0
    completed: int = 0

    def __post_init__(self):
        self._soa: Optional[_SoA] = None
        self._idx = -1
        self._ema = 0.0
        self._coop = 0
        self._tokens = 0

    # --- SoA-backed hot state (vectorized row reads via FleetTopology) ---
    @property
    def ema_round_s(self) -> float:
        s = self._soa
        return float(s.ema_round_s[self._idx]) if s is not None else self._ema

    @ema_round_s.setter
    def ema_round_s(self, v: float) -> None:
        s = self._soa
        if s is not None:
            s.ema_round_s[self._idx] = v
        else:
            self._ema = v

    @property
    def coop_inflight(self) -> int:
        """*Planned* cooperative span memberships for requests slotted at
        other edges; per-round demotion may temporarily shrink the spans
        actually executed (see coop_busy_s in FleetMetrics for realized
        compute)."""
        s = self._soa
        return int(s.coop_inflight[self._idx]) if s is not None else self._coop

    @coop_inflight.setter
    def coop_inflight(self, v: int) -> None:
        s = self._soa
        if s is not None:
            s.coop_inflight[self._idx] = v
        else:
            self._coop = v

    @property
    def tokens_owed(self) -> int:
        """Decode tokens still owed to queued+active requests (FleetEngine:
        +max_new_tokens on enqueue, -1 per request per round)."""
        s = self._soa
        return int(s.tokens_owed[self._idx]) if s is not None else self._tokens

    @tokens_owed.setter
    def tokens_owed(self, v: int) -> None:
        s = self._soa
        if s is not None:
            s.tokens_owed[self._idx] = v
        else:
            self._tokens = v

    def backlog(self) -> int:
        """Requests currently bound to this edge (queued + in the batch);
        tombstoned queue entries are already gone logically."""
        return len(self.queue) - self.q_dead + len(self.active)

    def backlog_s(self) -> float:
        """Pending-work estimate (seconds) for latency-aware routing: tokens
        still owed to queued + active requests, amortized over the batch
        width at the recent round time.  Counting *tokens* rather than
        requests matters — a queued arrival waits for slots that free at
        whole-request granularity, so per-request counting underestimates
        the wait by the mean decode length.  ``tokens_owed`` is maintained
        incrementally because this sits on the per-arrival routing hot path
        (every edge per arrival, times every candidate set under joint
        planning); routers read the whole fleet at once via
        :meth:`FleetTopology.backlog_s_row`."""
        per_round = self.ema_round_s if self.ema_round_s > 0 else 1e-3
        return per_round * self.tokens_owed / max(self.capacity, 1)


def _edge_capacity_get(self) -> int:
    s = getattr(self, "_soa", None)
    return int(s.capacity[self._idx]) if s is not None else self._cap


def _edge_capacity_set(self, v: int) -> None:
    # Runs once from the generated dataclass __init__ (before _soa exists:
    # getattr fallback) and thereafter from the engine's `scale` events.
    # edge_cap_div tracks max(capacity, 1) so the vectorized backlog row
    # stays bit-identical to the scalar backlog_s().
    s = getattr(self, "_soa", None)
    if s is not None:
        s.capacity[self._idx] = v
        s.edge_cap_div[self._idx] = float(max(v, 1))
    else:
        self._cap = int(v)


# Attached after class creation so the dataclass keeps `capacity: int = 8`
# in its __init__ signature while reads/writes route into the SoA column
# once the topology binds the node (same pattern as the in-class hot-state
# properties; those can live in the body because they have no field).
EdgeNode.capacity = property(_edge_capacity_get, _edge_capacity_set)


@dataclass
class FleetTopology:
    devices: List[DeviceNode]
    edges: List[EdgeNode]
    # edge<->edge backbone bandwidth (bytes/s): edges sit on a wired LAN/MAN,
    # orders of magnitude above the device wireless links, which is what
    # makes CoEdge-style multi-edge spans viable at all.
    edge_bw_bps: float = 50e6

    def __post_init__(self):
        edges, devices = self.edges, self.devices
        # id-contiguity contract: node ids are ``id0 + list index``, so the
        # SoA row of edge ``eid`` is ``eid - eid0``.  Holds for every
        # builder (make_fleet, make_mobile_fleet, shard tiles).
        self.eid0 = edges[0].eid if edges else 0
        self.did0 = devices[0].did if devices else 0
        for i, e in enumerate(edges):
            if e.eid != self.eid0 + i:
                raise ValueError("edge ids must be contiguous from eid0")
        for i, d in enumerate(devices):
            if d.did != self.did0 + i:
                raise ValueError("device ids must be contiguous from did0")
        self.edge_speed = np.array([e.speed for e in edges])
        # hashable speed tuple for plan/step cache keys (routers key on the
        # immutable inputs, never on topology object identity)
        self.speed_key = tuple(self.edge_speed.tolist())
        caps = np.array([e.capacity for e in edges], np.int64)
        soa = _SoA(len(edges), len(devices), caps)
        for i, e in enumerate(edges):
            soa.tokens_owed[i] = e.tokens_owed
            soa.ema_round_s[i] = e.ema_round_s
            soa.coop_inflight[i] = e.coop_inflight
            e._soa, e._idx = soa, i
        for i, d in enumerate(devices):
            soa.dev_busy_until_s[i] = d.busy_until_s
            d._soa, d._idx = soa, i
        self._soa = soa
        # live view of provisioned slots (scale events mutate it in place)
        # plus the provisioned-at-build snapshot the engine resets from at
        # the start of each autoscaled run
        self.edge_capacity = soa.capacity
        self.base_capacity = caps

    @property
    def num_devices(self) -> int:
        return len(self.devices)

    @property
    def num_edges(self) -> int:
        return len(self.edges)

    def edge(self, eid: int) -> EdgeNode:
        return self.edges[eid - self.eid0]

    def device(self, did: int) -> DeviceNode:
        return self.devices[did - self.did0]

    # --- vectorized rows (one entry per edge, in eid order) ---
    def backlog_s_row(self) -> np.ndarray:
        """All edges' :meth:`EdgeNode.backlog_s` in one vector expression —
        elementwise identical to the scalar method (same op order per
        entry)."""
        s = self._soa
        per_round = np.where(s.ema_round_s > 0.0, s.ema_round_s, 1e-3)
        return per_round * s.tokens_owed / s.edge_cap_div

    def backlog_n_row(self) -> np.ndarray:
        """Engine-maintained request-count backlog per edge (mirror of
        :meth:`EdgeNode.backlog`; see FleetEngine's enqueue/dequeue)."""
        return self._soa.backlog_n

    def tokens_owed_row(self) -> np.ndarray:
        return self._soa.tokens_owed

    def coop_inflight_row(self) -> np.ndarray:
        return self._soa.coop_inflight


def make_fleet(num_devices: int, num_edges: int, *, seed: int = 0,
               trace: str = "oboe", edge_capacity: int = 8,
               hetero_edges: bool = True, max_edge_slowdown: float = 3.0,
               device_slowdown_range=(0.8, 2.5),
               lo_mbps: float = 0.3, hi_mbps: float = 6.0,
               trace_len: int = 600,
               edge_bw_mbps: float = 400.0,
               eid0: int = 0, did0: int = 0) -> FleetTopology:
    """Sample a reproducible heterogeneous topology.

    ``trace='oboe'`` gives each device an independent piecewise-stationary
    trace (Sec. V-C statistics); ``trace='lte'`` cycles the five Belgium-LTE
    mobility modes across devices.  ``eid0``/``did0`` offset node ids for
    shard tiles (repro.sim.shard) without perturbing any sampling."""
    rng = np.random.default_rng(seed)
    if trace == "oboe":
        traces = oboe_like_traces(seed=seed, num=num_devices, chunks=trace_len,
                                  lo_mbps=lo_mbps, hi_mbps=hi_mbps)
    elif trace == "lte":
        modes = ["foot", "bicycle", "bus", "train", "car"]
        traces = [belgium_lte_like(seed=seed + i, length=trace_len,
                                   transport=modes[i % len(modes)],
                                   hi_mbps=hi_mbps)
                  for i in range(num_devices)]
    else:
        raise ValueError(f"unknown trace kind: {trace!r}")
    lo, hi = device_slowdown_range
    # one batched draw == the former per-device scalar draws, bit-identical
    # (np.random.Generator.uniform fills the output sequentially)
    slowdowns = rng.uniform(lo, hi, num_devices).tolist()
    devices = [DeviceNode(did0 + i, TraceLink(np.asarray(traces[i])),
                          slowdown=slowdowns[i])
               for i in range(num_devices)]
    speeds = np.linspace(1.0, max_edge_slowdown, num_edges) if hetero_edges \
        else np.ones(num_edges)
    speeds = speeds.tolist()
    edges = [EdgeNode(eid0 + j, capacity=edge_capacity, speed=speeds[j])
             for j in range(num_edges)]
    return FleetTopology(devices, edges, edge_bw_bps=edge_bw_mbps * 125e3)
