"""Heterogeneous fleet topology: N device nodes x M edge nodes.

Each :class:`DeviceNode` carries its own bandwidth trace (an independent
draw from ``repro.data.bandwidth``) and a compute-slowdown factor; each
:class:`EdgeNode` is a capacity-limited continuous-batching server with a
speed factor (>1 = slower hardware), so a fleet can mix one beefy edge with
several weak ones — the regime where routing policy matters.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import List

import numpy as np

from repro.data.bandwidth import belgium_lte_like, oboe_like_traces


@dataclass
class TraceLink:
    """Time-indexed bandwidth trace (bytes/s), one per device.

    Unlike ``serving.tiers.Link`` (stepped once per decode iteration of a
    single engine), fleet links are read at *virtual timestamps* so that
    concurrent edges observe a consistent bandwidth history."""
    trace_bps: np.ndarray
    dt_s: float = 1.0

    def __post_init__(self):
        # hot path: plain-list indexing returns Python floats directly,
        # skipping per-call ndarray scalar boxing (same float64 values)
        self._trace = [float(v) for v in self.trace_bps]

    def bw_at(self, t_s: float) -> float:
        i = min(max(int(t_s / self.dt_s), 0), len(self._trace) - 1)
        return self._trace[i]


@dataclass
class DeviceNode:
    """One end-user device: a bandwidth link (``TraceLink`` for static
    fleets, ``fleet.mobility.MobileLink`` under mobility) plus a compute
    slowdown; executes device-side partitions serially."""
    did: int
    link: object                 # TraceLink | MobileLink (duck-typed bw_at)
    slowdown: float = 1.0        # device-tier compute multiplier (>=1 = slower)
    # --- runtime state (owned by FleetEngine) ---
    busy_until_s: float = 0.0    # device-local execution is serial: one
    #                              request at a time, later ones queue

    def local_backlog_s(self, now: float) -> float:
        return max(0.0, self.busy_until_s - now)


@dataclass
class EdgeNode:
    eid: int
    capacity: int = 8            # concurrent decode slots (continuous-batch width)
    speed: float = 1.0           # edge-tier compute multiplier (>=1 = slower)
    # --- runtime state (owned by FleetEngine) ---
    queue: list = field(default_factory=list)   # EDF heap: [deadline, seq, req]
    #                              entries; req slot None = tombstoned by a
    #                              replan (lazy deletion, see FleetEngine)
    q_dead: int = 0              # tombstoned entries still sitting in `queue`
    active: list = field(default_factory=list)  # requests in the running batch
    round_inflight: bool = False
    busy_s: float = 0.0
    ema_round_s: float = 0.0
    completed: int = 0
    coop_inflight: int = 0       # *planned* cooperative span memberships for
    #                              requests slotted at other edges; per-round
    #                              demotion may temporarily shrink the spans
    #                              actually executed (see coop_busy_s in
    #                              FleetMetrics for realized compute)
    tokens_owed: int = 0         # decode tokens still owed to queued+active
    #                              requests (FleetEngine: +max_new_tokens on
    #                              enqueue, -1 per request per round)

    def backlog(self) -> int:
        """Requests currently bound to this edge (queued + in the batch);
        tombstoned queue entries are already gone logically."""
        return len(self.queue) - self.q_dead + len(self.active)

    def backlog_s(self) -> float:
        """Pending-work estimate (seconds) for latency-aware routing: tokens
        still owed to queued + active requests, amortized over the batch
        width at the recent round time.  Counting *tokens* rather than
        requests matters — a queued arrival waits for slots that free at
        whole-request granularity, so per-request counting underestimates
        the wait by the mean decode length.  ``tokens_owed`` is maintained
        incrementally because this sits on the per-arrival routing hot path
        (every edge per arrival, times every candidate set under joint
        planning)."""
        per_round = self.ema_round_s if self.ema_round_s > 0 else 1e-3
        return per_round * self.tokens_owed / max(self.capacity, 1)


@dataclass
class FleetTopology:
    devices: List[DeviceNode]
    edges: List[EdgeNode]
    # edge<->edge backbone bandwidth (bytes/s): edges sit on a wired LAN/MAN,
    # orders of magnitude above the device wireless links, which is what
    # makes CoEdge-style multi-edge spans viable at all.
    edge_bw_bps: float = 50e6

    @property
    def num_devices(self) -> int:
        return len(self.devices)

    @property
    def num_edges(self) -> int:
        return len(self.edges)


def make_fleet(num_devices: int, num_edges: int, *, seed: int = 0,
               trace: str = "oboe", edge_capacity: int = 8,
               hetero_edges: bool = True, max_edge_slowdown: float = 3.0,
               device_slowdown_range=(0.8, 2.5),
               lo_mbps: float = 0.3, hi_mbps: float = 6.0,
               trace_len: int = 600,
               edge_bw_mbps: float = 400.0) -> FleetTopology:
    """Sample a reproducible heterogeneous topology.

    ``trace='oboe'`` gives each device an independent piecewise-stationary
    trace (Sec. V-C statistics); ``trace='lte'`` cycles the five Belgium-LTE
    mobility modes across devices."""
    rng = np.random.default_rng(seed)
    if trace == "oboe":
        traces = oboe_like_traces(seed=seed, num=num_devices, chunks=trace_len,
                                  lo_mbps=lo_mbps, hi_mbps=hi_mbps)
    elif trace == "lte":
        modes = ["foot", "bicycle", "bus", "train", "car"]
        traces = [belgium_lte_like(seed=seed + i, length=trace_len,
                                   transport=modes[i % len(modes)],
                                   hi_mbps=hi_mbps)
                  for i in range(num_devices)]
    else:
        raise ValueError(f"unknown trace kind: {trace!r}")
    lo, hi = device_slowdown_range
    devices = [DeviceNode(i, TraceLink(np.asarray(traces[i])),
                          slowdown=float(rng.uniform(lo, hi)))
               for i in range(num_devices)]
    speeds = np.linspace(1.0, max_edge_slowdown, num_edges) if hetero_edges \
        else np.ones(num_edges)
    edges = [EdgeNode(j, capacity=edge_capacity, speed=float(speeds[j]))
             for j in range(num_edges)]
    return FleetTopology(devices, edges, edge_bw_bps=edge_bw_mbps * 125e3)
