"""Device mobility over a 2-D edge geography (docs/handover.md).

The static fleet gives each device a *time-indexed* bandwidth trace that is
independent of which edge serves it.  This module makes bandwidth a function
of **position**: edges sit at fixed coordinates, devices follow
random-waypoint trajectories, and the wireless rate to each edge follows a
path-loss curve of the device<->edge distance.  A moving device therefore
sees its link to the serving edge *degrade as it walks away* — the dynamic
environment of the paper (Sec. IV-C), realized at fleet scale.

Three pieces:

* :class:`Trajectory` / :func:`random_trajectory` — piecewise-linear
  random-waypoint motion at a configurable speed (area units / s).
* :class:`MobilityModel` — edge positions + device trajectories + the
  position->bandwidth law ``bw(d) = peak / (1 + (d / d_ref)^path_exp)``
  with deterministic per-device multiplicative noise; exposes per-pair
  ``bw(did, eid, t)``, ``distance``, and ``nearest``.
* :class:`HandoverController` — decides *when* a device's in-flight work
  should be re-planned: ``oracle`` watches the geometry directly (fires when
  a strictly nearer edge appears, with hysteresis), ``bocd`` runs the
  paper's Bayesian online change-point detector (`repro.core.bocd`) on the
  bandwidth samples the device actually observes and fires on a detected
  state transition (Algorithm 3 lifted to the fleet), ``none`` never fires.

The controller only raises the flag; the migration itself (state snapshot,
backbone billing, re-binding) is executed by
:class:`~repro.fleet.engine.FleetEngine` using
:meth:`~repro.fleet.joint.JointPlanner.replan` — see docs/handover.md.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.core.bocd import BandwidthStateDetector
from repro.core.graph import InferenceGraph
from repro.fleet.cluster import DeviceNode, EdgeNode, FleetTopology

MBPS = 1e6 / 8  # bytes/s


@dataclass
class Trajectory:
    """Piecewise-linear position over time: waypoint ``points[i]`` is reached
    at ``times_s[i]``; the position is clamped to the endpoints outside the
    waypoint interval (a device that ran out of waypoints parks)."""
    times_s: np.ndarray          # [K] ascending, times_s[0] == 0
    points: np.ndarray           # [K, 2]

    def pos(self, t_s: float) -> np.ndarray:
        t = float(t_s)
        times, pts = self.times_s, self.points
        if t <= times[0] or len(times) == 1:
            return pts[0]
        if t >= times[-1]:
            return pts[-1]
        i = int(np.searchsorted(times, t, side="right"))
        t0, t1 = times[i - 1], times[i]
        w = (t - t0) / max(t1 - t0, 1e-12)
        return (1.0 - w) * pts[i - 1] + w * pts[i]


def random_trajectory(rng: np.random.Generator, speed: float,
                      horizon_s: float, area: float = 1.0) -> Trajectory:
    """Random-waypoint motion: start uniformly in ``[0, area]^2``, walk to
    i.i.d. uniform waypoints at constant ``speed`` until the horizon is
    covered.  ``speed <= 0`` yields a stationary device."""
    start = rng.uniform(0.0, area, 2)
    if speed <= 0.0:
        return Trajectory(np.zeros(1), start[None, :])
    times, pts = [0.0], [start]
    while times[-1] < horizon_s:
        nxt = rng.uniform(0.0, area, 2)
        d = float(np.linalg.norm(nxt - pts[-1]))
        if d < 1e-9:
            continue
        times.append(times[-1] + d / speed)
        pts.append(nxt)
    return Trajectory(np.asarray(times), np.stack(pts))


@dataclass
class MobilityModel:
    """Edge geography + device trajectories + the position->bandwidth law.

    ``bw(did, eid, t) = peak_bps / (1 + (d / d_ref)^path_exp) * noise``,
    floored at ``floor_bps``.  The noise is a pre-drawn per-(device, time
    slot) multiplicative grid so that two runs of the same seed observe the
    identical bandwidth history (the fleet determinism contract)."""
    edge_pos: np.ndarray                     # [M, 2]
    trajectories: List[Trajectory]           # one per device
    peak_bps: float = 6.0 * MBPS
    floor_bps: float = 0.05 * MBPS
    d_ref: float = 0.25                      # distance at which bw halves
    path_exp: float = 3.0
    noise: Optional[np.ndarray] = None       # [N, T] multiplicative
    noise_dt: float = 0.5

    def pos(self, did: int, t_s: float) -> np.ndarray:
        return self.trajectories[did].pos(t_s)

    def distance(self, did: int, eid: int, t_s: float) -> float:
        return float(np.linalg.norm(self.pos(did, t_s) - self.edge_pos[eid]))

    def bw(self, did: int, eid: int, t_s: float) -> float:
        d = self.distance(did, eid, t_s)
        raw = self.peak_bps / (1.0 + (d / self.d_ref) ** self.path_exp)
        if self.noise is not None:
            slot = min(max(int(t_s / self.noise_dt), 0),
                       self.noise.shape[1] - 1)
            raw *= float(self.noise[did, slot])
        return max(raw, self.floor_bps)

    def nearest(self, did: int, t_s: float) -> int:
        """Closest edge (deterministic tie-break on lowest eid)."""
        p = self.pos(did, t_s)
        d = np.linalg.norm(self.edge_pos - p[None, :], axis=1)
        return int(np.argmin(d))        # argmin takes the first minimum


@dataclass
class MobileLink:
    """Drop-in for :class:`~repro.fleet.cluster.TraceLink` under mobility:
    ``bw_at(t)`` reports the *best available* signal (the nearest edge's
    rate), which is what a placement-only router should shop with.  The
    per-serving-edge rate — the one decode rounds are actually billed at —
    comes from ``MobilityModel.bw`` via ``FleetEngine._bw``."""
    model: MobilityModel
    did: int

    def bw_at(self, t_s: float) -> float:
        return self.model.bw(self.did, self.model.nearest(self.did, t_s), t_s)


def edge_grid(num_edges: int, area: float = 1.0) -> np.ndarray:
    """Deterministic edge placement: cell centers of the smallest square grid
    covering ``num_edges`` sites over ``[0, area]^2``."""
    g = int(np.ceil(np.sqrt(num_edges)))
    pos = [((i % g + 0.5) / g * area, (i // g + 0.5) / g * area)
           for i in range(num_edges)]
    return np.asarray(pos)


def migration_bytes(graph: InferenceGraph, exit_point: int, partition: int,
                    tokens: int) -> int:
    """State that must ship when the edge span ``[0, partition)`` of branch
    ``exit_point`` moves to another edge mid-request: per-token attention
    state approximated as 2x (K and V) the activation width at every layer
    boundary inside the span, times the tokens processed so far, plus any
    explicit recurrent state the graph declares (``GraphLayer.state_bytes``,
    which is token-count independent)."""
    if partition <= 0 or tokens <= 0:
        return 0
    branch = graph.branches[exit_point - 1]
    p = min(partition, len(branch))
    per_token = sum(2 * lay.out_bytes for lay in branch[:p])
    state = sum(lay.state_bytes for lay in branch[:p])
    return int(per_token * tokens + state)


class HandoverController:
    """When should device ``did`` re-plan its in-flight work?

    * ``none``   — never (static binding; the no-handover baseline).
    * ``oracle`` — fires whenever some *serving* edge (an edge currently
      hosting one of the device's in-flight requests) has a strictly nearer
      alternative by the ``hysteresis`` margin: a geometry oracle, the
      upper reference in ``benchmarks/fleet_scale.py --mobility``.
    * ``bocd``   — feeds the bandwidth the device observes on its most
      at-risk serving link (the farthest serving edge) to a per-device
      :class:`~repro.core.bocd.BandwidthStateDetector` (sampled every
      ``sample_dt`` seconds of virtual time) and fires on a detected change
      point, rate-limited by ``min_gap_s`` — the paper's Algorithm 3
      trigger driving fleet-level migration.

    The controller is *stateful per run*; :meth:`reset` restores a clean
    slate so one engine can be re-run deterministically.
    """

    POLICIES = ("none", "oracle", "bocd")

    def __init__(self, mobility: MobilityModel, policy: str = "bocd", *,
                 sample_dt: float = 0.5, hazard: float = 1 / 20.0,
                 hysteresis: float = 0.05, min_gap_s: float = 1.0):
        if policy not in self.POLICIES:
            raise ValueError(f"unknown handover policy {policy!r}: expected "
                             f"one of {', '.join(self.POLICIES)}")
        self.mobility = mobility
        self.policy = policy
        self.sample_dt = sample_dt
        self.hazard = hazard
        self.hysteresis = hysteresis
        self.min_gap_s = min_gap_s
        self.reset()

    def reset(self):
        self.detectors: Dict[int, BandwidthStateDetector] = {}
        self._last_fire: Dict[int, float] = {}

    # ------------------------------------------------------------ engine API
    def observe(self, did: int, now: float,
                serving: Tuple[int, ...] = ()) -> bool:
        """One bandwidth sample at virtual time ``now``; ``serving`` lists
        the distinct edges currently hosting this device's in-flight
        requests (a device with several concurrent requests may be bound to
        several).  True => the engine should re-plan the device's in-flight
        work."""
        if self.policy == "none":
            return False
        if self.policy == "oracle":
            if not serving:
                return False
            near = self.mobility.nearest(did, now)
            d_near = self.mobility.distance(did, near, now)
            fire = any(
                eid != near and d_near <= (1.0 - self.hysteresis) *
                self.mobility.distance(did, eid, now)
                for eid in serving)
        else:
            # bocd: sample the most at-risk link the device is actually
            # using (the farthest serving edge — the one whose degradation
            # is hurting in-flight work), falling back to the best signal
            # while idle so the detector's history stays contiguous; a state
            # transition is a MAP run-length collapse (a new entry in the
            # detector's change log, NOT its float return — that is the
            # posterior state mean)
            if serving:
                eid = max(serving, key=lambda e:
                          (self.mobility.distance(did, e, now), e))
            else:
                eid = self.mobility.nearest(did, now)
            det = self.detectors.get(did)
            if det is None:
                det = self.detectors[did] = BandwidthStateDetector(
                    hazard=self.hazard)
            n_before = len(det.changes)
            det.update(self.mobility.bw(did, eid, now) / MBPS)
            fire = len(det.changes) > n_before and bool(serving)
        if not fire:
            return False
        # rate-limit both policies: while a condition persists (a nearer
        # edge exists but replan keeps deciding to stay put), re-searching
        # every sample is wasted compute
        last = self._last_fire.get(did)
        if last is not None and now - last < self.min_gap_s:
            return False
        self._last_fire[did] = now
        return True


def make_mobile_fleet(num_devices: int, num_edges: int, *, seed: int = 0,
                      speed: float = 0.1, horizon_s: float = 60.0,
                      area: float = 1.0, edge_capacity: int = 8,
                      hetero_edges: bool = True,
                      max_edge_slowdown: float = 3.0,
                      device_slowdown_range=(0.8, 2.5),
                      peak_mbps: float = 6.0, floor_mbps: float = 0.05,
                      d_ref: float = 0.25, path_exp: float = 3.0,
                      noise_sigma: float = 0.1, noise_dt: float = 0.5,
                      edge_bw_mbps: float = 400.0
                      ) -> Tuple[FleetTopology, MobilityModel]:
    """Sample a reproducible *mobile* fleet: edges on a grid over
    ``[0, area]^2``, devices on random-waypoint trajectories at ``speed``
    (jittered +/-50% per device), per-pair bandwidth from the path-loss law.
    Device links are :class:`MobileLink`s so placement-only routers keep
    working unchanged."""
    rng = np.random.default_rng(seed)
    pos = edge_grid(num_edges, area)
    trajs = [random_trajectory(rng, speed * float(rng.uniform(0.5, 1.5)),
                               horizon_s, area)
             for _ in range(num_devices)]
    slots = max(int(np.ceil(horizon_s / noise_dt)) + 1, 1)
    noise = np.clip(rng.normal(1.0, noise_sigma,
                               (num_devices, slots)), 0.3, 1.7) \
        if noise_sigma > 0 else None
    mobility = MobilityModel(edge_pos=pos, trajectories=trajs,
                             peak_bps=peak_mbps * MBPS,
                             floor_bps=floor_mbps * MBPS,
                             d_ref=d_ref, path_exp=path_exp,
                             noise=noise, noise_dt=noise_dt)
    lo, hi = device_slowdown_range
    devices = [DeviceNode(i, MobileLink(mobility, i),
                          slowdown=float(rng.uniform(lo, hi)))
               for i in range(num_devices)]
    speeds = np.linspace(1.0, max_edge_slowdown, num_edges) if hetero_edges \
        else np.ones(num_edges)
    edges = [EdgeNode(j, capacity=edge_capacity, speed=float(speeds[j]))
             for j in range(num_edges)]
    topo = FleetTopology(devices, edges, edge_bw_bps=edge_bw_mbps * 125e3)
    return topo, mobility
