"""Device mobility over a 2-D edge geography (docs/handover.md).

The static fleet gives each device a *time-indexed* bandwidth trace that is
independent of which edge serves it.  This module makes bandwidth a function
of **position**: edges sit at fixed coordinates, devices follow
random-waypoint trajectories, and the wireless rate to each edge follows a
path-loss curve of the device<->edge distance.  A moving device therefore
sees its link to the serving edge *degrade as it walks away* — the dynamic
environment of the paper (Sec. IV-C), realized at fleet scale.

Three pieces:

* :class:`Trajectory` / :func:`random_trajectory` — piecewise-linear
  random-waypoint motion at a configurable speed (area units / s).
* :class:`MobilityModel` — edge positions + device trajectories + the
  position->bandwidth law ``bw(d) = peak / (1 + (d / d_ref)^path_exp)``
  with deterministic per-device multiplicative noise; exposes per-pair
  ``bw(did, eid, t)``, ``distance``, and ``nearest``.
* :class:`HandoverController` — decides *when* a device's in-flight work
  should be re-planned: ``oracle`` watches the geometry directly (fires when
  a strictly nearer edge appears, with hysteresis), ``bocd`` runs the
  paper's Bayesian online change-point detector (`repro.core.bocd`) on the
  bandwidth samples the device actually observes and fires on a detected
  state transition (Algorithm 3 lifted to the fleet), ``none`` never fires.

The controller only raises the flag; the migration itself (state snapshot,
backbone billing, re-binding) is executed by
:class:`~repro.fleet.engine.FleetEngine` using
:meth:`~repro.fleet.joint.JointPlanner.replan` — see docs/handover.md.
"""
from __future__ import annotations

import math
from bisect import bisect_right
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.core.bocd import BandwidthStateDetector, BOCDBank
from repro.core.graph import InferenceGraph
from repro.fleet.cluster import DeviceNode, EdgeNode, FleetTopology

MBPS = 1e6 / 8  # bytes/s


@dataclass
class Trajectory:
    """Piecewise-linear position over time: waypoint ``points[i]`` is reached
    at ``times_s[i]``; the position is clamped to the endpoints outside the
    waypoint interval (a device that ran out of waypoints parks)."""
    times_s: np.ndarray          # [K] ascending, times_s[0] == 0
    points: np.ndarray           # [K, 2]

    def pos(self, t_s: float) -> np.ndarray:
        return np.array(self.pos_xy(t_s))

    def pos_xy(self, t_s: float) -> Tuple[float, float]:
        """Scalar hot path: the same interpolation as the ndarray ``pos``
        over cached plain-float waypoint lists (``bisect`` instead of
        ``searchsorted``, identical float64 arithmetic per component)."""
        times = getattr(self, "_times_l", None)
        if times is None:
            times = self._times_l = [float(v) for v in self.times_s]
            self._pts_l = [(float(p[0]), float(p[1])) for p in self.points]
        pts = self._pts_l
        t = float(t_s)
        if t <= times[0] or len(times) == 1:
            return pts[0]
        if t >= times[-1]:
            return pts[-1]
        i = bisect_right(times, t)
        t0, t1 = times[i - 1], times[i]
        w = (t - t0) / max(t1 - t0, 1e-12)
        x0, y0 = pts[i - 1]
        x1, y1 = pts[i]
        return (1.0 - w) * x0 + w * x1, (1.0 - w) * y0 + w * y1


def random_trajectory(rng: np.random.Generator, speed: float,
                      horizon_s: float, area: float = 1.0) -> Trajectory:
    """Random-waypoint motion: start uniformly in ``[0, area]^2``, walk to
    i.i.d. uniform waypoints at constant ``speed`` until the horizon is
    covered.  ``speed <= 0`` yields a stationary device."""
    start = rng.uniform(0.0, area, 2)
    if speed <= 0.0:
        return Trajectory(np.zeros(1), start[None, :])
    times, pts = [0.0], [start]
    while times[-1] < horizon_s:
        nxt = rng.uniform(0.0, area, 2)
        # scalar hypot == np.linalg.norm's 2-vector reduction, bitwise
        dx = float(nxt[0]) - float(pts[-1][0])
        dy = float(nxt[1]) - float(pts[-1][1])
        d = math.sqrt(dx * dx + dy * dy)
        if d < 1e-9:
            continue
        times.append(times[-1] + d / speed)
        pts.append(nxt)
    return Trajectory(np.asarray(times), np.stack(pts))


@dataclass
class MobilityModel:
    """Edge geography + device trajectories + the position->bandwidth law.

    ``bw(did, eid, t) = peak_bps / (1 + (d / d_ref)^path_exp) * noise``,
    floored at ``floor_bps``.  The noise is a pre-drawn per-(device, time
    slot) multiplicative grid so that two runs of the same seed observe the
    identical bandwidth history (the fleet determinism contract).

    ``eid0``/``did0`` make the model *tile-capable* (repro.sim.shard): a
    sharded run hands each geography tile its own model covering only that
    tile's edges and devices, with ids offset into the fleet-global
    namespace.  Scalar APIs (``bw``, ``distance``, ``nearest``) speak
    global ids; the row/matrix APIs (``distance_row``, ``bw_row``,
    ``distances_at``, ``bw_matrix``) stay tile-local-indexed — callers
    offset columns by ``eid0`` (as :class:`~repro.fleet.joint.JointPlanner`
    does with ``topo.eid0``)."""
    edge_pos: np.ndarray                     # [M, 2]
    trajectories: List[Trajectory]           # one per device
    peak_bps: float = 6.0 * MBPS
    floor_bps: float = 0.05 * MBPS
    d_ref: float = 0.25                      # distance at which bw halves
    path_exp: float = 3.0
    noise: Optional[np.ndarray] = None       # [N, T] multiplicative
    noise_dt: float = 0.5
    eid0: int = 0                            # first global edge id
    did0: int = 0                            # first global device id

    def pos(self, did: int, t_s: float) -> np.ndarray:
        return self.trajectories[did - self.did0].pos(t_s)

    def _edge_xy(self) -> List[Tuple[float, float]]:
        xy = getattr(self, "_edge_xy_l", None)
        if xy is None:
            xy = self._edge_xy_l = [(float(p[0]), float(p[1]))
                                    for p in self.edge_pos]
        return xy

    def distance(self, did: int, eid: int, t_s: float) -> float:
        # sqrt(dx*dx + dy*dy): the exact reduction np.linalg.norm applies
        # to a 2-vector, without building one
        x, y = self.trajectories[did - self.did0].pos_xy(t_s)
        ex, ey = self._edge_xy()[eid - self.eid0]
        dx, dy = x - ex, y - ey
        return math.sqrt(dx * dx + dy * dy)

    def bw(self, did: int, eid: int, t_s: float) -> float:
        d = self.distance(did, eid, t_s)
        raw = self.peak_bps / (1.0 + (d / self.d_ref) ** self.path_exp)
        if self.noise is not None:
            slot = min(max(int(t_s / self.noise_dt), 0),
                       self.noise.shape[1] - 1)
            raw *= float(self.noise[did - self.did0, slot])
        return max(raw, self.floor_bps)

    # ----------------------------------------------- spatial nearest-edge
    # A uniform grid over the edge positions answers nearest() by expanding
    # ring search instead of an O(M) scan.  Bit-identical to
    # argmin(distance_row): per-candidate distances use the same scalar
    # sqrt(dx*dx+dy*dy) as distance() (== np.sqrt per element), ties break
    # on the lowest edge index ((d, i) lexicographic — argmin's
    # first-minimum), and rings keep expanding while a tie at the ring's
    # lower bound is still possible (<= , not <).

    def _grid(self):
        g = getattr(self, "_grid_t", None)
        if g is None:
            xy = self._edge_xy()
            m = len(xy)
            gdim = max(1, int(math.sqrt(m)))
            minx = min(p[0] for p in xy)
            miny = min(p[1] for p in xy)
            ext = max(max(p[0] for p in xy) - minx,
                      max(p[1] for p in xy) - miny)
            cs = ext / gdim if ext > 0.0 else 1.0
            cells: List[List[int]] = [[] for _ in range(gdim * gdim)]
            for i, (x, y) in enumerate(xy):
                cx = min(int((x - minx) / cs), gdim - 1)
                cy = min(int((y - miny) / cs), gdim - 1)
                cells[cy * gdim + cx].append(i)  # ascending i per cell
            self._grid_t = g = (gdim, minx, miny, cs, cells)
        return g

    def _nearest_xy(self, x: float, y: float) -> int:
        """Tile-local index of the edge closest to ``(x, y)``; exact
        argmin-equivalent (see the block comment above)."""
        gdim, minx, miny, cs, cells = self._grid()
        xy = self._edge_xy()
        cx = min(max(int((x - minx) / cs), 0), gdim - 1)
        cy = min(max(int((y - miny) / cs), 0), gdim - 1)
        best_d = math.inf
        best_i = -1
        max_r = max(cx, cy, gdim - 1 - cx, gdim - 1 - cy)
        for r in range(max_r + 1):
            # any edge in ring r is >= (r-1)*cs away (axis separation); a
            # strictly greater bound cannot beat OR tie the incumbent
            if best_i >= 0 and (r - 1) * cs > best_d:
                break
            x0, x1 = max(cx - r, 0), min(cx + r, gdim - 1)
            y0, y1 = max(cy - r, 0), min(cy + r, gdim - 1)
            for gy in range(y0, y1 + 1):
                on_rim_y = gy == cy - r or gy == cy + r
                for gx in range(x0, x1 + 1):
                    if r and not on_rim_y and gx != cx - r and gx != cx + r:
                        continue            # interior: scanned by ring < r
                    for i in cells[gy * gdim + gx]:
                        ex, ey = xy[i]
                        dx, dy = x - ex, y - ey
                        d = math.sqrt(dx * dx + dy * dy)
                        if d < best_d or (d == best_d and i < best_i):
                            best_d, best_i = d, i
        return best_i

    def nearest(self, did: int, t_s: float) -> int:
        """Closest edge, as a *global* eid (deterministic tie-break on the
        lowest eid — the first minimum ``argmin`` would take over
        :meth:`distance_row`), answered by the spatial grid in O(1)-ish."""
        x, y = self.trajectories[did - self.did0].pos_xy(t_s)
        return self.eid0 + self._nearest_xy(x, y)

    def nearest_bruteforce(self, did: int, t_s: float) -> int:
        """Reference O(M) nearest (the pre-grid implementation); the
        equivalence tests pin ``nearest == nearest_bruteforce`` everywhere,
        including exact-tie geometries."""
        row = self.distance_row(did, t_s)
        return self.eid0 + int(np.argmin(row))  # first minimum

    def distance_row(self, did: int, t_s: float) -> np.ndarray:
        """One device's distance to every edge (tile-local ``[M]``), entry
        ``e`` == ``distance(did, eid0 + e, t_s)`` bitwise — the replanner's
        nearest-first candidate ordering reads this instead of M scalar
        calls."""
        x, y = self.trajectories[did - self.did0].pos_xy(t_s)
        dx = x - self.edge_pos[:, 0]
        dy = y - self.edge_pos[:, 1]
        return np.sqrt(dx * dx + dy * dy)

    def bw_row(self, did: int, t_s: float) -> np.ndarray:
        """One device's bandwidth to every edge (tile-local ``[M]``), entry
        ``e`` == ``bw(did, eid0 + e, t_s)`` bitwise — this row prices
        *replans*, so it must match the engine's scalar billing exactly;
        the ``**`` runs through scalar pow per edge because numpy's SIMD
        pow can differ from it in the last ulp (see :meth:`bw_matrix`)."""
        d = self.distance_row(did, t_s)
        noise = 1.0
        if self.noise is not None:
            slot = min(max(int(t_s / self.noise_dt), 0),
                       self.noise.shape[1] - 1)
            noise = float(self.noise[did - self.did0, slot])
        peak, d_ref, exp_ = self.peak_bps, self.d_ref, self.path_exp
        out = np.empty(len(d))
        for e in range(len(d)):
            raw = peak / (1.0 + (float(d[e]) / d_ref) ** exp_)
            if self.noise is not None:
                raw *= noise
            out[e] = max(raw, self.floor_bps)
        return out

    # ------------------------------------------------- vectorized (per slot)
    # The sampling sweep evaluates every device-edge pair once per time
    # slot.  These batched paths apply the *same elementwise float64 ops*
    # as pos()/distance()/bw() above, so each matrix entry is bit-identical
    # to the corresponding scalar call (pinned by
    # tests/test_fleet_perf.py::test_vectorized_mobility_matches_scalar) —
    # they only drop the per-call Python and tiny-ndarray overhead.

    def _pos_tables(self):
        """Trajectory waypoints padded into rectangular arrays (cached):
        ``(times [N, K] padded +inf, points [N, K, 2] padded with the last
        waypoint, valid counts [N], last valid time [N])``."""
        tabs = getattr(self, "_ptabs", None)
        if tabs is None:
            n = len(self.trajectories)
            kv = np.array([len(tr.times_s) for tr in self.trajectories])
            k = max(int(kv.max()), 2)
            times = np.full((n, k), np.inf)
            pts = np.empty((n, k, 2))
            for i, tr in enumerate(self.trajectories):
                ki = len(tr.times_s)
                times[i, :ki] = tr.times_s
                pts[i, :ki] = tr.points
                pts[i, ki:] = tr.points[-1]
            t_last = np.array([tr.times_s[-1] for tr in self.trajectories])
            self._ptabs = tabs = (times, pts, kv, t_last)
        return tabs

    def positions_at(self, t_s: float) -> np.ndarray:
        """All device positions at one instant: ``[N, 2]``, row ``d`` ==
        ``pos(d, t_s)`` bitwise."""
        t = float(t_s)
        times, pts, kv, t_last = self._pos_tables()
        n = len(kv)
        rows = np.arange(n)
        # count of waypoint times <= t == searchsorted(times, t, "right");
        # +inf padding never counts.  Clamp into the valid interior so the
        # gathers stay in-bounds; boundary rows are overwritten below.
        i = np.clip((times <= t).sum(axis=1), 1, np.maximum(kv - 1, 1))
        t0, t1 = times[rows, i - 1], times[rows, i]
        p0, p1 = pts[rows, i - 1], pts[rows, i]
        w = (t - t0) / np.maximum(t1 - t0, 1e-12)
        out = (1.0 - w)[:, None] * p0 + w[:, None] * p1
        first = (t <= times[:, 0]) | (kv == 1)
        last = t >= t_last
        return np.where(first[:, None], pts[:, 0],
                        np.where(last[:, None],
                                 pts[rows, np.maximum(kv - 1, 0)], out))

    def distances_at(self, t_s: float) -> np.ndarray:
        """Device-edge distance matrix ``[N, M]`` at one instant; entry
        ``(d, e)`` == ``distance(d, e, t_s)`` bitwise."""
        p = self.positions_at(t_s)
        dx = p[:, 0][:, None] - self.edge_pos[:, 0][None, :]
        dy = p[:, 1][:, None] - self.edge_pos[:, 1][None, :]
        return np.sqrt(dx * dx + dy * dy)

    def bw_matrix(self, t_s: float) -> np.ndarray:
        """Device-edge bandwidth matrix ``[N, M]`` at one instant (the
        path-loss law over :meth:`distances_at`).

        Entry ``(d, e)`` equals ``bw(d, e, t_s)`` up to 1 ulp: numpy's
        vectorized ``**`` may round differently from scalar ``pow`` in the
        last bit (everything else — interpolation, distances, noise, floor
        — is bit-exact; tests/test_fleet_perf.py pins the tolerance).  The
        matrix only feeds the handover policies' *observations* (BOCD
        samples, which are threshold decisions), never latency billing;
        both paths are individually deterministic, and the registry
        scenarios' metrics are pinned bit-identical to the pre-vectorized
        engine."""
        d = self.distances_at(t_s)
        raw = self.peak_bps / (1.0 + (d / self.d_ref) ** self.path_exp)
        if self.noise is not None:
            slot = min(max(int(t_s / self.noise_dt), 0),
                       self.noise.shape[1] - 1)
            raw = raw * self.noise[:, slot][:, None]
        return np.maximum(raw, self.floor_bps)


@dataclass
class MobileLink:
    """Drop-in for :class:`~repro.fleet.cluster.TraceLink` under mobility:
    ``bw_at(t)`` reports the *best available* signal (the nearest edge's
    rate), which is what a placement-only router should shop with.  The
    per-serving-edge rate — the one decode rounds are actually billed at —
    comes from ``MobilityModel.bw`` via ``FleetEngine._bw``."""
    model: MobilityModel
    did: int

    def bw_at(self, t_s: float) -> float:
        return self.model.bw(self.did, self.model.nearest(self.did, t_s), t_s)


def edge_grid(num_edges: int, area: float = 1.0) -> np.ndarray:
    """Deterministic edge placement: cell centers of the smallest square grid
    covering ``num_edges`` sites over ``[0, area]^2``."""
    g = int(np.ceil(np.sqrt(num_edges)))
    pos = [((i % g + 0.5) / g * area, (i // g + 0.5) / g * area)
           for i in range(num_edges)]
    return np.asarray(pos)


def migration_bytes(graph: InferenceGraph, exit_point: int, partition: int,
                    tokens: int) -> int:
    """State that must ship when the edge span ``[0, partition)`` of branch
    ``exit_point`` moves to another edge mid-request: per-token attention
    state approximated as 2x (K and V) the activation width at every layer
    boundary inside the span, times the tokens processed so far, plus any
    explicit recurrent state the graph declares (``GraphLayer.state_bytes``,
    which is token-count independent)."""
    if partition <= 0 or tokens <= 0:
        return 0
    branch = graph.branches[exit_point - 1]
    p = min(partition, len(branch))
    per_token = sum(2 * lay.out_bytes for lay in branch[:p])
    state = sum(lay.state_bytes for lay in branch[:p])
    return int(per_token * tokens + state)


class HandoverController:
    """When should device ``did`` re-plan its in-flight work?

    * ``none``   — never (static binding; the no-handover baseline).
    * ``oracle`` — fires whenever some *serving* edge (an edge currently
      hosting one of the device's in-flight requests) has a strictly nearer
      alternative by the ``hysteresis`` margin: a geometry oracle, the
      upper reference in ``benchmarks/fleet_scale.py --mobility``.
    * ``bocd``   — feeds the bandwidth the device observes on its most
      at-risk serving link (the farthest serving edge) to a per-device
      :class:`~repro.core.bocd.BandwidthStateDetector` (sampled every
      ``sample_dt`` seconds of virtual time) and fires on a detected change
      point, rate-limited by ``min_gap_s`` — the paper's Algorithm 3
      trigger driving fleet-level migration.

    The controller is *stateful per run*; :meth:`reset` restores a clean
    slate so one engine can be re-run deterministically.
    """

    POLICIES = ("none", "oracle", "bocd")

    def __init__(self, mobility: MobilityModel, policy: str = "bocd", *,
                 sample_dt: float = 0.5, hazard: float = 1 / 20.0,
                 hysteresis: float = 0.05, min_gap_s: float = 1.0):
        if policy not in self.POLICIES:
            raise ValueError(f"unknown handover policy {policy!r}: expected "
                             f"one of {', '.join(self.POLICIES)}")
        self.mobility = mobility
        self.policy = policy
        self.sample_dt = sample_dt
        self.hazard = hazard
        self.hysteresis = hysteresis
        self.min_gap_s = min_gap_s
        self.reset()

    def reset(self):
        self.detectors: Dict[int, BandwidthStateDetector] = {}
        self.bank: Optional[BOCDBank] = None
        self._last_fire: Dict[int, float] = {}

    # ------------------------------------------------------------ engine API
    def observe(self, did: int, now: float,
                serving: Tuple[int, ...] = ()) -> bool:
        """One bandwidth sample at virtual time ``now``; ``serving`` lists
        the distinct edges currently hosting this device's in-flight
        requests (a device with several concurrent requests may be bound to
        several).  True => the engine should re-plan the device's in-flight
        work.

        This is the one-device path (lazy per-device detectors); the engine
        drives the fleet through :meth:`observe_sweep` instead, which updates
        every detector in one batched step.  Do not mix the two in one run —
        the sweep's :class:`~repro.core.bocd.BOCDBank` and the lazy
        ``detectors`` dict are separate state."""
        if self.policy == "none":
            return False
        if self.policy == "oracle":
            if not serving:
                return False
            near = self.mobility.nearest(did, now)
            d_near = self.mobility.distance(did, near, now)
            fire = any(
                eid != near and d_near <= (1.0 - self.hysteresis) *
                self.mobility.distance(did, eid, now)
                for eid in serving)
        else:
            # bocd: sample the most at-risk link the device is actually
            # using (the farthest serving edge — the one whose degradation
            # is hurting in-flight work), falling back to the best signal
            # while idle so the detector's history stays contiguous; a state
            # transition is a MAP run-length collapse (a new entry in the
            # detector's change log, NOT its float return — that is the
            # posterior state mean)
            if serving:
                eid = max(serving, key=lambda e:
                          (self.mobility.distance(did, e, now), e))
            else:
                eid = self.mobility.nearest(did, now)
            det = self.detectors.get(did)
            if det is None:
                det = self.detectors[did] = BandwidthStateDetector(
                    hazard=self.hazard)
            n_before = len(det.changes)
            det.update(self.mobility.bw(did, eid, now) / MBPS)
            fire = len(det.changes) > n_before and bool(serving)
        if not fire:
            return False
        return self._rate_limit(did, now)

    def _rate_limit(self, did: int, now: float) -> bool:
        # rate-limit both policies: while a condition persists (a nearer
        # edge exists but replan keeps deciding to stay put), re-searching
        # every sample is wasted compute
        last = self._last_fire.get(did)
        if last is not None and now - last < self.min_gap_s:
            return False
        self._last_fire[did] = now
        return True

    def observe_sweep(self, now: float, servings: List[Tuple[int, ...]],
                      dist: np.ndarray, bw: np.ndarray) -> List[int]:
        """One tick of the whole fleet's sampling grid: ``servings[did]``
        lists the edges serving device ``did``; ``dist``/``bw`` are this
        slot's :meth:`MobilityModel.distances_at` /
        :meth:`MobilityModel.bw_matrix` matrices.  Returns the devices whose
        in-flight work should re-plan, in ascending id order — exactly the
        devices (and order) the per-device :meth:`observe` grid would have
        fired, with all BOCD posteriors advanced in one
        :class:`~repro.core.bocd.BOCDBank` step instead of a Python loop."""
        if self.policy == "none":
            return []
        n = len(servings)
        # servings/dist/bw are tile-local-indexed; serving eids and the
        # fired device ids are global (the engine replans by global did)
        e0, d0 = self.mobility.eid0, self.mobility.did0
        fired: List[int] = []
        if self.policy == "oracle":
            near = dist.argmin(axis=1)          # first minimum per row
            for did, serving in enumerate(servings):
                if not serving:
                    continue
                nr = int(near[did])
                d_near = float(dist[did, nr])
                if any(eid - e0 != nr and d_near <=
                       (1.0 - self.hysteresis) * float(dist[did, eid - e0])
                       for eid in serving) and \
                        self._rate_limit(did + d0, now):
                    fired.append(did + d0)
            return fired
        # bocd: one bank row per device, all rows updated in lockstep (the
        # engine samples every device on the same grid, so run lengths agree)
        if self.bank is None:
            self.bank = BOCDBank(n, hazard=self.hazard)
        near = dist.argmin(axis=1)
        # idle devices sample their best signal (vectorized gather); only
        # devices with in-flight work pick a serving link in Python
        xs = bw[np.arange(n), near]
        has_serving = np.zeros(n, dtype=bool)
        for did, serving in enumerate(servings):
            if serving:
                eid = max(serving,
                          key=lambda e: (float(dist[did, e - e0]), e))
                has_serving[did] = True
                xs[did] = bw[did, eid - e0]
        changed = self.bank.update(xs / MBPS) & has_serving
        for did in np.flatnonzero(changed):
            if self._rate_limit(int(did) + d0, now):
                fired.append(int(did) + d0)
        return fired


def make_mobile_fleet(num_devices: int, num_edges: int, *, seed: int = 0,
                      speed: float = 0.1, horizon_s: float = 60.0,
                      area: float = 1.0, edge_capacity: int = 8,
                      hetero_edges: bool = True,
                      max_edge_slowdown: float = 3.0,
                      device_slowdown_range=(0.8, 2.5),
                      peak_mbps: float = 6.0, floor_mbps: float = 0.05,
                      d_ref: float = 0.25, path_exp: float = 3.0,
                      noise_sigma: float = 0.1, noise_dt: float = 0.5,
                      edge_bw_mbps: float = 400.0,
                      eid0: int = 0, did0: int = 0
                      ) -> Tuple[FleetTopology, MobilityModel]:
    """Sample a reproducible *mobile* fleet: edges on a grid over
    ``[0, area]^2``, devices on random-waypoint trajectories at ``speed``
    (jittered +/-50% per device), per-pair bandwidth from the path-loss law.
    Device links are :class:`MobileLink`s so placement-only routers keep
    working unchanged.  ``eid0``/``did0`` offset all ids into a
    fleet-global namespace for geography-sharded runs (repro.sim.shard)."""
    rng = np.random.default_rng(seed)
    pos = edge_grid(num_edges, area)
    trajs = [random_trajectory(rng, speed * float(rng.uniform(0.5, 1.5)),
                               horizon_s, area)
             for _ in range(num_devices)]
    slots = max(int(np.ceil(horizon_s / noise_dt)) + 1, 1)
    noise = np.clip(rng.normal(1.0, noise_sigma,
                               (num_devices, slots)), 0.3, 1.7) \
        if noise_sigma > 0 else None
    mobility = MobilityModel(edge_pos=pos, trajectories=trajs,
                             peak_bps=peak_mbps * MBPS,
                             floor_bps=floor_mbps * MBPS,
                             d_ref=d_ref, path_exp=path_exp,
                             noise=noise, noise_dt=noise_dt,
                             eid0=eid0, did0=did0)
    lo, hi = device_slowdown_range
    # one batched draw == num_devices sequential scalar uniforms, bitwise
    slowdowns = rng.uniform(lo, hi, num_devices)
    devices = [DeviceNode(did0 + i, MobileLink(mobility, did0 + i),
                          slowdown=s)
               for i, s in enumerate(slowdowns.tolist())]
    speeds = np.linspace(1.0, max_edge_slowdown, num_edges) if hetero_edges \
        else np.ones(num_edges)
    edges = [EdgeNode(eid0 + j, capacity=edge_capacity,
                      speed=float(speeds[j]))
             for j in range(num_edges)]
    topo = FleetTopology(devices, edges, edge_bw_bps=edge_bw_mbps * 125e3)
    return topo, mobility
