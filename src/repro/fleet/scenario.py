"""Deprecated tuple-returning scenario helpers (use ``repro.sim``).

These were the canonical fleet-experiment entry points before the
declarative scenario API (docs/api.md): ``smoke_lm_scenario`` returned a
3- or 5-tuple depending on ``with_model``, ``smoke_mobility_scenario`` a
6-tuple — exactly the flag-dependent arity ``repro.sim.Scenario`` replaces
with named fields.  Both remain as thin shims over the spec builders so
external callers keep working: they reproduce the legacy tuples bit-for-bit
and emit a ``DeprecationWarning`` (pinned in tests/test_sim.py).

Migration (see docs/api.md for the full table)::

    cfg, graph, planner = smoke_lm_scenario()          # before
    sc = build_stack(PlannerSpec())                    # after: named fields
    sc.cfg, sc.graph, sc.planner

    _, g, p, topo, mob, ctrl = smoke_mobility_scenario(40, 4, ...)  # before
    sc = Simulation(get_scenario("smoke-mobility")).build()         # after
    sc.graph, sc.planner, sc.topo, sc.mobility, sc.handover, sc.engine
"""
from __future__ import annotations

import warnings


def smoke_lm_scenario(arch: str = "llama3.2-1b", *,
                      latency_req_s: float = 0.5,
                      input_kb: float = 24.0,
                      device_step_s: float = 0.06,
                      edge_step_s: float = 0.004,
                      with_model: bool = False):
    """Deprecated: build ``(cfg, graph, planner[, model, params])`` as a
    positional tuple.  Use ``repro.sim.build_stack(PlannerSpec(...))`` —
    it returns the same objects as named ``Scenario`` fields with no
    flag-dependent arity."""
    warnings.warn(
        "smoke_lm_scenario() is deprecated: use repro.sim "
        "(build_stack(PlannerSpec(...)) for the model stack, or "
        "Simulation(get_scenario('smoke-lm')) for a full experiment); "
        "the tuple return will be removed", DeprecationWarning,
        stacklevel=2)
    from repro.sim.build import build_stack
    from repro.sim.spec import PlannerSpec
    sc = build_stack(
        PlannerSpec(arch=arch, latency_req_s=latency_req_s,
                    input_kb=input_kb, device_step_s=device_step_s,
                    edge_step_s=edge_step_s),
        with_model=with_model)
    if not with_model:
        return sc.cfg, sc.graph, sc.planner
    return sc.cfg, sc.graph, sc.planner, sc.model, sc.params


def smoke_mobility_scenario(num_devices: int, num_edges: int = 4, *,
                            seed: int = 0, speed: float = 0.1,
                            policy: str = "bocd", horizon_s: float = 60.0,
                            arch: str = "llama3.2-1b",
                            latency_req_s: float = 0.5,
                            result_kb: float = 4.0,
                            sample_dt: float = 0.5, hazard: float = 1 / 20.0,
                            **mobile_kwargs):
    """Deprecated: build the mobile smoke stack as the positional tuple
    ``(cfg, graph, planner, topo, mobility, controller)`` (``controller``
    is ``None`` for ``policy='none'``).  Use a ``repro.sim`` ScenarioSpec
    with ``TopologySpec(kind='mobile')`` + ``MobilitySpec`` instead —
    ``Simulation(spec).build()`` returns the same objects by name, plus the
    wired ``FleetEngine``."""
    warnings.warn(
        "smoke_mobility_scenario() is deprecated: use repro.sim "
        "(Simulation(get_scenario('smoke-mobility')), or a ScenarioSpec "
        "with TopologySpec(kind='mobile') + MobilitySpec); the tuple "
        "return will be removed", DeprecationWarning, stacklevel=2)
    from repro.fleet.mobility import HandoverController
    from repro.sim.build import build_stack, build_topology
    from repro.sim.spec import PlannerSpec, TopologySpec
    sc = build_stack(PlannerSpec(arch=arch, latency_req_s=latency_req_s,
                                 result_kb=result_kb))
    topo, mobility = build_topology(
        TopologySpec(kind="mobile", num_devices=num_devices,
                     num_edges=num_edges, speed=speed, horizon_s=horizon_s,
                     **mobile_kwargs), seed)
    controller = None if policy == "none" else HandoverController(
        mobility, policy=policy, sample_dt=sample_dt, hazard=hazard)
    return sc.cfg, sc.graph, sc.planner, topo, mobility, controller
