"""Canonical fleet scenario: a smoke-scale LM graph with paper-anchored
tier speeds.

The roofline predictors are rescaled so one device-only decode step costs
``device_step_s`` and one edge step ``edge_step_s`` (Fig. 2 asymmetry at
per-token granularity), and the input payload is set to a multimodal-style
prompt (image features shipped from the device) so the partition decision
genuinely trades bandwidth against tier compute: low-bandwidth devices plan
device-only, well-connected ones offload.  Used by ``benchmarks/
fleet_scale.py``, ``examples/serve_fleet.py``, and ``tests/test_fleet.py``.
"""
from __future__ import annotations

from typing import Tuple

from repro.configs import get_smoke_config
from repro.core import EdgentPlanner, lm_graph
from repro.core.latency_model import RooflineLatencyModel, ScaledLatencyModel


def smoke_lm_scenario(arch: str = "llama3.2-1b", *,
                      latency_req_s: float = 0.5,
                      input_kb: float = 24.0,
                      device_step_s: float = 0.06,
                      edge_step_s: float = 0.004,
                      with_model: bool = False):
    """Build (cfg, graph, planner[, model, params]) for fleet experiments."""
    cfg = get_smoke_config(arch)
    graph = lm_graph(cfg, batch=1, seq=1)
    graph.input_bytes = int(input_kb * 1024)
    edge = RooflineLatencyModel(chips=8, efficiency=0.4)
    dev = RooflineLatencyModel(chips=1, efficiency=0.4)
    full = graph.branches[-1]
    k_edge = edge_step_s / sum(edge.predict(l) for l in full)
    k_dev = device_step_s / sum(dev.predict(l) for l in full)
    planner = EdgentPlanner(graph, latency_req_s=latency_req_s)
    planner.with_models(ScaledLatencyModel(edge, k_edge),
                        ScaledLatencyModel(dev, k_dev))
    if not with_model:
        return cfg, graph, planner
    import jax
    import jax.numpy as jnp
    from repro.models import Model
    model = Model(cfg)
    params = model.init_params(jax.random.key(0), dtype=jnp.float32)
    return cfg, graph, planner, model, params


def smoke_mobility_scenario(num_devices: int, num_edges: int = 4, *,
                            seed: int = 0, speed: float = 0.1,
                            policy: str = "bocd", horizon_s: float = 60.0,
                            arch: str = "llama3.2-1b",
                            latency_req_s: float = 0.5,
                            result_kb: float = 4.0,
                            sample_dt: float = 0.5, hazard: float = 1 / 20.0,
                            **mobile_kwargs):
    """Canonical mobility scenario: the smoke LM stack on a *mobile* fleet.

    Wires the three mobility pieces together (trajectories + position->
    bandwidth geography via :func:`~repro.fleet.mobility.make_mobile_fleet`,
    BOCD/oracle trigger via
    :class:`~repro.fleet.mobility.HandoverController`) around the same graph
    and planner as :func:`smoke_lm_scenario`, so the static and mobile
    benchmarks compare the same model.  ``policy='none'`` returns
    ``controller=None`` — the no-handover baseline still moves (bandwidth
    to the serving edge degrades) but never migrates.

    Returns ``(cfg, graph, planner, topo, mobility, controller)``; feed the
    last three to ``FleetEngine(mobility=..., handover=..., router='nearest')``.
    Used by ``benchmarks/fleet_scale.py --mobility`` and the handover
    invariant tests."""
    from repro.fleet.mobility import HandoverController, make_mobile_fleet
    cfg, graph, planner = smoke_lm_scenario(arch,
                                            latency_req_s=latency_req_s)
    # streaming per-token downlink (multimodal features back to the device):
    # decode rounds exercise the wireless link every token, so a degrading
    # serving link hurts *in-flight* requests — the regime handover rescues
    graph.result_bytes = int(result_kb * 1024)
    topo, mobility = make_mobile_fleet(num_devices, num_edges, seed=seed,
                                       speed=speed, horizon_s=horizon_s,
                                       **mobile_kwargs)
    controller = None if policy == "none" else HandoverController(
        mobility, policy=policy, sample_dt=sample_dt, hazard=hazard)
    return cfg, graph, planner, topo, mobility, controller
