"""Fleet-scale event-driven serving simulator (docs/fleet.md).

Many devices x many edges on a virtual clock: bandwidth-aware routing,
continuous batching per edge, and per-pair Edgent planning reused fleet-wide
through a shared ``CoInferenceStepper``.
"""
from repro.fleet.cluster import (DeviceNode, EdgeNode, FleetTopology,  # noqa: F401
                                 TraceLink, make_fleet)
from repro.fleet.coop import (CoopAssignment, assign_spans,  # noqa: F401
                              hop_schedule, span_seconds)
from repro.fleet.engine import FleetEngine  # noqa: F401
from repro.fleet.events import Event, EventQueue  # noqa: F401
from repro.fleet.joint import JointDecision, JointPlanner  # noqa: F401
from repro.fleet.metrics import FleetMetrics, RequestRecord  # noqa: F401
from repro.fleet.scenario import smoke_lm_scenario  # noqa: F401
from repro.fleet.router import (BandwidthAwareRouter,  # noqa: F401
                                JoinShortestQueueRouter, JointRouter,
                                RoundRobinRouter, Router, make_router)
from repro.fleet.workload import (DEFAULT_TENANTS, FleetRequest,  # noqa: F401
                                  TenantClass, diurnal_arrivals,
                                  make_workload, poisson_arrivals)
