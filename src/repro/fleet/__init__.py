"""Fleet-scale event-driven serving simulator (docs/fleet.md).

Many devices x many edges on a virtual clock: bandwidth-aware routing,
continuous batching per edge, and per-pair Edgent planning reused fleet-wide
through a shared ``CoInferenceStepper``.  Cooperative multi-edge spans and
joint (edge-set, partition, exit) planning live in ``fleet.coop`` /
``fleet.joint`` (docs/coop.md); device mobility and BOCD-driven mid-request
handover live in ``fleet.mobility`` (docs/handover.md).

Experiments are declared one layer up: ``repro.sim`` (docs/api.md) wires
topology + workload + planner + router + engine from a serializable
``ScenarioSpec``.  The ``smoke_*_scenario`` tuple helpers re-exported here
are deprecated shims over that API.
"""
from repro.fleet.cluster import (DeviceNode, EdgeNode, FleetTopology,  # noqa: F401
                                 TraceLink, make_fleet)
from repro.fleet.coop import (CoopAssignment, assign_spans,  # noqa: F401
                              hop_schedule, span_seconds)
from repro.fleet.engine import FleetEngine  # noqa: F401
from repro.fleet.events import Event, EventQueue  # noqa: F401
from repro.fleet.joint import JointDecision, JointPlanner  # noqa: F401
from repro.fleet.metrics import FleetMetrics, RequestRecord  # noqa: F401
from repro.fleet.mobility import (HandoverController, MobileLink,  # noqa: F401
                                  MobilityModel, Trajectory, edge_grid,
                                  make_mobile_fleet, migration_bytes,
                                  random_trajectory)
from repro.fleet.scenario import (smoke_lm_scenario,  # noqa: F401
                                  smoke_mobility_scenario)
from repro.fleet.router import (BandwidthAwareRouter,  # noqa: F401
                                JoinShortestQueueRouter, JointRouter,
                                NearestEdgeRouter, RoundRobinRouter, Router,
                                make_router)
from repro.fleet.workload import (DEFAULT_TENANTS, FleetRequest,  # noqa: F401
                                  TenantClass, diurnal_arrivals,
                                  make_workload, poisson_arrivals)
