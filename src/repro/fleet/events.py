"""Virtual-clock discrete-event core of the fleet simulator.

A single binary heap orders :class:`Event`s by ``(time, seq)``; the ``seq``
counter breaks ties deterministically, which pins the **ordering contract**
(tested by ``tests/test_fleet.py::test_event_queue_orders_by_time_then_fifo``
and ``test_event_queue_tie_break_contract``):

* events pop in ascending ``time``;
* events pushed with the *same* timestamp pop in push (FIFO) order — the
  ``seq`` tie-break — regardless of kind or payload;
* therefore an event pushed *while handling* an event at time ``t`` pops
  after every event already scheduled for ``t``.

That last property is what lets the engine batch all per-device bandwidth
samples of one time slot into a single fleet-wide ``sample`` sweep event
(devices observed in ascending id order) without reordering anything: the
per-device sample events it replaces were themselves pushed — and therefore
popped — in device order, ahead of any same-timestamp event scheduled later.
A fixed seed always replays the identical schedule regardless of host speed.

``Event`` is a :class:`~typing.NamedTuple` so heap comparisons are plain
C-level tuple comparisons (the previous ``@dataclass(order=True)`` spent a
measurable slice of large simulations inside generated ``__lt__``); the
unique ``seq`` in slot 1 guarantees comparisons never reach ``kind``.
"""
from __future__ import annotations

import heapq
from typing import Any, List, NamedTuple


class Event(NamedTuple):
    time: float
    seq: int
    kind: str
    payload: Any = None


class EventQueue:
    """Min-heap of events + the simulator's virtual clock (``now``)."""

    def __init__(self):
        self._heap: List[Event] = []
        self._seq = 0
        self.now = 0.0

    def push(self, time_s: float, kind: str, payload: Any = None) -> Event:
        """Schedule an event; same-time events pop in push (FIFO) order."""
        ev = Event(time_s, self._seq, kind, payload)
        self._seq += 1
        heapq.heappush(self._heap, ev)
        return ev

    def pop(self) -> Event:
        """Remove and return the earliest event, advancing ``now``."""
        ev = heapq.heappop(self._heap)
        self.now = ev.time
        return ev

    def __len__(self) -> int:
        return len(self._heap)

    def __bool__(self) -> bool:
        return bool(self._heap)
