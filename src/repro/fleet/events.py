"""Virtual-clock discrete-event core of the fleet simulator.

A single binary heap orders :class:`Event`s by ``(time, seq)``; the ``seq``
counter breaks ties deterministically (FIFO among simultaneous events), so a
fixed seed always replays the identical schedule regardless of host speed.
"""
from __future__ import annotations

import heapq
from dataclasses import dataclass, field
from typing import Any, List


@dataclass(order=True)
class Event:
    time: float
    seq: int
    kind: str = field(compare=False)
    payload: Any = field(compare=False, default=None)


class EventQueue:
    """Min-heap of events + the simulator's virtual clock (``now``)."""

    def __init__(self):
        self._heap: List[Event] = []
        self._seq = 0
        self.now = 0.0

    def push(self, time_s: float, kind: str, payload: Any = None) -> Event:
        """Schedule an event; same-time events pop in push (FIFO) order."""
        ev = Event(time_s, self._seq, kind, payload)
        self._seq += 1
        heapq.heappush(self._heap, ev)
        return ev

    def pop(self) -> Event:
        """Remove and return the earliest event, advancing ``now``."""
        ev = heapq.heappop(self._heap)
        self.now = ev.time
        return ev

    def __len__(self) -> int:
        return len(self._heap)

    def __bool__(self) -> bool:
        return bool(self._heap)
