"""Fleet elasticity: autoscaling, admission control, and the price model.

Edgent promises *on-demand* acceleration, but a fixed ``capacity=8`` edge
cannot answer capacity-planning questions: saturated cells silently degrade
instead of scaling up or shedding load.  This module makes per-edge capacity
a first-class dynamic quantity:

* :class:`Autoscaler` — a deterministic threshold policy over the streaming
  backlog/utilization gauges the engine already maintains (the same SoA rows
  ``repro.obs.Timeline`` snapshots).  The engine runs it on a dedicated
  ``scale`` event grid; decisions are (edge, target-slots) pairs.  Scale-down
  *drains*: busy slots are never reclaimed — the engine steps provisioned
  capacity down at round boundaries as requests retire (docs/elastic.md).
* :class:`AdmissionControl` — a per-cell reject path at saturated edges:
  ``policy='reject'`` sheds the arrival outright (an explicit ``rejected``
  outcome in :class:`~repro.fleet.metrics.FleetMetrics`), ``policy='local'``
  degrades it to device-only execution.  ``JointPlanner`` additionally masks
  saturated primaries so joint routing steers around full cells before the
  engine-level backstop fires.
* the price model — capacity costs ``usd_per_slot_hour`` while provisioned;
  the engine integrates the piecewise-constant capacity timeline into
  ``FleetMetrics.slot_s`` and ``summary()['cost_usd']``, which is what the
  cost-vs-SLO frontier sweeps trade off (``repro.sim.sweep --frontier``).

Everything here is deterministic and pure with respect to the virtual clock:
the same spec replays the identical scale-event log bit-for-bit, and with no
autoscaler/admission attached the engine's behavior is byte-identical to the
pre-elasticity code paths (golden-pinned by tests/test_elastic.py).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Tuple

import numpy as np

from repro.fleet.cluster import EdgeNode, FleetTopology

__all__ = ["AdmissionControl", "Autoscaler", "build_elasticity"]


@dataclass
class Autoscaler:
    """Threshold autoscaling over live per-edge gauges.

    Scale **up** by ``step`` slots when an edge's ``backlog_s`` (pending
    seconds of work) exceeds ``up_backlog_s``; scale **down** by ``step``
    when the queue is empty and the running batch fills at most
    ``down_util`` of the provisioned slots.  ``cooldown_s`` rate-limits
    decisions per edge; ``min_slots >= 1`` is enforced because a zero-slot
    edge with queued work would stall the event loop.

    ``planner`` (optional) is a :class:`repro.runtime.elastic.ElasticPlanner`
    calibrated with the fleet's latency models: when a scale-down changes an
    edge's effective speed-per-slot economics, the engine asks it to re-price
    queued requests' (partition, exit) plans (``FleetEngine._replan_shrunk``).
    """
    min_slots: int = 1
    max_slots: int = 16
    decide_dt: float = 1.0           # scale-event grid period (virtual s)
    up_backlog_s: float = 1.0        # pending-work trigger for scale-up
    down_util: float = 0.25          # batch-fill ceiling for scale-down
    step: int = 1                    # slots added/removed per decision
    cooldown_s: float = 0.0          # per-edge minimum gap between decisions
    usd_per_slot_hour: float = 1.0   # the price model ($ per slot-hour)
    planner: object = None           # optional ElasticPlanner (shrink replan)
    _last: Dict[int, float] = field(default_factory=dict, repr=False)

    def __post_init__(self):
        if self.min_slots < 1:
            raise ValueError(
                f"min_slots must be >= 1 (a zero-slot edge with queued work "
                f"stalls the event loop), got {self.min_slots}")
        if self.max_slots < self.min_slots:
            raise ValueError(
                f"max_slots ({self.max_slots}) must be >= min_slots "
                f"({self.min_slots})")
        if self.decide_dt <= 0:
            raise ValueError(f"decide_dt must be positive, got "
                             f"{self.decide_dt}")
        if self.step < 1:
            raise ValueError(f"step must be >= 1, got {self.step}")

    def reset(self) -> None:
        """Engine calls this per run: decisions must not leak across runs
        (the same determinism contract routers follow)."""
        self._last.clear()

    def decide(self, now: float,
               topo: FleetTopology) -> List[Tuple[int, int]]:
        """(eid, target-slots) for every edge whose gauges cross a threshold
        this tick.  Deterministic: edges are scanned in id order and the
        decision is a pure function of (now, live edge state)."""
        out: List[Tuple[int, int]] = []
        for e in topo.edges:
            last = self._last.get(e.eid)
            if last is not None and now - last < self.cooldown_s:
                continue
            cap = e.capacity
            if e.backlog_s() > self.up_backlog_s and cap < self.max_slots:
                self._last[e.eid] = now
                out.append((e.eid, min(self.max_slots, cap + self.step)))
            elif cap > self.min_slots \
                    and len(e.queue) - e.q_dead == 0 \
                    and len(e.active) <= self.down_util * cap:
                self._last[e.eid] = now
                out.append((e.eid, max(self.min_slots, cap - self.step)))
        return out


@dataclass
class AdmissionControl:
    """Per-cell admission control: an edge is *saturated* once its bound
    requests (queued + in the batch) reach ``capacity + max_queue``.

    ``policy='reject'`` sheds saturated arrivals outright (counted as
    ``rejected`` in FleetMetrics — never silently dropped);
    ``policy='local'`` degrades them to device-only execution (the request
    still completes, on its own hardware).  The saturation test reads the
    engine-maintained SoA backlog mirror, so the joint planner can mask a
    whole fleet row at once (:meth:`saturated_row`)."""

    POLICIES = ("reject", "local")

    policy: str = "reject"
    max_queue: int = 0

    def __post_init__(self):
        if self.policy not in self.POLICIES:
            raise ValueError(
                f"unknown admission policy {self.policy!r}: expected one "
                f"of {', '.join(self.POLICIES)}")
        if self.max_queue < 0:
            raise ValueError(f"max_queue must be >= 0, got {self.max_queue}")

    def saturated(self, edge: EdgeNode) -> bool:
        return edge.backlog() >= edge.capacity + self.max_queue

    def saturated_row(self, topo: FleetTopology) -> np.ndarray:
        """Boolean saturation per edge, elementwise identical to
        :meth:`saturated` over ``topo.edges`` (the JointPlanner mask)."""
        return topo.backlog_n_row() >= topo.edge_capacity + self.max_queue


def build_elasticity(autoscale, admission, *, graph=None, planner=None,
                     latency_req_s: float = 0.5, ref_chips: int = 8):
    """Spec -> live policy objects, shared by ``repro.sim.build`` and
    ``repro.sim.shard``.  ``autoscale`` / ``admission`` are the plain-data
    :class:`~repro.sim.spec.AutoscaleSpec` / ``AdmissionSpec`` (duck-typed —
    anything with the same attributes works); either may be ``None``.

    When the autoscale spec asks for shrink re-planning and the caller
    provides the model stack, the autoscaler gets an
    :class:`~repro.runtime.elastic.ElasticPlanner` built from the fleet's
    *calibrated* latency models (``ref_chips`` = the slots those models
    price one edge at), so shrunk-edge plans re-price on the same cost
    surface the Edgent planner used."""
    adm = None
    if admission is not None:
        adm = AdmissionControl(policy=admission.policy,
                               max_queue=admission.max_queue)
    sca = None
    if autoscale is not None:
        ep = None
        if getattr(autoscale, "replan_on_shrink", False) \
                and graph is not None and planner is not None:
            from repro.runtime.elastic import ElasticPlanner
            ep = ElasticPlanner(graph=graph, latency_req_s=latency_req_s,
                                link_bps=1.0, f_edge=planner.f_edge,
                                f_dev=planner.f_device, ref_chips=ref_chips)
        sca = Autoscaler(
            min_slots=autoscale.min_slots, max_slots=autoscale.max_slots,
            decide_dt=autoscale.decide_dt,
            up_backlog_s=autoscale.up_backlog_s,
            down_util=autoscale.down_util, step=autoscale.step,
            cooldown_s=autoscale.cooldown_s,
            usd_per_slot_hour=autoscale.usd_per_slot_hour, planner=ep)
    return sca, adm
