"""Event-driven fleet engine: continuous batching per edge over a
device x edge topology.

Per arrival the router picks an edge; the edge holds an EDF queue and a
running batch of up to ``capacity`` requests.  Decode proceeds in *rounds*
(one token per active request per round): at each round boundary new
requests are admitted into the running batch and finished ones retire —
iteration-level continuous batching.  Round timing reuses the per-pair
Edgent stack through :class:`~repro.serving.engine.CoInferenceStepper`
(plan at the device's current bandwidth, per-exit step times, ``pick_exit``
deadline demotion); the round lasts as long as its slowest member, i.e. the
straggler defines the batch step.

With ``model=None`` the engine is a pure virtual-time simulator (used by
``benchmarks/fleet_scale.py`` at hundreds of devices).  With a real model +
params it also runs the actual decode path per request (B=1 caches, the
jitted per-exit variants shared fleet-wide via the stepper).

With ``mobility=`` + ``handover=`` the engine additionally models **device
motion and mid-request migration** (docs/handover.md): per-round bandwidth
is billed to the request's *serving* edge from the position->bandwidth law,
periodic ``sample`` events feed each device's handover policy (BOCD change
points or the geometry oracle), and a fired policy re-plans the device's
in-flight requests via :meth:`~repro.fleet.joint.JointPlanner.replan` —
snapshotting the edge-resident state at the current cut, billing the
transfer over the backbone, and re-binding the request to its new primary
without dropping or double-counting it.
"""
from __future__ import annotations

import heapq
import time
from typing import List, Optional, Union

import numpy as np

from repro.core.graph import InferenceGraph
from repro.core.planner import EdgentPlanner
from repro.fleet.cluster import EdgeNode, FleetTopology
from repro.fleet.coop import (effective_assignment, hop_schedule,
                              span_seconds)
from repro.fleet.events import EventQueue
from repro.fleet.joint import JointDecision, JointPlanner
from repro.fleet.metrics import FleetMetrics, RequestRecord
from repro.fleet.mobility import (HandoverController, MobilityModel,
                                  migration_bytes)
from repro.fleet.router import Router, RoundRobinRouter, make_router
from repro.fleet.workload import FleetRequest
from repro.serving.engine import CoInferenceStepper


class FleetEngine:
    """Event-driven fleet simulator: see the module docstring for the model
    and docs/fleet.md for the architecture.  ``run(workload)`` is the only
    public entry point; everything else is event handlers."""

    def __init__(self, topo: FleetTopology, graph: InferenceGraph,
                 planner: EdgentPlanner, *,
                 router: Union[Router, str, None] = None,
                 model=None, params=None, dynamic: bool = False,
                 dtype=None, demote_on_deadline: bool = True,
                 prefill_div: int = 8,
                 mobility: Optional[MobilityModel] = None,
                 handover: Union[HandoverController, str, None] = None,
                 replan_max_coop: int = 1, max_coop: int = 3,
                 retain_records: bool = True,
                 compact_ratio: Optional[float] = 0.5,
                 autoscaler=None, admission=None,
                 tracer=None, timeline=None, profiler=None,
                 batch_decode: bool = True, shard_decode: bool = False,
                 arena_decode: bool = False, arena_bucket: str = "pow2"):
        self.topo = topo
        # elasticity (fleet.elastic, docs/elastic.md): an Autoscaler drives
        # `scale` events that resize per-edge capacity (scale-down drains —
        # busy slots are never reclaimed); an AdmissionControl sheds
        # arrivals at saturated edges (reject or device-only fallback).
        # Both None (the default) leaves every code path byte-identical to
        # the pre-elasticity engine (golden-pinned).
        self.autoscaler = autoscaler
        self.admission = admission
        self._cap_target = {}          # eid -> pending drain target
        # EDF-heap tombstone compaction threshold (None disables); see
        # _maybe_compact.  Summaries are bit-identical either way.
        self.compact_ratio = compact_ratio
        self.compactions = 0
        # observability (repro.obs, docs/observability.md) — all optional,
        # all read-only with respect to simulation state, so summaries are
        # bit-identical with observers attached or not (tests/test_obs.py):
        #   tracer   — repro.obs.trace.Tracer, fed at every lifecycle edge
        #   timeline — repro.obs.timeline.Timeline, sampled on the sweep
        #              grid (or dedicated "obs" events for static fleets)
        #   profiler — repro.obs.profile.SimProfiler, wall time per event
        self.tracer = tracer
        self.timeline = timeline
        self.profiler = profiler
        self.model, self.params = model, params
        self.dtype = dtype
        # real-decode execution strategy (docs/calibration.md): with
        # batch_decode a round's co-located requests decode as vmapped
        # groups — one compiled call per (exit, cache-geometry) group —
        # instead of one call per request; shard_decode additionally
        # shard_maps the group over the host's device mesh when one exists.
        # Token values are bit-identical either way (tests/test_calib.py);
        # virtual timing never depends on these flags.
        self.batch_decode = batch_decode
        self.shard_decode = shard_decode
        # slot-resident decode arena (docs/performance.md): with
        # arena_decode each edge holds a persistent [slots, ...] KV stack —
        # requests scatter in at admission, stay resident across rounds,
        # and a round is at most one masked compiled call per model exit
        # (no per-token restacking, no pad-by-replication).  Token values
        # stay bit-identical to the serial path (tests/test_arena.py);
        # virtual timing never depends on the flag.
        self.arena_decode = arena_decode
        self.arena_bucket = arena_bucket
        self._arenas = {}              # eid -> DecodeArena (reset per run)
        self._arena_len_hint = 1
        self.demote = demote_on_deadline
        self.prefill_div = prefill_div
        # retain_records=False keeps FleetMetrics to its running aggregates
        # (summaries unchanged, memory ~O(edges) instead of per-request
        # record objects) — the 10k-device setting
        self.retain_records = retain_records
        # one stepper for the whole fleet: the plan cache and the compiled
        # decode variants are shared across every device and edge
        self.stepper = CoInferenceStepper(model, graph, planner,
                                          dynamic=dynamic)
        self.mobility = mobility
        if isinstance(handover, str):
            if handover not in HandoverController.POLICIES:
                raise ValueError(
                    f"unknown handover policy {handover!r}: expected one "
                    f"of {', '.join(HandoverController.POLICIES)} (see "
                    "repro.fleet.mobility.HandoverController)")
            if mobility is None:
                raise ValueError(
                    f"handover={handover!r} needs a mobility model: pass "
                    "mobility= alongside the policy name (from "
                    "make_mobile_fleet, or build the engine via a "
                    "repro.sim mobile topology)")
            handover = HandoverController(mobility, policy=handover)
        self.handover = handover
        # mid-request replanning searches (edge set, partition, exit) with
        # nearest-first candidate ordering; max_coop=1 keeps migrated
        # requests single-edge by default (coop re-binding is opt-in)
        self.replanner = JointPlanner(
            self.stepper, topo, max_coop=replan_max_coop,
            prefill_div=prefill_div, mobility=mobility) \
            if mobility is not None else None
        if router is None:
            router = RoundRobinRouter()
        elif isinstance(router, str):
            # make_router validates the name against the registry and
            # raises ValueError (with the known names) on a bad one
            router = make_router(router, stepper=self.stepper, topo=topo,
                                 max_coop=max_coop, prefill_div=prefill_div,
                                 mobility=mobility, admission=admission)
        self.router = router
        # hop/span timelines are memoized on the *stepper* (fleet-wide: all
        # engines sharing the stepper share the entries), keyed on exit,
        # assignment, and this topology's backbone bandwidth
        self._hop_cache = self.stepper.hop_cache
        # run() resets these; initialized here so _enqueue/_dequeue work on
        # an engine driven directly (tests exercise queue mechanics bare)
        self.events_processed = 0
        self.event_counts = {}
        self.enqueued = self.tombstoned = 0

    # ---------------------------------------------------------------- run
    def run(self, workload: List[FleetRequest]) -> FleetMetrics:
        """Simulate one workload to completion and return its metrics.

        Deterministic: the same topology + workload + seed replays the
        identical event schedule (bit-identical summaries).  Engines and
        workload lists are reusable — all runtime state is reset here."""
        evq = EventQueue()
        metrics = FleetMetrics(num_edges=self.topo.num_edges,
                               retain_records=self.retain_records)
        self._qseq = 0
        self._pending = len(workload)      # requests not yet completed
        self._dev_inflight = {d.did: [] for d in self.topo.devices}
        self._qentry = {}                  # req -> its live edge-queue entry
        self.router.reset()                # stateful policies must not leak
        #                                    decisions across runs
        if self.handover is not None:
            self.handover.reset()
        for edge in self.topo.edges:       # reset runtime state for reruns
            edge.queue, edge.active = [], []
            edge.q_dead = 0
            edge.round_inflight = False
            edge.busy_s = edge.ema_round_s = 0.0
            edge.completed = 0
            edge.coop_inflight = 0
            edge.tokens_owed = 0
        self.topo._soa.backlog_n[:] = 0
        self.compactions = 0
        for dev in self.topo.devices:
            dev.busy_until_s = 0.0
        elastic = self.autoscaler is not None or self.admission is not None
        if elastic:
            metrics.elastic = True
            self._cap_target = {}
            if self.autoscaler is not None:
                # rerunnable engines: capacity restarts from the
                # provisioned-at-build snapshot, not wherever the previous
                # run's autoscaler left it
                soa = self.topo._soa
                soa.capacity[:] = self.topo.base_capacity
                soa.edge_cap_div[:] = np.maximum(
                    self.topo.base_capacity, 1).astype(float)
                self.autoscaler.reset()
                metrics.usd_per_slot_hour = self.autoscaler.usd_per_slot_hour
                if workload:
                    evq.push(self.autoscaler.decide_dt, "scale", None)
            # the price model integrates *live* capacity from t=0, so the
            # timeline opens for every edge even if it never changes
            for edge in self.topo.edges:
                metrics.mark_capacity(edge.eid, edge.capacity, 0.0)
        self._arenas = {}                  # arena residency is per-run state
        if self.arena_decode and self.model is not None:
            # pre-size the arena length from the workload so steady-state
            # geometry (and the compiled-variant population) is fixed from
            # the first round: the longest cache any request will need
            self._arena_len_hint = max(
                (r.prompt_len + r.max_new_tokens + 1 for r in workload),
                default=1)
        for req in workload:               # same: a workload list is reusable
            req.edge, req.admitted_s = -1, None
            req.assign = None
            req.tokens_done, req.prefill_pending = 0, True
            req.plan, req.exit_point = None, 0
            req.cache, req.next_tok, req.tokens = None, None, []
            req.replan_pending = req.migrating = False
            req.handovers, req.migrated_bytes = 0, 0
            req.coop_counted = False
            evq.push(req.arrival_s, "arrival", req)
        sweeping = self.handover is not None and self.handover.policy != "none"
        if sweeping:
            # one fleet-wide sampling sweep per slot: the sweep observes
            # every device in ascending id order — the exact pop order the
            # per-device events it batches had under the EventQueue's FIFO
            # tie-break (see repro.fleet.events)
            evq.push(self.handover.sample_dt, "sample", None)
        if self.tracer is not None:
            self.tracer.reset()            # reused engines: one run per file
            self.tracer.annotate_fleet(self.topo)
        if self.timeline is not None:
            self.timeline.reset()
            if not sweeping and workload:
                # no sampling grid to piggyback on: schedule a dedicated
                # snapshot grid.  "obs" events never mutate state, and the
                # EventQueue's FIFO tie-break keeps the relative order of
                # all other events unchanged — summaries stay bit-identical
                # with the timeline attached (tests/test_obs.py)
                evq.push(self.timeline.dt, "obs", None)
        prof = self.profiler
        if prof is not None:
            prof.reset()
        self.events_processed = 0          # sweeps count once per device
        self.event_counts = {}             # heap pops by event kind
        self.enqueued = self.tombstoned = 0
        while evq:
            ev = evq.pop()
            self.events_processed += 1
            kind = ev.kind
            self.event_counts[kind] = self.event_counts.get(kind, 0) + 1
            if prof is not None:
                t0 = time.perf_counter()
            if kind == "arrival":
                self._on_arrival(ev.payload, evq, metrics)
            elif kind == "round":
                self._on_round_done(ev.payload, evq, metrics)
            elif kind == "local_done":
                self._on_local_done(ev.payload, evq, metrics)
            elif kind == "transfer":
                src, dst, nbytes = ev.payload
                metrics.add_transfer(src, dst, nbytes)
            elif kind == "sample":
                self._on_sample_sweep(evq, metrics)
            elif kind == "handover":
                self._on_handover(ev.payload, evq, metrics)
            elif kind == "scale":
                self._on_scale(evq, metrics)
            elif kind == "obs":
                self._on_obs(evq)
            if prof is not None:
                prof.add(kind, time.perf_counter() - t0, len(evq))
        if elastic:
            metrics.finalize_capacity()
        if self.tracer is not None and self.model is not None:
            # decode-efficiency panel data for `repro.obs report`: a trace
            # metadata record (no timestamp — it is not a span), read-only
            # with respect to the simulation like every tracer write.
            # Stepper counters are cumulative over its lifetime.
            st = self.stepper.cache_stats()
            self.tracer.decode_stats({"decode": st["decode"],
                                      "arena": st["arena"],
                                      "jit": st["jit"]})
        return metrics

    # ------------------------------------------------------------ bandwidth
    def _bw(self, device, eid: int, now: float) -> float:
        """Wireless bandwidth the device sees *to a specific edge*: under
        mobility this is the position-dependent per-pair rate (a request
        keeps paying its serving edge's link, which degrades as the device
        walks away); otherwise the device's single trace."""
        if self.mobility is not None and eid >= 0:
            return self.mobility.bw(device.did, eid, now)
        return device.link.bw_at(now)

    # ---------------------------------------------------------------- events
    def _on_arrival(self, req: FleetRequest, evq: EventQueue,
                    metrics: FleetMetrics):
        device = self.topo.device(req.device)
        bw = device.link.bw_at(evq.now)
        tr = self.tracer
        if tr is not None:
            # request-scoped async span: survives queue moves and handovers
            tr.async_begin("request", req.rid, evq.now, tr.PID_DEVICES,
                           req.device, args={"tenant": req.tenant,
                                             "device": req.device})
        decision = self.router.decide(req, device, self.topo, evq.now)
        if decision is not None:
            # joint routing: (edge set, partition, exit) chosen together;
            # the primary edge hosts the queue slot and decode rounds
            req.plan, req.assign = decision.plan, decision.assign
            if decision.local:
                self._run_local(req, device, bw, evq)
                return
            edge = self.topo.edge(decision.primary)
        else:
            req.plan = self.stepper.plan(bw)
            if req.plan.partition == 0:
                # Edgent chose device-only: the request never touches an edge
                self._run_local(req, device, bw, evq)
                return
            edge = self.router.route(req, device, self.topo, evq.now)
            if self.mobility is not None:
                # mobility-aware pricing: the router shopped with the *best*
                # signal (MobileLink.bw_at = nearest edge); once placement
                # is fixed, the plan must price the link the request will
                # actually pay — the serving edge's.  For the nearest-edge
                # router the two bandwidths are identical and this is a
                # no-op; for placement policies that pick another edge the
                # old code silently kept the best-signal plan.  (The joint
                # decision branch above already prices each candidate at
                # its own primary's bandwidth — JointPlanner._decide_mobile.)
                bw_serve = self._bw(device, edge.eid, evq.now)
                if bw_serve != bw:
                    req.plan = self.stepper.plan(bw_serve)
                    if req.plan.partition == 0:
                        self._run_local(req, device, bw_serve, evq)
                        return
        if self.admission is not None and self.admission.saturated(edge):
            # per-cell admission control: the placed edge is full.  (Joint
            # routing already masks saturated primaries — this is the
            # engine-level backstop for placement-only routers.)
            self._admission_deny(req, device, bw, evq, metrics)
            return
        req.edge = edge.eid
        if tr is not None:
            tr.instant("plan", evq.now, tr.PID_DEVICES, req.device, args={
                "rid": req.rid, "partition": req.plan.partition,
                "exit": req.plan.exit_point, "edge": edge.eid,
                "coop": list(req.assign.eids) if req.assign is not None
                else [edge.eid]})
            tr.async_begin("queue", req.rid, evq.now, tr.PID_DEVICES,
                           req.device, args={"edge": edge.eid})
        self._enqueue(edge, req)
        edge.tokens_owed += req.max_new_tokens
        self._dev_inflight[req.device].append(req)
        if not edge.round_inflight:
            self._begin_round(edge, evq, metrics)

    def _enqueue(self, edge: EdgeNode, req: FleetRequest):
        """EDF-queue a request at an edge.  Entries are mutable lists so a
        mid-request replan can *tombstone* them in O(1) (slot 2 set to None)
        instead of rebuilding + re-heapifying the whole queue; admission
        skips dead entries as they surface (lazy deletion)."""
        entry = [req.deadline_s, self._qseq, req]
        self._qentry[req] = entry
        heapq.heappush(edge.queue, entry)
        self._qseq += 1
        self.enqueued += 1
        self._blg_add(edge, 1)

    def _dequeue(self, edge: EdgeNode, req: FleetRequest):
        """Remove a queued request in O(1): tombstone its heap entry."""
        entry = self._qentry.pop(req)
        entry[2] = None
        edge.q_dead += 1
        self.tombstoned += 1
        self._blg_add(edge, -1)
        self._maybe_compact(edge)

    @staticmethod
    def _blg_add(edge: EdgeNode, delta: int):
        """Maintain the SoA mirror of ``EdgeNode.backlog()`` (queued +
        active, tombstones excluded) at its net-change sites: enqueue (+1),
        tombstone (-1), completion (-1), migration off the batch (-1).
        Queue->batch admission is net zero.  Bare edges (no topology) have
        no row to maintain."""
        s = edge._soa
        if s is not None:
            s.backlog_n[edge._idx] += delta

    def _maybe_compact(self, edge: EdgeNode):
        """Rebuild an edge's EDF heap once tombstones exceed
        ``compact_ratio`` of its entries.  Lazy O(1) deletion alone lets
        dead entries accumulate without bound over a long mobility run
        (every push pays log of the *inflated* heap); dropping them and
        re-heapifying is O(live) and amortized O(1) per tombstone.  Pop
        order is untouched — the heap is a total order on (deadline, seq),
        and admission skips tombstones either way — so summaries and the
        handover log are bit-identical with compaction on or off
        (tests/test_fleet_perf.py pins this)."""
        ratio = self.compact_ratio
        if ratio is None:
            return
        q_dead = edge.q_dead
        if q_dead and q_dead >= ratio * len(edge.queue):
            edge.queue = [en for en in edge.queue if en[2] is not None]
            heapq.heapify(edge.queue)
            edge.q_dead = 0
            self.compactions += 1

    def _run_local(self, req: FleetRequest, device, bw: float,
                   evq: EventQueue):
        # the device decodes one request at a time: later arrivals queue
        # behind its in-flight local work (no free concurrency on-device)
        now = evq.now
        start = max(now, device.busy_until_s)
        req.admitted_s = start
        per_exit = self.stepper.per_exit_times_cached(
            0, bw, device_load=device.slowdown)
        # prefill is billed at the plan exit regardless of demotion, so it
        # must come out of the budget the exit choice sees
        prefill = per_exit[req.plan.exit_point - 1] * \
            max(1, req.prompt_len // self.prefill_div)
        req.exit_point = self.stepper.choose_exit(
            req.deadline_s - start - prefill, per_exit, req.max_new_tokens,
            req.plan.exit_point) if self.demote else req.plan.exit_point
        total = per_exit[req.exit_point - 1] * req.max_new_tokens + prefill
        tr = self.tracer
        if tr is not None:
            did = device.did
            tr.instant("plan", now, tr.PID_DEVICES, did, args={
                "rid": req.rid, "partition": 0,
                "exit": req.plan.exit_point})
            if start > now:
                tr.complete("queue", now, start, tr.PID_DEVICES, did,
                            args={"rid": req.rid})
            if prefill > 0.0:
                tr.complete("prefill", start, start + prefill,
                            tr.PID_DEVICES, did, args={"rid": req.rid})
            tr.complete("decode", start + prefill, start + total,
                        tr.PID_DEVICES, did,
                        args={"rid": req.rid, "exit": req.exit_point,
                              "tokens": req.max_new_tokens})
        if self.model is not None:
            self._prefill_real(req)
            while req.tokens_done < req.max_new_tokens:
                self._decode_real(req)
                req.tokens_done += 1
            req.cache = req.next_tok = None
        device.busy_until_s = start + total
        evq.push(start + total, "local_done", req)

    def _on_local_done(self, req: FleetRequest, evq: EventQueue,
                       metrics: FleetMetrics):
        now = evq.now
        self._pending -= 1
        tr = self.tracer
        if tr is not None:
            met = now <= req.deadline_s
            tr.instant("complete", now, tr.PID_DEVICES, req.device,
                       args={"rid": req.rid, "met_slo": met,
                             "exit": req.exit_point})
            tr.async_end("request", req.rid, now, tr.PID_DEVICES,
                         req.device, args={"met_slo": met})
        metrics.record(RequestRecord(
            rid=req.rid, tenant=req.tenant, device=req.device, edge=-1,
            arrival_s=req.arrival_s, finish_s=now,
            latency_s=max(0.0, now - req.arrival_s),
            queue_delay_s=max(0.0, (req.admitted_s or 0.0) - req.arrival_s),
            met_slo=now <= req.deadline_s, exit_point=req.exit_point,
            partition=0, handovers=req.handovers,
            migrated_bytes=req.migrated_bytes))

    def _on_round_done(self, edge: EdgeNode, evq: EventQueue,
                       metrics: FleetMetrics):
        now = evq.now
        still_active = []
        for req in edge.active:
            req.tokens_done += 1
            edge.tokens_owed -= 1
            if req.tokens_done >= req.max_new_tokens:
                edge.completed += 1
                self._blg_add(edge, -1)
                self._pending -= 1
                self._untrack(req)
                if self.tracer is not None:
                    tr = self.tracer
                    met = now <= req.deadline_s
                    tr.instant("complete", now, edge.eid, 0,
                               args={"rid": req.rid, "met_slo": met,
                                     "exit": req.exit_point})
                    tr.async_end("request", req.rid, now, tr.PID_DEVICES,
                                 req.device, args={"met_slo": met})
                metrics.record(RequestRecord(
                    rid=req.rid, tenant=req.tenant, device=req.device,
                    edge=edge.eid, arrival_s=req.arrival_s, finish_s=now,
                    latency_s=max(0.0, now - req.arrival_s),
                    queue_delay_s=max(0.0, (now if req.admitted_s is None
                                            else req.admitted_s)
                                      - req.arrival_s),
                    met_slo=now <= req.deadline_s,
                    exit_point=req.exit_point,
                    partition=req.plan.partition,
                    edges=(req.assign.eids if req.assign is not None
                           else (edge.eid,)),
                    handovers=req.handovers,
                    migrated_bytes=req.migrated_bytes))
                self._release_coop(req)
                req.cache = req.next_tok = None      # free decode state
                if self.arena_decode and self.model is not None:
                    ar = self._arenas.get(edge.eid)
                    if ar is not None and ar.has(req.rid):
                        ar.evict(req.rid)            # free the slot row
            elif req.replan_pending:
                # the handover policy fired mid-round; the migration (or
                # in-place replan) executes at this round boundary, where the
                # edge-resident state is at a well-defined cut
                req.replan_pending = False
                self._replan_active(req, edge, now, evq, metrics,
                                    still_active)
            else:
                still_active.append(req)
        edge.active = still_active
        edge.round_inflight = False
        if self.autoscaler is not None:
            # scale-down drain: reclaim provisioned slots as requests retire
            # (capacity never drops below the running batch)
            tgt = self._cap_target.get(edge.eid)
            if tgt is not None:
                cap = max(tgt, len(edge.active))
                if cap < edge.capacity:
                    self._set_capacity(edge, cap, now, metrics)
                if cap == tgt:
                    del self._cap_target[edge.eid]
        self._begin_round(edge, evq, metrics)

    # ---------------------------------------------------------------- rounds
    def _begin_round(self, edge: EdgeNode, evq: EventQueue,
                     metrics: FleetMetrics):
        now = evq.now
        # admit in EDF order up to the batch width (continuous batching:
        # this happens at every round boundary, not at batch completion).
        # While a scale-down is draining, admission is capped at the drain
        # *target*, not the still-provisioned width — otherwise sustained
        # load would refill reclaimed slots and the drain never completes.
        limit = edge.capacity
        if self.autoscaler is not None:
            limit = min(limit, self._cap_target.get(edge.eid, limit))
        while edge.queue and len(edge.active) < limit:
            req = heapq.heappop(edge.queue)[2]
            if req is None:                # tombstoned by a replan
                edge.q_dead -= 1
                continue
            del self._qentry[req]
            if self.tracer is not None:
                self.tracer.async_end("queue", req.rid, now,
                                      self.tracer.PID_DEVICES, req.device)
            if req.admitted_s is None:
                req.admitted_s = now
            if req.assign is not None and not req.coop_counted:
                # (re-)acquire cooperative span slots; a migrated request
                # re-acquires at its new edge set here
                for eid in req.assign.eids[1:]:
                    self.topo.edge(eid).coop_inflight += 1
                req.coop_counted = True
            if self.model is not None:
                if self.arena_decode:
                    # slot-resident path: prefill (or a migrated request's
                    # shipped cache) scatters into the edge arena once here;
                    # the request stays resident until completion/extract
                    ar = self._arena(edge)
                    if not ar.has(req.rid):
                        if req.cache is None:
                            self._prefill_real(req)
                        ar.admit(req.rid, req.cache)
                        req.cache = None   # state lives in the arena now
                elif req.cache is None:
                    # migrated requests keep their shipped cache —
                    # re-prefilling would clobber the decode state the
                    # handover paid to move
                    self._prefill_real(req)
            edge.active.append(req)
        if not edge.active:
            return
        tr = self.tracer
        round_dt = 0.0
        decode_batch = []          # this round's real-decode group
        for slot, req in enumerate(edge.active):
            device = self.topo.device(req.device)
            bw = self._bw(device, edge.eid, now)
            if req.plan is None:
                req.plan = self.stepper.plan(bw)
            if req.assign is not None:
                # cooperative chain: spans at each member's speed + backbone
                # hops (k=1 degenerates to the single-edge numbers exactly)
                per_exit = self.stepper.per_exit_times_coop_cached(
                    req.plan.partition, req.assign.speeds, bw,
                    device_load=device.slowdown,
                    edge_bw_bps=self.topo.edge_bw_bps, include_input=False)
            else:
                per_exit = self.stepper.per_exit_times_cached(
                    req.plan.partition, bw, edge_load=edge.speed,
                    device_load=device.slowdown, include_input=False)
            tokens_left = req.max_new_tokens - req.tokens_done
            # input payload ships once, then prompt_len/8 prefill steps —
            # billed at the plan exit, so the first round's exit choice must
            # budget for it.  (t_up + t_pf is the identical float expression
            # the single-line form computed; the split names the uplink and
            # prefill sub-spans for the tracer.)
            if req.prefill_pending:
                t_up = self.stepper.input_time(req.plan.partition, bw)
                t_pf = per_exit[req.plan.exit_point - 1] * \
                    max(1, req.prompt_len // self.prefill_div)
                prefill = t_up + t_pf
            else:
                t_up = t_pf = prefill = 0.0
            if self.demote:
                req.exit_point = self.stepper.choose_exit(
                    req.deadline_s - now - prefill, per_exit, tokens_left,
                    req.plan.exit_point)
            else:
                req.exit_point = req.plan.exit_point
            t_step = per_exit[req.exit_point - 1] + prefill
            req.prefill_pending = False
            if tr is not None:
                # slot tracks are 1-based (tid 0 is the rounds track)
                tid = slot + 1
                if t_up > 0.0:
                    tr.complete("uplink", now, now + t_up, edge.eid, tid,
                                args={"rid": req.rid})
                if t_pf > 0.0:
                    tr.complete("prefill", now + t_up, now + prefill,
                                edge.eid, tid, args={"rid": req.rid})
                tr.complete("decode", now + prefill, now + t_step,
                            edge.eid, tid,
                            args={"rid": req.rid, "exit": req.exit_point,
                                  "token": req.tokens_done})
            if req.assign is not None and req.assign.k > 1:
                self._emit_hops(req, now, evq, metrics)
            if self.model is not None:
                # token values are produced after the slot loop: the whole
                # round decodes as one batched group (exit choices above are
                # already fixed, so collecting first changes nothing)
                decode_batch.append(req)
            round_dt = max(round_dt, t_step)
        if decode_batch:
            if self.arena_decode:
                self._decode_real_arena(edge, decode_batch)
            else:
                self._decode_real_batch(decode_batch)
        edge.busy_s += round_dt
        metrics.add_busy(edge.eid, round_dt)
        edge.ema_round_s = round_dt if edge.ema_round_s == 0.0 else \
            0.8 * edge.ema_round_s + 0.2 * round_dt
        edge.round_inflight = True
        if tr is not None:
            eid = edge.eid
            tr.complete("round", now, now + round_dt, eid, 0,
                        args={"batch": len(edge.active)})
            tr.counter("backlog_s", now, eid,
                       {"backlog_s": edge.backlog_s()})
            tr.counter("slots", now, eid,
                       {"active": len(edge.active),
                        "queued": len(edge.queue) - edge.q_dead})
            tr.counter("tokens_owed", now, eid,
                       {"tokens_owed": edge.tokens_owed})
            tr.counter("coop_inflight", now, eid,
                       {"coop_inflight": edge.coop_inflight})
        evq.push(now + round_dt, "round", edge)

    # ---------------------------------------------------------------- coop
    def _emit_hops(self, req: FleetRequest, now: float, evq: EventQueue,
                   metrics: FleetMetrics):
        """One decode round of a cooperative request hops across its edge
        set: schedule the inter-edge hand-offs as ``transfer`` events at
        their in-round completion offsets and track each secondary edge's
        span compute as cooperative busy time (the primary's full round —
        which spans the whole chain — is billed by the caller)."""
        key = (req.exit_point, req.assign, self.topo.edge_bw_bps)
        hit = self._hop_cache.get(key)
        if hit is None:
            self.stepper.hop_misses += 1
            f_edge = self.stepper.planner.f_edge
            # a demoted exit's branch can be shorter than the planned
            # partition — hop/busy accounting must follow the clamped spans
            # the latency model actually bills for this exit
            eff = effective_assignment(self.stepper.graph, req.exit_point,
                                       req.assign)
            hit = self._hop_cache[key] = (
                eff,
                hop_schedule(self.stepper.graph, req.exit_point, eff,
                             f_edge, self.topo.edge_bw_bps),
                span_seconds(self.stepper.graph, req.exit_point, eff,
                             f_edge))
        else:
            self.stepper.hop_hits += 1
        eff, hops, spans = hit
        for dt, src, dst, nbytes in hops:
            evq.push(now + dt, "transfer", (src, dst, nbytes))
        if self.tracer is not None:
            tr, bb = self.tracer, self.topo.edge_bw_bps
            for dt, src, dst, nbytes in hops:
                # the wire time of the hop, ending at its completion offset
                tr.complete("transfer", now + dt - nbytes / bb, now + dt,
                            tr.PID_NET, src,
                            args={"rid": req.rid, "src": src, "dst": dst,
                                  "bytes": nbytes})
        # secondary compute is tracked apart from busy_s: the primary's
        # round_dt already covers the full chain, so adding spans to
        # edge_busy_s would double-bill utilization
        for eid, span_s in zip(eff.eids[1:], spans[1:]):
            metrics.add_coop_busy(eid, span_s)

    # ---------------------------------------------------------------- elastic
    def _set_capacity(self, edge: EdgeNode, new: int, now: float,
                      metrics: FleetMetrics):
        """Resize one edge's provisioned slot count: bill the closed
        capacity segment into the price model and log the change."""
        old = edge.capacity
        if new == old:
            return
        metrics.on_scale(edge.eid, old, new, now)
        edge.capacity = new
        if self.tracer is not None:
            self.tracer.counter("capacity", now, edge.eid,
                                {"capacity": new})

    def _on_scale(self, evq: EventQueue, metrics: FleetMetrics):
        """One tick of the autoscaling grid: apply this slot's (edge,
        target) decisions.  Scale-up takes effect immediately (and kicks a
        round if work was waiting on slots); scale-down provisions down to
        ``max(target, running batch)`` now and drains the rest at round
        boundaries (see _on_round_done) — busy slots are never reclaimed.
        The grid self-terminates with the workload, like sample/obs."""
        now = evq.now
        for eid, target in self.autoscaler.decide(now, self.topo):
            edge = self.topo.edge(eid)
            cur = edge.capacity
            self._cap_target.pop(eid, None)   # a fresh decision supersedes
            if target == cur:
                continue
            provision = max(target, len(edge.active))
            if target < provision:
                self._cap_target[eid] = target
            self._set_capacity(edge, provision, now, metrics)
            if target < cur:
                self._replan_shrunk(edge, target, now, evq, metrics)
            elif provision > cur and not edge.round_inflight \
                    and len(edge.queue) - edge.q_dead > 0:
                self._begin_round(edge, evq, metrics)
        if self._pending > 0:
            evq.push(now + self.autoscaler.decide_dt, "scale", None)

    def _replan_shrunk(self, edge: EdgeNode, target: int, now: float,
                       evq: EventQueue, metrics: FleetMetrics):
        """A scale-down changed the edge's effective speed-per-slot: re-price
        the (partition, exit) plans of its queued, un-prefilled, single-edge
        requests through the autoscaler's
        :class:`~repro.runtime.elastic.ElasticPlanner` (calibrated on the
        fleet's latency models) at each request's own bandwidth.  A plan
        that collapses to partition 0 pushes the request back to its device
        — the elastic analogue of the mobility queue-replan fallback.
        Cooperative requests keep their plans (their span assignment is
        bound to the partition) and prefilled ones hold edge state."""
        planner = getattr(self.autoscaler, "planner", None)
        if planner is None:
            return
        from repro.runtime.elastic import TierSpec
        for entry in list(edge.queue):
            req = entry[2]
            if req is None or not req.prefill_pending or req.migrating \
                    or req.assign is not None:
                continue
            device = self.topo.device(req.device)
            bw = self._bw(device, edge.eid, now)
            plan = planner.plan_for(TierSpec(chips=target), TierSpec(chips=1),
                                    link_bps=bw)
            if plan.partition == 0:
                self._dequeue(edge, req)
                if self.tracer is not None:
                    self.tracer.async_end("queue", req.rid, now,
                                          self.tracer.PID_DEVICES,
                                          req.device)
                edge.tokens_owed -= req.max_new_tokens - req.tokens_done
                req.plan, req.assign, req.edge = plan, None, -1
                self._untrack(req)
                self._run_local(req, device, device.link.bw_at(now), evq)
            else:
                req.plan = plan

    def _admission_deny(self, req: FleetRequest, device, bw: float,
                        evq: EventQueue, metrics: FleetMetrics):
        """Shed one arrival at a saturated edge.  ``policy='local'``
        degrades to device-only execution (the request still completes);
        ``policy='reject'`` counts an explicit rejected outcome — the
        request leaves the system, conserving
        ``completed + rejected + in_flight == issued``."""
        now = evq.now
        if self.admission.policy == "local":
            req.plan = self.stepper.plan_multi(
                bw, (), device_load=device.slowdown)
            req.assign = None
            self._run_local(req, device, bw, evq)
            return
        self._pending -= 1
        metrics.reject()
        if self.tracer is not None:
            tr = self.tracer
            tr.instant("reject", now, tr.PID_DEVICES, req.device,
                       args={"rid": req.rid, "tenant": req.tenant})
            tr.async_end("request", req.rid, now, tr.PID_DEVICES,
                         req.device, args={"rejected": True})

    # ---------------------------------------------------------------- handover
    def _untrack(self, req: FleetRequest):
        reqs = self._dev_inflight.get(req.device)
        if reqs is not None and req in reqs:
            reqs.remove(req)

    def _release_coop(self, req: FleetRequest):
        if req.coop_counted:
            for eid in req.assign.eids[1:]:
                self.topo.edge(eid).coop_inflight -= 1
            req.coop_counted = False

    def _apply_decision(self, req: FleetRequest, dec: JointDecision, *,
                        acquire: bool):
        """Swap the request's (plan, assign) for a replan decision.  Span
        accounting moves with it: old cooperative slots are released, and the
        new ones are acquired immediately when the request stays active
        (``acquire=True``) or lazily at re-admission otherwise."""
        self._release_coop(req)
        req.plan = dec.plan
        req.assign = dec.assign if dec.assign.k > 0 else None
        if acquire and req.assign is not None:
            for eid in req.assign.eids[1:]:
                self.topo.edge(eid).coop_inflight += 1
            req.coop_counted = True

    def _on_sample_sweep(self, evq: EventQueue, metrics: FleetMetrics):
        """One tick of the fleet-wide bandwidth sampling grid: the full
        device-edge geometry for this slot is computed as two numpy
        matrices (batched path-loss — bit-identical to the scalar law per
        entry), then each device's handover policy consumes its row in
        ascending device order and, when it fires, the device's in-flight
        requests re-plan immediately — the same per-device sequencing the
        old one-event-per-device grid produced.  The grid self-terminates
        once every request completed."""
        now = evq.now
        # a pre-built controller can be passed without mobility= (the engine
        # then never bills per-pair rates but the sampling grid still runs)
        mob = self.mobility if self.mobility is not None \
            else self.handover.mobility
        dist = mob.distances_at(now)
        bw = mob.bw_matrix(now)
        servings: list = [()] * self.topo.num_devices
        did0 = self.topo.did0
        for did, reqs in self._dev_inflight.items():
            if reqs:
                servings[did - did0] = tuple(sorted(
                    {r.edge for r in reqs
                     if r.edge >= 0 and not r.migrating}))
        fired = self.handover.observe_sweep(now, servings, dist, bw)
        if self.replanner is not None:
            for did in fired:
                self._replan_device(did, evq, metrics)
        if self.timeline is not None:
            # piggyback the telemetry snapshot on the sweep this grid
            # already runs: per-edge gauges post-replan, plus the device
            # signals the sweep just computed (best-signal bandwidth and
            # the BOCD run-length MAP when the bocd policy is active)
            bank = self.handover.bank
            self.timeline.snapshot(
                now, self.topo, bw_row=bw.max(axis=1),
                run_len=bank.map_run if bank is not None else None)
        self.events_processed += self.topo.num_devices - 1
        if self._pending > 0:
            evq.push(now + self.handover.sample_dt, "sample", None)

    def _on_obs(self, evq: EventQueue):
        """Dedicated timeline snapshot tick for fleets with no sampling
        sweep to piggyback on (static topologies / policy "none").  Pure
        observation: reads edge gauges, schedules only its own successor,
        and self-terminates with the workload."""
        now = evq.now
        self.timeline.snapshot(now, self.topo)
        if self._pending > 0:
            evq.push(now + self.timeline.dt, "obs", None)

    def _replan_device(self, did: int, evq: EventQueue,
                       metrics: FleetMetrics):
        device = self.topo.device(did)
        for req in list(self._dev_inflight.get(did, ())):
            if req.migrating or req.edge < 0:
                continue                       # mid-transfer: nothing to do
            edge = self.topo.edge(req.edge)
            if req in edge.active:
                # mid-decode: defer to the round boundary so the in-flight
                # round's billing stays intact and the state cut is exact
                req.replan_pending = True
            else:
                self._replan_queued(req, device, edge, evq, metrics)

    def _move_cost(self, req: FleetRequest) -> int:
        """State bytes resident at the request's current edge span: zero
        before prefill (nothing materialized yet), otherwise the KV/recurrent
        snapshot at the planned cut for the tokens processed so far."""
        if req.prefill_pending:
            return 0
        return migration_bytes(self.stepper.graph, req.plan.exit_point,
                               req.plan.partition,
                               req.prompt_len + req.tokens_done)

    def _replan_active(self, req: FleetRequest, edge: EdgeNode, now: float,
                       evq: EventQueue, metrics: FleetMetrics,
                       still_active: list):
        nbytes = self._move_cost(req)
        dec = self.replanner.replan(
            req, self.topo.device(req.device), self.topo, now,
            allow_local=False, move_cost_s=nbytes / self.topo.edge_bw_bps)
        if dec is None or dec.local or dec.primary == edge.eid:
            if dec is not None and not dec.local:
                # same primary, fresh (partition, exit) for the new
                # bandwidth state — an in-place replan, no state moves
                self._apply_decision(req, dec, acquire=True)
            still_active.append(req)
            return
        edge.tokens_owed -= req.max_new_tokens - req.tokens_done
        self._blg_add(edge, -1)        # leaves the batch without completing
        if self.arena_decode and self.model is not None:
            # gather the slot row back out (sliced to the request's own
            # length — bitwise what the serial path would ship) so the
            # handover snapshot carries real state; the destination edge's
            # arena re-admits it on arrival
            ar = self._arenas.get(edge.eid)
            if ar is not None and ar.has(req.rid):
                req.cache = ar.extract(req.rid)
        self._ship(req, edge.eid, dec, nbytes, now, evq, metrics)

    def _replan_queued(self, req: FleetRequest, device, edge: EdgeNode,
                       evq: EventQueue, metrics: FleetMetrics):
        """Re-plan a request still waiting in an edge queue.  Un-prefilled
        requests carry no edge state, so they may also fall back to
        device-only execution (offload admission control under mobility)."""
        now = evq.now
        nbytes = self._move_cost(req)
        dec = self.replanner.replan(
            req, device, self.topo, now, allow_local=req.prefill_pending,
            move_cost_s=nbytes / self.topo.edge_bw_bps)
        if dec is None or (not dec.local and dec.primary == req.edge):
            if dec is not None:
                self._apply_decision(req, dec, acquire=False)
            return
        self._dequeue(edge, req)
        if self.tracer is not None:
            self.tracer.async_end("queue", req.rid, now,
                                  self.tracer.PID_DEVICES, req.device)
        edge.tokens_owed -= req.max_new_tokens - req.tokens_done
        if dec.local:
            self._apply_decision(req, dec, acquire=False)
            req.edge = -1
            self._untrack(req)
            self._run_local(req, device, device.link.bw_at(now), evq)
            return
        self._ship(req, edge.eid, dec, nbytes, now, evq, metrics)

    def _ship(self, req: FleetRequest, src_eid: int, dec: JointDecision,
              nbytes: int, now: float, evq: EventQueue,
              metrics: FleetMetrics):
        """Migrate a request to a new primary edge: apply the replan, bill
        the state snapshot over the backbone (one ``transfer`` event at the
        arrival timestamp), and schedule the ``handover`` event that re-binds
        the request once the state has landed."""
        self._apply_decision(req, dec, acquire=False)
        dst = dec.primary
        dt = nbytes / self.topo.edge_bw_bps
        req.migrating = True
        req.handovers += 1
        req.migrated_bytes += nbytes
        req.edge = dst
        if self.tracer is not None:
            tr = self.tracer
            args = {"rid": req.rid, "src": src_eid, "dst": dst,
                    "bytes": nbytes}
            tr.async_begin("handover", req.rid, now, tr.PID_DEVICES,
                           req.device, args=args)
            # the state snapshot on the backbone wire is a transfer span
            # like any coop hop; the handover *stage* (snapshot -> resume)
            # is the async pair above
            tr.complete("transfer", now, now + dt, tr.PID_NET, src_eid,
                        args=args)
        metrics.add_handover(src_eid, dst, nbytes, now + dt, at_s=now)
        if nbytes > 0:
            evq.push(now + dt, "transfer", (src_eid, dst, nbytes))
        evq.push(now + dt, "handover", req)

    def _on_handover(self, req: FleetRequest, evq: EventQueue,
                     metrics: FleetMetrics):
        """The state snapshot landed: resume the request at its new primary.
        The request keeps its deadline, token progress, and decode cache —
        exactly-once completion is preserved (tests/test_fleet_invariants)."""
        edge = self.topo.edge(req.edge)
        req.migrating = False
        if self.tracer is not None:
            tr = self.tracer
            tr.async_end("handover", req.rid, evq.now, tr.PID_DEVICES,
                         req.device)
            tr.async_begin("queue", req.rid, evq.now, tr.PID_DEVICES,
                           req.device, args={"edge": edge.eid})
        self._enqueue(edge, req)
        edge.tokens_owed += req.max_new_tokens - req.tokens_done
        if not edge.round_inflight:
            self._begin_round(edge, evq, metrics)

    # ---------------------------------------------------------------- real decode
    def _prefill_real(self, req: FleetRequest):
        import jax.numpy as jnp
        assert req.prompt is not None, \
            "real-decode fleet needs prompts (make_workload(vocab_size=...))"
        dtype = self.dtype if self.dtype is not None else jnp.float32
        toks = jnp.asarray(req.prompt[None, :])
        cache = self.model.init_cache(
            1, req.prompt_len + req.max_new_tokens + 1, dtype=dtype,
            enc_len=req.prompt_len)
        h, cache = self.stepper.prefill_fn()(self.params, toks, cache)
        logits = self.model.logits(self.params, h)
        req.next_tok = jnp.argmax(logits[:, -1, :], -1).astype(jnp.int32)[:, None]
        req.cache = cache

    def _decode_real(self, req: FleetRequest):
        import jax.numpy as jnp
        fn = self.stepper.decode_fn(req.exit_point)
        pos = jnp.asarray(req.prompt_len + req.tokens_done, jnp.int32)
        h, req.cache = fn(self.params, req.cache, req.next_tok, pos)
        self.stepper.serial_tokens += 1
        logits = self.model.logits(self.params, h)
        req.next_tok = jnp.argmax(logits[:, -1, :], -1).astype(jnp.int32)[:, None]
        req.tokens.append(int(req.next_tok[0, 0]))

    def _decode_real_batch(self, reqs: List[FleetRequest]):
        """One decode round's token step for every active request at an
        edge: the stepper groups congruent requests into vmapped calls
        (``CoInferenceStepper.decode_step_batch``), then the logits/argmax
        epilogue runs per request exactly as the serial path does — token
        streams are bit-identical to per-request decode."""
        if not self.batch_decode or len(reqs) == 1:
            for req in reqs:
                self._decode_real(req)
            return
        import jax.numpy as jnp
        items = [(req.exit_point, req.cache, req.next_tok,
                  req.prompt_len + req.tokens_done) for req in reqs]
        outs = self.stepper.decode_step_batch(self.params, items,
                                              sharded=self.shard_decode)
        for req, (h, cache) in zip(reqs, outs):
            req.cache = cache
            logits = self.model.logits(self.params, h)
            req.next_tok = jnp.argmax(logits[:, -1, :], -1) \
                .astype(jnp.int32)[:, None]
            req.tokens.append(int(req.next_tok[0, 0]))

    def _arena(self, edge: EdgeNode):
        """The edge's decode arena, created lazily at first admission:
        slots sized to the edge's capacity, length to the workload's
        longest cache (both grow on demand — see serving.arena)."""
        ar = self._arenas.get(edge.eid)
        if ar is None:
            import jax.numpy as jnp
            from repro.serving.arena import DecodeArena
            dtype = self.dtype if self.dtype is not None else jnp.float32
            ar = DecodeArena(self.model, slots=max(1, edge.capacity),
                             length=self._arena_len_hint, dtype=dtype,
                             bucket=self.arena_bucket, stepper=self.stepper)
            self._arenas[edge.eid] = ar
        return ar

    def _decode_real_arena(self, edge: EdgeNode,
                           reqs: List[FleetRequest]):
        """One decode round's token step through the edge's slot-resident
        arena: at most one masked compiled call per model exit
        (``CoInferenceStepper.decode_step_arena``) with no per-round cache
        restacking, then one batched logits/argmax per exit group — the
        head is row-independent, so each request's token is bit-identical
        to the serial per-request epilogue."""
        import jax.numpy as jnp
        ar = self._arenas[edge.eid]
        items = [(req.exit_point, ar.slot(req.rid), req.next_tok,
                  req.prompt_len + req.tokens_done) for req in reqs]
        next_toks = {}
        for rows, h_all in self.stepper.decode_step_arena(
                self.params, ar, items):
            logits = self.model.logits(self.params, h_all[:, 0])
            toks = jnp.argmax(logits[:, -1, :], -1).astype(jnp.int32)
            for _, slot, _, _ in rows:
                next_toks[slot] = toks[slot][None, None]
        for req in reqs:
            req.next_tok = next_toks[ar.slot(req.rid)]
            req.tokens.append(int(req.next_tok[0, 0]))
