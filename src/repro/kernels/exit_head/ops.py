"""jit'd public wrapper for the fused exit-head kernel."""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels.exit_head.kernel import exit_confidence_pallas


@partial(jax.jit, static_argnames=("tile_rows", "tile_v", "interpret"))
def exit_confidence(h, emb, *, tile_rows: int = 256, tile_v: int = 512,
                    interpret: bool = True):
    """h: [B, S, D] exit-normed hidden; emb: [V, D].
    Returns dict(token [B,S] i32, conf [B,S] f32, entropy [B,S] f32) —
    same contract as ``repro.kernels.exit_head.ref.exit_confidence``."""
    B, S, D = h.shape
    tok, conf, ent = exit_confidence_pallas(
        h.reshape(B * S, D), emb, tile_rows=tile_rows, tile_v=tile_v,
        interpret=interpret)
    return {"token": tok.reshape(B, S), "conf": conf.reshape(B, S),
            "entropy": ent.reshape(B, S)}
