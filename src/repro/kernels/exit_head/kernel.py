"""Fused exit-head Pallas TPU kernel.

The paper's right-sizing knob evaluated at LM scale: deciding whether to exit
at an intermediate head requires argmax token + confidence over a vocab of up
to 202k.  The naive path writes the [T, V] logits to HBM (for llama4 decode:
128 x 202048 x 4B = 103 MB per exit per step) just to reduce them.  This
kernel streams the embedding through VMEM tiles and keeps ONLY the online
accumulators (running max, sum-exp, score-weighted sum, argmax) — logits
never touch HBM, turning the exit decision from memory-bound to
compute-bound.

Math (per row): with running max m, Z = sum e^{s-m}, W = sum s*e^{s-m}:
    conf    = exp(m - (m + log Z)) = 1/Z
    entropy = (m + log Z) - W/Z
    token   = argmax s

Grid: (rows/Tr, V/Tv), vocab tiles innermost (sequential on TPU) so the
accumulators live in VMEM scratch across the sweep.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(h_ref, emb_ref, tok_ref, conf_ref, ent_ref,
            m_scr, z_scr, w_scr, a_scr, *, n_vocab_tiles: int, tile_v: int,
            vocab: int):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        z_scr[...] = jnp.zeros_like(z_scr)
        w_scr[...] = jnp.zeros_like(w_scr)
        a_scr[...] = jnp.zeros_like(a_scr)

    h = h_ref[...].astype(jnp.float32)           # [Tr, D]
    e = emb_ref[...].astype(jnp.float32)         # [Tv, D]
    s = jax.lax.dot_general(h, e, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)  # [Tr, Tv]
    # mask padded vocab tail
    vbase = j * tile_v
    vidx = vbase + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    s = jnp.where(vidx < vocab, s, NEG_INF)

    m_old = m_scr[...][:, 0]                      # [Tr]
    tile_max = jnp.max(s, axis=1)
    tile_arg = vbase + jnp.argmax(s, axis=1).astype(jnp.int32)
    m_new = jnp.maximum(m_old, tile_max)
    corr = jnp.exp(m_old - m_new)
    p = jnp.exp(s - m_new[:, None])
    z_new = z_scr[...][:, 0] * corr + jnp.sum(p, axis=1)
    w_new = w_scr[...][:, 0] * corr + jnp.sum(p * s, axis=1)
    a_old = a_scr[...][:, 0]
    a_new = jnp.where(tile_max > m_old, tile_arg, a_old)

    m_scr[...] = m_new[:, None]
    z_scr[...] = z_new[:, None]
    w_scr[...] = w_new[:, None]
    a_scr[...] = a_new[:, None]

    @pl.when(j == n_vocab_tiles - 1)
    def _final():
        z = jnp.maximum(z_new, 1e-30)
        log_z = m_new + jnp.log(z)
        tok_ref[...] = a_new[:, None]
        conf_ref[...] = (1.0 / z)[:, None]
        ent_ref[...] = (log_z - w_new / z)[:, None]


def exit_confidence_pallas(h2d, emb, *, tile_rows: int = 256,
                           tile_v: int = 512, interpret: bool = True):
    """h2d: [T, D] (already exit-normed); emb: [V, D] tied embedding.
    Returns (token [T] i32, conf [T] f32, entropy [T] f32)."""
    T, D = h2d.shape
    V = emb.shape[0]
    Tr = min(tile_rows, max(8, T))
    padT = (-T) % Tr
    if padT:
        h2d = jnp.pad(h2d, ((0, padT), (0, 0)))
    Tp = T + padT
    Tv = min(tile_v, V)
    padV = (-V) % Tv
    embp = jnp.pad(emb, ((0, padV), (0, 0))) if padV else emb
    nv = (V + padV) // Tv
    grid = (Tp // Tr, nv)

    kern = functools.partial(_kernel, n_vocab_tiles=nv, tile_v=Tv, vocab=V)
    tok, conf, ent = pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((Tr, D), lambda i, j: (i, 0)),
            pl.BlockSpec((Tv, D), lambda i, j: (j, 0)),
        ],
        out_specs=[
            pl.BlockSpec((Tr, 1), lambda i, j: (i, 0)),
            pl.BlockSpec((Tr, 1), lambda i, j: (i, 0)),
            pl.BlockSpec((Tr, 1), lambda i, j: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((Tp, 1), jnp.int32),
            jax.ShapeDtypeStruct((Tp, 1), jnp.float32),
            jax.ShapeDtypeStruct((Tp, 1), jnp.float32),
        ],
        scratch_shapes=[
            pltpu.VMEM((Tr, 1), jnp.float32),   # running max
            pltpu.VMEM((Tr, 1), jnp.float32),   # sum exp
            pltpu.VMEM((Tr, 1), jnp.float32),   # score-weighted sum
            pltpu.VMEM((Tr, 1), jnp.int32),     # argmax
        ],
        interpret=interpret,
    )(h2d, embp)
    return tok[:T, 0], conf[:T, 0], ent[:T, 0]
