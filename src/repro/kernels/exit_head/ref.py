"""Pure-jnp oracle for the fused exit-head kernel.

Given normed hidden states ``h`` [B, S, D] and the tied embedding table
``emb`` [V, D], produce per position:

    token  = argmax_v h . emb_v
    conf   = max softmax probability  (maxprob confidence)
    ent    = entropy of the softmax   (the paper's accuracy proxy knob)

The naive version materializes the full [B, S, V] logits; the Pallas kernel
streams the vocab through VMEM tiles with an online max/sum/argmax
accumulator and never writes logits to HBM.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def exit_confidence(h, emb):
    """Returns dict(token [B,S] int32, conf [B,S] f32, entropy [B,S] f32)."""
    logits = jnp.einsum("bsd,vd->bsv", h, emb).astype(jnp.float32)
    m = logits.max(-1)
    p = jax.nn.softmax(logits, axis=-1)
    ent = -jnp.sum(p * jnp.log(jnp.clip(p, 1e-30, 1.0)), axis=-1)
    return {
        "token": jnp.argmax(logits, -1).astype(jnp.int32),
        "conf": p.max(-1),
        "entropy": ent,
    }
