"""jit'd public wrapper for the flash-attention kernel.

Accepts the model layout [B, S, H, hd] / [B, T, KV, hd] and transposes to the
kernel's [B, H, S, hd] head-major layout.
"""
from __future__ import annotations

from functools import partial

import jax

from repro.kernels.flash_attention.decode import decode_attention_pallas
from repro.kernels.flash_attention.kernel import flash_attention_pallas


@partial(jax.jit, static_argnames=("causal", "block_q", "block_k", "interpret"))
def flash_attention(q, k, v, *, causal: bool = True, block_q: int = 128,
                    block_k: int = 128, interpret: bool = True):
    """q: [B, S, H, hd]; k/v: [B, T, KV, hd] -> [B, S, H, hd]."""
    qt = q.transpose(0, 2, 1, 3)
    kt = k.transpose(0, 2, 1, 3)
    vt = v.transpose(0, 2, 1, 3)
    o = flash_attention_pallas(qt, kt, vt, causal=causal, block_q=block_q,
                               block_k=block_k, interpret=interpret)
    return o.transpose(0, 2, 1, 3)


@partial(jax.jit, static_argnames=("block_k", "interpret"))
def decode_attention(q, k, v, lengths, *, block_k: int = 128,
                     interpret: bool = True):
    """Arena-row decode attention in the model layout: q [B, 1, H, hd],
    k/v [B, T, KV, hd] (the slot axis first, as DecodeArena stacks them),
    lengths [B] per-slot true lengths -> [B, 1, H, hd]."""
    qt = q.transpose(0, 2, 1, 3)
    kt = k.transpose(0, 2, 1, 3)
    vt = v.transpose(0, 2, 1, 3)
    o = decode_attention_pallas(qt, kt, vt, lengths, block_k=block_k,
                                interpret=interpret)
    return o.transpose(0, 2, 1, 3)
