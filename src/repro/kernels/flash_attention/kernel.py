"""Causal GQA flash-attention Pallas TPU kernel (prefill hot spot).

TPU-native adaptation (DESIGN.md §2): HBM -> VMEM tiling via BlockSpec with
q/k blocks of 128/256 rows (MXU-aligned, multiples of 128 in the contracted
head dim), online-softmax accumulators in VMEM scratch, and *block-pruned
causality*: k-tiles strictly above the diagonal are skipped with ``pl.when``
— the FLOP waste of the masked rectangle in the jnp twin
(``repro.models.layers.flash_attention_jnp``) disappears here.

GQA is expressed in the BlockSpec index map: the k/v block for query head h
is kv-head ``h // group``, so no materialized head repetition.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
            scale: float, block_q: int, block_k: int, n_k: int, causal: bool):
    i = pl.program_id(2)     # q block
    j = pl.program_id(3)     # k block

    @pl.when(j == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)      # [bq, hd]
        k = k_ref[0, 0].astype(jnp.float32)      # [bk, hd]
        v = v_ref[0, 0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        if causal:
            qpos = i * block_q + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
            kpos = j * block_k + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
            s = jnp.where(kpos <= qpos, s, NEG_INF)
        m_old = m_scr[...][:, 0]
        m_new = jnp.maximum(m_old, jnp.max(s, axis=1))
        p = jnp.exp(s - m_new[:, None])
        corr = jnp.exp(m_old - m_new)
        l_scr[...] = (l_scr[...][:, 0] * corr + jnp.sum(p, axis=1))[:, None]
        acc_scr[...] = acc_scr[...] * corr[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
        m_scr[...] = m_new[:, None]

    if causal:
        # block-pruned causality: skip k tiles strictly above the diagonal
        pl.when(j * block_k <= i * block_q + block_q - 1)(_compute)
    else:
        _compute()

    @pl.when(j == n_k - 1)
    def _final():
        l = jnp.maximum(l_scr[...][:, 0], 1e-30)
        o_ref[0, 0] = (acc_scr[...] / l[:, None]).astype(o_ref.dtype)


def flash_attention_pallas(q, k, v, *, causal: bool = True,
                           block_q: int = 128, block_k: int = 128,
                           interpret: bool = True):
    """q: [B, H, S, hd]; k/v: [B, KV, T, hd].  Returns [B, H, S, hd]."""
    B, H, S, hd = q.shape
    KV, T = k.shape[1], k.shape[2]
    G = H // KV
    bq = min(block_q, S)
    bk = min(block_k, T)
    assert S % bq == 0 and T % bk == 0, (S, bq, T, bk)
    nq, nk = S // bq, T // bk
    scale = 1.0 / math.sqrt(hd)

    kern = functools.partial(_kernel, scale=scale, block_q=bq, block_k=bk,
                             n_k=nk, causal=causal)
    grid = (B, H, nq, nk)
    out = pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, bq, hd), lambda b, h, i, j: (b, h, i, 0)),
            pl.BlockSpec((1, 1, bk, hd), lambda b, h, i, j, G=G: (b, h // G, j, 0)),
            pl.BlockSpec((1, 1, bk, hd), lambda b, h, i, j, G=G: (b, h // G, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, hd), lambda b, h, i, j: (b, h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, S, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, hd), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
    return out
