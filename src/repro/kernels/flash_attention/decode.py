"""Single-query decode attention over arena rows (Pallas TPU kernel).

The decode-specialized sibling of :mod:`.kernel`: one query token per
sequence (S == 1) attending over a *slot-resident* KV arena row — the
layout ``repro.serving.arena.DecodeArena`` keeps caches in.  Arena rows
are padded to a shared bucketed length, so validity is a per-slot
``lengths[b]`` rather than a causal diagonal: key positions at or beyond
the slot's true length are masked to ``NEG_INF``, and whole k-tiles past
the length are block-pruned with ``pl.when`` — the decode twin of the
prefill kernel's block-pruned causality.

Same TPU-native structure as the prefill kernel (DESIGN.md §2): HBM ->
VMEM tiling via BlockSpec, online-softmax accumulators in VMEM scratch,
GQA folded into the k/v index map (query head ``h`` reads kv-head
``h // group``), grid ``(B, H, n_k)``.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(len_ref, q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
            scale: float, block_k: int, n_k: int):
    j = pl.program_id(2)     # k block

    @pl.when(j == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    length = len_ref[0]

    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)      # [1, hd]
        k = k_ref[0, 0].astype(jnp.float32)      # [bk, hd]
        v = v_ref[0, 0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        kpos = j * block_k + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        s = jnp.where(kpos < length, s, NEG_INF)
        m_old = m_scr[...][:, 0]
        m_new = jnp.maximum(m_old, jnp.max(s, axis=1))
        p = jnp.exp(s - m_new[:, None])
        corr = jnp.exp(m_old - m_new)
        l_scr[...] = (l_scr[...][:, 0] * corr + jnp.sum(p, axis=1))[:, None]
        acc_scr[...] = acc_scr[...] * corr[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
        m_scr[...] = m_new[:, None]

    # block-pruned padding: k tiles entirely at/beyond the slot's true
    # length hold only arena zero-padding — skip them
    pl.when(j * block_k < length)(_compute)

    @pl.when(j == n_k - 1)
    def _final():
        l = jnp.maximum(l_scr[...][:, 0], 1e-30)
        o_ref[0, 0] = (acc_scr[...] / l[:, None]).astype(o_ref.dtype)


def decode_attention_pallas(q, k, v, lengths, *, block_k: int = 128,
                            interpret: bool = True):
    """q: [B, H, 1, hd]; k/v: [B, KV, T, hd]; lengths: [B] int32.

    Returns [B, H, 1, hd].  Row ``b`` attends over ``k[b, :, :lengths[b]]``
    only; the padded tail contributes exactly nothing (a ``lengths[b] == 0``
    row returns zeros)."""
    B, H, S, hd = q.shape
    if S != 1:
        raise ValueError(f"decode kernel is single-query: got S={S}")
    KV, T = k.shape[1], k.shape[2]
    G = H // KV
    bk = min(block_k, T)
    assert T % bk == 0, (T, bk)
    nk = T // bk
    scale = 1.0 / math.sqrt(hd)
    lengths = jnp.asarray(lengths, jnp.int32)

    kern = functools.partial(_kernel, scale=scale, block_k=bk, n_k=nk)
    grid = (B, H, nk)
    out = pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1,), lambda b, h, j: (b,)),
            pl.BlockSpec((1, 1, 1, hd), lambda b, h, j: (b, h, 0, 0)),
            pl.BlockSpec((1, 1, bk, hd), lambda b, h, j, G=G: (b, h // G, j, 0)),
            pl.BlockSpec((1, 1, bk, hd), lambda b, h, j, G=G: (b, h // G, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, 1, hd), lambda b, h, j: (b, h, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, 1, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((1, 1), jnp.float32),
            pltpu.VMEM((1, 1), jnp.float32),
            pltpu.VMEM((1, hd), jnp.float32),
        ],
        interpret=interpret,
    )(lengths, q, k, v)
    return out
