"""Pure-jnp oracle for the flash-attention kernel."""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp


def decode_attention(q, k, v, lengths):
    """q: [B, H, 1, hd]; k/v: [B, KV, T, hd]; lengths: [B] -> [B, H, 1, hd].

    Single-query oracle for the arena decode kernel: row ``b`` attends over
    its first ``lengths[b]`` key positions only (a zero-length row returns
    zeros — the padded-slot convention)."""
    B, H, S, hd = q.shape
    KV, T = k.shape[1], k.shape[2]
    G = H // KV
    qr = q.reshape(B, KV, G, S, hd).astype(jnp.float32)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    s = jnp.einsum("bkgsd,bktd->bkgst", qr, kf) / math.sqrt(hd)
    valid = jnp.arange(T)[None, :] < jnp.asarray(lengths, jnp.int32)[:, None]
    s = jnp.where(valid[:, None, None, None, :], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    p = jnp.where(valid[:, None, None, None, :], p, 0.0)  # len==0 -> zeros
    o = jnp.einsum("bkgst,bktd->bkgsd", p, vf)
    return o.reshape(B, H, S, hd).astype(q.dtype)


def attention(q, k, v, *, causal: bool = True):
    """q: [B, H, S, hd]; k/v: [B, KV, T, hd] -> [B, H, S, hd]; f32 softmax."""
    B, H, S, hd = q.shape
    KV, T = k.shape[1], k.shape[2]
    G = H // KV
    qr = q.reshape(B, KV, G, S, hd).astype(jnp.float32)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    s = jnp.einsum("bkgsd,bktd->bkgst", qr, kf) / math.sqrt(hd)
    if causal:
        mask = jnp.tril(jnp.ones((S, T), bool), T - S)
        s = jnp.where(mask, s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgst,bktd->bkgsd", p, vf)
    return o.reshape(B, H, S, hd).astype(q.dtype)
