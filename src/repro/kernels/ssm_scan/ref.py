"""Pure-jnp oracle for the SSM scan kernel: the sequential recurrence from
``repro.models.linear_scan.scan_sequential`` (model layout)."""
from __future__ import annotations

from repro.models.linear_scan import scan_sequential


def ssm_scan(q, k, v, log_w, state, u=None):
    """q/k/log_w: [B,S,H,dk]; v: [B,S,H,dv]; state: [B,H,dk,dv]."""
    return scan_sequential(q, k, v, log_w, state, u=u)
