"""jit'd public wrapper for the SSM scan kernel (model layout in/out)."""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels.ssm_scan.kernel import ssm_scan_pallas


@partial(jax.jit, static_argnames=("chunk", "interpret"))
def _scan(q, k, v, log_w, state, u, *, chunk, interpret):
    B, S, H, dk = q.shape
    dv = v.shape[-1]
    fold = lambda x: x.transpose(0, 2, 1, 3).reshape(B * H, S, -1)
    o, sT = ssm_scan_pallas(fold(q), fold(k), fold(v), fold(log_w),
                            state.reshape(B * H, dk, dv),
                            None if u is None else jnp.broadcast_to(
                                u[None], (B, H, dk)).reshape(B * H, dk),
                            chunk=chunk, interpret=interpret)
    return (o.reshape(B, H, S, dv).transpose(0, 2, 1, 3),
            sT.reshape(B, H, dk, dv))


def ssm_scan(q, k, v, log_w, state, u=None, *, chunk: int = 16,
             interpret: bool = True):
    """Same contract as ``repro.models.linear_scan.linear_scan``:
    q/k/log_w [B,S,H,dk]; v [B,S,H,dv]; state [B,H,dk,dv]; u [H,dk]|None."""
    return _scan(q, k, v, log_w, state, u, chunk=chunk, interpret=interpret)
