"""Chunked diagonal-decay linear-attention scan Pallas TPU kernel — the
shared recurrence of RWKV-6 and Mamba-2 (see ``repro.models.linear_scan``).

TPU adaptation: a GPU implementation would assign one threadblock per (b, h)
and run warp-level scans; on TPU the natural decomposition is a *sequential
grid* over time chunks with the running state [dk, dv] held in VMEM scratch,
and the intra-chunk part expressed as two MXU matmuls (the [C, C] decay-
weighted attention matrix, then @ v).  Per-chunk cumulative-decay products
are computed in-register (cumsum in log space); MIN_LOG_W bounds the ratio
trick to f32 range for C <= 32.

Grid: (B*H, S/C), chunks innermost.  One kernel instance handles both RWKV
semantics (pre-update output + bonus ``u``) and Mamba-2 (post-update).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

MIN_LOG_W = -8.0


def _kernel(q_ref, k_ref, v_ref, lw_ref, s0_ref, u_ref, o_ref, sT_ref,
            state_scr, *, chunk: int, n_chunks: int, rwkv: bool):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        state_scr[...] = s0_ref[0].astype(jnp.float32)

    qc = q_ref[0].astype(jnp.float32)            # [C, dk]
    kc = k_ref[0].astype(jnp.float32)
    vc = v_ref[0].astype(jnp.float32)            # [C, dv]
    lw = jnp.maximum(lw_ref[0].astype(jnp.float32), MIN_LOG_W)
    C = chunk

    logP = jnp.cumsum(lw, axis=0)                # [C, dk]
    P = jnp.exp(logP)
    k_ = kc / P
    s = state_scr[...]                           # [dk, dv]

    if rwkv:
        q_ = qc * jnp.exp(logP - lw)             # P_{t-1}
        A = jax.lax.dot_general(q_, k_, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
        ti = jax.lax.broadcasted_iota(jnp.int32, (C, C), 0)
        si = jax.lax.broadcasted_iota(jnp.int32, (C, C), 1)
        A = jnp.where(si < ti, A, 0.0)
        u = u_ref[0].astype(jnp.float32)         # [dk]
        diag = jnp.sum(qc * u[None, :] * kc, axis=1)
        A = A + jnp.where(si == ti, diag[:, None], 0.0)
    else:
        q_ = qc * P                              # P_t
        A = jax.lax.dot_general(q_, k_, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
        ti = jax.lax.broadcasted_iota(jnp.int32, (C, C), 0)
        si = jax.lax.broadcasted_iota(jnp.int32, (C, C), 1)
        A = jnp.where(si <= ti, A, 0.0)

    intra = jax.lax.dot_general(A, vc, (((1,), (0,)), ((), ())),
                                preferred_element_type=jnp.float32)
    inter = jax.lax.dot_general(q_, s, (((1,), (0,)), ((), ())),
                                preferred_element_type=jnp.float32)
    o_ref[0] = (intra + inter).astype(o_ref.dtype)

    # state update: S' = diag(P_C) S + sum_s (P_C / P_s) k_s v_s^T
    kP = kc * jnp.exp(logP[-1][None, :] - logP)
    state_scr[...] = P[-1][:, None] * s + jax.lax.dot_general(
        kP, vc, (((0,), (0,)), ((), ())), preferred_element_type=jnp.float32)

    @pl.when(j == n_chunks - 1)
    def _final():
        sT_ref[0] = state_scr[...]


def ssm_scan_pallas(q, k, v, log_w, state, u=None, *, chunk: int = 16,
                    interpret: bool = True):
    """q/k/lw: [BH, S, dk]; v: [BH, S, dv]; state: [BH, dk, dv] f32;
    u: [BH, dk] or None.  Returns (o [BH, S, dv], final_state)."""
    BH, S, dk = q.shape
    dv = v.shape[-1]
    C = min(chunk, S)
    assert S % C == 0, (S, C)
    n = S // C
    rwkv = u is not None
    if u is None:
        u = jnp.zeros((BH, dk), jnp.float32)

    kern = functools.partial(_kernel, chunk=C, n_chunks=n, rwkv=rwkv)
    o, sT = pl.pallas_call(
        kern,
        grid=(BH, n),
        in_specs=[
            pl.BlockSpec((1, C, dk), lambda b, j: (b, j, 0)),
            pl.BlockSpec((1, C, dk), lambda b, j: (b, j, 0)),
            pl.BlockSpec((1, C, dv), lambda b, j: (b, j, 0)),
            pl.BlockSpec((1, C, dk), lambda b, j: (b, j, 0)),
            pl.BlockSpec((1, dk, dv), lambda b, j: (b, 0, 0)),
            pl.BlockSpec((1, dk), lambda b, j: (b, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, C, dv), lambda b, j: (b, j, 0)),
            pl.BlockSpec((1, dk, dv), lambda b, j: (b, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((BH, S, dv), v.dtype),
            jax.ShapeDtypeStruct((BH, dk, dv), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((dk, dv), jnp.float32)],
        interpret=interpret,
    )(q, k, v, log_w, state.astype(jnp.float32), u)
    return o, sT
