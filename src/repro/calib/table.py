"""The measurement artifact: serializable timing samples.

A :class:`CalibrationTable` is the interchange format between the three
calibration stages (measure -> fit -> validate) and the scenario layer
(``ScenarioSpec.calibration.table`` names a saved one).  Like
``repro.sim.spec`` it is plain data with strict field checking: unknown
keys raise ``ValueError`` on the way in, and
``table.to_dict() == json.loads(json.dumps(table.to_dict()))`` — the JSON
round-trip is lossless and canonical (pinned by tests/test_calib.py).
"""
from __future__ import annotations

import dataclasses
import json
from dataclasses import dataclass, field
from typing import Dict, List, Optional

__all__ = ["CalibrationTable", "TimingSample"]

#: sample phases a table may carry (measure emits all four for LM targets)
PHASES = ("layer", "prefill", "decode", "head")


def _check_fields(cls, d: Dict):
    names = {f.name for f in dataclasses.fields(cls)}
    unknown = set(d) - names
    if unknown:
        raise ValueError(
            f"unknown {cls.__name__} field(s) {sorted(unknown)}: "
            f"expected a subset of {sorted(names)}")


@dataclass
class TimingSample:
    """One median-of-k wall-clock measurement.

    ``phase`` says what was timed: a single ``layer`` (Table-I granularity,
    the branchy-alexnet path), one ``decode`` step of branch ``exit_point``
    at ``batch`` co-located requests, a ``prefill`` of ``seq`` tokens, or
    one exit ``head`` (logits projection).  ``kind`` is the Table-I layer
    type for ``layer`` samples (``conv``/``relu``/...; ``block`` per-segment
    for LMs) and empty otherwise.  ``features`` carries the regression
    features of whatever was timed — for branch-level phases the fitter
    reconstructs per-layer designs from the graph instead."""
    phase: str
    latency_s: float
    kind: str = ""
    features: Dict[str, float] = field(default_factory=dict)
    exit_point: Optional[int] = None
    batch: int = 1
    seq: int = 1
    reps: int = 1

    def __post_init__(self):
        if self.phase not in PHASES:
            raise ValueError(f"unknown sample phase {self.phase!r}: "
                             f"expected one of {PHASES}")
        if self.latency_s < 0.0:
            raise ValueError(
                f"latency_s must be >= 0, got {self.latency_s}")

    def to_dict(self) -> Dict:
        return dataclasses.asdict(self)

    @classmethod
    def from_dict(cls, d: Dict) -> "TimingSample":
        _check_fields(cls, d)
        return cls(**d)


@dataclass
class CalibrationTable:
    """A batch of :class:`TimingSample` rows plus provenance.

    ``arch`` names what was measured (a smoke-config arch or
    ``branchy-alexnet``); ``source`` how (``measure_lm`` /
    ``measure_alexnet`` / ``synthetic`` in tests); ``meta`` free-form
    measurement metadata (host, sweep axes, repeat counts)."""
    arch: str
    source: str = "measure"
    samples: List[TimingSample] = field(default_factory=list)
    meta: Dict = field(default_factory=dict)

    def __post_init__(self):
        self.samples = [TimingSample.from_dict(s) if isinstance(s, dict)
                        else s for s in self.samples]

    # ------------------------------------------------------------ queries
    def by_phase(self, phase: str) -> List[TimingSample]:
        if phase not in PHASES:
            raise ValueError(f"unknown sample phase {phase!r}: "
                             f"expected one of {PHASES}")
        return [s for s in self.samples if s.phase == phase]

    def exits(self) -> List[int]:
        return sorted({s.exit_point for s in self.samples
                       if s.exit_point is not None})

    # ------------------------------------------------------------ round-trip
    def to_dict(self) -> Dict:
        return {"arch": self.arch, "source": self.source,
                "samples": [s.to_dict() for s in self.samples],
                "meta": self.meta}

    @classmethod
    def from_dict(cls, d: Dict) -> "CalibrationTable":
        _check_fields(cls, d)
        return cls(**d)

    def to_json(self, indent: int = 2) -> str:
        return json.dumps(self.to_dict(), indent=indent, sort_keys=True)

    @classmethod
    def from_json(cls, s: str) -> "CalibrationTable":
        return cls.from_dict(json.loads(s))

    def save(self, path: str) -> None:
        with open(path, "w") as f:
            f.write(self.to_json() + "\n")

    @classmethod
    def load(cls, path: str) -> "CalibrationTable":
        with open(path) as f:
            return cls.from_json(f.read())
