"""Validate the analytic latency models against measured kernels.

:func:`validate_scenario` answers the calibration loop's question — *how
wrong is the simulator's cost surface, and would the planner decide
differently on the real one?* — for one scenario:

1. measure (or load) a :class:`~repro.calib.table.CalibrationTable` for the
   scenario's architecture;
2. compare per-exit branch step times and per-segment marginals between the
   analytic models and the measurements (signed bias + MAPE, after a single
   scalar aligns simulated seconds with host seconds — absolute scale is a
   scenario knob, shape is what calibration tests);
3. sweep the scenario's bandwidth range and count plan divergence: how
   often the calibrated planner picks a different (exit, partition) than
   the analytic one;
4. run the scenario model-only under both cost surfaces and report the two
   summaries (byte-identical exactly when no plan ever diverged).

The report is a plain JSON-able dict (schema asserted by the CI smoke leg
and tests/test_calib.py).
"""
from __future__ import annotations

import dataclasses
import json
import os
import tempfile
from typing import Dict, List, Optional, Union

import numpy as np

from repro.calib.fit import fit_table, models_from_table
from repro.calib.measure import measure_lm
from repro.calib.table import CalibrationTable

__all__ = ["validate_scenario"]

#: bandwidth grid resolution for the plan-divergence sweep
DEFAULT_BW_POINTS = 25


def _branch_sums(graph, f) -> List[float]:
    return [sum(f.predict(l) for l in graph.branches[e])
            for e in range(graph.num_exits)]


def _align_scale(pred: np.ndarray, meas: np.ndarray) -> float:
    """Least-squares scalar k minimizing ||k*pred - meas||: compares the
    shape of two cost surfaces independent of units."""
    denom = float(pred @ pred)
    return float(pred @ meas) / denom if denom > 0 else 1.0


def _err_rows(names, pred: np.ndarray, meas: np.ndarray) -> List[Dict]:
    rows = []
    for n, p, m in zip(names, pred, meas):
        rows.append({
            "name": n, "predicted_s": float(p), "measured_s": float(m),
            "bias_s": float(p - m),
            "rel_err": float((p - m) / m) if m > 0 else None})
    return rows


def _mape(rows: List[Dict]) -> float:
    errs = [abs(r["rel_err"]) for r in rows if r["rel_err"] is not None]
    return float(np.mean(errs)) if errs else 0.0


def _bias(rows: List[Dict]) -> float:
    return float(np.mean([r["bias_s"] for r in rows])) if rows else 0.0


def validate_scenario(spec_or_name: Union[str, object], *,
                      table: Optional[CalibrationTable] = None,
                      bw_points: int = DEFAULT_BW_POINTS,
                      run_summaries: bool = True,
                      reps: int = 3) -> Dict:
    """Full model-vs-measured validation for one scenario (see module
    docstring).  ``table=None`` measures a quick one in place (decode sweep
    at the scenario's prompt length); pass a saved table for reproducible
    reports.  ``run_summaries=False`` skips the two model-only fleet runs
    (the expensive step) and reports ``summaries: None``."""
    from repro.core.partitioner import optimize_with_fallback
    from repro.sim import CalibrationSpec, Simulation, get_scenario
    from repro.sim.build import build_stack

    spec = get_scenario(spec_or_name) if isinstance(spec_or_name, str) \
        else spec_or_name
    if table is None:
        table = measure_lm(spec.planner,
                           seqs=(spec.workload.prompt_len,), reps=reps)
    if table.arch != spec.planner.arch:
        raise ValueError(
            f"table measures arch {table.arch!r} but scenario "
            f"{spec.name!r} plans over {spec.planner.arch!r}")
    fitted = fit_table(table)

    # ---- per-exit / per-segment error: analytic vs measured (B=1 decode)
    sc = build_stack(spec.planner)
    graph = sc.graph
    decode = [s for s in table.by_phase("decode") if s.batch == 1]
    if not decode:
        raise ValueError(
            f"table for {table.arch!r} carries no B=1 decode samples: "
            "measure with 1 in batches= to validate per-exit error")
    meas_by_exit: Dict[int, List[float]] = {}
    for s in decode:
        meas_by_exit.setdefault(s.exit_point, []).append(s.latency_s)
    exits = sorted(meas_by_exit)
    meas = np.asarray([float(np.median(meas_by_exit[e])) for e in exits])
    pred_full = np.asarray(_branch_sums(graph, sc.planner.f_edge))
    pred = np.asarray([pred_full[e - 1] for e in exits])
    k = _align_scale(pred, meas)
    per_exit = _err_rows([f"exit{e}" for e in exits], k * pred, meas)
    # segment marginals: consecutive-exit differences (the shared exit-head
    # cost cancels) — per-layer error at the LM's segment granularity
    per_layer = []
    if len(exits) > 1:
        dm = np.diff(meas)
        dp = np.diff(k * pred)
        names = [f"seg{exits[i]}..{exits[i + 1]}"
                 for i in range(len(exits) - 1)]
        per_layer = _err_rows(names, dp, dm)

    # ---- plan divergence over the scenario's bandwidth range
    f_edge_c, f_dev_c = models_from_table(fitted, spec.planner, graph=graph)
    topo = spec.topology
    lo = topo.lo_mbps if topo.kind == "static" else topo.floor_mbps
    hi = topo.hi_mbps if topo.kind == "static" else topo.peak_mbps
    bws = np.logspace(np.log10(max(lo, 1e-3)), np.log10(max(hi, 1e-3)),
                      bw_points) * 1e6 / 8.0          # Mbps -> bytes/s
    req = spec.planner.latency_req_s
    points, diverged = [], 0
    for bw in bws:
        pa = optimize_with_fallback(graph, sc.planner.f_edge,
                                    sc.planner.f_device, float(bw), req)
        pc = optimize_with_fallback(graph, f_edge_c, f_dev_c, float(bw), req)
        same = (pa.exit_point, pa.partition) == (pc.exit_point, pc.partition)
        diverged += 0 if same else 1
        points.append({
            "bw_mbps": round(float(bw) * 8.0 / 1e6, 4),
            "analytic": [pa.exit_point, pa.partition],
            "calibrated": [pc.exit_point, pc.partition],
            "diverged": not same})
    plan_divergence = {
        "rate": diverged / len(points) if points else 0.0,
        "diverged": diverged, "points": len(points), "grid": points}

    # ---- model-only summaries under both cost surfaces
    summaries = None
    if run_summaries:
        base = dataclasses.replace(
            spec, engine=dataclasses.replace(spec.engine,
                                             real_decode=False),
            calibration=None)
        s_analytic = Simulation(base).run().summary()
        fd, path = tempfile.mkstemp(suffix=".json", prefix="calib_table_")
        os.close(fd)
        try:
            table.save(path)
            cal = dataclasses.replace(
                base, calibration=CalibrationSpec(table=path))
            s_calibrated = Simulation(cal).run().summary()
        finally:
            os.unlink(path)
        summaries = {
            "analytic": s_analytic, "calibrated": s_calibrated,
            "identical": json.dumps(s_analytic, sort_keys=True)
            == json.dumps(s_calibrated, sort_keys=True)}

    return {
        "scenario": spec.name,
        "arch": spec.planner.arch,
        "table": {"source": table.source, "samples": len(table.samples),
                  "meta": table.meta},
        "fit": {"theta": fitted.theta, "r2": fitted.r2},
        "scale": k,
        "per_exit": per_exit,
        "per_layer": per_layer,
        "bias_s": _bias(per_exit),
        "mape": _mape(per_exit),
        "per_layer_bias_s": _bias(per_layer),
        "per_layer_mape": _mape(per_layer),
        "plan_divergence": plan_divergence,
        "summaries": summaries,
    }
