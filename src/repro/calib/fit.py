"""Fit the paper's per-layer-type latency regressions from a measured
table, and re-parameterize planners with the result.

Two sample shapes, one model:

* ``layer`` samples (the branchy-AlexNet path) regress each Table-I kind
  directly — exactly :class:`~repro.core.latency_model
  .RegressionLatencyModel.fit`.
* branch-level ``decode``/``head`` samples (the LM path, where a single
  kernel step spans a whole branch) solve one *joint* least squares: the
  row for (exit ``e``, batch ``B``) is the per-kind sum of Table-I design
  vectors over branch ``e``'s layers at batch ``B`` (from
  ``core.graph.lm_graph``), the unknowns the concatenated per-kind thetas.
  Per-layer coefficients thus fall out of branch-level timings — the
  differencing the paper does with per-layer profiling, recovered by
  construction.

:func:`models_from_table` turns a fit into planner-ready ``(f_edge,
f_dev)`` predictors — anchored to a spec's per-tier step times by default
(calibration reshapes the cost surface; the simulated hardware speed stays
the scenario's) — and :func:`elastic_planner_from_table` /
:func:`reparameterize_planner` install them.
"""
from __future__ import annotations

import dataclasses
import json
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.calib.table import CalibrationTable
from repro.core.latency_model import (ProfileRecord, RegressionLatencyModel,
                                      TABLE_I_FEATURES)

__all__ = ["FittedLatencyModel", "elastic_planner_from_table", "fit_table",
           "models_from_table", "reparameterize_planner"]

#: emulated device:edge asymmetry when a table measures only this host
#: (``core.profiler.DEVICE_SLOWDOWN`` — paper Sec. V-A)
DEVICE_SLOWDOWN = 20.0


@dataclass
class FittedLatencyModel:
    """A serializable per-kind regression: ``theta[kind]`` are the Table-I
    design coefficients (feature order per ``TABLE_I_FEATURES`` + bias).
    ``predict(layer)`` matches ``RegressionLatencyModel`` exactly;
    ``to_regression()`` rehydrates one for call sites that type-check."""
    arch: str
    theta: Dict[str, List[float]] = field(default_factory=dict)
    r2: Dict[str, float] = field(default_factory=dict)
    source: str = "fit"
    meta: Dict = field(default_factory=dict)

    def predict(self, layer) -> float:
        th = self.theta.get(layer.kind)
        if th is None:
            raise KeyError(f"no fitted model for layer kind {layer.kind!r}")
        design = RegressionLatencyModel._design(layer.kind, layer.features)
        return float(max(0.0, design @ np.asarray(th)))

    def to_regression(self) -> RegressionLatencyModel:
        reg = RegressionLatencyModel()
        reg.theta = {k: np.asarray(v, float) for k, v in self.theta.items()}
        reg.residual = dict(self.r2)
        return reg

    # ------------------------------------------------------------ round-trip
    def to_dict(self) -> Dict:
        d = dataclasses.asdict(self)
        d["theta"] = {k: [float(x) for x in v]
                      for k, v in d["theta"].items()}
        return d

    @classmethod
    def from_dict(cls, d: Dict) -> "FittedLatencyModel":
        names = {f.name for f in dataclasses.fields(cls)}
        unknown = set(d) - names
        if unknown:
            raise ValueError(
                f"unknown FittedLatencyModel field(s) {sorted(unknown)}: "
                f"expected a subset of {sorted(names)}")
        return cls(**d)

    def to_json(self, indent: int = 2) -> str:
        return json.dumps(self.to_dict(), indent=indent, sort_keys=True)

    @classmethod
    def from_json(cls, s: str) -> "FittedLatencyModel":
        return cls.from_dict(json.loads(s))

    def save(self, path: str) -> None:
        with open(path, "w") as f:
            f.write(self.to_json() + "\n")

    @classmethod
    def load(cls, path: str) -> "FittedLatencyModel":
        with open(path) as f:
            return cls.from_json(f.read())


def _lm_graph_for(arch: str, batch: int):
    from repro.configs import get_smoke_config
    from repro.core.graph import lm_graph
    return lm_graph(get_smoke_config(arch), batch=batch, seq=1)


def _branch_design(graph, exit_point: int, kinds: List[str]) -> np.ndarray:
    """One joint-regression row: per-kind design sums over the branch."""
    row = []
    for kind in kinds:
        acc = np.zeros(len(TABLE_I_FEATURES[kind]) + 1)
        for layer in graph.branches[exit_point - 1]:
            if layer.kind == kind:
                acc += RegressionLatencyModel._design(kind, layer.features)
        row.append(acc)
    return np.concatenate(row)


def fit_table(table: CalibrationTable, *,
              arch: Optional[str] = None) -> FittedLatencyModel:
    """Fit per-kind regressions from every usable sample in ``table``.

    ``layer`` samples fit directly; ``decode`` + ``head`` samples join the
    branch-level system described in the module docstring (the graph is
    rebuilt at each sample's batch so features scale correctly).  Raises
    ``ValueError`` on a table with nothing to fit."""
    arch = arch or table.arch
    fitted = FittedLatencyModel(arch=arch, source=f"fit({table.source})",
                                meta=dict(table.meta))
    layer_samples = table.by_phase("layer")
    if layer_samples:
        reg = RegressionLatencyModel().fit([
            ProfileRecord(kind=s.kind, features=s.features,
                          latency_s=s.latency_s) for s in layer_samples])
        fitted.theta.update(
            {k: [float(x) for x in v] for k, v in reg.theta.items()})
        fitted.r2.update(reg.residual)
    branch_samples = table.by_phase("decode") + table.by_phase("head")
    if branch_samples and any(s.phase == "decode" for s in branch_samples):
        graphs = {}      # batch -> lm_graph at that batch
        for s in branch_samples:
            if s.batch not in graphs:
                graphs[s.batch] = _lm_graph_for(arch, s.batch)
        kinds = sorted({layer.kind
                        for g in graphs.values()
                        for b in g.branches for layer in b})
        widths = [len(TABLE_I_FEATURES[k]) + 1 for k in kinds]
        rows, y = [], []
        for s in branch_samples:
            g = graphs[s.batch]
            if s.phase == "decode":
                if not 1 <= (s.exit_point or 0) <= g.num_exits:
                    raise ValueError(
                        f"decode sample exit_point={s.exit_point!r} out of "
                        f"range for arch {arch!r} ({g.num_exits} exits)")
                rows.append(_branch_design(g, s.exit_point, kinds))
            else:                           # head: a lone fc layer
                row = np.zeros(sum(widths))
                off = 0
                for k, w in zip(kinds, widths):
                    if k == "fc":
                        row[off:off + w] = RegressionLatencyModel._design(
                            "fc", s.features)
                    off += w
                rows.append(row)
            y.append(s.latency_s)
        X = np.stack(rows)
        yv = np.asarray(y)
        theta, *_ = np.linalg.lstsq(X, yv, rcond=None)
        pred = X @ theta
        ss_res = float(np.sum((yv - pred) ** 2))
        ss_tot = float(np.sum((yv - yv.mean()) ** 2)) or 1e-12
        off = 0
        for k, w in zip(kinds, widths):
            fitted.theta[k] = [float(x) for x in theta[off:off + w]]
            fitted.r2[k] = 1.0 - ss_res / ss_tot
            off += w
    if not fitted.theta:
        raise ValueError(
            f"table for {table.arch!r} has no fittable samples (need "
            "'layer' or 'decode' phases; got "
            f"{sorted({s.phase for s in table.samples})})")
    return fitted


def models_from_table(table: CalibrationTable, spec, *, graph=None,
                      anchor: bool = True) -> Tuple[object, object]:
    """Planner-ready ``(f_edge, f_dev)`` from a measured table.

    ``anchor=True`` rescales the fitted predictor so a full-branch decode
    step costs the spec's ``edge_step_s`` / ``device_step_s`` — the same
    anchoring contract ``sim.build.build_stack`` applies to its rooflines,
    so swapping models changes where cuts land, never the simulated tier
    speeds.  ``anchor=False`` returns raw host seconds for the edge and the
    paper's ~20x Raspberry-Pi slowdown for the device tier."""
    from repro.core.latency_model import ScaledLatencyModel

    fitted = table if isinstance(table, FittedLatencyModel) \
        else fit_table(table)
    reg = fitted.to_regression()
    if graph is None:
        graph = _lm_graph_for(fitted.arch, 1)
    if not anchor:
        return reg, ScaledLatencyModel(reg, DEVICE_SLOWDOWN)
    full = graph.branches[-1]
    step = sum(reg.predict(l) for l in full)
    if step <= 0.0:
        raise ValueError(
            f"fitted model for {fitted.arch!r} predicts a non-positive "
            f"full-branch step ({step!r}): cannot anchor to spec step times")
    return (ScaledLatencyModel(reg, spec.edge_step_s / step),
            ScaledLatencyModel(reg, spec.device_step_s / step))


def reparameterize_planner(planner, table: CalibrationTable, spec, *,
                           anchor: bool = True):
    """Swap a live ``EdgentPlanner``'s latency models for calibrated ones
    (in place; returns the planner for chaining)."""
    f_edge, f_dev = models_from_table(table, spec, graph=planner.graph,
                                      anchor=anchor)
    planner.with_models(f_edge, f_dev)
    return planner


def elastic_planner_from_table(table: CalibrationTable, spec, *,
                               link_bps: float,
                               latency_req_s: Optional[float] = None,
                               ref_chips: int = 1, anchor: bool = True):
    """An ``runtime.elastic.ElasticPlanner`` running on calibrated per-layer
    models — the fleet-autoscaling consumer of a fitted table."""
    from repro.runtime.elastic import ElasticPlanner

    graph = _lm_graph_for(table.arch, 1)
    graph.input_bytes = int(spec.input_kb * 1024)
    if getattr(spec, "result_kb", None) is not None:
        graph.result_bytes = int(spec.result_kb * 1024)
    f_edge, f_dev = models_from_table(table, spec, graph=graph,
                                      anchor=anchor)
    return ElasticPlanner(
        graph=graph,
        latency_req_s=spec.latency_req_s if latency_req_s is None
        else latency_req_s,
        link_bps=link_bps, f_edge=f_edge, f_dev=f_dev, ref_chips=ref_chips)
