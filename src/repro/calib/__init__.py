"""Measure -> fit -> validate: the sim-to-real calibration loop
(docs/calibration.md).

The paper's planner quality rests on profiled per-layer latency
regressions (Table I); our fleet simulator normally runs on analytic
roofline models instead.  This package closes that gap on the real jax
kernels in three stages:

* :mod:`repro.calib.measure` — time per-layer / per-exit prefill and
  decode (warmup + ``block_until_ready``, median-of-k) over batch and
  sequence sweeps, emitting a serializable :class:`CalibrationTable`;
* :mod:`repro.calib.fit` — fit the paper-style per-layer-type regressions
  from a table and re-parameterize the planner
  (``core.latency_model.RegressionLatencyModel``) or an
  ``runtime.elastic.ElasticPlanner`` from the fit;
* :mod:`repro.calib.validate` — run one scenario on analytic vs calibrated
  models and report per-layer / per-exit error (signed bias + MAPE) and the
  plan-divergence rate over the scenario's bandwidth range.

``python -m repro.calib {measure,fit,validate}`` drives the loop from the
shell; ``ScenarioSpec.calibration`` points a scenario at a fitted table.
"""
from repro.calib.fit import (FittedLatencyModel, elastic_planner_from_table,  # noqa: F401
                             fit_table, models_from_table)
from repro.calib.measure import measure_alexnet, measure_lm  # noqa: F401
from repro.calib.table import CalibrationTable, TimingSample  # noqa: F401
from repro.calib.validate import validate_scenario  # noqa: F401
