"""CLI for the calibration loop: ``python -m repro.calib <cmd>``.

    # measure: time real kernels into a CalibrationTable JSON
    python -m repro.calib measure --smoke --out table.json        # alexnet
    python -m repro.calib measure --arch llama3.2-1b --out table.json
    python -m repro.calib measure --scenario smoke-lm --out table.json

    # fit: per-layer-type regressions from a table
    python -m repro.calib fit --table table.json --out fitted.json

    # validate: analytic vs calibrated error report for a scenario
    python -m repro.calib validate --scenario smoke-lm --out report.json
"""
from __future__ import annotations

import argparse
import json
import sys


def _ints(s: str):
    return tuple(int(x) for x in s.split(",") if x)


def _cmd_measure(args) -> int:
    from repro.calib.measure import measure_alexnet, measure_lm
    if args.smoke:
        table = measure_alexnet(reps=args.reps)
    else:
        spec = None
        if args.scenario:
            from repro.sim import get_scenario
            spec = get_scenario(args.scenario).planner
        table = measure_lm(spec, arch=args.arch, batches=_ints(args.batches),
                           seqs=_ints(args.seqs), reps=args.reps,
                           decode_path=args.decode_path)
    if args.out:
        table.save(args.out)
        print(f"wrote {len(table.samples)} samples for {table.arch} "
              f"-> {args.out}")
    else:
        print(table.to_json())
    return 0


def _cmd_fit(args) -> int:
    from repro.calib.fit import fit_table
    from repro.calib.table import CalibrationTable
    table = CalibrationTable.load(args.table)
    fitted = fit_table(table)
    if args.out:
        fitted.save(args.out)
        print(f"fitted {sorted(fitted.theta)} from {len(table.samples)} "
              f"samples -> {args.out}")
    for kind in sorted(fitted.theta):
        print(f"  {kind:8s} r2={fitted.r2.get(kind, float('nan')):.4f} "
              f"theta={[round(t, 9) for t in fitted.theta[kind]]}")
    if not args.out:
        print(fitted.to_json())
    return 0


def _cmd_validate(args) -> int:
    from repro.calib.table import CalibrationTable
    from repro.calib.validate import validate_scenario
    table = CalibrationTable.load(args.table) if args.table else None
    report = validate_scenario(
        args.scenario, table=table, bw_points=args.bw_points,
        run_summaries=not args.no_summaries, reps=args.reps)
    if args.out:
        with open(args.out, "w") as f:
            json.dump(report, f, indent=2, sort_keys=True)
            f.write("\n")
    pd = report["plan_divergence"]
    print(f"scenario={report['scenario']} arch={report['arch']} "
          f"scale={report['scale']:.3e}")
    print(f"per-exit   bias={report['bias_s']:+.3e}s "
          f"mape={100 * report['mape']:.2f}%")
    print(f"per-layer  bias={report['per_layer_bias_s']:+.3e}s "
          f"mape={100 * report['per_layer_mape']:.2f}%")
    print(f"plan divergence: {pd['diverged']}/{pd['points']} "
          f"({100 * pd['rate']:.1f}%) over the bandwidth grid")
    if report["summaries"] is not None:
        print("model-only summaries identical:",
              report["summaries"]["identical"])
    if args.out:
        print(f"report -> {args.out}")
    return 0


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.calib",
        description="measure -> fit -> validate latency-model calibration "
                    "(docs/calibration.md)")
    sub = ap.add_subparsers(dest="cmd", required=True)

    m = sub.add_parser("measure", help="time real kernels into a table")
    m.add_argument("--smoke", action="store_true",
                   help="branchy-alexnet per-layer profile (tiny, CI leg)")
    m.add_argument("--arch", default=None,
                   help="smoke LM arch (default: the PlannerSpec default)")
    m.add_argument("--scenario", default=None,
                   help="take the PlannerSpec from this registered scenario")
    m.add_argument("--batches", default="1,2,4",
                   help="comma-separated batch sizes (LM decode sweep)")
    m.add_argument("--seqs", default="8",
                   help="comma-separated prompt lengths (LM sweep)")
    m.add_argument("--reps", type=int, default=5, help="median-of-k repeats")
    m.add_argument("--decode-path", default="batched",
                   choices=("batched", "arena"), dest="decode_path",
                   help="which B>1 decode path the LM samples time: the "
                        "vmapped batched groups or the slot-resident "
                        "arena calls (docs/performance.md)")
    m.add_argument("--out", default=None, help="table JSON path")
    m.set_defaults(fn=_cmd_measure)

    f = sub.add_parser("fit", help="fit per-layer-type regressions")
    f.add_argument("--table", required=True, help="measured table JSON")
    f.add_argument("--out", default=None, help="fitted-model JSON path")
    f.set_defaults(fn=_cmd_fit)

    v = sub.add_parser("validate",
                       help="analytic-vs-calibrated report for a scenario")
    v.add_argument("--scenario", default="smoke-lm",
                   help="registered scenario name (default smoke-lm)")
    v.add_argument("--table", default=None,
                   help="measured table JSON (default: measure in place)")
    v.add_argument("--bw-points", type=int, default=25,
                   help="bandwidth grid size for plan divergence")
    v.add_argument("--reps", type=int, default=3,
                   help="median-of-k repeats for in-place measurement")
    v.add_argument("--no-summaries", action="store_true",
                   help="skip the two model-only fleet runs")
    v.add_argument("--out", default=None, help="report JSON path")
    v.set_defaults(fn=_cmd_validate)

    args = ap.parse_args(argv)
    if args.cmd == "measure" and args.arch and args.scenario:
        ap.error("--arch and --scenario are mutually exclusive")
    return args.fn(args)


if __name__ == "__main__":
    sys.exit(main())
