"""Time the real kernels: the measurement stage of the calibration loop.

Two targets, one artifact:

* :func:`measure_lm` — the smoke-scale LM stack a
  :class:`~repro.sim.spec.PlannerSpec` describes: per-exit decode steps
  through the *same* compiled variants the fleet's batched real-decode path
  runs (``CoInferenceStepper.decode_fn`` / ``decode_fn_batched``), swept
  over batch sizes and prompt lengths, plus prefill and exit-head samples.
* :func:`measure_alexnet` — the paper's branchy-AlexNet prototype at
  Table-I layer granularity (``core.profiler.profile_all_branches``).

Every sample is warmup + ``jax.block_until_ready`` + median-of-k
(``time.perf_counter``), recorded as a :class:`~repro.calib.table
.TimingSample` in a :class:`~repro.calib.table.CalibrationTable`.
Measurements are host wall clock — the one intentionally
non-deterministic corner of the repo; everything downstream (fit,
validate) is deterministic in the table.
"""
from __future__ import annotations

import time
from typing import Optional, Sequence

import numpy as np

from repro.calib.table import CalibrationTable, TimingSample

__all__ = ["measure_alexnet", "measure_lm"]


def _median_time(fn, *args, reps: int = 5, warmup: int = 2) -> float:
    import jax
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    ts = []
    for _ in range(reps):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts))


def measure_lm(spec=None, *, arch: Optional[str] = None,
               batches: Sequence[int] = (1, 2, 4),
               seqs: Sequence[int] = (8,), reps: int = 5,
               warmup: int = 2,
               decode_path: str = "batched") -> CalibrationTable:
    """Measure the LM decode/prefill/head kernels of ``spec`` (a
    ``PlannerSpec``; ``arch=`` shorthand builds one).

    Decode samples run through the fleet's own compiled paths — the serial
    per-exit variant at B=1 and, above that, the path ``decode_path``
    selects: ``"batched"`` (the vmapped ``decode_fn_batched`` groups) or
    ``"arena"`` (the slot-resident masked ``decode_fn_arena`` calls, with
    rows admitted to a ``DecodeArena`` sized to the batch) — so the table
    prices exactly what a ``real_decode=True`` scenario with the matching
    ``EngineSpec`` knob executes.  One table measures one path
    (``meta["decode_path"]``): the fitter treats every decode sample as
    the same regression family.  The position axis rides on ``seqs``:
    each prompt length measures decode at a different KV offset."""
    import jax
    import jax.numpy as jnp
    from repro.serving.engine import CoInferenceStepper
    from repro.sim.build import build_stack
    from repro.sim.spec import PlannerSpec

    if decode_path not in ("batched", "arena"):
        raise ValueError(f"unknown decode_path {decode_path!r}: expected "
                         "'batched' or 'arena'")
    if spec is None:
        spec = PlannerSpec() if arch is None else PlannerSpec(arch=arch)
    sc = build_stack(spec, with_model=True)
    model, params, graph = sc.model, sc.params, sc.graph
    stepper = CoInferenceStepper(model, graph, sc.planner)
    rng = np.random.default_rng(0)
    samples = []
    pf_jit = jax.jit(model.prefill)

    def prefill_rows(batch: int, seq: int):
        """``batch`` independent B=1 (cache, token) rows after a real
        prefill of ``seq`` random tokens — the fleet's request state."""
        rows = []
        for _ in range(batch):
            toks = jnp.asarray(
                rng.integers(0, sc.cfg.vocab_size, (1, seq)), jnp.int32)
            cache = model.init_cache(1, seq + 4, dtype=jnp.float32,
                                     enc_len=seq)
            h, cache = pf_jit(params, toks, cache)
            logits = model.logits(params, h)
            tok = jnp.argmax(logits[:, -1, :], -1).astype(jnp.int32)[:, None]
            rows.append((cache, tok))
        return rows

    tree = jax.tree_util.tree_map
    for seq in seqs:
        # ---- prefill: one [B, S] forward per (batch, seq)
        for b in batches:
            toks = jnp.asarray(
                rng.integers(0, sc.cfg.vocab_size, (b, seq)), jnp.int32)
            cache = model.init_cache(b, seq + 4, dtype=jnp.float32,
                                     enc_len=seq)
            t = _median_time(pf_jit, params, toks, cache,
                             reps=reps, warmup=warmup)
            samples.append(TimingSample(
                phase="prefill", latency_s=t, batch=b, seq=seq, reps=reps))
        # ---- decode: per exit x batch, at KV position `seq`
        for e in stepper.exit_points:
            for b in batches:
                rows = prefill_rows(b, seq)
                pos = jnp.asarray([seq] * b, jnp.int32)
                if b == 1:
                    fn = stepper.decode_fn(e)
                    cache, tok = rows[0]
                    t = _median_time(fn, params, cache, tok, pos[0],
                                     reps=reps, warmup=warmup)
                elif decode_path == "arena":
                    # the slot-resident path: rows admitted once, then the
                    # masked full-arena call is the steady-state per-token
                    # cost.  The cache argument is donated, so timing
                    # threads the returned cache forward instead of
                    # re-passing one buffer.
                    from repro.serving.arena import DecodeArena
                    arena = DecodeArena(model, slots=b, length=seq + 4,
                                        dtype=jnp.float32)
                    for i, (cache, tok) in enumerate(rows):
                        arena.admit(i, cache)
                    fn = stepper.decode_fn_arena(e, arena)
                    tb = jnp.stack([r[1] for r in rows])
                    tok_a = jnp.zeros((arena.slots, 1, 1), jnp.int32) \
                        .at[:b].set(tb)
                    pos_a = jnp.zeros((arena.slots,), jnp.int32) \
                        .at[:b].set(pos)
                    mask_a = jnp.arange(arena.slots) < b

                    def run_once():
                        h, arena.cache = fn(params, arena.cache, tok_a,
                                            pos_a, mask_a)
                        return h
                    t = _median_time(run_once, reps=reps, warmup=warmup)
                else:
                    fn = stepper.decode_fn_batched(e, b)
                    cb = tree(lambda *xs: jnp.stack(xs),
                              *[r[0] for r in rows])
                    tb = jnp.stack([r[1] for r in rows])
                    t = _median_time(fn, params, cb, tb, pos,
                                     reps=reps, warmup=warmup)
                samples.append(TimingSample(
                    phase="decode", latency_s=t, exit_point=e, batch=b,
                    seq=seq, reps=reps))
    # ---- exit head: the logits projection every exit pays once per token
    d = sc.cfg.d_model
    head_jit = jax.jit(model.logits)
    for b in batches:
        h = jnp.zeros((b, 1, d), jnp.float32)
        t = _median_time(head_jit, params, h, reps=reps, warmup=warmup)
        samples.append(TimingSample(
            phase="head", kind="fc", latency_s=t, batch=b, seq=1, reps=reps,
            features={"in_size": float(b * d * 2),
                      "out_size": float(b * sc.cfg.vocab_size * 2)}))
    return CalibrationTable(
        arch=spec.arch, source="measure_lm", samples=samples,
        meta={"reps": reps, "warmup": warmup, "batches": list(batches),
              "seqs": list(seqs), "decode_path": decode_path,
              "platform": jax.devices()[0].platform,
              "num_exits": stepper.n_graph,
              "edge_step_s": spec.edge_step_s,
              "device_step_s": spec.device_step_s})


def measure_alexnet(*, reps: int = 3, smoke: bool = True) -> CalibrationTable:
    """Measure the branchy-AlexNet prototype layer by layer — the paper's
    own granularity (Table I kinds, one sample per unique layer across all
    five branches).  ``smoke`` is accepted for CLI symmetry; the config is
    already CIFAR-10 scale."""
    import jax
    import jax.numpy as jnp
    from repro.configs import get_alexnet_config
    from repro.core.graph import alexnet_graph
    from repro.core.profiler import profile_all_branches
    from repro.models.alexnet import BranchyAlexNet

    cfg = get_alexnet_config()
    net = BranchyAlexNet(cfg)
    params = net.init(jax.random.key(0))
    graph = alexnet_graph(net)
    x = jnp.zeros((1, cfg.image_size, cfg.image_size, cfg.channels),
                  jnp.float32)
    profiles = profile_all_branches(graph, params, x, repeats=reps)
    samples = [TimingSample(phase="layer", kind=p.kind,
                            features=dict(p.features), latency_s=p.latency_s,
                            reps=reps)
               for p in profiles]
    return CalibrationTable(
        arch=cfg.name, source="measure_alexnet", samples=samples,
        meta={"reps": reps, "smoke": bool(smoke),
              "platform": jax.devices()[0].platform,
              "num_exits": net.num_exits})
