"""``python -m repro.obs`` — text dashboards over traces and timelines.

    python -m repro.obs report trace.json       # span breakdown + sparklines
    python -m repro.obs report timeline.jsonl   # per-edge gauge sparklines
    python -m repro.obs validate trace.json     # CI structural check

``report`` auto-detects the artifact kind (Chrome trace-event JSON from
``--trace``, or the timeline JSONL from ``--timeline``) and renders a
terminal dashboard: per-edge utilization sparklines and, for traces, the
span-latency breakdown table (queue vs uplink vs compute vs backbone vs
handover).  ``validate`` runs :func:`repro.obs.trace.validate_trace` and
exits non-zero on structural problems — the CI observability smoke leg.
"""
from __future__ import annotations

import argparse
import sys
from typing import Dict, List, Optional, Sequence

from repro.obs.timeline import load_timeline
from repro.obs.trace import load_trace, validate_trace

__all__ = ["main", "render_timeline", "render_trace", "sparkline"]

_BLOCKS = "▁▂▃▄▅▆▇█"
# the span-latency breakdown rows, in pipeline order; "queue" and
# "handover" are measured from their async b/e pairs, the rest are X spans
_STAGES = ("queue", "uplink", "prefill", "decode", "transfer", "handover")
_ASYNC_STAGES = ("queue", "handover")


def sparkline(values: Sequence[float], width: int = 40) -> str:
    """Resample ``values`` to ``width`` buckets (bucket mean) and render
    them as unicode block characters scaled to the series max."""
    vals = [float(v) for v in values]
    if not vals:
        return ""
    if len(vals) > width:
        per = len(vals) / width
        vals = [sum(vals[int(i * per):max(int(i * per) + 1,
                                          int((i + 1) * per))])
                / max(1, int((i + 1) * per) - int(i * per))
                for i in range(width)]
    peak = max(vals)
    if peak <= 0:
        return _BLOCKS[0] * len(vals)
    return "".join(_BLOCKS[min(len(_BLOCKS) - 1,
                               int(v / peak * (len(_BLOCKS) - 1) + 0.5))]
                   for v in vals)


# ------------------------------------------------------------------ trace
def _span_stats(events: List[Dict]) -> Dict[str, Dict]:
    """Per-stage duration stats: X spans by name plus async pairs (queue)
    matched on (cat, id, name)."""
    stats: Dict[str, Dict] = {}

    def add(name: str, dur_s: float):
        s = stats.setdefault(name, {"count": 0, "total_s": 0.0})
        s["count"] += 1
        s["total_s"] += dur_s

    begins: Dict[tuple, float] = {}
    for ev in events:
        ph = ev.get("ph")
        if ph == "X":
            add(ev["name"], ev.get("dur", 0.0) / 1e6)
        elif ph == "b":
            begins[(ev.get("cat"), ev.get("id"), ev["name"])] = ev["ts"]
        elif ph == "e":
            t0 = begins.pop((ev.get("cat"), ev.get("id"), ev["name"]), None)
            if t0 is not None:
                add(ev["name"] + " (async)", (ev["ts"] - t0) / 1e6)
    return stats


def _edge_utilization(events: List[Dict], width: int) -> Dict[int, str]:
    """Busy-fraction sparkline per edge process, from its ``round`` spans
    bucketed over the trace's virtual-time extent."""
    rounds: Dict[int, List[tuple]] = {}
    t_max = 0.0
    for ev in events:
        if ev.get("ph") == "X":
            t_max = max(t_max, ev["ts"] + ev.get("dur", 0.0))
            if ev["name"] == "round":
                rounds.setdefault(ev["pid"], []).append(
                    (ev["ts"], ev.get("dur", 0.0)))
    if not rounds or t_max <= 0:
        return {}
    bucket = t_max / width
    out = {}
    for pid in sorted(rounds):
        busy = [0.0] * width
        for ts, dur in rounds[pid]:
            lo, hi = ts, ts + dur
            b0, b1 = int(lo / bucket), min(width - 1, int(hi / bucket))
            for b in range(b0, b1 + 1):
                w0, w1 = b * bucket, (b + 1) * bucket
                busy[b] += max(0.0, min(hi, w1) - max(lo, w0))
        out[pid] = sparkline([v / bucket for v in busy], width)
    return out


def render_trace(trace: Dict, *, width: int = 40) -> str:
    events = trace.get("traceEvents", [])
    lines = [f"trace: {len(events)} events, "
             f"{sum(1 for e in events if e.get('ph') == 'X')} spans"]
    stats = _span_stats(events)
    named = [(s, stats.get(s + " (async)") if s in _ASYNC_STAGES
              else stats.get(s)) for s in _STAGES]
    named += [("round", stats.get("round")),
              ("request e2e", stats.get("request (async)"))]
    rows = [(name, s) for name, s in named if s]
    if rows:
        total = sum(s["total_s"] for name, s in rows
                    if name in _STAGES) or 1.0
        lines.append("")
        lines.append(f"{'stage':>12} {'spans':>8} {'total_s':>10} "
                     f"{'mean_ms':>9} {'share':>7}")
        for name, s in rows:
            share = f"{100.0 * s['total_s'] / total:6.1f}%" \
                if name in _STAGES else "      -"
            lines.append(
                f"{name:>12} {s['count']:>8} {s['total_s']:>10.3f} "
                f"{1e3 * s['total_s'] / s['count']:>9.2f} {share}")
    util = _edge_utilization(events, width)
    if util:
        lines.append("")
        lines.append("edge utilization (rounds in flight, virtual time ->)")
        for pid, spark in util.items():
            lines.append(f"  edge {pid:>3} {spark}")
    panel = _decode_panel(events)
    if panel:
        lines.append("")
        lines.extend(panel)
    return "\n".join(lines)


def _decode_panel(events) -> list:
    """The decode-efficiency panel from the engine's ``decode_stats``
    metadata record (real-decode runs only; see Tracer.decode_stats).
    Counters are cumulative over the stepper's lifetime."""
    recs = [ev for ev in events
            if ev.get("ph") == "M" and ev.get("name") == "decode_stats"
            and isinstance(ev.get("args"), dict)]
    if not recs:
        return []
    args = recs[-1]["args"]
    dec = args.get("decode", {})
    ar = args.get("arena", {})
    jit = args.get("jit", {})
    lines = ["decode efficiency (real-decode path)"]
    waste_den = dec.get("batched_tokens", 0) + dec.get("padded_rows", 0)
    waste = 100.0 * dec.get("padded_rows", 0) / waste_den if waste_den \
        else 0.0
    lines.append(
        f"  batched: {dec.get('batched_calls', 0)} calls, "
        f"{dec.get('batched_tokens', 0)} tokens, "
        f"max group {dec.get('batched_max', 0)}, "
        f"padded rows {dec.get('padded_rows', 0)} ({waste:.1f}% waste); "
        f"serial tokens {dec.get('serial_tokens', 0)}")
    occ = ar.get("occupancy")
    lines.append(
        f"  arena:   {ar.get('calls', 0)} calls, "
        f"{ar.get('tokens', 0)} tokens, "
        f"occupancy {f'{100.0 * occ:.1f}%' if occ is not None else '-'}, "
        f"admits/evicts/grows "
        f"{ar.get('admits', 0)}/{ar.get('evicts', 0)}/{ar.get('grows', 0)}")
    hr = jit.get("hit_rate")
    var = jit.get("variants", {})
    lines.append(
        f"  jit:     hit rate "
        f"{f'{100.0 * hr:.1f}%' if hr is not None else '-'}, "
        f"{jit.get('entries', 0)} compiled variants "
        f"(serial {var.get('serial', 0)} / batched {var.get('batched', 0)}"
        f" / arena {var.get('arena', 0)})")
    return lines


# --------------------------------------------------------------- timeline
def render_timeline(tl: Dict, *, width: int = 40) -> str:
    header = tl["header"]
    t = tl["t"]
    lines = [f"timeline: {header['samples']} samples x "
             f"{header['num_edges']} edges (dt={header['dt']}s"
             + (f", {header['num_devices']} devices" if
                header.get("device_signals") else "") + ")"]
    if len(t) == 0:
        return lines[0]
    span = float(t[-1] - t[0])
    lines.append(f"virtual time {float(t[0]):.2f}s .. {float(t[-1]):.2f}s")
    backlog = tl["edge"]["backlog_s"]
    busy = tl["edge"]["busy_s"]
    done = tl["edge"]["completed"]
    lines.append("")
    lines.append("per-edge backlog_s (sparkline over samples), "
                 "utilization, completions")
    for k in range(header["num_edges"]):
        util = float(busy[-1, k] - busy[0, k]) / span if span > 0 else 0.0
        lines.append(f"  edge {k:>3} {sparkline(backlog[:, k], width)}  "
                     f"util={util:4.2f}  done={int(done[-1, k])}")
    if tl.get("device"):
        bw = tl["device"]["bw_bps"]
        mean_bw = bw.mean(axis=1) / 1e6 * 8
        lines.append("")
        lines.append(f"fleet mean observed bandwidth (Mbps): "
                     f"{sparkline(mean_bw, width)}  "
                     f"last={float(mean_bw[-1]):.2f}")
    return "\n".join(lines)


# -------------------------------------------------------------------- CLI
def _detect_and_render(path: str, width: int) -> str:
    with open(path) as f:
        head = f.read(2048).lstrip()
    if head.startswith("{") and '"type": "timeline"' in head.splitlines()[0]:
        return render_timeline(load_timeline(path), width=width)
    return render_trace(load_trace(path), width=width)


def main(argv: Optional[List[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.obs",
        description="Dashboards over fleet observability artifacts "
                    "(docs/observability.md).")
    sub = ap.add_subparsers(dest="cmd", required=True)
    rep = sub.add_parser("report", help="render a text dashboard")
    rep.add_argument("path", help="trace JSON or timeline JSONL")
    rep.add_argument("--width", type=int, default=40,
                     help="sparkline width in characters")
    val = sub.add_parser("validate", help="structural trace check (CI)")
    val.add_argument("path", help="trace JSON")
    args = ap.parse_args(argv)

    if args.cmd == "report":
        print(_detect_and_render(args.path, args.width))
        return 0
    trace = load_trace(args.path)
    problems = validate_trace(trace)
    if problems:
        for p in problems:
            print(f"INVALID  {p}", file=sys.stderr)
        return 1
    events = trace["traceEvents"]
    print(f"valid Chrome trace: {len(events)} events, "
          f"{sum(1 for e in events if e.get('ph') == 'X')} complete spans")
    return 0


if __name__ == "__main__":
    sys.exit(main())
