"""Request-span tracing: Chrome/Perfetto trace-event JSON from a fleet run.

A :class:`Tracer` attached to a :class:`~repro.fleet.engine.FleetEngine`
(via ``EngineSpec(trace="out.json")`` or directly) records every request
lifecycle edge the engine crosses — arrival/plan, queue wait, uplink,
prefill, decode rounds, cooperative span hops, handover snapshot/transfer/
resume, completion — as standard trace events that open directly in
``chrome://tracing`` or https://ui.perfetto.dev (docs/observability.md).

Track layout (the pid/tid conventions the engine emits):

* one *process* per edge (``pid`` = edge id): ``tid 0`` is the rounds
  track (one ``X`` span per decode round), ``tid 1..capacity`` are the
  continuous-batching slots carrying per-request ``uplink`` / ``prefill``
  / ``decode`` spans, and per-edge counter tracks (``backlog_s``,
  ``slots``, ``tokens_owed``, ``coop_inflight``) ride alongside;
* ``pid`` :data:`Tracer.PID_DEVICES`: one thread per device with local
  execution spans, zero-duration ``plan`` instants, and the request-scoped
  async spans (``request`` / ``queue`` / ``handover``, ``ph`` b/e keyed by
  request id) that survive migrations across edges;
* ``pid`` :data:`Tracer.PID_NET`: backbone ``transfer`` / ``handover``
  wire spans (one thread per source edge).

Timestamps are the simulator's *virtual* seconds scaled to microseconds
(the trace-event unit), so the viewer's ruler reads virtual time directly.
The tracer is write-only with respect to the simulation: attaching one
never schedules events, mutates state, or consumes RNG, so summaries stay
bit-identical with tracing on or off (pinned by tests/test_obs.py).
"""
from __future__ import annotations

import json
from typing import Dict, List, Optional

__all__ = ["Tracer", "load_trace", "validate_trace"]

_US = 1e6          # virtual seconds -> trace-event microseconds


class Tracer:
    """Accumulates trace events in memory; ``save()`` writes the standard
    ``{"traceEvents": [...]}`` JSON object."""

    PID_DEVICES = 10_000      # devices pseudo-process (above any edge id)
    PID_NET = 10_001          # backbone pseudo-process

    def __init__(self):
        self.events: List[Dict] = []

    def reset(self) -> None:
        """Drop all accumulated events (the engine calls this per run so a
        reused engine does not concatenate runs into one file)."""
        self.events = []

    # ------------------------------------------------------------- emitters
    def complete(self, name: str, t0_s: float, t1_s: float, pid: int,
                 tid: int, *, cat: str = "sim",
                 args: Optional[Dict] = None) -> None:
        """One ``X`` (complete) span over virtual [t0_s, t1_s]."""
        ev = {"name": name, "ph": "X", "cat": cat, "pid": pid, "tid": tid,
              "ts": t0_s * _US, "dur": (t1_s - t0_s) * _US}
        if args:
            ev["args"] = args
        self.events.append(ev)

    def instant(self, name: str, t_s: float, pid: int, tid: int, *,
                cat: str = "sim", args: Optional[Dict] = None) -> None:
        ev = {"name": name, "ph": "i", "s": "t", "cat": cat, "pid": pid,
              "tid": tid, "ts": t_s * _US}
        if args:
            ev["args"] = args
        self.events.append(ev)

    def counter(self, name: str, t_s: float, pid: int,
                values: Dict[str, float]) -> None:
        """One sample on the ``name`` counter track of process ``pid``
        (every key in ``values`` is a series on that track)."""
        self.events.append({"name": name, "ph": "C", "pid": pid, "tid": 0,
                            "ts": t_s * _US, "args": values})

    def async_begin(self, name: str, id_: int, t_s: float, pid: int,
                    tid: int, *, cat: str = "req",
                    args: Optional[Dict] = None) -> None:
        """Open one nestable async span, keyed by (cat, id) — request-scoped
        stages that outlive any single edge/track use these."""
        ev = {"name": name, "ph": "b", "cat": cat, "id": id_, "pid": pid,
              "tid": tid, "ts": t_s * _US}
        if args:
            ev["args"] = args
        self.events.append(ev)

    def async_end(self, name: str, id_: int, t_s: float, pid: int,
                  tid: int, *, cat: str = "req",
                  args: Optional[Dict] = None) -> None:
        ev = {"name": name, "ph": "e", "cat": cat, "id": id_, "pid": pid,
              "tid": tid, "ts": t_s * _US}
        if args:
            ev["args"] = args
        self.events.append(ev)

    # ------------------------------------------------------------- metadata
    def process_name(self, pid: int, name: str) -> None:
        self.events.append({"name": "process_name", "ph": "M", "pid": pid,
                            "tid": 0, "args": {"name": name}})

    def thread_name(self, pid: int, tid: int, name: str) -> None:
        self.events.append({"name": "thread_name", "ph": "M", "pid": pid,
                            "tid": tid, "args": {"name": name}})

    def decode_stats(self, stats: Dict) -> None:
        """Attach the run's decode-efficiency counters (the engine's
        ``cache_stats()`` decode/arena/jit blocks) as a metadata record —
        not a span, so no timestamp.  ``repro.obs report`` renders it as
        the decode-efficiency panel."""
        self.events.append({"name": "decode_stats", "ph": "M", "pid": 0,
                            "tid": 0, "args": stats})

    def annotate_fleet(self, topo) -> None:
        """Name every track for a fleet topology (edges/slots/devices/net)
        so the viewer shows labels instead of bare pids."""
        for edge in topo.edges:
            self.process_name(edge.eid,
                              f"edge {edge.eid} (speed {edge.speed:g})")
            self.thread_name(edge.eid, 0, "rounds")
            for slot in range(edge.capacity):
                self.thread_name(edge.eid, slot + 1, f"slot {slot}")
        self.process_name(self.PID_DEVICES, "devices")
        for dev in topo.devices:
            self.thread_name(self.PID_DEVICES, dev.did, f"device {dev.did}")
        self.process_name(self.PID_NET, "backbone")
        for edge in topo.edges:
            self.thread_name(self.PID_NET, edge.eid,
                             f"from edge {edge.eid}")

    # ------------------------------------------------------------------ I/O
    def to_chrome(self) -> Dict:
        return {"traceEvents": self.events, "displayTimeUnit": "ms"}

    def save(self, path: str) -> None:
        with open(path, "w") as f:
            json.dump(self.to_chrome(), f, default=float)


def load_trace(path: str) -> Dict:
    """Read a trace file back (either the ``{"traceEvents": ...}`` object
    form or a bare event array, both of which viewers accept)."""
    with open(path) as f:
        obj = json.load(f)
    if isinstance(obj, list):
        obj = {"traceEvents": obj}
    return obj


def validate_trace(trace: Dict) -> List[str]:
    """Structural checks on a loaded trace; returns human-readable problem
    strings (empty = valid).  CI runs this on the smoke-mobility artifact:
    parseable, >0 complete events, non-negative durations, balanced async
    begin/end pairs, required fields per phase."""
    problems: List[str] = []
    events = trace.get("traceEvents")
    if not isinstance(events, list):
        return ["no traceEvents array"]
    n_complete = 0
    opens: Dict[tuple, int] = {}
    for i, ev in enumerate(events):
        if not isinstance(ev, dict) or "ph" not in ev or "name" not in ev:
            problems.append(f"event {i}: missing ph/name")
            continue
        ph = ev["ph"]
        if ph != "M" and "ts" not in ev:
            problems.append(f"event {i} ({ev['name']}): missing ts")
            continue
        if ph == "X":
            n_complete += 1
            if ev.get("dur", -1.0) < 0:
                problems.append(
                    f"event {i} ({ev['name']}): negative duration")
        elif ph in ("b", "e"):
            key = (ev.get("cat"), ev.get("id"), ev["name"])
            opens[key] = opens.get(key, 0) + (1 if ph == "b" else -1)
            if opens[key] < 0:
                problems.append(
                    f"event {i} ({ev['name']}): async end before begin")
        elif ph == "C" and not isinstance(ev.get("args"), dict):
            problems.append(f"event {i} ({ev['name']}): counter without "
                            "args series")
    if n_complete == 0:
        problems.append("no complete ('X') events")
    for key, depth in opens.items():
        if depth != 0:
            problems.append(f"unbalanced async span {key}: depth {depth}")
    return problems
