"""Counter / gauge / histogram instruments and their registry.

The primitive layer of ``repro.obs``: tiny, dependency-free instruments
that the rest of the stack aggregates through.  ``FleetMetrics`` keeps its
running aggregates in these (replacing the ad-hoc ``_handover_count``-style
private ints it used to carry), and anything else that wants a named
counter — cache stats, profilers, future autoscalers — registers it here so
``snapshot()`` can export everything at once.

Design constraints (the determinism contract, docs/observability.md):

* Instruments are *passive* — they never read clocks or RNG, so feeding
  them from the event loop cannot perturb a simulation.
* ``Histogram`` retains its raw samples: summaries need *exact* percentiles
  (``np.percentile`` over the full sample vector) to stay bit-identical
  with the pre-registry implementation, so there is no bucketing.
"""
from __future__ import annotations

from typing import Dict, Iterator, List, Optional, Tuple, Union

import numpy as np

__all__ = ["Counter", "CounterFamily", "Gauge", "Histogram",
           "MetricsRegistry"]

Number = Union[int, float]


class Counter:
    """Monotonic count (``inc`` only)."""
    __slots__ = ("name", "value")

    def __init__(self, name: str = ""):
        self.name = name
        self.value = 0

    def inc(self, n: Number = 1) -> None:
        self.value += n

    def __repr__(self) -> str:
        return f"Counter({self.name!r}, value={self.value!r})"


class Gauge:
    """Last-write-wins scalar (``set``)."""
    __slots__ = ("name", "value")

    def __init__(self, name: str = ""):
        self.name = name
        self.value: Number = 0

    def set(self, v: Number) -> None:
        self.value = v

    def __repr__(self) -> str:
        return f"Gauge({self.name!r}, value={self.value!r})"


class Histogram:
    """Sample-retaining distribution: exact percentiles and the pairwise
    ``np.mean``, bit-identical to computing over a plain list (~16 bytes per
    observation, the price of exactness)."""
    __slots__ = ("name", "samples")

    def __init__(self, name: str = ""):
        self.name = name
        self.samples: List[float] = []

    def observe(self, v: float) -> None:
        self.samples.append(v)

    @property
    def count(self) -> int:
        return len(self.samples)

    def percentile(self, q: float) -> Optional[float]:
        if not self.samples:
            return None
        return float(np.percentile(np.array(self.samples), q))

    def mean(self) -> Optional[float]:
        if not self.samples:
            return None
        return float(np.mean(np.array(self.samples)))

    def __repr__(self) -> str:
        return f"Histogram({self.name!r}, count={self.count})"


class CounterFamily:
    """A labeled set of counters (one count per label) — histograms over
    discrete keys like exit points, partitions, or tenant names."""
    __slots__ = ("name", "_counts")

    def __init__(self, name: str = ""):
        self.name = name
        self._counts: Dict = {}

    def inc(self, label, n: Number = 1) -> None:
        self._counts[label] = self._counts.get(label, 0) + n

    def get(self, label, default: Number = 0) -> Number:
        return self._counts.get(label, default)

    def items(self) -> Iterator[Tuple[object, Number]]:
        return iter(self._counts.items())

    def as_dict(self) -> Dict:
        """Label -> count, in sorted label order (summary()-stable)."""
        return dict(sorted(self._counts.items()))

    def __len__(self) -> int:
        return len(self._counts)

    def __contains__(self, label) -> bool:
        return label in self._counts

    def __repr__(self) -> str:
        return f"CounterFamily({self.name!r}, labels={len(self)})"


_KINDS = {"counter": Counter, "gauge": Gauge, "histogram": Histogram,
          "family": CounterFamily}


class MetricsRegistry:
    """Named instruments with get-or-create semantics.

    Asking twice for the same name returns the same instrument; asking for
    an existing name as a different kind raises (catching the silent-shadow
    bug where two subsystems fight over one name)."""

    def __init__(self):
        self._instruments: Dict[str, object] = {}

    def _get(self, name: str, cls):
        inst = self._instruments.get(name)
        if inst is None:
            inst = self._instruments[name] = cls(name)
        elif type(inst) is not cls:
            raise ValueError(
                f"metric {name!r} already registered as "
                f"{type(inst).__name__}, not {cls.__name__}")
        return inst

    def counter(self, name: str) -> Counter:
        return self._get(name, Counter)

    def gauge(self, name: str) -> Gauge:
        return self._get(name, Gauge)

    def histogram(self, name: str) -> Histogram:
        return self._get(name, Histogram)

    def family(self, name: str) -> CounterFamily:
        return self._get(name, CounterFamily)

    def names(self) -> List[str]:
        return list(self._instruments)

    def __contains__(self, name: str) -> bool:
        return name in self._instruments

    def snapshot(self) -> Dict:
        """Export every instrument's current state as plain data (counters/
        gauges -> value, families -> sorted dict, histograms -> count/mean/
        p50/p95/p99)."""
        out: Dict = {}
        for name, inst in self._instruments.items():
            if isinstance(inst, (Counter, Gauge)):
                out[name] = inst.value
            elif isinstance(inst, CounterFamily):
                out[name] = inst.as_dict()
            else:
                out[name] = {"count": inst.count, "mean": inst.mean(),
                             "p50": inst.percentile(50),
                             "p95": inst.percentile(95),
                             "p99": inst.percentile(99)}
        return out
