"""Simulator self-profiling: where the engine's wall time actually goes.

A :class:`SimProfiler` attached to a :class:`~repro.fleet.engine
.FleetEngine` times every event-handler dispatch (wall seconds and count
per event kind) and tracks the event heap's peak size.  ``report()`` folds
in the engine-side structural stats — queue tombstone ratio, the shared
:class:`~repro.serving.engine.CoInferenceStepper` cache hit rates, the
mobility replanner's cache hit rates — plus the scenario build time when
the caller stamps ``build_s``.

This is the measurement side of the ROADMAP's 100k-device scaling push:
``benchmarks/perf_fleet.py --smoke`` attaches one per cell and emits the
report as the cell's ``profile`` block.  Unlike the tracer/timeline, a
profiler reads *host* clocks, so its numbers vary run to run — but it
never touches simulation state, so virtual-time results remain
bit-identical with profiling on or off.
"""
from __future__ import annotations

from typing import Dict, Optional

__all__ = ["SimProfiler"]


def _cache_block(hits: int, misses: int, entries: int) -> Dict:
    total = hits + misses
    return {"hits": hits, "misses": misses, "entries": entries,
            "hit_rate": round(hits / total, 6) if total else None}


class SimProfiler:
    def __init__(self):
        self.build_s: Optional[float] = None   # stamped by the builder;
        #                                        survives reset()
        self.reset()

    def reset(self) -> None:
        """Clear per-run accumulators (the engine calls this per run);
        ``build_s`` is construction-time metadata and is kept."""
        self.wall_by_kind: Dict[str, float] = {}
        self.count_by_kind: Dict[str, int] = {}
        self.peak_heap = 0
        self.run_wall_s = 0.0

    def add(self, kind: str, wall_s: float, heap_len: int) -> None:
        """Account one dispatched event of ``kind`` (called by the engine
        loop with the post-dispatch heap length)."""
        self.wall_by_kind[kind] = self.wall_by_kind.get(kind, 0.0) + wall_s
        self.count_by_kind[kind] = self.count_by_kind.get(kind, 0) + 1
        if heap_len > self.peak_heap:
            self.peak_heap = heap_len
        self.run_wall_s += wall_s

    def report(self, engine=None) -> Dict:
        """The ``profile`` block: per-kind wall time/counts, heap peak, and
        — given the engine — tombstone ratio and cache hit rates."""
        total = self.run_wall_s
        out: Dict = {
            "wall_s": round(total, 6),
            "peak_heap": self.peak_heap,
            "events": {
                kind: {"count": self.count_by_kind[kind],
                       "wall_s": round(self.wall_by_kind[kind], 6),
                       "wall_pct": round(
                           100.0 * self.wall_by_kind[kind] / total, 2)
                       if total > 0 else 0.0}
                for kind in sorted(self.count_by_kind)},
        }
        if self.build_s is not None:
            out["build_s"] = round(self.build_s, 6)
        if engine is not None:
            enqueued = getattr(engine, "enqueued", 0)
            tombstoned = getattr(engine, "tombstoned", 0)
            out["tombstones"] = tombstoned
            out["tombstone_ratio"] = round(tombstoned / enqueued, 6) \
                if enqueued else 0.0
            stepper = getattr(engine, "stepper", None)
            if stepper is not None and hasattr(stepper, "cache_stats"):
                out["stepper_caches"] = stepper.cache_stats()
            replanner = getattr(engine, "replanner", None)
            if replanner is not None and hasattr(replanner, "cache_stats"):
                out["replanner_caches"] = replanner.cache_stats()
        return out
