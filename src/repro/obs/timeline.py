"""Telemetry timelines: columnar per-edge/per-device gauges over a run.

A :class:`Timeline` attached to a :class:`~repro.fleet.engine.FleetEngine`
(via ``EngineSpec(timeline="out.jsonl")``) snapshots fleet state on the
sampling grid into numpy ring buffers — the streaming utilization/backlog
feed the ROADMAP's autoscaler subscribes to, and the raw material for
``python -m repro.obs report`` dashboards.

Sampling piggybacks on whatever grid the engine already runs: under an
active handover policy each fleet-wide ``sample`` sweep takes one snapshot
(which also carries the per-device signals that sweep just computed —
observed best-signal bandwidth and the BOCD run-length MAP); otherwise the
engine schedules dedicated ``obs`` events every ``dt`` virtual seconds.
Either way snapshots read state and never mutate it, so summaries stay
bit-identical with the timeline on or off (pinned by tests/test_obs.py).

Columns per sample: ``t`` (virtual s), per-edge gauges
(:data:`EDGE_GAUGES`: backlog seconds, tokens owed, busy/queued slot
counts, cooperative in-flight spans, cumulative busy seconds, completions,
provisioned capacity — the admission/autoscaling state of every edge) and,
when device signals were available, :data:`DEVICE_SIGNALS`.  The buffers are rings: past
``capacity`` samples the oldest rows are overwritten (``n`` keeps the
total ever taken).  ``to_jsonl`` writes a self-describing header line plus
one JSON object per retained sample; :func:`load_timeline` reads that back
into arrays.
"""
from __future__ import annotations

import json
from typing import Dict, Iterator, Optional

import numpy as np

__all__ = ["DEVICE_SIGNALS", "EDGE_GAUGES", "Timeline", "load_timeline"]

EDGE_GAUGES = ("backlog_s", "tokens_owed", "active", "queued",
               "coop_inflight", "busy_s", "completed", "capacity")
DEVICE_SIGNALS = ("bw_bps", "run_len")


class Timeline:
    def __init__(self, num_edges: int, *, num_devices: int = 0,
                 dt: float = 0.5, capacity: int = 4096):
        if num_edges <= 0:
            raise ValueError(f"num_edges must be positive, got {num_edges}")
        self.num_edges = num_edges
        self.num_devices = num_devices
        self.dt = dt
        self.capacity = capacity
        self.n = 0                      # samples ever taken (ring may wrap)
        self.t = np.zeros(capacity)
        self.edge: Dict[str, np.ndarray] = {
            g: np.zeros((capacity, num_edges)) for g in EDGE_GAUGES}
        self.device: Dict[str, np.ndarray] = {
            s: np.zeros((capacity, num_devices)) for s in DEVICE_SIGNALS} \
            if num_devices > 0 else {}
        self._device_sampled = False    # any snapshot carried device signals

    def reset(self) -> None:
        """Restart the ring (the engine calls this per run)."""
        self.n = 0
        self._device_sampled = False

    @property
    def num_retained(self) -> int:
        return min(self.n, self.capacity)

    # ------------------------------------------------------------- sampling
    def snapshot(self, t_s: float, topo, *,
                 bw_row: Optional[np.ndarray] = None,
                 run_len: Optional[np.ndarray] = None) -> None:
        """Record one sample of every edge's gauges (plus optional
        per-device signals) at virtual time ``t_s``.  Read-only with
        respect to ``topo`` — snapshotting must never perturb the run."""
        i = self.n % self.capacity
        self.t[i] = t_s
        eg = self.edge
        for k, e in enumerate(topo.edges):
            eg["backlog_s"][i, k] = e.backlog_s()
            eg["tokens_owed"][i, k] = e.tokens_owed
            eg["active"][i, k] = len(e.active)
            eg["queued"][i, k] = len(e.queue) - e.q_dead
            eg["coop_inflight"][i, k] = e.coop_inflight
            eg["busy_s"][i, k] = e.busy_s
            eg["completed"][i, k] = e.completed
            eg["capacity"][i, k] = e.capacity
        if self.device:
            if bw_row is not None:
                self.device["bw_bps"][i] = bw_row
            if run_len is not None:
                self.device["run_len"][i] = run_len
            if bw_row is not None or run_len is not None:
                self._device_sampled = True
        self.n += 1

    # ------------------------------------------------------------------ I/O
    def rows(self) -> Iterator[Dict]:
        """Retained samples in chronological order (ring-aware)."""
        kept = self.num_retained
        start = self.n - kept
        for j in range(kept):
            i = (start + j) % self.capacity
            row = {"t": float(self.t[i]),
                   "edge": {g: self.edge[g][i].tolist()
                            for g in EDGE_GAUGES}}
            if self.device and self._device_sampled:
                row["device"] = {s: self.device[s][i].tolist()
                                 for s in DEVICE_SIGNALS}
            yield row

    def to_jsonl(self, path: str) -> None:
        with open(path, "w") as f:
            header = {"type": "timeline", "dt": self.dt,
                      "num_edges": self.num_edges,
                      "num_devices": self.num_devices,
                      "samples": self.num_retained, "total_samples": self.n,
                      "edge_gauges": list(EDGE_GAUGES),
                      "device_signals": list(DEVICE_SIGNALS)
                      if self.device and self._device_sampled else []}
            f.write(json.dumps(header) + "\n")
            for row in self.rows():
                f.write(json.dumps(row) + "\n")


def load_timeline(path: str) -> Dict:
    """Read a timeline JSONL back into arrays: ``{"header": ..., "t": [S],
    "edge": {gauge: [S, E]}, "device": {signal: [S, N]} | {}}``."""
    with open(path) as f:
        lines = [line for line in f if line.strip()]
    if not lines:
        raise ValueError(f"{path}: empty timeline file")
    header = json.loads(lines[0])
    if header.get("type") != "timeline":
        raise ValueError(f"{path}: not a timeline JSONL "
                         "(missing header line)")
    rows = [json.loads(line) for line in lines[1:]]
    out = {"header": header,
           "t": np.array([r["t"] for r in rows]),
           "edge": {g: np.array([r["edge"][g] for r in rows])
                    for g in header["edge_gauges"]} if rows else {},
           "device": {}}
    if rows and header.get("device_signals"):
        out["device"] = {s: np.array([r["device"][s] for r in rows])
                         for s in header["device_signals"]}
    return out
