"""Fleet-wide observability (docs/observability.md).

Three pillars, all zero-overhead when unattached and determinism-preserving
when attached (summaries bit-identical with observers on or off):

* :mod:`repro.obs.trace` — request-span tracing to Chrome/Perfetto
  trace-event JSON (``EngineSpec(trace=...)`` / ``repro.sim --trace``);
* :mod:`repro.obs.timeline` — columnar per-edge/per-device telemetry
  timelines (``EngineSpec(timeline=...)``), plus the
  :class:`~repro.obs.registry.MetricsRegistry` instrument layer that
  :class:`~repro.fleet.metrics.FleetMetrics` aggregates through;
* :mod:`repro.obs.profile` — simulator self-profiling (wall time per event
  kind, cache hit rates, tombstone ratio) surfaced by
  ``benchmarks/perf_fleet.py --smoke``.

``python -m repro.obs report FILE`` renders either artifact as a terminal
dashboard; ``python -m repro.obs validate FILE`` is the CI trace check.
"""
from repro.obs.profile import SimProfiler
from repro.obs.registry import (Counter, CounterFamily, Gauge, Histogram,
                                MetricsRegistry)
from repro.obs.timeline import (DEVICE_SIGNALS, EDGE_GAUGES, Timeline,
                                load_timeline)
from repro.obs.trace import Tracer, load_trace, validate_trace

__all__ = [
    "Counter", "CounterFamily", "DEVICE_SIGNALS", "EDGE_GAUGES", "Gauge",
    "Histogram", "MetricsRegistry", "SimProfiler", "Timeline", "Tracer",
    "load_timeline", "load_trace", "validate_trace",
]
