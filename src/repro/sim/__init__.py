"""Declarative scenario/experiment API over the fleet simulator
(docs/api.md).

One :class:`ScenarioSpec` — a plain-data tree of topology / workload /
planner / router / engine / mobility specs — fully determines a fleet
simulation; :class:`Simulation` builds and runs it; the registry names the
canonical presets; ``python -m repro.sim`` drives it all from the shell:

    from repro.sim import Simulation, get_scenario
    metrics = Simulation(get_scenario("smoke-lm")).run()

Specs round-trip through JSON (``to_json``/``from_json``), every random
draw derives from the single root seed (``ScenarioSpec.seeds()``), and the
same spec always reproduces bit-identical :class:`~repro.fleet.metrics
.FleetMetrics` — sweeps are spec edits, not rewired setup code.
"""
from repro.sim.build import (Scenario, Simulation, build_stack,  # noqa: F401
                             build_topology)
from repro.sim.registry import (STREAMING_TENANTS, get_scenario,  # noqa: F401
                                list_scenarios, register_scenario)
from repro.sim.spec import (AdmissionSpec, AutoscaleSpec,  # noqa: F401
                            CalibrationSpec, DerivedSeeds, EngineSpec,
                            MobilitySpec, PlannerSpec, RouterSpec,
                            ScenarioSpec, TopologySpec, WorkloadSpec,
                            apply_overrides)
from repro.sim.sweep import (grid_cells, pareto_frontier,  # noqa: F401
                             random_cells, run_sweep)
