"""Geography-sharded fleet simulation (docs/performance.md).

``TopologySpec.shards = k`` declares the fleet as ``k`` disjoint geography
*tiles*: tile ``g`` owns ``num_edges/k`` edges and ``num_devices/k``
devices, sampled from its own derived seed, with all ids offset into the
fleet-global namespace (edges ``g*M_t ..``, devices ``g*N_t ..``, request
ids ``g*RID_STRIDE ..``).  Reachability is block-diagonal — a tile's
devices route, cooperate, and hand over only within the tile — so each
tile is an independent discrete-event simulation, and a sharded run is
embarrassingly parallel across worker processes.

The merge is the virtual-time barrier: every tile's metric stream carries
its append times (:class:`~repro.fleet.metrics.FleetMetrics.finish_keys` /
``handover_at``), and :meth:`FleetMetrics.merged` replays the per-tile
streams in (virtual time, tile index) order.  Because the spec *defines*
the tiling, a sharded run (``processes=k``) and an unsharded run of the
same spec (``processes=1``, or plain ``Simulation(spec).run()``) execute
the identical per-tile event loops and the identical merge — summaries and
handover logs are bit-identical (pinned by tests/test_shard.py).

    spec = replace(get_scenario("smoke-mobility"), ...)   # shards=8
    metrics = run_sharded(spec, processes=8)              # -> FleetMetrics
"""
from __future__ import annotations

import dataclasses
import json
from typing import Dict, List, Optional, Tuple

from repro.fleet.engine import FleetEngine
from repro.fleet.metrics import FleetMetrics
from repro.fleet.mobility import HandoverController, make_mobile_fleet
from repro.fleet.cluster import make_fleet
from repro.fleet.workload import make_workload
from repro.sim.build import build_stack
from repro.sim.spec import ScenarioSpec

__all__ = ["run_sharded", "run_sharded_info", "run_tile", "tile_spec"]

# seed stride between tiles: tiles draw from disjoint seed lanes (tile 0
# keeps the spec's own seed, so a shards=1 spec is unchanged)
TILE_SEED_STRIDE = 100_003
# request-id namespace per tile: rids stay unique fleet-wide
RID_STRIDE = 10 ** 9


def _check_shardable(spec: ScenarioSpec):
    if spec.topology.shards < 2:
        raise ValueError(
            f"spec {spec.name!r} has topology.shards="
            f"{spec.topology.shards}: nothing to shard")
    if spec.engine.trace is not None or spec.engine.timeline is not None:
        raise ValueError(
            "sharded runs do not support engine.trace / engine.timeline "
            "observers (each tile would write its own partial artifact); "
            "run the spec with shards=1 to attach them")
    if spec.engine.real_decode:
        raise ValueError(
            "sharded runs do not support engine.real_decode (each tile "
            "would build its own model replica and produce per-tile token "
            "streams the merge does not carry); run the spec with shards=1 "
            "for real decode, or real_decode=False to shard")


def tile_spec(spec: ScenarioSpec, g: int) -> ScenarioSpec:
    """The per-tile scenario: tile ``g``'s share of the fleet as a
    standalone ``shards=1`` spec with its derived seed and its absolute
    slice of the arrival rate.  (Offsets into the global id namespace are
    *not* spec fields — :func:`run_tile` threads them into the builders.)"""
    k = spec.topology.shards
    topo = dataclasses.replace(
        spec.topology, shards=1,
        num_devices=spec.topology.num_devices // k,
        num_edges=spec.topology.num_edges // k)
    # resolve against the *fleet* size first, then split evenly: both
    # rate_hz and rate_per_device_hz forms land on the same per-tile rate
    rate = spec.workload.resolve_rate_hz(spec.topology.num_devices) / k
    workload = dataclasses.replace(spec.workload, rate_hz=rate,
                                   rate_per_device_hz=None)
    return dataclasses.replace(
        spec, name=f"{spec.name}/tile{g}", topology=topo, workload=workload,
        seed=spec.seed + g * TILE_SEED_STRIDE)


def run_tile(spec: ScenarioSpec, g: int) -> Tuple[FleetMetrics, Dict]:
    """Build and run one geography tile to completion.  Returns the tile's
    metrics plus run info (event counts — measurement metadata, not part of
    the determinism contract)."""
    k = spec.topology.shards
    tspec = tile_spec(spec, g)
    t = tspec.topology
    eid0 = g * t.num_edges
    did0 = g * t.num_devices
    seeds = tspec.seeds()
    sc = build_stack(tspec.planner, with_model=tspec.engine.real_decode,
                     scenario_spec=tspec)
    if t.kind == "static":
        topo = make_fleet(
            t.num_devices, t.num_edges, seed=seeds.topology, trace=t.trace,
            edge_capacity=t.edge_capacity, hetero_edges=t.hetero_edges,
            max_edge_slowdown=t.max_edge_slowdown,
            device_slowdown_range=t.device_slowdown_range,
            lo_mbps=t.lo_mbps, hi_mbps=t.hi_mbps, trace_len=t.trace_len,
            edge_bw_mbps=t.edge_bw_mbps, eid0=eid0, did0=did0)
        mobility = None
    else:
        topo, mobility = make_mobile_fleet(
            t.num_devices, t.num_edges, seed=seeds.topology, speed=t.speed,
            horizon_s=t.horizon_s, area=t.area,
            edge_capacity=t.edge_capacity, hetero_edges=t.hetero_edges,
            max_edge_slowdown=t.max_edge_slowdown,
            device_slowdown_range=t.device_slowdown_range,
            peak_mbps=t.peak_mbps, floor_mbps=t.floor_mbps,
            d_ref=t.d_ref, path_exp=t.path_exp,
            noise_sigma=t.noise_sigma, noise_dt=t.noise_dt,
            edge_bw_mbps=t.edge_bw_mbps, eid0=eid0, did0=did0)
    handover = None
    if tspec.mobility is not None and tspec.mobility.policy != "none":
        if mobility is None:
            raise ValueError(
                f"spec {spec.name!r} sets a handover policy but its "
                "topology is static: mobility policies need "
                "TopologySpec(kind='mobile')")
        m = tspec.mobility
        handover = HandoverController(
            mobility, policy=m.policy, sample_dt=m.sample_dt,
            hazard=m.hazard, hysteresis=m.hysteresis, min_gap_s=m.min_gap_s)
    w = tspec.workload
    vocab = sc.cfg.vocab_size \
        if (w.sample_prompts or tspec.engine.real_decode) else 0
    workload = make_workload(
        t.num_devices, rate_hz=w.resolve_rate_hz(t.num_devices),
        horizon_s=w.horizon_s, seed=seeds.workload, arrival=w.arrival,
        tenants=w.tenants, device_skew=w.device_skew,
        peak_factor=w.peak_factor, period_s=w.period_s,
        prompt_len=w.prompt_len, vocab_size=vocab,
        rid0=g * RID_STRIDE, did0=did0)
    dtype = None
    if tspec.engine.dtype is not None:
        import jax.numpy as jnp
        dtype = getattr(jnp, tspec.engine.dtype)
    autoscaler = admission = None
    if tspec.autoscale is not None or tspec.admission is not None:
        from repro.fleet.elastic import build_elasticity
        autoscaler, admission = build_elasticity(
            tspec.autoscale, tspec.admission, graph=sc.graph,
            planner=sc.planner, latency_req_s=tspec.planner.latency_req_s,
            ref_chips=t.edge_capacity)
    engine = FleetEngine(
        topo, sc.graph, sc.planner, router=tspec.router.name,
        model=sc.model, params=sc.params, dynamic=tspec.engine.dynamic,
        dtype=dtype,
        demote_on_deadline=tspec.engine.demote_on_deadline,
        prefill_div=tspec.engine.prefill_div, mobility=mobility,
        handover=handover, replan_max_coop=tspec.engine.replan_max_coop,
        max_coop=tspec.router.max_coop,
        retain_records=tspec.engine.retain_records,
        autoscaler=autoscaler, admission=admission,
        batch_decode=tspec.engine.batch_decode,
        shard_decode=tspec.engine.shard_decode)
    metrics = engine.run(workload)
    info = {"tile": g, "shards": k,
            "events_processed": engine.events_processed,
            "event_counts": dict(sorted(engine.event_counts.items())),
            "compactions": engine.compactions,
            "requests": len(workload)}
    return metrics, info


def _run_tile_json(payload: str) -> Tuple[FleetMetrics, Dict]:
    spec_json, g = json.loads(payload)
    return run_tile(ScenarioSpec.from_json(spec_json), g)


def run_sharded_info(spec: ScenarioSpec, *,
                     processes: Optional[int] = None
                     ) -> Tuple[FleetMetrics, Dict]:
    """Run every tile of a ``shards=k`` spec and merge (metrics, info).

    ``processes`` > 1 fans tiles out over a spawn-context worker pool (the
    ``repro.sim.sweep`` skeleton — no fork: jax/BLAS state is unsafe);
    otherwise tiles run sequentially in this process.  Either way the
    result is bit-identical: per-tile event loops are deterministic in the
    tile spec, and :meth:`FleetMetrics.merged` is deterministic in the
    per-tile streams."""
    _check_shardable(spec)
    k = spec.topology.shards
    parts: List[Optional[FleetMetrics]] = [None] * k
    infos: List[Optional[Dict]] = [None] * k
    if processes is not None and processes > 1:
        import multiprocessing as mp
        ctx = mp.get_context("spawn")
        payload = [json.dumps([spec.to_json(), g]) for g in range(k)]
        with ctx.Pool(min(processes, k)) as pool:
            for g, (m, info) in enumerate(pool.imap(_run_tile_json,
                                                    payload)):
                parts[g], infos[g] = m, info
    else:
        for g in range(k):
            parts[g], infos[g] = run_tile(spec, g)
    merged = FleetMetrics.merged(parts, num_edges=spec.topology.num_edges)
    by_kind: Dict[str, int] = {}
    for info in infos:
        for kind, n in info["event_counts"].items():
            by_kind[kind] = by_kind.get(kind, 0) + n
    info = {"shards": k,
            "events_processed": sum(i["events_processed"] for i in infos),
            "event_counts": dict(sorted(by_kind.items())),
            "compactions": sum(i["compactions"] for i in infos),
            "requests": sum(i["requests"] for i in infos),
            "tiles": infos}
    return merged, info


def run_sharded(spec: ScenarioSpec, *,
                processes: Optional[int] = None) -> FleetMetrics:
    """:func:`run_sharded_info` without the info dict — the
    ``Simulation(spec).run()`` equivalent for sharded specs."""
    return run_sharded_info(spec, processes=processes)[0]
