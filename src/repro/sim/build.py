"""Build and run fleet simulations from declarative specs.

The live-object half of ``repro.sim``: :func:`build_stack` turns a
:class:`~repro.sim.spec.PlannerSpec` into the (config, graph, planner[,
model, params]) stack, :class:`Simulation` owns the full wiring — topology,
mobility, handover controller, workload, and ``FleetEngine`` — that the
benchmarks, examples, and fleet test suites previously duplicated by hand.

    spec = get_scenario("smoke-lm")            # or build a ScenarioSpec
    metrics = Simulation(spec).run()           # -> FleetMetrics

``Simulation.build()`` returns the intermediate :class:`Scenario` (every
constructed object by name) for callers that need to drive the engine
directly — e.g. the invariant tests re-run one engine over a subsampled
workload.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import List, Optional, Tuple, Union

from repro.fleet.cluster import FleetTopology, make_fleet
from repro.fleet.engine import FleetEngine
from repro.fleet.metrics import FleetMetrics
from repro.fleet.mobility import (HandoverController, MobilityModel,
                                  make_mobile_fleet)
from repro.fleet.workload import FleetRequest, make_workload
from repro.sim.spec import PlannerSpec, ScenarioSpec, TopologySpec

__all__ = ["Scenario", "Simulation", "build_stack", "build_topology"]


@dataclass
class Scenario:
    """Everything a built spec produced, by name — the replacement for the
    old positional tuples (``smoke_lm_scenario``'s arity changed with its
    flags; this never does).  ``build_stack`` fills the model-stack fields;
    ``Simulation.build`` additionally fills the fleet fields."""
    spec: Optional[ScenarioSpec]
    cfg: object
    graph: object
    planner: object
    model: object = None
    params: object = None
    topo: Optional[FleetTopology] = None
    mobility: Optional[MobilityModel] = None
    handover: Optional[HandoverController] = None
    workload: Optional[List[FleetRequest]] = None
    engine: Optional[FleetEngine] = None


def build_stack(spec: PlannerSpec, *, with_model: bool = False,
                with_params: Optional[bool] = None,
                scenario_spec: Optional[ScenarioSpec] = None) -> Scenario:
    """Build the smoke-scale LM stack a spec's planner describes: config,
    ``InferenceGraph`` (input/result payloads applied), and an
    ``EdgentPlanner`` whose roofline predictors are rescaled to the spec's
    per-tier step times.  ``with_model=True`` additionally constructs the
    executable model; ``with_params`` (default: follows ``with_model``)
    controls whether its parameters are initialized — the expensive half
    (fp32 params, fixed init key — part of the scenario contract, not the
    seed tree).  Prompt-sampling-only scenarios need neither: the vocab
    comes from ``cfg``, so they build with both off and skip model
    construction entirely.

    With ``scenario_spec.calibration`` set, the planner's latency models are
    replaced by regressions fitted from the named measured
    :class:`~repro.calib.CalibrationTable` (``repro.calib.fit`` — see
    docs/calibration.md)."""
    from repro.configs import get_smoke_config
    from repro.core import EdgentPlanner, lm_graph
    from repro.core.latency_model import (RooflineLatencyModel,
                                          ScaledLatencyModel)

    cfg = get_smoke_config(spec.arch)
    graph = lm_graph(cfg, batch=1, seq=1)
    graph.input_bytes = int(spec.input_kb * 1024)
    if spec.result_kb is not None:
        # streaming per-token downlink: decode rounds exercise the wireless
        # link every token, so a degrading serving link hurts in-flight work
        graph.result_bytes = int(spec.result_kb * 1024)
    edge = RooflineLatencyModel(chips=8, efficiency=0.4)
    dev = RooflineLatencyModel(chips=1, efficiency=0.4)
    full = graph.branches[-1]
    k_edge = spec.edge_step_s / sum(edge.predict(l) for l in full)
    k_dev = spec.device_step_s / sum(dev.predict(l) for l in full)
    planner = EdgentPlanner(graph, latency_req_s=spec.latency_req_s)
    planner.with_models(ScaledLatencyModel(edge, k_edge),
                        ScaledLatencyModel(dev, k_dev))
    if scenario_spec is not None and scenario_spec.calibration is not None \
            and scenario_spec.calibration.table:
        from repro.calib.fit import models_from_table
        from repro.calib.table import CalibrationTable
        table = CalibrationTable.load(scenario_spec.calibration.table)
        f_edge, f_dev = models_from_table(
            table, spec, graph=graph,
            anchor=scenario_spec.calibration.anchor)
        planner.with_models(f_edge, f_dev)
    model = params = None
    if with_params is None:
        with_params = with_model
    if with_model:
        import jax
        import jax.numpy as jnp
        from repro.models import Model
        model = Model(cfg)
        if with_params:
            params = model.init_params(jax.random.key(0), dtype=jnp.float32)
    return Scenario(spec=scenario_spec, cfg=cfg, graph=graph,
                    planner=planner, model=model, params=params)


def build_topology(spec: TopologySpec, seed: int
                   ) -> Tuple[FleetTopology, Optional[MobilityModel]]:
    """Sample the fleet a topology spec describes (``(topo, None)`` for
    static fleets, ``(topo, mobility)`` for mobile ones)."""
    if spec.kind == "static":
        topo = make_fleet(
            spec.num_devices, spec.num_edges, seed=seed, trace=spec.trace,
            edge_capacity=spec.edge_capacity, hetero_edges=spec.hetero_edges,
            max_edge_slowdown=spec.max_edge_slowdown,
            device_slowdown_range=spec.device_slowdown_range,
            lo_mbps=spec.lo_mbps, hi_mbps=spec.hi_mbps,
            trace_len=spec.trace_len, edge_bw_mbps=spec.edge_bw_mbps)
        return topo, None
    return make_mobile_fleet(
        spec.num_devices, spec.num_edges, seed=seed, speed=spec.speed,
        horizon_s=spec.horizon_s, area=spec.area,
        edge_capacity=spec.edge_capacity, hetero_edges=spec.hetero_edges,
        max_edge_slowdown=spec.max_edge_slowdown,
        device_slowdown_range=spec.device_slowdown_range,
        peak_mbps=spec.peak_mbps, floor_mbps=spec.floor_mbps,
        d_ref=spec.d_ref, path_exp=spec.path_exp,
        noise_sigma=spec.noise_sigma, noise_dt=spec.noise_dt,
        edge_bw_mbps=spec.edge_bw_mbps)


class Simulation:
    """Declarative façade over the fleet stack: ``Simulation(spec).run()``.

    Accepts a :class:`~repro.sim.spec.ScenarioSpec` or a registered scenario
    name (``repro.sim.registry``).  ``build()`` constructs every live object
    exactly once (idempotent; returns the cached :class:`Scenario`);
    ``run()`` executes the workload and returns
    :class:`~repro.fleet.metrics.FleetMetrics`.  All randomness flows from
    ``spec.seeds()``, so the same spec — including one rebuilt from JSON —
    reproduces bit-identical metrics."""

    def __init__(self, spec: Union[ScenarioSpec, str]):
        if isinstance(spec, str):
            from repro.sim.registry import get_scenario
            spec = get_scenario(spec)
        self.spec = spec
        self.scenario: Optional[Scenario] = None
        self.build_s: Optional[float] = None  # wall time of build(); feeds
        #                                       SimProfiler.build_s

    def build(self) -> Scenario:
        if self.scenario is not None:
            return self.scenario
        if self.spec.topology.shards > 1:
            raise ValueError(
                f"spec {self.spec.name!r} is sharded (topology.shards="
                f"{self.spec.topology.shards}): there is no single live "
                "Scenario to build — Simulation.run() executes the tiles "
                "and merges, or use repro.sim.shard.run_sharded directly")
        import time
        t_build0 = time.perf_counter()
        spec = self.spec
        seeds = spec.seeds()
        sc = build_stack(spec.planner, with_model=spec.engine.real_decode,
                         scenario_spec=spec)
        topo, mobility = build_topology(spec.topology, seeds.topology)
        handover = None
        if spec.mobility is not None and spec.mobility.policy != "none":
            if mobility is None:
                raise ValueError(
                    f"spec {spec.name!r} sets a handover policy "
                    f"({spec.mobility.policy!r}) but its topology is "
                    "static: mobility policies need "
                    "TopologySpec(kind='mobile')")
            m = spec.mobility
            handover = HandoverController(
                mobility, policy=m.policy, sample_dt=m.sample_dt,
                hazard=m.hazard, hysteresis=m.hysteresis,
                min_gap_s=m.min_gap_s)
        vocab = sc.cfg.vocab_size \
            if (spec.workload.sample_prompts or spec.engine.real_decode) else 0
        w = spec.workload
        workload = make_workload(
            topo.num_devices, rate_hz=w.resolve_rate_hz(topo.num_devices),
            horizon_s=w.horizon_s, seed=seeds.workload, arrival=w.arrival,
            tenants=w.tenants, device_skew=w.device_skew,
            peak_factor=w.peak_factor, period_s=w.period_s,
            prompt_len=w.prompt_len, vocab_size=vocab)
        dtype = None
        if spec.engine.dtype is not None:
            import jax.numpy as jnp
            import numpy as np
            dtype = getattr(jnp, spec.engine.dtype, None)
            try:
                if dtype is None:
                    raise TypeError
                np.dtype(dtype)
            except TypeError:
                raise ValueError(
                    f"unknown engine dtype {spec.engine.dtype!r}: expected "
                    "a jax.numpy dtype name such as 'float32' or "
                    "'bfloat16'") from None
        autoscaler = admission = None
        if spec.autoscale is not None or spec.admission is not None:
            from repro.fleet.elastic import build_elasticity
            autoscaler, admission = build_elasticity(
                spec.autoscale, spec.admission, graph=sc.graph,
                planner=sc.planner, latency_req_s=spec.planner.latency_req_s,
                ref_chips=spec.topology.edge_capacity)
        tracer = timeline = None
        if spec.engine.trace is not None:
            from repro.obs.trace import Tracer
            tracer = Tracer()
        if spec.engine.timeline is not None:
            from repro.obs.timeline import Timeline
            timeline = Timeline(topo.num_edges,
                                num_devices=topo.num_devices,
                                dt=spec.engine.timeline_dt)
        engine = FleetEngine(
            topo, sc.graph, sc.planner, router=spec.router.name,
            model=sc.model, params=sc.params, dynamic=spec.engine.dynamic,
            dtype=dtype, demote_on_deadline=spec.engine.demote_on_deadline,
            prefill_div=spec.engine.prefill_div, mobility=mobility,
            handover=handover, replan_max_coop=spec.engine.replan_max_coop,
            max_coop=spec.router.max_coop,
            retain_records=spec.engine.retain_records,
            tracer=tracer, timeline=timeline,
            autoscaler=autoscaler, admission=admission,
            batch_decode=spec.engine.batch_decode,
            shard_decode=spec.engine.shard_decode,
            arena_decode=spec.engine.arena_decode,
            arena_bucket=spec.engine.arena_bucket)
        sc.topo, sc.mobility, sc.handover = topo, mobility, handover
        sc.workload, sc.engine = workload, engine
        self.build_s = time.perf_counter() - t_build0
        self.scenario = sc
        return sc

    def run(self) -> FleetMetrics:
        if self.spec.topology.shards > 1:
            # sharded geography: tiles run (sequentially here; pass
            # processes= to run_sharded for parallelism) and merge on
            # virtual-time keys — bit-identical either way
            from repro.sim.shard import run_sharded
            return run_sharded(self.spec)
        sc = self.build()
        metrics = sc.engine.run(sc.workload)
        # observers are read-only: saving artifacts after the run cannot
        # perturb the metrics above
        if sc.engine.tracer is not None and self.spec.engine.trace:
            sc.engine.tracer.save(self.spec.engine.trace)
        if sc.engine.timeline is not None and self.spec.engine.timeline:
            sc.engine.timeline.to_jsonl(self.spec.engine.timeline)
        return metrics
