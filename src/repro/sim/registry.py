"""Named-scenario registry (mirrors ``fleet.router.make_router``).

Scenarios register a *factory* returning a fresh :class:`ScenarioSpec`, so
callers can mutate what they get (``dataclasses.replace`` or in place)
without corrupting the preset.  Built-ins:

* ``smoke-lm``       — 40-device static fleet, diurnal arrivals, bandwidth-
  aware routing: the ``benchmarks/fleet_scale.py --smoke`` static cell.
* ``coop``           — the same fleet under joint (edge-set, partition,
  exit) planning: the ``--coop --smoke`` comparison cell.
* ``smoke-mobility`` — 40 mobile devices random-waypoint over a 4-edge
  geography, streaming tenants, nearest-edge routing, BOCD handover: the
  ``--mobility --smoke`` cell.
* ``elastic-smoke``   — the smoke-lm fleet shrunk to 4 base slots per edge
  with threshold autoscaling and a reject-at-saturation admission gate: the
  CI elasticity cell (docs/elastic.md).
* ``elastic-diurnal`` — a longer-horizon diurnal workload against elastic
  edges: the base spec the cost-vs-SLO frontier sweeps perturb
  (``repro.sim.sweep --frontier``).
"""
from __future__ import annotations

from typing import Callable, Dict, List

from repro.fleet.workload import TenantClass
from repro.sim.spec import (AdmissionSpec, AutoscaleSpec, MobilitySpec,
                            PlannerSpec, RouterSpec, ScenarioSpec,
                            TopologySpec, WorkloadSpec)

__all__ = ["get_scenario", "list_scenarios", "register_scenario",
           "STREAMING_TENANTS"]

_REGISTRY: Dict[str, Callable[[], ScenarioSpec]] = {}


def register_scenario(name: str, factory: Callable[[], ScenarioSpec], *,
                      overwrite: bool = False):
    """Register ``factory`` under ``name``.  The factory must return a fresh
    spec per call (a zero-arg lambda around a ScenarioSpec literal)."""
    if name in _REGISTRY and not overwrite:
        raise ValueError(f"scenario {name!r} is already registered "
                         "(pass overwrite=True to replace it)")
    _REGISTRY[name] = factory


def get_scenario(name: str) -> ScenarioSpec:
    """Resolve a registered scenario name to a fresh, caller-owned spec."""
    factory = _REGISTRY.get(name)
    if factory is None:
        raise ValueError(f"unknown scenario {name!r}: expected one of "
                         f"{sorted(_REGISTRY)} (register_scenario adds more)")
    return factory()


def list_scenarios() -> List[ScenarioSpec]:
    """Fresh specs for every registered scenario, sorted by name (the CLI's
    ``--list`` view)."""
    return [_REGISTRY[name]() for name in sorted(_REGISTRY)]


# ---------------------------------------------------------------- built-ins

# long-lived streaming requests: decode spans many handover sampling
# intervals, so mobility policies genuinely fire mid-request
STREAMING_TENANTS = (
    TenantClass("interactive", slo_s=1.0, max_new_tokens=32, weight=0.5),
    TenantClass("standard", slo_s=3.0, max_new_tokens=64, weight=0.35),
    TenantClass("batch", slo_s=8.0, max_new_tokens=128, weight=0.15),
)


def _smoke_lm(router: str, name: str, description: str) -> ScenarioSpec:
    return ScenarioSpec(
        name=name, description=description, seed=2,
        topology=TopologySpec(num_devices=40, num_edges=4, edge_capacity=8,
                              lo_mbps=0.1, hi_mbps=6.0,
                              max_edge_slowdown=4.0),
        workload=WorkloadSpec(rate_per_device_hz=1.2, horizon_s=30.0,
                              arrival="diurnal", device_skew=1.0),
        router=RouterSpec(name=router))


register_scenario("smoke-lm", lambda: _smoke_lm(
    "bandwidth-aware", "smoke-lm",
    "40-device static fleet, diurnal arrivals, bandwidth-aware routing "
    "(the fleet_scale --smoke static cell)"))

register_scenario("coop", lambda: _smoke_lm(
    "joint", "coop",
    "smoke-lm under joint (edge-set, partition, exit) planning "
    "(the fleet_scale --coop --smoke cell)"))

register_scenario("smoke-mobility", lambda: ScenarioSpec(
    name="smoke-mobility",
    description="40 mobile devices over a 4-edge geography, streaming "
                "tenants, nearest-edge routing, BOCD handover "
                "(the fleet_scale --mobility --smoke cell)",
    seed=3,
    planner=PlannerSpec(result_kb=4.0),
    topology=TopologySpec(kind="mobile", num_devices=40, num_edges=4,
                          speed=0.25, horizon_s=60.0, floor_mbps=0.1,
                          noise_sigma=0.08),
    workload=WorkloadSpec(rate_per_device_hz=0.2, horizon_s=25.0,
                          device_skew=0.5, tenants=STREAMING_TENANTS),
    router=RouterSpec(name="nearest"),
    mobility=MobilitySpec(policy="bocd")))


def _elastic(name: str, description: str, *, horizon_s: float,
             max_slots: int, peak_factor: float) -> ScenarioSpec:
    # capacity-bound by construction: streaming tenants (decode up to 128
    # tokens, so one slot is held for whole seconds) against a 2-slot base
    # — the diurnal peak genuinely forces the autoscaler's hand, and the
    # admission gate fires whenever provisioned capacity lags the ramp
    return ScenarioSpec(
        name=name, description=description, seed=2,
        topology=TopologySpec(num_devices=40, num_edges=4, edge_capacity=2,
                              lo_mbps=0.1, hi_mbps=6.0,
                              max_edge_slowdown=4.0),
        workload=WorkloadSpec(rate_per_device_hz=2.0, horizon_s=horizon_s,
                              arrival="diurnal", device_skew=1.0,
                              peak_factor=peak_factor,
                              tenants=STREAMING_TENANTS),
        router=RouterSpec(name="bandwidth-aware"),
        autoscale=AutoscaleSpec(min_slots=1, max_slots=max_slots,
                                decide_dt=0.5, up_backlog_s=0.5,
                                down_util=0.25, cooldown_s=1.0),
        admission=AdmissionSpec(policy="reject", max_queue=2))


register_scenario("elastic-smoke", lambda: _elastic(
    "elastic-smoke",
    "smoke-lm fleet on 4-slot elastic edges: threshold autoscaling plus a "
    "reject-at-saturation admission gate (the CI elasticity cell)",
    horizon_s=30.0, max_slots=8, peak_factor=2.0))

register_scenario("elastic-diurnal", lambda: _elastic(
    "elastic-diurnal",
    "longer diurnal workload against elastic edges — the base spec the "
    "cost-vs-SLO frontier sweeps perturb (repro.sim.sweep --frontier)",
    horizon_s=60.0, max_slots=12, peak_factor=3.0))
