"""Declarative scenario specs: the plain-data half of ``repro.sim``.

A :class:`ScenarioSpec` is a small tree of dataclasses — topology, workload,
planner, router, engine, and (optionally) mobility — that fully determines
one fleet simulation.  Specs are plain data: they hold numbers, strings, and
tenant tuples, never live objects, so they round-trip through
``to_dict()`` / ``from_dict()`` / JSON (``to_json()`` / ``from_json()``) and
a parameter sweep is just a spec edit (``dataclasses.replace`` or the CLI's
``--set key=value``).  Building live objects from a spec is ``repro.sim
.build``'s job; named presets live in ``repro.sim.registry``.

Seeding is centralized: every stochastic input derives from the single
``ScenarioSpec.seed`` through :meth:`ScenarioSpec.seeds` (topology/
trajectory sampling uses ``seed``, the arrival process ``seed + 1``),
replacing the ad-hoc ``seed`` / ``seed+1`` / hardcoded-constant drift the
old hand-wired call sites had.  Same spec, same metrics — bit-identical
(asserted by tests/test_sim.py and the invariant suite).
"""
from __future__ import annotations

import dataclasses
import json
from dataclasses import dataclass, field
from typing import Dict, Optional, Tuple

from repro.fleet.mobility import HandoverController
from repro.fleet.router import ROUTER_ALIASES
from repro.fleet.workload import DEFAULT_TENANTS, TenantClass

__all__ = [
    "AdmissionSpec", "AutoscaleSpec", "CalibrationSpec", "DerivedSeeds",
    "EngineSpec", "MobilitySpec", "PlannerSpec", "RouterSpec",
    "ScenarioSpec", "TopologySpec", "WorkloadSpec", "apply_overrides",
]


@dataclass(frozen=True)
class DerivedSeeds:
    """Per-subsystem seeds derived from one root seed (`ScenarioSpec.seeds`).

    ``topology`` drives every sample taken at fleet-construction time:
    bandwidth traces, device slowdowns, and — for mobile fleets —
    trajectories and the bandwidth-noise grid.  ``workload`` drives the
    arrival process, tenant draws, and prompt tokens."""
    topology: int
    workload: int


def _check_fields(cls, d: Dict):
    names = {f.name for f in dataclasses.fields(cls)}
    unknown = set(d) - names
    if unknown:
        raise ValueError(
            f"unknown {cls.__name__} field(s) {sorted(unknown)}: "
            f"expected a subset of {sorted(names)}")


def _jsonify(x):
    """Tuples -> lists, recursively: ``to_dict`` output is JSON-canonical,
    so ``spec.to_dict() == json.loads(json.dumps(spec.to_dict()))`` and
    dict/JSON round-trips compare equal (``__post_init__`` re-tuples on the
    way back in)."""
    if isinstance(x, (list, tuple)):
        return [_jsonify(v) for v in x]
    if isinstance(x, dict):
        return {k: _jsonify(v) for k, v in x.items()}
    return x


class _Spec:
    """Shared plain-data behavior: dict round-trip with strict field
    checking.  Subclasses override the hooks for non-scalar fields."""

    def to_dict(self) -> Dict:
        return _jsonify(dataclasses.asdict(self))

    @classmethod
    def from_dict(cls, d: Dict) -> "_Spec":
        _check_fields(cls, d)
        return cls(**d)


@dataclass
class TopologySpec(_Spec):
    """Where requests run: N devices x M edges, static traces or a mobile
    geography.  ``kind='static'`` builds via ``fleet.cluster.make_fleet``
    (the trace/``*_mbps`` fields apply); ``kind='mobile'`` via
    ``fleet.mobility.make_mobile_fleet`` (the speed/area/path-loss fields
    apply).  Field defaults mirror those builders exactly."""
    kind: str = "static"                 # "static" | "mobile"
    num_devices: int = 40
    num_edges: int = 4
    # geography sharding (repro.sim.shard, docs/performance.md): > 1 splits
    # the fleet into `shards` disjoint tiles (num_devices/num_edges must
    # divide evenly), each an independent geography simulated by its own
    # event loop — in parallel worker processes or sequentially in one —
    # and merged into fleet-global metrics on virtual-time keys.  The spec
    # *defines* the tiling, so sharded and unsharded executions of the same
    # spec are bit-identical.
    shards: int = 1
    edge_capacity: int = 8
    hetero_edges: bool = True
    max_edge_slowdown: float = 3.0
    device_slowdown_range: Tuple[float, float] = (0.8, 2.5)
    edge_bw_mbps: float = 400.0          # edge<->edge backbone
    # --- static fleets (kind="static") ---
    trace: str = "oboe"                  # "oboe" | "lte"
    lo_mbps: float = 0.3
    hi_mbps: float = 6.0
    trace_len: int = 600
    # --- mobile fleets (kind="mobile") ---
    speed: float = 0.1                   # area units / s (jittered per device)
    horizon_s: float = 60.0              # trajectory + noise-grid horizon
    area: float = 1.0
    peak_mbps: float = 6.0
    floor_mbps: float = 0.05
    d_ref: float = 0.25
    path_exp: float = 3.0
    noise_sigma: float = 0.1
    noise_dt: float = 0.5

    def __post_init__(self):
        if self.kind not in ("static", "mobile"):
            raise ValueError(f"unknown topology kind {self.kind!r}: "
                             "expected 'static' or 'mobile'")
        if self.shards < 1:
            raise ValueError(f"shards must be >= 1, got {self.shards}")
        if self.shards > 1 and (self.num_devices % self.shards
                                or self.num_edges % self.shards):
            raise ValueError(
                f"shards={self.shards} must divide num_devices="
                f"{self.num_devices} and num_edges={self.num_edges} evenly")
        self.device_slowdown_range = tuple(self.device_slowdown_range)


@dataclass
class WorkloadSpec(_Spec):
    """The request stream: arrival process, device skew, tenant mix.
    Exactly one of ``rate_hz`` (fleet-wide) or ``rate_per_device_hz``
    (scales with ``TopologySpec.num_devices``) must be set."""
    rate_hz: Optional[float] = None
    rate_per_device_hz: Optional[float] = None
    horizon_s: float = 30.0
    arrival: str = "poisson"             # "poisson" | "diurnal"
    device_skew: float = 0.0
    peak_factor: float = 4.0             # diurnal peak/base ratio
    period_s: Optional[float] = None     # diurnal period (None = horizon)
    prompt_len: int = 8
    tenants: Tuple[TenantClass, ...] = DEFAULT_TENANTS
    sample_prompts: bool = False         # draw real token prompts (needs the
    #                                      model config's vocab; implied by
    #                                      EngineSpec.real_decode)

    def __post_init__(self):
        self.tenants = tuple(
            TenantClass(**t) if isinstance(t, dict) else t
            for t in self.tenants)

    def resolve_rate_hz(self, num_devices: int) -> float:
        if (self.rate_hz is None) == (self.rate_per_device_hz is None):
            raise ValueError(
                "WorkloadSpec needs exactly one of rate_hz / "
                f"rate_per_device_hz, got rate_hz={self.rate_hz!r} "
                f"rate_per_device_hz={self.rate_per_device_hz!r}")
        if self.rate_hz is not None:
            return self.rate_hz
        return self.rate_per_device_hz * num_devices


@dataclass
class MobilitySpec(_Spec):
    """When in-flight work re-plans as devices move: the handover policy and
    its trigger parameters (``fleet.mobility.HandoverController``).
    Requires ``TopologySpec(kind='mobile')``; ``policy='none'`` keeps the
    mobile fleet but never migrates (the baseline in the benchmarks)."""
    policy: str = "none"                 # "none" | "oracle" | "bocd"
    sample_dt: float = 0.5               # bandwidth sampling grid (virtual s)
    hazard: float = 1 / 20.0             # BOCD change-point hazard
    hysteresis: float = 0.05             # oracle nearer-edge margin
    min_gap_s: float = 1.0               # per-device refire rate limit

    def __post_init__(self):
        if self.policy not in HandoverController.POLICIES:
            raise ValueError(
                f"unknown handover policy {self.policy!r}: expected one of "
                f"{', '.join(HandoverController.POLICIES)}")


@dataclass
class AutoscaleSpec(_Spec):
    """Elastic per-edge capacity (``fleet.elastic.Autoscaler``, docs/
    elastic.md): a threshold policy run on the engine's ``scale`` event
    grid every ``decide_dt`` virtual seconds.  Capacity starts at
    ``TopologySpec.edge_capacity``, scales up by ``step`` slots when an
    edge's backlog exceeds ``up_backlog_s`` seconds, and drains down by
    ``step`` when its queue is empty and the batch fills at most
    ``down_util`` of the provisioned slots, always within
    [``min_slots``, ``max_slots``].  Provisioned slots cost
    ``usd_per_slot_hour`` — the ``cost_usd`` axis of the frontier sweeps.
    ``replan_on_shrink`` re-prices queued requests' plans through
    ``runtime.elastic.ElasticPlanner`` after a scale-down."""
    min_slots: int = 1
    max_slots: int = 16
    decide_dt: float = 1.0
    up_backlog_s: float = 1.0
    down_util: float = 0.25
    step: int = 1
    cooldown_s: float = 0.0
    usd_per_slot_hour: float = 1.0
    replan_on_shrink: bool = True

    def __post_init__(self):
        # mirrors fleet.elastic.Autoscaler validation so a bad spec fails
        # at parse time, not mid-build
        if self.min_slots < 1:
            raise ValueError(f"min_slots must be >= 1, got {self.min_slots}")
        if self.max_slots < self.min_slots:
            raise ValueError(
                f"max_slots ({self.max_slots}) must be >= min_slots "
                f"({self.min_slots})")
        if self.decide_dt <= 0:
            raise ValueError(
                f"decide_dt must be positive, got {self.decide_dt}")
        if self.step < 1:
            raise ValueError(f"step must be >= 1, got {self.step}")


@dataclass
class AdmissionSpec(_Spec):
    """Per-cell admission control (``fleet.elastic.AdmissionControl``): an
    edge is saturated once queued + batched requests reach
    ``capacity + max_queue``; saturated arrivals are shed — rejected
    outright (``policy='reject'``, counted in ``summary()['rejected']``) or
    degraded to device-only execution (``policy='local'``)."""
    policy: str = "reject"               # "reject" | "local"
    max_queue: int = 0

    def __post_init__(self):
        if self.policy not in ("reject", "local"):
            raise ValueError(
                f"unknown admission policy {self.policy!r}: expected "
                "'reject' or 'local'")
        if self.max_queue < 0:
            raise ValueError(f"max_queue must be >= 0, got {self.max_queue}")


@dataclass
class PlannerSpec(_Spec):
    """The model stack the Edgent planner optimizes over: a smoke-scale LM
    graph with roofline predictors rescaled so one device-only decode step
    costs ``device_step_s`` and one edge step ``edge_step_s`` (the paper's
    Fig. 2 tier asymmetry at per-token granularity).  ``input_kb`` is the
    offloaded prompt payload (multimodal-style image features);
    ``result_kb``, when set, adds a per-token downlink so streaming
    requests stay bandwidth-bound for their whole decode (the mobility
    scenarios rely on this)."""
    arch: str = "llama3.2-1b"
    latency_req_s: float = 0.5
    input_kb: float = 24.0
    device_step_s: float = 0.06
    edge_step_s: float = 0.004
    result_kb: Optional[float] = None


@dataclass
class RouterSpec(_Spec):
    """Which edge (or edge set) serves each arrival: a name from the
    ``fleet.router.make_router`` registry plus the joint-planner fan-out
    bound (``max_coop``, only consulted by ``router='joint'``)."""
    name: str = "round-robin"
    max_coop: int = 3

    def __post_init__(self):
        if self.name not in ROUTER_ALIASES:
            raise ValueError(
                f"unknown router {self.name!r}: expected one of "
                f"{sorted(ROUTER_ALIASES)}")


@dataclass
class EngineSpec(_Spec):
    """FleetEngine knobs: timing-only simulation by default;
    ``real_decode=True`` also runs the actual model (B=1 caches, jitted
    per-exit variants) — ``dtype`` then names the cache dtype (e.g.
    ``'float32'``, ``'bfloat16'``).  ``retain_records=False`` keeps
    FleetMetrics to its running aggregates (identical summaries, no
    per-request record/handover-log retention) — the 10k-device / sweep
    setting (docs/performance.md).

    Observability (docs/observability.md): ``trace`` writes a
    Chrome/Perfetto trace-event JSON of every request's lifecycle spans to
    that path after the run; ``timeline`` writes the columnar per-edge
    gauge timeline as JSONL, sampled every ``timeline_dt`` virtual
    seconds.  Both are read-only observers — summaries stay bit-identical
    with them on or off."""
    real_decode: bool = False
    dtype: Optional[str] = None
    dynamic: bool = False
    demote_on_deadline: bool = True
    prefill_div: int = 8
    replan_max_coop: int = 1
    retain_records: bool = True
    trace: Optional[str] = None
    timeline: Optional[str] = None
    timeline_dt: float = 0.5
    # real-decode execution strategy (docs/calibration.md): batch_decode
    # runs each round's co-located requests as vmapped groups (one compiled
    # call per exit x cache-geometry group); shard_decode additionally
    # shard_maps groups over the host device mesh when one exists.  Token
    # values and virtual timing are identical either way — these are
    # host-throughput knobs only.
    batch_decode: bool = True
    shard_decode: bool = False
    # slot-resident decode arena (docs/performance.md): arena_decode keeps
    # each edge's KV state resident in a persistent [slots, ...] stack and
    # decodes a round in at most one masked compiled call per model exit —
    # no per-token restacking, no pad-by-replication.  arena_bucket sets
    # the arena-length policy ('pow2' rounds the shared cache length up to
    # a power of two, 'exact' keeps the workload maximum).  Token values
    # and virtual timing are identical either way; off (the default) keeps
    # runs byte-identical to pre-arena goldens.
    arena_decode: bool = False
    arena_bucket: str = "pow2"

    def __post_init__(self):
        if self.arena_bucket not in ("pow2", "exact"):
            raise ValueError(
                f"unknown arena_bucket {self.arena_bucket!r}: expected "
                "'pow2' or 'exact'")


@dataclass
class CalibrationSpec(_Spec):
    """Run the scenario's planner on *measured* per-layer latency models
    instead of the analytic rooflines (docs/calibration.md).

    ``table`` names a :class:`repro.calib.CalibrationTable` JSON produced by
    ``python -m repro.calib measure``; at build time ``repro.calib.fit``
    fits the paper-style per-layer-type regressions from it and swaps them
    into the planner.  ``anchor=True`` (default) rescales the fitted models
    so a full-branch decode step still costs the spec's
    ``edge_step_s`` / ``device_step_s`` — calibration then changes the
    *shape* of the cost surface (where cuts and exits land), not the
    simulated hardware speed; ``anchor=False`` uses raw measured seconds."""
    table: Optional[str] = None
    anchor: bool = True


@dataclass
class ScenarioSpec(_Spec):
    """One complete, serializable experiment: every knob of a fleet
    simulation in plain data.  ``Simulation(spec).run()`` executes it;
    ``spec.to_json()`` / ``ScenarioSpec.from_json()`` round-trip it
    losslessly (bit-identical metrics — tests/test_sim.py)."""
    name: str = "custom"
    description: str = ""
    seed: int = 0
    planner: PlannerSpec = field(default_factory=PlannerSpec)
    topology: TopologySpec = field(default_factory=TopologySpec)
    workload: WorkloadSpec = field(default_factory=WorkloadSpec)
    router: RouterSpec = field(default_factory=RouterSpec)
    engine: EngineSpec = field(default_factory=EngineSpec)
    mobility: Optional[MobilitySpec] = None
    # elasticity (docs/elastic.md): both default to None — the spec-level
    # off switch that keeps summaries bit-identical to pre-elastic runs
    autoscale: Optional[AutoscaleSpec] = None
    admission: Optional[AdmissionSpec] = None
    # calibration (docs/calibration.md): None runs the analytic latency
    # models — the pre-calibration behavior, byte-identical summaries
    calibration: Optional[CalibrationSpec] = None

    _NESTED = {"planner": PlannerSpec, "topology": TopologySpec,
               "workload": WorkloadSpec, "router": RouterSpec,
               "engine": EngineSpec, "mobility": MobilitySpec,
               "autoscale": AutoscaleSpec, "admission": AdmissionSpec,
               "calibration": CalibrationSpec}

    def seeds(self) -> DerivedSeeds:
        """The one place per-subsystem seeds come from (see module
        docstring): fleet sampling at ``seed``, arrivals at ``seed + 1``."""
        return DerivedSeeds(topology=self.seed, workload=self.seed + 1)

    @classmethod
    def from_dict(cls, d: Dict) -> "ScenarioSpec":
        _check_fields(cls, d)
        kw = dict(d)
        for key, sub_cls in cls._NESTED.items():
            if isinstance(kw.get(key), dict):
                kw[key] = sub_cls.from_dict(kw[key])
        return cls(**kw)

    def to_json(self, indent: int = 2) -> str:
        return json.dumps(self.to_dict(), indent=indent, sort_keys=True)

    @classmethod
    def from_json(cls, s: str) -> "ScenarioSpec":
        return cls.from_dict(json.loads(s))


# `_NESTED` must not look like a dataclass field (no annotation above) —
# assert that so a future edit cannot silently turn it into one.
assert "_NESTED" not in {f.name for f in dataclasses.fields(ScenarioSpec)}


def apply_overrides(spec: ScenarioSpec,
                    assignments: Dict[str, object]) -> ScenarioSpec:
    """Return a new spec with dotted-path overrides applied, e.g.
    ``{"topology.num_devices": 100, "router.name": "joint"}`` — the engine
    behind the CLI's ``--set``.  Overriding into an unset optional section
    (``mobility``, ``autoscale``, ``admission``) materializes that
    section's default spec first, so ``--set autoscale.max_slots=8`` both
    enables autoscaling and tunes it.  Unknown paths raise ``ValueError``
    (the same strict check as ``from_dict``)."""
    d = spec.to_dict()
    for path, value in assignments.items():
        parts = path.split(".")
        cur = d
        for i, p in enumerate(parts[:-1]):
            if p not in cur:
                raise ValueError(f"unknown spec path {path!r} "
                                 f"(no field {p!r})")
            if cur[p] is None and p in ScenarioSpec._NESTED:
                cur[p] = ScenarioSpec._NESTED[p]().to_dict()
            if not isinstance(cur[p], dict):
                raise ValueError(f"spec path {path!r} descends into "
                                 f"non-spec field {p!r}")
            cur = cur[p]
        leaf = parts[-1]
        if leaf not in cur:
            raise ValueError(f"unknown spec path {path!r} "
                             f"(no field {leaf!r})")
        cur[leaf] = value
    return ScenarioSpec.from_dict(d)
