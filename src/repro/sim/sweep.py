"""Parameter sweeps over declarative scenarios (docs/api.md).

The ROADMAP's sweep runner: a *sweep* is a base :class:`ScenarioSpec` plus a
set of dotted-path axes, expanded into cells (grid = cartesian product,
random = independent draws), executed in parallel across worker processes,
and written as one JSONL file of ``{"spec": ..., "metrics": ...}`` rows —
replacing the hand-rolled per-benchmark loops ``benchmarks/fleet_scale.py``
used to carry.

    from repro.sim.sweep import grid_cells, run_sweep
    cells = grid_cells(get_scenario("smoke-lm"),
                       {"topology.num_devices": [100, 200, 400],
                        "router.name": ["jsq", "bandwidth-aware"]})
    rows = run_sweep(cells, out_path="sweep.jsonl", processes=4)

Every cell is an independent, fully-specified spec, so results are
reproducible row by row (``python -m repro.sim --spec`` on the embedded
spec re-runs any cell) and cell order never affects metrics.  From the
shell:

    python -m repro.sim.sweep --scenario smoke-lm \\
        --grid topology.num_devices=[100,200] --grid router.name='["jsq"]' \\
        --out sweep.jsonl --processes 2
"""
from __future__ import annotations

import argparse
import itertools
import json
import sys
from typing import Dict, Iterable, List, Optional, Sequence

import numpy as np

from repro.sim.registry import get_scenario
from repro.sim.spec import ScenarioSpec, apply_overrides

__all__ = ["grid_cells", "pareto_frontier", "random_cells", "run_cell",
           "run_sweep", "main"]


def grid_cells(base: ScenarioSpec,
               axes: Dict[str, Sequence]) -> List[ScenarioSpec]:
    """Cartesian product of dotted-path axes over ``base`` — one fresh spec
    per combination, in row-major order of the axes dict (later axes vary
    fastest).  Axis paths take anything ``apply_overrides`` accepts,
    including ``seed``."""
    names = list(axes)
    cells = []
    for combo in itertools.product(*(axes[n] for n in names)):
        cells.append(apply_overrides(base, dict(zip(names, combo))))
    return cells


def random_cells(base: ScenarioSpec, axes: Dict[str, Sequence], n: int, *,
                 seed: int = 0) -> List[ScenarioSpec]:
    """``n`` independent draws: each cell picks one value per axis uniformly
    (deterministic in ``seed``) — random search over the same axis space a
    grid would enumerate."""
    rng = np.random.default_rng(seed)
    names = list(axes)
    cells = []
    for _ in range(n):
        combo = {name: axes[name][int(rng.integers(len(axes[name])))]
                 for name in names}
        cells.append(apply_overrides(base, combo))
    return cells


def run_cell(spec: ScenarioSpec) -> Dict:
    """Execute one cell; the JSONL row dict (``wall_s`` and ``events`` are
    measurement metadata — ``metrics`` is a pure function of ``spec``).
    ``engine.trace`` / ``engine.timeline`` are ordinary spec paths, so a
    sweep axis (or ``--set``) can attach the ``repro.obs`` observers to any
    cell without changing its metrics.  Module-level so worker processes
    can unpickle it."""
    import time

    from repro.sim.build import Simulation
    t0 = time.perf_counter()
    if spec.topology.shards > 1:
        # sharded cell: tiles run sequentially inside this worker (the
        # sweep already owns the process-level parallelism)
        from repro.sim.shard import run_sharded_info
        metrics, info = run_sharded_info(spec)
        return {"spec": spec.to_dict(), "metrics": metrics.summary(),
                "events": {"processed": info["events_processed"],
                           "by_kind": info["event_counts"]},
                "wall_s": round(time.perf_counter() - t0, 3)}
    sim = Simulation(spec)
    metrics = sim.run().summary()
    engine = sim.scenario.engine
    row = {"spec": spec.to_dict(), "metrics": metrics,
           "events": {"processed": engine.events_processed,
                      "by_kind": dict(sorted(engine.event_counts.items()))},
           "wall_s": round(time.perf_counter() - t0, 3)}
    if spec.engine.real_decode:
        # decode-efficiency columns (docs/performance.md): deterministic
        # token/call counters from the stepper, so parallel and inline
        # sweeps still produce identical rows (only wall_s is stripped by
        # the equivalence pin in tests/test_sweep.py)
        st = engine.stepper.cache_stats()
        dec, ar, jit = st["decode"], st["arena"], st["jit"]
        waste_den = dec["batched_tokens"] + dec["padded_rows"]
        row["decode"] = {
            "batched_calls": dec["batched_calls"],
            "batched_max": dec["batched_max"],
            "padded_rows": dec["padded_rows"],
            "pad_waste": round(dec["padded_rows"] / waste_den, 4)
            if waste_den else 0.0,
            "serial_tokens": dec["serial_tokens"],
            "jit_hit_rate": jit["hit_rate"],
            "jit_variants": jit["variants"],
            "arena_calls": ar["calls"],
            "arena_tokens": ar["tokens"],
            "arena_occupancy": ar["occupancy"],
        }
    return row


def _run_cell_json(spec_json: str) -> Dict:
    return run_cell(ScenarioSpec.from_json(spec_json))


def run_sweep(cells: Iterable[ScenarioSpec], *,
              out_path: Optional[str] = None,
              processes: Optional[int] = None,
              progress: bool = False) -> List[Dict]:
    """Run every cell and return its rows in cell order (the order is
    presentation only — each cell is deterministic in its own spec).

    ``processes`` > 1 fans cells out over a multiprocessing pool (specs
    travel as JSON, so workers rebuild them with the same strict
    validation); ``None`` or 1 runs inline.  ``out_path`` additionally
    streams rows to a JSONL file as they arrive."""
    cells = list(cells)
    rows: List[Optional[Dict]] = [None] * len(cells)
    out = open(out_path, "w") if out_path else None

    def emit(i: int, row: Dict):
        rows[i] = row
        if out is not None:
            out.write(json.dumps(row, sort_keys=True, default=float) + "\n")
            out.flush()
        if progress:
            print(f"[{sum(r is not None for r in rows)}/{len(cells)}] "
                  f"{cells[i].name}: slo="
                  f"{row['metrics'].get('slo_attainment', 0.0):.4f}",
                  file=sys.stderr)

    try:
        if processes is not None and processes > 1 and len(cells) > 1:
            import multiprocessing as mp
            ctx = mp.get_context("spawn")  # no fork: jax/BLAS state unsafe
            with ctx.Pool(processes) as pool:
                payload = [c.to_json() for c in cells]
                for i, row in enumerate(pool.imap(_run_cell_json, payload)):
                    emit(i, row)
        else:
            for i, cell in enumerate(cells):
                emit(i, run_cell(cell))
    finally:
        if out is not None:
            out.close()
    return rows  # type: ignore[return-value]


def pareto_frontier(rows: Sequence[Dict], *, x: str = "cost_usd",
                    y: str = "slo_attainment") -> List[Dict]:
    """Non-dominated sweep rows on (minimize ``metrics[x]``, maximize
    ``metrics[y]``), sorted by ``x`` ascending — the cost-vs-SLO frontier of
    an elastic sweep (docs/elastic.md).  A row survives iff no other row is
    at least as good on both axes and strictly better on one; rows missing
    either metric (e.g. cells run without elasticity, so no ``cost_usd``)
    are excluded.  Exact ties on both axes all survive, so the result is
    deterministic in the row set, not the row order."""
    pts = [r for r in rows
           if r is not None and r["metrics"].get(x) is not None
           and r["metrics"].get(y) is not None]
    front = []
    for r in pts:
        rx, ry = r["metrics"][x], r["metrics"][y]
        dominated = any(
            (o["metrics"][x] <= rx and o["metrics"][y] >= ry)
            and (o["metrics"][x] < rx or o["metrics"][y] > ry)
            for o in pts)
        if not dominated:
            front.append(r)
    return sorted(front, key=lambda r: (r["metrics"][x], -r["metrics"][y]))


def _parse_axis(pair: str) -> tuple:
    if "=" not in pair:
        raise ValueError(f"--grid expects PATH=JSON_LIST, got {pair!r}")
    path, _, raw = pair.partition("=")
    try:
        values = json.loads(raw)
    except json.JSONDecodeError as e:
        raise ValueError(
            f"--grid {path}: value must be a JSON list, got {raw!r}") from e
    if not isinstance(values, list) or not values:
        raise ValueError(f"--grid {path}: need a non-empty JSON list")
    return path, values


def main(argv: Optional[List[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.sim.sweep",
        description="Grid/random sweeps over declarative fleet scenarios.")
    ap.add_argument("--scenario", metavar="NAME",
                    help="registered base scenario (see repro.sim --list)")
    ap.add_argument("--spec", metavar="FILE",
                    help="base ScenarioSpec JSON file")
    ap.add_argument("--set", dest="overrides", action="append", default=[],
                    metavar="KEY=VALUE",
                    help="fixed override applied to the base spec first")
    ap.add_argument("--grid", dest="grid", action="append", default=[],
                    metavar="PATH=JSON_LIST",
                    help="sweep axis, e.g. topology.num_devices=[100,400]")
    ap.add_argument("--random", type=int, default=0, metavar="N",
                    help="draw N random cells from the axes instead of the "
                         "full grid")
    ap.add_argument("--sweep-seed", type=int, default=0,
                    help="rng seed for --random cell draws")
    ap.add_argument("--out", metavar="FILE", required=True,
                    help="JSONL output path ({spec, metrics} per row)")
    ap.add_argument("--processes", type=int, default=1,
                    help="worker processes across cells (1 = inline)")
    ap.add_argument("--frontier", metavar="FILE",
                    help="additionally write the cost-vs-SLO Pareto "
                         "frontier (non-dominated rows on cost_usd vs "
                         "slo_attainment) as JSONL")
    args = ap.parse_args(argv)

    if (args.scenario is None) == (args.spec is None):
        raise ValueError("pass exactly one of --scenario NAME or --spec FILE")
    if args.spec is not None:
        with open(args.spec) as f:
            base = ScenarioSpec.from_json(f.read())
    else:
        base = get_scenario(args.scenario)
    if args.overrides:
        from repro.sim.cli import _parse_overrides
        base = apply_overrides(base, _parse_overrides(args.overrides))
    axes = dict(_parse_axis(p) for p in args.grid)
    if not axes:
        raise ValueError("pass at least one --grid PATH=JSON_LIST axis")
    cells = random_cells(base, axes, args.random, seed=args.sweep_seed) \
        if args.random else grid_cells(base, axes)
    rows = run_sweep(cells, out_path=args.out, processes=args.processes,
                     progress=True)
    print(f"{len(rows)} cells -> {args.out}")
    if args.frontier:
        front = pareto_frontier(rows)
        with open(args.frontier, "w") as f:
            for row in front:
                f.write(json.dumps(row, sort_keys=True, default=float)
                        + "\n")
        for row in front:
            m = row["metrics"]
            print(f"  frontier: cost_usd={m['cost_usd']:.4f} "
                  f"slo={m['slo_attainment']:.4f} "
                  f"reject_rate={m.get('reject_rate', 0.0):.4f}")
        print(f"{len(front)} non-dominated cells -> {args.frontier}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
