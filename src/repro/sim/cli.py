"""``python -m repro.sim`` — run declarative scenarios from the shell.

    python -m repro.sim --list
    python -m repro.sim --scenario smoke-lm
    python -m repro.sim --scenario smoke-mobility --json
    python -m repro.sim --scenario smoke-lm --set router.name=joint \\
                        --set topology.num_devices=100
    python -m repro.sim --spec my_scenario.json --json
    python -m repro.sim --scenario smoke-mobility --trace trace.json

``--set key=value`` takes dotted spec paths (values parsed as JSON, falling
back to bare strings), so a sweep is a shell loop over spec edits — no
bespoke argparse per experiment.  ``--json`` emits ``{scenario, spec,
metrics, events}`` on stdout for CI artifacts and downstream tooling; the
default output is a human-readable metrics listing.  ``--trace`` /
``--timeline`` attach the ``repro.obs`` observers and write their artifacts
after the run (summaries are bit-identical either way —
docs/observability.md).
"""
from __future__ import annotations

import argparse
import json
from typing import Dict, List, Optional

from repro.sim.build import Simulation
from repro.sim.registry import get_scenario, list_scenarios
from repro.sim.spec import ScenarioSpec, apply_overrides

__all__ = ["main"]


def _parse_overrides(pairs: List[str]) -> Dict[str, object]:
    out: Dict[str, object] = {}
    for pair in pairs:
        if "=" not in pair:
            raise ValueError(f"--set expects key=value, got {pair!r}")
        key, _, raw = pair.partition("=")
        try:
            out[key] = json.loads(raw)
        except json.JSONDecodeError:
            out[key] = raw              # bare string (e.g. router names)
    return out


def _resolve_spec(args) -> ScenarioSpec:
    if (args.scenario is None) == (args.spec is None):
        raise ValueError("pass exactly one of --scenario NAME or "
                         "--spec FILE (--list shows the registry)")
    if args.spec is not None:
        with open(args.spec) as f:
            return ScenarioSpec.from_json(f.read())
    return get_scenario(args.scenario)


def main(argv: Optional[List[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.sim",
        description="Run a declarative fleet scenario (docs/api.md).",
        epilog="For grid/random sweeps over scenarios (parallel cells, "
               "JSONL output) use `python -m repro.sim.sweep`.")
    ap.add_argument("--scenario", metavar="NAME",
                    help="registered scenario name (see --list)")
    ap.add_argument("--spec", metavar="FILE",
                    help="path to a ScenarioSpec JSON file")
    ap.add_argument("--set", dest="overrides", action="append", default=[],
                    metavar="KEY=VALUE",
                    help="dotted spec override, e.g. topology.num_devices=100"
                         " (repeatable)")
    ap.add_argument("--list", action="store_true",
                    help="list registered scenarios and exit")
    ap.add_argument("--json", action="store_true",
                    help="emit {scenario, spec, metrics, events} as JSON")
    ap.add_argument("--trace", metavar="FILE",
                    help="write a Chrome/Perfetto trace-event JSON of the "
                         "run (view: ui.perfetto.dev, or `python -m "
                         "repro.obs report FILE`)")
    ap.add_argument("--timeline", metavar="FILE",
                    help="write the per-edge gauge timeline as JSONL "
                         "(render: `python -m repro.obs report FILE`)")
    args = ap.parse_args(argv)

    if args.list:
        for spec in list_scenarios():
            print(f"{spec.name:>16}  {spec.description}")
        return 0

    spec = _resolve_spec(args)
    overrides = _parse_overrides(args.overrides)
    if args.trace:
        overrides["engine.trace"] = args.trace
    if args.timeline:
        overrides["engine.timeline"] = args.timeline
    if overrides:
        spec = apply_overrides(spec, overrides)

    # events_processed lives OUTSIDE summary(): observers add "obs" events,
    # so it may differ observers-on vs off while summaries stay identical
    if spec.topology.shards > 1:
        # sharded geography: tiles run and merge (no single live engine);
        # the merged info dict carries the fleet-wide event counts
        from repro.sim.shard import run_sharded_info
        m, info = run_sharded_info(spec)
        metrics = m.summary()
        events = {"processed": info["events_processed"],
                  "by_kind": info["event_counts"]}
    else:
        sim = Simulation(spec)
        metrics = sim.run().summary()
        engine = sim.scenario.engine
        events = {"processed": engine.events_processed,
                  "by_kind": dict(sorted(engine.event_counts.items()))}
    if args.json:
        print(json.dumps({"scenario": spec.name, "spec": spec.to_dict(),
                          "metrics": metrics, "events": events},
                         indent=2, default=float))
        return 0
    topo = spec.topology
    print(f"scenario {spec.name!r}: {topo.num_devices} devices x "
          f"{topo.num_edges} edges ({topo.kind}), router={spec.router.name}, "
          f"seed={spec.seed}")
    for key, value in metrics.items():
        print(f"  {key:>20}: {value}")
    kinds = ", ".join(f"{k}={v}" for k, v in sorted(events["by_kind"].items()))
    print(f"  {'events':>20}: {events['processed']} ({kinds})")
    if args.trace:
        print(f"  {'trace':>20}: {args.trace}")
    if args.timeline:
        print(f"  {'timeline':>20}: {args.timeline}")
    return 0
