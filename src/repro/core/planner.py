"""Edgent planner facade — offline configuration + online tuning in one
object (paper Fig. 5 workflow).

``EdgentPlanner.offline_static``  : profile -> fit regressions -> static cfg
``EdgentPlanner.offline_dynamic`` : sketch states -> build config map
``planner.plan(bandwidth)``       : online tuning (Algorithm 1 or 3)
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence

from repro.core import config_map as CM
from repro.core.graph import InferenceGraph
from repro.core.latency_model import (ProfileRecord, RegressionLatencyModel,
                                      ScaledLatencyModel)
from repro.core.partitioner import (CoInferencePlan, branch_preds,
                                    optimize_multi)
from repro.core.profiler import (DEVICE_SLOWDOWN, profile_all_branches,
                                 profiles_to_records)
from repro.core.runtime_optimizer import (DynamicRuntimeOptimizer,
                                          StaticRuntimeOptimizer)


@dataclass
class EdgentPlanner:
    graph: InferenceGraph
    latency_req_s: float
    f_edge: Optional[object] = None
    f_device: Optional[object] = None
    static_opt: Optional[StaticRuntimeOptimizer] = None
    dynamic_opt: Optional[DynamicRuntimeOptimizer] = None

    # calibration artifacts (None until offline_static runs)
    edge_factor: float = 1.0
    device_factor: float = DEVICE_SLOWDOWN

    # ------------------------------------------------------------ offline
    def offline_static(self, params, input_x, *,
                       device_slowdown: float = DEVICE_SLOWDOWN,
                       calibrate_to: Optional[tuple] = (2.3, 0.010)):
        """Stage 1 of Fig. 6: profile layers once, fit per-type regressions
        for each tier.

        ``calibrate_to=(device_s, edge_s)`` rescales the tier emulation so
        the full main-branch inference matches the paper's measured
        endpoints (Fig. 2: Raspberry-Pi ~2.3 s device-only, ~10 ms edge
        compute) — this host's CPU is far faster than both testbed tiers, so
        absolute speeds are anchored to the publication and the *trends* are
        what we validate."""
        profiles = profile_all_branches(self.graph, params, input_x)
        host_full = sum(p.latency_s for p in profiles
                        if not p.name.startswith("b"))  # main branch only
        if calibrate_to is not None and host_full > 0:
            dev_s, edge_s = calibrate_to
            self.device_factor = dev_s / host_full
            self.edge_factor = edge_s / host_full
        else:
            self.device_factor, self.edge_factor = device_slowdown, 1.0
        edge_records = profiles_to_records(profiles, scale=self.edge_factor)
        dev_records = profiles_to_records(profiles, scale=self.device_factor)
        self.f_edge = RegressionLatencyModel().fit(edge_records)
        self.f_device = RegressionLatencyModel().fit(dev_records)
        self.static_opt = StaticRuntimeOptimizer(
            self.graph, self.f_edge, self.f_device, self.latency_req_s)
        return self

    def with_models(self, f_edge, f_device):
        """Inject predictors directly (e.g. RooflineLatencyModel tiers)."""
        self.f_edge, self.f_device = f_edge, f_device
        self.static_opt = StaticRuntimeOptimizer(
            self.graph, f_edge, f_device, self.latency_req_s)
        return self

    def offline_dynamic(self, traces_bps: Sequence[Sequence[float]],
                        hazard: float = 1 / 50.0):
        """Fig. 7: sketch bandwidth states from historical traces, build the
        configuration map, arm the BOCD-driven optimizer."""
        assert self.f_edge is not None, "run offline_static/with_models first"
        states = CM.sketch_states(traces_bps)
        cmap = CM.build_map(self.graph, self.f_edge, self.f_device,
                            states, self.latency_req_s)
        self.dynamic_opt = DynamicRuntimeOptimizer(cmap, hazard=hazard)
        return self

    # ------------------------------------------------------------ online
    def plan(self, bandwidth_bps: float, *, dynamic: bool = False
             ) -> CoInferencePlan:
        if dynamic:
            assert self.dynamic_opt is not None
            return self.dynamic_opt.plan(bandwidth_bps)
        assert self.static_opt is not None
        return self.static_opt.plan(bandwidth_bps)

    def plan_multi(self, bandwidth_bps: float, edge_speeds: Sequence[float],
                   *, device_load: float = 1.0,
                   edge_bw_bps: Optional[float] = None) -> CoInferencePlan:
        """Joint (exit, k-cut partition) search for one ordered edge set:
        spans sized proportionally to ``edge_speeds``, device compute scaled
        by ``device_load``, edge<->edge hops billed at ``edge_bw_bps``.
        Unlike :meth:`plan`, the result is conditioned on the candidate
        hardware — the caller (``repro.fleet.joint.JointPlanner``) searches
        edge sets on top of this."""
        assert self.f_edge is not None, "run offline_static/with_models first"
        return optimize_multi(self.graph, self.f_edge, self.f_device,
                              bandwidth_bps, self.latency_req_s, edge_speeds,
                              device_load=device_load,
                              edge_bw_bps=edge_bw_bps,
                              preds=self._branch_preds())

    def _branch_preds(self):
        """Memoized :func:`~repro.core.partitioner.branch_preds` for the
        planner's own (graph, models) triple — the fleet's joint plan
        search calls :meth:`plan_multi` on every cache miss."""
        key = (id(self.f_edge), id(self.f_device))
        if getattr(self, "_preds_key", None) != key:
            self._preds_key = key
            self._preds = branch_preds(self.graph, self.f_edge,
                                       self.f_device)
        return self._preds
