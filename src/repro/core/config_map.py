"""Algorithm 2 — Configuration Map Construction (dynamic environment).

For each bandwidth state (sketched from historical traces, Oboe-style
piecewise-stationary segments) evaluate every co-inference strategy
C_j = (exit point, partition point) with the reward of Eq. (1):

    reward = exp(acc) + throughput   if t_step <= t_req
             0                        otherwise

and record argmax_j in the map.  The map is the *dynamic configuration*
consumed by Algorithm 3 at the online stage.
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.graph import InferenceGraph
from repro.core.partitioner import branch_latency


@dataclass
class MapEntry:
    exit_point: int
    partition: int
    reward: float
    latency_s: float
    accuracy: float


def reward_fn(accuracy: float, latency_s: float, latency_req_s: float) -> float:
    """Eq. (1).  throughput = 1 / t_step."""
    if latency_s > latency_req_s or latency_s <= 0:
        return 0.0
    return math.exp(accuracy) + 1.0 / latency_s


def sketch_states(traces: Sequence[Sequence[float]]) -> List[float]:
    """Oboe-style state sketching (paper Sec. V-C): each trace contributes
    the mean of its chunk bandwidths as one piecewise-stationary state."""
    return sorted(float(np.mean(np.asarray(t))) for t in traces if len(t))


def build_map(graph: InferenceGraph, f_edge, f_device,
              states_bps: Sequence[float], latency_req_s: float
              ) -> Dict[float, MapEntry]:
    """Algorithm 2: exhaustive reward search per bandwidth state."""
    cmap: Dict[float, MapEntry] = {}
    for s in states_bps:
        best: Optional[MapEntry] = None
        for i in range(1, graph.num_exits + 1):
            n = len(graph.branches[i - 1])
            for p in range(n + 1):
                lat = branch_latency(graph, i, p, f_edge, f_device, s)
                r = reward_fn(graph.accuracy[i - 1], lat, latency_req_s)
                if best is None or r >= best.reward:
                    best = MapEntry(i, p, r, lat, graph.accuracy[i - 1])
        cmap[float(s)] = best
    return cmap


def lookup(cmap: Dict[float, MapEntry], state_bps: float) -> MapEntry:
    """find(state): nearest recorded bandwidth state (paper Sec. IV-C)."""
    keys = np.array(sorted(cmap))
    idx = int(np.argmin(np.abs(keys - state_bps)))
    return cmap[float(keys[idx])]
