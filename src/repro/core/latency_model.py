"""Layer-wise latency prediction (paper Table I + Sec. IV-B).

Two predictors behind one interface:

* :class:`RegressionLatencyModel` — the paper's approach verbatim: one linear
  regression per layer *type* over the Table-I independent variables, fit by
  closed-form least squares on profiled (features, latency) records.
* :class:`RooflineLatencyModel`  — the TPU adaptation (DESIGN.md §2): no wall
  clock exists for the target hardware in this container, so per-layer latency
  = max(flops/peak_flops, bytes/hbm_bw) from the analytic counts carried by
  the InferenceGraph.

Both return seconds via ``predict(layer) -> float``.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, Iterable, List, Sequence, Tuple

import numpy as np

from repro.config import HBM_BW, PEAK_FLOPS_BF16
from repro.core.graph import GraphLayer

# feature ordering per layer type (Table I)
TABLE_I_FEATURES: Dict[str, Tuple[str, ...]] = {
    "conv": ("in_maps", "comp"),
    "relu": ("in_size",),
    "pool": ("in_size", "out_size"),
    "lrn": ("in_size",),
    "dropout": ("in_size",),
    "fc": ("in_size", "out_size"),
    "block": ("in_size", "flops"),   # LM segment granularity
}


@dataclass
class ProfileRecord:
    kind: str
    features: Dict[str, float]
    latency_s: float


class RegressionLatencyModel:
    """Per-type linear model  latency = theta . [features, 1]."""

    def __init__(self):
        self.theta: Dict[str, np.ndarray] = {}
        self.residual: Dict[str, float] = {}

    @staticmethod
    def _design(kind: str, feats: Dict[str, float]) -> np.ndarray:
        names = TABLE_I_FEATURES[kind]
        return np.array([feats.get(n, 0.0) for n in names] + [1.0])

    def fit(self, records: Iterable[ProfileRecord]) -> "RegressionLatencyModel":
        by_kind: Dict[str, List[ProfileRecord]] = {}
        for r in records:
            by_kind.setdefault(r.kind, []).append(r)
        for kind, rs in by_kind.items():
            X = np.stack([self._design(kind, r.features) for r in rs])
            y = np.array([r.latency_s for r in rs])
            theta, *_ = np.linalg.lstsq(X, y, rcond=None)
            self.theta[kind] = theta
            pred = X @ theta
            ss_res = float(np.sum((y - pred) ** 2))
            ss_tot = float(np.sum((y - y.mean()) ** 2)) or 1e-12
            self.residual[kind] = 1.0 - ss_res / ss_tot   # R^2
        return self

    def predict(self, layer: GraphLayer) -> float:
        th = self.theta.get(layer.kind)
        if th is None:
            raise KeyError(f"no regression model for layer kind {layer.kind!r}")
        return float(max(0.0, self._design(layer.kind, layer.features) @ th))

    def r2(self) -> Dict[str, float]:
        return dict(self.residual)


class RooflineLatencyModel:
    """Analytic predictor for a TPU tier: latency = max(compute, memory) term.

    ``chips``: tier size; ``efficiency``: achievable fraction of peak (MFU-like
    discount, default 0.5).
    """

    def __init__(self, chips: int = 1, peak_flops: float = PEAK_FLOPS_BF16,
                 hbm_bw: float = HBM_BW, efficiency: float = 0.5):
        self.chips = chips
        self.peak = peak_flops * chips * efficiency
        self.bw = hbm_bw * chips * efficiency

    def predict(self, layer: GraphLayer) -> float:
        compute = layer.flops / self.peak
        memory = layer.bytes_moved / self.bw
        return float(max(compute, memory))


class ScaledLatencyModel:
    """Wrap any predictor with a constant speed factor (e.g. emulating the
    Raspberry-Pi : desktop asymmetry when both tiers profile on this CPU)."""

    def __init__(self, base, factor: float):
        self.base, self.factor = base, factor

    def predict(self, layer: GraphLayer) -> float:
        return self.base.predict(layer) * self.factor
