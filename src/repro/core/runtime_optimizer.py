"""Runtime optimizers — the online-tuning stage for both environments.

* :class:`StaticRuntimeOptimizer`  — Algorithm 1 on demand: measure
  bandwidth, search (exit, partition) with the regression predictors.
* :class:`DynamicRuntimeOptimizer` — Algorithm 3: feed bandwidth
  measurements to the BOCD state detector; on a state transition, look up
  the nearest state in the configuration map (Algorithm 2 output).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Optional

from repro.core import config_map as CM
from repro.core.bocd import BandwidthStateDetector
from repro.core.graph import InferenceGraph
from repro.core.partitioner import CoInferencePlan, optimize_with_fallback


class StaticRuntimeOptimizer:
    def __init__(self, graph: InferenceGraph, f_edge, f_device,
                 latency_req_s: float):
        self.graph, self.f_edge, self.f_device = graph, f_edge, f_device
        self.latency_req_s = latency_req_s

    def plan(self, bandwidth_bps: float) -> CoInferencePlan:
        return optimize_with_fallback(self.graph, self.f_edge, self.f_device,
                                      bandwidth_bps, self.latency_req_s)


class DynamicRuntimeOptimizer:
    """Algorithm 3: C_t = C_{t-1} unless D(B_{1..t}) reports a new state."""

    def __init__(self, cmap: Dict[float, CM.MapEntry], hazard: float = 1 / 50.0):
        self.cmap = cmap
        self.detector = BandwidthStateDetector(hazard=hazard)
        self.state: Optional[float] = None
        self.current: Optional[CM.MapEntry] = None
        self.transitions = 0

    def step(self, bandwidth_bps: float) -> CM.MapEntry:
        state = self.detector.update(bandwidth_bps)
        if self.current is None or self.state is None or \
                abs(state - self.state) > 1e-9:
            entry = CM.lookup(self.cmap, state)
            if self.current is None or entry is not self.current:
                self.transitions += 1
            self.current = entry
            self.state = state
        return self.current

    def plan(self, bandwidth_bps: float) -> CoInferencePlan:
        e = self.step(bandwidth_bps)
        return CoInferencePlan(exit_point=e.exit_point, partition=e.partition,
                               latency_s=e.latency_s, accuracy=e.accuracy,
                               feasible=e.reward > 0)
