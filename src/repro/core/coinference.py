"""Co-inference executor — the co-inference stage (paper Sec. IV-A).

Executes a :class:`CoInferencePlan` over an InferenceGraph across two tiers
with a bandwidth-limited link.  Tiers and link are simulated on this host
with a *virtual clock*: edge layers run at measured speed, device layers are
billed at ``device_slowdown`` x, transfers at ``bytes / bandwidth``.  The
executor returns both the result and the accounted end-to-end latency, so
experiments are reproducible and independent of host jitter.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional

import jax
import numpy as np

from repro.core.graph import InferenceGraph
from repro.core.partitioner import CoInferencePlan


@dataclass
class CoInferenceResult:
    output: Any
    latency_s: float          # virtual end-to-end latency
    edge_s: float
    device_s: float
    transfer_s: float
    exit_point: int
    partition: int
    hops_s: float = 0.0       # inter-edge backbone transfer (k-cut plans)


@dataclass
class TwoTierExecutor:
    """Executes 1-cut plans on (edge, device) and k-cut plans on an ordered
    chain of edge tiers (``edge_slowdowns``, one per span) with inter-edge
    hand-offs billed at ``edge_bw_bps``."""
    graph: InferenceGraph
    params: Any
    bandwidth_bps: float
    device_slowdown: float = 20.0
    edge_slowdown: float = 1.0
    edge_slowdowns: Optional[List[float]] = None   # per-span, k-cut plans
    edge_bw_bps: float = 1e9                       # edge<->edge backbone

    def _run_layers(self, layers, x, slowdown: float):
        total = 0.0
        for layer in layers:
            fn = jax.jit(lambda p, x, run=layer.run: run(p, x))
            y = fn(self.params, x)          # warm cache so we time steady state
            jax.block_until_ready(y)
            t0 = time.perf_counter()
            y = fn(self.params, x)
            jax.block_until_ready(y)
            total += (time.perf_counter() - t0) * slowdown
            x = y
        return x, total

    def run(self, plan: CoInferencePlan, x, bandwidth_bps: Optional[float] = None
            ) -> CoInferenceResult:
        bw = bandwidth_bps or self.bandwidth_bps
        branch = self.graph.branches[plan.exit_point - 1]
        p = plan.partition
        transfer = 0.0
        if p > 0:
            transfer += self.graph.input_bytes / bw
            transfer += self.graph.cut_bytes(plan.exit_point, p) / bw
        cuts = plan.all_cuts
        slowdowns = self.edge_slowdowns if self.edge_slowdowns is not None \
            else [self.edge_slowdown] * len(cuts)
        x_edge, t_edge, hops = x, 0.0, 0.0
        start = 0
        for i, cut in enumerate(cuts):
            span = branch[start:min(cut, len(branch))]
            x_edge, dt = self._run_layers(span, x_edge, slowdowns[i])
            t_edge += dt
            if i < len(cuts) - 1:
                hops += self.graph.cut_bytes(plan.exit_point, cut) / \
                    self.edge_bw_bps
            start = cut
        out, t_dev = self._run_layers(branch[p:], x_edge, self.device_slowdown)
        return CoInferenceResult(
            output=out, latency_s=t_edge + t_dev + transfer + hops,
            edge_s=t_edge, device_s=t_dev, transfer_s=transfer,
            exit_point=plan.exit_point, partition=p, hops_s=hops)
