"""Co-inference executor — the co-inference stage (paper Sec. IV-A).

Executes a :class:`CoInferencePlan` over an InferenceGraph across two tiers
with a bandwidth-limited link.  Tiers and link are simulated on this host
with a *virtual clock*: edge layers run at measured speed, device layers are
billed at ``device_slowdown`` x, transfers at ``bytes / bandwidth``.  The
executor returns both the result and the accounted end-to-end latency, so
experiments are reproducible and independent of host jitter.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional

import jax
import numpy as np

from repro.core.graph import InferenceGraph
from repro.core.partitioner import CoInferencePlan


@dataclass
class CoInferenceResult:
    output: Any
    latency_s: float          # virtual end-to-end latency
    edge_s: float
    device_s: float
    transfer_s: float
    exit_point: int
    partition: int


@dataclass
class TwoTierExecutor:
    graph: InferenceGraph
    params: Any
    bandwidth_bps: float
    device_slowdown: float = 20.0
    edge_slowdown: float = 1.0

    def _run_layers(self, layers, x, slowdown: float):
        total = 0.0
        for layer in layers:
            fn = jax.jit(lambda p, x, run=layer.run: run(p, x))
            y = fn(self.params, x)          # warm cache so we time steady state
            jax.block_until_ready(y)
            t0 = time.perf_counter()
            y = fn(self.params, x)
            jax.block_until_ready(y)
            total += (time.perf_counter() - t0) * slowdown
            x = y
        return x, total

    def run(self, plan: CoInferencePlan, x, bandwidth_bps: Optional[float] = None
            ) -> CoInferenceResult:
        bw = bandwidth_bps or self.bandwidth_bps
        branch = self.graph.branches[plan.exit_point - 1]
        p = plan.partition
        transfer = 0.0
        if p > 0:
            transfer += self.graph.input_bytes / bw
            transfer += self.graph.cut_bytes(plan.exit_point, p) / bw
        x_edge, t_edge = self._run_layers(branch[:p], x, self.edge_slowdown)
        out, t_dev = self._run_layers(branch[p:], x_edge, self.device_slowdown)
        return CoInferenceResult(
            output=out, latency_s=t_edge + t_dev + transfer,
            edge_s=t_edge, device_s=t_dev, transfer_s=transfer,
            exit_point=plan.exit_point, partition=p)
