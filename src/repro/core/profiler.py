"""Layer-wise profiling harness (paper Fig. 3 + the offline stage of the
static configurator).

Times each layer of an InferenceGraph on this host and emits
:class:`ProfileRecord` rows for the regression fit.  The device/edge
asymmetry of the paper's testbed (Raspberry Pi ~ 20x slower than the
desktop) is emulated with a latency scale factor, recorded in the output.
"""
from __future__ import annotations

import time
from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.graph import InferenceGraph
from repro.core.latency_model import ProfileRecord

DEVICE_SLOWDOWN = 20.0  # Raspberry Pi 3 vs desktop PC (paper Sec. V-A)


@dataclass
class LayerProfile:
    name: str
    kind: str
    latency_s: float          # measured on this host ("edge" tier)
    out_bytes: int
    features: Dict[str, float]


def profile_graph(graph: InferenceGraph, params, input_x, *, repeats: int = 5,
                  warmup: int = 2) -> List[LayerProfile]:
    """Run the longest branch layer-by-layer, timing each layer."""
    branch = graph.branches[-1]
    profiles = []
    x = input_x
    for layer in branch:
        fn = jax.jit(lambda p, x, run=layer.run: run(p, x))
        for _ in range(warmup):
            y = fn(params, x)
            jax.block_until_ready(y)
        ts = []
        for _ in range(repeats):
            t0 = time.perf_counter()
            y = fn(params, x)
            jax.block_until_ready(y)
            ts.append(time.perf_counter() - t0)
        profiles.append(LayerProfile(
            name=layer.name, kind=layer.kind,
            latency_s=float(np.median(ts)),
            out_bytes=layer.out_bytes, features=layer.features))
        x = y
    return profiles


def profiles_to_records(profiles: Sequence[LayerProfile],
                        scale: float = 1.0) -> List[ProfileRecord]:
    return [ProfileRecord(kind=p.kind, features=p.features,
                          latency_s=p.latency_s * scale) for p in profiles]


def profile_all_branches(graph: InferenceGraph, params, input_x, *,
                         repeats: int = 3) -> List[LayerProfile]:
    """Profile every branch (side layers differ across branches)."""
    seen = set()
    out: List[LayerProfile] = []
    for bi in range(graph.num_exits, 0, -1):
        x = input_x
        for layer in graph.branches[bi - 1]:
            if layer.name in seen:
                x = jax.jit(lambda p, x, run=layer.run: run(p, x))(params, x)
                continue
            seen.add(layer.name)
            fn = jax.jit(lambda p, x, run=layer.run: run(p, x))
            y = fn(params, x)
            jax.block_until_ready(y)
            ts = []
            for _ in range(repeats):
                t0 = time.perf_counter()
                y = fn(params, x)
                jax.block_until_ready(y)
                ts.append(time.perf_counter() - t0)
            out.append(LayerProfile(layer.name, layer.kind,
                                    float(np.median(ts)), layer.out_bytes,
                                    layer.features))
            x = y
    return out
