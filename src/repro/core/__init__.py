# The paper's primary contribution: joint DNN partitioning + right-sizing
# under a latency SLO, for static and dynamic bandwidth environments.
from repro.core.graph import GraphLayer, InferenceGraph, alexnet_graph, lm_graph  # noqa: F401
from repro.core.latency_model import (ProfileRecord, RegressionLatencyModel,  # noqa: F401
                                      RooflineLatencyModel, ScaledLatencyModel)
from repro.core.partitioner import (CoInferencePlan, multi_branch_latency,  # noqa: F401
                                    optimize, optimize_multi,
                                    optimize_with_fallback, proportional_cuts)
from repro.core.planner import EdgentPlanner  # noqa: F401
