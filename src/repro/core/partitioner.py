"""Algorithm 1 — Runtime Optimizer for the static environment.

Joint exhaustive search over (exit point i, partition point p): maximize
accuracy subject to the latency requirement, preferring larger exits (the
paper iterates i = M..1 and returns the first branch whose best partition
meets the deadline).

Partition convention (paper Sec. IV-B, re-indexed 0-based; DESIGN.md §3):
``p`` = number of leading layers of branch ``i`` that run on the EDGE tier.
The input lives on the device, so a non-trivial cut pays ``Input/B`` uplink,
edge computes layers [0, p), ships the intermediate ``D_{p}`` downlink, and
the device computes [p, N).  ``p = 0`` -> device-only (no transfers);
``p = N`` -> edge-only (uplink + result return).
"""
from __future__ import annotations

import time
from dataclasses import dataclass
from typing import List, Optional, Sequence, Tuple

from repro.core.graph import InferenceGraph


@dataclass
class CoInferencePlan:
    exit_point: int        # 1-based (paper numbering; num_exits = full model)
    partition: int         # layers on the edge tier (total, across all cuts)
    latency_s: float       # predicted end-to-end latency
    accuracy: float
    feasible: bool = True
    # k-cut generalization (CoEdge-style multi-edge spans): ascending cut
    # points over the edge portion, last == partition.  Empty == legacy
    # single-cut plan (one edge owns [0, partition)).
    cuts: tuple = ()

    @property
    def all_cuts(self) -> tuple:
        return self.cuts if self.cuts else ((self.partition,)
                                            if self.partition > 0 else ())


_CUTS_MEMO: dict = {}   # (p, speeds) -> (cuts, keep); see proportional_cuts


def branch_latency(graph: InferenceGraph, exit_idx: int, p: int,
                   f_edge, f_device, bandwidth_bps: float,
                   edge_load: float = 1.0, device_load: float = 1.0) -> float:
    """A_{i,p} of Algorithm 1 (seconds).  ``bandwidth_bps`` in bytes/s.

    ``edge_load`` / ``device_load`` scale the respective tier's compute time;
    the fleet simulator uses them for heterogeneous edges and per-device
    slowdowns."""
    branch = graph.branches[exit_idx - 1]
    n = len(branch)
    t = 0.0
    if p > 0:
        t += graph.input_bytes / bandwidth_bps            # Input/B uplink
        t += graph.cut_bytes(exit_idx, p) / bandwidth_bps  # D_{p-1}/B downlink
    for j, layer in enumerate(branch):
        if j < p:
            t += f_edge.predict(layer) * edge_load
        else:
            t += f_device.predict(layer) * device_load
    return t


def proportional_cuts(p: int, speeds: Sequence[float]) -> Tuple[tuple, tuple]:
    """Split the edge portion ``[0, p)`` into contiguous spans sized
    proportionally to each edge's throughput (``1/speed`` — ``speed`` > 1
    means slower hardware, so faster edges own more layers; CoEdge's
    workload-proportional allocation at layer granularity).

    Returns ``(cuts, keep)``: ascending cut points (span ``i`` is
    ``[cuts[i-1], cuts[i])``, ``cuts[-1] == p``) and the indices into
    ``speeds`` that received a non-empty span.  Cumulative rounding keeps the
    allocation deterministic and the spans contiguous; edges whose share
    rounds to zero layers are dropped and the split re-runs over the
    survivors until stable, so the function is *idempotent on the kept set*
    — re-splitting ``p`` over ``speeds[keep]`` returns the same cuts.  Plan
    search, span assignment, and round timing all rely on that to agree on
    one span layout.  ``k == 1`` always returns ``((p,), (0,))``.

    Pure function of ``(p, speeds)``, memoized: the fleet's plan search and
    per-round span assignment ask for the same handful of splits millions
    of times at scale."""
    if p <= 0:
        return (), ()
    memo_key = (p, tuple(speeds))
    hit = _CUTS_MEMO.get(memo_key)
    if hit is not None:
        return hit

    def split(spds):
        weights = [1.0 / max(s, 1e-12) for s in spds]
        total = sum(weights)
        cuts: List[int] = []
        keep: List[int] = []
        prev, cum = 0, 0.0
        for i, w in enumerate(weights):
            cum += w
            c = p if i == len(weights) - 1 else int(round(p * cum / total))
            if c > prev:
                cuts.append(c)
                keep.append(i)
                prev = c
        return tuple(cuts), tuple(keep)

    idx = tuple(range(len(speeds)))
    spds = tuple(speeds)
    while True:
        cuts, keep = split(spds)
        if len(keep) == len(spds):
            out = cuts, tuple(idx[i] for i in keep)
            _CUTS_MEMO[memo_key] = out
            return out
        idx = tuple(idx[i] for i in keep)
        spds = tuple(spds[i] for i in keep)


def branch_preds(graph: InferenceGraph, f_edge, f_device):
    """Per-branch per-layer predictor outputs ``(edge, device)`` — the
    ``preds`` argument of :func:`multi_branch_latency`/:func:`optimize_multi`.
    ``predict`` is a pure function of the layer, so replaying these floats
    through the same accumulation order is bit-exact; callers that own a
    stable (graph, models) triple memoize this to skip per-call predictor
    dispatch on the fleet hot path."""
    return ([[f_edge.predict(l) for l in b] for b in graph.branches],
            [[f_device.predict(l) for l in b] for b in graph.branches])


def multi_branch_latency(graph: InferenceGraph, exit_idx: int,
                         cuts: Sequence[int], edge_loads: Sequence[float],
                         f_edge, f_device, bandwidth_bps: float,
                         device_load: float = 1.0,
                         edge_bw_bps: Optional[float] = None,
                         preds=None) -> float:
    """k-cut generalization of :func:`branch_latency`.

    ``cuts`` are ascending; span ``i`` = layers ``[cuts[i-1], cuts[i])`` runs
    on an edge with compute multiplier ``edge_loads[i]``; the device runs
    ``[cuts[-1], N)``.  Consecutive spans hand the activation over an
    edge<->edge backbone link (``edge_bw_bps``); the device<->edge uplink and
    final downlink are billed at ``bandwidth_bps`` exactly as in the 1-cut
    case.  With a single cut this accumulates the identical float terms in
    the identical order as :func:`branch_latency` — bit-exact reduction
    (asserted by tests/test_coop.py)."""
    branch = graph.branches[exit_idx - 1]
    n = len(branch)
    if preds is None:
        pe = [f_edge.predict(l) for l in branch]
        pd = [f_device.predict(l) for l in branch]
    else:
        pe, pd = preds[0][exit_idx - 1], preds[1][exit_idx - 1]
    p = cuts[-1] if cuts else 0
    t = 0.0
    if p > 0:
        t += graph.input_bytes / bandwidth_bps             # Input/B uplink
        t += graph.cut_bytes(exit_idx, p) / bandwidth_bps  # D_p/B downlink
    start = 0
    for i, (cut, load) in enumerate(zip(cuts, edge_loads)):
        for j in range(start, min(cut, n)):
            t += pe[j] * load
        if i < len(cuts) - 1:                              # edge -> edge hop
            assert edge_bw_bps is not None, \
                "multi-edge plans need an edge<->edge backbone bandwidth"
            t += graph.cut_bytes(exit_idx, cut) / edge_bw_bps
        start = cut
    for j in range(p, n):
        t += pd[j] * device_load
    return t


def optimize_multi(graph: InferenceGraph, f_edge, f_device,
                   bandwidth_bps: float, latency_req_s: float,
                   edge_speeds: Sequence[float], *,
                   device_load: float = 1.0,
                   edge_bw_bps: Optional[float] = None,
                   preds=None) -> CoInferencePlan:
    """Algorithm 1 over the k-cut space for one *fixed ordered* edge set:
    search (exit i, total edge layers p) with spans sized proportionally to
    ``edge_speeds``; prefer the largest exit meeting the deadline, else the
    global minimum-latency plan flagged infeasible (fallback semantics of
    :func:`optimize_with_fallback`).  ``preds`` optionally carries
    :func:`branch_preds` output to skip per-call predictor dispatch."""
    speeds = tuple(edge_speeds)

    def scan(exit_idx: int) -> Tuple[int, tuple, float]:
        nn = len(graph.branches[exit_idx - 1])
        best = (0, (), float("inf"))
        for p in range(nn + 1):
            cuts, kept = proportional_cuts(p, speeds)
            loads = [speeds[i] for i in kept]
            lat = multi_branch_latency(graph, exit_idx, cuts, loads, f_edge,
                                       f_device, bandwidth_bps,
                                       device_load=device_load,
                                       edge_bw_bps=edge_bw_bps, preds=preds)
            if lat < best[2]:
                best = (p, cuts, lat)
        return best

    fallback = None
    for i in range(graph.num_exits, 0, -1):        # largest exit first
        p, cuts, lat = scan(i)
        plan = CoInferencePlan(exit_point=i, partition=p, latency_s=lat,
                               accuracy=graph.accuracy[i - 1], cuts=cuts)
        if lat <= latency_req_s:
            return plan
        if fallback is None or lat < fallback.latency_s:
            plan.feasible = False
            fallback = plan
    return fallback


def best_partition(graph: InferenceGraph, exit_idx: int, f_edge, f_device,
                   bandwidth_bps: float) -> Tuple[int, float]:
    """Exhaustive scan over p = 0..N for one branch; returns (p*, latency)."""
    n = len(graph.branches[exit_idx - 1])
    best = (0, float("inf"))
    for p in range(n + 1):
        lat = branch_latency(graph, exit_idx, p, f_edge, f_device, bandwidth_bps)
        if lat < best[1]:
            best = (p, lat)
    return best


def optimize(graph: InferenceGraph, f_edge, f_device, bandwidth_bps: float,
             latency_req_s: float) -> Optional[CoInferencePlan]:
    """Algorithm 1.  Returns None when no (i, p) meets the deadline
    (the paper's NULL)."""
    for i in range(graph.num_exits, 0, -1):       # largest exit first
        p, lat = best_partition(graph, i, f_edge, f_device, bandwidth_bps)
        if lat <= latency_req_s:
            return CoInferencePlan(exit_point=i, partition=p, latency_s=lat,
                                   accuracy=graph.accuracy[i - 1])
    return None


def optimize_with_fallback(graph, f_edge, f_device, bandwidth_bps,
                           latency_req_s) -> CoInferencePlan:
    """Like :func:`optimize` but when infeasible returns the minimum-latency
    plan flagged infeasible — used by the serving engine as a straggler
    rescue (DESIGN.md §2)."""
    plan = optimize(graph, f_edge, f_device, bandwidth_bps, latency_req_s)
    if plan is not None:
        return plan
    best = None
    for i in range(1, graph.num_exits + 1):
        p, lat = best_partition(graph, i, f_edge, f_device, bandwidth_bps)
        if best is None or lat < best.latency_s:
            best = CoInferencePlan(i, p, lat, graph.accuracy[i - 1], feasible=False)
    return best


def search_latency(graph, f_edge, f_device, bandwidth_bps, latency_req_s,
                   repeats: int = 10) -> float:
    """Wall-clock of one Algorithm-1 search (paper claims < 1 ms)."""
    t0 = time.perf_counter()
    for _ in range(repeats):
        optimize(graph, f_edge, f_device, bandwidth_bps, latency_req_s)
    return (time.perf_counter() - t0) / repeats
