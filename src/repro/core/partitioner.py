"""Algorithm 1 — Runtime Optimizer for the static environment.

Joint exhaustive search over (exit point i, partition point p): maximize
accuracy subject to the latency requirement, preferring larger exits (the
paper iterates i = M..1 and returns the first branch whose best partition
meets the deadline).

Partition convention (paper Sec. IV-B, re-indexed 0-based; DESIGN.md §3):
``p`` = number of leading layers of branch ``i`` that run on the EDGE tier.
The input lives on the device, so a non-trivial cut pays ``Input/B`` uplink,
edge computes layers [0, p), ships the intermediate ``D_{p}`` downlink, and
the device computes [p, N).  ``p = 0`` -> device-only (no transfers);
``p = N`` -> edge-only (uplink + result return).
"""
from __future__ import annotations

import time
from dataclasses import dataclass
from typing import List, Optional, Tuple

from repro.core.graph import InferenceGraph


@dataclass
class CoInferencePlan:
    exit_point: int        # 1-based (paper numbering; num_exits = full model)
    partition: int         # layers on the edge tier
    latency_s: float       # predicted end-to-end latency
    accuracy: float
    feasible: bool = True


def branch_latency(graph: InferenceGraph, exit_idx: int, p: int,
                   f_edge, f_device, bandwidth_bps: float,
                   edge_load: float = 1.0, device_load: float = 1.0) -> float:
    """A_{i,p} of Algorithm 1 (seconds).  ``bandwidth_bps`` in bytes/s.

    ``edge_load`` / ``device_load`` scale the respective tier's compute time;
    the fleet simulator uses them for heterogeneous edges and per-device
    slowdowns."""
    branch = graph.branches[exit_idx - 1]
    n = len(branch)
    t = 0.0
    if p > 0:
        t += graph.input_bytes / bandwidth_bps            # Input/B uplink
        t += graph.cut_bytes(exit_idx, p) / bandwidth_bps  # D_{p-1}/B downlink
    for j, layer in enumerate(branch):
        if j < p:
            t += f_edge.predict(layer) * edge_load
        else:
            t += f_device.predict(layer) * device_load
    return t


def best_partition(graph: InferenceGraph, exit_idx: int, f_edge, f_device,
                   bandwidth_bps: float) -> Tuple[int, float]:
    """Exhaustive scan over p = 0..N for one branch; returns (p*, latency)."""
    n = len(graph.branches[exit_idx - 1])
    best = (0, float("inf"))
    for p in range(n + 1):
        lat = branch_latency(graph, exit_idx, p, f_edge, f_device, bandwidth_bps)
        if lat < best[1]:
            best = (p, lat)
    return best


def optimize(graph: InferenceGraph, f_edge, f_device, bandwidth_bps: float,
             latency_req_s: float) -> Optional[CoInferencePlan]:
    """Algorithm 1.  Returns None when no (i, p) meets the deadline
    (the paper's NULL)."""
    for i in range(graph.num_exits, 0, -1):       # largest exit first
        p, lat = best_partition(graph, i, f_edge, f_device, bandwidth_bps)
        if lat <= latency_req_s:
            return CoInferencePlan(exit_point=i, partition=p, latency_s=lat,
                                   accuracy=graph.accuracy[i - 1])
    return None


def optimize_with_fallback(graph, f_edge, f_device, bandwidth_bps,
                           latency_req_s) -> CoInferencePlan:
    """Like :func:`optimize` but when infeasible returns the minimum-latency
    plan flagged infeasible — used by the serving engine as a straggler
    rescue (DESIGN.md §2)."""
    plan = optimize(graph, f_edge, f_device, bandwidth_bps, latency_req_s)
    if plan is not None:
        return plan
    best = None
    for i in range(1, graph.num_exits + 1):
        p, lat = best_partition(graph, i, f_edge, f_device, bandwidth_bps)
        if best is None or lat < best.latency_s:
            best = CoInferencePlan(i, p, lat, graph.accuracy[i - 1], feasible=False)
    return best


def search_latency(graph, f_edge, f_device, bandwidth_bps, latency_req_s,
                   repeats: int = 10) -> float:
    """Wall-clock of one Algorithm-1 search (paper claims < 1 ms)."""
    t0 = time.perf_counter()
    for _ in range(repeats):
        optimize(graph, f_edge, f_device, bandwidth_bps, latency_req_s)
    return (time.perf_counter() - t0) / repeats
