"""Bayesian Online Change-point Detection (Adams & MacKay 2007), used by
Algorithm 3 to detect bandwidth-state transitions (paper Sec. IV-C).

Gaussian observation model with unknown mean and variance
(Normal-Inverse-Gamma conjugate prior -> Student-t predictive), constant
hazard H = 1/lambda.  The run-length posterior is maintained online; a
change point is declared when the MAP run length drops.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import List, Optional

import numpy as np
from numpy import vectorize

_lgamma = vectorize(__import__("math").lgamma)


def _student_t_logpdf(x, df, loc, scale):
    z = (x - loc) / scale
    return (_lgamma((df + 1) / 2) - _lgamma(df / 2)
            - 0.5 * (np.log(df) + np.log(np.pi)) - np.log(scale)
            - (df + 1) / 2 * np.log1p(z * z / df))


@dataclass
class BOCD:
    hazard: float = 1 / 50.0        # expected segment length lambda = 50
    mu0: float = 0.0
    kappa0: float = 1.0
    alpha0: float = 1.0
    beta0: float = 1.0
    max_run: int = 512
    trunc: float = 1e-6

    def __post_init__(self):
        self.reset()

    def reset(self):
        self.t = 0
        self.r_prob = np.array([1.0])           # P(r_t | x_1..t)
        self.mu = np.array([self.mu0])
        self.kappa = np.array([self.kappa0])
        self.alpha = np.array([self.alpha0])
        self.beta = np.array([self.beta0])
        self.map_run = 0

    def update(self, x: float) -> bool:
        """Ingest one measurement; returns True when a change point fires."""
        df = 2 * self.alpha
        scale = np.sqrt(self.beta * (self.kappa + 1) / (self.alpha * self.kappa))
        logpred = _student_t_logpdf(x, df, self.mu, scale)
        pred = np.exp(logpred - logpred.max())
        pred = pred * np.exp(logpred.max())     # unnormalized predictive

        growth = self.r_prob * pred * (1 - self.hazard)
        cp = float(np.sum(self.r_prob * pred * self.hazard))
        new_r = np.concatenate([[cp], growth])
        s = new_r.sum()
        if s <= 0 or not np.isfinite(s):
            new_r = np.zeros_like(new_r)
            new_r[0] = 1.0
        else:
            new_r = new_r / s

        # posterior parameter update
        mu_new = np.concatenate([[self.mu0], (self.kappa * self.mu + x) / (self.kappa + 1)])
        kappa_new = np.concatenate([[self.kappa0], self.kappa + 1])
        alpha_new = np.concatenate([[self.alpha0], self.alpha + 0.5])
        beta_new = np.concatenate([
            [self.beta0],
            self.beta + self.kappa * (x - self.mu) ** 2 / (2 * (self.kappa + 1))])

        # truncate tail for O(max_run) updates: run lengths beyond the cap
        # collapse into the boundary (standard SOR truncation; indices stay
        # equal to run lengths so MAP-collapse detection remains valid)
        if len(new_r) > self.max_run:
            new_r = new_r[: self.max_run]
            mu_new = mu_new[: self.max_run]
            kappa_new = kappa_new[: self.max_run]
            alpha_new = alpha_new[: self.max_run]
            beta_new = beta_new[: self.max_run]
            s = new_r.sum()
            new_r = new_r / s if s > 0 else np.eye(len(new_r))[0]

        prev_map = self.map_run
        self.r_prob, self.mu = new_r, mu_new
        self.kappa, self.alpha, self.beta = kappa_new, alpha_new, beta_new
        self.map_run = int(np.argmax(self.r_prob))
        self.t += 1
        # change point: MAP run length collapsed
        return self.map_run < prev_map - 2 or (self.map_run == 0 and prev_map > 3)

    @property
    def state_mean(self) -> float:
        """Posterior mean of the current segment (MAP run length)."""
        return float(self.mu[self.map_run])


class BandwidthStateDetector:
    """D(B_{1..t}) of Algorithm 3: wraps BOCD, exposes the current bandwidth
    state (segment mean) and change flags."""

    def __init__(self, hazard: float = 1 / 50.0):
        self.bocd = BOCD(hazard=hazard)
        self.history: List[float] = []
        self.changes: List[int] = []

    def update(self, bandwidth: float) -> float:
        changed = self.bocd.update(float(bandwidth))
        self.history.append(float(bandwidth))
        if changed:
            self.changes.append(len(self.history) - 1)
        return self.bocd.state_mean

    @property
    def current_state(self) -> float:
        return self.bocd.state_mean
