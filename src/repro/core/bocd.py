"""Bayesian Online Change-point Detection (Adams & MacKay 2007), used by
Algorithm 3 to detect bandwidth-state transitions (paper Sec. IV-C).

Gaussian observation model with unknown mean and variance
(Normal-Inverse-Gamma conjugate prior -> Student-t predictive), constant
hazard H = 1/lambda.  The run-length posterior is maintained online; a
change point is declared when the MAP run length drops.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

import numpy as np
from numpy import vectorize

_lgamma = vectorize(__import__("math").lgamma)


def _student_t_logpdf(x, df, loc, scale):
    z = (x - loc) / scale
    return (_lgamma((df + 1) / 2) - _lgamma(df / 2)
            - 0.5 * (np.log(df) + np.log(np.pi)) - np.log(scale)
            - (df + 1) / 2 * np.log1p(z * z / df))


# ---------------------------------------------------------------- run tables
# The NIG posterior's kappa/alpha arrays are *pure functions of the run
# length*: kappa[i] follows kappa0, kappa0+1, ... and alpha[i] follows
# alpha0, alpha0+0.5, ... regardless of the data.  Every lgamma/log term of
# the Student-t predictive that depends only on them is therefore a fixed
# per-index table, shared by all detectors with the same prior — the fleet
# runs one BOCD per device, and evaluating lgamma per element per update
# (via np.vectorize) dominated the mobility hot path.  Tables are built with
# the *same recurrences and elementwise ops* as the original update, so the
# fast path below is bit-identical to it (pinned by tests/test_bocd.py).
_TABLES: Dict[Tuple[float, float], dict] = {}


def _run_tables(alpha0: float, kappa0: float, n: int) -> dict:
    tab = _TABLES.get((alpha0, kappa0))
    if tab is not None and tab["n"] >= n:
        return tab
    m = max(n, 128, 2 * tab["n"] if tab is not None else 0)
    alpha_l, kappa_l = [alpha0], [kappa0]
    for _ in range(m - 1):                 # the exact += recurrences the
        alpha_l.append(alpha_l[-1] + 0.5)  # posterior update used to apply
        kappa_l.append(kappa_l[-1] + 1)
    alpha = np.array(alpha_l)
    kappa = np.array(kappa_l)
    df = 2 * alpha
    halfdfp1 = (df + 1) / 2
    tab = {
        "n": m,
        "alpha": alpha,
        "kappa": kappa,
        "df": df,
        "halfdfp1": halfdfp1,
        # lgamma((df+1)/2) - lgamma(df/2) - 0.5*(log(df) + log(pi)): the
        # data-independent prefix of the Student-t logpdf, in its exact
        # left-to-right accumulation order
        "const": (_lgamma(halfdfp1) - _lgamma(df / 2)
                  - 0.5 * (np.log(df) + np.log(np.pi))),
        "k1": kappa + 1,                   # kappa + 1
        "ak": alpha * kappa,               # alpha * kappa
        "t2k1": 2 * (kappa + 1),           # 2 * (kappa + 1)
    }
    _TABLES[(alpha0, kappa0)] = tab
    return tab


@dataclass
class BOCD:
    hazard: float = 1 / 50.0        # expected segment length lambda = 50
    mu0: float = 0.0
    kappa0: float = 1.0
    alpha0: float = 1.0
    beta0: float = 1.0
    max_run: int = 512
    trunc: float = 1e-6

    def __post_init__(self):
        self.reset()

    def reset(self):
        self.t = 0
        self.r_prob = np.array([1.0])           # P(r_t | x_1..t)
        self.mu = np.array([self.mu0])
        self.beta = np.array([self.beta0])
        self.map_run = 0

    # kappa/alpha are pure functions of the run-length index (see
    # _run_tables); only the current length is state.  The views keep the
    # pre-table attribute API intact.
    @property
    def kappa(self) -> np.ndarray:
        return _run_tables(self.alpha0, self.kappa0,
                           len(self.r_prob))["kappa"][: len(self.r_prob)]

    @property
    def alpha(self) -> np.ndarray:
        return _run_tables(self.alpha0, self.kappa0,
                           len(self.r_prob))["alpha"][: len(self.r_prob)]

    def update(self, x: float) -> bool:
        """Ingest one measurement; returns True when a change point fires.

        Every lgamma/log term that depends only on the run-length index
        comes from :func:`_run_tables`; the remaining ops accumulate the
        identical floats in the identical order as the pre-table
        implementation (bit-exact — tests/test_bocd.py pins a trace)."""
        n = len(self.r_prob)
        tab = _run_tables(self.alpha0, self.kappa0, n)
        kappa, k1 = tab["kappa"][:n], tab["k1"][:n]
        scale = np.sqrt(self.beta * k1 / tab["ak"][:n])
        z = (x - self.mu) / scale
        logpred = (tab["const"][:n] - np.log(scale)
                   - tab["halfdfp1"][:n] * np.log1p(z * z / tab["df"][:n]))
        pred = np.exp(logpred - logpred.max())
        pred = pred * np.exp(logpred.max())     # unnormalized predictive

        growth = self.r_prob * pred * (1 - self.hazard)
        cp = float(np.sum(self.r_prob * pred * self.hazard))
        new_r = np.concatenate([[cp], growth])
        s = new_r.sum()
        if s <= 0 or not np.isfinite(s):
            new_r = np.zeros_like(new_r)
            new_r[0] = 1.0
        else:
            new_r = new_r / s

        # posterior parameter update (kappa/alpha advance implicitly with
        # the array length)
        mu_new = np.concatenate([[self.mu0], (kappa * self.mu + x) / k1])
        beta_new = np.concatenate([
            [self.beta0],
            self.beta + kappa * (x - self.mu) ** 2 / tab["t2k1"][:n]])

        # truncate tail for O(max_run) updates: run lengths beyond the cap
        # collapse into the boundary (standard SOR truncation; indices stay
        # equal to run lengths so MAP-collapse detection remains valid)
        if len(new_r) > self.max_run:
            new_r = new_r[: self.max_run]
            mu_new = mu_new[: self.max_run]
            beta_new = beta_new[: self.max_run]
            s = new_r.sum()
            new_r = new_r / s if s > 0 else np.eye(len(new_r))[0]

        prev_map = self.map_run
        self.r_prob, self.mu, self.beta = new_r, mu_new, beta_new
        self.map_run = int(np.argmax(self.r_prob))
        self.t += 1
        # change point: MAP run length collapsed
        return self.map_run < prev_map - 2 or (self.map_run == 0 and prev_map > 3)

    @property
    def state_mean(self) -> float:
        """Posterior mean of the current segment (MAP run length)."""
        return float(self.mu[self.map_run])


class BOCDBank:
    """``n`` independent :class:`BOCD` detectors with a shared prior,
    updated in lockstep as one batch of 2-D numpy ops.

    The fleet simulator samples *every* device's bandwidth on the same
    virtual-time grid, so all per-device run-length posteriors always have
    the same length — rows of ``[n, run_length]`` matrices.  One batched
    update replaces ``n`` sequential :meth:`BOCD.update` calls; every row is
    bit-identical to the detector it replaces (numpy applies the same
    elementwise ops and the same pairwise reductions along the last axis —
    pinned by tests/test_bocd.py::test_bank_matches_scalar_detectors).
    """

    def __init__(self, n: int, hazard: float = 1 / 50.0, mu0: float = 0.0,
                 kappa0: float = 1.0, alpha0: float = 1.0, beta0: float = 1.0,
                 max_run: int = 512):
        self.n = n
        self.hazard, self.max_run = hazard, max_run
        self.mu0, self.kappa0 = mu0, kappa0
        self.alpha0, self.beta0 = alpha0, beta0
        self.t = 0
        self.r_prob = np.ones((n, 1))
        self.mu = np.full((n, 1), mu0)
        self.beta = np.full((n, 1), beta0)
        self.map_run = np.zeros(n, dtype=int)

    def update(self, x: np.ndarray) -> np.ndarray:
        """Ingest one measurement per detector (``x``: ``[n]``); returns a
        boolean ``[n]`` — which detectors fired a change point."""
        m = self.r_prob.shape[1]
        tab = _run_tables(self.alpha0, self.kappa0, m)
        kappa, k1 = tab["kappa"][:m], tab["k1"][:m]
        xc = np.asarray(x, dtype=float)[:, None]
        scale = np.sqrt(self.beta * k1 / tab["ak"][:m])
        z = (xc - self.mu) / scale
        logpred = (tab["const"][:m] - np.log(scale)
                   - tab["halfdfp1"][:m] * np.log1p(z * z / tab["df"][:m]))
        lmax = logpred.max(axis=1)
        pred = np.exp(logpred - lmax[:, None])
        pred = pred * np.exp(lmax)[:, None]     # unnormalized predictive

        growth = self.r_prob * pred * (1 - self.hazard)
        cp = (self.r_prob * pred * self.hazard).sum(axis=1)
        new_r = np.concatenate([cp[:, None], growth], axis=1)
        s = new_r.sum(axis=1)
        bad = (s <= 0) | ~np.isfinite(s)
        with np.errstate(divide="ignore", invalid="ignore"):
            new_r = new_r / s[:, None]
        mu_new = np.concatenate(
            [np.full((self.n, 1), self.mu0), (kappa * self.mu + xc) / k1],
            axis=1)
        beta_new = np.concatenate(
            [np.full((self.n, 1), self.beta0),
             self.beta + kappa * (xc - self.mu) ** 2 / tab["t2k1"][:m]],
            axis=1)

        if new_r.shape[1] > self.max_run:       # SOR truncation, all rows
            new_r = new_r[:, : self.max_run]
            mu_new = mu_new[:, : self.max_run]
            beta_new = beta_new[:, : self.max_run]
            s = new_r.sum(axis=1)
            with np.errstate(divide="ignore", invalid="ignore"):
                new_r = new_r / s[:, None]
            bad = bad | ~(s > 0)        # mirrors the scalar `if s > 0` gate
        if bad.any():
            new_r[bad] = 0.0
            new_r[bad, 0] = 1.0

        prev_map = self.map_run
        self.r_prob, self.mu, self.beta = new_r, mu_new, beta_new
        self.map_run = new_r.argmax(axis=1)
        self.t += 1
        return (self.map_run < prev_map - 2) | \
            ((self.map_run == 0) & (prev_map > 3))


class BandwidthStateDetector:
    """D(B_{1..t}) of Algorithm 3: wraps BOCD, exposes the current bandwidth
    state (segment mean) and change flags."""

    def __init__(self, hazard: float = 1 / 50.0):
        self.bocd = BOCD(hazard=hazard)
        self.history: List[float] = []
        self.changes: List[int] = []

    def update(self, bandwidth: float) -> float:
        changed = self.bocd.update(float(bandwidth))
        self.history.append(float(bandwidth))
        if changed:
            self.changes.append(len(self.history) - 1)
        return self.bocd.state_mean

    @property
    def current_state(self) -> float:
        return self.bocd.state_mean
