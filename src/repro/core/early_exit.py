"""Early-exit (right-sizing) policies.

The paper's knob is *plan-selected*: the runtime optimizer fixes the exit
point per bandwidth state.  Two beyond-paper policies are provided for the
LM serving engine:

* entropy/confidence exit — per-token exit when the exit head is confident
  (uses the fused Pallas exit-head kernel at scale);
* deadline demotion — straggler mitigation: when a microbatch is behind its
  deadline, demote it to an earlier exit (the paper's accuracy-latency
  tradeoff used as a rescue).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional

import numpy as np


@dataclass
class StaticExitPolicy:
    """Paper semantics: exit point fixed by the plan (1-based)."""
    exit_point: int

    def select(self, confidences=None, **_) -> int:
        return self.exit_point


@dataclass
class ConfidenceExitPolicy:
    """Exit at the first head whose max-softmax-prob exceeds ``threshold``
    (BranchyNet's inference rule), else run to the end."""
    threshold: float = 0.9
    num_exits: int = 5

    def select(self, confidences, **_) -> int:
        for i, c in enumerate(confidences):
            if float(np.mean(c)) >= self.threshold:
                return i + 1
        return self.num_exits


@dataclass
class DeadlineDemotionPolicy:
    """Straggler mitigation: given remaining budget and per-exit predicted
    latency, pick the deepest exit that still meets the deadline."""
    exit_latencies_s: list            # predicted latency per exit point
    floor_exit: int = 1

    def select(self, remaining_s: float, **_) -> int:
        best = self.floor_exit
        for i, t in enumerate(self.exit_latencies_s, start=1):
            if t <= remaining_s:
                best = i
        return best
