"""Inference graph abstraction — what the Edgent planner operates on.

A model is presented to the planner as a set of *branches* (one per exit
point, paper Fig. 4): branch ``i`` is an ordered list of :class:`GraphLayer`,
each carrying its Table-I regression features, its output size in bytes, and
an executable closure.  Both the branchy AlexNet (layer granularity) and the
LM architectures (transformer-segment granularity) lower to this form, which
is exactly the structure Algorithm 1 searches over.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Sequence

import numpy as np


@dataclass
class GraphLayer:
    name: str
    kind: str                      # Table-I type, or "block" for LM segments
    features: Dict[str, float]    # regression features
    out_bytes: int                 # activation size shipped if we cut *after* this layer
    flops: float = 0.0             # analytic FLOPs (roofline latency model)
    bytes_moved: float = 0.0       # analytic HBM traffic
    run: Optional[Callable] = None  # (params, x) -> x
    state_bytes: int = 0           # recurrent state that must ship with a cut here


@dataclass
class InferenceGraph:
    """All branches of a multi-exit model."""
    name: str
    branches: List[List[GraphLayer]]     # index i -> exit point i+1 (paper: 1-based)
    accuracy: List[float]                # measured accuracy per exit point
    input_bytes: int                     # the `Input` term of Algorithm 1
    result_bytes: int = 64               # final result return size

    @property
    def num_exits(self) -> int:
        return len(self.branches)

    def cut_bytes(self, exit_idx: int, p: int) -> int:
        """Bytes shipped when the first ``p`` layers of branch ``exit_idx``
        (1-based) run on the edge: the activation after layer p plus any
        recurrent state (DESIGN.md §4, rwkv/zamba)."""
        branch = self.branches[exit_idx - 1]
        if p <= 0:
            return 0
        if p >= len(branch):
            return self.result_bytes
        lay = branch[p - 1]
        return lay.out_bytes + lay.state_bytes


def alexnet_graph(net, accuracy: Optional[Sequence[float]] = None,
                  batch: int = 1, dtype_bytes: int = 4) -> InferenceGraph:
    """Lower a BranchyAlexNet to an InferenceGraph."""
    from repro.models.alexnet import layer_features, layer_out_shape

    branches = []
    for i in range(1, net.num_exits + 1):
        layers = []
        shapes = net.branch_shapes(i)
        for spec, (in_shape, out_shape) in zip(net.branch_layers(i), shapes):
            layers.append(GraphLayer(
                name=spec.name,
                kind=spec.kind,
                features=layer_features(spec, in_shape),
                out_bytes=int(np.prod(out_shape)) * batch * dtype_bytes,
                run=(lambda spec: lambda params, x: _apply(net, spec, params, x))(spec),
            ))
        branches.append(layers)
    img = net.cfg.image_size
    acc = list(accuracy) if accuracy is not None else [0.5 + 0.08 * i for i in range(net.num_exits)]
    return InferenceGraph(
        name=net.cfg.name,
        branches=branches,
        accuracy=acc,
        input_bytes=img * img * net.cfg.channels * batch * dtype_bytes,
        result_bytes=net.cfg.num_classes * batch * dtype_bytes,
    )


def _apply(net, spec, params, x):
    from repro.models.alexnet import apply_layer
    return apply_layer(spec, params.get(spec.name, {}), x)


def lm_graph(cfg, accuracy: Optional[Sequence[float]] = None,
             batch: int = 1, seq: int = 1, dtype_bytes: int = 2) -> InferenceGraph:
    """Lower an LM ModelConfig to an InferenceGraph at *segment* granularity
    (a cut between segments == a pipeline cut across the pod boundary).

    Exit point i (1-based) = run segments [0, i]; branch i's layer list is
    those segments.  Used by the datacenter-scale planner; per-layer FLOPs /
    bytes are analytic (roofline latency model feeds on them).
    """
    from repro.models.api import Model

    model = Model(cfg)
    stack = model.stack
    segs = stack.segment_lengths(cfg)
    d = cfg.d_model
    act_bytes = batch * seq * d * dtype_bytes

    def seg_layer(si: int, n_units: int) -> GraphLayer:
        flops = _segment_flops(cfg, n_units, batch, seq)
        state = 0
        if cfg.family == "ssm":
            state = n_units * batch * cfg.num_heads * cfg.hd * cfg.hd * 4
        elif cfg.family == "hybrid":
            from repro.models import mamba2 as M2
            state = n_units * batch * M2.n_heads(cfg) * cfg.ssm_state * M2.DH * 4
        return GraphLayer(
            name=f"seg{si}", kind="block",
            features={"in_size": float(act_bytes), "flops": flops},
            out_bytes=act_bytes, flops=flops,
            bytes_moved=_segment_param_bytes(cfg, n_units, dtype_bytes),
            state_bytes=state,
        )

    layers = [seg_layer(si, n) for si, n in enumerate(segs)]
    # exit head cost appended per branch
    branches = []
    for i in range(1, len(segs) + 1):
        b = list(layers[:i])
        head_flops = 2.0 * batch * seq * d * cfg.vocab_size
        b.append(GraphLayer(name=f"exit{i}", kind="fc",
                            features={"in_size": float(act_bytes),
                                      "out_size": float(batch * seq * cfg.vocab_size * dtype_bytes)},
                            out_bytes=batch * seq * 8,  # sampled token + conf
                            flops=head_flops,
                            bytes_moved=cfg.vocab_size * d * dtype_bytes))
        branches.append(b)
    acc = list(accuracy) if accuracy is not None else \
        [0.55 + 0.35 * (i + 1) / len(segs) for i in range(len(segs))]
    return InferenceGraph(
        name=cfg.name, branches=branches, accuracy=acc,
        input_bytes=batch * seq * 4, result_bytes=batch * 8,
    )


def _segment_flops(cfg, n_units, batch, seq) -> float:
    """6*params_active per token forward? No — forward-only: 2*params_active
    per token, plus attention O(S^2)."""
    # active params per unit
    from repro.config import ModelConfig
    attn = cfg._attn_params()
    if cfg.family == "ssm":
        per_unit = cfg._rwkv_layer_params()
    elif cfg.family == "hybrid":
        per_unit = cfg._mamba2_layer_params()
    elif cfg.num_experts and cfg.moe_period == 2:
        per_unit = 2 * attn + cfg._dense_ffn_params() + cfg.experts_per_tok * 3 * cfg.d_model * cfg.d_ff
    elif cfg.num_experts:
        per_unit = attn + cfg.experts_per_tok * 3 * cfg.d_model * cfg.d_ff
    else:
        per_unit = attn + cfg._dense_ffn_params()
    flops = 2.0 * per_unit * batch * seq * n_units
    if cfg.family not in ("ssm",):
        # causal attention score+value FLOPs
        flops += n_units * 2.0 * 2.0 * batch * seq * seq / 2 * cfg.num_heads * cfg.hd
    return flops


def _segment_param_bytes(cfg, n_units, dtype_bytes) -> float:
    if cfg.family == "ssm":
        per = cfg._rwkv_layer_params()
    elif cfg.family == "hybrid":
        per = cfg._mamba2_layer_params()
    elif cfg.num_experts:
        per = cfg._attn_params() + cfg._moe_ffn_params() / max(1, cfg.moe_period)
    else:
        per = cfg._attn_params() + cfg._dense_ffn_params()
    return float(per * n_units * dtype_bytes)
