"""Bandwidth trace generators reproducing the statistics of the paper's
datasets (neither ships offline):

* ``oboe_like_traces``   — Sec. V-C: 428 synthetic traces of 49 download
  chunks each, piecewise-stationary, state means spanning 0..6 Mbps; each
  trace's mean is one *bandwidth state* for the configuration map.
* ``belgium_lte_like``   — HTTP/2 4G/LTE mobility logs (van der Hooft et al.):
  mobility-segmented trace with mode-dependent mean/variance, scaled into
  0..10 Mbps as the paper does.
* ``dcn_trace``          — datacenter adaptation: inter-pod link GB/s with
  congestion episodes (used by the LM serving experiments).

All values are bytes/s.
"""
from __future__ import annotations

from typing import List

import numpy as np

MBPS = 1e6 / 8  # bytes/s


def oboe_like_traces(seed: int = 0, num: int = 428, chunks: int = 49,
                     lo_mbps: float = 0.05, hi_mbps: float = 6.0
                     ) -> List[np.ndarray]:
    rng = np.random.default_rng(seed)
    traces = []
    means = np.linspace(lo_mbps, hi_mbps, num)
    rng.shuffle(means)
    for m in means:
        segs = rng.integers(1, 4)
        bounds = sorted(rng.choice(np.arange(5, chunks - 1), segs - 1, replace=False)) \
            if segs > 1 else []
        levels = np.clip(rng.normal(m, 0.15 * m + 0.02, segs), 0.01, hi_mbps)
        trace = np.empty(chunks)
        prev = 0
        for lvl, b in zip(levels, list(bounds) + [chunks]):
            trace[prev:b] = np.clip(rng.normal(lvl, 0.05 * lvl + 0.01, b - prev), 0.01, hi_mbps)
            prev = b
        traces.append(trace * MBPS)
    return traces


def belgium_lte_like(seed: int = 0, length: int = 600, transport: str = "bus",
                     hi_mbps: float = 10.0) -> np.ndarray:
    """Mobility trace: piecewise segments (stops, moving, handovers) with
    mode-dependent statistics, scaled to [0, hi_mbps] (paper Sec. V-C)."""
    params = {
        "foot": (6.0, 0.8, 40), "bicycle": (5.0, 1.2, 30),
        "bus": (4.0, 1.8, 25), "train": (3.0, 2.5, 15), "car": (5.0, 2.0, 20),
    }[transport]
    mean, vol, seg_len = params
    rng = np.random.default_rng(seed)
    out = np.empty(length)
    t = 0
    level = mean
    while t < length:
        n = int(rng.integers(seg_len // 2, seg_len * 2))
        level = float(np.clip(rng.normal(mean, vol), 0.2, hi_mbps))
        seg = np.clip(rng.normal(level, 0.15 * level, n), 0.05, hi_mbps)
        out[t : t + n] = seg[: length - t]
        t += n
    return out * MBPS


def dcn_trace(seed: int = 0, length: int = 600, base_gbps: float = 400.0,
              congested_gbps: float = 40.0) -> np.ndarray:
    """Inter-pod DCN bandwidth with congestion episodes (bytes/s)."""
    rng = np.random.default_rng(seed)
    out = np.full(length, base_gbps)
    t = 0
    while t < length:
        t += int(rng.integers(40, 120))
        dur = int(rng.integers(10, 60))
        out[t : t + dur] = congested_gbps * rng.uniform(0.5, 2.0)
        t += dur
    noise = rng.normal(1.0, 0.05, length)
    return np.clip(out * noise, 1.0, None) * 1e9 / 8
