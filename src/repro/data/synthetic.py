"""Synthetic datasets (no datasets ship offline; both are class-structured so
models genuinely learn and per-exit accuracy differences are measurable).

* ``cifar_like``  — 32x32x3 images: each class has a Gaussian template plus
  noise; linear separability is controlled by ``noise``, so deeper exits
  (more capacity) measurably outperform shallow exits after training.
* ``token_stream`` — integer LM batches from a mixture of k-gram generators,
  giving a learnable next-token structure.
"""
from __future__ import annotations

from typing import Iterator, Tuple

import jax
import jax.numpy as jnp
import numpy as np


def cifar_like(rng: np.random.Generator, num: int, num_classes: int = 10,
               noise: float = 0.7, image: int = 32, channels: int = 3
               ) -> Tuple[np.ndarray, np.ndarray]:
    """Returns (x [N,H,W,C] f32, y [N] int32)."""
    tpl_rng = np.random.default_rng(1234)  # fixed templates across calls
    templates = tpl_rng.normal(0, 1, (num_classes, image, image, channels))
    # low-frequency templates: blur by average pooling then upsampling
    t = templates.reshape(num_classes, image // 4, 4, image // 4, 4, channels).mean((2, 4))
    templates = np.repeat(np.repeat(t, 4, axis=1), 4, axis=2)
    y = rng.integers(0, num_classes, num)
    x = templates[y] + noise * rng.normal(0, 1, (num, image, image, channels))
    return x.astype(np.float32), y.astype(np.int32)


def cifar_batches(seed: int, batch: int, num_classes: int = 10,
                  noise: float = 0.7) -> Iterator[Tuple[np.ndarray, np.ndarray]]:
    rng = np.random.default_rng(seed)
    while True:
        yield cifar_like(rng, batch, num_classes, noise)


def token_stream(rng: np.random.Generator, batch: int, seq: int,
                 vocab: int, order: int = 2) -> np.ndarray:
    """Markov-ish token batch [B, S] with learnable bigram structure."""
    tab_rng = np.random.default_rng(99)
    nxt = tab_rng.integers(0, vocab, (vocab,))
    toks = np.empty((batch, seq), np.int64)
    toks[:, 0] = rng.integers(0, vocab, batch)
    noise = rng.random((batch, seq)) < 0.15
    rnd = rng.integers(0, vocab, (batch, seq))
    for t in range(1, seq):
        toks[:, t] = np.where(noise[:, t], rnd[:, t], nxt[toks[:, t - 1]])
    return toks.astype(np.int32)


def token_batches(seed: int, batch: int, seq: int, vocab: int
                  ) -> Iterator[np.ndarray]:
    rng = np.random.default_rng(seed)
    while True:
        yield token_stream(rng, batch, seq, vocab)
