"""Sharded host data pipeline: background prefetch + device put with the
batch sharded over the mesh ``data`` (and ``pod``) axes."""
from __future__ import annotations

import queue
import threading
from typing import Callable, Iterator, Optional

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


class PrefetchLoader:
    """Wraps a host batch iterator with a background prefetch thread and
    (optionally) sharded device placement."""

    def __init__(self, it: Iterator, mesh: Optional[Mesh] = None,
                 spec: Optional[P] = None, depth: int = 2):
        self.it = it
        self.mesh, self.spec = mesh, spec
        self.q: queue.Queue = queue.Queue(maxsize=depth)
        self._stop = threading.Event()
        self.thread = threading.Thread(target=self._worker, daemon=True)
        self.thread.start()

    def _place(self, batch):
        if self.mesh is None:
            return jax.tree.map(jax.numpy.asarray, batch)
        def put(x):
            return jax.device_put(x, NamedSharding(self.mesh, self.spec))
        return jax.tree.map(put, batch)

    def _worker(self):
        for batch in self.it:
            if self._stop.is_set():
                return
            self.q.put(batch)

    def __iter__(self):
        return self

    def __next__(self):
        batch = self.q.get()
        return self._place(batch)

    def close(self):
        self._stop.set()
