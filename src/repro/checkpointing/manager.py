"""Checkpoint manager: atomic, async, restartable.

Layout: ``<dir>/step_<N>/`` with one ``.npy`` per pytree leaf plus a
``manifest.json`` (treedef paths, shapes, dtypes).  Writes go to
``step_<N>.tmp`` and are atomically renamed, so a crash mid-save never
corrupts the restore point — the fault-tolerance contract for
checkpoint/restart at cluster scale.  Saves can run on a background thread
(async) so the train loop is not blocked; ``wait()`` joins before the next
save or at exit.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Any, Optional

import jax
import numpy as np


def _flatten_with_paths(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    out = []
    for path, leaf in flat:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        out.append((key, leaf))
    return out, treedef


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3):
        self.dir = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)
        self._thread: Optional[threading.Thread] = None

    # ------------------------------------------------------------ save
    def save(self, step: int, tree: Any, *, async_: bool = True):
        # snapshot to host memory synchronously (cheap), write async
        flat, _ = _flatten_with_paths(tree)
        host = [(k, np.asarray(v)) for k, v in flat]
        self.wait()
        if async_:
            self._thread = threading.Thread(
                target=self._write, args=(step, host), daemon=True)
            self._thread.start()
        else:
            self._write(step, host)

    def _write(self, step: int, host):
        tmp = os.path.join(self.dir, f"step_{step:09d}.tmp")
        final = os.path.join(self.dir, f"step_{step:09d}")
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        os.makedirs(tmp)
        manifest = {}
        for i, (key, arr) in enumerate(host):
            fname = f"leaf_{i:05d}.npy"
            np.save(os.path.join(tmp, fname), arr)
            manifest[key] = {"file": fname, "shape": list(arr.shape),
                             "dtype": str(arr.dtype)}
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump({"step": step, "leaves": manifest}, f)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)           # atomic publish
        self._gc()

    def _gc(self):
        steps = self.all_steps()
        for s in steps[: -self.keep]:
            shutil.rmtree(os.path.join(self.dir, f"step_{s:09d}"),
                          ignore_errors=True)

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    # ------------------------------------------------------------ restore
    def all_steps(self):
        out = []
        for name in os.listdir(self.dir):
            if name.startswith("step_") and not name.endswith(".tmp"):
                out.append(int(name.split("_")[1]))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, tree_like: Any, step: Optional[int] = None):
        """Restore into the structure of ``tree_like`` (values replaced)."""
        self.wait()
        step = step if step is not None else self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {self.dir}")
        d = os.path.join(self.dir, f"step_{step:09d}")
        with open(os.path.join(d, "manifest.json")) as f:
            manifest = json.load(f)["leaves"]
        flat, treedef = _flatten_with_paths(tree_like)
        leaves = []
        for key, ref in flat:
            meta = manifest[key]
            arr = np.load(os.path.join(d, meta["file"]))
            leaves.append(jax.numpy.asarray(arr, dtype=ref.dtype if hasattr(ref, "dtype") else None))
        return jax.tree_util.tree_unflatten(treedef, leaves), step
