"""Fault tolerance: checkpoint/restart orchestration + failure simulation.

At 1000+ node scale the relevant contract is: (a) any step may die; (b) the
job resumes from the last durable checkpoint with identical results; (c) the
blast radius of a slow/flaky worker is bounded (straggler mitigation).  This
module provides the host-side pieces; sharded-state save/restore lives in
``repro.checkpointing``; the straggler knob is Edgent's own early-exit
demotion (core/early_exit.py).
"""
from __future__ import annotations

import random
import time
from dataclasses import dataclass, field
from typing import Any, Callable, Optional

from repro.checkpointing import CheckpointManager


class SimulatedFailure(RuntimeError):
    pass


@dataclass
class FailureInjector:
    """Deterministic failure schedule for tests: fail at given steps."""
    fail_at: tuple = ()
    fired: set = field(default_factory=set)

    def maybe_fail(self, step: int):
        if step in self.fail_at and step not in self.fired:
            self.fired.add(step)
            raise SimulatedFailure(f"injected failure at step {step}")


@dataclass
class ResilientLoop:
    """Run a step function with checkpoint/restart.

    ``state`` is any pytree (params, opt state, data cursor).  On failure the
    loop restores the latest checkpoint and replays — the cluster-scale
    restart path, exercised in-process.
    """
    ckpt: CheckpointManager
    save_every: int = 50
    max_restarts: int = 10

    def run(self, state: Any, step_fn: Callable[[Any, int], Any],
            num_steps: int, start_step: int = 0,
            injector: Optional[FailureInjector] = None,
            on_restart: Optional[Callable[[int], None]] = None):
        restarts = 0
        step = start_step
        # resume if a checkpoint exists
        latest = self.ckpt.latest_step()
        if latest is not None and latest > step:
            state, step = self.ckpt.restore(state)
        while step < num_steps:
            try:
                if injector is not None:
                    injector.maybe_fail(step)
                state = step_fn(state, step)
                step += 1
                if step % self.save_every == 0 or step == num_steps:
                    self.ckpt.save(step, state)
            except SimulatedFailure:
                restarts += 1
                if restarts > self.max_restarts:
                    raise
                latest = self.ckpt.latest_step()
                if latest is None:
                    step = start_step   # cold restart
                else:
                    state, step = self.ckpt.restore(state)
                if on_restart:
                    on_restart(step)
        self.ckpt.wait()
        return state, {"restarts": restarts, "final_step": step}


@dataclass
class Heartbeat:
    """Book-keeping for worker liveness (control-plane simulation)."""
    timeout_s: float = 10.0
    last: dict = field(default_factory=dict)

    def beat(self, worker: str, t: Optional[float] = None):
        self.last[worker] = t if t is not None else time.monotonic()

    def dead(self, now: Optional[float] = None):
        now = now if now is not None else time.monotonic()
        return [w for w, t in self.last.items() if now - t > self.timeout_s]
