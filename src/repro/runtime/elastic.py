"""Elastic scaling: re-plan when tier capacity or mesh size changes.

Two levers, both Edgent-native:
* serving — the planner re-solves (exit, partition) with a re-scaled
  RooflineLatencyModel when chips join/leave a tier;
* training — the data-parallel degree changes; batch is re-sharded and the
  step re-jitted for the surviving mesh (dry-run-validated re-mesh).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional, Tuple

import jax

from repro.core.latency_model import RooflineLatencyModel
from repro.core.partitioner import CoInferencePlan, optimize_with_fallback


@dataclass
class TierSpec:
    chips: int
    efficiency: float = 0.5


@dataclass
class ElasticPlanner:
    """Re-derive co-inference plans as tier sizes change."""
    graph: object
    latency_req_s: float
    link_bps: float

    def plan_for(self, edge: TierSpec, device: TierSpec) -> CoInferencePlan:
        f_edge = RooflineLatencyModel(chips=edge.chips, efficiency=edge.efficiency)
        f_dev = RooflineLatencyModel(chips=device.chips, efficiency=device.efficiency)
        return optimize_with_fallback(self.graph, f_edge, f_dev,
                                      self.link_bps, self.latency_req_s)

    def shrink_event(self, edge: TierSpec, device: TierSpec,
                     lost_chips: int) -> Tuple[CoInferencePlan, TierSpec]:
        """A failure removed chips from the edge tier: re-plan."""
        new_edge = TierSpec(max(1, edge.chips - lost_chips), edge.efficiency)
        return self.plan_for(new_edge, device), new_edge


def viable_mesh(total_devices: int, model_parallel: int) -> Tuple[int, int]:
    """Largest (data, model) grid for the surviving device count, keeping the
    model-parallel degree fixed (params resharding-free)."""
    data = max(1, total_devices // model_parallel)
    return data, model_parallel
