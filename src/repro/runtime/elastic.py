"""Elastic scaling: re-plan when tier capacity or mesh size changes.

Two levers, both Edgent-native:
* serving — the planner re-solves (exit, partition) with a re-scaled
  RooflineLatencyModel when chips join/leave a tier;
* training — the data-parallel degree changes; batch is re-sharded and the
  step re-jitted for the surviving mesh (dry-run-validated re-mesh).

The fleet simulator reuses this for autoscaled edges
(:mod:`repro.fleet.elastic`): an :class:`ElasticPlanner` built with the
fleet's *calibrated* latency models (``f_edge``/``f_dev`` + ``ref_chips``)
re-prices queued requests' plans when a scale-down changes an edge's
effective speed-per-slot, at the request's own link bandwidth
(``plan_for(..., link_bps=...)``).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional, Tuple

import jax

from repro.core.latency_model import (RooflineLatencyModel,
                                      ScaledLatencyModel)
from repro.core.partitioner import CoInferencePlan, optimize_with_fallback


@dataclass
class TierSpec:
    chips: int
    efficiency: float = 0.5


@dataclass
class ElasticPlanner:
    """Re-derive co-inference plans as tier sizes change.

    Two calibration modes:
    * default — per-tier :class:`RooflineLatencyModel` built from each
      :class:`TierSpec`'s (chips, efficiency);
    * explicit — ``f_edge``/``f_dev`` are pre-calibrated per-layer latency
      models (e.g. the fleet's rescaled rooflines) priced for ``ref_chips``
      edge slots; tier sizes then *re-scale* them, so halving the chips
      doubles the per-layer time on the identical cost surface the original
      planner optimized over.
    """
    graph: object
    latency_req_s: float
    link_bps: float
    f_edge: object = None
    f_dev: object = None
    ref_chips: int = 1

    def _models(self, edge: TierSpec, device: TierSpec):
        if self.f_edge is not None:
            f_edge = ScaledLatencyModel(
                self.f_edge, self.ref_chips / max(1, edge.chips))
        else:
            f_edge = RooflineLatencyModel(chips=edge.chips,
                                          efficiency=edge.efficiency)
        if self.f_dev is not None:
            f_dev = self.f_dev if device.chips <= 1 else \
                ScaledLatencyModel(self.f_dev, 1.0 / device.chips)
        else:
            f_dev = RooflineLatencyModel(chips=device.chips,
                                         efficiency=device.efficiency)
        return f_edge, f_dev

    def plan_for(self, edge: TierSpec, device: TierSpec, *,
                 link_bps: Optional[float] = None) -> CoInferencePlan:
        f_edge, f_dev = self._models(edge, device)
        return optimize_with_fallback(
            self.graph, f_edge, f_dev,
            self.link_bps if link_bps is None else link_bps,
            self.latency_req_s)

    def shrink_event(self, edge: TierSpec, device: TierSpec,
                     lost_chips: int) -> Tuple[CoInferencePlan, TierSpec]:
        """A failure removed chips from the edge tier: re-plan.  The tier
        never shrinks below one chip (clamped), so a plan always exists."""
        new_edge = TierSpec(max(1, edge.chips - lost_chips), edge.efficiency)
        return self.plan_for(new_edge, device), new_edge


def viable_mesh(total_devices: int, model_parallel: int) -> Tuple[int, int]:
    """Largest (data, model) grid for the surviving device count, keeping the
    model-parallel degree fixed (params resharding-free)."""
    data = max(1, total_devices // model_parallel)
    return data, model_parallel
