"""SLO-aware admission + batching.

Requests carry an end-to-end deadline.  The scheduler forms decode batches,
tracks each batch's remaining budget, and exposes the *deadline demotion*
hook: when the predicted time to finish at the current exit point exceeds the
remaining budget, the batch is demoted to an earlier exit (Edgent's
right-sizing used as straggler mitigation — DESIGN.md §2)."""
from __future__ import annotations

import heapq
from dataclasses import dataclass, field
from typing import List, Optional


@dataclass(order=True)
class _Queued:
    deadline: float
    idx: int = field(compare=False)
    arrival_s: float = field(compare=False, default=0.0)


class SLOScheduler:
    """Earliest-deadline-first admission into fixed-size batches."""

    def __init__(self, batch_size: int):
        self.batch_size = batch_size
        self.heap: List[_Queued] = []

    def submit(self, idx: int, deadline: float, arrival_s: float = 0.0):
        heapq.heappush(self.heap, _Queued(deadline, idx, arrival_s))

    def next_batch(self, now: Optional[float] = None) -> List[int]:
        """EDF batch.  With ``now`` given, only requests that have already
        arrived are admitted — a future request must not hold up an arrived
        one (empty result => nothing has arrived yet; see
        :meth:`earliest_arrival`)."""
        if now is None:
            out = []
            while self.heap and len(out) < self.batch_size:
                out.append(heapq.heappop(self.heap).idx)
            return out
        arrived = sorted(q for q in self.heap if q.arrival_s <= now)
        take = arrived[: self.batch_size]
        for q in take:
            self.heap.remove(q)
        heapq.heapify(self.heap)
        return [q.idx for q in take]

    def earliest_arrival(self) -> float:
        return min(q.arrival_s for q in self.heap)

    def __len__(self):
        return len(self.heap)


def pick_exit(remaining_s: float, per_exit_step_s: List[float],
              tokens_left: int, preferred: int) -> int:
    """Deepest exit (<= preferred) whose projected completion fits the
    remaining budget; floor at exit 1."""
    for e in range(preferred, 0, -1):
        if per_exit_step_s[e - 1] * tokens_left <= remaining_s:
            return e
    return 1
