"""Slot-resident decode arena: persistent stacked KV state for one edge.

The PR-9 batched decode path re-stacks every request's KV leaves host-side
each round (``CoInferenceStepper.decode_step_batch``), pads groups by
replicating rows, and compiles one variant per ``(exit, batch-bucket)``.
A :class:`DecodeArena` removes all three costs: cache leaves are
preallocated ``[slots, ...]`` stacks padded along the sequence axis to a
shared arena length, a request scatters its row in **once** at admission
(``admit``), stays resident across rounds, and gathers it back out only
when it leaves (``extract``, for handover shipping).  Per-round device
traffic is just the tiny (tokens, positions, active-mask) arrays; the
compiled call shape never changes, so there is at most one variant per
model exit (``CoInferenceStepper.decode_fn_arena``).

Bit-identity with the serial path rests on two facts, both pinned by
tests/test_arena.py:

* ``vmap`` rows are independent — the per-row math of the arena call is
  the per-request serial step (the PR-9 contract); and
* the decode attention bias masks positions beyond the cache write head
  with ``-1e30`` (``models/layers``), so the zero-initialized padding
  between a request's true cache length and the arena length contributes
  ``exp(-1e30 - m) == +0.0`` exactly — extra trailing zeros in the
  softmax/PV reductions are exact no-ops.

Inactive slots decode dummy inputs (token 0 at position 0) whose cache
writes are discarded by the masked commit; their FLOPs are counted in
``stepper.arena_masked_rows`` so occupancy waste stays observable.
"""
from __future__ import annotations

import heapq
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp

__all__ = ["DecodeArena", "pow2"]


def pow2(n: int) -> int:
    """Smallest power of two >= n (>= 1)."""
    b = 1
    while b < n:
        b <<= 1
    return b


class DecodeArena:
    """Persistent ``[slots, ...]`` decode state for one edge's batch.

    ``slots`` and ``length`` are sized up front (edge capacity, workload
    max cache length) so steady-state geometry — and therefore the set of
    compiled variants — is fixed; both still grow on demand (slots double,
    length re-buckets) when a workload outruns its hints.  ``bucket``
    selects the length policy: ``"pow2"`` rounds the arena length up to a
    power of two (fewer recompiles if the hint was wrong), ``"exact"``
    keeps it as given.
    """

    def __init__(self, model, *, slots: int, length: int, dtype,
                 bucket: str = "pow2", stepper=None):
        if bucket not in ("pow2", "exact"):
            raise ValueError(f"unknown arena bucket policy {bucket!r}: "
                             "expected 'pow2' or 'exact'")
        self.model = model
        self.dtype = dtype
        self.bucket = bucket
        self.stepper = stepper
        self.slots = pow2(max(1, slots))
        self.length = self._bucket_len(max(1, length))
        # per-leaf sequence axis, discovered by diffing cache shapes at two
        # lengths (-1 = length-independent leaf); axes are a tree congruent
        # with the cache so tree_maps stay structural
        s1 = jax.eval_shape(lambda: model.init_cache(1, 17, dtype=dtype))
        s2 = jax.eval_shape(lambda: model.init_cache(1, 19, dtype=dtype))
        def seq_axis(a, b):
            diff = [i for i, (x, y) in enumerate(zip(a.shape, b.shape))
                    if x != y]
            if len(diff) > 1:
                raise ValueError(
                    f"cache leaf varies on {len(diff)} axes with max_seq "
                    f"({a.shape} vs {b.shape}); arena needs exactly one "
                    "sequence axis per leaf")
            return diff[0] if diff else -1
        self._seq_ax = jax.tree_util.tree_map(seq_axis, s1, s2)
        self.cache = self._alloc(self.slots, self.length)
        self._free: List[int] = list(range(self.slots))
        heapq.heapify(self._free)
        self._slot_of: Dict[object, int] = {}
        self._true_len: Dict[object, int] = {}

    # ------------------------------------------------------------ geometry
    def _bucket_len(self, n: int) -> int:
        return pow2(n) if self.bucket == "pow2" else n

    def _alloc(self, slots: int, length: int):
        shapes = jax.eval_shape(
            lambda: self.model.init_cache(1, length, dtype=self.dtype))
        return jax.tree_util.tree_map(
            lambda s: jnp.zeros((slots,) + s.shape, s.dtype), shapes)

    def sig(self) -> tuple:
        """Hashable shape/dtype signature of the arena leaves — the jit key
        of the compiled arena variant (one per (exit, sig))."""
        return tuple((tuple(leaf.shape), str(leaf.dtype))
                     for leaf in jax.tree_util.tree_leaves(self.cache))

    @property
    def active(self) -> int:
        return len(self._slot_of)

    def has(self, rid) -> bool:
        return rid in self._slot_of

    def slot(self, rid) -> int:
        return self._slot_of[rid]

    def true_len(self, rid) -> int:
        """The resident request's own cache length (its serial-path
        ``max_seq``); ``extract`` slices the arena row back to it."""
        return self._true_len[rid]

    def _count(self, name: str, n: int = 1) -> None:
        if self.stepper is not None:
            setattr(self.stepper, name, getattr(self.stepper, name) + n)

    def _grow_slots(self) -> None:
        new_slots = self.slots * 2
        self.cache = jax.tree_util.tree_map(
            lambda leaf: jnp.concatenate(
                [leaf, jnp.zeros((new_slots - self.slots,) + leaf.shape[1:],
                                 leaf.dtype)], axis=0),
            self.cache)
        for s in range(self.slots, new_slots):
            heapq.heappush(self._free, s)
        self.slots = new_slots
        self._count("arena_grows")

    def _grow_length(self, need: int) -> None:
        new_len = self._bucket_len(need)
        def grow(leaf, ax):
            if ax < 0:
                return leaf
            pad = [(0, 0)] * leaf.ndim
            pad[ax + 1] = (0, new_len - leaf.shape[ax + 1])  # +1: slots axis
            return jnp.pad(leaf, pad)
        self.cache = jax.tree_util.tree_map(grow, self.cache, self._seq_ax)
        self.length = new_len
        self._count("arena_grows")

    # ------------------------------------------------------------ residency
    def admit(self, rid, cache) -> int:
        """Scatter one request's B=1 cache into a free slot row (padded
        along the sequence axis with zeros — inert under the decode
        attention mask) and return the slot.  The scatter is the only
        per-request device write until the request leaves."""
        assert rid not in self._slot_of, f"rid {rid!r} already resident"
        lens = [leaf.shape[ax] for leaf, ax in zip(
            jax.tree_util.tree_leaves(cache),
            jax.tree_util.tree_leaves(self._seq_ax)) if ax >= 0]
        true_len = max(lens) if lens else self.length
        if true_len > self.length:
            self._grow_length(true_len)
        if not self._free:
            self._grow_slots()
        slot = heapq.heappop(self._free)
        def pad_row(leaf, ax):
            if ax >= 0 and leaf.shape[ax] < self.length:
                pad = [(0, 0)] * leaf.ndim
                pad[ax] = (0, self.length - leaf.shape[ax])
                leaf = jnp.pad(leaf, pad)
            return leaf
        row = jax.tree_util.tree_map(pad_row, cache, self._seq_ax)
        self.cache = jax.tree_util.tree_map(
            lambda a, r: a.at[slot].set(r), self.cache, row)
        self._slot_of[rid] = slot
        self._true_len[rid] = true_len
        self._count("arena_admits")
        return slot

    def evict(self, rid) -> None:
        """Free the slot (bookkeeping only — stale rows are masked out of
        every subsequent call and fully overwritten on re-admission)."""
        slot = self._slot_of.pop(rid)
        del self._true_len[rid]
        heapq.heappush(self._free, slot)
        self._count("arena_evicts")

    def extract(self, rid):
        """Gather the resident row back out as a standalone B=1 cache —
        sliced to the request's own length, bitwise equal to what the
        serial path would hold — and evict.  The handover path ships this
        snapshot to the destination edge, whose arena re-admits it."""
        slot = self._slot_of[rid]
        true_len = self._true_len[rid]
        def cut(leaf, ax):
            row = leaf[slot]
            if ax >= 0 and row.shape[ax] > true_len:
                row = jax.lax.slice_in_dim(row, 0, true_len, axis=ax)
            return row
        out = jax.tree_util.tree_map(cut, self.cache, self._seq_ax)
        self.evict(rid)
        return out
