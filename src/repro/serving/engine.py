"""Batched-request serving engine with Edgent planning.

Pipeline per batch: admit (SLO scheduler) -> prefill -> decode loop.  Before
every decode step the engine consults the planner with the *current*
bandwidth (static Algorithm 1 or dynamic Algorithm 3), obtaining the
(exit point, partition) plan; the decode step executes the right-sized model
(``exit_point`` static argument -> the compiled variant that stops at that
segment), virtual time is billed per tier + link, and deadline demotion
rescues batches that fall behind.

Token values come from real model execution (smoke-scale on CPU); timing
comes from the latency models — deterministic and host-independent.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.graph import InferenceGraph
from repro.core.partitioner import branch_latency
from repro.core.planner import EdgentPlanner
from repro.models.api import Model
from repro.serving.scheduler import SLOScheduler, pick_exit
from repro.serving.tiers import Link


@dataclass
class Request:
    rid: int
    prompt: np.ndarray            # [S] int32
    max_new_tokens: int
    slo_s: float
    arrival_s: float = 0.0


@dataclass
class ServeStats:
    latencies: List[float] = field(default_factory=list)
    met_slo: List[bool] = field(default_factory=list)
    exits: List[int] = field(default_factory=list)
    partitions: List[int] = field(default_factory=list)
    throughputs: List[float] = field(default_factory=list)
    tokens: Dict[int, List[int]] = field(default_factory=dict)

    def summary(self) -> Dict[str, float]:
        return {
            "requests": len(self.latencies),
            "p50_latency_s": float(np.percentile(self.latencies, 50)) if self.latencies else 0.0,
            "p99_latency_s": float(np.percentile(self.latencies, 99)) if self.latencies else 0.0,
            "slo_attainment": float(np.mean(self.met_slo)) if self.met_slo else 0.0,
            "mean_exit": float(np.mean(self.exits)) if self.exits else 0.0,
            "mean_throughput_tps": float(np.mean(self.throughputs)) if self.throughputs else 0.0,
        }


class ServingEngine:
    def __init__(self, model: Model, params, graph: InferenceGraph,
                 planner: EdgentPlanner, link: Link, *, batch_size: int = 4,
                 max_seq: int = 128, dtype=jnp.float32,
                 dynamic: bool = False, demote_on_deadline: bool = True):
        self.model, self.params, self.graph = model, params, graph
        self.planner, self.link = planner, link
        self.batch_size, self.max_seq = batch_size, max_seq
        self.dtype = dtype
        self.dynamic = dynamic
        self.demote = demote_on_deadline
        self.sched = SLOScheduler(batch_size)
        self._decode_jit: Dict[Optional[int], object] = {}
        # the planner's graph may describe the FULL-size architecture while
        # the executing model is the reduced config: map exit points
        # proportionally (graph exit i -> model segment)
        self.n_graph = graph.num_exits
        self.n_model = model.num_segments
        self._exit_points = list(range(1, self.n_graph + 1))

    # ------------------------------------------------------------ timing
    def _step_time(self, exit_point: int, partition: int, bw: float) -> float:
        """Virtual per-token latency of (exit, partition) at bandwidth bw."""
        return branch_latency(self.graph, exit_point, partition,
                              self.planner.f_edge, self.planner.f_device, bw)

    def _to_model_exit(self, graph_exit: int) -> int:
        return max(1, round(graph_exit * self.n_model / self.n_graph))

    # ------------------------------------------------------------ compiled steps
    def _decode_fn(self, graph_exit: Optional[int]):
        mexit = None if graph_exit is None else self._to_model_exit(graph_exit)
        if mexit not in self._decode_jit:
            ep = None if mexit is None or mexit >= self.n_model else mexit - 1
            fn = jax.jit(
                lambda p, c, t, pos: self.model.decode_step(p, c, t, pos,
                                                            exit_point=ep)[:2])
            self._decode_jit[mexit] = fn
        return self._decode_jit[mexit]

    # ------------------------------------------------------------ serve
    def serve(self, requests: List[Request]) -> ServeStats:
        stats = ServeStats()
        for r in requests:
            self.sched.submit(r.rid, r.arrival_s + r.slo_s)
        reqs = {r.rid: r for r in requests}
        while len(self.sched):
            batch_ids = self.sched.next_batch()
            batch = [reqs[i] for i in batch_ids]
            self._serve_batch(batch, stats)
        return stats

    def _serve_batch(self, batch: List[Request], stats: ServeStats):
        B = len(batch)
        prompt_len = max(len(r.prompt) for r in batch)
        toks = np.zeros((B, prompt_len), np.int32)
        for i, r in enumerate(batch):
            toks[i, -len(r.prompt):] = r.prompt            # left-pad
        max_new = max(r.max_new_tokens for r in batch)
        cache = self.model.init_cache(B, prompt_len + max_new + 1,
                                      dtype=self.dtype, enc_len=prompt_len)
        # ---- plan at batch start
        bw = self.link.current()
        plan = self.planner.plan(bw, dynamic=self.dynamic)
        clock = 0.0
        # prefill (virtual time: prefill ~ prompt_len * step cost; value: real)
        h, cache = self.model.prefill(self.params, jnp.asarray(toks), cache)
        clock += self._step_time(plan.exit_point, plan.partition, bw) * \
            max(1, prompt_len // 8)
        logits = self.model.logits(self.params, h)
        next_tok = jnp.argmax(logits[:, -1, :], -1).astype(jnp.int32)[:, None]
        out_tokens = [[] for _ in range(B)]
        budget = min(r.slo_s for r in batch)
        exit_point = plan.exit_point
        for step in range(max_new):
            bw = self.link.current()
            if self.demote:
                per_exit = [self._step_time(e, plan.partition, bw)
                            for e in self._exit_points]
                exit_point = pick_exit(budget - clock, per_exit,
                                       max_new - step, plan.exit_point)
            t_step = self._step_time(exit_point, plan.partition, bw)
            fn = self._decode_fn(exit_point)
            pos = jnp.asarray(prompt_len + step, jnp.int32)
            h, cache = fn(self.params, cache, next_tok, pos)
            logits = self.model.logits(self.params, h)
            next_tok = jnp.argmax(logits[:, -1, :], -1).astype(jnp.int32)[:, None]
            for i in range(B):
                if step < batch[i].max_new_tokens:
                    out_tokens[i].append(int(next_tok[i, 0]))
            clock += t_step
            self.link.advance()
        for i, r in enumerate(batch):
            stats.latencies.append(clock)
            stats.met_slo.append(clock <= r.slo_s)
            stats.exits.append(exit_point)
            stats.partitions.append(plan.partition)
            stats.throughputs.append(max_new / max(clock, 1e-9))
            stats.tokens[r.rid] = out_tokens[i]
