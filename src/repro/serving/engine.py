"""Batched-request serving engine with Edgent planning.

Pipeline per batch: admit (SLO scheduler) -> prefill -> decode loop.  Before
every decode step the engine consults the planner with the *current*
bandwidth (static Algorithm 1 or dynamic Algorithm 3), obtaining the
(exit point, partition) plan; the decode step executes the right-sized model
(``exit_point`` static argument -> the compiled variant that stops at that
segment), virtual time is billed per tier + link, and deadline demotion
rescues batches that fall behind.

The plan -> decode -> demote step lives in :class:`CoInferenceStepper`, a
reusable unit shared with the fleet simulator (``repro.fleet.engine``): it
owns the per-exit jit cache and an optional plan cache keyed on quantized
bandwidth state, so many devices that observe the same bandwidth state reuse
one Algorithm-1 search result.

Token values come from real model execution (smoke-scale on CPU); timing
comes from the latency models — deterministic and host-independent.
"""
from __future__ import annotations

import math
from collections import OrderedDict
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.graph import InferenceGraph
from repro.core.partitioner import (CoInferencePlan, branch_latency,
                                    branch_preds, multi_branch_latency,
                                    proportional_cuts)
from repro.core.planner import EdgentPlanner
from repro.models.api import Model
from repro.serving.scheduler import SLOScheduler, pick_exit
from repro.serving.tiers import Link


@dataclass
class Request:
    rid: int
    prompt: np.ndarray            # [S] int32
    max_new_tokens: int
    slo_s: float
    arrival_s: float = 0.0

    @property
    def deadline_s(self) -> float:
        return self.arrival_s + self.slo_s


@dataclass
class ServeStats:
    latencies: List[float] = field(default_factory=list)
    met_slo: List[bool] = field(default_factory=list)
    exits: List[int] = field(default_factory=list)
    partitions: List[int] = field(default_factory=list)
    throughputs: List[float] = field(default_factory=list)
    queue_delays: List[float] = field(default_factory=list)
    tokens: Dict[int, List[int]] = field(default_factory=dict)

    def summary(self) -> Dict[str, float]:
        return {
            "requests": len(self.latencies),
            "p50_latency_s": float(np.percentile(self.latencies, 50)) if self.latencies else 0.0,
            "p99_latency_s": float(np.percentile(self.latencies, 99)) if self.latencies else 0.0,
            "slo_attainment": float(np.mean(self.met_slo)) if self.met_slo else 0.0,
            "mean_exit": float(np.mean(self.exits)) if self.exits else 0.0,
            "mean_throughput_tps": float(np.mean(self.throughputs)) if self.throughputs else 0.0,
            "mean_queue_delay_s": float(np.mean(self.queue_delays)) if self.queue_delays else 0.0,
        }


_QBW_MEMO: Dict[float, float] = {}


def quantize_bw(bw_bps: float, sig_figs: int = 3) -> float:
    """Round a bandwidth observation to ``sig_figs`` significant figures —
    the plan-cache key: devices in the same (quantized) bandwidth state share
    one Algorithm-1/2 search result.  Memoized at the default precision (a
    pure function; trace bandwidths recur constantly on the fleet hot path,
    where the floor/log10 pair is measurable)."""
    if sig_figs == 3:
        hit = _QBW_MEMO.get(bw_bps)
        if hit is not None:
            return hit
    if bw_bps <= 0.0:
        q = 0.0
    else:
        mag = 10.0 ** (math.floor(math.log10(bw_bps)) - sig_figs + 1)
        q = round(bw_bps / mag) * mag
    if sig_figs == 3 and len(_QBW_MEMO) < (1 << 20):
        _QBW_MEMO[bw_bps] = q
    return q


class CoInferenceStepper:
    """Reusable plan -> decode -> demote unit.

    Shared by :class:`ServingEngine` (one device-edge pair) and
    ``repro.fleet.engine.FleetEngine`` (many pairs): holds the compiled
    per-exit decode variants and a plan cache shared across callers.
    ``model`` may be ``None`` for timing-only simulation (no real decode).
    """

    #: default bound on compiled batched-decode variants (see ``jit_cache_max``)
    JIT_CACHE_MAX = 32

    def __init__(self, model: Optional[Model], graph: InferenceGraph,
                 planner: EdgentPlanner, *, dynamic: bool = False,
                 plan_cache: Optional[Dict[tuple, CoInferencePlan]] = None,
                 jit_cache_max: int = JIT_CACHE_MAX):
        self.model, self.graph, self.planner = model, graph, planner
        self.dynamic = dynamic
        # key: (quantized bw, edge-speed tuple[, quantized device slowdown,
        #       backbone bw])
        self.plan_cache: Dict[tuple, CoInferencePlan] = \
            plan_cache if plan_cache is not None else {}
        self._step_cache: Dict[tuple, List[float]] = {}
        # (partition, qbw, edge_load) -> per-exit accumulator snapshots
        # taken after the edge-side terms of per_exit_times' fold; misses
        # on the continuous device_load axis replay only the device suffix
        # (see per_exit_times_cached)
        self._prefix_cache: Dict[tuple, tuple] = {}
        # (exit, assignment, backbone bw) -> precomputed hop/span timeline;
        # lives on the stepper so every engine sharing it (the whole fleet)
        # shares one memo — see FleetEngine._emit_hops
        self.hop_cache: Dict[tuple, object] = {}
        # cumulative hit/miss counters per cache (repro.obs self-profiling;
        # plain ints — the lookups sit under every fleet round).  hop_*
        # is maintained by FleetEngine._emit_hops, whose cache this is.
        self.plan_hits = self.plan_misses = 0
        self.step_hits = self.step_misses = 0
        self.hop_hits = self.hop_misses = 0
        self._decode_jit: Dict[Optional[int], object] = {}
        # batched decode (docs/calibration.md): compiled vmap variants keyed
        # (model exit, batch bucket, sharded), LRU-bounded — a sweep over
        # many batch widths must not accumulate unbounded compiled programs.
        # The serial `_decode_jit` cache stays unbounded: it holds at most
        # n_model + 1 entries by construction.
        self._decode_vjit: "OrderedDict[tuple, object]" = OrderedDict()
        # arena decode (docs/performance.md): masked full-arena variants
        # keyed (model exit, arena signature).  Unbounded like _decode_jit:
        # steady-state arena geometry is fixed, so the population is at
        # most one entry per model exit per geometry epoch.
        self._decode_ajit: Dict[tuple, object] = {}
        # one persistent jitted prefill wrapper (lazy; jit's own shape cache
        # compiles per cache geometry).  Calling model.prefill eagerly
        # re-traces its scan segments on EVERY request — O(requests) compile
        # work and retained executables; through one jit object a fleet pays
        # one compile per geometry instead.
        self._prefill_jit = None
        self.jit_cache_max = max(1, jit_cache_max)
        self.jit_hits = self.jit_misses = 0
        # decode-path execution counters (asserted by tests/test_calib.py:
        # a real-decode fleet round with co-located requests must land on
        # the batched path)
        self.batched_calls = 0        # jitted group calls issued
        self.batched_tokens = 0       # tokens produced through vmap groups
        self.serial_tokens = 0        # tokens produced one request at a time
        self.padded_rows = 0          # bucket padding rows computed+discarded
        self.batched_max = 0          # largest single vmap group seen
        # arena-path execution counters (tests/test_arena.py): slot-resident
        # decode — admit/evict/grow are the only per-request device writes,
        # masked_rows counts inactive-slot FLOPs discarded per call
        self.arena_calls = 0          # masked full-arena calls issued
        self.arena_tokens = 0         # tokens produced through arena calls
        self.arena_masked_rows = 0    # inactive rows computed+discarded
        self.arena_admits = 0         # slot scatters (request enters arena)
        self.arena_evicts = 0         # slot frees (complete or extracted)
        self.arena_grows = 0          # slot-doubling / length re-bucketing
        self.n_graph = graph.num_exits
        self.n_model = model.num_segments if model is not None else graph.num_exits
        self.exit_points = list(range(1, self.n_graph + 1))

    # ------------------------------------------------------------ planning
    def plan(self, bw_bps: float) -> CoInferencePlan:
        """Online tuning at the current bandwidth.  Static plans are cached
        by (quantized bandwidth, edge-speed tuple) — the single-pair path
        uses the empty speed tuple; the dynamic optimizer is stateful (BOCD)
        so it is always consulted directly."""
        if self.dynamic:
            return self.planner.plan(bw_bps, dynamic=True)
        key = (quantize_bw(bw_bps), ())
        plan = self.plan_cache.get(key)
        if plan is None:
            self.plan_misses += 1
            plan = self.plan_cache[key] = self.planner.plan(bw_bps)
        else:
            self.plan_hits += 1
        return plan

    def plan_multi(self, bw_bps: float, edge_speeds: tuple, *,
                   device_load: float = 1.0,
                   edge_bw_bps: Optional[float] = None) -> CoInferencePlan:
        """Joint (exit, k-cut partition) plan for one ordered candidate edge
        set, cached on (quantized bandwidth, edge-speed tuple, quantized
        device slowdown): every device in the same bandwidth state asking
        about the same hardware reuses one search (the key the fleet's
        ``JointPlanner`` fans out over)."""
        assert not self.dynamic, "joint planning is static-environment only"
        key = (quantize_bw(bw_bps), tuple(edge_speeds),
               round(device_load, 3), edge_bw_bps)
        plan = self.plan_cache.get(key)
        if plan is None:
            self.plan_misses += 1
            plan = self.plan_cache[key] = self.planner.plan_multi(
                bw_bps, edge_speeds, device_load=device_load,
                edge_bw_bps=edge_bw_bps)
        else:
            self.plan_hits += 1
        return plan

    # ------------------------------------------------------------ timing
    def step_time(self, exit_point: int, partition: int, bw_bps: float, *,
                  edge_load: float = 1.0, device_load: float = 1.0,
                  include_input: bool = True) -> float:
        """Virtual per-token latency of (exit, partition) at bandwidth bw.

        ``include_input=False`` drops the input-uplink term (paid once at
        prefill, not per decode token) — the fleet engine bills it that way
        so queueing delay stays honest."""
        t = branch_latency(self.graph, exit_point, partition,
                           self.planner.f_edge, self.planner.f_device,
                           bw_bps, edge_load=edge_load,
                           device_load=device_load)
        if not include_input and partition > 0:
            t -= self.graph.input_bytes / bw_bps
        return t

    def _branch_preds(self):
        """Memoized :func:`~repro.core.partitioner.branch_preds` for this
        stepper's (graph, models) triple — bit-exact input to the inlined
        latency accumulations below (see branch_preds for the contract)."""
        f_edge, f_device = self.planner.f_edge, self.planner.f_device
        key = (id(f_edge), id(f_device))
        if getattr(self, "_pred_key", None) != key:
            self._pred_key = key
            self._preds = branch_preds(self.graph, f_edge, f_device)
        return self._preds

    def per_exit_times(self, partition: int, bw_bps: float, *,
                       edge_load: float = 1.0, device_load: float = 1.0,
                       include_input: bool = True) -> List[float]:
        # inlined branch_latency over memoized per-layer predictions: the
        # identical float terms in the identical order as step_time(), minus
        # the per-call predictor dispatch (this sits under every fleet
        # round's cache miss)
        pe_all, pd_all = self._branch_preds()
        graph, p = self.graph, partition
        out = []
        for e in self.exit_points:
            pe, pd = pe_all[e - 1], pd_all[e - 1]
            t = 0.0
            if p > 0:
                t += graph.input_bytes / bw_bps
                t += graph.cut_bytes(e, p) / bw_bps
            for j in range(len(pe)):
                if j < p:
                    t += pe[j] * edge_load
                else:
                    t += pd[j] * device_load
            if not include_input and p > 0:
                t -= graph.input_bytes / bw_bps
            out.append(t)
        return out

    def input_time(self, partition: int, bw_bps: float) -> float:
        """One-shot input uplink cost (zero for device-only plans)."""
        return self.graph.input_bytes / bw_bps if partition > 0 else 0.0

    def _edge_prefix(self, partition: int, qbw: float,
                     edge_load: float) -> tuple:
        """Per-exit accumulator snapshots after the edge-side terms of
        :meth:`per_exit_times`' fold (io + cut + edge layers, in that
        order), plus the input-uplink term.  The snapshot is independent of
        ``device_load`` — the one continuous cache axis — so a fresh
        device_load only replays the short device suffix instead of the
        whole fold.  Replaying the suffix onto the snapshot reproduces the
        full fold bit-identically (same terms, same order)."""
        key = (partition, qbw, edge_load)
        hit = self._prefix_cache.get(key)
        if hit is None:
            pe_all, _ = self._branch_preds()
            graph, p = self.graph, partition
            inp = graph.input_bytes / qbw if p > 0 else 0.0
            base = []
            for e in self.exit_points:
                pe = pe_all[e - 1]
                t = 0.0
                if p > 0:
                    t += graph.input_bytes / qbw
                    t += graph.cut_bytes(e, p) / qbw
                for j in range(min(p, len(pe))):
                    t += pe[j] * edge_load
                base.append(t)
            hit = self._prefix_cache[key] = (base, inp)
        return hit

    def per_exit_times_cached(self, partition: int, bw_bps: float, *,
                              edge_load: float = 1.0,
                              device_load: float = 1.0,
                              include_input: bool = True) -> List[float]:
        """Memoized :meth:`per_exit_times` at quantized bandwidth — the fleet
        hot path: all inputs are piecewise-constant (traces change on a 1 s
        grid, loads are fixed per node), so devices in the same bandwidth
        state share one evaluation.  Misses rebuild from the
        :meth:`_edge_prefix` snapshot (device-suffix replay only) —
        bit-identical to the full :meth:`per_exit_times` fold."""
        qbw = quantize_bw(bw_bps)
        key = (partition, qbw, edge_load, device_load, include_input)
        hit = self._step_cache.get(key)
        if hit is None:
            self.step_misses += 1
            base, inp = self._edge_prefix(partition, qbw, edge_load)
            _, pd_all = self._branch_preds()
            p = partition
            out = []
            for i, e in enumerate(self.exit_points):
                pd = pd_all[e - 1]
                t = base[i]
                for j in range(p, len(pd)):
                    t += pd[j] * device_load
                if not include_input and p > 0:
                    t -= inp
                out.append(t)
            hit = self._step_cache[key] = out
        else:
            self.step_hits += 1
        return hit

    def per_exit_times_coop_cached(self, partition: int, edge_speeds: tuple,
                                   bw_bps: float, *,
                                   device_load: float = 1.0,
                                   edge_bw_bps: Optional[float] = None,
                                   include_input: bool = True) -> List[float]:
        """Per-exit step times for a multi-edge span plan (k-cut chain across
        ``edge_speeds`` with backbone hops).  With a single edge in the set
        this *is* :meth:`per_exit_times_cached` at that edge's speed — the
        k=1 reduction the oracle test pins — so the fleet engine can use one
        call site for both shapes."""
        speeds = tuple(edge_speeds)
        if len(speeds) <= 1:
            return self.per_exit_times_cached(
                partition, bw_bps, edge_load=speeds[0] if speeds else 1.0,
                device_load=device_load, include_input=include_input)
        qbw = quantize_bw(bw_bps)
        key = (partition, speeds, qbw, device_load, edge_bw_bps,
               include_input)
        hit = self._step_cache.get(key)
        if hit is not None:
            self.step_hits += 1
            return hit
        self.step_misses += 1
        out = []
        for e in self.exit_points:
            p_e = min(partition, len(self.graph.branches[e - 1]))
            cuts, kept = proportional_cuts(p_e, speeds)
            loads = [speeds[i] for i in kept]
            t = multi_branch_latency(self.graph, e, cuts, loads,
                                     self.planner.f_edge,
                                     self.planner.f_device, qbw,
                                     device_load=device_load,
                                     edge_bw_bps=edge_bw_bps,
                                     preds=self._branch_preds())
            if not include_input and p_e > 0:
                t -= self.graph.input_bytes / qbw
            out.append(t)
        self._step_cache[key] = out
        return out

    def choose_exit(self, remaining_s: float, per_exit: List[float],
                    tokens_left: int, preferred: int) -> int:
        """Deadline demotion (``pick_exit``) against the remaining budget."""
        return pick_exit(remaining_s, per_exit, tokens_left, preferred)

    def cache_stats(self) -> Dict[str, Dict]:
        """Hit/miss/size per memo (plan search, per-exit step times, coop
        hop schedules) — cumulative over the stepper's lifetime, which is
        fleet-wide and cross-run for a shared stepper.  Surfaced by
        ``repro.obs.SimProfiler.report`` and ``perf_fleet.py --smoke``."""
        def block(hits: int, misses: int, entries: int) -> Dict:
            total = hits + misses
            return {"hits": hits, "misses": misses, "entries": entries,
                    "hit_rate": round(hits / total, 6) if total else None}
        return {
            "plan": block(self.plan_hits, self.plan_misses,
                          len(self.plan_cache)),
            "step": block(self.step_hits, self.step_misses,
                          len(self._step_cache)),
            "hop": block(self.hop_hits, self.hop_misses,
                         len(self.hop_cache)),
            # compiled decode variants: serial per-exit + LRU-bounded
            # batched (exit, bucket) entries + masked arena (exit, sig)
            # entries, with the per-family split under "variants"
            "jit": dict(block(self.jit_hits, self.jit_misses,
                              len(self._decode_jit) + len(self._decode_vjit)
                              + len(self._decode_ajit)),
                        max_entries=self.jit_cache_max,
                        variants={"serial": len(self._decode_jit),
                                  "batched": len(self._decode_vjit),
                                  "arena": len(self._decode_ajit)}),
            # execution counters, not a hit/miss cache: how decode tokens
            # actually ran (tests/test_calib.py pins the batched path)
            "decode": {"batched_calls": self.batched_calls,
                       "batched_tokens": self.batched_tokens,
                       "serial_tokens": self.serial_tokens,
                       "padded_rows": self.padded_rows,
                       "batched_max": self.batched_max},
            # arena execution counters (tests/test_arena.py pins the
            # slot-resident path); occupancy = active rows / rows computed
            "arena": {"calls": self.arena_calls,
                      "tokens": self.arena_tokens,
                      "masked_rows": self.arena_masked_rows,
                      "admits": self.arena_admits,
                      "evicts": self.arena_evicts,
                      "grows": self.arena_grows,
                      "occupancy": round(
                          self.arena_tokens
                          / (self.arena_tokens + self.arena_masked_rows), 4)
                      if self.arena_tokens + self.arena_masked_rows else None,
                      "variants": len(self._decode_ajit)},
        }

    # ------------------------------------------------------------ decode path
    def to_model_exit(self, graph_exit: int) -> int:
        # the planner's graph may describe the FULL-size architecture while
        # the executing model is the reduced config: map exit points
        # proportionally (graph exit i -> model segment)
        return max(1, round(graph_exit * self.n_model / self.n_graph))

    def prefill_fn(self):
        """The shared jitted prefill: one compile per cache geometry for the
        engine's whole lifetime (see ``_prefill_jit`` in ``__init__``)."""
        assert self.model is not None, "timing-only stepper has no prefill"
        if self._prefill_jit is None:
            self._prefill_jit = jax.jit(self.model.prefill)
        return self._prefill_jit

    def decode_fn(self, graph_exit: Optional[int]):
        assert self.model is not None, "timing-only stepper has no decode path"
        mexit = None if graph_exit is None else self.to_model_exit(graph_exit)
        if mexit not in self._decode_jit:
            self.jit_misses += 1
            ep = None if mexit is None or mexit >= self.n_model else mexit - 1
            fn = jax.jit(
                lambda p, c, t, pos: self.model.decode_step(p, c, t, pos,
                                                            exit_point=ep)[:2])
            self._decode_jit[mexit] = fn
        else:
            self.jit_hits += 1
        return self._decode_jit[mexit]

    # --------------------------------------------------------- batched decode
    @staticmethod
    def batch_bucket(n: int) -> int:
        """Compiled batch widths come in power-of-two buckets: a group of
        ``n`` co-located requests pads up to the bucket, so a continuous
        batch whose width wobbles round to round reuses one compiled
        variant per bucket instead of one per width."""
        b = 1
        while b < n:
            b <<= 1
        return b

    def _shard_wrap(self, vstep, bucket: int):
        """``shard_map`` the vmapped step over a 1-D device mesh when the
        host has one (params replicated, the batch axis split).  On a
        single-device host — or a bucket the mesh doesn't divide — this is
        the identity: the plain vmap variant runs, bit-identically."""
        devices = jax.devices()
        if len(devices) <= 1 or bucket % len(devices) != 0:
            return vstep
        try:
            from jax.experimental.shard_map import shard_map
            from jax.sharding import Mesh, PartitionSpec as P
        except ImportError:                                # pragma: no cover
            return vstep
        mesh = Mesh(np.array(devices), ("b",))
        return shard_map(vstep, mesh=mesh,
                         in_specs=(P(), P("b"), P("b"), P("b")),
                         out_specs=(P("b"), P("b")))

    def decode_fn_batched(self, graph_exit: Optional[int], batch: int, *,
                          sharded: bool = False):
        """The compiled batched decode variant for ``graph_exit`` at
        ``batch`` co-located requests: ``vmap`` of the per-request step over
        stacked B=1 (cache, token, position) rows, jitted once per
        ``(model exit, batch bucket)`` and held in an LRU of at most
        ``jit_cache_max`` entries."""
        assert self.model is not None, "timing-only stepper has no decode path"
        mexit = None if graph_exit is None else self.to_model_exit(graph_exit)
        key = (mexit, self.batch_bucket(batch), bool(sharded))
        fn = self._decode_vjit.get(key)
        if fn is None:
            self.jit_misses += 1
            ep = None if mexit is None or mexit >= self.n_model else mexit - 1
            step = lambda p, c, t, pos: self.model.decode_step(  # noqa: E731
                p, c, t, pos, exit_point=ep)[:2]
            vstep = jax.vmap(step, in_axes=(None, 0, 0, 0))
            if sharded:
                vstep = self._shard_wrap(vstep, key[1])
            fn = jax.jit(vstep)
            self._decode_vjit[key] = fn
            if len(self._decode_vjit) > self.jit_cache_max:
                self._decode_vjit.popitem(last=False)     # evict LRU
        else:
            self.jit_hits += 1
            self._decode_vjit.move_to_end(key)
        return fn

    @staticmethod
    def _cache_sig(cache) -> tuple:
        """Hashable shape/dtype signature of one request's decode cache.
        Batched groups stack caches leaf-by-leaf, so only requests whose
        caches are congruent (same tenant geometry: prompt + budget sizing)
        may share a vmap call."""
        return tuple((tuple(leaf.shape), str(leaf.dtype))
                     for leaf in jax.tree_util.tree_leaves(cache))

    def decode_step_batch(self, params, items: Sequence[tuple], *,
                          sharded: bool = False) -> List[Tuple[object, object]]:
        """One decode step for many co-located requests in as few compiled
        calls as the cache geometry allows.

        ``items`` rows are ``(graph_exit, cache, next_tok, pos)`` with B=1
        leaves (``pos`` a python int).  Rows are grouped by (exit, cache
        signature); each group is stacked, padded up to its power-of-two
        bucket by replicating row 0 (vmap rows are independent, so padding
        changes nothing but FLOPs — the discard is counted in
        ``padded_rows``), and run through :meth:`decode_fn_batched`.
        Returns ``(hidden, new_cache)`` per item, in item order,
        bit-identical to looping :meth:`decode_fn` per request.  A
        single-row group skips the batched machinery entirely and runs the
        serial variant (no stack/unstack, shares its compiled fn with the
        serial path)."""
        stack = jax.tree_util.tree_map
        out: List[Optional[Tuple[object, object]]] = [None] * len(items)
        groups: "OrderedDict[tuple, List[int]]" = OrderedDict()
        for i, (gexit, cache, _tok, _pos) in enumerate(items):
            groups.setdefault((gexit, self._cache_sig(cache)), []).append(i)
        for (gexit, _sig), idxs in groups.items():
            n = len(idxs)
            if n == 1:
                i = idxs[0]
                _, cache, tok, pos = items[i]
                fn = self.decode_fn(gexit)
                h, new_cache = fn(params, cache, tok,
                                  jnp.asarray(pos, jnp.int32))
                out[i] = (h, new_cache)
                self.serial_tokens += 1
                continue
            bucket = self.batch_bucket(n)
            rows = [items[i] for i in idxs]
            rows += [rows[0]] * (bucket - n)              # pad: replicate
            cb = stack(lambda *xs: jnp.stack(xs), *[r[1] for r in rows])
            tb = jnp.stack([r[2] for r in rows])
            pb = jnp.asarray([r[3] for r in rows], jnp.int32)
            fn = self.decode_fn_batched(gexit, n, sharded=sharded)
            hb, cob = fn(params, cb, tb, pb)
            for j, i in enumerate(idxs):
                out[i] = (hb[j], stack(lambda x, j=j: x[j], cob))
            self.batched_calls += 1
            self.batched_tokens += n
            self.padded_rows += bucket - n
            if n > self.batched_max:
                self.batched_max = n
        return out

    # ---------------------------------------------------------- arena decode
    def decode_fn_arena(self, graph_exit: Optional[int], arena):
        """The compiled masked-arena decode variant for ``graph_exit``
        over ``arena``'s fixed geometry: ``vmap`` of the per-request step
        over the full ``[slots, ...]`` cache stack, with a boolean
        active-mask selecting which rows' cache writes commit
        (``jnp.where`` per leaf — inactive rows keep their old state
        bit-for-bit).  Keyed ``(model exit, arena signature)``, so as long
        as the arena never regrows there is exactly one variant per model
        exit regardless of the prompt-length / batch-width mix.  The cache
        argument is donated: callers must thread the returned cache
        forward (``DecodeArena`` does)."""
        assert self.model is not None, "timing-only stepper has no decode path"
        mexit = None if graph_exit is None else self.to_model_exit(graph_exit)
        key = (mexit, arena.sig())
        fn = self._decode_ajit.get(key)
        if fn is not None:
            self.jit_hits += 1
            return fn
        self.jit_misses += 1
        ep = None if mexit is None or mexit >= self.n_model else mexit - 1
        step = lambda p, c, t, pos: self.model.decode_step(  # noqa: E731
            p, c, t, pos, exit_point=ep)[:2]
        vstep = jax.vmap(step, in_axes=(None, 0, 0, 0))

        def astep(p, cache, tok, pos, mask):
            h, new_cache = vstep(p, cache, tok, pos)
            committed = jax.tree_util.tree_map(
                lambda n, o: jnp.where(
                    mask.reshape(mask.shape + (1,) * (n.ndim - 1)), n, o),
                new_cache, cache)
            return h, committed

        fn = jax.jit(astep, donate_argnums=(1,))
        self._decode_ajit[key] = fn
        return fn

    def decode_step_arena(self, params, arena, items: Sequence[tuple]
                          ) -> List[tuple]:
        """One decode step for every active slot of ``arena`` in at most
        one compiled call per model exit.

        ``items`` rows are ``(graph_exit, slot, next_tok, pos)`` — no
        caches: the KV state is already resident.  Rows sharing a model
        exit decode in one masked full-arena call; rows outside the mask
        run with dummy inputs (token 0, position 0) and their cache writes
        are discarded by the masked commit, so multiple exit groups may
        sweep the same arena sequentially with disjoint masks.  Returns
        one ``(rows, hidden)`` pair per exit group, ``hidden`` being the
        full ``[slots, 1, 1, d]`` stack — callers index it by slot (each
        row bit-identical to the serial path) or, cheaper, feed it whole
        to one batched logits/argmax epilogue per group instead of one
        per request (row-wise bit-identical on every backend we pin)."""
        slots = arena.slots
        groups: "OrderedDict[Optional[int], List[tuple]]" = OrderedDict()
        for gexit, slot, tok, pos in items:
            mexit = None if gexit is None else self.to_model_exit(gexit)
            groups.setdefault(mexit, []).append((gexit, slot, tok, pos))
        out: List[tuple] = []
        for rows in groups.values():
            tok_a = np.zeros((slots, 1, 1), np.int32)
            pos_a = np.zeros((slots,), np.int32)
            mask_a = np.zeros((slots,), bool)
            for _, slot, tok, pos in rows:
                tok_a[slot] = np.asarray(tok, np.int32)
                pos_a[slot] = pos
                mask_a[slot] = True
            fn = self.decode_fn_arena(rows[0][0], arena)
            h_all, arena.cache = fn(params, arena.cache,
                                    jnp.asarray(tok_a), jnp.asarray(pos_a),
                                    jnp.asarray(mask_a))
            out.append((rows, h_all))
            self.arena_calls += 1
            self.arena_tokens += len(rows)
            self.arena_masked_rows += slots - len(rows)
        return out


class ServingEngine:
    def __init__(self, model: Model, params, graph: InferenceGraph,
                 planner: EdgentPlanner, link: Link, *, batch_size: int = 4,
                 max_seq: int = 128, dtype=jnp.float32,
                 dynamic: bool = False, demote_on_deadline: bool = True):
        self.model, self.params, self.graph = model, params, graph
        self.planner, self.link = planner, link
        self.batch_size, self.max_seq = batch_size, max_seq
        self.dtype = dtype
        self.dynamic = dynamic
        self.demote = demote_on_deadline
        self.sched = SLOScheduler(batch_size)
        self.stepper = CoInferenceStepper(model, graph, planner,
                                          dynamic=dynamic)

    # ------------------------------------------------------------ serve
    def serve(self, requests: List[Request]) -> ServeStats:
        stats = ServeStats()
        for r in requests:
            self.sched.submit(r.rid, r.deadline_s, r.arrival_s)
        reqs = {r.rid: r for r in requests}
        now = 0.0
        while len(self.sched):
            batch_ids = self.sched.next_batch(now)
            if not batch_ids:           # idle until the next arrival
                now = self.sched.earliest_arrival()
                continue
            batch = [reqs[i] for i in batch_ids]
            now = self._serve_batch(batch, stats, now)
        return stats

    def _serve_batch(self, batch: List[Request], stats: ServeStats,
                     start_s: float = 0.0) -> float:
        B = len(batch)
        prompt_len = max(len(r.prompt) for r in batch)
        toks = np.zeros((B, prompt_len), np.int32)
        for i, r in enumerate(batch):
            toks[i, -len(r.prompt):] = r.prompt            # left-pad
        max_new = max(r.max_new_tokens for r in batch)
        cache = self.model.init_cache(B, prompt_len + max_new + 1,
                                      dtype=self.dtype, enc_len=prompt_len)
        # ---- plan at batch start
        bw = self.link.current()
        plan = self.stepper.plan(bw)
        clock = start_s
        # prefill (virtual time: prefill ~ prompt_len * step cost; value: real)
        h, cache = self.stepper.prefill_fn()(self.params, jnp.asarray(toks),
                                             cache)
        clock += self.stepper.step_time(plan.exit_point, plan.partition, bw) * \
            max(1, prompt_len // 8)
        logits = self.model.logits(self.params, h)
        next_tok = jnp.argmax(logits[:, -1, :], -1).astype(jnp.int32)[:, None]
        out_tokens = [[] for _ in range(B)]
        # each request's own deadline includes the time it already spent
        # queued: the batch budget is the earliest deadline in absolute time
        budget = min(r.deadline_s for r in batch)
        exit_point = plan.exit_point
        for step in range(max_new):
            bw = self.link.current()
            if self.demote:
                per_exit = self.stepper.per_exit_times(plan.partition, bw)
                exit_point = self.stepper.choose_exit(
                    budget - clock, per_exit, max_new - step, plan.exit_point)
            t_step = self.stepper.step_time(exit_point, plan.partition, bw)
            fn = self.stepper.decode_fn(exit_point)
            pos = jnp.asarray(prompt_len + step, jnp.int32)
            h, cache = fn(self.params, cache, next_tok, pos)
            logits = self.model.logits(self.params, h)
            next_tok = jnp.argmax(logits[:, -1, :], -1).astype(jnp.int32)[:, None]
            for i in range(B):
                if step < batch[i].max_new_tokens:
                    out_tokens[i].append(int(next_tok[i, 0]))
            clock += t_step
            self.link.advance()
        for i, r in enumerate(batch):
            stats.latencies.append(max(0.0, clock - r.arrival_s))
            stats.met_slo.append(clock <= r.deadline_s)
            stats.exits.append(exit_point)
            stats.partitions.append(plan.partition)
            stats.throughputs.append(max_new / max(clock - start_s, 1e-9))
            stats.queue_delays.append(max(0.0, start_s - r.arrival_s))
            stats.tokens[r.rid] = out_tokens[i]
        return clock
