from repro.serving.engine import Request, ServingEngine, ServeStats  # noqa: F401
