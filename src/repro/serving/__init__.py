from repro.serving.engine import (CoInferenceStepper, Request, ServeStats,  # noqa: F401
                                  ServingEngine, quantize_bw)
