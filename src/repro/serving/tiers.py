"""Two-tier serving topology: a weak 'device' tier and a strong 'edge' tier
joined by a bandwidth-limited link — the paper's testbed, datacenter-scaled.

Tiers bill virtual time from a latency model (RooflineLatencyModel at TPU
scale, RegressionLatencyModel when profiled); the link bills bytes/bandwidth
with the current trace value.  This keeps experiments deterministic and
host-independent while the *token values* come from real model execution.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable, Optional

import numpy as np


@dataclass
class Link:
    """Bandwidth-limited link fed by a trace (bytes/s per step index)."""
    trace_bps: np.ndarray
    idx: int = 0

    def current(self) -> float:
        return float(self.trace_bps[min(self.idx, len(self.trace_bps) - 1)])

    def advance(self):
        self.idx += 1

    def transfer_s(self, nbytes: float) -> float:
        return nbytes / max(self.current(), 1.0)


@dataclass
class Tier:
    name: str
    latency_model: object                  # .predict(GraphLayer) -> seconds

    def time_layers(self, layers) -> float:
        return sum(self.latency_model.predict(l) for l in layers)
