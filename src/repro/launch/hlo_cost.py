"""Loop-aware cost model over compiled (post-SPMD) HLO text.

XLA's ``compiled.cost_analysis()`` counts every instruction ONCE — a
``lax.scan`` over 40 layers contributes a single body, undercounting FLOPs
and bytes by the trip count.  This walker parses the HLO text into
computations, builds a per-computation symbol table (every instruction line
carries its result type inline), and computes:

  * flops  — 2 * |result| * contraction_size for dot/convolution (recursing
    into fusion computations), everything else ~ |result| per arithmetic op;
  * bytes  — fusion-boundary traffic: each top-level instruction reads its
    operands and writes its result (parameter / gte / tuple / bitcast /
    constant are free); fusions count only their boundary;
  * while loops multiply their body costs by the trip count (largest integer
    compared against in the condition computation).

Used by the dry-run for the roofline terms; validated against analytic
MODEL_FLOPS in tests/test_hlo_cost.py.
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "s64": 8, "u64": 8,
                "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1,
                "pred": 1, "f8e4m3fn": 1, "f8e5m2": 1}

_TYPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_INSTR_RE = re.compile(r"^\s*(?:ROOT\s+)?%?([\w\.\-]+)\s*=\s*(.*)$")
_OPNAME_RE = re.compile(r"%([\w\.\-]+)")
_CONST_RE = re.compile(r"constant\((\d+)\)")
_CALLS_RE = re.compile(r"calls=%?([\w\.\-]+)")
_WHILE_RE = re.compile(r"condition=%?([\w\.\-]+), body=%?([\w\.\-]+)")
_CDIMS_RE = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")

_FREE_OPS = ("parameter", "get-tuple-element", "tuple(", "bitcast", "constant",
             "after-all", "partition-id", "replica-id", "iota")
_ELEMENTWISE_HINT = ("add", "multiply", "subtract", "divide", "exponential",
                     "maximum", "minimum", "compare", "select", "convert",
                     "tanh", "log", "rsqrt", "sqrt", "power", "negate", "abs",
                     "and", "or", "xor", "not", "sign", "floor", "ceil",
                     "round", "clamp", "sine", "cosine", "exponential-minus-one")
# ops the TPU fusion pipeline folds into neighbours (no HBM round trip)
_FUSABLE = ("broadcast", "reshape", "slice", "pad", "reverse", "rev",
            "concatenate", "reduce", "transpose", "map")


def _parse_type(s: str) -> Tuple[int, int]:
    """First type in s -> (elements, bytes)."""
    m = _TYPE_RE.search(s)
    if not m:
        return 0, 0
    dt, dims = m.groups()
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n, n * _DTYPE_BYTES.get(dt, 0)


def _all_types(s: str):
    out = []
    for m in _TYPE_RE.finditer(s):
        dt, dims = m.groups()
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        out.append((dt, [int(d) for d in dims.split(",")] if dims else [],
                    n * _DTYPE_BYTES[dt]))
    return out


@dataclass
class Instr:
    name: str
    rhs: str
    elems: int
    nbytes: int
    dims: List[int]


def _split_computations(text: str) -> Dict[str, List[Instr]]:
    comps: Dict[str, List[Instr]] = {}
    cur: Optional[str] = None
    for raw in text.splitlines():
        s = raw.rstrip()
        st = s.strip()
        if st.endswith("{") and ("->" in st or st.startswith("ENTRY")):
            m = re.match(r"(?:ENTRY\s+)?%?([\w\.\-]+)", st)
            cur = m.group(1) if m else None
            if cur:
                comps[cur] = []
            continue
        if st == "}":
            cur = None
            continue
        if cur is None:
            continue
        im = _INSTR_RE.match(st)
        if not im:
            continue
        name, rhs = im.groups()
        tys = _all_types(rhs.split(" ", 2)[0] if rhs else "")
        if tys:
            dt, dims, nb = tys[0]
            elems = 1
            for d in dims:
                elems *= d
        else:
            dims, nb, elems = [], 0, 0
        comps[cur].append(Instr(name, rhs, elems, nb, dims))
    return comps


@dataclass
class Cost:
    flops: float = 0.0
    bytes: float = 0.0

    def __iadd__(self, o):
        self.flops += o.flops
        self.bytes += o.bytes
        return self

    def scaled(self, k: float) -> "Cost":
        return Cost(self.flops * k, self.bytes * k)


class HloCostModel:
    def __init__(self, text: str, fused: bool = True):
        self.fused = fused
        self.comps = _split_computations(text)
        self.symtab: Dict[str, Dict[str, Instr]] = {
            c: {i.name: i for i in instrs} for c, instrs in self.comps.items()}
        self._memo: Dict[str, Cost] = {}
        entry = None
        for line in text.splitlines():
            if line.startswith("ENTRY"):
                m = re.match(r"ENTRY\s+%?([\w\.\-]+)", line)
                entry = m.group(1) if m else None
                break
        self.entry = entry if entry in self.comps else (
            max(self.comps, key=lambda c: len(self.comps[c])) if self.comps else None)

    # ------------------------------------------------------------------
    def _trip_count(self, cond: str) -> int:
        best = 1
        for i in self.comps.get(cond, []):
            for m in _CONST_RE.finditer(i.rhs):
                best = max(best, int(m.group(1)))
        return best

    def _operands(self, comp: str, rhs: str):
        lp = rhs.find("(")
        if lp < 0:
            return []
        depth, end = 1, len(rhs)
        for i in range(lp + 1, len(rhs)):
            if rhs[i] == "(":
                depth += 1
            elif rhs[i] == ")":
                depth -= 1
                if depth == 0:
                    end = i
                    break
        tab = self.symtab.get(comp, {})
        out = []
        for m in _OPNAME_RE.finditer(rhs[lp:end]):
            ins = tab.get(m.group(1))
            if ins is not None:
                out.append(ins)
        return out

    def _operand_bytes(self, comp: str, rhs: str, hbm_only: bool = False) -> float:
        """Sum operand bytes.  ``hbm_only``: bill only operands that enter the
        computation from outside (parameter / get-tuple-element / constant) —
        locally-produced values live in VMEM under the fusion assumption."""
        total = 0.0
        for ins in self._operands(comp, rhs):
            if hbm_only and not any(t in ins.rhs for t in (
                    "parameter(", "get-tuple-element(", "constant(")):
                continue
            total += ins.nbytes
        return total

    def _dot_flops(self, comp: str, ins: Instr) -> float:
        cd = _CDIMS_RE.search(ins.rhs)
        contract = 1
        ops = _OPNAME_RE.findall(ins.rhs[ins.rhs.find("("):])
        lhs = self.symtab.get(comp, {}).get(ops[0]) if ops else None
        if cd and lhs is not None:
            for d in (int(x) for x in cd.group(1).split(",") if x):
                if d < len(lhs.dims):
                    contract *= lhs.dims[d]
        return 2.0 * ins.elems * contract

    def _conv_flops(self, comp: str, ins: Instr) -> float:
        ops = _OPNAME_RE.findall(ins.rhs[ins.rhs.find("("):])
        ker = self.symtab.get(comp, {}).get(ops[1]) if len(ops) > 1 else None
        k = 1
        if ker is not None and ker.dims:
            for d in ker.dims[:-1]:       # spatial * in_ch
                k *= d
        return 2.0 * ins.elems * k

    @staticmethod
    def _opname(rhs: str) -> str:
        """Op token: first lowercase identifier followed by '(' after the
        result type, e.g. 'bf16[8]{0} dot(%a, %b)' -> 'dot'."""
        m = re.search(r"(?:^|\s|\})([a-z][a-z0-9\-\.]*)\(", rhs)
        return m.group(1) if m else ""

    # ------------------------------------------------------------------
    def computation_cost(self, comp: str) -> Cost:
        if comp in self._memo:
            return self._memo[comp]
        self._memo[comp] = Cost()      # guard cycles
        total = Cost()
        for ins in self.comps.get(comp, []):
            rhs = ins.rhs
            op = self._opname(rhs)
            if op == "while":
                wm = _WHILE_RE.search(rhs)
                if wm:
                    cond, body = wm.groups()
                    total += self.computation_cost(body).scaled(self._trip_count(cond))
                total += Cost(0.0, float(ins.nbytes))
                continue
            if op == "fusion":
                cm = _CALLS_RE.search(rhs)
                inner = self.computation_cost(cm.group(1)) if cm else Cost()
                if self.fused:
                    # TPU assumption: only loop-carried state / weights
                    # (parameter / gte operands) are HBM-resident; locals
                    # between CPU-granularity fusions stay in VMEM
                    ob = self._operand_bytes(comp, rhs, hbm_only=True)
                    total += Cost(inner.flops, ob)
                else:
                    total += Cost(inner.flops,
                                  float(ins.nbytes) + self._operand_bytes(comp, rhs))
                continue
            if op in ("call", "conditional", "map"):
                cm = _CALLS_RE.search(rhs)
                if cm:
                    total += self.computation_cost(cm.group(1))
                total += Cost(0.0, float(ins.nbytes))
                continue
            if op == "dot":
                ob = self._operand_bytes(comp, rhs, hbm_only=self.fused)
                rb = 0.0 if self.fused else float(ins.nbytes)
                total += Cost(self._dot_flops(comp, ins), rb + ob)
                continue
            if op == "convolution":
                total += Cost(self._conv_flops(comp, ins),
                              float(ins.nbytes) + self._operand_bytes(comp, rhs))
                continue
            if op == "dynamic-slice":
                total += Cost(0.0, float(ins.nbytes))     # reads slice, not buffer
                continue
            if op == "dynamic-update-slice":
                ops_ = self._operands(comp, rhs)
                upd = sum(o.nbytes for o in ops_[1:2])    # the written slice
                total += Cost(0.0, 2.0 * float(upd))
                continue
            if op in ("parameter", "get-tuple-element", "tuple", "bitcast",
                      "constant", "after-all", "partition-id", "replica-id",
                      "iota", "copy-start", "copy-done") or op == "":
                continue
            arith = any(op.startswith(e) for e in _ELEMENTWISE_HINT)
            flops = float(ins.elems) if arith else 0.0
            if self.fused and (arith or op in _FUSABLE):
                # fusion-closure estimate: elementwise chains fuse into their
                # producers/consumers on TPU — no HBM round-trip billed
                total += Cost(flops, 0.0)
                continue
            total += Cost(flops, float(ins.nbytes) + self._operand_bytes(comp, rhs))
        self._memo[comp] = total
        return total

    def entry_cost(self) -> Cost:
        if self.entry is None:
            return Cost()
        return self.computation_cost(self.entry)


def walk_costs(hlo_text: str, fused: bool = True) -> Tuple[float, float]:
    """Returns (flops, bytes) per device, loop-aware.  ``fused=True`` applies
    the fusion-closure byte model (TPU assumption); ``fused=False`` bills
    every materialized op (the literal CPU-backend lowering)."""
    c = HloCostModel(hlo_text, fused=fused).entry_cost()
    return c.flops, c.bytes
