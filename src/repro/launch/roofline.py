"""Roofline analysis from the dry-run artifacts (EXPERIMENTS.md §Roofline).

Per (arch x shape) on the single-pod mesh:

    compute    = HLO_FLOPs / (chips * peak)
    memory     = HLO_bytes / (chips * hbm_bw)
    collective = per-chip link bytes / link_bw        (already per-device)

plus MODEL_FLOPS = 6*N*D (training; 2*N*D forward-only) with N = (active)
params and D = tokens, and the useful-compute ratio MODEL_FLOPS / HLO_FLOPs.

Note on units: ``cost_analysis()`` on the CPU backend reports FLOPs/bytes of
the *per-device partitioned* module; we convert to per-chip terms directly
(no further division), and cross-check against the analytic MODEL_FLOPS.
"""
from __future__ import annotations

import json
import os
from dataclasses import dataclass
from typing import Dict, Optional

from repro.config import HBM_BW, ICI_BW, PEAK_FLOPS_BF16, SHAPES
from repro.configs import get_config


@dataclass
class RooflineTerms:
    arch: str
    shape: str
    compute_s: float
    memory_s: float
    collective_s: float
    model_flops: float
    hlo_flops: float
    useful_ratio: float
    bottleneck: str
    roofline_fraction: float      # model-useful time / dominant term

    def dominant(self) -> float:
        return max(self.compute_s, self.memory_s, self.collective_s)


def model_flops(arch: str, shape_name: str) -> float:
    """Analytic useful FLOPs for the cell: 6*N*D train, 2*N*D inference."""
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    n_active = cfg.param_count(active_only=True)
    if shape.kind == "train":
        return 6.0 * n_active * shape.tokens
    if shape.kind == "prefill":
        return 2.0 * n_active * shape.tokens
    return 2.0 * n_active * shape.global_batch  # decode: one token per seq


def terms_from_record(rec: dict, *, chips: Optional[int] = None,
                      peak: float = PEAK_FLOPS_BF16, hbm: float = HBM_BW,
                      link: float = ICI_BW) -> RooflineTerms:
    chips = chips or rec["chips"]
    # prefer the loop-aware walked costs (cost_analysis counts scan bodies once)
    flops = float(rec.get("flops_walked") or rec["flops"])
    byts = float(rec.get("bytes_walked") or rec["bytes_accessed"])
    coll = float(rec["collectives"]["total_link_bytes"])
    # cost_analysis of the SPMD module is per-device
    compute = flops / peak
    memory = byts / hbm
    collective = coll / link
    mf = model_flops(rec["arch"], rec["shape"])
    useful = mf / max(flops * chips, 1.0)
    dom = max(compute, memory, collective)
    name = ("compute" if dom == compute else
            "memory" if dom == memory else "collective")
    ideal = mf / (chips * peak)
    return RooflineTerms(
        arch=rec["arch"], shape=rec["shape"],
        compute_s=compute, memory_s=memory, collective_s=collective,
        model_flops=mf, hlo_flops=flops * chips, useful_ratio=useful,
        bottleneck=name, roofline_fraction=ideal / max(dom, 1e-30))


def load_results(path: str) -> Dict[str, dict]:
    with open(path) as f:
        return json.load(f)


def report(path: str, mesh: str = "single", tag: str = "") -> str:
    results = load_results(path)
    lines = [
        f"{'arch':26s} {'shape':12s} {'compute_s':>11s} {'memory_s':>11s} "
        f"{'collect_s':>11s} {'bottleneck':>10s} {'useful':>7s} {'roofline%':>9s}"]
    for key, rec in sorted(results.items()):
        parts = key.split("|")
        if len(parts) < 3 or parts[2] != mesh:
            continue
        if (len(parts) > 3) != bool(tag) or (tag and parts[3] != tag):
            continue
        if rec.get("status") == "skipped":
            lines.append(f"{parts[0]:26s} {parts[1]:12s} {'skipped: ' + rec['reason']}")
            continue
        if rec.get("status") != "ok":
            lines.append(f"{parts[0]:26s} {parts[1]:12s} ERROR")
            continue
        t = terms_from_record(rec)
        lines.append(
            f"{t.arch:26s} {t.shape:12s} {t.compute_s:11.4e} {t.memory_s:11.4e} "
            f"{t.collective_s:11.4e} {t.bottleneck:>10s} {t.useful_ratio:7.3f} "
            f"{100*t.roofline_fraction:8.1f}%")
    return "\n".join(lines)


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--results", default=os.path.abspath(os.path.join(
        os.path.dirname(__file__), "../../../benchmarks/results/dryrun.json")))
    ap.add_argument("--mesh", default="single")
    ap.add_argument("--tag", default="")
    args = ap.parse_args()
    print(report(args.results, args.mesh, args.tag))
