"""Production mesh construction.

Defined as functions (never module-level constants) so importing this module
never touches jax device state.  The dry-run launcher sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before any jax
import; smoke tests and benchmarks see the real single device.
"""
from __future__ import annotations

import jax
from jax.sharding import Mesh

try:                                   # jax >= 0.5: explicit axis types
    from jax.sharding import AxisType
except ImportError:                    # older jax: meshes are Auto already
    AxisType = None


def mesh_axis_kwargs(num_axes: int) -> dict:
    """``axis_types=`` kwarg for :func:`jax.make_mesh`, empty on jax
    versions that predate ``AxisType`` (where Auto is the only behavior)."""
    if AxisType is None:
        return {}
    return {"axis_types": (AxisType.Auto,) * num_axes}


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes, **mesh_axis_kwargs(len(axes)))


def make_host_mesh(model_parallel: int = 1) -> Mesh:
    """Tiny mesh over the real host devices (tests / examples)."""
    n = len(jax.devices())
    data = max(1, n // model_parallel)
    return jax.make_mesh((data, model_parallel), ("data", "model"),
                         **mesh_axis_kwargs(2))


def batch_axes(mesh: Mesh):
    """Mesh axes that shard the batch dimension."""
    return ("pod", "data") if "pod" in mesh.axis_names else ("data",)
