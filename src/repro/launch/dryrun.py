import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

This is the proof that the distribution config is coherent without real
hardware: ``jax.jit(step).lower(**ShapeDtypeStructs).compile()`` must succeed
on the 16x16 single-pod mesh AND the 2x16x16 multi-pod mesh for every
assigned cell.  Per cell we record:

  * memory_analysis()  — argument/output/temp bytes per device (CPU-backend
    temp is pessimistic: no TPU memory passes, no donation aliasing — the
    analytic state estimate is recorded alongside);
  * cost_analysis()    — HLO FLOPs + bytes accessed (roofline numerator);
  * collective bytes   — parsed from the post-partitioning HLO text: operand
    bytes of all-gather / all-reduce / reduce-scatter / all-to-all /
    collective-permute (per-device program => per-chip traffic).

Results append to benchmarks/results/dryrun.json (reruns skip done cells).

Usage:
  python -m repro.launch.dryrun --arch granite-3-8b --shape train_4k --mesh single
  python -m repro.launch.dryrun --all --mesh both
"""
import argparse
import json
import re
import time
import traceback

import numpy as np

DEFAULT_OUT = os.path.join(os.path.dirname(__file__),
                           "../../../benchmarks/results/dryrun.json")

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")
_DTYPE_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "s64": 8, "u64": 8,
                "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1,
                "pred": 1, "f8e4m3fn": 1, "f8e5m2": 1}

# `%x = bf16[8,128]{1,0} all-gather(...)` — result type + collective kind
_COLL_RE = re.compile(
    r"=\s+(?:\()?(\w+)\[([\d,]*)\][^=]*?\s"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(")
_GROUPS_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_BRACES_RE = re.compile(r"replica_groups=\{\{([\d,]+)\}")
_WHILE_RE = re.compile(r"while\(.*?condition=%?([\w\.\-]+), body=%?([\w\.\-]+)")
_CONST_RE = re.compile(r"constant\((\d+)\)")


def _result_bytes(dt: str, dims: str) -> int:
    if dt not in _DTYPE_BYTES:
        return 0
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES[dt]


def _split_computations(hlo_text: str):
    """Map computation name -> list of instruction lines."""
    comps = {}
    cur = None
    for line in hlo_text.splitlines():
        s = line.strip()
        m = re.match(r"(?:ENTRY\s+)?%?([\w\.\-]+)\s*(?:\([^)]*\))?.*\{\s*$", s)
        if ("{" in s and ("->" in s or s.startswith("ENTRY"))) and m:
            cur = m.group(1)
            comps[cur] = []
        elif s == "}":
            cur = None
        elif cur is not None:
            comps[cur].append(s)
    return comps


def _link_bytes(kind: str, result: int, g: int) -> float:
    """Per-device ring traffic for one execution, from the result size."""
    g = max(g, 2)
    if kind == "all-gather":
        return result * (g - 1) / g            # operand = result/g, send (g-1) shards
    if kind == "reduce-scatter":
        return result * (g - 1)                # operand = result*g
    if kind == "all-reduce":
        return 2.0 * result * (g - 1) / g
    return result * (g - 1) / g if kind == "all-to-all" else float(result)


def collective_stats(hlo_text: str):
    """Per-device collective traffic from post-SPMD HLO text, with
    while-loop (scan) bodies multiplied by their trip counts (estimated from
    the largest integer constant in the loop condition computation)."""
    comps = _split_computations(hlo_text)

    # trip-count estimate per condition computation
    def trip_of(cond_name):
        best = 1
        for line in comps.get(cond_name, []):
            if "compare" in line or "constant" in line:
                for m in _CONST_RE.finditer(line):
                    best = max(best, int(m.group(1)))
        return best

    memo = {}

    def comp_stats(name):
        if name in memo:
            return memo[name]
        totals = {k: {"count": 0.0, "bytes": 0.0, "link_bytes": 0.0}
                  for k in _COLLECTIVES}
        for line in comps.get(name, []):
            m = _COLL_RE.search(line)
            if m:
                dt, dims, kind = m.groups()
                res = _result_bytes(dt, dims)
                gm = _GROUPS_RE.search(line)
                if gm:
                    g = int(gm.group(2))
                else:
                    gb = _GROUPS_BRACES_RE.search(line)
                    g = len(gb.group(1).split(",")) if gb else 2
                totals[kind]["count"] += 1
                totals[kind]["bytes"] += res
                totals[kind]["link_bytes"] += _link_bytes(kind, res, g)
                continue
            wm = _WHILE_RE.search(line)
            if wm:
                cond, body = wm.groups()
                trips = trip_of(cond)
                sub = comp_stats(body)
                for k in _COLLECTIVES:
                    for f in ("count", "bytes", "link_bytes"):
                        totals[k][f] += trips * sub[k][f]
        memo[name] = totals
        return totals

    # entry computation: the one holding top-level while ops; fall back to sum
    entry = None
    for line in hlo_text.splitlines():
        if line.startswith("ENTRY"):
            m = re.match(r"ENTRY\s+%?([\w\.\-]+)", line)
            if m:
                entry = m.group(1)
            break
    if entry is None or entry not in comps:
        entry = max(comps, key=lambda c: len(comps[c])) if comps else None
    totals = comp_stats(entry) if entry else {
        k: {"count": 0, "bytes": 0, "link_bytes": 0} for k in _COLLECTIVES}
    out = {k: {"count": totals[k]["count"], "bytes": totals[k]["bytes"],
               "link_bytes": totals[k]["link_bytes"]} for k in _COLLECTIVES}
    out["total_bytes"] = sum(out[k]["bytes"] for k in _COLLECTIVES)
    out["total_link_bytes"] = sum(out[k]["link_bytes"] for k in _COLLECTIVES)
    return out


def run_cell(arch: str, shape_name: str, multi_pod: bool, *,
             exit_point=None, moe_dispatch="einsum", attn_impl="auto",
             ce_chunk=512, scan_chunk=16, kv_quant=False, seq_parallel=False,
             extra_tag="") -> dict:
    import jax
    from repro.config import SHAPES, cell_applicable
    from repro.configs import get_config
    from repro.launch.mesh import make_production_mesh
    from repro.launch.steps import make_step
    from repro.models import Model

    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    ok, reason = cell_applicable(cfg, shape)
    if not ok:
        return {"status": "skipped", "reason": reason}
    mesh = make_production_mesh(multi_pod=multi_pod)
    model = Model(cfg)
    kw = {}
    if shape.kind == "train":
        kw = dict(moe_dispatch=moe_dispatch, attn_impl=attn_impl,
                  ce_chunk=ce_chunk, scan_chunk=scan_chunk,
                  seq_parallel=seq_parallel)
    elif shape.kind == "prefill":
        kw = dict(moe_dispatch=moe_dispatch, attn_impl=attn_impl)
    else:
        kw = dict(moe_dispatch=moe_dispatch, exit_point=exit_point,
                  kv_quant=kv_quant)
    step, abstract_inputs = make_step(model, mesh, shape, **kw)

    t0 = time.time()
    with mesh:
        lowered = step.lower(*abstract_inputs())
    t_lower = time.time() - t0
    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0

    ma = compiled.memory_analysis()
    ca = compiled.cost_analysis() or {}
    txt = compiled.as_text()
    coll = collective_stats(txt)
    from repro.launch.hlo_cost import walk_costs
    flops_walked, bytes_walked = walk_costs(txt, fused=True)
    _, bytes_literal = walk_costs(txt, fused=False)
    n_chips = int(np.prod(list(mesh.shape.values())))

    # archive the HLO for offline re-analysis (no recompiles needed)
    import gzip
    hlo_dir = os.path.join(os.path.dirname(os.path.abspath(DEFAULT_OUT)), "hlo")
    os.makedirs(hlo_dir, exist_ok=True)
    tagstr = f"_{extra_tag}" if extra_tag else ""
    hlo_path = os.path.join(
        hlo_dir, f"{arch}_{shape_name}_{'multi' if multi_pod else 'single'}{tagstr}.txt.gz")
    with gzip.open(hlo_path, "wt") as f:
        f.write(txt)

    # analytic steady-state per-device bytes (params [+opt] [+cache])
    pbytes = int(sum(np.prod(l.shape) * l.dtype.itemsize
                     for l in jax.tree.leaves(model.abstract_params())))
    state = pbytes
    if shape.kind == "train":
        state += 2 * 4 * (pbytes // 2) + pbytes          # f32 moments + grads
    if shape.kind != "train":
        cache = jax.eval_shape(lambda: model.init_cache(
            shape.global_batch, shape.seq_len, enc_len=shape.seq_len))
        state += int(sum(np.prod(l.shape) * l.dtype.itemsize
                         for l in jax.tree.leaves(cache)))

    return {
        "status": "ok",
        "arch": arch, "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "chips": n_chips,
        "lower_s": round(t_lower, 1), "compile_s": round(t_compile, 1),
        "flops": ca.get("flops", 0.0),
        "bytes_accessed": ca.get("bytes accessed", 0.0),
        "flops_walked": flops_walked,      # loop-aware (see hlo_cost.py)
        "bytes_walked": bytes_walked,      # fusion-closure byte model
        "bytes_literal": bytes_literal,    # every materialized op billed
        "collectives": coll,
        "memory": {
            "argument_bytes": ma.argument_size_in_bytes,
            "output_bytes": ma.output_size_in_bytes,
            "temp_bytes": ma.temp_size_in_bytes,
            "alias_bytes": ma.alias_size_in_bytes,
            "generated_code_bytes": ma.generated_code_size_in_bytes,
        },
        "analytic_state_bytes_per_chip": state // n_chips,
        "tag": extra_tag,
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--mesh", default="both", choices=["single", "multi", "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default=os.path.abspath(DEFAULT_OUT))
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--exit-point", type=int, default=None)
    ap.add_argument("--moe-dispatch", default="einsum")
    ap.add_argument("--attn-impl", default="auto")
    ap.add_argument("--ce-chunk", type=int, default=512)
    ap.add_argument("--scan-chunk", type=int, default=16)
    ap.add_argument("--kv-quant", action="store_true")
    ap.add_argument("--seq-parallel", action="store_true")
    ap.add_argument("--tag", default="")
    args = ap.parse_args()

    from repro.config import SHAPES
    from repro.configs import ARCH_IDS

    os.makedirs(os.path.dirname(args.out), exist_ok=True)
    results = {}
    if os.path.exists(args.out):
        with open(args.out) as f:
            results = json.load(f)

    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]
    cells = []
    if args.all:
        for arch in ARCH_IDS:
            for shape in SHAPES:
                for mp in meshes:
                    cells.append((arch, shape, mp))
    else:
        for mp in meshes:
            cells.append((args.arch, args.shape, mp))

    n_ok = n_skip = n_fail = 0
    for arch, shape, mp in cells:
        key = f"{arch}|{shape}|{'multi' if mp else 'single'}"
        if args.tag:
            key += f"|{args.tag}"
        if key in results and results[key].get("status") in ("ok", "skipped") \
                and not args.force:
            print(f"[cached] {key}: {results[key]['status']}")
            n_ok += results[key]["status"] == "ok"
            n_skip += results[key]["status"] == "skipped"
            continue
        print(f"[run] {key} ...", flush=True)
        try:
            r = run_cell(arch, shape, mp, exit_point=args.exit_point,
                         moe_dispatch=args.moe_dispatch,
                         attn_impl=args.attn_impl, ce_chunk=args.ce_chunk,
                         scan_chunk=args.scan_chunk, kv_quant=args.kv_quant,
                         seq_parallel=args.seq_parallel, extra_tag=args.tag)
        except Exception as e:  # record and continue
            r = {"status": "error", "error": f"{type(e).__name__}: {e}",
                 "trace": traceback.format_exc()[-2000:]}
        results[key] = r
        with open(args.out, "w") as f:
            json.dump(results, f, indent=1)
        if r["status"] == "ok":
            n_ok += 1
            print(f"    ok: lower={r['lower_s']}s compile={r['compile_s']}s "
                  f"flops={r['flops']:.3e} coll={r['collectives']['total_bytes']:.3e}B",
                  flush=True)
        elif r["status"] == "skipped":
            n_skip += 1
            print(f"    skipped: {r['reason']}", flush=True)
        else:
            n_fail += 1
            print(f"    ERROR: {r['error']}", flush=True)
    print(f"\ndone: ok={n_ok} skipped={n_skip} failed={n_fail}")


if __name__ == "__main__":
    main()
