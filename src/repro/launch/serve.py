"""Serving driver: ``python -m repro.launch.serve --arch <id>``.

Runs the Edgent-planned two-tier serving engine on the smoke config: builds
the LM inference graph, arms the planner (static or dynamic configurator),
streams batched requests against a bandwidth trace, reports SLO attainment /
exit statistics — the paper's co-inference stage as a service.
"""
from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, get_smoke_config
from repro.core import EdgentPlanner, lm_graph
from repro.core.latency_model import RooflineLatencyModel
from repro.data.bandwidth import belgium_lte_like, dcn_trace
from repro.models import Model
from repro.serving import Request, ServingEngine
from repro.serving.tiers import Link


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-1b")
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--new-tokens", type=int, default=16)
    ap.add_argument("--slo-ms", type=float, default=400.0)
    ap.add_argument("--dynamic", action="store_true")
    ap.add_argument("--trace", default="dcn", choices=["dcn", "lte"])
    ap.add_argument("--batch", type=int, default=4)
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch)
    model = Model(cfg)
    rng = jax.random.key(0)
    params = model.init_params(rng, dtype=jnp.float32)

    # tiers: edge = 8-chip slice, device = 1 chip (datacenter adaptation);
    # full-size graph for virtual timing, smoke model for token values
    graph = lm_graph(get_config(args.arch), batch=args.batch, seq=1)
    f_edge = RooflineLatencyModel(chips=8, efficiency=0.4)
    f_device = RooflineLatencyModel(chips=1, efficiency=0.4)
    planner = EdgentPlanner(graph, latency_req_s=args.slo_ms / 1e3)
    planner.with_models(f_edge, f_device)
    trace = (dcn_trace(0, 2048) if args.trace == "dcn"
             else belgium_lte_like(0, 2048))
    if args.dynamic:
        hist = [trace[i : i + 49] for i in range(0, 980, 49)]
        planner.offline_dynamic(hist)
    link = Link(trace_bps=trace)

    engine = ServingEngine(model, params, graph, planner, link,
                           batch_size=args.batch, dynamic=args.dynamic)
    rs = np.random.default_rng(0)
    reqs = [Request(rid=i,
                    prompt=rs.integers(0, cfg.vocab_size, 12).astype(np.int32),
                    max_new_tokens=args.new_tokens,
                    slo_s=args.slo_ms / 1e3)
            for i in range(args.requests)]
    stats = engine.serve(reqs)
    print("summary:", stats.summary())


if __name__ == "__main__":
    main()
