"""Training driver: ``python -m repro.launch.train --arch <id> [--smoke]``.

Full configs only make sense on real hardware; ``--smoke`` (default on CPU)
trains the reduced config on the host mesh with the full production stack:
sharded step, checkpoint/restart (auto-resume), failure injection for drills.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpointing import CheckpointManager
from repro.config import ShapeConfig
from repro.configs import get_config, get_smoke_config
from repro.data.synthetic import token_batches
from repro.launch.mesh import make_host_mesh, make_production_mesh
from repro.launch.steps import make_train_step
from repro.models import Model
from repro.optim.adamw import adamw_init
from repro.runtime.fault_tolerance import FailureInjector, ResilientLoop


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-1b")
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--full", dest="smoke", action="store_false")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--save-every", type=int, default=50)
    ap.add_argument("--inject-failure-at", type=int, default=None)
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    model = Model(cfg)
    mesh = make_host_mesh() if args.smoke else make_production_mesh()
    shape = ShapeConfig("cli", args.seq, args.batch, "train")
    step, _ = make_train_step(model, mesh, shape, remat=True,
                              ce_chunk=min(512, args.seq))

    rng = jax.random.key(0)
    with mesh:
        params = model.init_params(rng, dtype=jnp.float32 if args.smoke else jnp.bfloat16)
        opt = adamw_init(params)
    data = token_batches(0, args.batch, args.seq, cfg.vocab_size)

    ckpt = CheckpointManager(args.ckpt_dir)
    loop = ResilientLoop(ckpt, save_every=args.save_every)
    injector = (FailureInjector(fail_at=(args.inject_failure_at,))
                if args.inject_failure_at else None)
    losses = []

    def step_fn(state, i):
        params, opt = state
        batch = {"tokens": jnp.asarray(next(data))}
        if cfg.is_encdec:
            batch["frames"] = jax.random.normal(
                jax.random.fold_in(rng, i), (args.batch, args.seq, 1024),
                jnp.float32)
        if cfg.frontend == "vision":
            batch["prefix_emb"] = jax.random.normal(
                jax.random.fold_in(rng, i),
                (args.batch, cfg.num_prefix_tokens, 1024), jnp.float32)
            batch["tokens"] = batch["tokens"][:, : args.seq - cfg.num_prefix_tokens + 1]
        with mesh:
            params, opt, metrics = step(params, opt, batch)
        loss = float(metrics["loss"])
        losses.append(loss)
        if i % 20 == 0:
            print(f"step {i:5d} loss {loss:.4f}", flush=True)
        return params, opt

    t0 = time.time()
    (params, opt), info = loop.run((params, opt), step_fn, args.steps,
                                   injector=injector,
                                   on_restart=lambda s: print(f"[restart] resumed at step {s}"))
    dt = time.time() - t0
    print(f"done: {args.steps} steps in {dt:.1f}s "
          f"({args.steps * args.batch * args.seq / dt:.0f} tok/s), "
          f"loss {losses[0]:.3f} -> {losses[-1]:.3f}, restarts={info['restarts']}")


if __name__ == "__main__":
    main()
