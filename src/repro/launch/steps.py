"""Jitted, sharded step builders: train_step / prefill_step / serve_step.

One function per (model, mesh, shape-kind); in/out shardings are explicit
NamedSharding trees (FSDP on ``data``, TP on ``model``, batch over
``pod``+``data``), params/opt-state/cache donated.  The dry-run lowers these
with ShapeDtypeStruct inputs; the real drivers execute them.
"""
from __future__ import annotations

from functools import partial
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

from repro.config import ModelConfig, ShapeConfig
from repro.launch.mesh import batch_axes
from repro.models.api import Model
from repro.optim.adamw import AdamWState, adamw_init, adamw_update
from repro.optim.schedule import warmup_cosine


def _ns(mesh: Mesh, spec_tree):
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s),
        spec_tree, is_leaf=lambda x: isinstance(x, P))


def batch_specs(cfg: ModelConfig, shape: ShapeConfig, mesh: Mesh):
    """PartitionSpec tree matching Model.make_inputs output."""
    b = batch_axes(mesh)
    # decide batch shardability: every batch-axis group must divide B
    groups = 1
    for ax in b:
        groups *= mesh.shape[ax]
    bspec = b if shape.global_batch % groups == 0 else None
    if shape.kind == "train":
        out = {"tokens": P(bspec, None)}
        if cfg.is_encdec:
            out["frames"] = P(bspec, None, None)
        if cfg.frontend == "vision":
            out["prefix_emb"] = P(bspec, None, None)
        return out
    if shape.kind == "prefill":
        out = {"tokens": P(bspec, None)}
        if cfg.is_encdec:
            out["frames"] = P(bspec, None, None)
        if cfg.frontend == "vision":
            out["prefix_emb"] = P(bspec, None, None)
        return out
    return {"tokens": P(bspec, None), "pos": P()}


def cache_sharding_axes(shape: ShapeConfig, mesh: Mesh):
    """(batch_axes, seq_axes) for the KV cache / recurrent state."""
    b = batch_axes(mesh)
    groups = 1
    for ax in b:
        groups *= mesh.shape[ax]
    if shape.global_batch % groups == 0:
        return b, "model"
    # tiny batch (long-context): replicate batch, shard cache seq everywhere
    return None, tuple(mesh.axis_names)


# ----------------------------------------------------------------------------
# train
# ----------------------------------------------------------------------------

def make_train_step(model: Model, mesh: Mesh, shape: ShapeConfig, *,
                    moment_dtype=jnp.float32, peak_lr: float = 3e-4,
                    warmup: int = 200, total_steps: int = 10000,
                    remat: bool = True, moe_dispatch: str = "einsum",
                    attn_impl: str = "auto", use_kernel: bool = False,
                    ce_chunk: int = 512, scan_chunk: int = 16,
                    seq_parallel: bool = False):
    cfg = model.cfg
    pspecs = model.param_specs()
    ospecs = AdamWState(step=P(), mu=pspecs, nu=pspecs)
    bspecs = batch_specs(cfg, shape, mesh)
    p_sh, o_sh, b_sh = _ns(mesh, pspecs), _ns(mesh, ospecs), _ns(mesh, bspecs)
    metric_sh = NamedSharding(mesh, P())

    def train_step(params, opt_state, batch):
        def loss_fn(p):
            return model.loss(p, batch, remat=remat, moe_dispatch=moe_dispatch,
                              attn_impl=attn_impl, use_kernel=use_kernel,
                              scan_chunk=scan_chunk, seq_parallel=seq_parallel)
        (loss, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(params)
        lr = warmup_cosine(opt_state.step, peak_lr=peak_lr, warmup=warmup,
                           total=total_steps)
        new_params, new_opt = adamw_update(grads, opt_state, params, lr=lr)
        return new_params, new_opt, {"loss": metrics["loss"],
                                     "final_ce": metrics["final_ce"]}

    jitted = jax.jit(
        train_step,
        in_shardings=(p_sh, o_sh, b_sh),
        out_shardings=(p_sh, o_sh,
                       {"loss": metric_sh, "final_ce": metric_sh}),
        donate_argnums=(0, 1),
    )

    def abstract_inputs():
        params = model.abstract_params()
        opt = jax.eval_shape(partial(adamw_init, moment_dtype=moment_dtype), params)
        batch = model.make_inputs(shape, abstract=True)
        return params, opt, batch

    return jitted, abstract_inputs


# ----------------------------------------------------------------------------
# prefill
# ----------------------------------------------------------------------------

def make_prefill_step(model: Model, mesh: Mesh, shape: ShapeConfig, *,
                      attn_impl: str = "auto", moe_dispatch: str = "einsum",
                      use_kernel: bool = False):
    cfg = model.cfg
    pspecs = model.param_specs()
    bspecs = batch_specs(cfg, shape, mesh)
    baxes, saxes = cache_sharding_axes(shape, mesh)
    cspecs = model.cache_specs(batch_axes=baxes, seq_axes=saxes)
    p_sh, b_sh, c_sh = _ns(mesh, pspecs), _ns(mesh, bspecs), _ns(mesh, cspecs)
    h_sh = NamedSharding(mesh, P(None if baxes is None else baxes, None, None))

    def prefill_step(params, batch):
        cache = model.init_cache(shape.global_batch, shape.seq_len,
                                 enc_len=shape.seq_len)
        h, cache = model.prefill(params, batch["tokens"], cache,
                                 frames=batch.get("frames"),
                                 prefix_emb=batch.get("prefix_emb"),
                                 attn_impl=attn_impl,
                                 moe_dispatch=moe_dispatch,
                                 use_kernel=use_kernel)
        return h, cache

    jitted = jax.jit(prefill_step, in_shardings=(p_sh, b_sh),
                     out_shardings=(h_sh, c_sh))

    def abstract_inputs():
        return model.abstract_params(), model.make_inputs(shape, abstract=True)

    return jitted, abstract_inputs


# ----------------------------------------------------------------------------
# decode (serve_step)
# ----------------------------------------------------------------------------

def make_serve_step(model: Model, mesh: Mesh, shape: ShapeConfig, *,
                    exit_point: Optional[int] = None,
                    with_exit_confidence: bool = False,
                    use_exit_kernel: bool = False,
                    moe_dispatch: str = "einsum", use_kernel: bool = False,
                    kv_quant: bool = False):
    """One-token decode against a seq_len cache (the paper's serving step;
    ``exit_point`` compiles the right-sized variant)."""
    cfg = model.cfg
    pspecs = model.param_specs()
    bspecs = batch_specs(cfg, shape, mesh)
    baxes, saxes = cache_sharding_axes(shape, mesh)
    cspecs = model.cache_specs(batch_axes=baxes, seq_axes=saxes, quant=kv_quant)
    p_sh, b_sh, c_sh = _ns(mesh, pspecs), _ns(mesh, bspecs), _ns(mesh, cspecs)
    tok_sh = NamedSharding(mesh, P(baxes, None))

    def serve_step(params, cache, batch):
        h, new_cache, confs = model.decode_step(
            params, cache, batch["tokens"], batch["pos"],
            exit_point=exit_point, moe_dispatch=moe_dispatch,
            with_exit_confidence=with_exit_confidence,
            use_exit_kernel=use_exit_kernel, use_kernel=use_kernel)
        logits = model.logits(params, h)
        token = jnp.argmax(logits[:, -1, :], axis=-1).astype(jnp.int32)[:, None]
        return token, new_cache

    jitted = jax.jit(serve_step, in_shardings=(p_sh, c_sh, b_sh),
                     out_shardings=(tok_sh, c_sh), donate_argnums=(1,))

    def abstract_inputs():
        params = model.abstract_params()
        cache = jax.eval_shape(lambda: model.init_cache(
            shape.global_batch, shape.seq_len, enc_len=shape.seq_len,
            quant=kv_quant))
        batch = model.make_inputs(shape, abstract=True)
        return params, cache, batch

    return jitted, abstract_inputs


def make_step(model: Model, mesh: Mesh, shape: ShapeConfig, **kw):
    if shape.kind == "train":
        return make_train_step(model, mesh, shape, **kw)
    if shape.kind == "prefill":
        return make_prefill_step(model, mesh, shape, **kw)
    return make_serve_step(model, mesh, shape, **kw)
