"""Top-1 Mixture-of-Experts FFN (llama4-*), GShard-style einsum dispatch.

Tokens are grouped as [G, T_g] with G sharded over ``data`` and experts
sharded over ``model`` — GSPMD lowers the dispatch/combine einsums into the
canonical all-to-all pattern.  Capacity-factor drop policy; dense one-hot
dispatch is the paper-era baseline, a gather-based dispatch lives in
``moe_gather`` (perf hillclimb, see EXPERIMENTS.md §Perf).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.config import ModelConfig
from repro.models.layers import dense_init, rms_norm


def init_moe(key, cfg: ModelConfig, dtype, stack: int = 0):
    d, f, e = cfg.d_model, cfg.d_ff, cfg.num_experts
    ks = jax.random.split(key, 4)
    pre = (stack,) if stack else ()
    return {
        "router": dense_init(ks[0], pre + (d, e), jnp.float32, d),
        "wg": dense_init(ks[1], pre + (e, d, f), dtype, d),
        "wu": dense_init(ks[2], pre + (e, d, f), dtype, d),
        "wd": dense_init(ks[3], pre + (e, f, d), dtype, f),
        "ln": jnp.ones(pre + (d,), dtype),
    }


def spec_moe(stack: bool = False):
    pre = (None,) if stack else ()
    return {
        "router": P(*pre, "data", None),
        "wg": P(*pre, "model", "data", None),
        "wu": P(*pre, "model", "data", None),
        "wd": P(*pre, "model", None, "data"),
        "ln": P(*pre, None),
    }


def _capacity(tokens_per_group: int, cfg: ModelConfig) -> int:
    c = int(tokens_per_group * cfg.capacity_factor * cfg.experts_per_tok / cfg.num_experts)
    return max(4, c)


def moe_ffn(p, cfg: ModelConfig, x, *, dispatch_mode: str = "einsum"):
    """x: [B, S, D] -> [B, S, D]; returns (out, aux_loss)."""
    B, S, D = x.shape
    E = cfg.num_experts
    xn = rms_norm(x, p["ln"], cfg.norm_eps)
    g = xn.reshape(B, S, D)  # groups = batch rows
    router_logits = jnp.einsum("gsd,de->gse", g.astype(jnp.float32), p["router"])
    probs = jax.nn.softmax(router_logits, axis=-1)            # [G,S,E]
    expert_idx = jnp.argmax(probs, axis=-1)                   # [G,S]
    top_p = jnp.take_along_axis(probs, expert_idx[..., None], axis=-1)[..., 0]

    # load-balancing aux loss (Switch): E * sum_e f_e * p_e
    me = jnp.mean(jax.nn.one_hot(expert_idx, E, dtype=jnp.float32), axis=(0, 1))
    ce = jnp.mean(probs, axis=(0, 1))
    aux = E * jnp.sum(me * ce)

    C = _capacity(S, cfg)
    onehot = jax.nn.one_hot(expert_idx, E, dtype=jnp.float32)          # [G,S,E]
    pos = jnp.cumsum(onehot, axis=1) * onehot                          # 1-based slot
    slot = (pos - 1.0).max(axis=-1).astype(jnp.int32)                  # [G,S]
    keep = (slot < C) & (pos.max(axis=-1) > 0)
    slot_oh = jax.nn.one_hot(slot, C, dtype=jnp.float32) * keep[..., None]
    dispatch = onehot[..., None] * slot_oh[..., None, :]               # [G,S,E,C]
    dispatch = dispatch.astype(x.dtype)
    combine = dispatch * top_p[..., None, None].astype(x.dtype)

    if dispatch_mode == "gather":
        return _moe_gather(p, cfg, g, expert_idx, top_p, keep), aux

    xe = jnp.einsum("gsec,gsd->egcd", dispatch, g)                     # a2a: data->model
    h = jax.nn.silu(jnp.einsum("egcd,edf->egcf", xe, p["wg"]))
    h = h * jnp.einsum("egcd,edf->egcf", xe, p["wu"])
    ye = jnp.einsum("egcf,efd->egcd", h, p["wd"])
    out = jnp.einsum("gsec,egcd->gsd", combine, ye)                    # a2a: model->data
    return out.reshape(B, S, D), aux


def _moe_gather(p, cfg: ModelConfig, g, expert_idx, top_p, keep):
    """Gather-based dispatch: sort tokens by expert, run experts on
    contiguous slabs, scatter back.  Cuts the one-hot dispatch matmul FLOPs
    (beyond-paper optimization; see EXPERIMENTS.md §Perf)."""
    G, S, D = g.shape
    E = cfg.num_experts
    C = _capacity(S, cfg)
    # position of each token within its expert's capacity slab
    onehot = jax.nn.one_hot(expert_idx, E, dtype=jnp.int32)
    slot = (jnp.cumsum(onehot, axis=1) * onehot).max(axis=-1) - 1      # [G,S]
    ok = keep
    dest = jnp.where(ok, expert_idx * C + slot, E * C)                 # overflow bucket
    slab = jnp.zeros((G, E * C + 1, D), g.dtype)
    slab = jax.vmap(lambda sl, d_, v: sl.at[d_].add(v))(slab, dest, g)  # scatter
    xe = slab[:, : E * C].reshape(G, E, C, D).transpose(1, 0, 2, 3)     # [E,G,C,D]
    h = jax.nn.silu(jnp.einsum("egcd,edf->egcf", xe, p["wg"]))
    h = h * jnp.einsum("egcd,edf->egcf", xe, p["wu"])
    ye = jnp.einsum("egcf,efd->egcd", h, p["wd"]).transpose(1, 0, 2, 3)  # [G,E,C,D]
    ye = ye.reshape(G, E * C, D)
    out = jax.vmap(lambda y, d_: y[jnp.minimum(d_, E * C - 1)])(ye, dest)
    out = out * (ok[..., None] * top_p[..., None]).astype(g.dtype)
    return out
