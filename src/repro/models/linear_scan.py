"""Diagonal-decay linear-attention scan — the shared recurrence of RWKV-6 and
Mamba-2 (SSD):

    S_t = diag(w_t) @ S_{t-1} + k_t v_t^T          (state:  [dk, dv])
    o_t = q_t @ (S_{t-1} + diag(u) k_t v_t^T)      (rwkv: pre-update + bonus)
    o_t = q_t @ S_t                                 (mamba2: post-update)

Two implementations with identical semantics:
  * ``scan_sequential`` — plain ``lax.scan`` over time (decode / oracle).
  * ``scan_chunked``    — chunk-parallel ratio-trick formulation (train /
    prefill); per chunk the intra-chunk part is a masked matmul, the
    inter-chunk part carries the state.  This is the jnp twin of the Pallas
    kernel in ``repro.kernels.ssm_scan``.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

# clamp on per-step log-decay: keeps the chunk ratio trick inside f32 range
MIN_LOG_W = -8.0


def scan_sequential(q, k, v, log_w, state, u=None):
    """q/k/log_w: [B,S,H,dk]; v: [B,S,H,dv]; state: [B,H,dk,dv] (f32).

    Returns (o [B,S,H,dv], final_state).  ``u`` (per-head bonus, [H,dk])
    switches to RWKV semantics (output from pre-update state + bonus)."""
    B, S, H, dk = q.shape
    dv = v.shape[-1]
    qf, kf, vf = (t.astype(jnp.float32) for t in (q, k, v))
    w = jnp.exp(jnp.maximum(log_w.astype(jnp.float32), MIN_LOG_W))

    def step(s, inp):
        qt, kt, vt, wt = inp  # [B,H,dk], [B,H,dk], [B,H,dv], [B,H,dk]
        kv = kt[..., :, None] * vt[..., None, :]          # [B,H,dk,dv]
        if u is not None:
            o = jnp.einsum("bhk,bhkv->bhv", qt, s + u[None, :, :, None] * kv)
            s = wt[..., None] * s + kv
        else:
            s = wt[..., None] * s + kv
            o = jnp.einsum("bhk,bhkv->bhv", qt, s)
        return s, o

    xs = (qf.transpose(1, 0, 2, 3), kf.transpose(1, 0, 2, 3),
          vf.transpose(1, 0, 2, 3), w.transpose(1, 0, 2, 3))
    state, o = jax.lax.scan(step, state.astype(jnp.float32), xs)
    return o.transpose(1, 0, 2, 3).astype(v.dtype), state


def scan_chunked(q, k, v, log_w, state, u=None, chunk: int = 16):
    """Chunk-parallel twin of :func:`scan_sequential` (same outputs).

    Within a chunk of length C the output decomposes into
      inter: (q_t * P_{t-1}) @ S_chunk_in
      intra: [(q_t * P_{t-1}) @ (k_s / P_s)^T masked s<t  (+ diag bonus)] @ v
    where P_t = prod_{tau<=t} w_tau.  MIN_LOG_W bounds P so k/P stays finite
    in f32 for C <= 32.
    """
    B, S, H, dk = q.shape
    dv = v.shape[-1]
    assert S % chunk == 0, (S, chunk)
    C = chunk
    N = S // C
    qf = q.astype(jnp.float32).reshape(B, N, C, H, dk)
    kf = k.astype(jnp.float32).reshape(B, N, C, H, dk)
    vf = v.astype(jnp.float32).reshape(B, N, C, H, dv)
    lw = jnp.maximum(log_w.astype(jnp.float32), MIN_LOG_W).reshape(B, N, C, H, dk)

    def chunk_step(s, inp):
        qc, kc, vc, lwc = inp                       # [B,C,H,*]
        logP = jnp.cumsum(lwc, axis=1)              # [B,C,H,dk], log P_t
        P = jnp.exp(logP)
        k_ = kc / P
        if u is not None:
            # rwkv: pre-update state -> coeff P_{t-1}, strict mask, diag bonus u
            q_ = qc * jnp.exp(logP - lwc)
            A = jnp.einsum("bthk,bshk->bhts", q_, k_)
            A = A * jnp.tril(jnp.ones((C, C), jnp.float32), -1)[None, None]
            diag = jnp.einsum("bthk,hk,bthk->bth", qc, u, kc)  # [B,C,H]
            A = A + jnp.eye(C, dtype=jnp.float32)[None, None] * diag.transpose(0, 2, 1)[:, :, :, None]
        else:
            # mamba2: post-update state -> coeff P_t, inclusive mask
            q_ = qc * P
            A = jnp.einsum("bthk,bshk->bhts", q_, k_)
            A = A * jnp.tril(jnp.ones((C, C), jnp.float32))[None, None]
        intra = jnp.einsum("bhts,bshv->bthv", A, vc)
        inter = jnp.einsum("bthk,bhkv->bthv", q_, s)
        # state update: S' = diag(P_C) S + sum_s diag(P_C / P_s) k_s v_s
        kP = kc * jnp.exp(logP[:, -1:, :, :] - logP)
        s = P[:, -1][..., None] * s + jnp.einsum("bshk,bshv->bhkv", kP, vc)
        return s, intra + inter

    xs = tuple(t.transpose(1, 0, 2, 3, 4) for t in (qf, kf, vf, lw))
    # remat the chunk body: autodiff then saves only (state, chunk inputs)
    # per step instead of every intra-chunk intermediate (logP, k/P, A, ...)
    # — the dominant HBM-residual traffic of SSM training
    # (EXPERIMENTS.md §Perf C2)
    state, o = jax.lax.scan(jax.checkpoint(chunk_step),
                            state.astype(jnp.float32), xs)
    o = o.transpose(1, 0, 2, 3, 4).reshape(B, S, H, dv)
    return o.astype(v.dtype), state


def linear_scan(q, k, v, log_w, state, u=None, *, mode: str = "auto",
                chunk: int = 16, use_kernel: bool = False):
    """Dispatch: sequential for short/decode, chunked for long sequences,
    Pallas kernel when ``use_kernel`` (TPU target; interpret on CPU tests)."""
    if use_kernel:
        from repro.kernels.ssm_scan import ops as ssm_ops
        return ssm_ops.ssm_scan(q, k, v, log_w, state, u=u, chunk=chunk)
    S = q.shape[1]
    if mode == "sequential" or (mode == "auto" and (S < chunk or S % chunk)):
        return scan_sequential(q, k, v, log_w, state, u=u)
    return scan_chunked(q, k, v, log_w, state, u=u, chunk=chunk)
