"""Decoder-only LM stack (dense / MoE / VLM families).

Layers are grouped into *segments* separated by early-exit heads (the paper's
right-sizing knob); each segment is a ``lax.scan`` over stacked layer params,
so HLO size is O(num_segments), not O(num_layers).  For ``moe_period == 2``
(llama4-maverick) the scan unit is a (dense-FFN layer, MoE layer) pair.

Exit heads are tied to the embedding (RMSNorm + shared vocab projection), so
right-sizing adds compute but no parameters — BranchyNet-faithful.
"""
from __future__ import annotations

import math
from functools import partial
from typing import Any, Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.config import ModelConfig
from repro.models import layers as L
from repro.models import moe as MOE

VIS_DIM = 1024  # stub modality-frontend embedding width


# ----------------------------------------------------------------------------
# structure: units / segments
# ----------------------------------------------------------------------------

def unit_size(cfg: ModelConfig) -> int:
    if cfg.num_experts and cfg.moe_period == 2:
        return 2
    return 1


def num_units(cfg: ModelConfig) -> int:
    return cfg.num_layers // unit_size(cfg)


def segment_boundaries(cfg: ModelConfig):
    """Exit positions in *units*, strictly inside (0, n_units)."""
    n = num_units(cfg)
    u = unit_size(cfg)
    bounds = []
    for li in cfg.exit_layer_indices():
        b = min(max(1, round(li / u)), n - 1)
        if b not in bounds:
            bounds.append(b)
    return sorted(bounds)


def segment_lengths(cfg: ModelConfig):
    bounds = segment_boundaries(cfg)
    edges = [0] + bounds + [num_units(cfg)]
    return [b - a for a, b in zip(edges[:-1], edges[1:])]


# ----------------------------------------------------------------------------
# params
# ----------------------------------------------------------------------------

def _init_unit(key, cfg: ModelConfig, dtype, n: int):
    ks = jax.random.split(key, 4)
    u = unit_size(cfg)
    if cfg.num_experts and u == 2:
        return {
            "attn0": L.init_attn(ks[0], cfg, dtype, stack=n),
            "ffn": L.init_ffn(ks[1], cfg, dtype, stack=n),
            "attn1": L.init_attn(ks[2], cfg, dtype, stack=n),
            "moe": MOE.init_moe(ks[3], cfg, dtype, stack=n),
        }
    if cfg.num_experts:
        return {
            "attn": L.init_attn(ks[0], cfg, dtype, stack=n),
            "moe": MOE.init_moe(ks[1], cfg, dtype, stack=n),
        }
    return {
        "attn": L.init_attn(ks[0], cfg, dtype, stack=n),
        "ffn": L.init_ffn(ks[1], cfg, dtype, stack=n),
    }


def _attn_shard_flags(cfg: ModelConfig):
    from repro.config import MODEL_AXIS_SIZE
    return (cfg.padded_heads % MODEL_AXIS_SIZE == 0,
            cfg.num_kv_heads % MODEL_AXIS_SIZE == 0)


def _spec_unit(cfg: ModelConfig):
    qs, ks = _attn_shard_flags(cfg)
    sa = L.spec_attn(True, q_shard=qs, kv_shard=ks)
    if cfg.num_experts and unit_size(cfg) == 2:
        return {"attn0": sa, "ffn": L.spec_ffn(True),
                "attn1": sa, "moe": MOE.spec_moe(True)}
    if cfg.num_experts:
        return {"attn": sa, "moe": MOE.spec_moe(True)}
    return {"attn": sa, "ffn": L.spec_ffn(True)}


def init_params(cfg: ModelConfig, key, dtype=jnp.bfloat16):
    segs = segment_lengths(cfg)
    keys = jax.random.split(key, len(segs) + 3)
    params = {
        "embed": L.init_embed(keys[0], cfg, dtype),
        "segments": tuple(_init_unit(keys[i + 1], cfg, dtype, n)
                          for i, n in enumerate(segs)),
        "final_norm": jnp.ones((cfg.d_model,), dtype),
    }
    if cfg.num_exits:
        params["exit_norms"] = jnp.ones((len(segs) - 1, cfg.d_model), dtype)
    if cfg.frontend == "vision":
        params["mm_proj"] = L.dense_init(keys[-1], (VIS_DIM, cfg.d_model), dtype, VIS_DIM)
    return params


def param_specs(cfg: ModelConfig):
    segs = segment_lengths(cfg)
    specs = {
        "embed": L.spec_embed(),
        "segments": tuple(_spec_unit(cfg) for _ in segs),
        "final_norm": P(None),
    }
    if cfg.num_exits:
        specs["exit_norms"] = P(None, None)
    if cfg.frontend == "vision":
        specs["mm_proj"] = P(None, "data")
    return specs


def abstract_params(cfg: ModelConfig, dtype=jnp.bfloat16):
    return jax.eval_shape(lambda: init_params(cfg, jax.random.key(0), dtype))


# ----------------------------------------------------------------------------
# blocks
# ----------------------------------------------------------------------------

def _seq_shard(x):
    """Sequence parallelism (EXPERIMENTS.md §Perf A3): constrain the residual
    stream to be sequence-sharded over the model axis between blocks, so
    GSPMD lowers the TP output all-reduces into reduce-scatter + all-gather
    pairs (half the ring traffic on the residual activations)."""
    U = P.UNCONSTRAINED
    return jax.lax.with_sharding_constraint(x, P(U, "model", U))


def _unit_fwd(cfg, lp, x, positions, *, moe_dispatch="einsum", attn_impl="auto",
              kv=None, cache_pos=None, prefill_mode=False, seq_parallel=False):
    """One scan unit. kv: dict of stacked caches for this unit or None.
    Returns (x, aux, new_kv)."""
    aux = 0.0
    new_kv = {}
    maybe_shard = _seq_shard if seq_parallel else (lambda x: x)

    def attn(name, x):
        if kv is None:
            c = None
        elif name + "_k_scale" in kv:
            c = {"k": kv[name + "_k"], "v": kv[name + "_v"],
                 "k_scale": kv[name + "_k_scale"], "v_scale": kv[name + "_v_scale"]}
        else:
            c = (kv[name + "_k"], kv[name + "_v"])
        out, nc = L.attention(lp[name], cfg, x, positions, kv_cache=c,
                              cache_pos=cache_pos, impl=attn_impl,
                              prefill_mode=prefill_mode)
        if isinstance(nc, dict):
            new_kv[name + "_k"], new_kv[name + "_v"] = nc["k"], nc["v"]
            new_kv[name + "_k_scale"] = nc["k_scale"]
            new_kv[name + "_v_scale"] = nc["v_scale"]
        elif nc is not None:
            new_kv[name + "_k"], new_kv[name + "_v"] = nc
        return x + out

    if cfg.num_experts and unit_size(cfg) == 2:
        x = maybe_shard(attn("attn0", x))
        x = maybe_shard(x + L.ffn(lp["ffn"], cfg, x))
        x = maybe_shard(attn("attn1", x))
        mo, a = MOE.moe_ffn(lp["moe"], cfg, x, dispatch_mode=moe_dispatch)
        x, aux = maybe_shard(x + mo), a
    elif cfg.num_experts:
        x = maybe_shard(attn("attn", x))
        mo, a = MOE.moe_ffn(lp["moe"], cfg, x, dispatch_mode=moe_dispatch)
        x, aux = maybe_shard(x + mo), a
    else:
        x = maybe_shard(attn("attn", x))
        x = maybe_shard(x + L.ffn(lp["ffn"], cfg, x))
    return x, aux, (new_kv if kv is not None else None)


def _run_segment(cfg, seg_params, x, positions, *, moe_dispatch="einsum",
                 attn_impl="auto", seg_cache=None, cache_pos=None, remat=False,
                 prefill_mode=False, seq_parallel=False):
    """Scan a segment of stacked units. Returns (x, aux_sum, new_seg_cache)."""

    def body(carry, xs):
        x, aux = carry
        lp = xs if seg_cache is None else xs[0]
        kv = None if seg_cache is None else xs[1]
        x, a, nkv = _unit_fwd(cfg, lp, x, positions, moe_dispatch=moe_dispatch,
                              attn_impl=attn_impl, kv=kv, cache_pos=cache_pos,
                              prefill_mode=prefill_mode, seq_parallel=seq_parallel)
        return (x, aux + a), nkv

    fn = jax.checkpoint(body) if remat else body
    xs = seg_params if seg_cache is None else (seg_params, seg_cache)
    (x, aux), new_cache = jax.lax.scan(fn, (x, 0.0), xs)
    return x, aux, new_cache


def _embed_inputs(cfg, params, tokens, prefix_emb):
    x = L.embed(params["embed"], tokens)
    if cfg.frontend == "vision" and prefix_emb is not None:
        px = prefix_emb.astype(x.dtype) @ params["mm_proj"]
        x = jnp.concatenate([px, x], axis=1)
    return x


def forward(cfg: ModelConfig, params, tokens, prefix_emb=None, *,
            exit_point: Optional[int] = None, moe_dispatch="einsum",
            attn_impl="auto", remat=False, collect_exits=True,
            seq_parallel=False):
    """Training/eval forward.  Returns (list of (exit_idx, hidden_normed),
    aux_loss).  Hidden states are returned (not logits) so callers fuse the
    vocab projection with their loss / confidence computation."""
    B = tokens.shape[0]
    x = _embed_inputs(cfg, params, tokens, prefix_emb)
    S = x.shape[1]
    positions = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
    segs = segment_lengths(cfg)
    n_seg = len(segs) if exit_point is None else exit_point + 1
    outs = []
    aux = 0.0
    for si in range(n_seg):
        x, a, _ = _run_segment(cfg, params["segments"][si], x, positions,
                               moe_dispatch=moe_dispatch, attn_impl=attn_impl,
                               remat=remat, seq_parallel=seq_parallel)
        aux = aux + a
        is_last = si == n_seg - 1
        if not is_last and cfg.num_exits and collect_exits:
            h = L.rms_norm(x, params["exit_norms"][si], cfg.norm_eps)
            outs.append((si, h))
        if is_last:
            norm = params["final_norm"] if exit_point in (None, len(segs) - 1) \
                else params["exit_norms"][si]
            outs.append((si, L.rms_norm(x, norm, cfg.norm_eps)))
    return outs, aux


# ----------------------------------------------------------------------------
# KV cache / decode
# ----------------------------------------------------------------------------

def init_cache(cfg: ModelConfig, batch: int, max_seq: int, dtype=jnp.bfloat16,
               quant: bool = False):
    segs = segment_lengths(cfg)
    kvh, hd = cfg.num_kv_heads, cfg.hd
    u = unit_size(cfg)
    names = ["attn0", "attn1"] if (cfg.num_experts and u == 2) else ["attn"]
    cache = []
    for n in segs:
        seg = {}
        for nm in names:
            if quant:
                seg[nm + "_k"] = jnp.zeros((n, batch, max_seq, kvh, hd), jnp.int8)
                seg[nm + "_v"] = jnp.zeros((n, batch, max_seq, kvh, hd), jnp.int8)
                seg[nm + "_k_scale"] = jnp.zeros((n, batch, max_seq, kvh), jnp.bfloat16)
                seg[nm + "_v_scale"] = jnp.zeros((n, batch, max_seq, kvh), jnp.bfloat16)
            else:
                seg[nm + "_k"] = jnp.zeros((n, batch, max_seq, kvh, hd), dtype)
                seg[nm + "_v"] = jnp.zeros((n, batch, max_seq, kvh, hd), dtype)
        cache.append(seg)
    return tuple(cache)


def cache_specs(cfg: ModelConfig, batch_axes, seq_axes="model", quant: bool = False):
    segs = segment_lengths(cfg)
    u = unit_size(cfg)
    names = ["attn0", "attn1"] if (cfg.num_experts and u == 2) else ["attn"]
    spec = P(None, batch_axes, seq_axes, None, None)
    sspec = P(None, batch_axes, seq_axes, None)
    out = []
    for _ in segs:
        seg = {nm + sfx: spec for nm in names for sfx in ("_k", "_v")}
        if quant:
            seg.update({nm + sfx: sspec for nm in names
                        for sfx in ("_k_scale", "_v_scale")})
        out.append(seg)
    return tuple(out)


def prefill(cfg: ModelConfig, params, tokens, cache, prefix_emb=None, *,
            moe_dispatch="einsum", attn_impl="auto"):
    """Fills cache positions [0, S); returns (final_hidden_last_tok, cache)."""
    B = tokens.shape[0]
    x = _embed_inputs(cfg, params, tokens, prefix_emb)
    S = x.shape[1]
    positions = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
    new_cache = []
    for si, segp in enumerate(params["segments"]):
        x, _, nc = _run_segment(cfg, segp, x, positions, moe_dispatch=moe_dispatch,
                                attn_impl=attn_impl, seg_cache=cache[si],
                                cache_pos=0, prefill_mode=True)
        new_cache.append(nc)
    h = L.rms_norm(x[:, -1:, :], params["final_norm"], cfg.norm_eps)
    return h, tuple(new_cache)


def decode_step(cfg: ModelConfig, params, cache, tokens, pos, *,
                exit_point: Optional[int] = None, moe_dispatch="einsum",
                with_exit_confidence: bool = False, use_exit_kernel: bool = False):
    """One decode step.  tokens: [B,1]; pos: scalar int32 cache position.

    ``exit_point`` (static) right-sizes the model: only segments
    [0, exit_point] are executed and the exit head at that boundary produces
    the hidden state — the paper's knob compiled as a variant.
    Returns (normed_hidden [B,1,D], new_cache, exit_confidences).
    """
    B = tokens.shape[0]
    x = L.embed(params["embed"], tokens)
    positions = jnp.broadcast_to(pos[None, None] if jnp.ndim(pos) == 0 else pos,
                                 (B, 1))
    segs = segment_lengths(cfg)
    n_seg = len(segs) if exit_point is None else exit_point + 1
    new_cache = list(cache)
    confs = []
    for si in range(n_seg):
        x, _, nc = _run_segment(cfg, params["segments"][si], x, positions,
                                moe_dispatch=moe_dispatch,
                                seg_cache=cache[si], cache_pos=pos)
        new_cache[si] = nc
        is_last = si == n_seg - 1
        if with_exit_confidence and not is_last and cfg.num_exits:
            h = L.rms_norm(x, params["exit_norms"][si], cfg.norm_eps)
            confs.append(_exit_confidence(params["embed"], h, use_exit_kernel))
    norm = params["final_norm"] if exit_point in (None, len(segs) - 1) \
        else params["exit_norms"][n_seg - 1]
    h = L.rms_norm(x, norm, cfg.norm_eps)
    return h, tuple(new_cache), confs


def _exit_confidence(embed_table, h, use_kernel):
    if use_kernel:
        from repro.kernels.exit_head import ops as eh_ops
        return eh_ops.exit_confidence(h, embed_table)
    from repro.kernels.exit_head import ref as eh_ref
    return eh_ref.exit_confidence(h, embed_table)
