"""RWKV-6 "Finch" — attention-free RNN with data-dependent decay
(arXiv:2404.05892), adapted to the shared diagonal-decay linear scan.

Per layer: time-mix (token shift, r/k/v/g projections, data-dependent decay
w_t = exp(-exp(w0 + tanh(x @ A) @ B)), wkv state recurrence with bonus u) and
channel-mix (squared-relu MLP with receptance gate).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.config import ModelConfig
from repro.models.layers import dense_init, rms_norm
from repro.models.linear_scan import linear_scan

LORA_R = 64


def init_layer(key, cfg: ModelConfig, dtype, stack: int = 0):
    d, f = cfg.d_model, cfg.d_ff
    pre = (stack,) if stack else ()
    ks = jax.random.split(key, 12)
    return {
        "ln1": jnp.ones(pre + (d,), dtype),
        "ln2": jnp.ones(pre + (d,), dtype),
        "mu": 0.5 * jnp.ones(pre + (5, d), dtype),     # shift-mix for r,k,v,g,w
        "wr": dense_init(ks[0], pre + (d, d), dtype, d),
        "wk": dense_init(ks[1], pre + (d, d), dtype, d),
        "wv": dense_init(ks[2], pre + (d, d), dtype, d),
        "wg": dense_init(ks[3], pre + (d, d), dtype, d),
        "wo": dense_init(ks[4], pre + (d, d), dtype, d),
        "w0": -6.0 * jnp.ones(pre + (d,), jnp.float32),  # base log-log decay
        "wA": dense_init(ks[5], pre + (d, LORA_R), dtype, d),
        "wB": dense_init(ks[6], pre + (LORA_R, d), dtype, LORA_R),
        "u": dense_init(ks[7], pre + (cfg.num_heads, cfg.hd), jnp.float32, cfg.hd),
        "gn": jnp.ones(pre + (d,), dtype),
        "cm_mu": 0.5 * jnp.ones(pre + (2, d), dtype),
        "cm_k": dense_init(ks[8], pre + (d, f), dtype, d),
        "cm_v": dense_init(ks[9], pre + (f, d), dtype, f),
        "cm_r": dense_init(ks[10], pre + (d, d), dtype, d),
    }


def spec_layer(stack: bool = False):
    pre = (None,) if stack else ()
    d2 = P(*pre, "data", "model")
    return {
        "ln1": P(*pre, None), "ln2": P(*pre, None), "mu": P(*pre, None, None),
        "wr": d2, "wk": d2, "wv": d2, "wg": d2,
        "wo": P(*pre, "model", "data"),
        "w0": P(*pre, None), "wA": P(*pre, "data", None), "wB": P(*pre, None, "data"),
        "u": P(*pre, None, None), "gn": P(*pre, None),
        "cm_mu": P(*pre, None, None),
        "cm_k": d2, "cm_v": P(*pre, "model", "data"), "cm_r": P(*pre, "data", "model"),
    }


def _shift(x, last):
    """Token shift: returns x_{t-1} per position; ``last`` is [B,1,D] carry
    (previous token of the preceding chunk / step)."""
    return jnp.concatenate([last, x[:, :-1]], axis=1)


def time_mix(p, cfg: ModelConfig, x, state, last, *, mode="auto", use_kernel=False,
             chunk=16):
    """x: [B,S,D]; state: [B,H,hd,hd] f32; last: [B,1,D] previous token.
    Returns (out, new_state, new_last)."""
    B, S, D = x.shape
    H, hd = cfg.num_heads, cfg.hd
    xn = rms_norm(x, p["ln1"], cfg.norm_eps)
    xx = _shift(xn, last)
    mu = p["mu"]
    xr, xk, xv, xg, xw = (xn + (xx - xn) * mu[i] for i in range(5))
    r = (xr @ p["wr"]).reshape(B, S, H, hd)
    k = (xk @ p["wk"]).reshape(B, S, H, hd)
    v = (xv @ p["wv"]).reshape(B, S, H, hd)
    g = jax.nn.silu(xg @ p["wg"])
    # data-dependent decay (the Finch hallmark)
    ddw = jnp.tanh(xw @ p["wA"]) @ p["wB"]
    log_w = -jnp.exp(jnp.clip(p["w0"] + ddw.astype(jnp.float32), -20.0, 3.0))
    log_w = log_w.reshape(B, S, H, hd)
    o, new_state = linear_scan(r, k, v, log_w, state, u=p["u"], mode=mode,
                               use_kernel=use_kernel, chunk=chunk)
    o = o.reshape(B, S, D)
    # group norm over heads
    og = o.reshape(B, S, H, hd)
    og = (og - og.mean(-1, keepdims=True)) * jax.lax.rsqrt(og.var(-1, keepdims=True) + cfg.norm_eps)
    o = og.reshape(B, S, D).astype(x.dtype) * p["gn"] * g
    return o @ p["wo"], new_state, xn[:, -1:, :]


def channel_mix(p, cfg: ModelConfig, x, last):
    xn = rms_norm(x, p["ln2"], cfg.norm_eps)
    xx = _shift(xn, last)
    xk = xn + (xx - xn) * p["cm_mu"][0]
    xr = xn + (xx - xn) * p["cm_mu"][1]
    k = jnp.square(jax.nn.relu(xk @ p["cm_k"]))
    return jax.nn.sigmoid(xr @ p["cm_r"]) * (k @ p["cm_v"]), xn[:, -1:, :]


def block(p, cfg: ModelConfig, x, state, lasts, *, mode="auto", use_kernel=False,
          chunk=16):
    """One RWKV layer.  ``lasts`` = (last_tm, last_cm) each [B,1,D]."""
    tm, new_state, l1 = time_mix(p, cfg, x, state, lasts[0], mode=mode,
                                 use_kernel=use_kernel, chunk=chunk)
    x = x + tm
    cm, l2 = channel_mix(p, cfg, x, lasts[1])
    return x + cm, new_state, (l1, l2)


def init_state(cfg: ModelConfig, batch: int):
    """Recurrent state shipped at a partition cut (see DESIGN.md §4)."""
    return {
        "wkv": jnp.zeros((cfg.num_layers, batch, cfg.num_heads, cfg.hd, cfg.hd), jnp.float32),
        "last_tm": jnp.zeros((cfg.num_layers, batch, 1, cfg.d_model), jnp.float32),
        "last_cm": jnp.zeros((cfg.num_layers, batch, 1, cfg.d_model), jnp.float32),
    }


def state_specs(batch_axes):
    # heads (40) don't divide the 16-way model axis; shard the key channel
    # dim (64) instead — partial r.S sums all-reduce under GSPMD.
    return {
        "wkv": P(None, batch_axes, None, "model", None),
        "last_tm": P(None, batch_axes, None, None),
        "last_cm": P(None, batch_axes, None, None),
    }
