"""Unified model facade: one entry point per family, shared loss/step logic.

``Model(cfg)`` dispatches to the family stack (transformer / ssm_stack /
encdec) and exposes:

    init_params / abstract_params / param_specs
    loss(params, batch, ...)            joint multi-exit CE (BranchyNet)
    prefill / decode_step / init_cache / cache_specs
    make_inputs(shape)                  concrete or abstract batch
"""
from __future__ import annotations

from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import ModelConfig, ShapeConfig
from repro.models import encdec, ssm_stack, transformer
from repro.models.encdec import AUDIO_DIM
from repro.models.transformer import VIS_DIM

EXIT_LOSS_WEIGHT = 0.3  # BranchyNet-style joint loss: side exits weighted


def _stack(cfg: ModelConfig):
    if cfg.family in ("ssm", "hybrid"):
        return ssm_stack
    if cfg.is_encdec:
        return encdec
    return transformer


def softmax_xent(hidden, embed_table, labels, mask=None, chunk: int = 512):
    """CE from hidden states against tied-embedding logits.

    The [B,S,V] logits are never materialized whole: the sequence is processed
    in ``chunk``-sized slices (lax.scan) and each slice is checkpointed, so
    peak transient is [B, chunk, V_shard] — the memory-side twin of the fused
    exit-head kernel (EXPERIMENTS.md §Perf)."""

    @jax.checkpoint
    def _ce(h, lab):
        logits = jnp.einsum("bsd,vd->bsv", h, embed_table).astype(jnp.float32)
        lse = jax.scipy.special.logsumexp(logits, axis=-1)
        ll = jnp.take_along_axis(logits, lab[..., None], axis=-1)[..., 0]
        return lse - ll

    B, S, D = hidden.shape
    if chunk and S > chunk and S % chunk == 0:
        nc = S // chunk
        hs = hidden.reshape(B, nc, chunk, D).transpose(1, 0, 2, 3)
        ls = labels.reshape(B, nc, chunk).transpose(1, 0, 2)
        ms = (mask.reshape(B, nc, chunk).transpose(1, 0, 2)
              if mask is not None else None)

        def body(carry, xs):
            tot, cnt = carry
            if ms is not None:
                h_c, l_c, m_c = xs
                ce = _ce(h_c, l_c)
                return (tot + jnp.sum(ce * m_c), cnt + jnp.sum(m_c)), None
            h_c, l_c = xs
            ce = _ce(h_c, l_c)
            return (tot + jnp.sum(ce), cnt + ce.size), None

        xs = (hs, ls, ms) if ms is not None else (hs, ls)
        (tot, cnt), _ = jax.lax.scan(body, (jnp.zeros((), jnp.float32),
                                            jnp.zeros((), jnp.float32)), xs)
        return tot / jnp.maximum(cnt, 1.0)
    ce = _ce(hidden, labels)
    if mask is not None:
        return jnp.sum(ce * mask) / jnp.maximum(jnp.sum(mask), 1.0)
    return jnp.mean(ce)


class Model:
    def __init__(self, cfg: ModelConfig):
        self.cfg = cfg
        self.stack = _stack(cfg)

    # ------------------------------------------------------------------ params
    def init_params(self, key, dtype=jnp.bfloat16):
        return self.stack.init_params(self.cfg, key, dtype)

    def abstract_params(self, dtype=jnp.bfloat16):
        return self.stack.abstract_params(self.cfg, dtype)

    def param_specs(self):
        return self.stack.param_specs(self.cfg)

    @property
    def num_segments(self) -> int:
        return len(self.stack.segment_lengths(self.cfg))

    # ------------------------------------------------------------------ loss
    def loss(self, params, batch, *, remat=True, moe_dispatch="einsum",
             attn_impl="auto", use_kernel=False, scan_chunk=16,
             seq_parallel=False):
        """Joint multi-exit next-token CE.  batch keys: tokens [B,S]
        (+frames for enc-dec, +prefix_emb for vlm)."""
        cfg = self.cfg
        tokens = batch["tokens"]
        inputs, labels = tokens[:, :-1], tokens[:, 1:]
        kw: Dict[str, Any] = dict(remat=remat, attn_impl=attn_impl)
        if cfg.is_encdec:
            outs, aux = self.stack.forward(cfg, params, inputs, batch["frames"], **kw)
        elif cfg.family in ("ssm", "hybrid"):
            outs, aux = self.stack.forward(cfg, params, inputs,
                                           use_kernel=use_kernel,
                                           scan_chunk=scan_chunk, **kw)
        else:
            outs, aux = self.stack.forward(cfg, params, inputs,
                                           prefix_emb=batch.get("prefix_emb"),
                                           moe_dispatch=moe_dispatch,
                                           seq_parallel=seq_parallel, **kw)
        P = cfg.num_prefix_tokens if (cfg.frontend == "vision"
                                      and batch.get("prefix_emb") is not None) else 0
        losses = []
        for i, (si, h) in enumerate(outs):
            if P:
                h = h[:, P:, :]
            is_final = i == len(outs) - 1
            w = 1.0 if is_final else EXIT_LOSS_WEIGHT
            losses.append((w, softmax_xent(h, params["embed"], labels)))
        total = sum(w * l for w, l in losses) / sum(w for w, _ in losses)
        total = total + 0.01 * aux
        metrics = {"loss": total, "aux": aux,
                   "final_ce": losses[-1][1],
                   "exit_ce": jnp.stack([l for _, l in losses])}
        return total, metrics

    # ------------------------------------------------------------------ serving
    def init_cache(self, batch, max_seq, dtype=jnp.bfloat16, enc_len=None,
                   quant=False):
        if self.cfg.is_encdec:
            return encdec.init_cache(self.cfg, batch, max_seq,
                                     enc_len or max_seq, dtype)
        if quant and self.stack is transformer:
            return transformer.init_cache(self.cfg, batch, max_seq, dtype,
                                          quant=True)
        return self.stack.init_cache(self.cfg, batch, max_seq, dtype)

    def cache_specs(self, batch_axes="data", seq_axes="model", quant=False):
        if quant and self.stack is transformer:
            return transformer.cache_specs(self.cfg, batch_axes, seq_axes,
                                           quant=True)
        return self.stack.cache_specs(self.cfg, batch_axes, seq_axes)

    def prefill(self, params, tokens, cache, *, frames=None, prefix_emb=None,
                attn_impl="auto", moe_dispatch="einsum", use_kernel=False):
        cfg = self.cfg
        if cfg.is_encdec:
            return encdec.prefill(cfg, params, tokens, cache, frames,
                                  attn_impl=attn_impl)
        if cfg.family in ("ssm", "hybrid"):
            return ssm_stack.prefill(cfg, params, tokens, cache,
                                     use_kernel=use_kernel, attn_impl=attn_impl)
        return transformer.prefill(cfg, params, tokens, cache,
                                   prefix_emb=prefix_emb, attn_impl=attn_impl,
                                   moe_dispatch=moe_dispatch)

    def decode_step(self, params, cache, tokens, pos, *, exit_point=None,
                    moe_dispatch="einsum", with_exit_confidence=False,
                    use_exit_kernel=False, use_kernel=False):
        cfg = self.cfg
        if cfg.is_encdec:
            return encdec.decode_step(cfg, params, cache, tokens, pos,
                                      exit_point=exit_point)
        if cfg.family in ("ssm", "hybrid"):
            return ssm_stack.decode_step(cfg, params, cache, tokens, pos,
                                         exit_point=exit_point,
                                         use_kernel=use_kernel)
        return transformer.decode_step(cfg, params, cache, tokens, pos,
                                       exit_point=exit_point,
                                       moe_dispatch=moe_dispatch,
                                       with_exit_confidence=with_exit_confidence,
                                       use_exit_kernel=use_exit_kernel)

    def logits(self, params, hidden):
        return jnp.einsum("bsd,vd->bsv", hidden, params["embed"])

    # ------------------------------------------------------------------ inputs
    def make_inputs(self, shape: ShapeConfig, *, abstract=False, rng=None):
        """Batch pytree for a shape cell — ShapeDtypeStruct when abstract
        (the dry-run path: no allocation)."""
        cfg = self.cfg
        B, S = shape.global_batch, shape.seq_len

        def arr(shp, dtype, maxval=None):
            if abstract:
                return jax.ShapeDtypeStruct(shp, dtype)
            if dtype == jnp.int32:
                return jax.random.randint(rng, shp, 0, maxval or cfg.vocab_size,
                                          dtype=jnp.int32)
            return jax.random.normal(rng, shp, dtype)

        if shape.kind == "train":
            if cfg.is_encdec:
                return {"tokens": arr((B, S + 1), jnp.int32),
                        "frames": arr((B, S, AUDIO_DIM), jnp.bfloat16)}
            if cfg.frontend == "vision":
                t = S - cfg.num_prefix_tokens
                return {"tokens": arr((B, t + 1), jnp.int32),
                        "prefix_emb": arr((B, cfg.num_prefix_tokens, VIS_DIM),
                                          jnp.bfloat16)}
            return {"tokens": arr((B, S + 1), jnp.int32)}
        if shape.kind == "prefill":
            out = {"tokens": arr((B, S), jnp.int32)}
            if cfg.is_encdec:
                out["tokens"] = arr((B, S), jnp.int32)
                out["frames"] = arr((B, S, AUDIO_DIM), jnp.bfloat16)
            elif cfg.frontend == "vision":
                out["tokens"] = arr((B, S - cfg.num_prefix_tokens), jnp.int32)
                out["prefix_emb"] = arr((B, cfg.num_prefix_tokens, VIS_DIM),
                                        jnp.bfloat16)
            return out
        # decode: one new token against a seq_len cache
        return {"tokens": arr((B, 1), jnp.int32),
                "pos": (jax.ShapeDtypeStruct((), jnp.int32) if abstract
                        else jnp.asarray(S - 1, jnp.int32))}
