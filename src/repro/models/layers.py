"""Shared model primitives: norms, RoPE, GQA attention (+KV cache), SwiGLU.

Pure functions over param pytrees.  Every ``init_*`` has a matching
``spec_*`` returning a :class:`jax.sharding.PartitionSpec` tree using the
logical mesh axes ``("data", "model")`` — FSDP on ``data``, tensor parallel on
``model``.  The ``pod`` axis (multi-pod) only ever shards the batch.
"""
from __future__ import annotations

import math
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.config import ModelConfig

# ----------------------------------------------------------------------------
# init helpers
# ----------------------------------------------------------------------------

def dense_init(key, shape, dtype, fan_in: Optional[int] = None):
    fan = fan_in if fan_in is not None else shape[-2] if len(shape) >= 2 else shape[-1]
    scale = 1.0 / math.sqrt(max(1, fan))
    return (jax.random.normal(key, shape, jnp.float32) * scale).astype(dtype)


def rms_norm(x, w, eps):
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps)).astype(x.dtype) * w


def init_rmsnorm(d, dtype):
    return jnp.ones((d,), dtype)


# ----------------------------------------------------------------------------
# RoPE
# ----------------------------------------------------------------------------

def rope_freqs(hd: int, theta: float):
    return 1.0 / (theta ** (jnp.arange(0, hd, 2, dtype=jnp.float32) / hd))


def apply_rope(x, positions, theta):
    """x: [..., S, H, hd]; positions: [..., S] int32."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)                      # [hd/2]
    angles = positions[..., None].astype(jnp.float32) * freqs  # [..., S, hd/2]
    cos = jnp.cos(angles)[..., None, :]
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ----------------------------------------------------------------------------
# GQA attention
# ----------------------------------------------------------------------------

def init_attn(key, cfg: ModelConfig, dtype, stack: int = 0):
    d, hd = cfg.d_model, cfg.hd
    hp, kv = cfg.padded_heads, cfg.num_kv_heads
    ks = jax.random.split(key, 4)
    pre = (stack,) if stack else ()

    def mk(k, shape, fan):
        return dense_init(k, pre + shape, dtype, fan_in=fan)

    return {
        "wq": mk(ks[0], (d, hp * hd), d),
        "wk": mk(ks[1], (d, kv * hd), d),
        "wv": mk(ks[2], (d, kv * hd), d),
        "wo": mk(ks[3], (hp * hd, d), hp * hd),
        "ln": jnp.ones(pre + (d,), dtype),
    }


def spec_attn(stack: bool = False, q_shard: bool = True, kv_shard: bool = True):
    """Sharding for attention projections.

    ``q_shard`` / ``kv_shard`` must be False when the respective head count
    does not divide the 16-way ``model`` axis: naively sharding head*hd
    splits individual heads across devices, which turns every QK^T
    contraction into a partial-sum all-reduce of the *score blocks* — the
    dominant collective of the naive lowering (EXPERIMENTS.md §Perf,
    iteration A2).  Replicated K/V is cheap under GQA.
    """
    pre = (None,) if stack else ()
    qs = P(*pre, "data", "model") if q_shard else P(*pre, "data", None)
    kvs = P(*pre, "data", "model") if kv_shard else P(*pre, "data", None)
    return {
        "wq": qs,
        "wk": kvs,
        "wv": kvs,
        "wo": P(*pre, "model", "data") if q_shard else P(*pre, None, "data"),
        "ln": P(*pre, None),
    }


def _sdpa(q, k, v, mask_bias):
    """q: [B,S,H,hd], k/v: [B,T,KV,hd] -> [B,S,H,hd]; f32 softmax."""
    B, S, H, hd = q.shape
    KV = k.shape[2]
    G = H // KV
    q = q.reshape(B, S, KV, G, hd)
    scores = jnp.einsum("bskgd,btkd->bkgst", q, k).astype(jnp.float32)
    scores = scores / math.sqrt(hd) + mask_bias
    probs = jax.nn.softmax(scores, axis=-1).astype(v.dtype)
    out = jnp.einsum("bkgst,btkd->bskgd", probs, v)
    return out.reshape(B, S, H, hd)


def causal_bias(S: int, T: int, offset: int = 0):
    """[1,1,1,S,T] additive bias; position i attends to j <= i + offset."""
    qi = jnp.arange(S)[:, None] + offset
    kj = jnp.arange(T)[None, :]
    return jnp.where(kj <= qi, 0.0, -1e30).astype(jnp.float32)[None, None, None]


def flash_attention_jnp(q, k, v, *, causal=True, q_block=1024, kv_block=1024):
    """Blocked online-softmax attention in pure jnp (lax.scan over q and kv
    chunks) — the memory-safe default for long-context prefill/train; the
    Pallas kernel in ``repro.kernels.flash_attention`` is its TPU-optimized
    twin (same math, block-pruned causal grid).

    q: [B,S,H,hd]; k/v: [B,T,KV,hd].  Returns [B,S,H,hd].
    """
    B, S, H, hd = q.shape
    T, KV = k.shape[1], k.shape[2]
    G = H // KV
    qb = min(q_block, S)
    kb = min(kv_block, T)
    nq, nk = S // qb, T // kb
    assert S % qb == 0 and T % kb == 0, (S, qb, T, kb)
    scale = 1.0 / math.sqrt(hd)
    qr = q.reshape(B, nq, qb, KV, G, hd)
    kr = k.reshape(B, nk, kb, KV, hd)
    vr = v.reshape(B, nk, kb, KV, hd)

    def q_step(_, qi_q):
        qi, qc = qi_q  # chunk idx, [B,qb,KV,G,hd]

        def kv_step(carry, kj_kv):
            m, l, acc = carry
            kj, kc, vc = kj_kv
            s = jnp.einsum("bqkgd,btkd->bkgqt", qc, kc).astype(jnp.float32) * scale
            if causal:
                qpos = qi * qb + jnp.arange(qb)
                kpos = kj * kb + jnp.arange(kb)
                s = jnp.where(kpos[None, :] <= qpos[:, None], s, -1e30)
            m_new = jnp.maximum(m, s.max(-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l = l * corr + p.sum(-1)
            acc = acc * corr[..., None] + jnp.einsum(
                "bkgqt,btkd->bkgqd", p.astype(vc.dtype), vc).astype(jnp.float32)
            return (m_new, l, acc), None

        m0 = jnp.full((B, KV, G, qb), -1e30, jnp.float32)
        l0 = jnp.zeros((B, KV, G, qb), jnp.float32)
        a0 = jnp.zeros((B, KV, G, qb, hd), jnp.float32)
        (m, l, acc), _ = jax.lax.scan(
            kv_step, (m0, l0, a0),
            (jnp.arange(nk), kr.transpose(1, 0, 2, 3, 4), vr.transpose(1, 0, 2, 3, 4)))
        out = acc / jnp.maximum(l[..., None], 1e-30)
        return None, out.astype(q.dtype)  # [B,KV,G,qb,hd]

    _, o = jax.lax.scan(q_step, None,
                        (jnp.arange(nq), qr.transpose(1, 0, 2, 3, 4, 5)))
    o = o.transpose(1, 0, 4, 2, 3, 5)  # [B,nq,qb,KV,G,hd]
    return o.reshape(B, S, H, hd)


def _flash_fwd_blocks(q, k, v, *, causal, q_block, kv_block):
    """Forward flash returning (o, lse); q: [B,S,H,hd], k/v: [B,T,KV,hd]."""
    B, S, H, hd = q.shape
    T, KV = k.shape[1], k.shape[2]
    G = H // KV
    qb, kb = min(q_block, S), min(kv_block, T)
    nq, nk = S // qb, T // kb
    scale = 1.0 / math.sqrt(hd)
    qr = q.reshape(B, nq, qb, KV, G, hd)
    kr = k.reshape(B, nk, kb, KV, hd)
    vr = v.reshape(B, nk, kb, KV, hd)

    def q_step(_, qi_q):
        qi, qc = qi_q

        def kv_step(carry, kj_kv):
            m, l, acc = carry
            kj, kc, vc = kj_kv
            s = jnp.einsum("bqkgd,btkd->bkgqt", qc, kc).astype(jnp.float32) * scale
            if causal:
                qpos = qi * qb + jnp.arange(qb)
                kpos = kj * kb + jnp.arange(kb)
                s = jnp.where(kpos[None, :] <= qpos[:, None], s, -1e30)
            m_new = jnp.maximum(m, s.max(-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l = l * corr + p.sum(-1)
            acc = acc * corr[..., None] + jnp.einsum(
                "bkgqt,btkd->bkgqd", p.astype(vc.dtype), vc).astype(jnp.float32)
            return (m_new, l, acc), None

        m0 = jnp.full((B, KV, G, qb), -1e30, jnp.float32)
        l0 = jnp.zeros((B, KV, G, qb), jnp.float32)
        a0 = jnp.zeros((B, KV, G, qb, hd), jnp.float32)
        (m, l, acc), _ = jax.lax.scan(
            kv_step, (m0, l0, a0),
            (jnp.arange(nk), kr.transpose(1, 0, 2, 3, 4), vr.transpose(1, 0, 2, 3, 4)))
        l = jnp.maximum(l, 1e-30)
        out = (acc / l[..., None]).astype(q.dtype)
        return None, (out, (m + jnp.log(l)))      # [B,KV,G,qb,hd], lse [B,KV,G,qb]

    _, (o, lse) = jax.lax.scan(q_step, None,
                               (jnp.arange(nq), qr.transpose(1, 0, 2, 3, 4, 5)))
    o = o.transpose(1, 0, 4, 2, 3, 5).reshape(B, S, H, hd)
    lse = lse.transpose(1, 0, 4, 2, 3).reshape(B, S, H)   # per q-position
    return o, lse


@partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5))
def flash_attention_fused(q, k, v, causal=True, q_block=1024, kv_block=1024):
    """Flash attention with a flash *backward* (custom_vjp): the backward
    pass recomputes the block attention probabilities from (q, k, v, lse)
    instead of letting autodiff save the [bq, bk] probability blocks as scan
    residuals — the dominant HBM-traffic term of the naive lowering
    (EXPERIMENTS.md §Perf, iteration 1)."""
    o, _ = _flash_fwd_blocks(q, k, v, causal=causal, q_block=q_block,
                             kv_block=kv_block)
    return o


def _flash_fwd_rule(q, k, v, causal, q_block, kv_block):
    o, lse = _flash_fwd_blocks(q, k, v, causal=causal, q_block=q_block,
                               kv_block=kv_block)
    return o, (q, k, v, o, lse)


def _flash_bwd_rule(causal, q_block, kv_block, res, do):
    q, k, v, o, lse = res
    B, S, H, hd = q.shape
    T, KV = k.shape[1], k.shape[2]
    G = H // KV
    qb, kb = min(q_block, S), min(kv_block, T)
    nq, nk = S // qb, T // kb
    scale = 1.0 / math.sqrt(hd)
    qr = q.reshape(B, nq, qb, KV, G, hd).transpose(1, 0, 3, 4, 2, 5)   # [nq,B,KV,G,qb,hd]
    dor = do.reshape(B, nq, qb, KV, G, hd).transpose(1, 0, 3, 4, 2, 5)
    Dr = jnp.sum(do.astype(jnp.float32) * o.astype(jnp.float32), axis=-1)
    Dr = Dr.reshape(B, nq, qb, KV, G).transpose(1, 0, 3, 4, 2)          # [nq,B,KV,G,qb]
    lser = lse.reshape(B, nq, qb, KV, G).transpose(1, 0, 3, 4, 2)
    kr = k.reshape(B, nk, kb, KV, hd).transpose(1, 0, 3, 2, 4)          # [nk,B,KV,kb,hd]
    vr = v.reshape(B, nk, kb, KV, hd).transpose(1, 0, 3, 2, 4)

    def kv_step(_, kj_kv):
        kj, kc, vc = kj_kv                 # [B,KV,kb,hd]

        def q_step(carry, qi_q):
            dk_acc, dv_acc = carry
            qi, qc, doc, Dc, lsec = qi_q
            s = jnp.einsum("bkgqd,bktd->bkgqt", qc.astype(jnp.float32),
                           kc.astype(jnp.float32)) * scale
            if causal:
                qpos = qi * qb + jnp.arange(qb)
                kpos = kj * kb + jnp.arange(kb)
                s = jnp.where(kpos[None, :] <= qpos[:, None], s, -1e30)
            p = jnp.exp(s - lsec[..., None])                    # [B,KV,G,qb,kb]
            dv_acc = dv_acc + jnp.einsum("bkgqt,bkgqd->bktd", p,
                                         doc.astype(jnp.float32))
            dp = jnp.einsum("bkgqd,bktd->bkgqt", doc.astype(jnp.float32),
                            vc.astype(jnp.float32))
            ds = p * (dp - Dc[..., None]) * scale
            dq_blk = jnp.einsum("bkgqt,bktd->bkgqd", ds, kc.astype(jnp.float32))
            dk_acc = dk_acc + jnp.einsum("bkgqt,bkgqd->bktd", ds,
                                         qc.astype(jnp.float32))
            return (dk_acc, dv_acc), dq_blk

        z = jnp.zeros((B, KV, kb, hd), jnp.float32)
        (dk_b, dv_b), dq_blocks = jax.lax.scan(
            q_step, (z, z), (jnp.arange(nq), qr, dor, Dr, lser))
        return None, (dk_b, dv_b, dq_blocks)

    _, (dk_all, dv_all, dq_all) = jax.lax.scan(
        kv_step, None, (jnp.arange(nk), kr, vr))
    # dq: sum over kv blocks; [nk,nq,B,KV,G,qb,hd] -> [B,S,H,hd]
    dq = dq_all.sum(0).transpose(1, 0, 4, 2, 3, 5).reshape(B, S, H, hd)
    dk = dk_all.transpose(1, 0, 3, 2, 4).reshape(B, T, KV, hd)
    dv = dv_all.transpose(1, 0, 3, 2, 4).reshape(B, T, KV, hd)
    return dq.astype(q.dtype), dk.astype(k.dtype), dv.astype(v.dtype)


flash_attention_fused.defvjp(_flash_fwd_rule, _flash_bwd_rule)


def attention(p, cfg: ModelConfig, x, positions, *, causal=True,
              kv_cache=None, cache_pos=None, cross_kv=None,
              impl="auto", prefill_mode=False):
    """Full/cached attention.

    - training: ``kv_cache is None`` -> self attention over x.
    - prefill: ``kv_cache`` given + ``prefill_mode=True`` — writes k/v at
      [cache_pos, cache_pos+S) but attends within the current block only
      (cache was empty), so the flash path applies.
    - decode: ``kv_cache=(k,v) [B,T,KV,hd]`` and ``cache_pos`` scalar — writes
      the new kv at ``cache_pos`` and attends to [0, cache_pos].
    - cross attention: ``cross_kv=(k,v)`` precomputed encoder memory.
    ``impl``: dense | flash | pallas | auto (flash when S*T is large).
    Returns (out [B,S,D], new_cache or None).

    When ``cfg.padded_heads > cfg.num_heads`` the padding query heads (added
    so whole heads shard over the model axis) are masked to zero before the
    output projection — zero output AND zero gradient, exact semantics.
    """

    def _mask_pad_heads(out, h):
        if h == cfg.num_heads:
            return out
        gp = h // cfg.num_kv_heads
        g = cfg.num_heads // cfg.num_kv_heads
        mask = (jnp.arange(h) % gp) < g
        return out * mask[None, None, :, None].astype(out.dtype)
    B, S, _ = x.shape
    hd, kv_h = cfg.hd, cfg.num_kv_heads
    h = p["wq"].shape[-1] // hd           # padded head count (cfg.padded_heads)
    xn = rms_norm(x, p["ln"], cfg.norm_eps)
    q = (xn @ p["wq"]).reshape(B, S, h, hd)
    new_cache = None
    if cross_kv is not None:
        k, v = cross_kv
        causal = False
    else:
        k = (xn @ p["wk"]).reshape(B, S, kv_h, hd)
        v = (xn @ p["wv"]).reshape(B, S, kv_h, hd)
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
        if kv_cache is not None and isinstance(kv_cache, dict):
            # int8-quantized cache: per (position, kv-head) scales
            def quant(x):
                sc = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1) / 127.0
                sc = jnp.maximum(sc, 1e-8)
                q = jnp.clip(jnp.round(x.astype(jnp.float32) / sc[..., None]),
                             -127, 127).astype(jnp.int8)
                return q, sc.astype(jnp.bfloat16)

            k8, ks_ = quant(k)
            v8, vs_ = quant(v)
            upd = lambda buf, val: jax.lax.dynamic_update_slice_in_dim(
                buf, val.astype(buf.dtype), cache_pos, axis=1)
            new_cache = {"k": upd(kv_cache["k"], k8), "v": upd(kv_cache["v"], v8),
                         "k_scale": upd(kv_cache["k_scale"], ks_),
                         "v_scale": upd(kv_cache["v_scale"], vs_)}
            if not prefill_mode:
                T = new_cache["k"].shape[1]
                ck = (new_cache["k"].astype(jnp.float32) *
                      new_cache["k_scale"].astype(jnp.float32)[..., None]).astype(q.dtype)
                cv = (new_cache["v"].astype(jnp.float32) *
                      new_cache["v_scale"].astype(jnp.float32)[..., None]).astype(q.dtype)
                kj = jnp.arange(T)[None, :]
                qi = cache_pos + jnp.arange(S)[:, None]
                bias = jnp.where(kj <= qi, 0.0, -1e30).astype(jnp.float32)[None, None, None]
                out = _mask_pad_heads(_sdpa(q, ck, cv, bias), h)
                return out.reshape(B, S, h * hd) @ p["wo"], new_cache
        elif kv_cache is not None:
            ck, cv = kv_cache
            ck = jax.lax.dynamic_update_slice_in_dim(ck, k.astype(ck.dtype), cache_pos, axis=1)
            cv = jax.lax.dynamic_update_slice_in_dim(cv, v.astype(cv.dtype), cache_pos, axis=1)
            new_cache = (ck, cv)
            if not prefill_mode:
                # decode: attend to the filled cache
                T = ck.shape[1]
                kj = jnp.arange(T)[None, :]
                qi = cache_pos + jnp.arange(S)[:, None]
                bias = jnp.where(kj <= qi, 0.0, -1e30).astype(jnp.float32)[None, None, None]
                out = _mask_pad_heads(
                    _sdpa(q, ck.astype(q.dtype), cv.astype(q.dtype), bias), h)
                return out.reshape(B, S, h * hd) @ p["wo"], new_cache
    if impl == "auto":
        impl = "flash" if S * k.shape[1] > 1024 * 1024 else "dense"
    blk = 1024                             # default tuned in EXPERIMENTS §Perf it.0b
    if impl.startswith("flash@"):          # e.g. "flash@2048": block-size knob
        blk = int(impl.split("@", 1)[1])
        impl = "flash"
    if impl == "pallas" and causal and S == k.shape[1]:
        from repro.kernels.flash_attention import ops as fa_ops
        out = fa_ops.flash_attention(q, k, v, causal=True)
    elif impl == "flash":
        out = flash_attention_fused(q, k, v, causal, blk, blk)
    elif impl == "flash_novjp":
        # naive-autodiff baseline: backward saves probability blocks as scan
        # residuals (EXPERIMENTS.md §Perf baseline)
        out = flash_attention_jnp(q, k, v, causal=causal)
    else:
        bias = causal_bias(S, S) if causal else 0.0
        out = _sdpa(q, k, v, bias)
    out = _mask_pad_heads(out, h)
    out = out.reshape(B, S, h * hd) @ p["wo"]
    return out, new_cache


# ----------------------------------------------------------------------------
# SwiGLU FFN
# ----------------------------------------------------------------------------

def init_ffn(key, cfg: ModelConfig, dtype, stack: int = 0, d_ff: Optional[int] = None):
    d, f = cfg.d_model, d_ff or cfg.d_ff
    ks = jax.random.split(key, 3)
    pre = (stack,) if stack else ()
    return {
        "wg": dense_init(ks[0], pre + (d, f), dtype, d),
        "wu": dense_init(ks[1], pre + (d, f), dtype, d),
        "wd": dense_init(ks[2], pre + (f, d), dtype, f),
        "ln": jnp.ones(pre + (d,), dtype),
    }


def spec_ffn(stack: bool = False):
    pre = (None,) if stack else ()
    return {
        "wg": P(*pre, "data", "model"),
        "wu": P(*pre, "data", "model"),
        "wd": P(*pre, "model", "data"),
        "ln": P(*pre, None),
    }


def ffn(p, cfg: ModelConfig, x):
    xn = rms_norm(x, p["ln"], cfg.norm_eps)
    return (jax.nn.silu(xn @ p["wg"]) * (xn @ p["wu"])) @ p["wd"]


# ----------------------------------------------------------------------------
# Embedding / logits (tied)
# ----------------------------------------------------------------------------

def init_embed(key, cfg: ModelConfig, dtype):
    return dense_init(key, (cfg.padded_vocab, cfg.d_model), dtype, fan_in=cfg.d_model)


def spec_embed():
    return P("model", "data")


def embed(table, tokens):
    return jnp.take(table, tokens, axis=0)


def logits(table, x):
    """Tied LM head: [B,S,D] @ [V,D]^T -> [B,S,V]."""
    return jnp.einsum("bsd,vd->bsv", x, table)
