"""Encoder-decoder backbone (seamless-m4t-large-v2).

Encoder: stack of non-causal dense blocks over precomputed audio-frame
embeddings (the modality frontend is a STUB per the assignment —
``input_specs`` feeds frame embeddings directly).

Decoder: causal self-attention + cross-attention + FFN; early-exit heads on
decoder segments only (DESIGN.md §4).  Cross K/V are precomputed once from
the encoder memory at prefill and carried in the cache.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.config import ModelConfig
from repro.models import layers as L

AUDIO_DIM = 1024  # stub frontend embedding width (== d_model for seamless)


# ----------------------------------------------------------------------------
# params
# ----------------------------------------------------------------------------

def _init_enc_unit(key, cfg, dtype, n):
    k1, k2 = jax.random.split(key)
    return {"attn": L.init_attn(k1, cfg, dtype, stack=n),
            "ffn": L.init_ffn(k2, cfg, dtype, stack=n)}


def _init_dec_unit(key, cfg, dtype, n):
    k1, k2, k3 = jax.random.split(key, 3)
    return {"attn": L.init_attn(k1, cfg, dtype, stack=n),
            "xattn": L.init_attn(k2, cfg, dtype, stack=n),
            "ffn": L.init_ffn(k3, cfg, dtype, stack=n)}


def _attn_shard_flags(cfg):
    from repro.config import MODEL_AXIS_SIZE
    return (cfg.padded_heads % MODEL_AXIS_SIZE == 0,
            cfg.num_kv_heads % MODEL_AXIS_SIZE == 0)


def _dec_spec(cfg):
    qs, ks = _attn_shard_flags(cfg)
    sa = L.spec_attn(True, q_shard=qs, kv_shard=ks)
    return {"attn": sa, "xattn": sa, "ffn": L.spec_ffn(True)}


def segment_lengths(cfg: ModelConfig):
    """Decoder segments (exits between them)."""
    L_ = cfg.num_layers
    bounds = []
    for li in cfg.exit_layer_indices():
        b = min(max(1, li), L_ - 1)
        if b not in bounds:
            bounds.append(b)
    edges = [0] + sorted(bounds) + [L_]
    return [b - a for a, b in zip(edges[:-1], edges[1:])]


def init_params(cfg: ModelConfig, key, dtype=jnp.bfloat16):
    segs = segment_lengths(cfg)
    keys = jax.random.split(key, len(segs) + 4)
    params = {
        "embed": L.init_embed(keys[0], cfg, dtype),
        "audio_proj": L.dense_init(keys[1], (AUDIO_DIM, cfg.d_model), dtype, AUDIO_DIM),
        "encoder": _init_enc_unit(keys[2], cfg, dtype, cfg.num_encoder_layers),
        "enc_norm": jnp.ones((cfg.d_model,), dtype),
        "segments": tuple(_init_dec_unit(keys[3 + i], cfg, dtype, n)
                          for i, n in enumerate(segs)),
        "final_norm": jnp.ones((cfg.d_model,), dtype),
    }
    if cfg.num_exits:
        params["exit_norms"] = jnp.ones((len(segs) - 1, cfg.d_model), dtype)
    return params


def param_specs(cfg: ModelConfig):
    segs = segment_lengths(cfg)
    specs = {
        "embed": L.spec_embed(),
        "audio_proj": P(None, "data"),
        "encoder": {"attn": L.spec_attn(True, *_attn_shard_flags(cfg)),
                    "ffn": L.spec_ffn(True)},
        "enc_norm": P(None),
        "segments": tuple(_dec_spec(cfg) for _ in segs),
        "final_norm": P(None),
    }
    if cfg.num_exits:
        specs["exit_norms"] = P(None, None)
    return specs


def abstract_params(cfg: ModelConfig, dtype=jnp.bfloat16):
    return jax.eval_shape(lambda: init_params(cfg, jax.random.key(0), dtype))


# ----------------------------------------------------------------------------
# forward
# ----------------------------------------------------------------------------

def encode(cfg: ModelConfig, params, frames, *, attn_impl="auto", remat=False):
    """frames: [B, S_enc, AUDIO_DIM] stub embeddings -> [B, S_enc, D]."""
    x = frames.astype(params["audio_proj"].dtype) @ params["audio_proj"]
    B, S, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(S)[None], (B, S))

    def body(carry, lp):
        x = carry
        a, _ = L.attention(lp["attn"], cfg, x, positions, causal=False,
                           impl=attn_impl)
        x = x + a
        x = x + L.ffn(lp["ffn"], cfg, x)
        return x, None

    fn = jax.checkpoint(body) if remat else body
    x, _ = jax.lax.scan(fn, x, params["encoder"])
    return L.rms_norm(x, params["enc_norm"], cfg.norm_eps)


def _cross_kv(cfg, lp_x, memory):
    """Precompute cross-attention K/V for one stacked segment: memory
    [B,T,D] -> k/v [n, B, T, KV, hd]."""
    B, T, _ = memory.shape
    kvh, hd = cfg.num_kv_heads, cfg.hd

    def one(lp):
        mn = L.rms_norm(memory, lp["ln"], cfg.norm_eps)
        k = (mn @ lp["wk"]).reshape(B, T, kvh, hd)
        v = (mn @ lp["wv"]).reshape(B, T, kvh, hd)
        return k, v

    return jax.vmap(one)(lp_x)


def _dec_segment(cfg, segp, x, positions, cross_k, cross_v, *, attn_impl="auto",
                 seg_cache=None, cache_pos=None, remat=False, prefill_mode=False):
    def body(carry, xs):
        x = carry
        if seg_cache is None:
            lp, ck, cv = xs
            kv = None
        else:
            lp, ck, cv, kv = xs
        a, nkv = L.attention(lp["attn"], cfg, x, positions,
                             kv_cache=None if kv is None else (kv["k"], kv["v"]),
                             cache_pos=cache_pos, impl=attn_impl,
                             prefill_mode=prefill_mode)
        x = x + a
        xa, _ = L.attention(lp["xattn"], cfg, x, positions, cross_kv=(ck, cv),
                            impl=attn_impl)
        x = x + xa
        x = x + L.ffn(lp["ffn"], cfg, x)
        return x, (None if nkv is None else {"k": nkv[0], "v": nkv[1]})

    fn = jax.checkpoint(body) if remat else body
    xs = (segp, cross_k, cross_v) if seg_cache is None else (segp, cross_k, cross_v, seg_cache)
    x, new_cache = jax.lax.scan(fn, x, xs)
    return x, new_cache


def forward(cfg: ModelConfig, params, tokens, frames, *,
            exit_point: Optional[int] = None, attn_impl="auto", remat=False,
            collect_exits=True, **_):
    """Training forward: encoder over frames + teacher-forced decoder.
    Returns ([(seg_idx, normed_hidden)], aux=0)."""
    memory = encode(cfg, params, frames, attn_impl=attn_impl, remat=remat)
    B, S = tokens.shape
    x = L.embed(params["embed"], tokens)
    positions = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
    segs = segment_lengths(cfg)
    n_seg = len(segs) if exit_point is None else exit_point + 1
    outs = []
    for si in range(n_seg):
        ck, cv = _cross_kv(cfg, params["segments"][si]["xattn"], memory)
        x, _ = _dec_segment(cfg, params["segments"][si], x, positions, ck, cv,
                            attn_impl=attn_impl, remat=remat)
        is_last = si == n_seg - 1
        if not is_last and cfg.num_exits and collect_exits:
            outs.append((si, L.rms_norm(x, params["exit_norms"][si], cfg.norm_eps)))
        if is_last:
            norm = params["final_norm"] if exit_point in (None, len(segs) - 1) \
                else params["exit_norms"][si]
            outs.append((si, L.rms_norm(x, norm, cfg.norm_eps)))
    return outs, 0.0


# ----------------------------------------------------------------------------
# cache / decode
# ----------------------------------------------------------------------------

def init_cache(cfg: ModelConfig, batch: int, max_seq: int, enc_len: int,
               dtype=jnp.bfloat16):
    segs = segment_lengths(cfg)
    kvh, hd = cfg.num_kv_heads, cfg.hd
    cache = {"self": [], "cross_k": [], "cross_v": []}
    for n in segs:
        cache["self"].append({"k": jnp.zeros((n, batch, max_seq, kvh, hd), dtype),
                              "v": jnp.zeros((n, batch, max_seq, kvh, hd), dtype)})
        cache["cross_k"].append(jnp.zeros((n, batch, enc_len, kvh, hd), dtype))
        cache["cross_v"].append(jnp.zeros((n, batch, enc_len, kvh, hd), dtype))
    cache["self"] = tuple(cache["self"])
    cache["cross_k"] = tuple(cache["cross_k"])
    cache["cross_v"] = tuple(cache["cross_v"])
    return cache


def cache_specs(cfg: ModelConfig, batch_axes, seq_axes="model"):
    segs = segment_lengths(cfg)
    self_spec = P(None, batch_axes, seq_axes, None, None)
    return {
        "self": tuple({"k": self_spec, "v": self_spec} for _ in segs),
        "cross_k": tuple(self_spec for _ in segs),
        "cross_v": tuple(self_spec for _ in segs),
    }


def prefill(cfg: ModelConfig, params, tokens, cache, frames, *,
            attn_impl="auto", **_):
    """Encode + teacher-forced decoder prefill; fills self+cross caches."""
    memory = encode(cfg, params, frames, attn_impl=attn_impl)
    B, S = tokens.shape
    x = L.embed(params["embed"], tokens)
    positions = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
    new_cache = {"self": [], "cross_k": [], "cross_v": []}
    for si, segp in enumerate(params["segments"]):
        ck, cv = _cross_kv(cfg, segp["xattn"], memory)
        x, nc = _dec_segment(cfg, segp, x, positions, ck, cv,
                             attn_impl=attn_impl, seg_cache=cache["self"][si],
                             cache_pos=0, prefill_mode=True)
        new_cache["self"].append(nc)
        new_cache["cross_k"].append(ck.astype(cache["cross_k"][si].dtype))
        new_cache["cross_v"].append(cv.astype(cache["cross_v"][si].dtype))
    for k in ("self", "cross_k", "cross_v"):
        new_cache[k] = tuple(new_cache[k])
    h = L.rms_norm(x[:, -1:, :], params["final_norm"], cfg.norm_eps)
    return h, new_cache


def decode_step(cfg: ModelConfig, params, cache, tokens, pos, *,
                exit_point: Optional[int] = None, **_):
    """One decoder step against filled self/cross caches."""
    B = tokens.shape[0]
    x = L.embed(params["embed"], tokens)
    positions = jnp.broadcast_to(jnp.reshape(pos, (1, 1)), (B, 1))
    segs = segment_lengths(cfg)
    n_seg = len(segs) if exit_point is None else exit_point + 1
    new_self = list(cache["self"])
    for si in range(n_seg):
        x, nc = _dec_segment(cfg, params["segments"][si], x, positions,
                             cache["cross_k"][si], cache["cross_v"][si],
                             seg_cache=cache["self"][si], cache_pos=pos)
        new_self[si] = nc
    norm = params["final_norm"] if exit_point in (None, len(segs) - 1) \
        else params["exit_norms"][n_seg - 1]
    h = L.rms_norm(x, norm, cfg.norm_eps)
    new_cache = dict(cache)
    new_cache["self"] = tuple(new_self)
    return h, new_cache, []
